"""L2: split GPT-2 with LoRA adapters — the paper's fine-tuning model.

The model is partitioned at a transformer-block boundary (the paper's
split vector mu, constraint C3 forces a contiguous prefix on the
client): the *client* runs token+position embedding plus the first
``l_c`` blocks and emits the split-layer activations ``s``; the *main
server* runs the remaining blocks, the final LayerNorm and the (tied)
LM head, computes the loss, and returns the activation gradients
``ds`` (Sec. IV, steps a–f).

Only the LoRA adapters on the query/value projections train (the paper
applies LoRA "to the query and value matrices across all Transformer
layers"); every pre-trained weight is frozen and flows in as a runtime
argument so the Rust side can upload it to device once and reuse the
buffer every step.

Three jitted entry points are AOT-lowered per (split, rank) variant:

    client_fwd (W_c, A_c, tokens)            -> s
    server_step(W_s, A_s, s, tokens, mask)   -> (loss, dA_s..., ds)
    client_bwd (W_c, A_c, tokens, ds)        -> (dA_c...,)

``client_bwd`` recomputes the client forward (rematerialization): the
client never stores intermediate state between its two phases, matching
the paper's client-memory constraint, at the cost of one extra client
FP that the delay model already charges via varpi_j ≈ 2 rho_j.

The q/v projections go through the L1 Pallas kernel ``lora_proj`` so
the whole stack lowers into one HLO module per entry point.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import lora_proj

LN_EPS = 1e-5
LORA_ALPHA = 16.0  # adapter scaling numerator: scale = alpha / r


@dataclasses.dataclass(frozen=True)
class GPT2Config:
    """Architecture hyper-parameters for one model variant."""

    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    seq: int
    batch: int

    @property
    def d_ff(self) -> int:
        return 4 * self.d_model

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


# The end-to-end training variant: a faithfully-shaped GPT-2 scaled to
# CPU-trainable size (DESIGN.md §2 records the substitution for GPT2-S).
TINY = GPT2Config(name="tiny", vocab=256, d_model=192, n_layers=6, n_heads=6, seq=64, batch=8)
# Fast variant for runtime integration tests.
MICRO = GPT2Config(name="micro", vocab=64, d_model=32, n_layers=2, n_heads=2, seq=8, batch=2)

CONFIGS: Dict[str, GPT2Config] = {c.name: c for c in (TINY, MICRO)}


# ---------------------------------------------------------------------------
# parameter layout
# ---------------------------------------------------------------------------
#
# Frozen weights and trainable adapters are ordered, named lists of
# arrays; the same order is recorded in artifacts/manifest.json and is
# the wire format between Rust host buffers and the HLO entry points.


def block_weight_names(j: int) -> List[str]:
    p = f"h{j}."
    return [
        p + "ln1_g", p + "ln1_b",
        p + "wq", p + "bq", p + "wk", p + "bk", p + "wv", p + "bv",
        p + "wo", p + "bo",
        p + "ln2_g", p + "ln2_b",
        p + "w1", p + "b1", p + "w2", p + "b2",
    ]


def client_weight_names(cfg: GPT2Config, l_c: int) -> List[str]:
    names = ["wte", "wpe"]
    for j in range(l_c):
        names += block_weight_names(j)
    return names


def server_weight_names(cfg: GPT2Config, l_c: int) -> List[str]:
    names: List[str] = []
    for j in range(l_c, cfg.n_layers):
        names += block_weight_names(j)
    names += ["lnf_g", "lnf_b", "wte_head"]  # tied head shipped explicitly
    return names


def weight_shape(cfg: GPT2Config, name: str) -> Tuple[int, ...]:
    d, f = cfg.d_model, cfg.d_ff
    base = name.split(".")[-1]
    if name == "wte" or name == "wte_head":
        return (cfg.vocab, d)
    if name == "wpe":
        return (cfg.seq, d)
    if base in ("ln1_g", "ln1_b", "ln2_g", "ln2_b", "lnf_g", "lnf_b",
                "bq", "bk", "bv", "bo", "b2"):
        return (d,)
    if base in ("wq", "wk", "wv", "wo"):
        return (d, d)
    if base == "w1":
        return (d, f)
    if base == "b1":
        return (f,)
    if base == "w2":
        return (f, d)
    raise ValueError(f"unknown weight {name}")


def adapter_names(blocks: range) -> List[str]:
    """LoRA adapters on q and v of every block: A [d,r] then B [r,d]."""
    names = []
    for j in blocks:
        for proj in ("q", "v"):
            names += [f"h{j}.a{proj}_A", f"h{j}.a{proj}_B"]
    return names


def adapter_shape(cfg: GPT2Config, rank: int, name: str) -> Tuple[int, ...]:
    d = cfg.d_model
    return (d, rank) if name.endswith("_A") else (rank, d)


def init_weights(cfg: GPT2Config, seed: int = 0) -> Dict[str, np.ndarray]:
    """Deterministic "pre-trained" weights (GPT-2 init scheme).

    The real paper starts from the published GPT-2 checkpoint; offline we
    stand up the same architecture with the standard init (normal 0.02,
    residual projections scaled by 1/sqrt(2L)) — DESIGN.md §2.
    """
    rng = np.random.default_rng(seed)
    resid_scale = 1.0 / math.sqrt(2.0 * cfg.n_layers)
    out: Dict[str, np.ndarray] = {}
    all_names = client_weight_names(cfg, cfg.n_layers) + ["lnf_g", "lnf_b", "wte_head"]
    for name in all_names:
        shape = weight_shape(cfg, name)
        base = name.split(".")[-1]
        if base.endswith("_g") or base in ("ln1_g", "ln2_g", "lnf_g"):
            arr = np.ones(shape, np.float32)
        elif base.startswith("b") or base.endswith("_b"):
            arr = np.zeros(shape, np.float32)
        else:
            arr = rng.normal(0.0, 0.02, shape).astype(np.float32)
            if base in ("wo", "w2"):
                arr *= resid_scale
        out[name] = arr
    out["wte_head"] = out["wte"]  # tied embedding / head
    return out


def init_adapters(cfg: GPT2Config, rank: int, blocks: range, seed: int = 1) -> Dict[str, np.ndarray]:
    """LoRA init: A ~ N(0, 0.02), B = 0 (adapter starts as identity)."""
    rng = np.random.default_rng(seed)
    out: Dict[str, np.ndarray] = {}
    for name in adapter_names(blocks):
        shape = adapter_shape(cfg, rank, name)
        if name.endswith("_A"):
            out[name] = rng.normal(0.0, 0.02, shape).astype(np.float32)
        else:
            out[name] = np.zeros(shape, np.float32)
    return out


# ---------------------------------------------------------------------------
# model pieces
# ---------------------------------------------------------------------------


def _layernorm(x, g, b):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + LN_EPS) * g + b


def _attention(cfg: GPT2Config, x, w: Dict[str, jnp.ndarray], ad, scale):
    """Causal MHA; q and v projections run the fused LoRA Pallas kernel."""
    bsz, t, d = x.shape
    h, dh = cfg.n_heads, cfg.d_head
    x2 = x.reshape(bsz * t, d)
    q = lora_proj(x2, w["wq"], ad["aq_A"], ad["aq_B"], scale) + w["bq"]
    v = lora_proj(x2, w["wv"], ad["av_A"], ad["av_B"], scale) + w["bv"]
    k = jnp.dot(x2, w["wk"]) + w["bk"]

    def heads(z):
        return z.reshape(bsz, t, h, dh).transpose(0, 2, 1, 3)  # [B,h,T,dh]

    q, k, v = heads(q), heads(k), heads(v)
    att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / math.sqrt(dh)
    causal = jnp.tril(jnp.ones((t, t), bool))
    att = jnp.where(causal, att, -1e9)
    att = jax.nn.softmax(att, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", att, v)
    out = out.transpose(0, 2, 1, 3).reshape(bsz * t, d)
    out = jnp.dot(out, w["wo"]) + w["bo"]
    return out.reshape(bsz, t, d)


def _mlp(x, w):
    bsz, t, d = x.shape
    x2 = x.reshape(bsz * t, d)
    hdn = jnp.dot(x2, w["w1"]) + w["b1"]
    hdn = jax.nn.gelu(hdn, approximate=True)
    out = jnp.dot(hdn, w["w2"]) + w["b2"]
    return out.reshape(bsz, t, d)


def _block(cfg, x, w, ad, scale):
    x = x + _attention(cfg, _layernorm(x, w["ln1_g"], w["ln1_b"]), w, ad, scale)
    x = x + _mlp(_layernorm(x, w["ln2_g"], w["ln2_b"]), w)
    return x


def _weights_dict(names, arrays):
    return dict(zip(names, arrays))


# ---------------------------------------------------------------------------
# entry points (operate on flat lists — the AOT wire format)
# ---------------------------------------------------------------------------


def client_fwd(cfg: GPT2Config, l_c: int, rank: int,
               weights: List[jnp.ndarray], adapters: List[jnp.ndarray],
               tokens: jnp.ndarray) -> jnp.ndarray:
    """Client phase a: embed + first l_c blocks -> split activations s."""
    scale = LORA_ALPHA / rank
    wnames = client_weight_names(cfg, l_c)
    anames = adapter_names(range(l_c))
    wd = _weights_dict(wnames, weights)
    adl = _weights_dict(anames, adapters)
    x = wd["wte"][tokens] + wd["wpe"][None, :, :]
    for j in range(l_c):
        wblk = {n[len(f"h{j}."):]: wd[n] for n in block_weight_names(j)}
        ablk = {n[len(f"h{j}."):]: adl[n] for n in anames if n.startswith(f"h{j}.")}
        x = _block(cfg, x, wblk, ablk, scale)
    return x


def _server_loss(cfg: GPT2Config, l_c: int, rank: int,
                 weights: List[jnp.ndarray], adapters: List[jnp.ndarray],
                 s: jnp.ndarray, tokens: jnp.ndarray, mask: jnp.ndarray):
    """Server blocks + head + masked next-token cross-entropy."""
    scale = LORA_ALPHA / rank
    wnames = server_weight_names(cfg, l_c)
    anames = adapter_names(range(l_c, cfg.n_layers))
    wd = _weights_dict(wnames, weights)
    adl = _weights_dict(anames, adapters)
    x = s
    for j in range(l_c, cfg.n_layers):
        wblk = {n[len(f"h{j}."):]: wd[n] for n in block_weight_names(j)}
        ablk = {n[len(f"h{j}."):]: adl[n] for n in anames if n.startswith(f"h{j}.")}
        x = _block(cfg, x, wblk, ablk, scale)
    x = _layernorm(x, wd["lnf_g"], wd["lnf_b"])
    logits = jnp.einsum("btd,vd->btv", x, wd["wte_head"])  # tied head
    # next-token prediction: position t predicts tokens[t+1]
    logp = jax.nn.log_softmax(logits[:, :-1, :], axis=-1)
    tgt = tokens[:, 1:]
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]  # [B,T-1]
    m = mask[:, 1:]
    return jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)


def server_step(cfg: GPT2Config, l_c: int, rank: int,
                weights: List[jnp.ndarray], adapters: List[jnp.ndarray],
                s: jnp.ndarray, tokens: jnp.ndarray, mask: jnp.ndarray):
    """Server phases c–e: FP, loss, BP -> (loss, adapter grads, ds).

    Gradients w.r.t. the server adapters (Eq. 5 update is applied by the
    Rust host) and w.r.t. the incoming activations (shipped back to the
    client, Sec. IV step e).
    """

    def loss_fn(adapters, s):
        return _server_loss(cfg, l_c, rank, weights, adapters, s, tokens, mask)

    loss, (d_ad, ds) = jax.value_and_grad(loss_fn, argnums=(0, 1))(adapters, s)
    return (loss, *d_ad, ds)


def client_bwd(cfg: GPT2Config, l_c: int, rank: int,
               weights: List[jnp.ndarray], adapters: List[jnp.ndarray],
               tokens: jnp.ndarray, ds: jnp.ndarray):
    """Client phase f: recompute FP, pull ds back to adapter grads."""

    def fwd(adapters):
        return client_fwd(cfg, l_c, rank, weights, adapters, tokens)

    _, vjp = jax.vjp(fwd, adapters)
    (d_ad,) = vjp(ds)
    return tuple(d_ad)


# ---------------------------------------------------------------------------
# build-time pre-training (plain model, no LoRA, no Pallas — fast jnp path)
# ---------------------------------------------------------------------------


def _attention_plain(cfg: GPT2Config, x, w):
    bsz, t, d = x.shape
    h, dh = cfg.n_heads, cfg.d_head
    x2 = x.reshape(bsz * t, d)
    q = jnp.dot(x2, w["wq"]) + w["bq"]
    k = jnp.dot(x2, w["wk"]) + w["bk"]
    v = jnp.dot(x2, w["wv"]) + w["bv"]

    def heads(z):
        return z.reshape(bsz, t, h, dh).transpose(0, 2, 1, 3)

    q, k, v = heads(q), heads(k), heads(v)
    att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / math.sqrt(dh)
    causal = jnp.tril(jnp.ones((t, t), bool))
    att = jax.nn.softmax(jnp.where(causal, att, -1e9), axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", att, v)
    out = out.transpose(0, 2, 1, 3).reshape(bsz * t, d)
    return (jnp.dot(out, w["wo"]) + w["bo"]).reshape(bsz, t, d)


def _plain_loss(cfg: GPT2Config, wd: Dict[str, jnp.ndarray], tokens, mask):
    """Full-model next-token loss with frozen-weight layout, no adapters."""
    x = wd["wte"][tokens] + wd["wpe"][None, :, :]
    for j in range(cfg.n_layers):
        w = {n[len(f"h{j}."):]: wd[n] for n in block_weight_names(j)}
        x = x + _attention_plain(cfg, _layernorm(x, w["ln1_g"], w["ln1_b"]), w)
        x = x + _mlp(_layernorm(x, w["ln2_g"], w["ln2_b"]), w)
    x = _layernorm(x, wd["lnf_g"], wd["lnf_b"])
    logits = jnp.einsum("btd,vd->btv", x, wd["wte_head"])
    logp = jax.nn.log_softmax(logits[:, :-1, :], axis=-1)
    tgt = tokens[:, 1:]
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    m = mask[:, 1:]
    return jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)


def pretrain_weights(cfg: GPT2Config, steps: int, batch: int | None = None,
                     lr: float = 3e-4, seed: int = 0) -> Dict[str, np.ndarray]:
    """Full-weight pre-training on the restricted-template corpus.

    Stands in for the published GPT-2 checkpoint (DESIGN.md §2): the
    exported frozen weights already model the schema's surface language,
    so downstream LoRA fine-tuning (Rust side, all templates) measures
    *adaptation* capacity — which is where the paper's rank effect
    lives. Deterministic given (steps, batch, lr, seed).
    """
    from . import corpus as C

    batch = batch or cfg.batch
    weights = {k: jnp.asarray(v) for k, v in init_weights(cfg, seed=0).items()}
    # keep head tied to wte during pretraining by training wte only
    weights.pop("wte_head")

    def loss_fn(wd, tokens, mask):
        wd = dict(wd)
        wd["wte_head"] = wd["wte"]
        return _plain_loss(cfg, wd, tokens, mask)

    @jax.jit
    def step(wd, m_state, v_state, t, tokens, mask):
        loss, grads = jax.value_and_grad(loss_fn)(wd, tokens, mask)
        b1, b2, eps = 0.9, 0.999, 1e-8
        m_state = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, m_state, grads)
        v_state = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, v_state, grads)
        bc1 = 1 - b1 ** t
        bc2 = 1 - b2 ** t
        wd = jax.tree.map(
            lambda p, m, v: p - lr * (m / bc1) / (jnp.sqrt(v / bc2) + eps),
            wd, m_state, v_state,
        )
        return wd, m_state, v_state, loss

    m_state = jax.tree.map(jnp.zeros_like, weights)
    v_state = jax.tree.map(jnp.zeros_like, weights)
    first = last = None
    for i, (tokens, mask) in enumerate(
        C.pretrain_batches(cfg.seq, batch, steps, seed=seed)
    ):
        weights, m_state, v_state, loss = step(
            weights, m_state, v_state, i + 1, jnp.asarray(tokens), jnp.asarray(mask)
        )
        if first is None:
            first = float(loss)
        last = float(loss)
    print(f"  pretrain[{cfg.name}]: {steps} steps, loss {first:.3f} -> {last:.3f}")
    out = {k: np.asarray(v) for k, v in weights.items()}
    out["wte_head"] = out["wte"]  # re-tie for export
    return out


def full_loss(cfg: GPT2Config, l_c: int, rank: int,
              weights_c, adapters_c, weights_s, adapters_s, tokens, mask):
    """Composed loss client_fwd ∘ server loss — split-consistency oracle.

    For any split point the composed value must be identical; the tests
    assert this invariance across l_c, which is exactly what lets the
    optimizer move the split point without touching learning dynamics.
    """
    s = client_fwd(cfg, l_c, rank, weights_c, adapters_c, tokens)
    return _server_loss(cfg, l_c, rank, weights_s, adapters_s, s, tokens, mask)
