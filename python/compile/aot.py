"""AOT compile path: lower the L2 entry points to HLO text artifacts.

Emits, under ``artifacts/``:

    manifest.json                        — variant/entry/tensor index
    weights_<config>.bin                 — frozen weights, canonical order, raw f32
    <variant>/client_fwd.hlo.txt         — HLO text (see below)
    <variant>/server_step.hlo.txt
    <variant>/client_bwd.hlo.txt
    <variant>/adapters_client.bin        — LoRA init (A ~ N(0,.02), B = 0)
    <variant>/adapters_server.bin

Interchange is HLO **text**, not a serialized ``HloModuleProto``: the
image's xla_extension 0.5.1 rejects jax>=0.5 protos (64-bit instruction
ids); the text parser reassigns ids and round-trips cleanly
(/opt/xla-example/README.md). Everything is lowered with
``return_tuple=True`` so the Rust side always unwraps a tuple.

Run via ``make artifacts`` (a no-op when inputs are unchanged). Python
never runs again after this — the Rust binary owns the training path.
"""

from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# lowering helpers
# ---------------------------------------------------------------------------


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape: Sequence[int], dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _dtype_str(d) -> str:
    return {"float32": "f32", "int32": "i32"}[np.dtype(d).name]


class EntryBuilder:
    """Accumulates the ordered input/output signature of one entry point."""

    def __init__(self):
        self.inputs: List[dict] = []
        self.specs: List[jax.ShapeDtypeStruct] = []

    def arg(self, name: str, kind: str, shape: Sequence[int], dtype=jnp.float32):
        self.inputs.append(
            {"name": name, "kind": kind, "shape": list(shape), "dtype": _dtype_str(dtype)}
        )
        self.specs.append(_spec(shape, dtype))


def _weight_args(eb: EntryBuilder, cfg: M.GPT2Config, names: List[str], kind: str):
    for n in names:
        eb.arg(n, kind, M.weight_shape(cfg, n))


def _adapter_args(eb: EntryBuilder, cfg: M.GPT2Config, rank: int, names: List[str], kind: str):
    for n in names:
        eb.arg(n, kind, M.adapter_shape(cfg, rank, n))


def build_entries(cfg: M.GPT2Config, l_c: int, rank: int) -> Dict[str, Tuple]:
    """Return {entry_name: (callable over flat args, EntryBuilder, outputs)}."""
    B, T, d = cfg.batch, cfg.seq, cfg.d_model
    wc_names = M.client_weight_names(cfg, l_c)
    ws_names = M.server_weight_names(cfg, l_c)
    ac_names = M.adapter_names(range(l_c))
    as_names = M.adapter_names(range(l_c, cfg.n_layers))

    # --- client_fwd -------------------------------------------------------
    eb_cf = EntryBuilder()
    _weight_args(eb_cf, cfg, wc_names, "weight")
    _adapter_args(eb_cf, cfg, rank, ac_names, "adapter")
    eb_cf.arg("tokens", "data", (B, T), jnp.int32)

    def f_client_fwd(*args):
        nw, na = len(wc_names), len(ac_names)
        return (
            M.client_fwd(cfg, l_c, rank, list(args[:nw]), list(args[nw:nw + na]), args[nw + na]),
        )

    out_cf = [{"name": "s", "shape": [B, T, d], "dtype": "f32"}]

    # --- server_step ------------------------------------------------------
    eb_ss = EntryBuilder()
    _weight_args(eb_ss, cfg, ws_names, "weight")
    _adapter_args(eb_ss, cfg, rank, as_names, "adapter")
    eb_ss.arg("s", "data", (B, T, d))
    eb_ss.arg("tokens", "data", (B, T), jnp.int32)
    eb_ss.arg("mask", "data", (B, T))

    def f_server_step(*args):
        nw, na = len(ws_names), len(as_names)
        weights = list(args[:nw])
        adapters = list(args[nw:nw + na])
        s, tokens, mask = args[nw + na:]
        return M.server_step(cfg, l_c, rank, weights, adapters, s, tokens, mask)

    out_ss = (
        [{"name": "loss", "shape": [], "dtype": "f32"}]
        + [
            {"name": "d_" + n, "shape": list(M.adapter_shape(cfg, rank, n)), "dtype": "f32"}
            for n in as_names
        ]
        + [{"name": "ds", "shape": [B, T, d], "dtype": "f32"}]
    )

    # --- client_bwd -------------------------------------------------------
    eb_cb = EntryBuilder()
    _weight_args(eb_cb, cfg, wc_names, "weight")
    _adapter_args(eb_cb, cfg, rank, ac_names, "adapter")
    eb_cb.arg("tokens", "data", (B, T), jnp.int32)
    eb_cb.arg("ds", "data", (B, T, d))

    def f_client_bwd(*args):
        nw, na = len(wc_names), len(ac_names)
        weights = list(args[:nw])
        adapters = list(args[nw:nw + na])
        tokens, ds = args[nw + na:]
        return M.client_bwd(cfg, l_c, rank, weights, adapters, tokens, ds)

    out_cb = [
        {"name": "d_" + n, "shape": list(M.adapter_shape(cfg, rank, n)), "dtype": "f32"}
        for n in ac_names
    ]

    return {
        "client_fwd": (f_client_fwd, eb_cf, out_cf),
        "server_step": (f_server_step, eb_ss, out_ss),
        "client_bwd": (f_client_bwd, eb_cb, out_cb),
    }


# ---------------------------------------------------------------------------
# binary tensor files
# ---------------------------------------------------------------------------


def write_tensor_file(path: str, tensors: List[Tuple[str, np.ndarray]]) -> List[dict]:
    """Concatenate raw little-endian f32 tensors; return the index table."""
    table = []
    offset = 0
    with open(path, "wb") as f:
        for name, arr in tensors:
            arr = np.ascontiguousarray(arr, dtype=np.float32)
            f.write(arr.tobytes())
            table.append({"name": name, "shape": list(arr.shape), "offset": offset})
            offset += arr.nbytes
    return table


def canonical_weight_order(cfg: M.GPT2Config) -> List[str]:
    return M.client_weight_names(cfg, cfg.n_layers) + ["lnf_g", "lnf_b", "wte_head"]


# ---------------------------------------------------------------------------
# main
# ---------------------------------------------------------------------------


def default_variants() -> List[Tuple[str, int, int]]:
    """(config, l_c, rank) set built by `make artifacts`.

    micro: runtime integration tests. tiny: the end-to-end experiments —
    rank sweep for Fig. 3/4 and Table IV at the default split, plus a
    split ablation at the default rank.
    """
    v = [("micro", 1, 2)]
    for r in (1, 2, 4, 6, 8):
        v.append(("tiny", 2, r))
    for l_c in (1, 3):
        v.append(("tiny", l_c, 4))
    return v


def parse_variant(s: str) -> Tuple[str, int, int]:
    cfg, l_c, r = s.split(":")
    return cfg, int(l_c), int(r)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument(
        "--variant", action="append", default=None,
        help="config:l_c:rank (repeatable); default = the standard set",
    )
    ap.add_argument(
        "--pretrain-steps", type=int, default=1200,
        help="full-weight pre-training steps for the tiny config "
             "(0 = raw init; micro always exports raw init)",
    )
    args = ap.parse_args()

    out_dir = args.out
    os.makedirs(out_dir, exist_ok=True)
    variants = (
        [parse_variant(v) for v in args.variant] if args.variant else default_variants()
    )

    manifest: dict = {"format": 1, "configs": {}, "variants": {}}
    weights_cache: Dict[str, Dict[str, np.ndarray]] = {}

    for cfg_name in sorted({c for c, _, _ in variants}):
        cfg = M.CONFIGS[cfg_name]
        # tiny gets build-time pre-training (the paper's "pre-trained
        # model"); micro stays raw init (pure plumbing tests).
        if cfg_name == "tiny" and args.pretrain_steps > 0:
            weights = M.pretrain_weights(cfg, steps=args.pretrain_steps)
        else:
            weights = M.init_weights(cfg, seed=0)
        weights_cache[cfg_name] = weights
        order = canonical_weight_order(cfg)
        wfile = f"weights_{cfg_name}.bin"
        table = write_tensor_file(
            os.path.join(out_dir, wfile), [(n, weights[n]) for n in order]
        )
        manifest["configs"][cfg_name] = {
            "vocab": cfg.vocab, "d_model": cfg.d_model, "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads, "seq": cfg.seq, "batch": cfg.batch,
            "lora_alpha": M.LORA_ALPHA,
            "weights_file": wfile, "weights": table,
        }

    for cfg_name, l_c, rank in variants:
        cfg = M.CONFIGS[cfg_name]
        vname = f"{cfg_name}_s{l_c}_r{rank}"
        vdir = os.path.join(out_dir, vname)
        os.makedirs(vdir, exist_ok=True)
        weights = weights_cache[cfg_name]

        ad_c = M.init_adapters(cfg, rank, range(l_c), seed=1)
        ad_s = M.init_adapters(cfg, rank, range(l_c, cfg.n_layers), seed=2)
        tab_c = write_tensor_file(
            os.path.join(vdir, "adapters_client.bin"), list(ad_c.items())
        )
        tab_s = write_tensor_file(
            os.path.join(vdir, "adapters_server.bin"), list(ad_s.items())
        )

        entries = {}
        for ename, (fn, eb, outs) in build_entries(cfg, l_c, rank).items():
            # keep_unused: the Rust side feeds the full declared signature;
            # jit must not drop structurally-unused parameters.
            lowered = jax.jit(fn, keep_unused=True).lower(*eb.specs)
            text = to_hlo_text(lowered)
            fname = f"{vname}/{ename}.hlo.txt"
            with open(os.path.join(out_dir, fname), "w") as f:
                f.write(text)
            entries[ename] = {"file": fname, "inputs": eb.inputs, "outputs": outs}
            print(f"  {fname}: {len(eb.specs)} inputs, {len(outs)} outputs, {len(text)} chars")

        manifest["variants"][vname] = {
            "config": cfg_name, "l_c": l_c, "rank": rank,
            "lora_scale": M.LORA_ALPHA / rank,
            "adapters_client": {"file": f"{vname}/adapters_client.bin", "tensors": tab_c},
            "adapters_server": {"file": f"{vname}/adapters_server.bin", "tensors": tab_s},
            "entries": entries,
        }
        print(f"variant {vname} done")

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {os.path.join(out_dir, 'manifest.json')}")


if __name__ == "__main__":
    main()
