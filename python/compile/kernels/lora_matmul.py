"""L1 Pallas kernels: fused LoRA projection and a tiled matmul.

The paper's client/server compute hot-spot is the LoRA-augmented
projection ``y = x @ W + (alpha/r) * (x @ A) @ B`` applied to the query
and value matrices of every transformer block (Sec. IV, Table III).

TPU mapping (see DESIGN.md §Hardware-Adaptation): the grid walks
``(M/bm, N/bn)`` output tiles; BlockSpec streams an ``(bm, K)`` slab of
``x`` and a ``(K, bn)`` slab of ``W`` into VMEM per step, while the tiny
rank-r factors ``A`` (K, r) and the ``(r, bn)`` slice of ``B`` ride in
the same residency — one HBM pass over ``x`` feeds both the MXU matmul
and the LoRA bottleneck, which is the fusion the paper's FLOP model
charges as ``rho_j + r*delta_rho_j``.

On this CPU testbed every ``pallas_call`` uses ``interpret=True`` (the
CPU PJRT plugin cannot execute Mosaic custom-calls); the kernels still
lower into the exported HLO and are validated against ``ref.py``.

Autodiff: ``pallas_call`` has no automatic VJP, so ``lora_proj`` is a
``jax.custom_vjp`` whose backward pass is itself built from these
kernels (dx is another fused LoRA projection over transposed operands;
dA/dB are tiled matmuls).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["lora_proj", "matmul", "lora_proj_nograd"]

# Preferred VMEM tile edges, largest first. We pick the largest divisor of
# the actual dim so interpret mode never needs masking. 128 matches the
# MXU systolic edge; smaller fallbacks keep odd test shapes legal.
_TILE_CANDIDATES = (256, 128, 64, 32, 16, 8, 4, 2, 1)


def _pick_tile(dim: int, cap: int = 256) -> int:
    for t in _TILE_CANDIDATES:
        if t <= cap and dim % t == 0:
            return t
    return 1


# ---------------------------------------------------------------------------
# fused LoRA projection forward
# ---------------------------------------------------------------------------


def _lora_kernel(x_ref, w_ref, a_ref, b_ref, o_ref, *, scale: float):
    x = x_ref[...]
    base = jnp.dot(x, w_ref[...], preferred_element_type=jnp.float32)
    bott = jnp.dot(x, a_ref[...], preferred_element_type=jnp.float32)
    delta = jnp.dot(bott, b_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = (base + scale * delta).astype(o_ref.dtype)


def _lora_pallas(x, w, a, b, scale: float):
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"inner dims mismatch {k} vs {k2}"
    r = a.shape[1]
    bm, bn = _pick_tile(m), _pick_tile(n)
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        functools.partial(_lora_kernel, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),   # x slab: reused over j
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),   # W column panel
            pl.BlockSpec((k, r), lambda i, j: (0, 0)),    # A resident
            pl.BlockSpec((r, bn), lambda i, j: (0, j)),   # B column panel
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, w, a, b)


# ---------------------------------------------------------------------------
# tiled matmul (used by the backward pass for dA / dB)
# ---------------------------------------------------------------------------


def _matmul_kernel(x_ref, y_ref, o_ref):
    o_ref[...] = jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def matmul(x, y):
    """Tiled Pallas matmul ``x @ y`` with f32 accumulation.

    Grid over output tiles with the K dimension resident per step —
    adequate for the adapter-gradient matmuls where one of M/N is the
    tiny LoRA rank.
    """
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"inner dims mismatch {k} vs {k2}"
    bm, bn = _pick_tile(m), _pick_tile(n)
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, y)


# ---------------------------------------------------------------------------
# custom-VJP wrapper
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def lora_proj(x, w, a, b, scale: float):
    """Differentiable fused LoRA projection ``x@w + scale*(x@a)@b``.

    ``w`` is frozen: its cotangent is returned as zeros and never
    materialized as a dense [K, N] product in the backward kernels.
    """
    return _lora_pallas(x, w, a, b, scale)


def _lora_fwd(x, w, a, b, scale):
    return _lora_pallas(x, w, a, b, scale), (x, w, a, b)


def _lora_bwd(scale, res, dy):
    x, w, a, b = res
    # dx = dy @ w.T + scale*(dy @ b.T) @ a.T — same fused form, transposed.
    dx = _lora_pallas(dy, w.T, b.T, a.T, scale)
    t = matmul(dy, b.T)                       # [M, r]
    da = scale * matmul(x.T, t)               # [K, r]
    db = scale * matmul(matmul(x, a).T, dy)   # [r, N]
    dw = jnp.zeros_like(w)                    # frozen
    return dx, dw, da.astype(a.dtype), db.astype(b.dtype)


lora_proj.defvjp(_lora_fwd, _lora_bwd)


def lora_proj_nograd(x, w, a, b, scale: float):
    """Forward-only entry (no VJP bookkeeping) for inference paths."""
    return _lora_pallas(x, w, a, b, scale)
