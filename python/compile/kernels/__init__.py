"""L1: Pallas kernels for the paper's compute hot-spot (LoRA projection)."""

from . import ref  # noqa: F401
from .lora_matmul import lora_proj, lora_proj_nograd, matmul  # noqa: F401
