"""Pure-jnp reference oracle for the L1 Pallas kernels.

This is the CORE correctness signal for the compile path: every Pallas
kernel in this package must match these functions (pytest + hypothesis
sweep shapes/dtypes in ``python/tests/test_kernel.py``).

Convention (matches the paper's LoRA definition W0 + BA up to layout):

    x : [M, K]   activation slab (M = batch*seq rows)
    w : [K, N]   frozen pre-trained projection
    a : [K, r]   LoRA down-projection ("A", trainable)
    b : [r, N]   LoRA up-projection  ("B", trainable)

    y = x @ w + scale * (x @ a) @ b        with scale = alpha / r
"""

from __future__ import annotations

import jax.numpy as jnp


def lora_proj(x, w, a, b, scale):
    """Fused LoRA projection y = x@w + scale*(x@a)@b (f32 accumulation)."""
    base = jnp.dot(x, w, preferred_element_type=jnp.float32)
    bottleneck = jnp.dot(x, a, preferred_element_type=jnp.float32)
    delta = jnp.dot(bottleneck, b, preferred_element_type=jnp.float32)
    return (base + scale * delta).astype(x.dtype)


def lora_proj_grads(x, w, a, b, scale, dy):
    """Reference VJP products for ``lora_proj``.

    Returns (dx, da, db); ``w`` is frozen so dw is never materialized —
    exactly the saving LoRA exists for.
    """
    f32 = jnp.float32
    dy32 = dy.astype(f32)
    x32 = x.astype(f32)
    # dx = dy @ w.T + scale * (dy @ b.T) @ a.T
    t = jnp.dot(dy32, b.astype(f32).T)                    # [M, r]
    dx = jnp.dot(dy32, w.astype(f32).T) + scale * jnp.dot(t, a.astype(f32).T)
    # da = scale * x.T @ (dy @ b.T)
    da = scale * jnp.dot(x32.T, t)                        # [K, r]
    # db = scale * (x @ a).T @ dy
    db = scale * jnp.dot(jnp.dot(x32, a.astype(f32)).T, dy32)  # [r, N]
    return dx.astype(x.dtype), da.astype(a.dtype), db.astype(b.dtype)


def matmul(x, y):
    """Plain reference matmul with f32 accumulation."""
    return jnp.dot(x, y, preferred_element_type=jnp.float32).astype(x.dtype)
