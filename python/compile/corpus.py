"""Build-time replica of the Rust synthetic E2E-style generator.

Used ONLY for pre-training the tiny model's frozen weights (aot.py):
the paper starts from a *pre-trained* GPT-2 and LoRA-fine-tunes it on
E2E; offline we pre-train the same architecture on a restricted slice
of the schema (templates 0–1) so that the Rust-side fine-tuning corpus
(all 5 templates, `rust/src/data/corpus.rs`) contains genuinely new
realizations for the adapters to learn — giving LoRA rank a real
capacity effect, as in the paper.

Slot pools MUST stay in sync with `rust/src/data/corpus.rs` (same
schema, same byte budget); the tokenizer layout must match
`rust/src/data/tokenizer.rs` (MR · 0x1F · text, pad 0).
"""

from __future__ import annotations

import numpy as np

NAMES = ["Aromi", "Bento", "Cocum", "Eagle", "Lilly", "Rex", "Sole", "Strada",
         "Vaults", "Zizzi"]
FOODS = ["Thai", "Chinese", "French", "Indian", "Italian", "Turkish", "English"]
PRICES = ["cheap", "moderate", "high"]
AREAS = ["centre", "river"]
RATINGS = ["low", "average", "high"]

SEP = 0x1F
PAD = 0


def render(name: int, food: int, price: int, area: int, rating: int, tpl: int):
    n, f, p = NAMES[name], FOODS[food], PRICES[price]
    a, r = AREAS[area], RATINGS[rating]
    mr = f"{n}|{f}|{p}"
    text = [
        f"{n} serves {p} {f} food.",
        f"{n} is a {p} {f} spot.",
        f"Try {n} for {f} food.",
        f"{n} has {r} rated {f}.",
        f"{n} is {p}, at the {a}.",
    ][tpl]
    return mr, text


def encode(mr: str, text: str, seq: int):
    """Byte-level layout identical to rust Tokenizer::encode."""
    b = list(mr.encode()) + [SEP] + list(text.encode())
    if len(b) > seq:
        return None
    mask = [0.0] * (len(mr) + 1) + [1.0] * len(text)
    b += [PAD] * (seq - len(b))
    mask += [0.0] * (seq - len(mask))
    return np.array(b, np.int32), np.array(mask, np.float32)


def pretrain_batches(seq: int, batch: int, steps: int, seed: int = 0,
                     templates=(0, 1)):
    """Yield (tokens [B,T] i32, mask [B,T] f32) pre-training batches.

    Restricted to `templates` so the downstream fine-tuning corpus
    (all templates) has unseen structure to adapt to.
    """
    rng = np.random.default_rng(seed)
    for _ in range(steps):
        toks = np.zeros((batch, seq), np.int32)
        masks = np.zeros((batch, seq), np.float32)
        for i in range(batch):
            enc = None
            for _ in range(64):  # guard: seq too small to fit any sample
                enc = encode(*render(
                    rng.integers(len(NAMES)), rng.integers(len(FOODS)),
                    rng.integers(len(PRICES)), rng.integers(len(AREAS)),
                    rng.integers(len(RATINGS)),
                    int(rng.choice(templates)),
                ), seq)
                if enc is not None:
                    break
            if enc is None:
                raise ValueError(f"no schema sample fits seq={seq}")
            toks[i], masks[i] = enc
        yield toks, masks
