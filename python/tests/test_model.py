"""L2 correctness: split GPT-2 + LoRA model invariants.

Key oracle: for any split point l_c the composed loss
client_fwd ∘ server_loss must be identical — this is what lets the L3
optimizer move the split point freely (P3) without touching learning.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

jax.config.update("jax_platform_name", "cpu")

CFG = M.MICRO
RANK = 2


def _data(seed=0):
    rng = np.random.default_rng(seed)
    tokens = jnp.asarray(
        rng.integers(0, CFG.vocab, (CFG.batch, CFG.seq)), jnp.int32
    )
    mask = jnp.ones((CFG.batch, CFG.seq), jnp.float32)
    return tokens, mask


def _setup(l_c, rank=RANK, seed=0):
    w = M.init_weights(CFG, seed=seed)
    wc = [jnp.asarray(w[n]) for n in M.client_weight_names(CFG, l_c)]
    ws = [jnp.asarray(w[n]) for n in M.server_weight_names(CFG, l_c)]
    ac = [
        jnp.asarray(v) for v in M.init_adapters(CFG, rank, range(l_c), seed=1).values()
    ]
    a_s = [
        jnp.asarray(v)
        for v in M.init_adapters(CFG, rank, range(l_c, CFG.n_layers), seed=2).values()
    ]
    return wc, ws, ac, a_s


def test_client_fwd_shape():
    tokens, _ = _data()
    wc, _, ac, _ = _setup(1)
    s = M.client_fwd(CFG, 1, RANK, wc, ac, tokens)
    assert s.shape == (CFG.batch, CFG.seq, CFG.d_model)
    assert jnp.isfinite(s).all()


def test_server_step_shapes():
    tokens, mask = _data()
    wc, ws, ac, a_s = _setup(1)
    s = M.client_fwd(CFG, 1, RANK, wc, ac, tokens)
    out = M.server_step(CFG, 1, RANK, ws, a_s, s, tokens, mask)
    loss, grads, ds = out[0], out[1:-1], out[-1]
    assert loss.shape == ()
    assert float(loss) > 0
    assert ds.shape == s.shape
    names = M.adapter_names(range(1, CFG.n_layers))
    assert len(grads) == len(names)
    for g, n in zip(grads, names):
        assert g.shape == M.adapter_shape(CFG, RANK, n)


def test_client_bwd_shapes():
    tokens, mask = _data()
    wc, ws, ac, a_s = _setup(1)
    s = M.client_fwd(CFG, 1, RANK, wc, ac, tokens)
    ds = M.server_step(CFG, 1, RANK, ws, a_s, s, tokens, mask)[-1]
    grads = M.client_bwd(CFG, 1, RANK, wc, ac, tokens, ds)
    names = M.adapter_names(range(1))
    assert len(grads) == len(names)
    for g, n in zip(grads, names):
        assert g.shape == M.adapter_shape(CFG, RANK, n)
        assert jnp.isfinite(g).all()


@pytest.mark.parametrize("l_c", [1, CFG.n_layers - 1])
def test_split_consistency(l_c):
    """Composed loss must not depend on where the model is split."""
    tokens, mask = _data()
    losses = []
    for split in (l_c, 1):
        wc, ws, ac, a_s = _setup(split)
        losses.append(
            float(M.full_loss(CFG, split, RANK, wc, ac, ws, a_s, tokens, mask))
        )
    np.testing.assert_allclose(losses[0], losses[1], rtol=1e-5)


def test_split_grads_match_joint_autodiff():
    """Client grads via the split path (server ds -> client_bwd) must equal
    d(full composed loss)/d(client adapters)."""
    l_c = 1
    tokens, mask = _data()
    wc, ws, ac, a_s = _setup(l_c)

    # split path
    s = M.client_fwd(CFG, l_c, RANK, wc, ac, tokens)
    ds = M.server_step(CFG, l_c, RANK, ws, a_s, s, tokens, mask)[-1]
    g_split = M.client_bwd(CFG, l_c, RANK, wc, ac, tokens, ds)

    # joint path
    def loss_fn(ac):
        return M.full_loss(CFG, l_c, RANK, wc, ac, ws, a_s, tokens, mask)

    g_joint = jax.grad(loss_fn)(ac)
    for a, b in zip(g_split, g_joint):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)


def test_initial_loss_near_uniform():
    """With B=0 adapters and random frozen weights, loss ≈ ln(vocab)."""
    tokens, mask = _data()
    wc, ws, ac, a_s = _setup(1)
    loss = float(M.full_loss(CFG, 1, RANK, wc, ac, ws, a_s, tokens, mask))
    assert abs(loss - np.log(CFG.vocab)) < 1.0


def test_mask_zeroes_positions():
    """Fully masked batch elements must not contribute to the loss."""
    tokens, mask = _data()
    wc, ws, ac, a_s = _setup(1)
    full = float(M.full_loss(CFG, 1, RANK, wc, ac, ws, a_s, tokens, mask))
    # Mask out the second half of the batch: loss should equal the loss
    # computed on the first half alone.
    m2 = mask.at[CFG.batch // 2 :].set(0.0)
    half = float(M.full_loss(CFG, 1, RANK, wc, ac, ws, a_s, tokens, m2))
    t3 = tokens[: CFG.batch // 2]
    # recompute on the half-batch via masking (shape must stay fixed)
    assert np.isfinite(half)
    assert abs(half - full) < 1.0  # same distribution, sanity bound
    del t3


def test_sgd_steps_reduce_loss():
    """A few SGD steps on the adapters must reduce the training loss —
    the end-to-end learning signal of the whole split stack."""
    l_c = 1
    tokens, mask = _data(seed=3)
    wc, ws, ac, a_s = _setup(l_c)
    lr = 0.05

    def loss_fn(ac, a_s):
        return M.full_loss(CFG, l_c, RANK, wc, ac, ws, a_s, tokens, mask)

    l0 = float(loss_fn(ac, a_s))
    for _ in range(5):
        s = M.client_fwd(CFG, l_c, RANK, wc, ac, tokens)
        out = M.server_step(CFG, l_c, RANK, ws, a_s, s, tokens, mask)
        g_s, ds = out[1:-1], out[-1]
        g_c = M.client_bwd(CFG, l_c, RANK, wc, ac, tokens, ds)
        ac = [p - lr * g for p, g in zip(ac, g_c)]
        a_s = [p - lr * g for p, g in zip(a_s, g_s)]
    l1 = float(loss_fn(ac, a_s))
    assert l1 < l0, f"loss did not decrease: {l0} -> {l1}"


def test_weight_tables_cover_all_layers():
    for l_c in range(CFG.n_layers + 1):
        c = M.client_weight_names(CFG, l_c)
        s = M.server_weight_names(CFG, l_c)
        assert len(c) + len(s) == 2 + 16 * CFG.n_layers + 3
        assert set(c) & set(s) == set()


def test_adapter_init_B_zero():
    ad = M.init_adapters(CFG, 4, range(CFG.n_layers))
    for n, v in ad.items():
        if n.endswith("_B"):
            assert not v.any()
        else:
            assert v.any()
