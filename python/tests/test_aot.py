"""AOT exporter invariants: signatures, tensor files, HLO round-trip.

Runs the full export for the `micro` variant into a temp dir and checks
the manifest contract the Rust runtime depends on. (Ordering between
weights/adapters/data inputs is the wire format — a regression here
breaks the Rust side silently, so it is pinned by tests.)
"""

import json
import os
import subprocess
import sys
import tempfile

import numpy as np
import pytest

from compile import aot
from compile import model as M


@pytest.fixture(scope="module")
def exported():
    d = tempfile.mkdtemp(prefix="sfllm_aot_")
    argv = sys.argv
    sys.argv = ["aot", "--out", d, "--variant", "micro:1:2"]
    try:
        aot.main()
    finally:
        sys.argv = argv
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    return d, manifest


def test_manifest_structure(exported):
    d, m = exported
    assert "micro" in m["configs"]
    assert "micro_s1_r2" in m["variants"]
    v = m["variants"]["micro_s1_r2"]
    assert set(v["entries"]) == {"client_fwd", "server_step", "client_bwd"}
    assert v["l_c"] == 1 and v["rank"] == 2
    assert v["lora_scale"] == M.LORA_ALPHA / 2


def test_input_ordering_weights_adapters_data(exported):
    _, m = exported
    for entry in m["variants"]["micro_s1_r2"]["entries"].values():
        kinds = [i["kind"] for i in entry["inputs"]]
        # contiguous: weights, then adapters, then data
        order = {"weight": 0, "adapter": 1, "data": 2}
        ranks = [order[k] for k in kinds]
        assert ranks == sorted(ranks), f"non-contiguous kinds: {kinds}"
        assert kinds[-1] == "data"


def test_weight_file_matches_table(exported):
    d, m = exported
    cfg_rec = m["configs"]["micro"]
    path = os.path.join(d, cfg_rec["weights_file"])
    raw = np.fromfile(path, dtype="<f4")
    total = sum(int(np.prod(t["shape"])) for t in cfg_rec["weights"])
    assert len(raw) == total
    # offsets are contiguous and 4-byte aligned
    off = 0
    for t in cfg_rec["weights"]:
        assert t["offset"] == off
        off += int(np.prod(t["shape"])) * 4


def test_weights_reproduce_init(exported):
    d, m = exported
    cfg_rec = m["configs"]["micro"]
    path = os.path.join(d, cfg_rec["weights_file"])
    raw = np.fromfile(path, dtype="<f4")
    w = M.init_weights(M.MICRO, seed=0)
    first = cfg_rec["weights"][0]
    arr = raw[: int(np.prod(first["shape"]))].reshape(first["shape"])
    np.testing.assert_array_equal(arr, w[first["name"]])


def test_hlo_files_nonempty_and_text(exported):
    d, m = exported
    for entry in m["variants"]["micro_s1_r2"]["entries"].values():
        path = os.path.join(d, entry["file"])
        with open(path) as f:
            text = f.read()
        assert len(text) > 1000
        assert text.lstrip().startswith("HloModule")
        # entry computation must carry all declared parameters
        # (jit(keep_unused=True) — see aot.py)
        assert text.count("parameter(") >= len(entry["inputs"])


def test_entry_signature_shapes(exported):
    _, m = exported
    cfg = M.MICRO
    v = m["variants"]["micro_s1_r2"]
    cf = v["entries"]["client_fwd"]
    assert cf["inputs"][-1]["shape"] == [cfg.batch, cfg.seq]
    assert cf["outputs"][0]["shape"] == [cfg.batch, cfg.seq, cfg.d_model]
    ss = v["entries"]["server_step"]
    assert ss["outputs"][0]["shape"] == []  # loss scalar
    assert ss["outputs"][-1]["shape"] == [cfg.batch, cfg.seq, cfg.d_model]  # ds


def test_adapter_files_match_manifest(exported):
    d, m = exported
    v = m["variants"]["micro_s1_r2"]
    for key in ("adapters_client", "adapters_server"):
        rec = v[key]
        raw = np.fromfile(os.path.join(d, rec["file"]), dtype="<f4")
        total = sum(int(np.prod(t["shape"])) for t in rec["tensors"])
        assert len(raw) == total
        # A tensors nonzero, B tensors zero
        for t in rec["tensors"]:
            n = int(np.prod(t["shape"]))
            chunk = raw[t["offset"] // 4 : t["offset"] // 4 + n]
            if t["name"].endswith("_B"):
                assert not chunk.any()
            else:
                assert chunk.any()
