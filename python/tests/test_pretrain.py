"""Build-time pre-training invariants (model.pretrain_weights).

Kept tiny (micro config, few steps) — the full tiny-model pretraining
runs once inside `make artifacts`.
"""

import numpy as np
import pytest

from compile import corpus as C
from compile import model as M


def test_corpus_matches_rust_generator_schema():
    # pools must match rust/src/data/corpus.rs (wire compatibility)
    assert len(C.NAMES) == 10 and max(len(n) for n in C.NAMES) <= 6
    assert len(C.FOODS) == 7 and max(len(f) for f in C.FOODS) <= 7
    assert C.PRICES == ["cheap", "moderate", "high"]
    # every rendered sample fits the tiny window
    for name in range(len(C.NAMES)):
        for tpl in range(5):
            mr, text = C.render(name, 1, 2, 0, 1, tpl)
            assert len(mr) + 1 + len(text) <= 64, (mr, text)


def test_encode_layout_matches_rust_tokenizer():
    mr, text = C.render(0, 0, 0, 0, 0, 0)
    tokens, mask = C.encode(mr, text, 64)
    assert tokens.shape == (64,) and mask.shape == (64,)
    assert tokens[len(mr)] == C.SEP
    assert mask[: len(mr) + 1].sum() == 0
    assert mask.sum() == len(text)
    assert (tokens[len(mr) + 1 + len(text):] == C.PAD).all()


def test_pretrain_batches_deterministic_and_restricted():
    b1 = list(C.pretrain_batches(64, 2, 3, seed=5))
    b2 = list(C.pretrain_batches(64, 2, 3, seed=5))
    for (t1, m1), (t2, m2) in zip(b1, b2):
        np.testing.assert_array_equal(t1, t2)
        np.testing.assert_array_equal(m1, m2)


def test_micro_window_too_small_raises_not_hangs():
    with pytest.raises(ValueError, match="no schema sample fits"):
        list(C.pretrain_batches(8, 2, 1, seed=0))


@pytest.mark.parametrize("steps", [2])
def test_pretrain_deterministic_and_decreasing(steps):
    # tiny is the only config that pretrains in production (seq 64)
    w1 = M.pretrain_weights(M.TINY, steps=steps, batch=2, seed=1)
    w2 = M.pretrain_weights(M.TINY, steps=steps, batch=2, seed=1)
    for k in w1:
        np.testing.assert_array_equal(w1[k], w2[k])
    # weights actually moved from init
    w0 = M.init_weights(M.TINY, seed=0)
    moved = any((w1[k] != w0[k]).any() for k in w0 if k != "wte_head")
    assert moved
    # head stays tied
    np.testing.assert_array_equal(w1["wte_head"], w1["wte"])


def test_pick_tile_divides_and_caps():
    from compile.kernels.lora_matmul import _pick_tile

    for dim in [1, 7, 64, 192, 512, 768, 8192]:
        t = _pick_tile(dim)
        assert dim % t == 0
        assert t <= 256
    # documented §Perf tile choices
    assert _pick_tile(512) == 256  # tiny M
    assert _pick_tile(192) == 64   # tiny d
    assert _pick_tile(768) == 256  # gpt2-s d
