"""L1 correctness: Pallas kernels vs the pure-jnp oracle in ref.py.

Hypothesis sweeps shapes, ranks, scales and dtypes; every case asserts
allclose between the kernel and the reference, for the forward pass and
for all three backward products.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import lora_proj, lora_proj_nograd, matmul, ref

jax.config.update("jax_platform_name", "cpu")


def _rand(key, shape, dtype, scale=1.0):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def _make_operands(seed, m, k, n, r, dtype):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = _rand(ks[0], (m, k), dtype)
    w = _rand(ks[1], (k, n), dtype, 0.2)
    a = _rand(ks[2], (k, r), dtype, 0.2)
    b = _rand(ks[3], (r, n), dtype, 0.2)
    dy = _rand(ks[4], (m, n), dtype)
    return x, w, a, b, dy


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=1e-4, atol=1e-4)


def _close(got, want, dtype):
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


# ---------------------------------------------------------------------------
# directed cases
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,k,n,r", [(8, 16, 16, 1), (64, 128, 128, 4), (128, 64, 192, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lora_fwd_matches_ref(m, k, n, r, dtype):
    x, w, a, b, _ = _make_operands(0, m, k, n, r, dtype)
    scale = 2.0 / r
    _close(lora_proj(x, w, a, b, scale), ref.lora_proj(x, w, a, b, scale), dtype)


@pytest.mark.parametrize("m,k,n,r", [(8, 16, 16, 1), (64, 128, 128, 4), (32, 48, 96, 6)])
def test_lora_bwd_matches_ref(m, k, n, r):
    dtype = jnp.float32
    x, w, a, b, dy = _make_operands(1, m, k, n, r, dtype)
    scale = 2.0 / r

    def loss(x, w, a, b):
        return (lora_proj(x, w, a, b, scale) * dy).sum()

    dx, dw, da, db = jax.grad(loss, argnums=(0, 1, 2, 3))(x, w, a, b)
    dxr, dar, dbr = ref.lora_proj_grads(x, w, a, b, scale, dy)
    _close(dx, dxr, dtype)
    _close(da, dar, dtype)
    _close(db, dbr, dtype)
    assert not np.asarray(dw).any(), "frozen weight must get zero cotangent"


def test_lora_grads_match_autodiff_of_ref():
    """Our hand-written VJP == jax.grad of the reference expression."""
    x, w, a, b, dy = _make_operands(2, 24, 32, 40, 4, jnp.float32)
    scale = 0.5

    def loss_kernel(x, a, b):
        return (lora_proj(x, w, a, b, scale) * dy).sum()

    def loss_ref(x, a, b):
        return (ref.lora_proj(x, w, a, b, scale) * dy).sum()

    gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(x, a, b)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(x, a, b)
    for got, want in zip(gk, gr):
        _close(got, want, jnp.float32)


def test_zero_adapters_reduce_to_plain_matmul():
    """With A=B=0 the fused kernel must equal the frozen projection."""
    x, w, a, b, _ = _make_operands(3, 16, 32, 24, 4, jnp.float32)
    z = jnp.zeros_like(a), jnp.zeros_like(b)
    _close(lora_proj(x, w, *z, 1.0), ref.matmul(x, w), jnp.float32)


def test_scale_linearity():
    """lora(x,..,2s) - lora(x,..,s) == s * (x@a)@b."""
    x, w, a, b, _ = _make_operands(4, 16, 32, 24, 2, jnp.float32)
    y1 = lora_proj(x, w, a, b, 1.0)
    y2 = lora_proj(x, w, a, b, 2.0)
    _close(y2 - y1, ref.matmul(ref.matmul(x, a), b), jnp.float32)


def test_nograd_entry_matches():
    x, w, a, b, _ = _make_operands(5, 16, 32, 24, 2, jnp.float32)
    _close(lora_proj_nograd(x, w, a, b, 0.7), lora_proj(x, w, a, b, 0.7), jnp.float32)


@pytest.mark.parametrize("m,k,n", [(1, 1, 1), (7, 13, 5), (64, 128, 64)])
def test_matmul_matches_ref(m, k, n):
    ks = jax.random.split(jax.random.PRNGKey(6), 2)
    x = _rand(ks[0], (m, k), jnp.float32)
    y = _rand(ks[1], (k, n), jnp.float32)
    _close(matmul(x, y), ref.matmul(x, y), jnp.float32)


# ---------------------------------------------------------------------------
# hypothesis sweeps
# ---------------------------------------------------------------------------

_dims = st.sampled_from([1, 2, 3, 4, 6, 8, 12, 16, 24, 32, 48, 64, 96, 128])
_ranks = st.sampled_from([1, 2, 4, 6, 8])


@settings(max_examples=25, deadline=None)
@given(m=_dims, k=_dims, n=_dims, r=_ranks, seed=st.integers(0, 2**16))
def test_hypothesis_lora_fwd(m, k, n, r, seed):
    x, w, a, b, _ = _make_operands(seed, m, k, n, r, jnp.float32)
    scale = 1.0 / r
    _close(lora_proj(x, w, a, b, scale), ref.lora_proj(x, w, a, b, scale), jnp.float32)


@settings(max_examples=10, deadline=None)
@given(m=_dims, k=_dims, n=_dims, r=_ranks, seed=st.integers(0, 2**16))
def test_hypothesis_lora_bwd(m, k, n, r, seed):
    x, w, a, b, dy = _make_operands(seed, m, k, n, r, jnp.float32)
    scale = 1.0 / r

    def loss(x, a, b):
        return (lora_proj(x, w, a, b, scale) * dy).sum()

    dx, da, db = jax.grad(loss, argnums=(0, 1, 2))(x, a, b)
    dxr, dar, dbr = ref.lora_proj_grads(x, w, a, b, scale, dy)
    _close(dx, dxr, jnp.float32)
    _close(da, dar, jnp.float32)
    _close(db, dbr, jnp.float32)


@settings(max_examples=20, deadline=None)
@given(m=_dims, k=_dims, n=_dims, seed=st.integers(0, 2**16))
def test_hypothesis_matmul(m, k, n, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    x = _rand(ks[0], (m, k), jnp.float32)
    y = _rand(ks[1], (k, n), jnp.float32)
    _close(matmul(x, y), ref.matmul(x, y), jnp.float32)


@settings(max_examples=10, deadline=None)
@given(
    m=st.sampled_from([8, 16, 32]),
    k=st.sampled_from([16, 32, 64]),
    n=st.sampled_from([16, 32, 64]),
    r=_ranks,
    seed=st.integers(0, 2**16),
)
def test_hypothesis_lora_fwd_bf16(m, k, n, r, seed):
    x, w, a, b, _ = _make_operands(seed, m, k, n, r, jnp.bfloat16)
    scale = 1.0 / r
    _close(lora_proj(x, w, a, b, scale), ref.lora_proj(x, w, a, b, scale), jnp.bfloat16)
