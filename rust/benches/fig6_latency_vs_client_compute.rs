//! Fig. 6 — total training latency vs client computing capability
//! (FLOPs per cycle, i.e. 1/κ_k), proposed vs baselines a–d.
//!
//! Expected shape: latency falls as clients strengthen; the gap to
//! baseline c (random split) narrows, since with strong clients the
//! split location matters less.
//!
//! Writes `results/fig6_latency_vs_client_compute.csv`.

use sfllm::opt::PolicyRegistry;
use sfllm::sim::{ScenarioBuilder, SweepAxis, SweepRunner};

fn main() -> anyhow::Result<()> {
    let base = ScenarioBuilder::preset("paper")?;
    let cfg = base.config();
    let reg = PolicyRegistry::paper_suite(&cfg.train.ranks, cfg.system.seed, 5);
    // paper default: 1024 FLOPs/cycle on clients
    let report = SweepRunner::new(&base)
        .over(SweepAxis::client_flops_per_cycle(&[256.0, 512.0, 1024.0, 2048.0, 4096.0]))
        .policies(reg.resolve("all")?)
        .run()?;
    println!("Fig.6: total latency (s) vs client compute (FLOPs/cycle)");
    report.print_table();
    report.write_csv("results/fig6_latency_vs_client_compute.csv")?;
    println!("series written to results/fig6_latency_vs_client_compute.csv");
    Ok(())
}
