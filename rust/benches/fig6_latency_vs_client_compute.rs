//! Fig. 6 — total training latency vs client computing capability
//! (FLOPs per cycle, i.e. 1/κ_k), proposed vs baselines a–d.
//!
//! Expected shape: latency falls as clients strengthen; the gap to
//! baseline c (random split) narrows, since with strong clients the
//! split location matters less.
//!
//! Writes `results/fig6_latency_vs_client_compute.csv`.

use sfllm::config::Config;
use sfllm::delay::ConvergenceModel;
use sfllm::opt::baselines::compare_all;
use sfllm::util::csv::CsvWriter;

fn main() -> anyhow::Result<()> {
    let base = Config::paper_defaults();
    let conv = ConvergenceModel::paper_default();
    // paper default: 1024 FLOPs/cycle on clients
    let flops_per_cycle = [256.0, 512.0, 1024.0, 2048.0, 4096.0];
    let mut csv = CsvWriter::create(
        "results/fig6_latency_vs_client_compute.csv",
        &["client_flops_per_cycle", "proposed", "baseline_a", "baseline_b", "baseline_c", "baseline_d"],
    )?;
    println!("Fig.6: total latency (s) vs client compute (FLOPs/cycle)");
    println!(
        "{:>12} {:>12} {:>12} {:>12} {:>12} {:>12} {:>10}",
        "FLOPs/cyc", "proposed", "a", "b", "c", "d", "gap to c"
    );
    for &fpc in &flops_per_cycle {
        let mut cfg = base.clone();
        cfg.system.kappa_client = 1.0 / fpc;
        let scn = sfllm::sim::build_scenario(&cfg)?;
        let [p, a, b, c, d] = compare_all(&scn, &conv, &cfg.train.ranks, cfg.system.seed, 5)?;
        println!(
            "{:>12.0} {:>12.1} {:>12.1} {:>12.1} {:>12.1} {:>12.1} {:>9.0}%",
            fpc, p, a, b, c, d, 100.0 * (c / p - 1.0)
        );
        csv.row_f64(&[fpc, p, a, b, c, d])?;
    }
    csv.flush()?;
    println!("series written to results/fig6_latency_vs_client_compute.csv");
    Ok(())
}
