//! Fig. 4 — steps required to reach a target validation loss vs LoRA
//! rank, extracted from the Fig. 3 measurement runs, plus the fitted
//! E(r) law the resource optimizer (P4) consumes.
//!
//! Run `cargo bench --bench fig3_convergence` first (cargo bench runs
//! them in this order by default); this bench reads
//! `results/fig3_val_loss.csv`, computes steps-to-target per rank,
//! fits `E(r) = e_inf (1 + c/r^alpha)`, and writes
//! `results/fig4_steps_to_target.csv`.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};
use sfllm::delay::ConvergenceModel;
use sfllm::util::csv::{read_csv, CsvWriter};

fn main() -> Result<()> {
    let (header, rows) = read_csv("results/fig3_val_loss.csv")
        .context("run `cargo bench --bench fig3_convergence` first")?;
    if header != ["rank", "step", "val_loss", "ppl"] {
        bail!("unexpected fig3 csv header: {header:?}");
    }
    // rank -> [(step, val_loss)]
    let mut series: BTreeMap<usize, Vec<(usize, f64)>> = BTreeMap::new();
    for r in rows {
        let rank: usize = r[0].parse::<f64>()? as usize;
        let step: usize = r[1].parse::<f64>()? as usize;
        let loss: f64 = r[2].parse()?;
        series.entry(rank).or_default().push((step, loss));
    }
    if series.is_empty() {
        bail!("no data in fig3 csv");
    }

    // target: the worst (largest) final loss across ranks, so every rank
    // reaches it; mirrors the paper's "steps to achieve target loss"
    let target = series
        .values()
        .map(|v| v.iter().map(|&(_, l)| l).fold(f64::INFINITY, f64::min))
        .fold(f64::NEG_INFINITY, f64::max);

    println!("Fig.4: steps to reach target validation loss {target:.4}");
    let mut csv = CsvWriter::create(
        "results/fig4_steps_to_target.csv",
        &["rank", "steps_to_target"],
    )?;
    let mut points = Vec::new();
    for (&rank, curve) in &series {
        let steps = curve
            .iter()
            .find(|&&(_, l)| l <= target)
            .map(|&(s, _)| s)
            .unwrap_or(curve.last().unwrap().0);
        println!("  rank {rank}: {steps} steps");
        csv.row_f64(&[rank as f64, steps as f64])?;
        points.push((rank, steps as f64));
    }
    csv.flush()?;

    // shape check: monotone non-increasing in rank (diminishing returns)
    let decreasing = points.windows(2).all(|w| w[1].1 <= w[0].1);
    println!(
        "  [{}] steps-to-target non-increasing with rank",
        if decreasing { "ok" } else { "WARN" }
    );

    if points.len() >= 2 {
        let fit = ConvergenceModel::fit(&points);
        if let ConvergenceModel::Fitted { e_inf, c, alpha } = &fit {
            println!(
                "fitted E(r) = {e_inf:.1} * (1 + {c:.3} / r^{alpha:.2})  \
                 — feed into delay::ConvergenceModel for P4"
            );
            for &(r, measured) in &points {
                println!("    rank {r}: fit {:.1} vs measured {measured:.0}", fit.rounds(r));
            }
        }
    }
    println!("written results/fig4_steps_to_target.csv");
    Ok(())
}
