//! Table III — computational complexity of GPT2-S with LoRA: parameter
//! counts and per-component forward FLOPs.
//!
//! Parameter counts reproduce the paper exactly. FLOPs are computed
//! from first principles (2 FLOPs/MAC, per sample at seq 512); the
//! paper's GFLOP column does not follow a single per-sample/per-batch
//! convention we could identify, so we print both and compare the
//! *shape* (FFN > MHA >> LoRA/LN; LM head dominates), which holds.
//!
//! Writes `results/table3_complexity.csv`.

use sfllm::model::{Gpt2Config, WorkloadProfile};
use sfllm::util::csv::CsvWriter;

fn main() -> anyhow::Result<()> {
    let cfg = Gpt2Config::gpt2_s();
    let seq = 512usize;
    let p = WorkloadProfile::new(cfg.clone(), seq);
    let t = seq as f64;
    let d = cfg.d_model as f64;
    let f = cfg.d_ff() as f64;
    let h = cfg.n_heads as f64;
    let g = 1e9;

    let ln = 8.0 * t * d; // one LayerNorm
    let mha = 8.0 * t * d * d + 4.0 * t * t * d + 5.0 * h * t * t;
    let ffn = 4.0 * t * d * f + 8.0 * t * f;
    let lora = 8.0 * t * d; // per rank, q+v adapters
    let head = p.head_fwd_flops;

    // (component, our params, paper params, our GFLOPs, paper GFLOPs)
    let rows: Vec<(&str, f64, f64, f64, f64)> = vec![
        ("token_embedding", cfg.params_token_embedding() as f64, 38.6e6, f64::NAN, f64::NAN),
        ("position_encoding", cfg.params_position_encoding() as f64, 0.786e6, f64::NAN, f64::NAN),
        ("layernorm", cfg.params_layernorm() as f64, 1.5e3, ln / g, 0.025),
        ("multi_head_attention", cfg.params_attention() as f64, 2.36e6, mha / g, 257.7),
        ("lora_adapter_per_rank", cfg.params_lora_per_rank_per_proj() as f64, 1.5e3, lora / g, 0.050),
        ("feed_forward", cfg.params_ffn() as f64, 4.72e6, ffn / g, 309.2),
        ("final_layernorm", cfg.params_layernorm() as f64, 1.5e3, ln / g, 0.025),
        ("lm_head", f64::NAN, f64::NAN, head / g, 1264.1),
    ];

    println!("Table III: GPT2-S with LoRA (per sample, seq={seq})");
    println!(
        "{:<24} {:>12} {:>12} {:>12} {:>12}",
        "component", "params", "paper", "GFLOPs", "paper"
    );
    let mut csv = CsvWriter::create(
        "results/table3_complexity.csv",
        &["component", "params", "paper_params", "gflops", "paper_gflops"],
    )?;
    for (name, params, pp, gf, pg) in &rows {
        println!(
            "{:<24} {:>12} {:>12} {:>12} {:>12}",
            name,
            fmt(*params),
            fmt(*pp),
            fmt3(*gf),
            fmt3(*pg)
        );
        csv.row(&[
            name.to_string(),
            params.to_string(),
            pp.to_string(),
            gf.to_string(),
            pg.to_string(),
        ])?;
    }
    csv.flush()?;

    // shape assertions (reported, not just silently checked)
    let checks = [
        // 5% tolerance: the paper prints rounded values ("1.5K" for 1536)
        ("params match paper (<5% each)", {
            rows.iter()
                .filter(|r| r.1.is_finite())
                .all(|r| (r.1 - r.2).abs() / r.2 < 0.05)
        }),
        ("FFN > MHA per block", ffn > mha),
        ("LM head dominates any single block", head > mha + ffn),
        ("LoRA per rank << block compute", lora < 0.01 * (mha + ffn)),
    ];
    println!();
    for (name, ok) in checks {
        println!("  [{}] {name}", if ok { "ok" } else { "FAIL" });
    }
    println!("total params: {:.2}M (paper: ~124M)", cfg.params_total() as f64 / 1e6);
    println!("written results/table3_complexity.csv");
    Ok(())
}

fn fmt(v: f64) -> String {
    if !v.is_finite() {
        "-".into()
    } else if v >= 1e6 {
        format!("{:.2}M", v / 1e6)
    } else if v >= 1e3 {
        format!("{:.1}K", v / 1e3)
    } else {
        format!("{v:.0}")
    }
}

fn fmt3(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.3}")
    } else {
        "-".into()
    }
}
