//! Table IV — converged test perplexity: centralized LoRA fine-tuning
//! vs SfLLM, across ranks {1, 2, 4, 6, 8}.
//!
//! Centralized = the same model and optimizer with ALL data on one
//! node (K=1: no split-aggregation noise, every sample in one shard),
//! trained for the same number of steps. SfLLM numbers are reused from
//! the Fig. 3 runs (`results/fig3_final_ppl.csv`) when present, else
//! recomputed here.
//!
//! Expected shape (paper): SfLLM PPL within a whisker of centralized at
//! every rank; higher rank → (weakly) better PPL.
//!
//! Environment knobs: SFLLM_ROUNDS (default 15), SFLLM_CLIENTS (default 3).

use std::collections::BTreeMap;

use anyhow::Result;
use sfllm::coordinator::{train, OptKind, TrainOptions};
use sfllm::runtime::{Manifest, SflModel, SflRuntime};
use sfllm::util::csv::{read_csv, CsvWriter};

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn run(variant: &str, clients: usize, rounds: usize) -> Result<f64> {
    let opts = TrainOptions {
        clients,
        local_steps: 12,
        global_rounds: rounds,
        lr_client: 1e-3,
        lr_server: 1e-3,
        corpus_size: 2000,
        val_size: 200,
        eval_batches: 4,
        non_iid: false,
        optimizer: OptKind::Adam,
        byte_corpus: false,
        save_adapters: None,
        retry_budget: 2,
        retry_backoff_s: 0.05,
        seed: 42,
    };
    let v = variant.to_string();
    let report = train(&opts, move || {
        let m = Manifest::load("artifacts")?;
        Ok(Box::new(SflRuntime::load(&m, &v)?) as Box<dyn SflModel>)
    })?;
    Ok(report.final_ppl)
}

fn main() -> Result<()> {
    let rounds = env_usize("SFLLM_ROUNDS", 15);
    let clients = env_usize("SFLLM_CLIENTS", 3);
    let ranks = [1usize, 2, 4, 6, 8];

    // SfLLM side: reuse fig3 results if available
    let mut sfllm_ppl: BTreeMap<usize, f64> = BTreeMap::new();
    if let Ok((_, rows)) = read_csv("results/fig3_final_ppl.csv") {
        for r in rows {
            if let (Ok(rank), Ok(ppl)) = (r[0].parse::<f64>(), r[1].parse::<f64>()) {
                sfllm_ppl.insert(rank as usize, ppl);
            }
        }
        println!("(SfLLM column reused from results/fig3_final_ppl.csv)");
    }

    let mut csv = CsvWriter::create(
        "results/table4_perplexity.csv",
        &["rank", "centralized_ppl", "sfllm_ppl", "gap"],
    )?;
    println!("Table IV: converged validation perplexity (tiny GPT-2, E2E-style corpus)");
    println!("{:>6} {:>14} {:>12} {:>10}", "rank", "centralized", "SfLLM", "gap");
    let mut max_gap: f64 = 0.0;
    for &rank in &ranks {
        let variant = format!("tiny_s2_r{rank}");
        let central = run(&variant, 1, rounds)?;
        let sfl = match sfllm_ppl.get(&rank) {
            Some(&p) => p,
            None => run(&variant, clients, rounds)?,
        };
        let gap = sfl - central;
        max_gap = max_gap.max(gap.abs());
        println!("{rank:>6} {central:>14.4} {sfl:>12.4} {gap:>+10.4}");
        csv.row_f64(&[rank as f64, central, sfl, gap])?;
    }
    csv.flush()?;
    println!(
        "max |gap| = {max_gap:.4} (paper: SfLLM within ~0.001 of centralized \
         on full-scale GPT2-S; shape criterion: comparable, no collapse)"
    );
    println!("written results/table4_perplexity.csv");
    Ok(())
}
