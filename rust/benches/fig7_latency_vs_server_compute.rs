//! Fig. 7 — total training latency vs main-server computing capability,
//! proposed vs baselines a–d.
//!
//! Expected shape: latency falls with server capacity; the persistent
//! gap between baselines b and d shows rank optimization contributing
//! more than communication tuning in this regime (paper's reading).
//!
//! Writes `results/fig7_latency_vs_server_compute.csv`.

use sfllm::opt::PolicyRegistry;
use sfllm::sim::{ScenarioBuilder, SweepAxis, SweepRunner};

fn main() -> anyhow::Result<()> {
    let base = ScenarioBuilder::preset("paper")?;
    let cfg = base.config();
    let reg = PolicyRegistry::paper_suite(&cfg.train.ranks, cfg.system.seed, 5);
    let report = SweepRunner::new(&base)
        .over(SweepAxis::server_compute_ghz(&[2.5, 5.0, 10.0, 20.0, 40.0]))
        .policies(reg.resolve("all")?)
        .run()?;
    println!("Fig.7: total latency (s) vs main-server compute");
    report.print_table();
    report.write_csv("results/fig7_latency_vs_server_compute.csv")?;
    println!("series written to results/fig7_latency_vs_server_compute.csv");
    Ok(())
}
