//! Fig. 7 — total training latency vs main-server computing capability,
//! proposed vs baselines a–d.
//!
//! Expected shape: latency falls with server capacity; the persistent
//! gap between baselines b and d shows rank optimization contributing
//! more than communication tuning in this regime (paper's reading).
//!
//! Writes `results/fig7_latency_vs_server_compute.csv`.

use sfllm::config::Config;
use sfllm::delay::ConvergenceModel;
use sfllm::opt::baselines::compare_all;
use sfllm::util::csv::CsvWriter;

fn main() -> anyhow::Result<()> {
    let base = Config::paper_defaults();
    let conv = ConvergenceModel::paper_default();
    let f_servers = [2.5e9, 5e9, 10e9, 20e9, 40e9];
    let mut csv = CsvWriter::create(
        "results/fig7_latency_vs_server_compute.csv",
        &["f_server_ghz", "proposed", "baseline_a", "baseline_b", "baseline_c", "baseline_d"],
    )?;
    println!("Fig.7: total latency (s) vs main-server compute");
    println!(
        "{:>10} {:>12} {:>12} {:>12} {:>12} {:>12}",
        "f_s (GHz)", "proposed", "a", "b", "c", "d"
    );
    for &fs in &f_servers {
        let mut cfg = base.clone();
        cfg.system.f_server = fs;
        let scn = sfllm::sim::build_scenario(&cfg)?;
        let [p, a, b, c, d] = compare_all(&scn, &conv, &cfg.train.ranks, cfg.system.seed, 5)?;
        println!(
            "{:>10.1} {:>12.1} {:>12.1} {:>12.1} {:>12.1} {:>12.1}",
            fs / 1e9, p, a, b, c, d
        );
        csv.row_f64(&[fs / 1e9, p, a, b, c, d])?;
    }
    csv.flush()?;
    println!("series written to results/fig7_latency_vs_server_compute.csv");
    Ok(())
}
