//! Fig. 3 — validation loss vs training steps for LoRA ranks
//! {1, 2, 4, 6, 8}: REAL split-federated training of the tiny GPT-2
//! through the full three-layer stack (Pallas kernels → AOT artifacts →
//! PJRT → Rust coordinator), on the synthetic E2E-style corpus.
//!
//! Expected shape (paper): higher rank converges in fewer steps, with
//! diminishing returns beyond a point.
//!
//! Writes `results/fig3_val_loss.csv` (rank, step, val_loss, ppl) and
//! `results/fig3_final_ppl.csv` (consumed by the Table IV bench), plus
//! `results/fig3_train_loss.csv`.
//!
//! Environment knobs (used to trade fidelity for wall-clock):
//!   SFLLM_ROUNDS   global rounds E        (default 15)
//!   SFLLM_CLIENTS  number of clients K    (default 3)

// Timing harness: wall-clock reads are the point (clippy mirror of
// sfllm-lint D002 opts out here).
#![allow(clippy::disallowed_methods)]

use anyhow::Result;
use sfllm::coordinator::{train, OptKind, TrainOptions};
use sfllm::runtime::{Manifest, SflModel, SflRuntime};
use sfllm::util::csv::CsvWriter;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() -> Result<()> {
    let rounds = env_usize("SFLLM_ROUNDS", 15);
    let clients = env_usize("SFLLM_CLIENTS", 3);
    let ranks = [1usize, 2, 4, 6, 8];

    let mut val_csv = CsvWriter::create(
        "results/fig3_val_loss.csv",
        &["rank", "step", "val_loss", "ppl"],
    )?;
    let mut train_csv =
        CsvWriter::create("results/fig3_train_loss.csv", &["rank", "step", "train_loss"])?;
    let mut ppl_csv = CsvWriter::create("results/fig3_final_ppl.csv", &["rank", "ppl"])?;

    println!(
        "Fig.3: SfLLM convergence vs LoRA rank (tiny GPT-2, K={clients}, I=12, E={rounds})"
    );
    for &rank in &ranks {
        let variant = format!("tiny_s2_r{rank}");
        let opts = TrainOptions {
            clients,
            local_steps: 12,
            global_rounds: rounds,
            lr_client: 1e-3,
            lr_server: 1e-3,
            corpus_size: 2000,
            val_size: 200,
            eval_batches: 4,
            non_iid: false,
            optimizer: OptKind::Adam,
            byte_corpus: false,
            save_adapters: None,
            retry_budget: 2,
            retry_backoff_s: 0.05,
            seed: 42, // same data/placement for every rank
        };
        let v2 = variant.clone();
        let t0 = std::time::Instant::now();
        let report = train(&opts, move || {
            let m = Manifest::load("artifacts")?;
            Ok(Box::new(SflRuntime::load(&m, &v2)?) as Box<dyn SflModel>)
        })?;
        for (i, l) in report.train_loss.iter().enumerate() {
            train_csv.row_f64(&[rank as f64, (i + 1) as f64, *l])?;
        }
        for &(s, l) in &report.val_loss {
            val_csv.row_f64(&[rank as f64, s as f64, l, l.exp()])?;
        }
        ppl_csv.row_f64(&[rank as f64, report.final_ppl])?;
        let first = report.val_loss.first().map(|x| x.1).unwrap_or(f64::NAN);
        let last = report.val_loss.last().map(|x| x.1).unwrap_or(f64::NAN);
        println!(
            "  rank {rank}: val {first:.4} -> {last:.4} (ppl {:.3}) in {} steps [{:.0}s wall]",
            report.final_ppl,
            report.train_loss.len(),
            t0.elapsed().as_secs_f64()
        );
    }
    val_csv.flush()?;
    train_csv.flush()?;
    ppl_csv.flush()?;
    println!(
        "series written to results/fig3_val_loss.csv, results/fig3_train_loss.csv, \
         results/fig3_final_ppl.csv"
    );
    Ok(())
}
