//! Fig. 8 — total training latency vs maximum client transmit power,
//! proposed vs baselines a–d.
//!
//! Expected shape: more transmit power, lower latency for every scheme;
//! the proposed allocation keeps the lowest curve, and the benefit of
//! power optimization is most pronounced when power (not bandwidth) is
//! the binding constraint.
//!
//! Writes `results/fig8_latency_vs_power.csv`.

use sfllm::opt::PolicyRegistry;
use sfllm::sim::{ScenarioBuilder, SweepAxis, SweepRunner};

fn main() -> anyhow::Result<()> {
    let base = ScenarioBuilder::preset("paper")?;
    let cfg = base.config();
    let reg = PolicyRegistry::paper_suite(&cfg.train.ranks, cfg.system.seed, 5);
    let report = SweepRunner::new(&base)
        .over(SweepAxis::p_max_dbm(&[29.76, 33.76, 37.76, 41.76, 45.76]))
        .policies(reg.resolve("all")?)
        .run()?;
    println!("Fig.8: total latency (s) vs max client transmit power");
    report.print_table();
    report.write_csv("results/fig8_latency_vs_power.csv")?;
    println!("series written to results/fig8_latency_vs_power.csv");
    Ok(())
}
