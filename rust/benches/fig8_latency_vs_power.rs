//! Fig. 8 — total training latency vs maximum client transmit power,
//! proposed vs baselines a–d.
//!
//! Expected shape: more transmit power, lower latency for every scheme;
//! the proposed allocation keeps the lowest curve, and the benefit of
//! power optimization is most pronounced when power (not bandwidth) is
//! the binding constraint.
//!
//! Writes `results/fig8_latency_vs_power.csv`.

use sfllm::config::Config;
use sfllm::delay::ConvergenceModel;
use sfllm::opt::baselines::compare_all;
use sfllm::util::csv::CsvWriter;

fn main() -> anyhow::Result<()> {
    let base = Config::paper_defaults();
    let conv = ConvergenceModel::paper_default();
    let p_max_dbm = [29.76, 33.76, 37.76, 41.76, 45.76];
    let mut csv = CsvWriter::create(
        "results/fig8_latency_vs_power.csv",
        &["p_max_dbm", "proposed", "baseline_a", "baseline_b", "baseline_c", "baseline_d"],
    )?;
    println!("Fig.8: total latency (s) vs max client transmit power");
    println!(
        "{:>10} {:>12} {:>12} {:>12} {:>12} {:>12}",
        "p (dBm)", "proposed", "a", "b", "c", "d"
    );
    for &pm in &p_max_dbm {
        let mut cfg = base.clone();
        cfg.system.p_max_dbm = pm;
        let scn = sfllm::sim::build_scenario(&cfg)?;
        let [p, a, b, c, d] = compare_all(&scn, &conv, &cfg.train.ranks, cfg.system.seed, 5)?;
        println!(
            "{:>10.2} {:>12.1} {:>12.1} {:>12.1} {:>12.1} {:>12.1}",
            pm, p, a, b, c, d
        );
        csv.row_f64(&[pm, p, a, b, c, d])?;
    }
    csv.flush()?;
    println!("series written to results/fig8_latency_vs_power.csv");
    Ok(())
}
