//! Fig. 5 — total training latency vs per-link bandwidth, proposed
//! scheme against baselines a–d (paper Sec. VII-C).
//!
//! Expected shape: proposed lowest everywhere; up to ~60% below
//! baseline a at low bandwidth; the gap to baseline b (random comm)
//! narrows as bandwidth grows and computation becomes the bottleneck.
//!
//! Writes `results/fig5_latency_vs_bandwidth.csv`.

use sfllm::opt::PolicyRegistry;
use sfllm::sim::{ScenarioBuilder, SweepAxis, SweepRunner};

fn main() -> anyhow::Result<()> {
    let base = ScenarioBuilder::preset("paper")?;
    let cfg = base.config();
    let reg = PolicyRegistry::paper_suite(&cfg.train.ranks, cfg.system.seed, 5);
    let report = SweepRunner::new(&base)
        .over(SweepAxis::bandwidth_khz(&[125.0, 250.0, 500.0, 1000.0, 2000.0]))
        .policies(reg.resolve("all")?)
        .run()?;
    println!("Fig.5: total latency (s) vs per-link bandwidth");
    report.print_table();
    report.write_csv("results/fig5_latency_vs_bandwidth.csv")?;
    println!("series written to results/fig5_latency_vs_bandwidth.csv");
    Ok(())
}
