//! Fig. 5 — total training latency vs per-link bandwidth, proposed
//! scheme against baselines a–d (paper Sec. VII-C).
//!
//! Expected shape: proposed lowest everywhere; up to ~60% below
//! baseline a at low bandwidth; the gap to baseline b (random comm)
//! narrows as bandwidth grows and computation becomes the bottleneck.
//!
//! Writes `results/fig5_latency_vs_bandwidth.csv`.

use sfllm::config::Config;
use sfllm::delay::ConvergenceModel;
use sfllm::opt::baselines::compare_all;
use sfllm::util::csv::CsvWriter;

fn main() -> anyhow::Result<()> {
    let base = Config::paper_defaults();
    let conv = ConvergenceModel::paper_default();
    let bandwidths = [125e3, 250e3, 500e3, 1000e3, 2000e3];
    let mut csv = CsvWriter::create(
        "results/fig5_latency_vs_bandwidth.csv",
        &["bandwidth_khz", "proposed", "baseline_a", "baseline_b", "baseline_c", "baseline_d"],
    )?;
    println!("Fig.5: total latency (s) vs per-link bandwidth");
    println!(
        "{:>10} {:>12} {:>12} {:>12} {:>12} {:>12} {:>8}",
        "B (kHz)", "proposed", "a", "b", "c", "d", "red. vs a"
    );
    for &bw in &bandwidths {
        let mut cfg = base.clone();
        cfg.system.bandwidth_main_hz = bw;
        cfg.system.bandwidth_fed_hz = bw;
        let scn = sfllm::sim::build_scenario(&cfg)?;
        let [p, a, b, c, d] = compare_all(&scn, &conv, &cfg.train.ranks, cfg.system.seed, 5)?;
        println!(
            "{:>10.0} {:>12.1} {:>12.1} {:>12.1} {:>12.1} {:>12.1} {:>7.0}%",
            bw / 1e3, p, a, b, c, d, 100.0 * (1.0 - p / a)
        );
        csv.row_f64(&[bw / 1e3, p, a, b, c, d])?;
    }
    csv.flush()?;
    println!("series written to results/fig5_latency_vs_bandwidth.csv");
    Ok(())
}
