//! L3 hot-path micro-benchmarks (hand-rolled harness; no criterion in
//! the offline crate set). Times the pieces the BCD optimizer and the
//! coordinator hit per iteration/step:
//!
//! * P2 exact power solve (the BCD inner-loop hot spot),
//! * Algorithm 2 greedy assignment,
//! * one full BCD optimize() on the Table-II scenario,
//! * delay-model evaluation,
//! * FedAvg + Adam step on tiny-sized adapters,
//! * coordinator round overhead over the mock model (channel + thread
//!   cost with zero compute).
//!
//! §Perf in EXPERIMENTS.md records these numbers before/after tuning.

use std::time::Instant;

use sfllm::coordinator::mock::MockModel;
use sfllm::coordinator::{train, OptKind, Optimizer, TrainOptions};
use sfllm::delay::ConvergenceModel;
use sfllm::model::lora::{AdapterSet, Tensor};
use sfllm::opt::bcd::{self, BcdOptions};
use sfllm::opt::{assignment, power};
use sfllm::sim::ScenarioBuilder;

fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) -> f64 {
    // warmup
    for _ in 0..iters.div_ceil(10).max(1) {
        f();
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    let (val, unit) = if per < 1e-6 {
        (per * 1e9, "ns")
    } else if per < 1e-3 {
        (per * 1e6, "us")
    } else if per < 1.0 {
        (per * 1e3, "ms")
    } else {
        (per, "s ")
    };
    println!("  {name:<44} {val:>10.2} {unit}/op   ({iters} iters)");
    per
}

fn main() -> anyhow::Result<()> {
    let scn = ScenarioBuilder::new().build()?;
    let conv = ConvergenceModel::paper_default();

    println!("L3 hot-path micro-benchmarks (Table II scenario, K=5, M=N=20):");

    // Algorithm 2
    bench("algorithm2 greedy assignment", 2000, || {
        let a = assignment::algorithm2(&scn, 6, 4);
        std::hint::black_box(a);
    });

    // P2 exact solve
    let a2 = assignment::algorithm2(&scn, 6, 4);
    let alloc = sfllm::delay::Allocation {
        assign_main: a2.assign_main,
        assign_fed: a2.assign_fed,
        psd_main: vec![0.0; 20],
        psd_fed: vec![0.0; 20],
        l_c: 6,
        rank: 4,
    };
    bench("P2 exact power solve (bisection+waterfill)", 500, || {
        let s = power::solve_power(&scn, &alloc).unwrap();
        std::hint::black_box(s);
    });

    // delay evaluation
    let mut alloc2 = alloc.clone();
    let ps = power::solve_power(&scn, &alloc)?;
    alloc2.psd_main = ps.psd_main;
    alloc2.psd_fed = ps.psd_fed;
    bench("delay model total_delay eval", 20000, || {
        let t = scn.total_delay(&alloc2, &conv);
        std::hint::black_box(t);
    });

    // full BCD
    bench("Algorithm 3 full optimize()", 100, || {
        let r = bcd::optimize(&scn, &conv, &BcdOptions::default()).unwrap();
        std::hint::black_box(r.objective);
    });

    // adapter math at tiny-model scale: 2 blocks x (q,v) x (A,B), d=192 r=4
    let mk = || AdapterSet {
        tensors: (0..8)
            .map(|i| Tensor {
                name: format!("t{i}"),
                shape: vec![192, 4],
                data: vec![0.01; 192 * 4],
            })
            .collect(),
    };
    let sets: Vec<AdapterSet> = (0..5).map(|_| mk()).collect();
    let refs: Vec<&AdapterSet> = sets.iter().collect();
    bench("FedAvg over K=5 tiny adapter sets", 5000, || {
        let avg = AdapterSet::fedavg(&refs, &[1.0; 5]).unwrap();
        std::hint::black_box(avg);
    });
    let mut params = mk();
    let grads = mk();
    let mut opt = Optimizer::new(OptKind::Adam, 1e-3);
    bench("Adam step on tiny adapter set", 5000, || {
        opt.step(&mut params, &grads).unwrap();
    });

    // coordinator round overhead: mock model => pure channel/thread cost
    println!("\ncoordinator overhead (mock model, zero device compute):");
    let t0 = Instant::now();
    let opts = TrainOptions {
        clients: 5,
        local_steps: 10,
        global_rounds: 20,
        lr_client: 0.01,
        lr_server: 0.01,
        corpus_size: 200,
        val_size: 40,
        eval_batches: 1,
        non_iid: false,
        optimizer: OptKind::Sgd,
        byte_corpus: false,
        save_adapters: None,
        seed: 1,
    };
    let report = train(&opts, || Ok(Box::new(MockModel::new(8, 64, 192))))?;
    let total = t0.elapsed().as_secs_f64();
    let steps = report.train_loss.len();
    println!(
        "  {steps} steps x K=5 in {total:.3}s -> {:.2} ms/step of pure \
         coordination (device calls are no-ops)",
        1e3 * total / steps as f64
    );
    Ok(())
}
