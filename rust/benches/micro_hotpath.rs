//! L3 hot-path micro-benchmarks (hand-rolled harness; no criterion in
//! the offline crate set). Times the pieces the BCD optimizer and the
//! coordinator hit per iteration/step:
//!
//! * P2 exact power solve (the BCD inner-loop hot spot), cold vs
//!   warm-started (`solve_power_hinted`: previous optimum as the
//!   bisection hint + reused probe buffers — bit-identical results),
//! * Algorithm 2 greedy assignment — the incremental heap engine vs
//!   the naive reference scan, including a K ∈ {5, 100, 1000} scaling
//!   axis on the `many_clients` preset,
//! * one full BCD optimize() on the Table-II scenario,
//! * delay-model evaluation,
//! * the joint split×rank grid: clone-per-candidate `total_delay` vs
//!   the cached `DelayEvaluator` (the P3/P4 engine), plus an
//!   energy-objective axis (delay vs energy vs weighted scans on the
//!   same evaluator) and a large-K axis on the `many_clients` preset
//!   showing the evaluator scaling to thousands of clients,
//! * FedAvg + Adam step on tiny-sized adapters,
//! * coordinator round overhead over the mock model (channel + thread
//!   cost with zero compute).
//!
//! §Perf in EXPERIMENTS.md records these numbers before/after tuning.

// Timing harness: wall-clock reads are the point (clippy mirror of
// sfllm-lint D002 opts out here).
#![allow(clippy::disallowed_methods)]

use std::time::Instant;

use sfllm::coordinator::mock::MockModel;
use sfllm::coordinator::{train, OptKind, Optimizer, TrainOptions};
use sfllm::delay::{ConvergenceModel, DelayEvaluator, WorkloadCache};
use sfllm::model::lora::{AdapterSet, Tensor};
use sfllm::opt::bcd::{self, BcdOptions};
use sfllm::opt::policy::Proposed;
use sfllm::opt::{assignment, power, Objective};
use sfllm::sim::{ReOptStrategy, RoundSimulator, ScenarioBuilder};

fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) -> f64 {
    // warmup
    for _ in 0..iters.div_ceil(10).max(1) {
        f();
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    let (val, unit) = if per < 1e-6 {
        (per * 1e9, "ns")
    } else if per < 1e-3 {
        (per * 1e6, "us")
    } else if per < 1.0 {
        (per * 1e3, "ms")
    } else {
        (per, "s ")
    };
    println!("  {name:<44} {val:>10.2} {unit}/op   ({iters} iters)");
    per
}

fn main() -> anyhow::Result<()> {
    let scn = ScenarioBuilder::new().build()?;
    let conv = ConvergenceModel::paper_default();

    println!("L3 hot-path micro-benchmarks (Table II scenario, K=5, M=N=20):");

    // Algorithm 2: the heap engine (production path) vs the naive
    // reference scan it is bit-identical to
    let t_heap = bench("algorithm2 greedy assignment (heap engine)", 2000, || {
        let a = assignment::algorithm2(&scn, 6, 4);
        std::hint::black_box(a);
    });
    let t_naive = bench("algorithm2 greedy assignment (naive reference)", 500, || {
        let a = assignment::algorithm2_reference(&scn, 6, 4);
        std::hint::black_box(a);
    });
    println!("  -> heap engine speedup at K=5: {:.1}x", t_naive / t_heap);

    // P2 exact solve
    let a2 = assignment::algorithm2(&scn, 6, 4);
    let alloc = sfllm::delay::Allocation {
        assign_main: a2.assign_main,
        assign_fed: a2.assign_fed,
        psd_main: vec![0.0; 20],
        psd_fed: vec![0.0; 20],
        l_c: 6,
        rank: 4,
    };
    let t_cold = bench("P2 exact power solve (cold)", 500, || {
        let s = power::solve_power(&scn, &alloc).unwrap();
        std::hint::black_box(s);
    });
    let seed_sol = power::solve_power(&scn, &alloc)?;
    let p2_hint = Some((seed_sol.t1, seed_sol.t3));
    let mut p2_scratch = power::PowerScratch::default();
    let t_warm = bench("P2 exact power solve (warm: hint+scratch)", 500, || {
        let s = power::solve_power_hinted(&scn, &alloc, p2_hint, &mut p2_scratch).unwrap();
        std::hint::black_box(s);
    });
    println!("  -> warm-start P2 speedup: {:.2}x (bit-identical solution)", t_cold / t_warm);

    // delay evaluation
    let mut alloc2 = alloc.clone();
    let ps = power::solve_power(&scn, &alloc)?;
    alloc2.psd_main = ps.psd_main;
    alloc2.psd_fed = ps.psd_fed;
    bench("delay model total_delay eval", 20000, || {
        let t = scn.total_delay(&alloc2, &conv);
        std::hint::black_box(t);
    });

    // full BCD
    bench("Algorithm 3 full optimize()", 100, || {
        let r = bcd::optimize(&scn, &conv, &BcdOptions::default()).unwrap();
        std::hint::black_box(r.objective);
    });

    // the P3/P4 joint grid, old way vs cached evaluator. The clone path
    // is what best_split/best_rank did per candidate before delay::eval:
    // clone the whole Allocation, recompute every subchannel rate.
    let ranks = [1usize, 2, 4, 6, 8];
    let splits: Vec<usize> = scn.profile.split_candidates().collect();
    let grid = splits.len() * ranks.len();
    println!("\njoint split x rank grid ({grid} candidates):");
    let t_clone = bench("grid scan, clone-per-candidate total_delay", 500, || {
        let mut best = f64::INFINITY;
        for &l_c in &splits {
            for &r in &ranks {
                let mut cand = alloc2.clone();
                cand.l_c = l_c;
                cand.rank = r;
                best = best.min(scn.total_delay(&cand, &conv));
            }
        }
        std::hint::black_box(best);
    });
    let cache = WorkloadCache::new();
    let t_cached = bench("grid scan, cached DelayEvaluator (incl. build)", 500, || {
        let ev = DelayEvaluator::new(&scn, &alloc2, &conv, cache.table_for(&scn.profile, &ranks));
        std::hint::black_box(ev.best_split_rank());
    });
    let ev = DelayEvaluator::new(&scn, &alloc2, &conv, cache.table_for(&scn.profile, &ranks));
    bench("grid scan, cached DelayEvaluator (prebuilt)", 2000, || {
        std::hint::black_box(ev.best_split_rank());
    });
    println!(
        "  -> cached evaluator speedup on the full grid: {:.1}x{}",
        t_clone / t_cached,
        if t_cached < t_clone { "" } else { "  (REGRESSION: cache slower than clones!)" }
    );

    // objective axis on the same prebuilt evaluator: the energy and
    // weighted scans pay one extra O(K) energy pass per candidate; the
    // delay-objective scan must cost the same as the plain one
    println!("\nobjective-aware grid scan ({grid} candidates, prebuilt evaluator):");
    bench("grid scan, objective = delay", 2000, || {
        std::hint::black_box(ev.best_split_rank_obj(&Objective::Delay));
    });
    bench("grid scan, objective = energy", 2000, || {
        std::hint::black_box(ev.best_split_rank_obj(&Objective::Energy));
    });
    bench("grid scan, objective = weighted:0.05", 2000, || {
        std::hint::black_box(ev.best_split_rank_obj(&Objective::Weighted { lambda: 0.05 }));
    });
    bench("single eval_energy(l_c, r)", 20000, || {
        std::hint::black_box(ev.eval_energy(6, 4));
    });

    // large-K axis: the evaluator at production client counts
    println!("\nDelayEvaluator at scale (many_clients preset):");
    for k in [100usize, 1000, 4000] {
        let m = k.max(1024);
        let scn_k = ScenarioBuilder::preset("many_clients")?
            .clients(k)
            .subchannels(m, m)
            .build()?;
        let alloc_k = bcd::initial_alloc(&scn_k, 6, 4);
        let table = cache.table_for(&scn_k.profile, &ranks);
        let ev_k = DelayEvaluator::new(&scn_k, &alloc_k, &conv, table.clone());
        bench(
            &format!("evaluator build, K={k} M={m}"),
            if k >= 4000 { 50 } else { 200 },
            || {
                let e = DelayEvaluator::new(&scn_k, &alloc_k, &conv, table.clone());
                std::hint::black_box(&e);
            },
        );
        bench(
            &format!("full {grid}-point grid scan, K={k}"),
            if k >= 4000 { 50 } else { 200 },
            || {
                std::hint::black_box(ev_k.best_split_rank());
            },
        );
    }

    // Algorithm 2 at scale: heap engine vs naive reference on the
    // many_clients preset — measured through the same sfllm::bench axis
    // BENCH_pr5.json tracks, so these numbers cannot drift from the
    // CI-validated ones (the acceptance bar is >= 5x at K=1000)
    println!("\nAlgorithm 2 at scale (many_clients preset, heap vs reference):");
    for p in sfllm::bench::algorithm2_axis(0.15)? {
        println!(
            "  K={:<5} M={:<5} heap {:>10.2} us   reference {:>10.2} us   -> {:.1}x{}",
            p.k,
            p.m,
            p.heap_us,
            p.reference_us,
            p.speedup,
            if p.k == 1000 && p.speedup < 5.0 {
                "  (BELOW the 5x acceptance bar!)"
            } else {
                ""
            }
        );
    }

    // round-varying engine: one full dynamic run per op. one_shot pays
    // E(r) evaluator rebuilds; every_round adds a BCD re-solve per
    // round, all sharing one WorkloadCache across the whole run.
    println!("\nround-varying simulator (paper preset, rho=0.8, ~28 rounds):");
    let scn_dyn = ScenarioBuilder::new()
        .channel_correlation(0.8)
        .dynamics_seed(7)
        .build()?;
    let dyn_cache = WorkloadCache::new();
    let ranks_vec: Vec<usize> = ranks.to_vec();
    let sim = RoundSimulator::new(&scn_dyn, &conv, &dyn_cache, &ranks_vec);
    let proposed = Proposed::with_ranks(&ranks_vec);
    bench("dynamic run, one_shot", 50, || {
        let r = sim.run(&proposed, ReOptStrategy::OneShot).unwrap();
        std::hint::black_box(r.realized_delay);
    });
    bench("dynamic run, periodic:5", 10, || {
        let r = sim.run(&proposed, ReOptStrategy::Periodic(5)).unwrap();
        std::hint::black_box(r.realized_delay);
    });
    bench("dynamic run, every_round", 5, || {
        let r = sim.run(&proposed, ReOptStrategy::EveryRound).unwrap();
        std::hint::black_box(r.realized_delay);
    });

    // adapter math at tiny-model scale: 2 blocks x (q,v) x (A,B), d=192 r=4
    let mk = || AdapterSet {
        tensors: (0..8)
            .map(|i| Tensor {
                name: format!("t{i}"),
                shape: vec![192, 4],
                data: vec![0.01; 192 * 4],
            })
            .collect(),
    };
    let sets: Vec<AdapterSet> = (0..5).map(|_| mk()).collect();
    let refs: Vec<&AdapterSet> = sets.iter().collect();
    bench("FedAvg over K=5 tiny adapter sets", 5000, || {
        let avg = AdapterSet::fedavg(&refs, &[1.0; 5]).unwrap();
        std::hint::black_box(avg);
    });
    let mut params = mk();
    let grads = mk();
    let mut opt = Optimizer::new(OptKind::Adam, 1e-3);
    bench("Adam step on tiny adapter set", 5000, || {
        opt.step(&mut params, &grads).unwrap();
    });

    // coordinator round overhead: mock model => pure channel/thread cost
    println!("\ncoordinator overhead (mock model, zero device compute):");
    let t0 = Instant::now();
    let opts = TrainOptions {
        clients: 5,
        local_steps: 10,
        global_rounds: 20,
        lr_client: 0.01,
        lr_server: 0.01,
        corpus_size: 200,
        val_size: 40,
        eval_batches: 1,
        non_iid: false,
        optimizer: OptKind::Sgd,
        byte_corpus: false,
        save_adapters: None,
        retry_budget: 2,
        retry_backoff_s: 0.05,
        seed: 1,
    };
    let report = train(&opts, || Ok(Box::new(MockModel::new(8, 64, 192))))?;
    let total = t0.elapsed().as_secs_f64();
    let steps = report.train_loss.len();
    println!(
        "  {steps} steps x K=5 in {total:.3}s -> {:.2} ms/step of pure \
         coordination (device calls are no-ops)",
        1e3 * total / steps as f64
    );
    Ok(())
}
