//! Supporting bench — Algorithm 3 (BCD) convergence behaviour across
//! seeds/initializations: objective trajectories, iteration counts, and
//! the spread of final objectives (the paper claims reliable empirical
//! convergence "regardless of initialization").
//!
//! Writes `results/bcd_convergence.csv`.

use sfllm::delay::{ConvergenceModel, WorkloadCache};
use sfllm::opt::bcd::{self, BcdOptions};
use sfllm::sim::ScenarioBuilder;
use sfllm::util::csv::CsvWriter;
use sfllm::util::stats;

fn main() -> anyhow::Result<()> {
    let conv = ConvergenceModel::paper_default();
    // all seeds/inits share one model + rank set -> one workload table
    let cache = WorkloadCache::new();
    let mut csv = CsvWriter::create(
        "results/bcd_convergence.csv",
        &["seed", "init_l_c", "init_rank", "iterations", "objective"],
    )?;
    println!("Algorithm 3 convergence across seeds and initializations:");
    let mut finals = Vec::new();
    for seed in [1u64, 7, 42, 99, 1234] {
        for (init_l_c, init_rank) in [(1usize, 1usize), (6, 4), (11, 8)] {
            let scn = ScenarioBuilder::new().seed(seed).build()?;
            let res = bcd::optimize_cached(
                &scn,
                &conv,
                &BcdOptions {
                    init_l_c,
                    init_rank,
                    ..BcdOptions::default()
                },
                &cache,
            )?;
            println!(
                "  seed {seed:5} init (l_c={init_l_c:2}, r={init_rank}) -> {:2} iters, \
                 T = {:9.1} s, trajectory {:?}",
                res.iterations,
                res.objective,
                res.trajectory.iter().map(|t| t.round()).collect::<Vec<_>>()
            );
            csv.row_f64(&[
                seed as f64,
                init_l_c as f64,
                init_rank as f64,
                res.iterations as f64,
                res.objective,
            ])?;
            finals.push((seed, res.objective));
        }
    }
    csv.flush()?;
    // per-seed spread across initializations
    println!("\nper-seed spread across initializations (lower = more reliable):");
    for seed in [1u64, 7, 42, 99, 1234] {
        let vals: Vec<f64> = finals
            .iter()
            .filter(|(s, _)| *s == seed)
            .map(|(_, v)| *v)
            .collect();
        let spread = (stats::max(&vals) - stats::min(&vals)) / stats::mean(&vals);
        println!("  seed {seed:5}: spread {:.2}%", 100.0 * spread);
    }
    println!("written results/bcd_convergence.csv");
    Ok(())
}
