//! Experiment configuration: the paper's Table II defaults, overridable
//! from a TOML file and/or CLI flags.
//!
//! Units follow the paper: frequencies in Hz, powers in dBm at the
//! boundary (converted to watts internally via [`crate::net::power`]),
//! computing capability `f` in cycles/s, computing intensity `kappa` in
//! cycles/FLOP.

use anyhow::Result;

use crate::util::cli::Args;
use crate::util::toml::TomlDoc;

/// System-level parameters (paper Table II).
#[derive(Clone, Debug)]
pub struct SystemConfig {
    /// Number of participating clients K.
    pub clients: usize,
    /// Subchannels to the main server (M) and federated server (N).
    pub subch_main: usize,
    pub subch_fed: usize,
    /// Total uplink bandwidth to each server, equally divided (Hz).
    pub bandwidth_main_hz: f64,
    pub bandwidth_fed_hz: f64,
    /// Client compute capability range [lo, hi] (cycles/s).
    pub f_client_lo: f64,
    pub f_client_hi: f64,
    /// Main server compute capability (cycles/s).
    pub f_server: f64,
    /// Computing intensity (cycles per FLOP).
    pub kappa_client: f64,
    pub kappa_server: f64,
    /// Antenna gain products.
    pub gain_main: f64, // G_c * G_s
    pub gain_fed: f64,  // G_c * G_f
    /// Noise PSD (dBm/Hz).
    pub noise_dbm_hz: f64,
    /// Per-client max transmit power (dBm) and per-server totals (dBm).
    pub p_max_dbm: f64,
    pub p_th_main_dbm: f64,
    pub p_th_fed_dbm: f64,
    /// Geometry: clients uniform in a disk of `d_max_m` around the
    /// federated server; main server at `d_main_m` from the centroid.
    pub d_max_m: f64,
    pub d_main_m: f64,
    /// Shadow fading standard deviation (dB); 0 disables.
    pub shadowing_db: f64,
    /// Scenario seed (placement, fading, capability draws).
    pub seed: u64,
}

impl Default for SystemConfig {
    fn default() -> Self {
        // Paper Table II.
        SystemConfig {
            clients: 5,
            subch_main: 20,
            subch_fed: 20,
            bandwidth_main_hz: 500e3,
            bandwidth_fed_hz: 500e3,
            f_client_lo: 1.0e9,
            f_client_hi: 1.6e9,
            f_server: 5.0e9,
            kappa_client: 1.0 / 1024.0,
            kappa_server: 1.0 / 32768.0,
            gain_main: 160.0,
            gain_fed: 80.0,
            noise_dbm_hz: -174.0,
            p_max_dbm: 41.76,
            p_th_main_dbm: 46.99,
            p_th_fed_dbm: 46.99,
            d_max_m: 20.0,
            d_main_m: 100.0,
            shadowing_db: 8.0,
            seed: 42,
        }
    }
}

/// Training-process parameters.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// Mini-batch size b.
    pub batch: usize,
    /// Local steps per global round I.
    pub local_steps: usize,
    /// Client/server LoRA learning rates (paper: 4e-4).
    pub lr_client: f64,
    pub lr_server: f64,
    /// Candidate LoRA ranks for P4.
    pub ranks: Vec<usize>,
    /// Sequence length used by the workload model.
    pub seq: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            batch: 16,
            local_steps: 12,
            lr_client: 4e-4,
            lr_server: 4e-4,
            ranks: vec![1, 2, 4, 6, 8],
            seq: 512,
        }
    }
}

/// Round-varying environment dynamics consumed by
/// [`crate::sim::RoundSimulator`]. The defaults freeze every process,
/// so a config that never touches this section behaves exactly like
/// the static model.
#[derive(Clone, Debug)]
pub struct DynamicsConfig {
    /// AR(1) round-to-round shadowing correlation ρ in [0, 1];
    /// 1.0 freezes the channel at its initial draw.
    pub rho: f64,
    /// Stationary shadowing std σ (dB) of the AR(1) process; negative
    /// means "inherit `system.shadowing_db`" (resolved at build time).
    pub shadow_sigma_db: f64,
    /// Log-normal per-round jitter σ on client compute capability
    /// (`f_k(e) = f_k · exp(σ·w)`, median-preserving); 0 disables.
    pub compute_jitter: f64,
    /// Per-round probability an active client drops out; 0 disables
    /// the whole dropout process.
    pub dropout: f64,
    /// Per-round probability a dropped client returns.
    pub rejoin: f64,
    /// Seed of the dynamics streams (independent of the scenario seed,
    /// so redrawing the environment keeps the geometry fixed).
    pub seed: u64,
    /// Safety cap on simulated rounds per run.
    pub max_rounds: usize,
    /// Default re-optimization strategy spec for config-driven
    /// surfaces: `one_shot`, `every_round`, `periodic:<J>`, or
    /// `on_degrade:<threshold>` (see `sim::ReOptStrategy::parse`).
    pub strategy: String,
}

impl Default for DynamicsConfig {
    fn default() -> Self {
        DynamicsConfig {
            rho: 1.0,
            shadow_sigma_db: -1.0,
            compute_jitter: 0.0,
            dropout: 0.0,
            rejoin: 0.25,
            seed: 1,
            max_rounds: 10_000,
            strategy: "one_shot".to_string(),
        }
    }
}

/// Population-scale simulation parameters consumed by
/// [`crate::sim::Population`] / the `population` CLI subcommand: a
/// fleet of `size` modeled clients out of which a `cohort` is invited
/// each round by a `selector`, with an optional straggler deadline.
/// `system.clients` is ignored on this path — the cohort takes its
/// place as the per-round K.
#[derive(Clone, Debug)]
pub struct PopulationConfig {
    /// Modeled fleet size (clients are lazily materialized; 10^5–10^6
    /// is cheap).
    pub size: usize,
    /// Per-round cohort size (clamped to `size`); must fit on the
    /// subchannels.
    pub cohort: usize,
    /// Selection policy spec: `uniform`, `weighted`, or
    /// `staleness:<tau>` (see `sim::selector::parse_selector`).
    pub selector: String,
    /// Straggler deadline: drop the slowest fraction in [0, 1) of the
    /// round's online cohort from the aggregate; 0 disables.
    pub deadline_drop: f64,
    /// Seed of the population streams (geometry + selection lifecycle;
    /// the environment evolution keys on `dynamics.seed`).
    pub seed: u64,
}

impl Default for PopulationConfig {
    fn default() -> Self {
        PopulationConfig {
            size: 10_000,
            cohort: 64,
            selector: "uniform".to_string(),
            deadline_drop: 0.0,
            seed: 2,
        }
    }
}

/// Fault-injection parameters consumed by
/// [`crate::sim::FaultPlan::from_config`] (the TOML spelling of a
/// `--faults` spec). The defaults are the empty plan: a config that
/// never touches `[faults]` injects nothing and moves no bits.
#[derive(Clone, Debug)]
pub struct FaultsConfig {
    /// Seed of the injector's own counter-based streams (independent
    /// of the scenario / dynamics / population seeds).
    pub seed: u64,
    /// Per-client per-round crash probability; a crashed client is
    /// offline for `crash_rounds` rounds.
    pub crash_rate: f64,
    pub crash_rounds: usize,
    /// Per-client per-round compute-stall probability; a stalled
    /// client's `f` is multiplied by `stall_factor` in (0, 1].
    pub stall_rate: f64,
    pub stall_factor: f64,
    pub stall_rounds: usize,
    /// Per-client per-round main-uplink outage probability; the gain
    /// is multiplied by `outage_factor` in [0, 1] (0 = total outage).
    pub outage_rate: f64,
    pub outage_factor: f64,
    pub outage_rounds: usize,
    /// Per-round federated-server blackout probability; every fed
    /// gain is multiplied by `blackout_factor` in [0, 1].
    pub blackout_rate: f64,
    pub blackout_factor: f64,
    pub blackout_rounds: usize,
}

impl Default for FaultsConfig {
    fn default() -> Self {
        FaultsConfig {
            seed: 0xFA17,
            crash_rate: 0.0,
            crash_rounds: 1,
            stall_rate: 0.0,
            stall_factor: 0.5,
            stall_rounds: 1,
            outage_rate: 0.0,
            outage_factor: 0.0,
            outage_rounds: 1,
            blackout_rate: 0.0,
            blackout_factor: 1e-4,
            blackout_rounds: 1,
        }
    }
}

/// Optimization-objective and energy-model parameters consumed by
/// [`crate::opt::Objective::from_config`] and the energy evaluation
/// paths. The defaults reproduce the paper exactly: a pure-delay
/// objective, with the energy model inert until a surface asks for it.
#[derive(Clone, Debug)]
pub struct ObjectiveConfig {
    /// Objective spec: `delay`, `energy`, `weighted[:<lambda>]`, or
    /// `budget[:<joules>]` (see `opt::Objective::parse`). A bare
    /// `weighted` / `budget` takes its parameter from the `lambda` /
    /// `budget_j` fields below.
    pub kind: String,
    /// λ weight (seconds per joule) of the `weighted` objective
    /// `T + λ·E`; λ = 0 is exactly the delay objective.
    pub lambda: f64,
    /// Energy budget (J) of the `budget` objective (minimize delay
    /// subject to total energy ≤ budget); infinite = unconstrained.
    pub budget_j: f64,
    /// Effective switched-capacitance coefficient ζ (J·s²/cycle³) of
    /// the client compute-energy model `ζ·f²·cycles`.
    pub zeta: f64,
}

/// Effective switched-capacitance coefficient default (J·s²/cycle³
/// scale), the `ζ` of the client compute-energy model `ζ·f²·cycles`.
/// Re-exported as `delay::energy::DEFAULT_ZETA` next to the model
/// that consumes it.
pub const DEFAULT_ZETA: f64 = 1e-28;

impl Default for ObjectiveConfig {
    fn default() -> Self {
        ObjectiveConfig {
            kind: "delay".to_string(),
            lambda: 0.0,
            budget_j: f64::INFINITY,
            zeta: DEFAULT_ZETA,
        }
    }
}

/// Top-level config bundle.
#[derive(Clone, Debug, Default)]
pub struct Config {
    pub system: SystemConfig,
    pub train: TrainConfig,
    /// Round-varying dynamics (static by default).
    pub dynamics: DynamicsConfig,
    /// Population-scale simulation (only the `population` surfaces read
    /// this section).
    pub population: PopulationConfig,
    /// Optimization objective / energy model (pure delay by default).
    pub objective: ObjectiveConfig,
    /// Fault injection (empty plan by default — bit-transparent).
    pub faults: FaultsConfig,
    /// Model variant name for the workload model ("gpt2-s", "gpt2-m", "tiny").
    pub model: String,
}

impl Config {
    pub fn paper_defaults() -> Config {
        Config {
            system: SystemConfig::default(),
            train: TrainConfig::default(),
            dynamics: DynamicsConfig::default(),
            population: PopulationConfig::default(),
            objective: ObjectiveConfig::default(),
            faults: FaultsConfig::default(),
            model: "gpt2-s".to_string(),
        }
    }

    /// Load from a TOML document, starting from paper defaults.
    pub fn from_toml(doc: &TomlDoc) -> Result<Config> {
        let mut c = Config::paper_defaults();
        c.apply_toml(doc)?;
        Ok(c)
    }

    /// Overlay a TOML document onto this config (used to layer a file
    /// on top of a scenario preset; untouched keys keep their values).
    pub fn apply_toml(&mut self, doc: &TomlDoc) -> Result<()> {
        let c = self;
        let s = &mut c.system;
        s.clients = doc.usize_or("system.clients", s.clients)?;
        s.subch_main = doc.usize_or("system.subch_main", s.subch_main)?;
        s.subch_fed = doc.usize_or("system.subch_fed", s.subch_fed)?;
        s.bandwidth_main_hz = doc.f64_or("system.bandwidth_main_hz", s.bandwidth_main_hz)?;
        s.bandwidth_fed_hz = doc.f64_or("system.bandwidth_fed_hz", s.bandwidth_fed_hz)?;
        s.f_client_lo = doc.f64_or("system.f_client_lo", s.f_client_lo)?;
        s.f_client_hi = doc.f64_or("system.f_client_hi", s.f_client_hi)?;
        s.f_server = doc.f64_or("system.f_server", s.f_server)?;
        s.kappa_client = doc.f64_or("system.kappa_client", s.kappa_client)?;
        s.kappa_server = doc.f64_or("system.kappa_server", s.kappa_server)?;
        s.gain_main = doc.f64_or("system.gain_main", s.gain_main)?;
        s.gain_fed = doc.f64_or("system.gain_fed", s.gain_fed)?;
        s.noise_dbm_hz = doc.f64_or("system.noise_dbm_hz", s.noise_dbm_hz)?;
        s.p_max_dbm = doc.f64_or("system.p_max_dbm", s.p_max_dbm)?;
        s.p_th_main_dbm = doc.f64_or("system.p_th_main_dbm", s.p_th_main_dbm)?;
        s.p_th_fed_dbm = doc.f64_or("system.p_th_fed_dbm", s.p_th_fed_dbm)?;
        s.d_max_m = doc.f64_or("system.d_max_m", s.d_max_m)?;
        s.d_main_m = doc.f64_or("system.d_main_m", s.d_main_m)?;
        s.shadowing_db = doc.f64_or("system.shadowing_db", s.shadowing_db)?;
        s.seed = doc.usize_or("system.seed", s.seed as usize)? as u64;
        let t = &mut c.train;
        t.batch = doc.usize_or("train.batch", t.batch)?;
        t.local_steps = doc.usize_or("train.local_steps", t.local_steps)?;
        t.lr_client = doc.f64_or("train.lr_client", t.lr_client)?;
        t.lr_server = doc.f64_or("train.lr_server", t.lr_server)?;
        t.seq = doc.usize_or("train.seq", t.seq)?;
        if let Some(v) = doc.get("train.ranks") {
            t.ranks = v
                .as_f64_arr()?
                .into_iter()
                .map(|x| x as usize)
                .collect();
        }
        let d = &mut c.dynamics;
        d.rho = doc.f64_or("dynamics.rho", d.rho)?;
        d.shadow_sigma_db = doc.f64_or("dynamics.shadow_sigma_db", d.shadow_sigma_db)?;
        d.compute_jitter = doc.f64_or("dynamics.compute_jitter", d.compute_jitter)?;
        d.dropout = doc.f64_or("dynamics.dropout", d.dropout)?;
        d.rejoin = doc.f64_or("dynamics.rejoin", d.rejoin)?;
        d.seed = doc.usize_or("dynamics.seed", d.seed as usize)? as u64;
        d.max_rounds = doc.usize_or("dynamics.max_rounds", d.max_rounds)?;
        d.strategy = doc.str_or("dynamics.strategy", &d.strategy)?;
        let p = &mut c.population;
        p.size = doc.usize_or("population.size", p.size)?;
        p.cohort = doc.usize_or("population.cohort", p.cohort)?;
        p.selector = doc.str_or("population.selector", &p.selector)?;
        p.deadline_drop = doc.f64_or("population.deadline_drop", p.deadline_drop)?;
        p.seed = doc.usize_or("population.seed", p.seed as usize)? as u64;
        let f = &mut c.faults;
        f.seed = doc.usize_or("faults.seed", f.seed as usize)? as u64;
        f.crash_rate = doc.f64_or("faults.crash_rate", f.crash_rate)?;
        f.crash_rounds = doc.usize_or("faults.crash_rounds", f.crash_rounds)?;
        f.stall_rate = doc.f64_or("faults.stall_rate", f.stall_rate)?;
        f.stall_factor = doc.f64_or("faults.stall_factor", f.stall_factor)?;
        f.stall_rounds = doc.usize_or("faults.stall_rounds", f.stall_rounds)?;
        f.outage_rate = doc.f64_or("faults.outage_rate", f.outage_rate)?;
        f.outage_factor = doc.f64_or("faults.outage_factor", f.outage_factor)?;
        f.outage_rounds = doc.usize_or("faults.outage_rounds", f.outage_rounds)?;
        f.blackout_rate = doc.f64_or("faults.blackout_rate", f.blackout_rate)?;
        f.blackout_factor = doc.f64_or("faults.blackout_factor", f.blackout_factor)?;
        f.blackout_rounds = doc.usize_or("faults.blackout_rounds", f.blackout_rounds)?;
        let o = &mut c.objective;
        o.kind = doc.str_or("objective.kind", &o.kind)?;
        o.lambda = doc.f64_or("objective.lambda", o.lambda)?;
        o.budget_j = doc.f64_or("objective.budget_j", o.budget_j)?;
        o.zeta = doc.f64_or("objective.zeta", o.zeta)?;
        c.model = doc.str_or("model", &c.model)?;
        Ok(())
    }

    /// Load from an optional `--config path` plus CLI overrides.
    pub fn from_args(args: &mut Args) -> Result<Config> {
        let mut c = Config::paper_defaults();
        c.apply_file_and_args(args)?;
        Ok(c)
    }

    /// Overlay an optional `--config path` TOML file, then the CLI
    /// override flags, onto this config.
    pub fn apply_file_and_args(&mut self, args: &mut Args) -> Result<()> {
        if let Some(path) = args.get("config") {
            let text = std::fs::read_to_string(&path)?;
            self.apply_toml(&TomlDoc::parse(&text)?)?;
        }
        self.system.clients = args.usize_or("clients", self.system.clients)?;
        self.system.seed = args.u64_or("seed", self.system.seed)?;
        self.model = args.str_or("model", &self.model);
        self.train.batch = args.usize_or("batch", self.train.batch)?;
        self.train.local_steps = args.usize_or("local-steps", self.train.local_steps)?;
        self.population.size = args.usize_or("population", self.population.size)?;
        self.population.cohort = args.usize_or("cohort", self.population.cohort)?;
        self.population.selector = args.str_or("selector", &self.population.selector);
        self.population.deadline_drop =
            args.f64_or("deadline-drop", self.population.deadline_drop)?;
        self.population.seed = args.u64_or("population-seed", self.population.seed)?;
        self.objective.kind = args.str_or("objective", &self.objective.kind);
        self.objective.lambda = args.f64_or("lambda", self.objective.lambda)?;
        self.objective.budget_j = args.f64_or("energy-budget", self.objective.budget_j)?;
        self.objective.zeta = args.f64_or("zeta", self.objective.zeta)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_ii_defaults() {
        let c = Config::paper_defaults();
        assert_eq!(c.system.clients, 5);
        assert_eq!(c.system.subch_main, 20);
        assert_eq!(c.system.bandwidth_main_hz, 500e3);
        assert!((c.system.kappa_client - 1.0 / 1024.0).abs() < 1e-12);
        assert_eq!(c.train.ranks, vec![1, 2, 4, 6, 8]);
        assert_eq!(c.train.batch, 16);
    }

    #[test]
    fn toml_overrides() {
        let doc = TomlDoc::parse(
            "[system]\nclients = 8\nf_server = 1e10\n[train]\nranks = [2, 4]\nbatch = 4\n",
        )
        .unwrap();
        let c = Config::from_toml(&doc).unwrap();
        assert_eq!(c.system.clients, 8);
        assert_eq!(c.system.f_server, 1e10);
        assert_eq!(c.train.ranks, vec![2, 4]);
        assert_eq!(c.train.batch, 4);
        // untouched values keep paper defaults
        assert_eq!(c.system.subch_fed, 20);
    }

    #[test]
    fn dynamics_default_static_and_toml_overridable() {
        let c = Config::paper_defaults();
        assert_eq!(c.dynamics.rho, 1.0);
        assert_eq!(c.dynamics.compute_jitter, 0.0);
        assert_eq!(c.dynamics.dropout, 0.0);
        assert!(c.dynamics.shadow_sigma_db < 0.0, "must inherit by default");
        assert_eq!(c.dynamics.strategy, "one_shot");
        let doc = TomlDoc::parse(
            "[dynamics]\nrho = 0.8\ndropout = 0.05\nstrategy = \"periodic:5\"\nseed = 9\n",
        )
        .unwrap();
        let c = Config::from_toml(&doc).unwrap();
        assert_eq!(c.dynamics.rho, 0.8);
        assert_eq!(c.dynamics.dropout, 0.05);
        assert_eq!(c.dynamics.strategy, "periodic:5");
        assert_eq!(c.dynamics.seed, 9);
        // untouched dynamics keys keep their defaults
        assert_eq!(c.dynamics.rejoin, 0.25);
        assert_eq!(c.dynamics.max_rounds, 10_000);
    }

    #[test]
    fn cli_overrides_config() {
        let mut args = Args::from_iter(
            ["--clients", "3", "--seed", "7"].iter().map(|s| s.to_string()),
        );
        let c = Config::from_args(&mut args).unwrap();
        assert_eq!(c.system.clients, 3);
        assert_eq!(c.system.seed, 7);
        args.finish().unwrap();
    }

    #[test]
    fn population_defaults_and_toml_overrides() {
        let c = Config::paper_defaults();
        assert_eq!(c.population.size, 10_000);
        assert_eq!(c.population.cohort, 64);
        assert_eq!(c.population.selector, "uniform");
        assert_eq!(c.population.deadline_drop, 0.0);
        assert_eq!(c.population.seed, 2);
        let doc = TomlDoc::parse(
            "[population]\nsize = 100000\ncohort = 32\nselector = \"staleness:5\"\n\
             deadline_drop = 0.1\nseed = 77\n",
        )
        .unwrap();
        let c = Config::from_toml(&doc).unwrap();
        assert_eq!(c.population.size, 100_000);
        assert_eq!(c.population.cohort, 32);
        assert_eq!(c.population.selector, "staleness:5");
        assert_eq!(c.population.deadline_drop, 0.1);
        assert_eq!(c.population.seed, 77);
    }

    #[test]
    fn population_cli_flags_override() {
        let mut args = Args::from_iter(
            [
                "--population",
                "500000",
                "--cohort",
                "128",
                "--selector",
                "weighted",
                "--deadline-drop",
                "0.05",
                "--population-seed",
                "3",
            ]
            .iter()
            .map(|s| s.to_string()),
        );
        let c = Config::from_args(&mut args).unwrap();
        assert_eq!(c.population.size, 500_000);
        assert_eq!(c.population.cohort, 128);
        assert_eq!(c.population.selector, "weighted");
        assert_eq!(c.population.deadline_drop, 0.05);
        assert_eq!(c.population.seed, 3);
        args.finish().unwrap();
    }

    #[test]
    fn objective_defaults_are_pure_delay_and_toml_overridable() {
        let c = Config::paper_defaults();
        assert_eq!(c.objective.kind, "delay");
        assert_eq!(c.objective.lambda, 0.0);
        assert!(c.objective.budget_j.is_infinite());
        assert_eq!(c.objective.zeta, crate::delay::energy::DEFAULT_ZETA);
        let doc = TomlDoc::parse(
            "[objective]\nkind = \"weighted\"\nlambda = 0.05\nzeta = 2e-28\n",
        )
        .unwrap();
        let c = Config::from_toml(&doc).unwrap();
        assert_eq!(c.objective.kind, "weighted");
        assert_eq!(c.objective.lambda, 0.05);
        assert_eq!(c.objective.zeta, 2e-28);
        // untouched objective keys keep their defaults
        assert!(c.objective.budget_j.is_infinite());
    }

    #[test]
    fn faults_default_empty_and_toml_overridable() {
        let c = Config::paper_defaults();
        assert_eq!(c.faults.crash_rate, 0.0);
        assert_eq!(c.faults.stall_rate, 0.0);
        assert_eq!(c.faults.outage_rate, 0.0);
        assert_eq!(c.faults.blackout_rate, 0.0);
        assert_eq!(c.faults.seed, 0xFA17);
        let doc = TomlDoc::parse(
            "[faults]\ncrash_rate = 0.1\ncrash_rounds = 2\nstall_rate = 0.05\n\
             stall_factor = 0.25\noutage_rate = 0.2\noutage_factor = 0.0\n\
             blackout_rate = 0.01\nblackout_factor = 1e-3\nseed = 77\n",
        )
        .unwrap();
        let c = Config::from_toml(&doc).unwrap();
        assert_eq!(c.faults.crash_rate, 0.1);
        assert_eq!(c.faults.crash_rounds, 2);
        assert_eq!(c.faults.stall_rate, 0.05);
        assert_eq!(c.faults.stall_factor, 0.25);
        assert_eq!(c.faults.outage_rate, 0.2);
        assert_eq!(c.faults.outage_factor, 0.0);
        assert_eq!(c.faults.blackout_rate, 0.01);
        assert_eq!(c.faults.blackout_factor, 1e-3);
        assert_eq!(c.faults.seed, 77);
        // untouched fault keys keep their defaults
        assert_eq!(c.faults.stall_rounds, 1);
        assert_eq!(c.faults.blackout_rounds, 1);
    }

    #[test]
    fn objective_cli_flags_override() {
        let mut args = Args::from_iter(
            ["--objective", "energy", "--zeta", "5e-29", "--lambda", "0.2"]
                .iter()
                .map(|s| s.to_string()),
        );
        let c = Config::from_args(&mut args).unwrap();
        assert_eq!(c.objective.kind, "energy");
        assert_eq!(c.objective.zeta, 5e-29);
        assert_eq!(c.objective.lambda, 0.2);
        args.finish().unwrap();
    }
}
