//! PJRT engine: load HLO-text artifacts, compile once, execute many.
//!
//! Wraps the `xla` crate exactly the way /opt/xla-example/load_hlo
//! validates: `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `client.compile` → `execute_b` over device-resident buffers. Frozen
//! weights are uploaded to the device **once** per entry point and the
//! buffers reused for every step — the Python side never runs again.

use std::path::Path;

use anyhow::{bail, Context, Result};
use xla::{HloModuleProto, Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use super::artifacts::{ArgKind, DType, EntrySpec};
use crate::model::lora::{AdapterSet, Tensor};

/// Shared PJRT CPU client.
pub struct Engine {
    client: PjRtClient,
}

impl Engine {
    pub fn new() -> Result<Engine> {
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile one entry point from its HLO text file.
    pub fn compile(&self, artifacts_dir: &Path, spec: &EntrySpec) -> Result<CompiledEntry> {
        let path = artifacts_dir.join(&spec.file);
        let proto = HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", spec.name))?;
        Ok(CompiledEntry {
            exe,
            spec: spec.clone(),
        })
    }

    /// Upload an f32 host tensor.
    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    /// Upload an i32 host tensor.
    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    /// Upload every tensor of an adapter set, in order.
    pub fn upload_adapters(&self, set: &AdapterSet) -> Result<Vec<PjRtBuffer>> {
        set.tensors
            .iter()
            .map(|t| self.upload_f32(&t.data, &t.shape))
            .collect()
    }
}

/// One compiled entry point plus its signature.
pub struct CompiledEntry {
    exe: PjRtLoadedExecutable,
    pub spec: EntrySpec,
}

impl CompiledEntry {
    /// Execute over device buffers; outputs are unpacked from the
    /// 1-tuple convention (`return_tuple=True` at lowering) into one
    /// literal per declared output.
    pub fn execute(&self, args: &[&PjRtBuffer]) -> Result<Vec<Literal>> {
        if args.len() != self.spec.inputs.len() {
            bail!(
                "{}: got {} args, signature has {}",
                self.spec.name,
                args.len(),
                self.spec.inputs.len()
            );
        }
        let outs = self.exe.execute_b(args)?;
        let lit = outs[0][0].to_literal_sync()?;
        let parts = lit.to_tuple()?;
        if parts.len() != self.spec.outputs.len() {
            bail!(
                "{}: got {} outputs, signature has {}",
                self.spec.name,
                parts.len(),
                self.spec.outputs.len()
            );
        }
        Ok(parts)
    }

    /// Extract output `idx` as f32 vec, shape-checked against the spec.
    pub fn output_f32(&self, parts: &[Literal], idx: usize) -> Result<Vec<f32>> {
        let spec = &self.spec.outputs[idx];
        if spec.dtype != DType::F32 {
            bail!("output {} is not f32", spec.name);
        }
        let v = parts[idx].to_vec::<f32>()?;
        if v.len() != spec.numel() {
            bail!(
                "output {}: {} elements, expected {}",
                spec.name,
                v.len(),
                spec.numel()
            );
        }
        Ok(v)
    }

    /// Extract the adapter-gradient outputs (all outputs whose name
    /// starts with `d_h`) into an [`AdapterSet`] ordered like the spec.
    pub fn grads_from_outputs(&self, parts: &[Literal]) -> Result<AdapterSet> {
        let mut tensors = Vec::new();
        for (idx, out) in self.spec.outputs.iter().enumerate() {
            if out.name.starts_with("d_h") {
                let data = self.output_f32(parts, idx)?;
                tensors.push(Tensor {
                    name: out.name.trim_start_matches("d_").to_string(),
                    shape: out.shape.clone(),
                    data,
                });
            }
        }
        Ok(AdapterSet { tensors })
    }

    /// Index of the named input in the signature.
    pub fn input_index(&self, name: &str) -> Result<usize> {
        self.spec
            .inputs
            .iter()
            .position(|i| i.name == name)
            .with_context(|| format!("no input '{name}' in {}", self.spec.name))
    }

    /// Count of inputs of the given kind (they are contiguous by
    /// construction: weights, then adapters, then data).
    pub fn count_kind(&self, kind: ArgKind) -> usize {
        self.spec.inputs.iter().filter(|i| i.kind == kind).count()
    }
}
