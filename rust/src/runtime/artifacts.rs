//! Artifact manifest: the contract between `python/compile/aot.py` and
//! the Rust runtime.
//!
//! `artifacts/manifest.json` describes, for every exported variant
//! (model config × split × rank), the three HLO entry points with their
//! ordered input/output signatures, plus the raw-f32 tensor files for
//! frozen weights and LoRA adapter initializations.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::model::lora::{AdapterSet, Tensor};
use crate::util::json::Json;

/// Element type of an entry-point argument.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

/// Role of an input in the entry signature.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArgKind {
    /// Frozen pre-trained weight (uploaded once, reused every step).
    Weight,
    /// Trainable LoRA adapter (re-uploaded when it changes).
    Adapter,
    /// Per-step data (tokens, activations, gradients, masks).
    Data,
}

/// One argument or result of an entry point.
#[derive(Clone, Debug)]
pub struct ArgSpec {
    pub name: String,
    pub kind: ArgKind,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl ArgSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT-compiled entry point.
#[derive(Clone, Debug)]
pub struct EntrySpec {
    pub name: String,
    /// HLO text file, relative to the artifacts dir.
    pub file: String,
    pub inputs: Vec<ArgSpec>,
    pub outputs: Vec<ArgSpec>,
}

/// Index entry for one tensor inside a raw-f32 file.
#[derive(Clone, Debug)]
pub struct TensorEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
}

/// A named tensor file (weights or adapter init).
#[derive(Clone, Debug)]
pub struct TensorFile {
    pub file: String,
    pub tensors: Vec<TensorEntry>,
}

/// Model-architecture record (mirrors python GPT2Config).
#[derive(Clone, Debug)]
pub struct ConfigRecord {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub seq: usize,
    pub batch: usize,
    pub lora_alpha: f64,
    pub weights: TensorFile,
}

/// One exported (config, split, rank) variant.
#[derive(Clone, Debug)]
pub struct VariantRecord {
    pub name: String,
    pub config: String,
    pub l_c: usize,
    pub rank: usize,
    pub lora_scale: f64,
    pub adapters_client: TensorFile,
    pub adapters_server: TensorFile,
    pub entries: BTreeMap<String, EntrySpec>,
}

/// The parsed manifest plus its base directory.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub configs: BTreeMap<String, ConfigRecord>,
    pub variants: BTreeMap<String, VariantRecord>,
}

fn parse_shape(j: &Json) -> Result<Vec<usize>> {
    j.as_arr()?.iter().map(|v| v.as_usize()).collect()
}

fn parse_dtype(s: &str) -> Result<DType> {
    match s {
        "f32" => Ok(DType::F32),
        "i32" => Ok(DType::I32),
        _ => bail!("unknown dtype '{s}'"),
    }
}

fn parse_kind(s: &str) -> Result<ArgKind> {
    match s {
        "weight" => Ok(ArgKind::Weight),
        "adapter" => Ok(ArgKind::Adapter),
        "data" => Ok(ArgKind::Data),
        _ => bail!("unknown arg kind '{s}'"),
    }
}

fn parse_args(j: &Json, with_kind: bool) -> Result<Vec<ArgSpec>> {
    j.as_arr()?
        .iter()
        .map(|a| {
            Ok(ArgSpec {
                name: a.get("name")?.as_str()?.to_string(),
                kind: if with_kind {
                    parse_kind(a.get("kind")?.as_str()?)?
                } else {
                    ArgKind::Data
                },
                shape: parse_shape(a.get("shape")?)?,
                dtype: parse_dtype(a.get("dtype")?.as_str()?)?,
            })
        })
        .collect()
}

fn parse_tensor_file(j: &Json) -> Result<TensorFile> {
    Ok(TensorFile {
        file: j.get("file")?.as_str()?.to_string(),
        tensors: j
            .get("tensors")?
            .as_arr()?
            .iter()
            .map(|t| {
                Ok(TensorEntry {
                    name: t.get("name")?.as_str()?.to_string(),
                    shape: parse_shape(t.get("shape")?)?,
                    offset: t.get("offset")?.as_usize()?,
                })
            })
            .collect::<Result<Vec<_>>>()?,
    })
}

impl Manifest {
    /// Parse `<dir>/manifest.json`.
    pub fn load<P: AsRef<Path>>(dir: P) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;

        let mut configs = BTreeMap::new();
        for (name, c) in j.get("configs")?.as_obj()? {
            configs.insert(
                name.clone(),
                ConfigRecord {
                    vocab: c.get("vocab")?.as_usize()?,
                    d_model: c.get("d_model")?.as_usize()?,
                    n_layers: c.get("n_layers")?.as_usize()?,
                    n_heads: c.get("n_heads")?.as_usize()?,
                    seq: c.get("seq")?.as_usize()?,
                    batch: c.get("batch")?.as_usize()?,
                    lora_alpha: c.get("lora_alpha")?.as_f64()?,
                    weights: TensorFile {
                        file: c.get("weights_file")?.as_str()?.to_string(),
                        tensors: c
                            .get("weights")?
                            .as_arr()?
                            .iter()
                            .map(|t| {
                                Ok(TensorEntry {
                                    name: t.get("name")?.as_str()?.to_string(),
                                    shape: parse_shape(t.get("shape")?)?,
                                    offset: t.get("offset")?.as_usize()?,
                                })
                            })
                            .collect::<Result<Vec<_>>>()?,
                    },
                },
            );
        }

        let mut variants = BTreeMap::new();
        for (name, v) in j.get("variants")?.as_obj()? {
            let mut entries = BTreeMap::new();
            for (ename, e) in v.get("entries")?.as_obj()? {
                entries.insert(
                    ename.clone(),
                    EntrySpec {
                        name: ename.clone(),
                        file: e.get("file")?.as_str()?.to_string(),
                        inputs: parse_args(e.get("inputs")?, true)?,
                        outputs: parse_args(e.get("outputs")?, false)?,
                    },
                );
            }
            variants.insert(
                name.clone(),
                VariantRecord {
                    name: name.clone(),
                    config: v.get("config")?.as_str()?.to_string(),
                    l_c: v.get("l_c")?.as_usize()?,
                    rank: v.get("rank")?.as_usize()?,
                    lora_scale: v.get("lora_scale")?.as_f64()?,
                    adapters_client: parse_tensor_file(v.get("adapters_client")?)?,
                    adapters_server: parse_tensor_file(v.get("adapters_server")?)?,
                    entries,
                },
            );
        }

        Ok(Manifest {
            dir,
            configs,
            variants,
        })
    }

    pub fn variant(&self, name: &str) -> Result<&VariantRecord> {
        self.variants
            .get(name)
            .with_context(|| format!("variant '{name}' not in manifest"))
    }

    pub fn config(&self, name: &str) -> Result<&ConfigRecord> {
        self.configs
            .get(name)
            .with_context(|| format!("config '{name}' not in manifest"))
    }

    /// Read a raw-f32 tensor file into an ordered [`AdapterSet`].
    pub fn read_tensors(&self, tf: &TensorFile) -> Result<AdapterSet> {
        let path = self.dir.join(&tf.file);
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let mut tensors = Vec::with_capacity(tf.tensors.len());
        for t in &tf.tensors {
            let numel: usize = t.shape.iter().product();
            let end = t.offset + numel * 4;
            if end > bytes.len() {
                bail!("tensor '{}' out of bounds in {}", t.name, tf.file);
            }
            let mut data = vec![0f32; numel];
            for (i, chunk) in bytes[t.offset..end].chunks_exact(4).enumerate() {
                data[i] = f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
            }
            tensors.push(Tensor {
                name: t.name.clone(),
                shape: t.shape.clone(),
                data,
            });
        }
        Ok(AdapterSet { tensors })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        // tests run from the crate root
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn loads_real_manifest() {
        let m = Manifest::load(artifacts_dir()).unwrap();
        assert!(m.variants.contains_key("micro_s1_r2"), "{:?}", m.variants.keys());
        let v = m.variant("micro_s1_r2").unwrap();
        assert_eq!(v.l_c, 1);
        assert_eq!(v.rank, 2);
        assert_eq!(v.entries.len(), 3);
        let cf = &v.entries["client_fwd"];
        // last input is the token batch
        let tokens = cf.inputs.last().unwrap();
        assert_eq!(tokens.dtype, DType::I32);
        assert_eq!(tokens.kind, ArgKind::Data);
        let cfg = m.config("micro").unwrap();
        assert_eq!(tokens.shape, vec![cfg.batch, cfg.seq]);
    }

    #[test]
    fn weight_shapes_cover_signature() {
        let m = Manifest::load(artifacts_dir()).unwrap();
        let v = m.variant("micro_s1_r2").unwrap();
        let cfg = m.config("micro").unwrap();
        let weights = m.read_tensors(&cfg.weights).unwrap();
        // every weight input of client_fwd must exist in the weight file
        for inp in &v.entries["client_fwd"].inputs {
            if inp.kind == ArgKind::Weight {
                let t = weights
                    .tensors
                    .iter()
                    .find(|t| t.name == inp.name)
                    .unwrap_or_else(|| panic!("missing weight {}", inp.name));
                assert_eq!(t.shape, inp.shape, "shape of {}", inp.name);
            }
        }
    }

    #[test]
    fn adapter_init_matches_signature() {
        let m = Manifest::load(artifacts_dir()).unwrap();
        let v = m.variant("micro_s1_r2").unwrap();
        let ad = m.read_tensors(&v.adapters_client).unwrap();
        let adapter_inputs: Vec<_> = v.entries["client_fwd"]
            .inputs
            .iter()
            .filter(|i| i.kind == ArgKind::Adapter)
            .collect();
        assert_eq!(ad.tensors.len(), adapter_inputs.len());
        for (t, spec) in ad.tensors.iter().zip(&adapter_inputs) {
            assert_eq!(t.name, spec.name);
            assert_eq!(t.shape, spec.shape);
        }
        // B adapters start at zero, A adapters don't
        for t in &ad.tensors {
            if t.name.ends_with("_B") {
                assert!(t.data.iter().all(|&v| v == 0.0), "{} not zero", t.name);
            } else {
                assert!(t.data.iter().any(|&v| v != 0.0), "{} all zero", t.name);
            }
        }
    }
}
