//! Runtime: AOT artifacts → PJRT executables → the SFL training API.
//!
//! * [`artifacts`] — manifest parsing, tensor-file loading;
//! * [`engine`] — PJRT client wrapper (compile once, execute many);
//! * [`sfl`] — [`sfl::SflRuntime`], the three-entry training interface
//!   (`client_forward` / `server_step` / `client_backward`) the
//!   coordinator drives, plus the [`sfl::SflModel`] trait that lets
//!   tests substitute a mock.

pub mod artifacts;
pub mod engine;
pub mod sfl;

pub use artifacts::Manifest;
pub use engine::{CompiledEntry, Engine};
pub use sfl::{SflModel, SflRuntime, StepOutput};
