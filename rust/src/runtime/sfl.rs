//! The split-federated training interface over compiled artifacts.
//!
//! [`SflRuntime`] owns the three compiled entry points of one variant
//! plus the device-resident frozen-weight buffers, and exposes exactly
//! the operations of the paper's Algorithm 1:
//!
//! * `client_forward`  — phase a (client FP → split activations),
//! * `server_step`     — phases c–e (server FP, loss, BP, activation grads),
//! * `client_backward` — phase f (client BP → adapter grads).
//!
//! Adapters travel as host [`AdapterSet`]s: they are small (the whole
//! point of LoRA), so per-call upload is cheap; frozen weights never
//! travel after load.
//!
//! [`SflModel`] abstracts the interface so the coordinator can be
//! integration-tested with a deterministic mock (no PJRT).

use std::path::PathBuf;

use anyhow::{bail, Context, Result};
use xla::PjRtBuffer;

use super::artifacts::{ArgKind, Manifest, VariantRecord};
use super::engine::{CompiledEntry, Engine};
use crate::model::lora::AdapterSet;

/// Output of one server step.
#[derive(Clone, Debug)]
pub struct StepOutput {
    pub loss: f32,
    /// Gradients of the server-side adapters (same order as params).
    pub server_grads: AdapterSet,
    /// Gradient w.r.t. the split activations, to ship back to clients.
    pub ds: Vec<f32>,
}

/// Model operations the coordinator needs (implemented by the PJRT
/// runtime and by the test mock).
pub trait SflModel {
    /// Batch shape (B, T), split-activation feature dim d, vocabulary.
    fn batch(&self) -> usize;
    fn seq(&self) -> usize;
    fn d_model(&self) -> usize;
    fn vocab(&self) -> usize;

    /// Initial client/server adapter states (from the artifacts).
    fn init_client_adapters(&self) -> AdapterSet;
    fn init_server_adapters(&self) -> AdapterSet;

    /// Phase a: tokens [B*T] i32 → activations s [B*T*d] f32.
    fn client_forward(&mut self, adapters: &AdapterSet, tokens: &[i32]) -> Result<Vec<f32>>;

    /// Phases c–e.
    fn server_step(
        &mut self,
        adapters: &AdapterSet,
        s: &[f32],
        tokens: &[i32],
        mask: &[f32],
    ) -> Result<StepOutput>;

    /// Phase f: returns client adapter gradients.
    fn client_backward(
        &mut self,
        adapters: &AdapterSet,
        tokens: &[i32],
        ds: &[f32],
    ) -> Result<AdapterSet>;

    /// Evaluation: loss only, no gradients applied (reuses server_step).
    fn eval_loss(
        &mut self,
        client_adapters: &AdapterSet,
        server_adapters: &AdapterSet,
        tokens: &[i32],
        mask: &[f32],
    ) -> Result<f32> {
        let s = self.client_forward(client_adapters, tokens)?;
        Ok(self.server_step(server_adapters, &s, tokens, mask)?.loss)
    }
}

/// PJRT-backed implementation over one artifact variant.
pub struct SflRuntime {
    engine: Engine,
    dir: PathBuf,
    pub variant: VariantRecord,
    batch: usize,
    seq: usize,
    d_model: usize,
    vocab: usize,
    client_fwd: CompiledEntry,
    server_step_e: CompiledEntry,
    client_bwd: CompiledEntry,
    /// Device-resident frozen weights per entry, in signature order.
    w_client_fwd: Vec<PjRtBuffer>,
    w_server: Vec<PjRtBuffer>,
    w_client_bwd: Vec<PjRtBuffer>,
    adapters_client_init: AdapterSet,
    adapters_server_init: AdapterSet,
}

impl SflRuntime {
    /// Load a variant: compile its three entries and upload the frozen
    /// weights once.
    pub fn load(manifest: &Manifest, variant_name: &str) -> Result<SflRuntime> {
        let engine = Engine::new()?;
        Self::load_with_engine(engine, manifest, variant_name)
    }

    pub fn load_with_engine(
        engine: Engine,
        manifest: &Manifest,
        variant_name: &str,
    ) -> Result<SflRuntime> {
        let variant = manifest.variant(variant_name)?.clone();
        let cfg = manifest.config(&variant.config)?;
        let weights = manifest.read_tensors(&cfg.weights)?;

        let compile = |ename: &str| -> Result<CompiledEntry> {
            let spec = variant
                .entries
                .get(ename)
                .with_context(|| format!("variant {variant_name} missing entry {ename}"))?;
            engine.compile(&manifest.dir, spec)
        };
        let client_fwd = compile("client_fwd")?;
        let server_step_e = compile("server_step")?;
        let client_bwd = compile("client_bwd")?;

        // Upload the weight prefix of each signature once.
        let upload_weights = |entry: &CompiledEntry| -> Result<Vec<PjRtBuffer>> {
            entry
                .spec
                .inputs
                .iter()
                .filter(|i| i.kind == ArgKind::Weight)
                .map(|i| {
                    let t = weights
                        .tensors
                        .iter()
                        .find(|t| t.name == i.name)
                        .with_context(|| format!("weight '{}' not in weight file", i.name))?;
                    if t.shape != i.shape {
                        bail!("weight '{}' shape mismatch", i.name);
                    }
                    engine.upload_f32(&t.data, &t.shape)
                })
                .collect()
        };
        let w_client_fwd = upload_weights(&client_fwd)?;
        let w_server = upload_weights(&server_step_e)?;
        let w_client_bwd = upload_weights(&client_bwd)?;

        let adapters_client_init = manifest.read_tensors(&variant.adapters_client)?;
        let adapters_server_init = manifest.read_tensors(&variant.adapters_server)?;

        Ok(SflRuntime {
            engine,
            dir: manifest.dir.clone(),
            batch: cfg.batch,
            seq: cfg.seq,
            d_model: cfg.d_model,
            vocab: cfg.vocab,
            variant,
            client_fwd,
            server_step_e,
            client_bwd,
            w_client_fwd,
            w_server,
            w_client_bwd,
            adapters_client_init,
            adapters_server_init,
        })
    }

    pub fn artifacts_dir(&self) -> &PathBuf {
        &self.dir
    }

    fn check_tokens(&self, tokens: &[i32]) -> Result<()> {
        if tokens.len() != self.batch * self.seq {
            bail!(
                "tokens: {} elements, expected B*T = {}",
                tokens.len(),
                self.batch * self.seq
            );
        }
        Ok(())
    }
}

impl SflModel for SflRuntime {
    fn batch(&self) -> usize {
        self.batch
    }

    fn seq(&self) -> usize {
        self.seq
    }

    fn d_model(&self) -> usize {
        self.d_model
    }

    fn vocab(&self) -> usize {
        self.vocab
    }

    fn init_client_adapters(&self) -> AdapterSet {
        self.adapters_client_init.clone()
    }

    fn init_server_adapters(&self) -> AdapterSet {
        self.adapters_server_init.clone()
    }

    fn client_forward(&mut self, adapters: &AdapterSet, tokens: &[i32]) -> Result<Vec<f32>> {
        self.check_tokens(tokens)?;
        let ad = self.engine.upload_adapters(adapters)?;
        let tok = self.engine.upload_i32(tokens, &[self.batch, self.seq])?;
        let mut args: Vec<&PjRtBuffer> = self.w_client_fwd.iter().collect();
        args.extend(ad.iter());
        args.push(&tok);
        let parts = self.client_fwd.execute(&args)?;
        self.client_fwd.output_f32(&parts, 0)
    }

    fn server_step(
        &mut self,
        adapters: &AdapterSet,
        s: &[f32],
        tokens: &[i32],
        mask: &[f32],
    ) -> Result<StepOutput> {
        self.check_tokens(tokens)?;
        let (b, t, d) = (self.batch, self.seq, self.d_model);
        if s.len() != b * t * d {
            bail!("activations: {} elements, expected {}", s.len(), b * t * d);
        }
        let ad = self.engine.upload_adapters(adapters)?;
        let s_buf = self.engine.upload_f32(s, &[b, t, d])?;
        let tok = self.engine.upload_i32(tokens, &[b, t])?;
        let m_buf = self.engine.upload_f32(mask, &[b, t])?;
        let mut args: Vec<&PjRtBuffer> = self.w_server.iter().collect();
        args.extend(ad.iter());
        args.push(&s_buf);
        args.push(&tok);
        args.push(&m_buf);
        let parts = self.server_step_e.execute(&args)?;
        let loss = self.server_step_e.output_f32(&parts, 0)?[0];
        let server_grads = self.server_step_e.grads_from_outputs(&parts)?;
        let ds_idx = parts.len() - 1;
        let ds = self.server_step_e.output_f32(&parts, ds_idx)?;
        Ok(StepOutput {
            loss,
            server_grads,
            ds,
        })
    }

    fn client_backward(
        &mut self,
        adapters: &AdapterSet,
        tokens: &[i32],
        ds: &[f32],
    ) -> Result<AdapterSet> {
        self.check_tokens(tokens)?;
        let (b, t, d) = (self.batch, self.seq, self.d_model);
        let ad = self.engine.upload_adapters(adapters)?;
        let tok = self.engine.upload_i32(tokens, &[b, t])?;
        let ds_buf = self.engine.upload_f32(ds, &[b, t, d])?;
        let mut args: Vec<&PjRtBuffer> = self.w_client_bwd.iter().collect();
        args.extend(ad.iter());
        args.push(&tok);
        args.push(&ds_buf);
        let parts = self.client_bwd.execute(&args)?;
        self.client_bwd.grads_from_outputs(&parts)
    }
}
