//! sfllm — command-line launcher for the SfLLM reproduction.
//!
//! Subcommands:
//!
//! * `train`    — run split-federated fine-tuning (Algorithm 1) over an
//!                AOT artifact variant, logging the loss curve to CSV;
//! * `optimize` — solve one scenario with a named allocation policy
//!                (default: the proposed Algorithm 3) and print the
//!                chosen allocation;
//! * `latency`  — evaluate policies side by side on one scenario
//!                (default: `proposed` vs baselines a–d);
//! * `sweep`    — run a policy sweep along a named axis across worker
//!                threads, writing CSV/JSON reports;
//! * `dynamic`  — play the fine-tuning run out over E(r) rounds under
//!                round-varying channel/compute/membership dynamics,
//!                comparing re-optimization strategies (`one_shot`,
//!                `every_round`, `periodic:J`, `on_degrade:θ`) by
//!                *realized* total delay;
//! * `population` — play the run out over a modeled population of
//!                10^5–10^6 clients (default preset
//!                `metro_population`): per-round cohort selection
//!                (`--selector uniform|weighted|staleness:<τ>`),
//!                straggler deadlines (`--deadline-drop x`), and
//!                dropout/rejoin, at O(cohort) per-round cost
//!                (`--population`, `--cohort`, `--population-seed`);
//! * `serve`    — run the allocator service: replay a typed JSONL
//!                event stream (`--events`) through the long-running
//!                engine, streaming per-round JSONL metrics
//!                (`--metrics-out`), writing versioned `SFCK`
//!                checkpoints (`--checkpoint-out`, every N ticks via
//!                `--checkpoint-every` or on in-stream
//!                `checkpoint_requested` events), and resuming a
//!                checkpointed run bit-identically (`--resume`, which
//!                falls back to the rotated `.prev` artifact when the
//!                primary checkpoint is corrupt; `--lenient` skips
//!                malformed event lines with line-numbered warnings
//!                instead of aborting);
//! * `chaos`    — play the fault-matrix ladder (none/light/heavy,
//!                [`sfllm::sim::faults::matrix_levels`]) across presets
//!                through the matching engine, assert the zero-fault
//!                level is bit-identical to the fault-free baseline,
//!                and emit the degradation matrix (`--json`,
//!                `--trace-dir`);
//! * `bench`    — run the tracked perf axes (heap Algorithm 2 vs the
//!                naive reference, warm vs cold P2, full-solve and
//!                dynamic-run scaling) and emit the machine-readable
//!                report CI archives (`--json BENCH_pr5.json`,
//!                `--full` for lower-variance timings);
//! * `lint`     — run `sfllm-lint`, the offline static-analysis pass
//!                enforcing the determinism / numeric-safety /
//!                panic-surface contract (rule table in
//!                `analysis::rules::RULES`; `--json <path>` for the
//!                machine-readable report CI gates on; exits nonzero
//!                on any unsuppressed finding);
//! * `table3`   — print the GPT2-S complexity table (paper Table III);
//! * `info`     — list available artifact variants.
//!
//! Scenario flags shared by `optimize`/`latency`/`sweep`/`dynamic`/
//! `population`:
//! `--preset <paper|dense_cell|weak_edge|asymmetric_links|many_clients|mobile_edge|battery_edge|metro_population>`,
//! `--config <toml>`, `--clients`, `--seed`, `--model`, `--batch`,
//! `--local-steps`, plus the objective flags `--objective
//! <delay|energy|weighted[:λ]|budget[:J]>`, `--lambda <s/J>`,
//! `--energy-budget <J>` and `--zeta <J·s²/cycle³>` (the energy
//! model's switched capacitance). Policy flags: `--policy`/`--policies`
//! (names from the registry, comma-separated, or `all`) and `--draws`
//! (baseline averaging). `sweep` additionally takes `--threads` (grid
//! workers; 0 = all cores) and `--energy` (adds per-policy `:energy`
//! CSV columns); infeasible grid points are reported as skipped rows
//! rather than aborting the sweep. `dynamic` takes `--strategies`
//! (comma-separated strategy specs) and `--rounds-out` (per-round CSV
//! trace of the first policy × strategy pair, including realized
//! energy). `dynamic` and `population` take `--faults <spec>` (see
//! [`sfllm::sim::FaultPlan::parse`]; default: the config's `[faults]`
//! section), replaying each policy × strategy pair under the seeded
//! deterministic fault schedule.
//!
//! Defaults reproduce the paper's Table II setup.

#![forbid(unsafe_code)]

use anyhow::{bail, Context, Result};
use sfllm::config::Config;
use sfllm::coordinator::{train, OptKind, TrainOptions};
use sfllm::delay::{ConvergenceModel, WorkloadCache};
use sfllm::model::{Gpt2Config, WorkloadProfile};
use sfllm::opt::{AllocationPolicy, PolicyRegistry};
use sfllm::runtime::{Manifest, SflModel, SflRuntime};
use sfllm::sim::{
    DynamicOutcome, DynamicPolicy, FaultPlan, Population, PopulationSimulator, ReOptStrategy,
    RoundSimulator, ScenarioBuilder, SweepAxis, SweepRunner,
};
use sfllm::util::cli::Args;
use sfllm::util::csv::CsvWriter;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let mut args = Args::from_env();
    let cmd = args
        .positional()
        .first()
        .cloned()
        .unwrap_or_else(|| "help".to_string());
    match cmd.as_str() {
        "train" => cmd_train(&mut args),
        "optimize" => cmd_optimize(&mut args),
        "latency" => cmd_latency(&mut args),
        "sweep" => cmd_sweep(&mut args),
        "dynamic" => cmd_dynamic(&mut args),
        "population" => cmd_population(&mut args),
        "serve" => cmd_serve(&mut args),
        "chaos" => cmd_chaos(&mut args),
        "bench" => cmd_bench(&mut args),
        "lint" => cmd_lint(&mut args),
        "table3" => cmd_table3(&mut args),
        "info" => cmd_info(&mut args),
        _ => {
            println!(
                "sfllm — split federated learning for LLMs (paper reproduction)\n\n\
                 usage: sfllm <train|optimize|latency|sweep|dynamic|population|serve|chaos|bench|lint|table3|info> [--options]\n\n\
                 train     run Algorithm 1 over an artifact variant\n\
                 optimize  solve one scenario with a named policy (default: proposed)\n\
                 latency   compare policies (proposed vs baselines a-d) on one scenario\n\
                 sweep     sweep policies along an axis (--axis, --values, --threads, --energy)\n\
                 dynamic   simulate round-varying dynamics, comparing re-opt strategies\n\
                 population  simulate cohort selection over a 10^5-client fleet (O(cohort)/round)\n\
                 serve     replay a JSONL event stream through the allocator service\n\
                           (--events, --metrics-out, --checkpoint-out, --checkpoint-every,\n\
                           --resume, --lenient)\n\
                 chaos     play the fault-matrix ladder across presets\n\
                           (--presets, --policy, --strategy, --fault-seed, --json, --trace-dir)\n\
                 bench     run the tracked perf axes (--json <path>, --full)\n\
                 lint      run the determinism/architecture static analysis\n\
                           (--json, --arch-json, --dot-out, --allow-unused)\n\
                 table3    print the GPT2-S complexity table (Table III)\n\
                 info      list artifact variants"
            );
            Ok(())
        }
    }
}

fn artifacts_dir(args: &mut Args) -> String {
    args.str_or("artifacts", "artifacts")
}

/// Shared scenario flags: `--preset` as the base, then `--config` TOML
/// and individual CLI overrides layered on top.
fn builder_from_args(args: &mut Args) -> Result<ScenarioBuilder> {
    let preset = args.str_or("preset", "paper");
    let mut cfg = ScenarioBuilder::preset(&preset)?.into_config();
    cfg.apply_file_and_args(args)?;
    Ok(ScenarioBuilder::from_config(cfg))
}

/// Shared policy flags: the paper suite parameterized by the scenario's
/// rank candidates/seed and `--draws`.
fn registry_for(cfg: &Config, draws: usize) -> PolicyRegistry {
    PolicyRegistry::paper_suite(&cfg.train.ranks, cfg.system.seed, draws)
}

fn cmd_train(args: &mut Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let variant = args.str_or("variant", "tiny_s2_r4");
    let opts = TrainOptions {
        clients: args.usize_or("clients", 5)?,
        local_steps: args.usize_or("local-steps", 12)?,
        global_rounds: args.usize_or("rounds", 25)?,
        lr_client: args.f64_or("lr", 1e-3)? as f32,
        lr_server: args.f64_or("lr", 1e-3)? as f32,
        corpus_size: args.usize_or("corpus", 2000)?,
        val_size: args.usize_or("val", 200)?,
        eval_batches: args.usize_or("eval-batches", 4)?,
        non_iid: args.flag("non-iid"),
        optimizer: if args.flag("sgd") { OptKind::Sgd } else { OptKind::Adam },
        byte_corpus: args.flag("byte-corpus"),
        save_adapters: args.get("save-adapters"),
        retry_budget: args.usize_or("retries", 2)?,
        retry_backoff_s: args.f64_or("retry-backoff", 0.05)?,
        seed: args.u64_or("seed", 42)?,
    };
    let out = args.str_or("out", "results/train.csv");
    args.finish()?;

    println!(
        "training variant {variant} (K={}, I={}, E={})",
        opts.clients, opts.local_steps, opts.global_rounds
    );
    let dir2 = dir.clone();
    let variant2 = variant.clone();
    let report = train(&opts, move || {
        let m = Manifest::load(&dir2)?;
        Ok(Box::new(SflRuntime::load(&m, &variant2)?) as Box<dyn SflModel>)
    })?;

    let mut w = CsvWriter::create(&out, &["step", "train_loss"])?;
    for (i, l) in report.train_loss.iter().enumerate() {
        w.row_f64(&[(i + 1) as f64, *l])?;
    }
    w.flush()?;
    println!("val curve:");
    for (s, l) in &report.val_loss {
        println!("  step {s:5}  val_loss {l:.4}  ppl {:.4}", l.exp());
    }
    println!(
        "final ppl {:.4} | fed rounds {} | wall {:.1}s (server {:.1}s, agg {:.2}s, eval {:.1}s)",
        report.final_ppl,
        report.fed_rounds,
        report.walltime.total,
        report.walltime.server_compute,
        report.walltime.aggregation,
        report.walltime.evaluation
    );
    println!("loss curve written to {out}");
    Ok(())
}

fn cmd_optimize(args: &mut Args) -> Result<()> {
    let policy_name = args.str_or("policy", "proposed");
    let draws = args.usize_or("draws", 5)?;
    let builder = builder_from_args(args)?;
    args.finish()?;

    let scn = builder.build()?;
    let conv = ConvergenceModel::paper_default();
    let reg = registry_for(builder.config(), draws);
    let out = reg.get(&policy_name)?.solve(&scn, &conv)?;

    let objective = sfllm::opt::Objective::from_config(&scn.objective)?;
    match &out.trajectory {
        Some(traj) => {
            println!("{policy_name} converged in {} iterations", out.iterations);
            println!("objective trajectory: {traj:?}");
        }
        None => println!(
            "{policy_name}: mean objective over {} seeded draws {:.2}; \
             showing the best draw's allocation",
            out.iterations, out.objective
        ),
    }
    println!(
        "chosen: split l_c={} rank r={}  ->  total delay {:.2} s, \
         energy {:.2} kJ (objective {}: {:.2})",
        out.alloc.l_c,
        out.alloc.rank,
        out.delay,
        out.energy / 1e3,
        objective.label(),
        out.objective
    );
    for k in 0..scn.k() {
        println!(
            "  client {k}: main subch {:?} ({:.2} W), fed subch {:?} ({:.2} W)",
            out.alloc.assign_main[k],
            scn.power_main(&out.alloc, k),
            out.alloc.assign_fed[k],
            scn.power_fed(&out.alloc, k),
        );
    }
    Ok(())
}

fn cmd_latency(args: &mut Args) -> Result<()> {
    let spec = args.str_or("policies", "all");
    let draws = args.usize_or("draws", 5)?;
    let out = args.get("out");
    let builder = builder_from_args(args)?;
    args.finish()?;

    // a latency comparison is a single-point sweep, so no --threads here
    let reg = registry_for(builder.config(), draws);
    let report = SweepRunner::new(&builder)
        .policies(reg.resolve(&spec)?)
        .threads(1)
        .run()?;
    let Some(point) = report.points.first() else {
        report.print_errors();
        bail!("scenario could not be evaluated");
    };

    let objective = sfllm::opt::Objective::from_config(&builder.config().objective)?;
    println!(
        "objective '{}' (lower is better), with delay/energy breakdown:",
        objective.label()
    );
    let objectives = point.objectives();
    let proposed = report
        .policy_names
        .iter()
        .position(|n| n == "proposed")
        .map(|i| objectives[i]);
    for (i, (name, t)) in report.policy_names.iter().zip(&objectives).enumerate() {
        let o = &point.outcomes[i];
        let detail = format!("delay {:9.2} s  energy {:9.2} kJ", o.delay, o.energy / 1e3);
        match proposed {
            Some(p) if p > 0.0 => {
                println!("  {name:12} {t:10.2}  x{:.2}  ({detail})", t / p)
            }
            _ => println!("  {name:12} {t:10.2}  ({detail})"),
        }
    }
    if let Some(path) = out {
        report.write_csv(&path)?;
        println!("report written to {path}");
    }
    Ok(())
}

fn cmd_sweep(args: &mut Args) -> Result<()> {
    let axis_name = args
        .get("axis")
        .context("--axis required (bandwidth|client-compute|server-compute|power|clients)")?;
    let values_spec = args
        .get("values")
        .context("--values required (comma-separated numbers, in the axis display unit)")?;
    let values: Vec<f64> = values_spec
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(|s| s.parse::<f64>().with_context(|| format!("bad --values entry '{s}'")))
        .collect::<Result<_>>()?;
    let spec = args.str_or("policies", "all");
    let draws = args.usize_or("draws", 5)?;
    let threads = args.usize_or("threads", 0)?;
    let energy = args.flag("energy");
    let out = args.str_or("out", "results/sweep.csv");
    let json = args.get("json");
    let builder = builder_from_args(args)?;
    args.finish()?;

    let reg = registry_for(builder.config(), draws);
    let report = SweepRunner::new(&builder)
        .over(SweepAxis::by_name(&axis_name, &values)?)
        .policies(reg.resolve(&spec)?)
        .threads(threads)
        .report_energy(energy)
        .run()?;
    report.print_table();
    if !report.errors.is_empty() {
        println!(
            "{} of {} grid point(s) skipped as infeasible ({} error row(s) above)",
            report.skipped_points(),
            report.skipped_points() + report.points.len(),
            report.errors.len()
        );
    }
    report.write_csv(&out)?;
    println!("series written to {out}");
    if let Some(path) = json {
        report.write_json(&path)?;
        println!("json report written to {path}");
    }
    Ok(())
}

fn cmd_dynamic(args: &mut Args) -> Result<()> {
    let spec = args.str_or("policies", "proposed");
    let strategies_spec = args.str_or(
        "strategies",
        "one_shot,every_round,periodic:5,on_degrade:0.25",
    );
    let draws = args.usize_or("draws", 5)?;
    let out = args.get("out");
    let rounds_out = args.get("rounds-out");
    let faults_spec = args.get("faults");
    let builder = builder_from_args(args)?;
    args.finish()?;

    let cfg = builder.config().clone();
    let plan = match &faults_spec {
        Some(s) => FaultPlan::parse(s)?,
        None => FaultPlan::from_config(&cfg.faults)?,
    };
    let d = &cfg.dynamics;
    println!(
        "dynamics: rho={} sigma={} dB, compute jitter {}, dropout {} / rejoin {}, seed {}",
        d.rho,
        if d.shadow_sigma_db < 0.0 { cfg.system.shadowing_db } else { d.shadow_sigma_db },
        d.compute_jitter,
        d.dropout,
        d.rejoin,
        d.seed
    );

    let strategies: Vec<ReOptStrategy> = strategies_spec
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(ReOptStrategy::parse)
        .collect::<Result<_>>()?;
    if strategies.is_empty() {
        bail!("--strategies resolved to an empty list");
    }
    let reg = registry_for(&cfg, draws);
    let inners = reg.resolve(&spec)?;

    if !plan.is_empty() {
        // Fault runs bypass the sweep table: each policy × strategy
        // pair replays directly through the round simulator so the
        // degradation columns (faults injected, repair tier) are
        // visible next to the realized delay.
        if out.is_some() {
            bail!("--out (the sweep report) is not available under --faults; use --rounds-out");
        }
        println!("faults: {}", plan.label());
        let conv = ConvergenceModel::paper_default();
        let scn = builder.build()?;
        let cache = WorkloadCache::new();
        let sim = RoundSimulator::new(&scn, &conv, &cache, &cfg.train.ranks);
        println!("realized total delay (s) under faults, lower is better:");
        let mut first_run = None;
        for inner in &inners {
            for &st in &strategies {
                let run = sim.run_faulted(inner.as_ref(), st, &plan)?;
                let name = format!("{}+{}", inner.name(), st.label());
                println!(
                    "  {name:28} {:12.2}   ({} faults injected, max repair tier {})",
                    run.realized_delay, run.faults_injected, run.repair_max
                );
                if first_run.is_none() {
                    first_run = Some((name, run));
                }
            }
        }
        if let Some(path) = rounds_out {
            let (name, run) = first_run.expect("at least one policy x strategy ran");
            sfllm::service::write_rounds_csv(&path, &run.rounds)?;
            println!(
                "per-round trace of {name} written to {path} \
                 (realized {:.2} s / {:.2} kJ vs static prediction {:.2} s)",
                run.realized_delay,
                run.realized_energy / 1e3,
                run.static_prediction
            );
        }
        return Ok(());
    }

    let mut policies: Vec<std::sync::Arc<dyn AllocationPolicy>> = Vec::new();
    for inner in &inners {
        for &st in &strategies {
            policies.push(std::sync::Arc::new(DynamicPolicy::new(
                inner.clone(),
                st,
                &cfg.train.ranks,
            )));
        }
    }

    // one convergence model for both the comparison table and the
    // --rounds-out trace, so the two surfaces can never disagree
    let conv = ConvergenceModel::paper_default();
    let report = SweepRunner::new(&builder)
        .policies(policies)
        .convergence(conv.clone())
        .threads(1)
        .run()?;
    let Some(point) = report.points.first() else {
        report.print_errors();
        bail!("scenario could not be evaluated");
    };

    println!("realized total delay (s), lower is better:");
    let objectives = point.objectives();
    for (i, inner) in inners.iter().enumerate() {
        let base = i * strategies.len(); // one column per strategy, inner-major
        let one_shot = strategies
            .iter()
            .position(|s| *s == ReOptStrategy::OneShot)
            .map(|j| objectives[base + j]);
        for j in 0..strategies.len() {
            let name = &report.policy_names[base + j];
            let t = objectives[base + j];
            match one_shot {
                Some(os) if os > 0.0 && os.is_finite() => println!(
                    "  {name:28} {t:12.2}   ({:+.1}% vs {}+one_shot)",
                    100.0 * (t / os - 1.0),
                    inner.name()
                ),
                _ => println!("  {name:28} {t:12.2}"),
            }
        }
    }
    if let Some(path) = out {
        report.write_csv(&path)?;
        println!("report written to {path}");
    }

    if let Some(path) = rounds_out {
        // per-round trace of the first policy under the first strategy
        // (a deterministic replay of the sweep's first column, with the
        // per-round fields PolicyOutcome does not carry), under the
        // shared service trace schema — cohort == K and dropped == 0
        // for round-simulator runs
        let scn = builder.build()?;
        let cache = WorkloadCache::new();
        let sim = RoundSimulator::new(&scn, &conv, &cache, &cfg.train.ranks);
        let run = sim.run(inners[0].as_ref(), strategies[0])?;
        sfllm::service::write_rounds_csv(&path, &run.rounds)?;
        println!(
            "per-round trace of {}+{} written to {path} \
             (realized {:.2} s / {:.2} kJ vs static prediction {:.2} s)",
            inners[0].name(),
            strategies[0].label(),
            run.realized_delay,
            run.realized_energy / 1e3,
            run.static_prediction
        );
    }
    Ok(())
}

#[allow(clippy::disallowed_methods)] // wall-clock ms/round display; never feeds results
fn cmd_population(args: &mut Args) -> Result<()> {
    let spec = args.str_or("policies", "proposed");
    let strategies_spec = args.str_or("strategies", "one_shot,periodic:5");
    let draws = args.usize_or("draws", 5)?;
    let rounds_out = args.get("rounds-out");
    let faults_spec = args.get("faults");
    let preset = args.str_or("preset", "metro_population");
    let mut cfg = ScenarioBuilder::preset(&preset)?.into_config();
    cfg.apply_file_and_args(args)?;
    args.finish()?;

    let plan = match &faults_spec {
        Some(s) => FaultPlan::parse(s)?,
        None => FaultPlan::from_config(&cfg.faults)?,
    };
    let pop = Population::new(&cfg)?;
    println!(
        "population: {} modeled clients, cohort {} per round ({}), deadline drop {:.0}%, seed {}",
        pop.size(),
        pop.cohort(),
        pop.selector_label(),
        100.0 * pop.deadline_drop(),
        cfg.population.seed
    );
    let d = &pop.template().dynamics;
    println!(
        "dynamics: rho={} sigma={} dB, compute jitter {}, dropout {} / rejoin {}, seed {}",
        d.rho, d.shadow_sigma_db, d.compute_jitter, d.dropout, d.rejoin, d.seed
    );
    if !plan.is_empty() {
        println!("faults: {}", plan.label());
    }

    let strategies: Vec<ReOptStrategy> = strategies_spec
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(ReOptStrategy::parse)
        .collect::<Result<_>>()?;
    if strategies.is_empty() {
        bail!("--strategies resolved to an empty list");
    }
    let reg = registry_for(&cfg, draws);
    let inners = reg.resolve(&spec)?;
    let conv = ConvergenceModel::paper_default();
    let cache = WorkloadCache::new();
    let sim = PopulationSimulator::new(&pop, &conv, &cache, &cfg.train.ranks);

    println!("realized total delay (s), lower is better:");
    // the first (policy, strategy) run feeds --rounds-out
    let mut first_run = None;
    for inner in &inners {
        let mut one_shot: Option<f64> = None;
        for &st in &strategies {
            // lint:allow(D002) ms/round progress display only; never feeds simulated results
            let t0 = std::time::Instant::now();
            let out = sim.run_faulted(inner.as_ref(), st, &plan)?;
            let elapsed = t0.elapsed().as_secs_f64();
            let name = format!("{}+{}", inner.name(), st.label());
            let ms_per_round = 1e3 * elapsed / out.rounds.len().max(1) as f64;
            if st == ReOptStrategy::OneShot {
                one_shot = Some(out.realized_delay);
            }
            let vs = match one_shot {
                Some(os) if os > 0.0 && os.is_finite() && st != ReOptStrategy::OneShot => {
                    format!("  ({:+.1}% vs one_shot)", 100.0 * (out.realized_delay / os - 1.0))
                }
                _ => String::new(),
            };
            println!("  {name:28} {:12.2}{vs}", out.realized_delay);
            println!(
                "  {:28} {} rounds, {} fresh solves, reached {} clients, \
                 {} deadline cuts, {:.2} ms/round",
                "", out.rounds.len(), out.fresh_solves, out.unique_participants,
                out.deadline_drops, ms_per_round
            );
            if !plan.is_empty() {
                println!(
                    "  {:28} {} faults injected, max repair tier {}",
                    "", out.faults_injected, out.repair_max
                );
            }
            if first_run.is_none() {
                first_run = Some((name, out));
            }
        }
    }

    if let Some(path) = rounds_out {
        let (name, run) = first_run.expect("at least one policy x strategy ran");
        sfllm::service::write_rounds_csv(&path, &run.rounds)?;
        println!(
            "per-round trace of {name} written to {path} \
             (realized {:.2} s / {:.2} kJ vs static prediction {:.2} s)",
            run.realized_delay,
            run.realized_energy / 1e3,
            run.static_prediction
        );
    }
    Ok(())
}

/// `sfllm serve` — the allocator service over a replayable event file.
///
/// The stream is the complete description of the run: every random
/// quantity comes from the seeded streams the opening `scenario_loaded`
/// spec pins down, so replaying the file is bit-identical to having
/// driven the service live, and a `--resume` of a checkpoint written
/// mid-stream continues the uninterrupted run byte for byte (the
/// property `rust/tests/prop_service.rs` holds on every preset).
fn cmd_serve(args: &mut Args) -> Result<()> {
    let events_path = match args.get("events") {
        Some(p) => p,
        None => bail!("serve requires --events <jsonl> (a typed event stream to replay)"),
    };
    let metrics_out = args.get("metrics-out");
    let checkpoint_out = args.get("checkpoint-out");
    let checkpoint_every = args.usize_or("checkpoint-every", 0)?;
    let resume = args.get("resume");
    let lenient = args.flag("lenient");
    args.finish()?;

    if checkpoint_every > 0 && checkpoint_out.is_none() {
        bail!("--checkpoint-every requires --checkpoint-out <path>");
    }

    let text = std::fs::read_to_string(&events_path)
        .with_context(|| format!("reading event stream {events_path}"))?;
    // Strict by default: a malformed line aborts with its line number.
    // --lenient (PR-10) degrades instead — skip the line, warn with the
    // same line-numbered diagnostic, and count it in the run summary.
    let (events, skipped) = if lenient {
        let (events, skipped) = sfllm::service::parse_events_lenient(&text);
        for s in &skipped {
            eprintln!("warning: {events_path}:{}: skipping malformed event: {}", s.line, s.error);
        }
        (events, skipped.len())
    } else {
        (sfllm::service::parse_events(&text)?, 0)
    };
    if events.is_empty() {
        bail!("{events_path} contains no events");
    }

    let mut svc = sfllm::service::AllocatorService::new();
    svc.note_skipped_lines(skipped);
    if let Some(path) = &metrics_out {
        svc.add_sink(Box::new(sfllm::service::JsonlSink::create(path)?));
    }
    if let Some(path) = &checkpoint_out {
        svc.set_default_checkpoint(path);
    }

    // On resume: rebuild the session from the checkpoint, then skip the
    // prefix of the stream the checkpointed run had already consumed. A
    // corrupt or truncated primary checkpoint (the CRC32 footer catches
    // it) degrades to the rotated `.prev` last-good artifact (PR-10).
    let start = if let Some(ck_path) = &resume {
        match try_resume(&mut svc, ck_path, &events, &events_path) {
            Ok(skip) => skip,
            Err(e) => {
                let prev = format!("{ck_path}.prev");
                if std::path::Path::new(&prev).exists() {
                    eprintln!(
                        "warning: checkpoint {ck_path} is unusable ({e:#}); \
                         falling back to {prev}"
                    );
                    try_resume(&mut svc, &prev, &events, &events_path)
                        .with_context(|| format!("fallback checkpoint {prev} is unusable too"))?
                } else {
                    return Err(e);
                }
            }
        }
    } else {
        0
    };

    let mut ticks = 0usize;
    for (i, e) in events.iter().enumerate().skip(start) {
        svc.process(e)
            .with_context(|| format!("event {} ({})", i + 1, e.kind()))?;
        if matches!(e, sfllm::service::Event::RoundTick) {
            ticks += 1;
            if checkpoint_every > 0 && ticks % checkpoint_every == 0 {
                let path = checkpoint_out.as_ref().expect("validated above");
                svc.flush()?;
                svc.write_checkpoint(path)?;
            }
        }
    }
    svc.flush()?;

    match svc.summary() {
        Some(s) => {
            println!(
                "served {} events: {} rounds, realized {:.2} s / {:.2} kJ \
                 (static prediction {:.2} s), {} resolves ({} fresh), converged: {}",
                events.len() - start,
                s.rounds,
                s.realized_delay,
                s.realized_energy / 1e3,
                s.static_prediction,
                s.resolves,
                s.fresh_solves,
                s.converged
            );
            if s.faults_injected > 0 || s.repair_max > 0 || s.lines_skipped > 0 {
                println!(
                    "degradation: {} faults injected, max repair tier {}, \
                     {} malformed line(s) skipped",
                    s.faults_injected, s.repair_max, s.lines_skipped
                );
            }
        }
        None => println!("served {} events (no run opened)", events.len() - start),
    }
    Ok(())
}

/// Restore `svc` from the checkpoint at `ck_path`, verify it belongs to
/// the stream in `events_path`, and return how many stream events the
/// checkpointed run had already consumed.
fn try_resume(
    svc: &mut sfllm::service::AllocatorService,
    ck_path: &str,
    events: &[sfllm::service::Event],
    events_path: &str,
) -> Result<usize> {
    let bytes = std::fs::read(ck_path)
        .with_context(|| format!("reading checkpoint {ck_path}"))?;
    let header = sfllm::service::peek_header(&bytes)?;
    match events.first() {
        Some(sfllm::service::Event::ScenarioLoaded(spec))
            if spec.fingerprint() == header.fingerprint => {}
        Some(sfllm::service::Event::ScenarioLoaded(_)) => bail!(
            "{ck_path} was written by a different run than {events_path} \
             describes (run fingerprints disagree)"
        ),
        _ => bail!("{events_path} must begin with a scenario_loaded event"),
    }
    let skip = header.events_consumed as usize;
    if skip > events.len() {
        bail!(
            "{ck_path} had consumed {skip} events but {events_path} only \
             holds {}",
            events.len()
        );
    }
    // last fallible step: a failure above leaves the service empty, so
    // the caller can retry against the `.prev` fallback artifact
    svc.restore(&bytes)?;
    let done = svc.summary().map(|s| s.rounds).unwrap_or(0);
    println!(
        "resumed {} run at round {done} from {ck_path} \
         ({skip} of {} events already consumed)",
        header.mode.label(),
        events.len()
    );
    Ok(skip)
}

/// `sfllm chaos` — the preset × fault-matrix smoke harness (PR-10).
///
/// Each preset plays the named fault ladder from
/// [`sfllm::sim::faults::matrix_levels`] (none / light / heavy) through
/// its engine — `metro_population` exercises the population engine,
/// every other preset the round simulator — under one policy × strategy
/// pair. The `none` level is asserted bit-identical to a fault-free
/// baseline run of the same simulator (which, because the baseline runs
/// first on the same solver cache, also pins warm-cache determinism);
/// each level's per-round trace can be dumped for external diffing
/// (`--trace-dir`; CI `cmp`s the `none` trace against the plain
/// `dynamic` / `population` `--rounds-out` bytes), and the whole
/// degradation matrix is emitted as machine-readable JSON (`--json`).
fn cmd_chaos(args: &mut Args) -> Result<()> {
    let presets_spec = args.str_or("presets", "mobile_edge,metro_population");
    let policy_name = args.str_or("policy", "proposed");
    let strategy_spec = args.str_or("strategy", "periodic:5");
    let draws = args.usize_or("draws", 5)?;
    let fault_seed = args.u64_or("fault-seed", 0xFA17)?;
    let json = args.get("json");
    let trace_dir = args.get("trace-dir");
    args.finish()?;

    let strategy = ReOptStrategy::parse(&strategy_spec)?;
    let levels = sfllm::sim::faults::matrix_levels(fault_seed);
    let mut blocks = Vec::new();
    for preset in presets_spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        let cfg = ScenarioBuilder::preset(preset)?.into_config();
        let reg = registry_for(&cfg, draws);
        let policy = reg.get(&policy_name)?;
        let conv = ConvergenceModel::paper_default();
        let cache = WorkloadCache::new();
        // metro_population is the population-engine preset; every other
        // preset replays through the round simulator
        let engine = if preset == "metro_population" { "population" } else { "dynamic" };
        println!(
            "chaos: preset {preset} ({engine} engine), {policy_name}+{} over {} level(s)",
            strategy.label(),
            levels.len()
        );
        let rows = if engine == "population" {
            let pop = Population::new(&cfg)?;
            let sim = PopulationSimulator::new(&pop, &conv, &cache, &cfg.train.ranks);
            chaos_levels(preset, &levels, trace_dir.as_deref(), &|plan| {
                sim.run_faulted(policy.as_ref(), strategy, plan)
            })?
        } else {
            let scn = ScenarioBuilder::from_config(cfg.clone()).build()?;
            let sim = RoundSimulator::new(&scn, &conv, &cache, &cfg.train.ranks);
            chaos_levels(preset, &levels, trace_dir.as_deref(), &|plan| {
                sim.run_faulted(policy.as_ref(), strategy, plan)
            })?
        };
        blocks.push(format!(
            "{{\"preset\":\"{preset}\",\"engine\":\"{engine}\",\"levels\":[{}]}}",
            rows.join(",")
        ));
    }

    if let Some(path) = &json {
        let doc = format!(
            "{{\"pr\":\"pr10\",\"policy\":\"{policy_name}\",\"strategy\":\"{}\",\
             \"fault_seed\":{fault_seed},\"presets\":[{}]}}\n",
            strategy.label(),
            blocks.join(",")
        );
        if let Some(dir) = std::path::Path::new(path).parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(path, doc).with_context(|| format!("writing fault matrix to {path}"))?;
        println!("fault matrix written to {path}");
    }
    Ok(())
}

/// Run every fault-matrix level through `run`, assert the zero-fault
/// level is bit-identical to the fault-free baseline, dump per-level
/// traces, and return one JSON object per level.
fn chaos_levels(
    preset: &str,
    levels: &[(&'static str, FaultPlan)],
    trace_dir: Option<&str>,
    run: &dyn Fn(&FaultPlan) -> Result<DynamicOutcome>,
) -> Result<Vec<String>> {
    let baseline = run(&FaultPlan::default())
        .with_context(|| format!("fault-free baseline on {preset}"))?;
    let mut outs = Vec::new();
    for (name, plan) in levels {
        let out = run(plan).with_context(|| format!("chaos level {name} on {preset}"))?;
        if plan.is_empty() {
            assert_chaos_transparency(preset, &baseline, &out)?;
        }
        if let Some(dir) = trace_dir {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating trace dir {dir}"))?;
            let path = format!("{dir}/{preset}_{name}.rounds.csv");
            sfllm::service::write_rounds_csv(&path, &out.rounds)?;
        }
        outs.push((*name, plan.label(), out));
    }
    let none_delay = outs
        .iter()
        .find(|(n, _, _)| *n == "none")
        .map(|(_, _, o)| o.realized_delay)
        .unwrap_or(f64::NAN);
    let mut rows = Vec::new();
    for (name, spec, o) in &outs {
        let vs = if none_delay > 0.0 && none_delay.is_finite() {
            100.0 * (o.realized_delay / none_delay - 1.0)
        } else {
            0.0
        };
        println!(
            "  level {name:6} delay {:12.2} s ({vs:+6.1}% vs none)  {} faults, \
             max repair tier {}, {} deadline cuts",
            o.realized_delay, o.faults_injected, o.repair_max, o.deadline_drops
        );
        rows.push(format!(
            "{{\"level\":\"{name}\",\"spec\":\"{spec}\",\"realized_delay_s\":{},\
             \"realized_energy_j\":{},\"rounds\":{},\"faults_injected\":{},\
             \"repair_max\":{},\"deadline_drops\":{},\"delay_vs_none_pct\":{}}}",
            o.realized_delay,
            o.realized_energy,
            o.rounds.len(),
            o.faults_injected,
            o.repair_max,
            o.deadline_drops,
            vs
        ));
    }
    Ok(rows)
}

/// The chaos harness's transparency invariant: a `none`-level run must
/// match the fault-free baseline down to the float bits — totals and
/// every per-round record.
fn assert_chaos_transparency(
    preset: &str,
    base: &DynamicOutcome,
    none: &DynamicOutcome,
) -> Result<()> {
    let same_totals = base.realized_delay.to_bits() == none.realized_delay.to_bits()
        && base.realized_energy.to_bits() == none.realized_energy.to_bits()
        && base.rounds.len() == none.rounds.len();
    let same_rounds = base.rounds.iter().zip(&none.rounds).all(|(a, b)| {
        a.round == b.round
            && a.weight.to_bits() == b.weight.to_bits()
            && a.delay.to_bits() == b.delay.to_bits()
            && a.energy.to_bits() == b.energy.to_bits()
            && a.l_c == b.l_c
            && a.rank == b.rank
            && a.active == b.active
            && a.resolved == b.resolved
            && a.cohort == b.cohort
            && a.dropped == b.dropped
            && a.faults == b.faults
            && a.repair_tier == b.repair_tier
    });
    if !(same_totals && same_rounds) {
        bail!(
            "zero-fault chaos level diverged from the fault-free baseline on {preset}: \
             the empty fault plan must be bit-transparent \
             (baseline {:.6} s over {} rounds, none-level {:.6} s over {} rounds)",
            base.realized_delay,
            base.rounds.len(),
            none.realized_delay,
            none.rounds.len()
        );
    }
    println!("  level none   verified bit-identical to the fault-free baseline");
    Ok(())
}

fn cmd_bench(args: &mut Args) -> Result<()> {
    let json = args.get("json");
    let full = args.flag("full");
    args.finish()?;

    let report = sfllm::bench::run(&sfllm::bench::BenchOptions { full })?;
    report.print();
    if let Some(path) = json {
        report.write_json(&path)?;
        println!("bench report written to {path}");
    }
    Ok(())
}

fn cmd_lint(args: &mut Args) -> Result<()> {
    let root = args.get("root");
    let json = args.get("json");
    let arch_json = args.get("arch-json");
    let dot_out = args.get("dot-out");
    let allow_unused = args.flag("allow-unused");
    args.finish()?;
    let root = match root {
        Some(r) => std::path::PathBuf::from(r),
        None => sfllm::analysis::detect_root()?,
    };
    let opts = sfllm::analysis::LintOptions { allow_unused };
    let report = sfllm::analysis::lint_repo(&root, &opts)?;
    if let Some(path) = &json {
        std::fs::write(path, report.to_json())
            .with_context(|| format!("writing lint report to {path}"))?;
    }
    if let Some(path) = &arch_json {
        std::fs::write(path, report.arch.to_json())
            .with_context(|| format!("writing architecture report to {path}"))?;
    }
    if let Some(path) = &dot_out {
        std::fs::write(path, report.arch.to_dot())
            .with_context(|| format!("writing architecture graph to {path}"))?;
    }
    for f in &report.findings {
        println!("{}:{}: [{}] {} ({})", f.file, f.line, f.rule, f.message, f.snippet);
    }
    let unused = report.suppressions.iter().filter(|s| !s.used).count();
    println!(
        "sfllm-lint: {} files scanned, {} finding(s), {} suppression(s) ({} unused)",
        report.files_scanned, report.findings.len(), report.suppressions.len(), unused
    );
    println!(
        "sfllm-arch: {} modules, {} edges, g001={}, g002={}, contract fingerprint {}",
        report.arch.modules.len(),
        report.arch.edges.len(),
        report.arch.count("G001"),
        report.arch.count("G002"),
        report.arch.fingerprint
    );
    if let Some(path) = &json {
        println!("lint report written to {path}");
    }
    if let Some(path) = &arch_json {
        println!("architecture report written to {path}");
    }
    if let Some(path) = &dot_out {
        println!("architecture graph written to {path}");
    }
    if !report.findings.is_empty() {
        bail!(
            "sfllm-lint: {} unsuppressed finding(s); see the determinism and architecture \
             contracts in DESIGN.md",
            report.findings.len()
        );
    }
    Ok(())
}

fn cmd_table3(args: &mut Args) -> Result<()> {
    let seq = args.usize_or("seq", 512)?;
    let model = args.str_or("model", "gpt2-s");
    args.finish()?;
    let cfg = Gpt2Config::by_name(&model)?;
    let p = WorkloadProfile::new(cfg.clone(), seq);
    println!(
        "computational complexity of {} with LoRA (seq={seq}, per sample)",
        cfg.name
    );
    println!("{:<28} {:>12} {:>16}", "component", "params", "fwd GFLOPs");
    let g = 1e9;
    let t = seq as f64;
    let d = cfg.d_model as f64;
    let f = cfg.d_ff() as f64;
    let h = cfg.n_heads as f64;
    let ln = 2.0 * 8.0 * t * d;
    let mha = 8.0 * t * d * d + 4.0 * t * t * d + 5.0 * h * t * t;
    let ffn = 2.0 * 2.0 * t * d * f + 8.0 * t * f;
    let lora = 8.0 * t * d;
    println!("{:<28} {:>12} {:>16}", "token embedding", fmt_m(cfg.params_token_embedding()), "-");
    println!("{:<28} {:>12} {:>16}", "position encoding", fmt_m(cfg.params_position_encoding()), "-");
    println!("transformer block x{}", cfg.n_layers);
    println!("{:<28} {:>12} {:>16.3}", "  layernorm (x2)", fmt_m(2 * cfg.params_layernorm()), ln / g);
    println!("{:<28} {:>12} {:>16.3}", "  multi-head attention", fmt_m(cfg.params_attention()), mha / g);
    println!("{:<28} {:>12} {:>16.3}", "  lora adapter (per rank)", fmt_m(cfg.params_lora_per_rank_block()), lora / g);
    println!("{:<28} {:>12} {:>16.3}", "  feed-forward", fmt_m(cfg.params_ffn()), ffn / g);
    println!("{:<28} {:>12} {:>16.3}", "final layernorm", fmt_m(cfg.params_layernorm()), 8.0 * t * d / g);
    println!("{:<28} {:>12} {:>16.3}", "lm head (tied)", "-", p.head_fwd_flops / g);
    println!("{:<28} {:>12}", "total params", fmt_m(cfg.params_total()));
    Ok(())
}

fn fmt_m(n: usize) -> String {
    if n >= 1_000_000 {
        format!("{:.2}M", n as f64 / 1e6)
    } else if n >= 1000 {
        format!("{:.1}K", n as f64 / 1e3)
    } else {
        format!("{n}")
    }
}

fn cmd_info(args: &mut Args) -> Result<()> {
    let dir = artifacts_dir(args);
    args.finish()?;
    let m = Manifest::load(&dir).context("run `make artifacts` first")?;
    println!("artifact variants in {dir}:");
    for (name, v) in &m.variants {
        let cfg = m.config(&v.config)?;
        println!(
            "  {name:16} config={} l_c={} rank={} (B={}, T={}, d={}, vocab={})",
            v.config, v.l_c, v.rank, cfg.batch, cfg.seq, cfg.d_model, cfg.vocab
        );
    }
    if m.variants.is_empty() {
        bail!("no variants found");
    }
    Ok(())
}
