//! sfllm — command-line launcher for the SfLLM reproduction.
//!
//! Subcommands:
//!
//! * `train`    — run split-federated fine-tuning (Algorithm 1) over an
//!                AOT artifact variant, logging the loss curve to CSV;
//! * `optimize` — run the joint resource-allocation optimizer
//!                (Algorithm 3) on a wireless scenario and print the
//!                chosen allocation;
//! * `latency`  — evaluate the proposed scheme against baselines a–d;
//! * `table3`   — print the GPT2-S complexity table (paper Table III);
//! * `info`     — list available artifact variants.
//!
//! Defaults reproduce the paper's Table II setup.

use anyhow::{bail, Context, Result};
use sfllm::config::Config;
use sfllm::coordinator::{train, OptKind, TrainOptions};
use sfllm::delay::ConvergenceModel;
use sfllm::model::{Gpt2Config, WorkloadProfile};
use sfllm::opt::baselines;
use sfllm::opt::bcd::{self, BcdOptions};
use sfllm::runtime::{Manifest, SflModel, SflRuntime};
use sfllm::sim;
use sfllm::util::cli::Args;
use sfllm::util::csv::CsvWriter;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let mut args = Args::from_env();
    let cmd = args
        .positional()
        .first()
        .cloned()
        .unwrap_or_else(|| "help".to_string());
    match cmd.as_str() {
        "train" => cmd_train(&mut args),
        "optimize" => cmd_optimize(&mut args),
        "latency" => cmd_latency(&mut args),
        "table3" => cmd_table3(&mut args),
        "info" => cmd_info(&mut args),
        _ => {
            println!(
                "sfllm — split federated learning for LLMs (paper reproduction)\n\n\
                 usage: sfllm <train|optimize|latency|table3|info> [--options]\n\n\
                 train     run Algorithm 1 over an artifact variant\n\
                 optimize  run the BCD resource optimizer (Algorithm 3)\n\
                 latency   compare proposed allocation vs baselines a-d\n\
                 table3    print the GPT2-S complexity table (Table III)\n\
                 info      list artifact variants"
            );
            Ok(())
        }
    }
}

fn artifacts_dir(args: &mut Args) -> String {
    args.str_or("artifacts", "artifacts")
}

fn cmd_train(args: &mut Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let variant = args.str_or("variant", "tiny_s2_r4");
    let opts = TrainOptions {
        clients: args.usize_or("clients", 5)?,
        local_steps: args.usize_or("local-steps", 12)?,
        global_rounds: args.usize_or("rounds", 25)?,
        lr_client: args.f64_or("lr", 1e-3)? as f32,
        lr_server: args.f64_or("lr", 1e-3)? as f32,
        corpus_size: args.usize_or("corpus", 2000)?,
        val_size: args.usize_or("val", 200)?,
        eval_batches: args.usize_or("eval-batches", 4)?,
        non_iid: args.flag("non-iid"),
        optimizer: if args.flag("sgd") { OptKind::Sgd } else { OptKind::Adam },
        byte_corpus: args.flag("byte-corpus"),
        save_adapters: args.get("save-adapters"),
        seed: args.u64_or("seed", 42)?,
    };
    let out = args.str_or("out", "results/train.csv");
    args.finish()?;

    println!(
        "training variant {variant} (K={}, I={}, E={})",
        opts.clients, opts.local_steps, opts.global_rounds
    );
    let dir2 = dir.clone();
    let variant2 = variant.clone();
    let report = train(&opts, move || {
        let m = Manifest::load(&dir2)?;
        Ok(Box::new(SflRuntime::load(&m, &variant2)?) as Box<dyn SflModel>)
    })?;

    let mut w = CsvWriter::create(&out, &["step", "train_loss"])?;
    for (i, l) in report.train_loss.iter().enumerate() {
        w.row_f64(&[(i + 1) as f64, *l])?;
    }
    w.flush()?;
    println!("val curve:");
    for (s, l) in &report.val_loss {
        println!("  step {s:5}  val_loss {l:.4}  ppl {:.4}", l.exp());
    }
    println!(
        "final ppl {:.4} | fed rounds {} | wall {:.1}s (server {:.1}s, agg {:.2}s, eval {:.1}s)",
        report.final_ppl,
        report.fed_rounds,
        report.walltime.total,
        report.walltime.server_compute,
        report.walltime.aggregation,
        report.walltime.evaluation
    );
    println!("loss curve written to {out}");
    Ok(())
}

fn cmd_optimize(args: &mut Args) -> Result<()> {
    let cfg = Config::from_args(args)?;
    args.finish()?;
    let scn = sim::build_scenario(&cfg)?;
    let conv = ConvergenceModel::paper_default();
    let opts = BcdOptions {
        ranks: cfg.train.ranks.clone(),
        ..BcdOptions::default()
    };
    let res = bcd::optimize(&scn, &conv, &opts)?;
    println!("BCD converged in {} iterations", res.iterations);
    println!("objective trajectory: {:?}", res.trajectory);
    println!(
        "chosen: split l_c={} rank r={}  ->  total delay {:.2} s",
        res.alloc.l_c, res.alloc.rank, res.objective
    );
    for k in 0..scn.k() {
        println!(
            "  client {k}: main subch {:?} ({:.2} W), fed subch {:?} ({:.2} W)",
            res.alloc.assign_main[k],
            scn.power_main(&res.alloc, k),
            res.alloc.assign_fed[k],
            scn.power_fed(&res.alloc, k),
        );
    }
    Ok(())
}

fn cmd_latency(args: &mut Args) -> Result<()> {
    let draws = args.usize_or("draws", 5)?;
    let cfg = Config::from_args(args)?;
    args.finish()?;
    let scn = sim::build_scenario(&cfg)?;
    let conv = ConvergenceModel::paper_default();
    let [p, a, b, c, d] =
        baselines::compare_all(&scn, &conv, &cfg.train.ranks, cfg.system.seed, draws)?;
    println!("total training delay (s), paper baselines (lower is better):");
    println!("  proposed    {p:10.2}");
    println!("  baseline a  {a:10.2}  (random everything)  x{:.2}", a / p);
    println!("  baseline b  {b:10.2}  (random comm)        x{:.2}", b / p);
    println!("  baseline c  {c:10.2}  (random split)       x{:.2}", c / p);
    println!("  baseline d  {d:10.2}  (random rank)        x{:.2}", d / p);
    Ok(())
}

fn cmd_table3(args: &mut Args) -> Result<()> {
    let seq = args.usize_or("seq", 512)?;
    let model = args.str_or("model", "gpt2-s");
    args.finish()?;
    let cfg = Gpt2Config::by_name(&model)?;
    let p = WorkloadProfile::new(cfg.clone(), seq);
    println!(
        "computational complexity of {} with LoRA (seq={seq}, per sample)",
        cfg.name
    );
    println!("{:<28} {:>12} {:>16}", "component", "params", "fwd GFLOPs");
    let g = 1e9;
    let t = seq as f64;
    let d = cfg.d_model as f64;
    let f = cfg.d_ff() as f64;
    let h = cfg.n_heads as f64;
    let ln = 2.0 * 8.0 * t * d;
    let mha = 8.0 * t * d * d + 4.0 * t * t * d + 5.0 * h * t * t;
    let ffn = 2.0 * 2.0 * t * d * f + 8.0 * t * f;
    let lora = 8.0 * t * d;
    println!("{:<28} {:>12} {:>16}", "token embedding", fmt_m(cfg.params_token_embedding()), "-");
    println!("{:<28} {:>12} {:>16}", "position encoding", fmt_m(cfg.params_position_encoding()), "-");
    println!("transformer block x{}", cfg.n_layers);
    println!("{:<28} {:>12} {:>16.3}", "  layernorm (x2)", fmt_m(2 * cfg.params_layernorm()), ln / g);
    println!("{:<28} {:>12} {:>16.3}", "  multi-head attention", fmt_m(cfg.params_attention()), mha / g);
    println!("{:<28} {:>12} {:>16.3}", "  lora adapter (per rank)", fmt_m(cfg.params_lora_per_rank_block()), lora / g);
    println!("{:<28} {:>12} {:>16.3}", "  feed-forward", fmt_m(cfg.params_ffn()), ffn / g);
    println!("{:<28} {:>12} {:>16.3}", "final layernorm", fmt_m(cfg.params_layernorm()), 8.0 * t * d / g);
    println!("{:<28} {:>12} {:>16.3}", "lm head (tied)", "-", p.head_fwd_flops / g);
    println!("{:<28} {:>12}", "total params", fmt_m(cfg.params_total()));
    Ok(())
}

fn fmt_m(n: usize) -> String {
    if n >= 1_000_000 {
        format!("{:.2}M", n as f64 / 1e6)
    } else if n >= 1000 {
        format!("{:.1}K", n as f64 / 1e3)
    } else {
        format!("{n}")
    }
}

fn cmd_info(args: &mut Args) -> Result<()> {
    let dir = artifacts_dir(args);
    args.finish()?;
    let m = Manifest::load(&dir).context("run `make artifacts` first")?;
    println!("artifact variants in {dir}:");
    for (name, v) in &m.variants {
        let cfg = m.config(&v.config)?;
        println!(
            "  {name:16} config={} l_c={} rank={} (B={}, T={}, d={}, vocab={})",
            v.config, v.l_c, v.rank, cfg.batch, cfg.seq, cfg.d_model, cfg.vocab
        );
    }
    if m.variants.is_empty() {
        bail!("no variants found");
    }
    Ok(())
}
