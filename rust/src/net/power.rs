//! Power unit conversions: dBm ⇄ watts, PSD helpers.
//!
//! The paper quotes powers in dBm (Table II: p_max = 41.76 dBm,
//! p_th = 46.99 dBm, noise PSD −174 dBm/Hz); the solver works in watts
//! and W/Hz.

/// dBm to watts.
pub fn dbm_to_watt(dbm: f64) -> f64 {
    10f64.powf(dbm / 10.0) * 1e-3
}

/// Watts to dBm.
pub fn watt_to_dbm(w: f64) -> f64 {
    10.0 * (w / 1e-3).log10()
}

/// dBm/Hz to W/Hz (noise PSD).
pub fn dbm_per_hz_to_watt_per_hz(dbm_hz: f64) -> f64 {
    dbm_to_watt(dbm_hz)
}

/// Decibels to linear ratio.
pub fn db_to_linear(db: f64) -> f64 {
    10f64.powf(db / 10.0)
}

/// Linear ratio to decibels.
pub fn linear_to_db(lin: f64) -> f64 {
    10.0 * lin.log10()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dbm_round_trip() {
        for dbm in [-174.0, 0.0, 30.0, 41.76, 46.99] {
            let w = dbm_to_watt(dbm);
            assert!((watt_to_dbm(w) - dbm).abs() < 1e-9);
        }
    }

    #[test]
    fn table_ii_values() {
        // 41.76 dBm ≈ 15 W, 46.99 dBm ≈ 50 W, −174 dBm/Hz ≈ 3.98e-21 W/Hz
        assert!((dbm_to_watt(41.76) - 15.0).abs() < 0.05);
        assert!((dbm_to_watt(46.99) - 50.0).abs() < 0.15);
        let n0 = dbm_per_hz_to_watt_per_hz(-174.0);
        assert!((n0 - 3.98e-21).abs() < 0.02e-21);
    }

    #[test]
    fn db_linear_round_trip() {
        for db in [-20.0, 0.0, 9.03] {
            assert!((linear_to_db(db_to_linear(db)) - db).abs() < 1e-9);
        }
    }
}
