//! Round-varying channel dynamics: per-client shadow fading evolved as
//! a seeded AR(1) Gauss–Markov process across global rounds.
//!
//! The static substrate draws shadowing once per scenario (the paper's
//! "average channel gain" reading of Eqs. 9/14). Multi-round runs over
//! mobile edge networks see the shadowing *drift* instead; the standard
//! model is the Gauss–Markov recursion
//!
//! `s_{e+1} = ρ·s_e + sqrt(1 − ρ²)·σ·w_e`,   `w_e ~ N(0, 1)`
//!
//! which keeps the stationary distribution at the scenario's N(0, σ²)
//! log-normal shadowing while correlating consecutive rounds by ρ.
//! `ρ = 1` (or `σ = 0`) freezes the state — the process then touches
//! neither the shadows nor its RNG, so a frozen trajectory reproduces
//! the static scenario bit for bit.
//!
//! [`ChannelState`] is the shadow vector itself (both uplinks); it can
//! be sampled fresh — exactly the draw order `ScenarioBuilder` uses —
//! or recovered from an already-built scenario's linear gains.
//! [`ChannelProcess`] owns a state plus the AR(1) parameters and a
//! seeded RNG stream, and is what [`crate::sim::RoundSimulator`] steps
//! once per simulated round.

use crate::net::channel::ChannelModel;
use crate::net::power::{db_to_linear, linear_to_db};
use crate::net::topology::Topology;
use crate::util::rng::Rng;

/// Per-client shadow fading (dB) on the main and federated uplinks.
#[derive(Clone, Debug)]
pub struct ChannelState {
    pub shadow_main_db: Vec<f64>,
    pub shadow_fed_db: Vec<f64>,
}

impl ChannelState {
    /// Draw an initial state: N(0, σ²) in dB per client per link, all
    /// main-link draws first and then all fed-link draws — the exact
    /// order (and therefore the exact values) `ScenarioBuilder::build`
    /// consumes from its gain stream, so a scenario and a process
    /// seeded alike start from identical shadowing. With `σ = 0` no
    /// randomness is consumed, matching [`ChannelModel::gain`].
    pub fn sample(k: usize, model: &ChannelModel, rng: &mut Rng) -> ChannelState {
        let draw_all = |rng: &mut Rng| -> Vec<f64> {
            (0..k)
                .map(|_| {
                    if model.shadowing_db > 0.0 {
                        rng.normal_ms(0.0, model.shadowing_db)
                    } else {
                        0.0
                    }
                })
                .collect()
        };
        let shadow_main_db = draw_all(rng);
        let shadow_fed_db = draw_all(rng);
        ChannelState {
            shadow_main_db,
            shadow_fed_db,
        }
    }

    /// Recover the state that reproduces the given *linear* gains under
    /// `model` — the inverse of [`ChannelState::gains`], up to a
    /// floating-point round trip (~1e-12 dB). This lets a dynamic
    /// process continue from a scenario that only stored its gains
    /// (including hand-built test scenarios whose gains were never
    /// derived from a distance at all).
    pub fn recover(
        topo: &Topology,
        model: &ChannelModel,
        main_gain: &[f64],
        fed_gain: &[f64],
    ) -> ChannelState {
        let shadow = |d: f64, g: f64| -linear_to_db(g) - model.path_loss_db(d);
        ChannelState {
            shadow_main_db: topo
                .clients
                .iter()
                .zip(main_gain)
                .map(|(c, &g)| shadow(c.d_main_m, g))
                .collect(),
            shadow_fed_db: topo
                .clients
                .iter()
                .zip(fed_gain)
                .map(|(c, &g)| shadow(c.d_fed_m, g))
                .collect(),
        }
    }

    pub fn k(&self) -> usize {
        self.shadow_main_db.len()
    }

    /// Linear gains (main, fed) for the current state — the same
    /// `db_to_linear(-(path_loss + shadow))` expression as
    /// [`ChannelModel::gain`], so equal shadows give bit-equal gains.
    pub fn gains(&self, topo: &Topology, model: &ChannelModel) -> (Vec<f64>, Vec<f64>) {
        let main = topo
            .clients
            .iter()
            .zip(&self.shadow_main_db)
            .map(|(c, &s)| db_to_linear(-(model.path_loss_db(c.d_main_m) + s)))
            .collect();
        let fed = topo
            .clients
            .iter()
            .zip(&self.shadow_fed_db)
            .map(|(c, &s)| db_to_linear(-(model.path_loss_db(c.d_fed_m) + s)))
            .collect();
        (main, fed)
    }
}

/// Closed-form `gap`-step composition of the AR(1) recursion: the
/// coefficients `(ρ^gap, σ·sqrt(1 − ρ^{2gap}))` such that
///
/// `s' = ρ^gap·s + σ·sqrt(1 − ρ^{2gap})·w`,   `w ~ N(0, 1)`
///
/// has exactly the distribution of `gap` sequential steps from `s`
/// (iterating the recursion telescopes the innovations into one
/// Gaussian of that variance). This is what lets a population engine
/// advance a client that skipped `gap` rounds in O(1) instead of O(gap).
///
/// Exactness contract, relied on by `sim::population` and property
/// tests: at `gap = 1` the returned pair is **bit-identical** to the
/// eager step's `(rho, innovation_db)` — ρ^1 is ρ itself (the binary
/// exponentiation multiplies by 1.0, exact in IEEE 754) and ρ^2 is
/// computed as `ρ·ρ`, the same expression [`ChannelProcess::new`]
/// folds into `innovation_db`. For larger gaps the equivalence to
/// `gap` sequential steps is distributional, not path-bitwise: `gap`
/// steps consume `gap` independent Gaussians while the jump consumes
/// one, so no bijection of draws can make the trajectories equal —
/// see DESIGN.md (PR-6) for why that is a theorem, not a limitation.
pub fn ar1_jump(rho: f64, sigma_db: f64, gap: u64) -> (f64, f64) {
    if gap == 0 {
        return (1.0, 0.0);
    }
    // binary exponentiation; `1.0 * x` and `x * y` are exact/commutative
    // in IEEE 754, so gap = 1 returns rho's own bits
    let mut rho_k = 1.0f64;
    let mut base = rho;
    let mut e = gap;
    while e > 0 {
        if e & 1 == 1 {
            rho_k *= base;
        }
        e >>= 1;
        if e > 0 {
            base *= base;
        }
    }
    let sigma_k = (1.0 - rho_k * rho_k).max(0.0).sqrt() * sigma_db;
    (rho_k, sigma_k)
}

/// Seeded AR(1) evolution of a [`ChannelState`].
#[derive(Clone, Debug)]
pub struct ChannelProcess {
    model: ChannelModel,
    state: ChannelState,
    rho: f64,
    /// Innovation std `sqrt(1 − ρ²)·σ` (dB); 0 freezes the process.
    innovation_db: f64,
    rng: Rng,
}

impl ChannelProcess {
    /// `model.shadowing_db` is the stationary shadowing std σ; `rho`
    /// the round-to-round correlation in [0, 1].
    pub fn new(model: ChannelModel, state: ChannelState, rho: f64, seed: u64) -> ChannelProcess {
        assert!(
            (0.0..=1.0).contains(&rho),
            "AR(1) correlation must be in [0, 1], got {rho}"
        );
        let innovation_db = (1.0 - rho * rho).max(0.0).sqrt() * model.shadowing_db;
        ChannelProcess {
            model,
            state,
            rho,
            innovation_db,
            rng: Rng::new(seed),
        }
    }

    /// True when stepping can never change the state (`ρ = 1` or
    /// `σ = 0`): callers may then skip rewriting gains entirely and
    /// keep the static scenario's vectors bit-for-bit.
    pub fn is_frozen(&self) -> bool {
        self.innovation_db == 0.0
    }

    /// Advance one round: `s ← ρ·s + sqrt(1 − ρ²)·σ·w`. Frozen
    /// processes return immediately without consuming randomness.
    pub fn step(&mut self) {
        if self.is_frozen() {
            return;
        }
        for s in self
            .state
            .shadow_main_db
            .iter_mut()
            .chain(self.state.shadow_fed_db.iter_mut())
        {
            *s = self.rho * *s + self.rng.normal_ms(0.0, self.innovation_db);
        }
    }

    /// Advance `gap` rounds in one O(1)-per-client jump:
    /// `s ← ρ^gap·s + σ·sqrt(1 − ρ^{2gap})·w`, one innovation draw per
    /// shadow regardless of the gap (see [`ar1_jump`]). `advance(1)` is
    /// bit-identical to [`Self::step`]; larger gaps are exact in
    /// distribution but draw one Gaussian where `gap` sequential steps
    /// would draw `gap` — the whole point of the closed form. Frozen
    /// processes (and `gap = 0`) return without consuming randomness.
    pub fn advance(&mut self, gap: u64) {
        if self.is_frozen() || gap == 0 {
            return;
        }
        let (rho_k, sigma_k) = ar1_jump(self.rho, self.model.shadowing_db, gap);
        for s in self
            .state
            .shadow_main_db
            .iter_mut()
            .chain(self.state.shadow_fed_db.iter_mut())
        {
            *s = rho_k * *s + self.rng.normal_ms(0.0, sigma_k);
        }
    }

    pub fn state(&self) -> &ChannelState {
        &self.state
    }

    /// Overwrite the shadow state (checkpoint restore). The vectors
    /// must keep the process's client count.
    pub fn set_state(&mut self, state: ChannelState) {
        assert_eq!(
            state.k(),
            self.state.k(),
            "ChannelProcess::set_state: client count changed"
        );
        self.state = state;
    }

    /// Snapshot the innovation RNG's stream position (checkpoint save).
    pub fn rng_state(&self) -> [u64; 4] {
        self.rng.state()
    }

    /// Restore the innovation RNG's stream position (checkpoint
    /// restore): subsequent steps redraw the exact innovation sequence
    /// the uninterrupted process would have drawn.
    pub fn set_rng_state(&mut self, s: [u64; 4]) {
        self.rng = Rng::from_state(s);
    }

    /// Current linear gains (main, fed).
    pub fn gains(&self, topo: &Topology) -> (Vec<f64>, Vec<f64>) {
        self.state.gains(topo, &self.model)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::topology::ClientSite;

    fn topo2() -> Topology {
        Topology {
            clients: vec![
                ClientSite { d_main_m: 100.0, d_fed_m: 10.0, f_cycles: 1e9 },
                ClientSite { d_main_m: 150.0, d_fed_m: 18.0, f_cycles: 1.5e9 },
            ],
        }
    }

    #[test]
    fn sample_matches_the_builder_draw_order() {
        // drawing all main shadows first, then all fed shadows, must
        // consume the rng exactly like two sequential gain() passes
        let model = ChannelModel::new(8.0);
        let topo = topo2();
        let state = ChannelState::sample(2, &model, &mut Rng::new(77));
        let (main, fed) = state.gains(&topo, &model);
        let mut rng = Rng::new(77);
        let want_main: Vec<f64> =
            topo.clients.iter().map(|c| model.gain(c.d_main_m, &mut rng)).collect();
        let want_fed: Vec<f64> =
            topo.clients.iter().map(|c| model.gain(c.d_fed_m, &mut rng)).collect();
        assert_eq!(main, want_main);
        assert_eq!(fed, want_fed);
    }

    #[test]
    fn recover_round_trips_gains_to_high_precision() {
        let model = ChannelModel::new(8.0);
        let topo = topo2();
        let state = ChannelState::sample(2, &model, &mut Rng::new(5));
        let (main, fed) = state.gains(&topo, &model);
        let rec = ChannelState::recover(&topo, &model, &main, &fed);
        for (a, b) in state.shadow_main_db.iter().zip(&rec.shadow_main_db) {
            assert!((a - b).abs() < 1e-9, "{a} vs {b}");
        }
        for (a, b) in state.shadow_fed_db.iter().zip(&rec.shadow_fed_db) {
            assert!((a - b).abs() < 1e-9, "{a} vs {b}");
        }
    }

    #[test]
    fn frozen_process_never_moves_and_consumes_no_randomness() {
        let model = ChannelModel::new(8.0);
        let topo = topo2();
        let state = ChannelState::sample(2, &model, &mut Rng::new(9));
        let before = state.clone();
        let mut p = ChannelProcess::new(model.clone(), state, 1.0, 3);
        assert!(p.is_frozen());
        for _ in 0..10 {
            p.step();
        }
        assert_eq!(p.state().shadow_main_db, before.shadow_main_db);
        assert_eq!(p.state().shadow_fed_db, before.shadow_fed_db);
        let (g, _) = p.gains(&topo);
        let (g0, _) = before.gains(&topo, &model);
        assert_eq!(g, g0, "frozen gains must be bit-identical");
        // sigma = 0 freezes too, at any rho
        let m0 = ChannelModel::new(0.0);
        let s0 = ChannelState::sample(2, &m0, &mut Rng::new(1));
        assert!(ChannelProcess::new(m0, s0, 0.3, 4).is_frozen());
    }

    #[test]
    fn process_is_deterministic_per_seed() {
        let model = ChannelModel::new(8.0);
        let run = |seed| {
            let state = ChannelState::sample(2, &model, &mut Rng::new(11));
            let mut p = ChannelProcess::new(model.clone(), state, 0.7, seed);
            for _ in 0..25 {
                p.step();
            }
            p.state().shadow_main_db.clone()
        };
        assert_eq!(run(42), run(42));
        assert_ne!(run(42), run(43));
    }

    #[test]
    fn ar1_jump_at_gap_one_reproduces_the_step_coefficients_bit_for_bit() {
        for rho in [0.0, 0.3, 0.85, 0.999, 1.0] {
            for sigma in [0.0, 4.0, 8.0] {
                let (rho_k, sigma_k) = ar1_jump(rho, sigma, 1);
                assert_eq!(rho_k.to_bits(), rho.to_bits(), "rho={rho}");
                let innovation = (1.0 - rho * rho).max(0.0).sqrt() * sigma;
                assert_eq!(sigma_k.to_bits(), innovation.to_bits(), "rho={rho} sigma={sigma}");
            }
        }
        // gap = 0 is the identity jump
        assert_eq!(ar1_jump(0.7, 8.0, 0), (1.0, 0.0));
    }

    #[test]
    fn ar1_jump_variance_matches_iterated_composition() {
        // composing the 1-step recursion k times gives variance
        // sigma^2 (1 - rho^{2k}); the closed form must agree to fp
        // accuracy for every gap (and decay rho^k for the mean term)
        let (rho, sigma) = (0.85f64, 8.0f64);
        for gap in [1u64, 2, 3, 7, 32, 1000] {
            let (rho_k, sigma_k) = ar1_jump(rho, sigma, gap);
            let want_rho = rho.powi(gap as i32);
            let want_sig = (1.0 - rho.powi(2 * gap as i32)).max(0.0).sqrt() * sigma;
            assert!((rho_k - want_rho).abs() <= 1e-12 * want_rho.max(1e-300), "gap {gap}");
            assert!((sigma_k - want_sig).abs() <= 1e-12 * sigma, "gap {gap}");
        }
        // huge gaps forget the state entirely: stationary redraw
        let (rho_k, sigma_k) = ar1_jump(rho, sigma, 100_000);
        assert_eq!(rho_k, 0.0);
        assert_eq!(sigma_k, sigma);
    }

    #[test]
    fn advance_one_is_bit_identical_to_step() {
        let model = ChannelModel::new(8.0);
        let state = ChannelState::sample(3, &model, &mut Rng::new(21));
        let mut stepped = ChannelProcess::new(model.clone(), state.clone(), 0.8, 17);
        let mut jumped = ChannelProcess::new(model, state, 0.8, 17);
        for round in 0..40 {
            stepped.step();
            jumped.advance(1);
            for (a, b) in stepped
                .state()
                .shadow_main_db
                .iter()
                .chain(&stepped.state().shadow_fed_db)
                .zip(jumped.state().shadow_main_db.iter().chain(&jumped.state().shadow_fed_db))
            {
                assert_eq!(a.to_bits(), b.to_bits(), "round {round}");
            }
        }
    }

    #[test]
    fn advance_gap_consumes_one_draw_per_shadow_and_freezes_correctly() {
        let model = ChannelModel::new(8.0);
        let state = ChannelState::sample(2, &model, &mut Rng::new(4));
        // frozen: no state change, no rng consumption, at any gap
        let mut frozen = ChannelProcess::new(model.clone(), state.clone(), 1.0, 5);
        let before = frozen.state().clone();
        frozen.advance(1000);
        assert_eq!(frozen.state().shadow_main_db, before.shadow_main_db);
        // gap = 0 is a no-op even when unfrozen
        let mut p = ChannelProcess::new(model.clone(), state.clone(), 0.6, 5);
        let s0 = p.state().clone();
        p.advance(0);
        assert_eq!(p.state().shadow_main_db, s0.shadow_main_db);
        // a gap-k jump and k steps consume different draw counts, so
        // the trajectories must diverge — bitwise path equality across
        // decompositions is impossible by construction (see ar1_jump
        // docs); determinism per (seed, gap) still holds
        let run = |gap: u64| {
            let mut p =
                ChannelProcess::new(model.clone(), state.clone(), 0.6, 5);
            p.advance(gap);
            p.state().shadow_main_db.clone()
        };
        assert_eq!(run(7), run(7), "same gap must be deterministic");
        let mut stepped = ChannelProcess::new(model.clone(), state, 0.6, 5);
        for _ in 0..7 {
            stepped.step();
        }
        assert_ne!(run(7), stepped.state().shadow_main_db);
    }

    #[test]
    fn checkpoint_accessors_resume_the_exact_trajectory() {
        let model = ChannelModel::new(8.0);
        let state = ChannelState::sample(3, &model, &mut Rng::new(13));
        let mut p = ChannelProcess::new(model.clone(), state.clone(), 0.8, 99);
        for _ in 0..12 {
            p.step();
        }
        // snapshot mid-trajectory, keep stepping the original
        let saved_state = p.state().clone();
        let saved_rng = p.rng_state();
        for _ in 0..20 {
            p.step();
        }
        // rebuild a fresh process from the immutable spec + snapshot
        let mut q = ChannelProcess::new(model, state, 0.8, 99);
        q.set_state(saved_state);
        q.set_rng_state(saved_rng);
        for _ in 0..20 {
            q.step();
        }
        for (a, b) in p
            .state()
            .shadow_main_db
            .iter()
            .chain(&p.state().shadow_fed_db)
            .zip(q.state().shadow_main_db.iter().chain(&q.state().shadow_fed_db))
        {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn advance_gap_matches_stepping_in_distribution() {
        // many independent clients, one jump of gap 9 vs 9 steps:
        // match of mean decay and stationary variance within mc error
        let sigma = 8.0;
        let rho = 0.9;
        let gap = 9u64;
        let k = 20_000;
        let model = ChannelModel::new(sigma);
        let init = ChannelState {
            shadow_main_db: vec![10.0; k],
            shadow_fed_db: vec![0.0; k],
        };
        let mut jump = ChannelProcess::new(model.clone(), init.clone(), rho, 31);
        jump.advance(gap);
        let mut step = ChannelProcess::new(model, init, rho, 32);
        for _ in 0..gap {
            step.step();
        }
        let stats = |xs: &[f64]| {
            let mean = xs.iter().sum::<f64>() / xs.len() as f64;
            let var =
                xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
            (mean, var)
        };
        let (mj, vj) = stats(&jump.state().shadow_main_db);
        let (ms, vs) = stats(&step.state().shadow_main_db);
        let want_mean = 10.0 * rho.powi(gap as i32);
        let want_var = sigma * sigma * (1.0 - rho.powi(2 * gap as i32));
        assert!((mj - want_mean).abs() < 0.2, "jump mean {mj} vs {want_mean}");
        assert!((ms - want_mean).abs() < 0.2, "step mean {ms} vs {want_mean}");
        assert!((vj - want_var).abs() < 2.0, "jump var {vj} vs {want_var}");
        assert!((vs - want_var).abs() < 2.0, "step var {vs} vs {want_var}");
    }

    #[test]
    fn stationary_moments_and_lag1_correlation() {
        // one client, many rounds: mean ~0, std ~sigma, lag-1 corr ~rho
        let sigma = 8.0;
        let rho = 0.8;
        let model = ChannelModel::new(sigma);
        let state = ChannelState::sample(1, &model, &mut Rng::new(2));
        let mut p = ChannelProcess::new(model, state, rho, 6);
        let n = 60_000;
        let mut xs = Vec::with_capacity(n);
        for _ in 0..n {
            p.step();
            xs.push(p.state().shadow_main_db[0]);
        }
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.3, "mean {mean}");
        assert!((var.sqrt() - sigma).abs() < 0.4, "std {}", var.sqrt());
        let mut num = 0.0;
        for w in xs.windows(2) {
            num += (w[0] - mean) * (w[1] - mean);
        }
        let corr = num / ((n - 1) as f64 * var);
        assert!((corr - rho).abs() < 0.05, "lag-1 corr {corr}");
    }
}
