//! Round-varying channel dynamics: per-client shadow fading evolved as
//! a seeded AR(1) Gauss–Markov process across global rounds.
//!
//! The static substrate draws shadowing once per scenario (the paper's
//! "average channel gain" reading of Eqs. 9/14). Multi-round runs over
//! mobile edge networks see the shadowing *drift* instead; the standard
//! model is the Gauss–Markov recursion
//!
//! `s_{e+1} = ρ·s_e + sqrt(1 − ρ²)·σ·w_e`,   `w_e ~ N(0, 1)`
//!
//! which keeps the stationary distribution at the scenario's N(0, σ²)
//! log-normal shadowing while correlating consecutive rounds by ρ.
//! `ρ = 1` (or `σ = 0`) freezes the state — the process then touches
//! neither the shadows nor its RNG, so a frozen trajectory reproduces
//! the static scenario bit for bit.
//!
//! [`ChannelState`] is the shadow vector itself (both uplinks); it can
//! be sampled fresh — exactly the draw order `ScenarioBuilder` uses —
//! or recovered from an already-built scenario's linear gains.
//! [`ChannelProcess`] owns a state plus the AR(1) parameters and a
//! seeded RNG stream, and is what [`crate::sim::RoundSimulator`] steps
//! once per simulated round.

use crate::net::channel::ChannelModel;
use crate::net::power::{db_to_linear, linear_to_db};
use crate::net::topology::Topology;
use crate::util::rng::Rng;

/// Per-client shadow fading (dB) on the main and federated uplinks.
#[derive(Clone, Debug)]
pub struct ChannelState {
    pub shadow_main_db: Vec<f64>,
    pub shadow_fed_db: Vec<f64>,
}

impl ChannelState {
    /// Draw an initial state: N(0, σ²) in dB per client per link, all
    /// main-link draws first and then all fed-link draws — the exact
    /// order (and therefore the exact values) `ScenarioBuilder::build`
    /// consumes from its gain stream, so a scenario and a process
    /// seeded alike start from identical shadowing. With `σ = 0` no
    /// randomness is consumed, matching [`ChannelModel::gain`].
    pub fn sample(k: usize, model: &ChannelModel, rng: &mut Rng) -> ChannelState {
        let draw_all = |rng: &mut Rng| -> Vec<f64> {
            (0..k)
                .map(|_| {
                    if model.shadowing_db > 0.0 {
                        rng.normal_ms(0.0, model.shadowing_db)
                    } else {
                        0.0
                    }
                })
                .collect()
        };
        let shadow_main_db = draw_all(rng);
        let shadow_fed_db = draw_all(rng);
        ChannelState {
            shadow_main_db,
            shadow_fed_db,
        }
    }

    /// Recover the state that reproduces the given *linear* gains under
    /// `model` — the inverse of [`ChannelState::gains`], up to a
    /// floating-point round trip (~1e-12 dB). This lets a dynamic
    /// process continue from a scenario that only stored its gains
    /// (including hand-built test scenarios whose gains were never
    /// derived from a distance at all).
    pub fn recover(
        topo: &Topology,
        model: &ChannelModel,
        main_gain: &[f64],
        fed_gain: &[f64],
    ) -> ChannelState {
        let shadow = |d: f64, g: f64| -linear_to_db(g) - model.path_loss_db(d);
        ChannelState {
            shadow_main_db: topo
                .clients
                .iter()
                .zip(main_gain)
                .map(|(c, &g)| shadow(c.d_main_m, g))
                .collect(),
            shadow_fed_db: topo
                .clients
                .iter()
                .zip(fed_gain)
                .map(|(c, &g)| shadow(c.d_fed_m, g))
                .collect(),
        }
    }

    pub fn k(&self) -> usize {
        self.shadow_main_db.len()
    }

    /// Linear gains (main, fed) for the current state — the same
    /// `db_to_linear(-(path_loss + shadow))` expression as
    /// [`ChannelModel::gain`], so equal shadows give bit-equal gains.
    pub fn gains(&self, topo: &Topology, model: &ChannelModel) -> (Vec<f64>, Vec<f64>) {
        let main = topo
            .clients
            .iter()
            .zip(&self.shadow_main_db)
            .map(|(c, &s)| db_to_linear(-(model.path_loss_db(c.d_main_m) + s)))
            .collect();
        let fed = topo
            .clients
            .iter()
            .zip(&self.shadow_fed_db)
            .map(|(c, &s)| db_to_linear(-(model.path_loss_db(c.d_fed_m) + s)))
            .collect();
        (main, fed)
    }
}

/// Seeded AR(1) evolution of a [`ChannelState`].
#[derive(Clone, Debug)]
pub struct ChannelProcess {
    model: ChannelModel,
    state: ChannelState,
    rho: f64,
    /// Innovation std `sqrt(1 − ρ²)·σ` (dB); 0 freezes the process.
    innovation_db: f64,
    rng: Rng,
}

impl ChannelProcess {
    /// `model.shadowing_db` is the stationary shadowing std σ; `rho`
    /// the round-to-round correlation in [0, 1].
    pub fn new(model: ChannelModel, state: ChannelState, rho: f64, seed: u64) -> ChannelProcess {
        assert!(
            (0.0..=1.0).contains(&rho),
            "AR(1) correlation must be in [0, 1], got {rho}"
        );
        let innovation_db = (1.0 - rho * rho).max(0.0).sqrt() * model.shadowing_db;
        ChannelProcess {
            model,
            state,
            rho,
            innovation_db,
            rng: Rng::new(seed),
        }
    }

    /// True when stepping can never change the state (`ρ = 1` or
    /// `σ = 0`): callers may then skip rewriting gains entirely and
    /// keep the static scenario's vectors bit-for-bit.
    pub fn is_frozen(&self) -> bool {
        self.innovation_db == 0.0
    }

    /// Advance one round: `s ← ρ·s + sqrt(1 − ρ²)·σ·w`. Frozen
    /// processes return immediately without consuming randomness.
    pub fn step(&mut self) {
        if self.is_frozen() {
            return;
        }
        for s in self
            .state
            .shadow_main_db
            .iter_mut()
            .chain(self.state.shadow_fed_db.iter_mut())
        {
            *s = self.rho * *s + self.rng.normal_ms(0.0, self.innovation_db);
        }
    }

    pub fn state(&self) -> &ChannelState {
        &self.state
    }

    /// Current linear gains (main, fed).
    pub fn gains(&self, topo: &Topology) -> (Vec<f64>, Vec<f64>) {
        self.state.gains(topo, &self.model)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::topology::ClientSite;

    fn topo2() -> Topology {
        Topology {
            clients: vec![
                ClientSite { d_main_m: 100.0, d_fed_m: 10.0, f_cycles: 1e9 },
                ClientSite { d_main_m: 150.0, d_fed_m: 18.0, f_cycles: 1.5e9 },
            ],
        }
    }

    #[test]
    fn sample_matches_the_builder_draw_order() {
        // drawing all main shadows first, then all fed shadows, must
        // consume the rng exactly like two sequential gain() passes
        let model = ChannelModel::new(8.0);
        let topo = topo2();
        let state = ChannelState::sample(2, &model, &mut Rng::new(77));
        let (main, fed) = state.gains(&topo, &model);
        let mut rng = Rng::new(77);
        let want_main: Vec<f64> =
            topo.clients.iter().map(|c| model.gain(c.d_main_m, &mut rng)).collect();
        let want_fed: Vec<f64> =
            topo.clients.iter().map(|c| model.gain(c.d_fed_m, &mut rng)).collect();
        assert_eq!(main, want_main);
        assert_eq!(fed, want_fed);
    }

    #[test]
    fn recover_round_trips_gains_to_high_precision() {
        let model = ChannelModel::new(8.0);
        let topo = topo2();
        let state = ChannelState::sample(2, &model, &mut Rng::new(5));
        let (main, fed) = state.gains(&topo, &model);
        let rec = ChannelState::recover(&topo, &model, &main, &fed);
        for (a, b) in state.shadow_main_db.iter().zip(&rec.shadow_main_db) {
            assert!((a - b).abs() < 1e-9, "{a} vs {b}");
        }
        for (a, b) in state.shadow_fed_db.iter().zip(&rec.shadow_fed_db) {
            assert!((a - b).abs() < 1e-9, "{a} vs {b}");
        }
    }

    #[test]
    fn frozen_process_never_moves_and_consumes_no_randomness() {
        let model = ChannelModel::new(8.0);
        let topo = topo2();
        let state = ChannelState::sample(2, &model, &mut Rng::new(9));
        let before = state.clone();
        let mut p = ChannelProcess::new(model.clone(), state, 1.0, 3);
        assert!(p.is_frozen());
        for _ in 0..10 {
            p.step();
        }
        assert_eq!(p.state().shadow_main_db, before.shadow_main_db);
        assert_eq!(p.state().shadow_fed_db, before.shadow_fed_db);
        let (g, _) = p.gains(&topo);
        let (g0, _) = before.gains(&topo, &model);
        assert_eq!(g, g0, "frozen gains must be bit-identical");
        // sigma = 0 freezes too, at any rho
        let m0 = ChannelModel::new(0.0);
        let s0 = ChannelState::sample(2, &m0, &mut Rng::new(1));
        assert!(ChannelProcess::new(m0, s0, 0.3, 4).is_frozen());
    }

    #[test]
    fn process_is_deterministic_per_seed() {
        let model = ChannelModel::new(8.0);
        let run = |seed| {
            let state = ChannelState::sample(2, &model, &mut Rng::new(11));
            let mut p = ChannelProcess::new(model.clone(), state, 0.7, seed);
            for _ in 0..25 {
                p.step();
            }
            p.state().shadow_main_db.clone()
        };
        assert_eq!(run(42), run(42));
        assert_ne!(run(42), run(43));
    }

    #[test]
    fn stationary_moments_and_lag1_correlation() {
        // one client, many rounds: mean ~0, std ~sigma, lag-1 corr ~rho
        let sigma = 8.0;
        let rho = 0.8;
        let model = ChannelModel::new(sigma);
        let state = ChannelState::sample(1, &model, &mut Rng::new(2));
        let mut p = ChannelProcess::new(model, state, rho, 6);
        let n = 60_000;
        let mut xs = Vec::with_capacity(n);
        for _ in 0..n {
            p.step();
            xs.push(p.state().shadow_main_db[0]);
        }
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.3, "mean {mean}");
        assert!((var.sqrt() - sigma).abs() < 0.4, "std {}", var.sqrt());
        let mut num = 0.0;
        for w in xs.windows(2) {
            num += (w[0] - mean) * (w[1] - mean);
        }
        let corr = num / ((n - 1) as f64 * var);
        assert!((corr - rho).abs() < 0.05, "lag-1 corr {corr}");
    }
}
