//! FDMA subchannels and Shannon uplink rates (paper Eqs. 9 and 14).
//!
//! A [`Link`] is one uplink direction (clients → main server or
//! clients → federated server): a set of orthogonal subchannels with
//! bandwidths `B_i`, an antenna-gain product, the noise PSD, and each
//! client's average channel gain γ(d_k). C1/C2 exclusivity means a
//! subchannel carries exactly one client, so a client's rate is the sum
//! over its assigned subchannels (Eq. 9):
//!
//! `R_k = Σ_i  B_i · log2(1 + p_i · G · γ_k / σ²)`
//!
//! with `p_i` the transmit PSD (W/Hz) on subchannel i.

/// Bandwidths of the orthogonal subchannels of one link.
#[derive(Clone, Debug)]
pub struct SubchannelSet {
    pub bandwidth_hz: Vec<f64>,
}

impl SubchannelSet {
    /// Paper setting: total bandwidth equally divided among `m` subchannels.
    pub fn equal_split(total_hz: f64, m: usize) -> SubchannelSet {
        assert!(m > 0);
        SubchannelSet {
            bandwidth_hz: vec![total_hz / m as f64; m],
        }
    }

    pub fn len(&self) -> usize {
        self.bandwidth_hz.len()
    }

    pub fn is_empty(&self) -> bool {
        self.bandwidth_hz.is_empty()
    }

    pub fn total_hz(&self) -> f64 {
        self.bandwidth_hz.iter().sum()
    }
}

/// One uplink (to the main or federated server).
#[derive(Clone, Debug)]
pub struct Link {
    pub subch: SubchannelSet,
    /// Antenna gain product G_c·G_s (or G_c·G_f).
    pub gain_product: f64,
    /// Noise PSD σ² (W/Hz).
    pub noise_psd: f64,
    /// Per-client average channel gain γ(d_k).
    pub client_gain: Vec<f64>,
}

impl Link {
    /// SNR per unit PSD for client k: G·γ_k/σ² (1/(W/Hz)).
    pub fn snr_coeff(&self, k: usize) -> f64 {
        self.gain_product * self.client_gain[k] / self.noise_psd
    }

    /// Rate (bit/s) of client k on subchannel i at transmit PSD `psd` (W/Hz).
    pub fn subch_rate(&self, k: usize, i: usize, psd: f64) -> f64 {
        let b = self.subch.bandwidth_hz[i];
        b * (1.0 + psd * self.snr_coeff(k)).log2()
    }

    /// Inverse Shannon: the PSD needed for client k to push `rate` bit/s
    /// through subchannel i. This is the auxiliary-variable substitution
    /// of Eq. 22 solved for p.
    pub fn psd_for_rate(&self, k: usize, i: usize, rate: f64) -> f64 {
        let b = self.subch.bandwidth_hz[i];
        ((rate / b).exp2() - 1.0) / self.snr_coeff(k)
    }

    /// Transmit *power* (W) corresponding to PSD `psd` on subchannel i.
    pub fn power_w(&self, i: usize, psd: f64) -> f64 {
        psd * self.subch.bandwidth_hz[i]
    }

    pub fn k(&self) -> usize {
        self.client_gain.len()
    }

    /// Fault-injection mask (PR-10): multiply the listed clients'
    /// gains by per-client factors (a subchannel outage; factor 0
    /// kills the uplink entirely, driving the rate to 0 on every
    /// subchannel). Out-of-range indices are ignored — fault overlays
    /// are sized to the per-round view, which can shrink.
    pub fn mask_client_gains(&mut self, masks: &[(usize, f64)]) {
        for &(k, factor) in masks {
            if let Some(g) = self.client_gain.get_mut(k) {
                *g *= factor;
            }
        }
    }

    /// Fault-injection mask (PR-10): attenuate *every* client's gain
    /// by `factor` — a server-side blackout on this uplink.
    pub fn attenuate_all_gains(&mut self, factor: f64) {
        for g in &mut self.client_gain {
            *g *= factor;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn link() -> Link {
        Link {
            subch: SubchannelSet::equal_split(500e3, 20),
            gain_product: 160.0,
            noise_psd: 3.98e-21,
            client_gain: vec![8.9e-10, 1.2e-9],
        }
    }

    #[test]
    fn equal_split_sums_to_total() {
        let s = SubchannelSet::equal_split(500e3, 20);
        assert_eq!(s.len(), 20);
        assert!((s.total_hz() - 500e3).abs() < 1e-6);
        assert!((s.bandwidth_hz[0] - 25e3).abs() < 1e-9);
    }

    #[test]
    fn rate_psd_round_trip() {
        let l = link();
        for &rate in &[1e3, 5e4, 2e5, 1e6] {
            let psd = l.psd_for_rate(0, 3, rate);
            let back = l.subch_rate(0, 3, psd);
            assert!((back - rate).abs() / rate < 1e-9, "{rate} -> {back}");
        }
    }

    #[test]
    fn rate_increases_with_psd_and_gain() {
        let l = link();
        assert!(l.subch_rate(0, 0, 1e-4) < l.subch_rate(0, 0, 2e-4));
        // client 1 has the better channel
        assert!(l.subch_rate(0, 0, 1e-4) < l.subch_rate(1, 0, 1e-4));
    }

    #[test]
    fn zero_psd_zero_rate() {
        let l = link();
        assert_eq!(l.subch_rate(0, 0, 0.0), 0.0);
        assert_eq!(l.psd_for_rate(0, 0, 0.0), 0.0);
    }

    #[test]
    fn gain_masks_attenuate_and_ignore_out_of_range() {
        let mut l = link();
        let g0 = l.client_gain.clone();
        l.mask_client_gains(&[(1, 0.0), (7, 0.5)]);
        assert_eq!(l.client_gain[0].to_bits(), g0[0].to_bits());
        assert_eq!(l.client_gain[1], 0.0);
        l.attenuate_all_gains(0.5);
        assert_eq!(l.client_gain[0].to_bits(), (g0[0] * 0.5).to_bits());
        assert_eq!(l.client_gain[1], 0.0);
    }

    #[test]
    fn typical_snr_magnitude() {
        // Table II numbers: PSD from 15 W over 4×25 kHz subchannels
        let l = link();
        let psd = 15.0 / (4.0 * 25e3);
        let se = (1.0 + psd * l.snr_coeff(0)).log2();
        // spectral efficiency lands in the tens of bit/s/Hz
        assert!(se > 20.0 && se < 50.0, "spectral efficiency {se}");
    }
}
