//! Client/server geometry (paper Sec. VII-A): K clients uniform in a
//! disk of radius `d_max` centred on the federated server; the main
//! server sits `d_main` from the centroid.

use crate::util::rng::Rng;

/// One client's placement and draw-dependent radio/compute attributes.
#[derive(Clone, Debug)]
pub struct ClientSite {
    /// Distance to the main server (m).
    pub d_main_m: f64,
    /// Distance to the federated server (m).
    pub d_fed_m: f64,
    /// Compute capability f_k (cycles/s).
    pub f_cycles: f64,
}

/// Scenario geometry.
#[derive(Clone, Debug)]
pub struct Topology {
    pub clients: Vec<ClientSite>,
}

impl Topology {
    /// Sample a scenario: uniform disk placement (radius `d_max_m`),
    /// main server at (`d_main_m`, 0), uniform f_k in [f_lo, f_hi].
    pub fn sample(
        k: usize,
        d_max_m: f64,
        d_main_m: f64,
        f_lo: f64,
        f_hi: f64,
        rng: &mut Rng,
    ) -> Topology {
        let mut clients = Vec::with_capacity(k);
        for _ in 0..k {
            // uniform over the disk: r = R*sqrt(u)
            let r = d_max_m * rng.f64().sqrt();
            let theta = rng.range(0.0, 2.0 * std::f64::consts::PI);
            let (x, y) = (r * theta.cos(), r * theta.sin());
            let d_fed = (x * x + y * y).sqrt().max(1.0); // fed server at origin
            let dx = x - d_main_m;
            let d_main = (dx * dx + y * y).sqrt().max(1.0);
            clients.push(ClientSite {
                d_main_m: d_main,
                d_fed_m: d_fed,
                f_cycles: rng.range(f_lo, f_hi),
            });
        }
        Topology { clients }
    }

    pub fn k(&self) -> usize {
        self.clients.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn placement_within_bounds() {
        let mut rng = Rng::new(1);
        let t = Topology::sample(200, 20.0, 100.0, 1.0e9, 1.6e9, &mut rng);
        for c in &t.clients {
            assert!(c.d_fed_m <= 20.0 + 1e-9);
            // main server 100 m away: distance within [80, 120]
            assert!(c.d_main_m >= 79.0 && c.d_main_m <= 121.0);
            assert!(c.f_cycles >= 1.0e9 && c.f_cycles <= 1.6e9);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = Topology::sample(5, 20.0, 100.0, 1e9, 1.6e9, &mut Rng::new(3));
        let b = Topology::sample(5, 20.0, 100.0, 1e9, 1.6e9, &mut Rng::new(3));
        for (x, y) in a.clients.iter().zip(&b.clients) {
            assert_eq!(x.d_main_m, y.d_main_m);
            assert_eq!(x.f_cycles, y.f_cycles);
        }
    }

    #[test]
    fn disk_sampling_is_area_uniform() {
        // fraction of clients within r < R/2 should be ~1/4
        let mut rng = Rng::new(9);
        let t = Topology::sample(20_000, 20.0, 100.0, 1e9, 1.6e9, &mut rng);
        let inner = t.clients.iter().filter(|c| c.d_fed_m < 10.0).count();
        let frac = inner as f64 / t.k() as f64;
        assert!((frac - 0.25).abs() < 0.02, "frac {frac}");
    }
}
