//! Average channel gain model: 3GPP-style path loss plus log-normal
//! shadow fading (paper Sec. VII-A: `128.1 + 37.6 log10(d_km)`, 8 dB
//! shadowing standard deviation).
//!
//! The paper's delay model uses the *average* gain γ(d) per client-
//! server pair — fading is drawn once per scenario (seeded), matching
//! the "average channel gain" in Eqs. 9/14 rather than a per-slot
//! fast-fading process.

use crate::net::power::db_to_linear;
use crate::util::rng::Rng;

/// Path-loss/shadowing channel model.
#[derive(Clone, Debug)]
pub struct ChannelModel {
    /// Shadow-fading standard deviation in dB (0 disables).
    pub shadowing_db: f64,
}

impl ChannelModel {
    pub fn new(shadowing_db: f64) -> ChannelModel {
        ChannelModel { shadowing_db }
    }

    /// Path loss in dB at distance `d_m` meters. Distances below the
    /// 1 m reference are clamped to it: the log-distance model is only
    /// calibrated in the far field, and letting it run to near-zero
    /// distances produces *negative* path loss (linear gain > 1, and
    /// with it absurd Shannon rates).
    pub fn path_loss_db(&self, d_m: f64) -> f64 {
        let d_km = d_m.max(1.0) / 1000.0;
        128.1 + 37.6 * d_km.log10()
    }

    /// Average linear channel gain γ(d) with a seeded shadowing draw.
    pub fn gain(&self, d_m: f64, rng: &mut Rng) -> f64 {
        let shadow = if self.shadowing_db > 0.0 {
            rng.normal_ms(0.0, self.shadowing_db)
        } else {
            0.0
        };
        db_to_linear(-(self.path_loss_db(d_m) + shadow))
    }

    /// Gain without shadowing (deterministic lower-level tests).
    pub fn gain_deterministic(&self, d_m: f64) -> f64 {
        db_to_linear(-self.path_loss_db(d_m))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn path_loss_reference_points() {
        let m = ChannelModel::new(0.0);
        // at 1 km the model gives exactly 128.1 dB
        assert!((m.path_loss_db(1000.0) - 128.1).abs() < 1e-9);
        // at 100 m: 128.1 - 37.6 = 90.5 dB
        assert!((m.path_loss_db(100.0) - 90.5).abs() < 1e-9);
    }

    #[test]
    fn gain_monotone_decreasing_in_distance() {
        let m = ChannelModel::new(0.0);
        let mut prev = f64::INFINITY;
        for d in [5.0, 20.0, 100.0, 500.0] {
            let g = m.gain_deterministic(d);
            assert!(g < prev);
            prev = g;
        }
    }

    #[test]
    fn shadowing_is_seeded_and_zero_mean_in_db() {
        let m = ChannelModel::new(8.0);
        let g1 = m.gain(100.0, &mut Rng::new(1));
        let g2 = m.gain(100.0, &mut Rng::new(1));
        assert_eq!(g1, g2, "same seed, same draw");
        // sample mean of shadowing in dB ~ 0
        let mut rng = Rng::new(2);
        let base = m.path_loss_db(100.0);
        let n = 20_000;
        let mean_db: f64 = (0..n)
            .map(|_| -10.0 * m.gain(100.0, &mut rng).log10() - base)
            .sum::<f64>()
            / n as f64;
        assert!(mean_db.abs() < 0.2, "mean shadow {mean_db} dB");
    }

    #[test]
    fn near_field_clamps_to_one_meter_reference() {
        let m = ChannelModel::new(0.0);
        // everything at or below 1 m sees the 1 m loss
        let pl_1m = m.path_loss_db(1.0);
        assert!((pl_1m - (128.1 - 3.0 * 37.6)).abs() < 1e-9);
        assert_eq!(m.path_loss_db(0.0), pl_1m);
        assert_eq!(m.path_loss_db(1e-3), pl_1m);
        assert_eq!(m.path_loss_db(0.999), pl_1m);
    }

    #[test]
    fn deterministic_gain_never_exceeds_unity() {
        // the 1 mm clamp used to give d=1e-3 m a path loss of
        // 128.1 - 6*37.6 = -97.5 dB, i.e. linear gain ~5.6e9
        let m = ChannelModel::new(0.0);
        for d in [0.0, 1e-6, 1e-3, 0.1, 0.5, 1.0, 2.0, 10.0, 1e3, 1e6] {
            let g = m.gain_deterministic(d);
            assert!(g > 0.0 && g <= 1.0, "d={d}: gain {g}");
            assert!(m.path_loss_db(d) > 0.0, "d={d}: negative path loss");
        }
    }

    #[test]
    fn gain_at_100m_matches_hand_calc() {
        let m = ChannelModel::new(0.0);
        // PL = 90.5 dB -> gain = 10^-9.05 ≈ 8.91e-10
        assert!((m.gain_deterministic(100.0) - 8.91e-10).abs() < 0.02e-10);
    }
}
