//! Wireless-network substrate (paper Sections III / V, Table II).
//!
//! Deterministic simulator of everything the paper's testbed provides
//! the optimizer: client geometry, average channel gains with path loss
//! and log-normal shadowing, FDMA subchannels, Shannon uplink rates
//! (Eqs. 9 and 14), and the seeded AR(1) shadowing process that
//! [`crate::sim::RoundSimulator`] evolves round by round.

pub mod channel;
pub mod fdma;
pub mod power;
pub mod process;
pub mod topology;

pub use channel::ChannelModel;
pub use fdma::{Link, SubchannelSet};
pub use process::{ar1_jump, ChannelProcess, ChannelState};
pub use topology::Topology;
