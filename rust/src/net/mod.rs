//! Wireless-network substrate (paper Sections III / V, Table II).
//!
//! Deterministic simulator of everything the paper's testbed provides
//! the optimizer: client geometry, average channel gains with path loss
//! and log-normal shadowing, FDMA subchannels, and Shannon uplink rates
//! (Eqs. 9 and 14).

pub mod channel;
pub mod fdma;
pub mod power;
pub mod topology;

pub use channel::ChannelModel;
pub use fdma::{Link, SubchannelSet};
pub use topology::Topology;
