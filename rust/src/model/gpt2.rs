//! GPT-2 architecture variants.
//!
//! `gpt2-s` / `gpt2-m` mirror the published checkpoints and drive the
//! analytic workload model for every latency experiment; `tiny` /
//! `micro` are the CPU-trainable variants actually executed through the
//! AOT artifacts (DESIGN.md §2 records this substitution).

use anyhow::{bail, Result};

/// Architecture hyper-parameters for one GPT-2 variant.
#[derive(Clone, Debug, PartialEq)]
pub struct Gpt2Config {
    pub name: &'static str,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    /// Max sequence length (positions).
    pub n_ctx: usize,
}

impl Gpt2Config {
    pub const fn d_ff(&self) -> usize {
        4 * self.d_model
    }

    pub const fn d_head(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// GPT2-S: 12 layers, d=768 (~124M parameters).
    pub const fn gpt2_s() -> Gpt2Config {
        Gpt2Config {
            name: "gpt2-s",
            vocab: 50257,
            d_model: 768,
            n_layers: 12,
            n_heads: 12,
            n_ctx: 1024,
        }
    }

    /// GPT2-M: 24 layers, d=1024 (~355M parameters).
    pub const fn gpt2_m() -> Gpt2Config {
        Gpt2Config {
            name: "gpt2-m",
            vocab: 50257,
            d_model: 1024,
            n_layers: 24,
            n_heads: 16,
            n_ctx: 1024,
        }
    }

    /// The CPU-trainable end-to-end variant (matches python/compile/model.py TINY).
    pub const fn tiny() -> Gpt2Config {
        Gpt2Config {
            name: "tiny",
            vocab: 256,
            d_model: 192,
            n_layers: 6,
            n_heads: 6,
            n_ctx: 64,
        }
    }

    /// Integration-test variant (matches python MICRO).
    pub const fn micro() -> Gpt2Config {
        Gpt2Config {
            name: "micro",
            vocab: 64,
            d_model: 32,
            n_layers: 2,
            n_heads: 2,
            n_ctx: 8,
        }
    }

    pub fn by_name(name: &str) -> Result<Gpt2Config> {
        Ok(match name {
            "gpt2-s" => Self::gpt2_s(),
            "gpt2-m" => Self::gpt2_m(),
            "tiny" => Self::tiny(),
            "micro" => Self::micro(),
            _ => bail!("unknown model variant '{name}'"),
        })
    }

    // ---- parameter counts (paper Table III column 2) -------------------

    /// Token embedding parameters.
    pub fn params_token_embedding(&self) -> usize {
        self.vocab * self.d_model
    }

    /// Positional encoding parameters.
    pub fn params_position_encoding(&self) -> usize {
        self.n_ctx * self.d_model
    }

    /// One LayerNorm (gain + bias).
    pub fn params_layernorm(&self) -> usize {
        2 * self.d_model
    }

    /// Multi-head attention block: 4 projections + biases.
    pub fn params_attention(&self) -> usize {
        4 * self.d_model * self.d_model + 4 * self.d_model
    }

    /// Feed-forward block: two projections + biases.
    pub fn params_ffn(&self) -> usize {
        2 * self.d_model * self.d_ff() + self.d_ff() + self.d_model
    }

    /// LoRA adapter parameters per rank for ONE projection: r*(d+k) with
    /// d=k=d_model (paper Sec. V-A).
    pub fn params_lora_per_rank_per_proj(&self) -> usize {
        2 * self.d_model
    }

    /// LoRA parameters per rank per block (adapters on q and v).
    pub fn params_lora_per_rank_block(&self) -> usize {
        2 * self.params_lora_per_rank_per_proj()
    }

    /// Total parameters (tied LM head, as in GPT-2).
    pub fn params_total(&self) -> usize {
        self.params_token_embedding()
            + self.params_position_encoding()
            + self.n_layers * (2 * self.params_layernorm() + self.params_attention() + self.params_ffn())
            + self.params_layernorm()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gpt2_s_param_counts_match_table_iii() {
        let c = Gpt2Config::gpt2_s();
        // Table III: token embedding 38.6M, position encoding 0.786M,
        // LayerNorm 1.5K, MHA 2.36M, FFN 4.72M, LoRA 1.5K/rank.
        assert_eq!(c.params_token_embedding(), 50257 * 768); // 38.6M
        assert!((c.params_token_embedding() as f64 / 1e6 - 38.6).abs() < 0.1);
        assert_eq!(c.params_position_encoding(), 1024 * 768);
        assert!((c.params_position_encoding() as f64 / 1e6 - 0.786).abs() < 0.01);
        assert_eq!(c.params_layernorm(), 1536); // 1.5K
        assert!((c.params_attention() as f64 / 1e6 - 2.36).abs() < 0.01);
        assert!((c.params_ffn() as f64 / 1e6 - 4.72).abs() < 0.01);
        assert_eq!(c.params_lora_per_rank_per_proj(), 1536); // 1.5K
    }

    #[test]
    fn gpt2_s_total_is_about_124m() {
        let c = Gpt2Config::gpt2_s();
        let total = c.params_total() as f64 / 1e6;
        assert!((total - 124.0).abs() < 2.0, "total {total}M");
    }

    #[test]
    fn variants_resolve_by_name() {
        for n in ["gpt2-s", "gpt2-m", "tiny", "micro"] {
            assert_eq!(Gpt2Config::by_name(n).unwrap().name, n);
        }
        assert!(Gpt2Config::by_name("nope").is_err());
    }

    #[test]
    fn head_divides_model_dim() {
        for c in [
            Gpt2Config::gpt2_s(),
            Gpt2Config::gpt2_m(),
            Gpt2Config::tiny(),
            Gpt2Config::micro(),
        ] {
            assert_eq!(c.d_head() * c.n_heads, c.d_model);
        }
    }
}
