//! Model layer: GPT-2 architecture descriptions, the per-layer
//! FLOPs/bytes workload model the delay analysis consumes (paper
//! Table III / Section V-A), and host-side LoRA adapter state.

pub mod flops;
pub mod gpt2;
pub mod lora;

pub use flops::{LayerWorkload, WorkloadProfile, WorkloadTable};
pub use gpt2::Gpt2Config;
pub use lora::AdapterSet;
