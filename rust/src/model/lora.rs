//! Host-side LoRA adapter state: the trainable tensors the coordinator
//! moves between clients, the main server and the federated server.
//!
//! The wire/file format is the artifact convention: named, ordered f32
//! tensors (see `python/compile/aot.py::write_tensor_file`). FedAvg
//! (paper Eq. 7) and the SGD updates (Eqs. 5–6) both happen here, on
//! host buffers — the device only ever sees adapter *values*.

use anyhow::{bail, Result};

use crate::util::stats::{fsum, usum};

/// One named tensor.
#[derive(Clone, Debug)]
pub struct Tensor {
    pub name: String,
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(name: &str, shape: &[usize]) -> Tensor {
        Tensor {
            name: name.to_string(),
            shape: shape.to_vec(),
            data: vec![0.0; shape.iter().product()],
        }
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// An ordered set of adapter tensors (client-side or server-side).
#[derive(Clone, Debug, Default)]
pub struct AdapterSet {
    pub tensors: Vec<Tensor>,
}

impl AdapterSet {
    /// Total trainable parameter count.
    pub fn numel(&self) -> usize {
        usum(self.tensors.iter().map(Tensor::numel))
    }

    /// Upload volume in bits (the Delta Theta_c the delay model charges).
    pub fn bits(&self) -> f64 {
        (self.numel() * 32) as f64
    }

    /// SGD step: `p <- p - lr * g` (paper Eqs. 5–6). Gradients must be
    /// in the same tensor order as the parameters.
    pub fn sgd_step(&mut self, grads: &AdapterSet, lr: f32) -> Result<()> {
        if grads.tensors.len() != self.tensors.len() {
            bail!(
                "gradient set size {} != parameter set size {}",
                grads.tensors.len(),
                self.tensors.len()
            );
        }
        for (p, g) in self.tensors.iter_mut().zip(&grads.tensors) {
            if p.data.len() != g.data.len() {
                bail!("shape mismatch on '{}'", p.name);
            }
            for (pv, gv) in p.data.iter_mut().zip(&g.data) {
                *pv -= lr * gv;
            }
        }
        Ok(())
    }

    /// FedAvg (paper Eq. 7): weighted average of client adapter sets,
    /// weights proportional to local dataset sizes D_k.
    pub fn fedavg(sets: &[&AdapterSet], weights: &[f64]) -> Result<AdapterSet> {
        if sets.is_empty() || sets.len() != weights.len() {
            bail!("fedavg needs matching non-empty sets/weights");
        }
        let total: f64 = fsum(weights.iter().copied());
        if total <= 0.0 {
            bail!("fedavg weights must sum to a positive value");
        }
        let mut out = sets[0].clone();
        for t in &mut out.tensors {
            t.data.iter_mut().for_each(|v| *v = 0.0);
        }
        for (set, &w) in sets.iter().zip(weights) {
            if set.tensors.len() != out.tensors.len() {
                bail!("fedavg: tensor count mismatch");
            }
            let coef = (w / total) as f32;
            for (acc, src) in out.tensors.iter_mut().zip(&set.tensors) {
                if acc.data.len() != src.data.len() {
                    bail!("fedavg: shape mismatch on '{}'", acc.name);
                }
                for (a, s) in acc.data.iter_mut().zip(&src.data) {
                    *a += coef * s;
                }
            }
        }
        Ok(out)
    }

    /// L2 norm over all tensors (metrics / convergence diagnostics).
    pub fn l2_norm(&self) -> f64 {
        self.tensors
            .iter()
            .flat_map(|t| t.data.iter())
            .map(|&v| (v as f64) * (v as f64))
            .sum::<f64>()
            .sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn set(vals: &[f32]) -> AdapterSet {
        AdapterSet {
            tensors: vec![Tensor {
                name: "a".into(),
                shape: vec![vals.len()],
                data: vals.to_vec(),
            }],
        }
    }

    #[test]
    fn sgd_step_moves_against_gradient() {
        let mut p = set(&[1.0, 2.0]);
        let g = set(&[0.5, -1.0]);
        p.sgd_step(&g, 0.1).unwrap();
        assert_eq!(p.tensors[0].data, vec![0.95, 2.1]);
    }

    #[test]
    fn fedavg_weighted_mean() {
        let a = set(&[1.0, 0.0]);
        let b = set(&[0.0, 1.0]);
        // weights 3:1 -> [0.75, 0.25]
        let avg = AdapterSet::fedavg(&[&a, &b], &[3.0, 1.0]).unwrap();
        assert_eq!(avg.tensors[0].data, vec![0.75, 0.25]);
    }

    #[test]
    fn fedavg_identity_for_single_client() {
        let a = set(&[1.5, -2.5]);
        let avg = AdapterSet::fedavg(&[&a], &[7.0]).unwrap();
        assert_eq!(avg.tensors[0].data, a.tensors[0].data);
    }

    #[test]
    fn fedavg_preserves_consensus() {
        // all clients equal -> average equals them (any weights)
        let a = set(&[0.25, 0.5]);
        let avg = AdapterSet::fedavg(&[&a, &a, &a], &[1.0, 5.0, 2.0]).unwrap();
        assert_eq!(avg.tensors[0].data, a.tensors[0].data);
    }

    #[test]
    fn mismatch_errors() {
        let mut p = set(&[1.0]);
        let g = set(&[1.0, 2.0]);
        assert!(p.sgd_step(&g, 0.1).is_err());
        assert!(AdapterSet::fedavg(&[], &[]).is_err());
        assert!(AdapterSet::fedavg(&[&p], &[0.0]).is_err());
    }

    #[test]
    fn bits_counts_f32() {
        let p = set(&[0.0; 10]);
        assert_eq!(p.bits(), 320.0);
        assert_eq!(p.numel(), 10);
    }
}
