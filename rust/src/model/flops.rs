//! Per-layer workload model (paper Section V-A and Table III).
//!
//! The delay model consumes, for every transformer block j:
//!
//! * `rho_j` — forward FLOPs of the frozen weights per sample,
//! * `varpi_j = 2 * rho_j` — backward FLOPs (the paper assumes the
//!   backward pass costs twice the forward),
//! * `delta_rho_j` / `delta_varpi_j` — extra FLOPs per LoRA **rank**,
//! * `psi_j` — activation bits at the block output (the split-layer
//!   upload if the model is cut after block j),
//! * `delta_xi_j` — trainable-parameter bits per rank (the federated
//!   upload).
//!
//! The LM head and final LayerNorm always live on the main server and
//! enter the server terms as constants; embedding/positional lookup is
//! neglected, as in the paper ("the embedding and positional encoding
//! are neglected due to their minimal complexity").
//!
//! FLOP counts are first-principles (2 FLOPs per MAC). Parameter counts
//! reproduce Table III exactly (see `gpt2.rs` tests); the paper's FLOP
//! column does not follow from any single per-sample/per-batch
//! convention we could identify, so the benches print both our analytic
//! numbers and the paper's, and EXPERIMENTS.md compares the *shape*
//! (FFN > MHA >> LoRA/LayerNorm; LM head dominates).

use super::gpt2::Gpt2Config;
use crate::util::stats::fsum;

const BITS_PER_PARAM: f64 = 32.0; // f32 everywhere in this repro

/// Workload of one transformer block for one sample of `seq` tokens.
#[derive(Clone, Debug)]
pub struct LayerWorkload {
    /// rho_j: forward FLOPs, frozen weights.
    pub fwd_flops: f64,
    /// varpi_j: backward FLOPs, frozen weights.
    pub bwd_flops: f64,
    /// delta_rho_j: extra forward FLOPs per LoRA rank.
    pub lora_fwd_flops_per_rank: f64,
    /// delta_varpi_j: extra backward FLOPs per LoRA rank.
    pub lora_bwd_flops_per_rank: f64,
    /// psi_j: activation bits at the block output (per sample).
    pub act_bits: f64,
    /// delta_xi_j: trainable adapter bits per rank.
    pub adapter_bits_per_rank: f64,
}

/// Full-model workload profile at a fixed sequence length.
#[derive(Clone, Debug)]
pub struct WorkloadProfile {
    pub cfg: Gpt2Config,
    pub seq: usize,
    pub blocks: Vec<LayerWorkload>,
    /// LM head + final LayerNorm forward FLOPs (server-side constant).
    pub head_fwd_flops: f64,
    pub head_bwd_flops: f64,
    /// Per-sample label upload bits (tokens ride along with activations).
    pub label_bits: f64,
}

impl WorkloadProfile {
    pub fn new(cfg: Gpt2Config, seq: usize) -> WorkloadProfile {
        let t = seq as f64;
        let d = cfg.d_model as f64;
        let f = cfg.d_ff() as f64;
        let h = cfg.n_heads as f64;
        let v = cfg.vocab as f64;

        // Forward FLOPs per sample per block (2 FLOPs per MAC):
        let proj = 4.0 * 2.0 * t * d * d; // q,k,v,o projections
        let attn = 2.0 * 2.0 * t * t * d + 5.0 * h * t * t; // QK^T, AV, softmax
        let mlp = 2.0 * 2.0 * t * d * f + 8.0 * t * f; // two matmuls + gelu
        let ln = 2.0 * 8.0 * t * d; // two LayerNorms
        let fwd = proj + attn + mlp + ln;

        // LoRA on q and v: per rank, each projection adds x@A (2*T*d)
        // and (xA)@B (2*T*d) FLOPs.
        let lora_fwd = 2.0 * (2.0 * t * d + 2.0 * t * d);

        let block = LayerWorkload {
            fwd_flops: fwd,
            bwd_flops: 2.0 * fwd,
            lora_fwd_flops_per_rank: lora_fwd,
            lora_bwd_flops_per_rank: 2.0 * lora_fwd,
            act_bits: t * d * BITS_PER_PARAM,
            adapter_bits_per_rank: 4.0 * d * BITS_PER_PARAM, // q+v, A+B
        };

        let head_fwd = 2.0 * t * d * v + 8.0 * t * d; // logits + final LN
        WorkloadProfile {
            blocks: vec![block; cfg.n_layers],
            head_fwd_flops: head_fwd,
            head_bwd_flops: 2.0 * head_fwd,
            label_bits: t * 32.0,
            cfg,
            seq,
        }
    }

    fn lc_clamped(&self, l_c: usize) -> usize {
        l_c.min(self.blocks.len())
    }

    /// Phi_c^F + Delta Phi_c^F: client forward FLOPs per sample.
    pub fn client_fwd_flops(&self, l_c: usize, rank: usize) -> f64 {
        fsum(
            self.blocks[..self.lc_clamped(l_c)]
                .iter()
                .map(|b| b.fwd_flops + rank as f64 * b.lora_fwd_flops_per_rank),
        )
    }

    /// Phi_c^B + Delta Phi_c^B: client backward FLOPs per sample.
    pub fn client_bwd_flops(&self, l_c: usize, rank: usize) -> f64 {
        fsum(
            self.blocks[..self.lc_clamped(l_c)]
                .iter()
                .map(|b| b.bwd_flops + rank as f64 * b.lora_bwd_flops_per_rank),
        )
    }

    /// Phi_s^F + Delta Phi_s^F: server forward FLOPs per sample
    /// (remaining blocks + LM head/final LN).
    pub fn server_fwd_flops(&self, l_c: usize, rank: usize) -> f64 {
        fsum(
            self.blocks[self.lc_clamped(l_c)..]
                .iter()
                .map(|b| b.fwd_flops + rank as f64 * b.lora_fwd_flops_per_rank),
        ) + self.head_fwd_flops
    }

    /// Phi_s^B + Delta Phi_s^B: server backward FLOPs per sample.
    pub fn server_bwd_flops(&self, l_c: usize, rank: usize) -> f64 {
        fsum(
            self.blocks[self.lc_clamped(l_c)..]
                .iter()
                .map(|b| b.bwd_flops + rank as f64 * b.lora_bwd_flops_per_rank),
        ) + self.head_bwd_flops
    }

    /// Gamma_s: split-layer upload bits per sample (activations + labels).
    /// Independent of rank — the LoRA delta is summed into the same
    /// activation tensor (Sec. V-A.2).
    pub fn activation_bits(&self, l_c: usize) -> f64 {
        let l_c = self.lc_clamped(l_c);
        if l_c == 0 {
            // split before the first block: the embedding output goes up.
            // lint:allow(P101) blocks holds one entry per transformer layer and every Gpt2Config preset has n_layers >= 1
            self.blocks[0].act_bits + self.label_bits
        } else {
            self.blocks[l_c - 1].act_bits + self.label_bits
        }
    }

    /// Delta Theta_c: client adapter upload bits for the federated server.
    pub fn client_adapter_bits(&self, l_c: usize, rank: usize) -> f64 {
        fsum(
            self.blocks[..self.lc_clamped(l_c)]
                .iter()
                .map(|b| rank as f64 * b.adapter_bits_per_rank),
        )
    }

    /// Number of candidate split points (after block 1 .. after block L-1;
    /// the paper keeps at least one block on each side).
    pub fn split_candidates(&self) -> std::ops::Range<usize> {
        1..self.blocks.len()
    }
}

/// Precomputed per-(l_c, rank) workload sums over a candidate rank set.
///
/// The prefix sums behind [`WorkloadProfile::client_fwd_flops`] & co. are
/// re-walked on every delay evaluation; the P3/P4 joint scan evaluates
/// the whole split×rank grid every BCD iteration, so
/// [`crate::delay::eval::DelayEvaluator`] tabulates them once per
/// (profile, rank set) and reads them back as O(1) lookups. Every entry
/// is produced by the corresponding `WorkloadProfile` method, so lookups
/// are bit-identical to the uncached path (asserted by the property
/// tests in `rust/tests/prop_eval.rs`).
#[derive(Clone, Debug)]
pub struct WorkloadTable {
    ranks: Vec<usize>,
    /// Number of blocks L; tables are indexed by l_c in 0..=L.
    l_max: usize,
    /// Row-major (l_c, rank-index) tables, (L+1) × ranks.len().
    client_fwd: Vec<f64>,
    client_bwd: Vec<f64>,
    server_fwd: Vec<f64>,
    server_bwd: Vec<f64>,
    adapter_bits: Vec<f64>,
    /// Energy-side sum `client_fwd + client_bwd` — the per-sample FLOPs
    /// the compute-energy model `ζ·f²·κ·b·Φ` bills a client for, stored
    /// pre-added so `DelayEvaluator::eval_energy` replicates
    /// `delay::energy::round_energy`'s `(fwd + bwd)` bit for bit.
    client_energy: Vec<f64>,
    /// Per-l_c activation upload bits (rank-independent), L+1 entries.
    act_bits: Vec<f64>,
}

impl WorkloadTable {
    pub fn new(profile: &WorkloadProfile, ranks: &[usize]) -> WorkloadTable {
        assert!(!ranks.is_empty(), "empty candidate rank set");
        let l_max = profile.blocks.len();
        let cells = (l_max + 1) * ranks.len();
        let mut t = WorkloadTable {
            ranks: ranks.to_vec(),
            l_max,
            client_fwd: Vec::with_capacity(cells),
            client_bwd: Vec::with_capacity(cells),
            server_fwd: Vec::with_capacity(cells),
            server_bwd: Vec::with_capacity(cells),
            adapter_bits: Vec::with_capacity(cells),
            client_energy: Vec::with_capacity(cells),
            act_bits: (0..=l_max).map(|l| profile.activation_bits(l)).collect(),
        };
        for l_c in 0..=l_max {
            for &r in ranks {
                t.client_fwd.push(profile.client_fwd_flops(l_c, r));
                t.client_bwd.push(profile.client_bwd_flops(l_c, r));
                t.server_fwd.push(profile.server_fwd_flops(l_c, r));
                t.server_bwd.push(profile.server_bwd_flops(l_c, r));
                t.adapter_bits.push(profile.client_adapter_bits(l_c, r));
                t.client_energy
                    .push(profile.client_fwd_flops(l_c, r) + profile.client_bwd_flops(l_c, r));
            }
        }
        t
    }

    /// The candidate rank set, in construction order (the joint scan's
    /// tie-break order).
    pub fn ranks(&self) -> &[usize] {
        &self.ranks
    }

    /// Position of `rank` in the candidate set, if present.
    pub fn rank_index(&self, rank: usize) -> Option<usize> {
        self.ranks.iter().position(|&r| r == rank)
    }

    fn idx(&self, l_c: usize, ri: usize) -> usize {
        debug_assert!(ri < self.ranks.len());
        l_c.min(self.l_max) * self.ranks.len() + ri
    }

    pub fn client_fwd_flops(&self, l_c: usize, ri: usize) -> f64 {
        self.client_fwd[self.idx(l_c, ri)]
    }

    pub fn client_bwd_flops(&self, l_c: usize, ri: usize) -> f64 {
        self.client_bwd[self.idx(l_c, ri)]
    }

    pub fn server_fwd_flops(&self, l_c: usize, ri: usize) -> f64 {
        self.server_fwd[self.idx(l_c, ri)]
    }

    pub fn server_bwd_flops(&self, l_c: usize, ri: usize) -> f64 {
        self.server_bwd[self.idx(l_c, ri)]
    }

    pub fn adapter_bits(&self, l_c: usize, ri: usize) -> f64 {
        self.adapter_bits[self.idx(l_c, ri)]
    }

    /// `client_fwd_flops + client_bwd_flops` — the energy model's
    /// per-sample client FLOPs, pre-added at table build.
    pub fn client_energy_flops(&self, l_c: usize, ri: usize) -> f64 {
        self.client_energy[self.idx(l_c, ri)]
    }

    pub fn activation_bits(&self, l_c: usize) -> f64 {
        self.act_bits[l_c.min(self.l_max)]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn profile() -> WorkloadProfile {
        WorkloadProfile::new(Gpt2Config::gpt2_s(), 512)
    }

    #[test]
    fn split_partitions_total_work() {
        let p = profile();
        let total_f = p.client_fwd_flops(12, 4) + p.server_fwd_flops(12, 4) - p.head_fwd_flops;
        for l_c in 0..=12 {
            let s = p.client_fwd_flops(l_c, 4) + p.server_fwd_flops(l_c, 4) - p.head_fwd_flops;
            assert!((s - total_f).abs() < 1.0, "l_c={l_c}");
        }
    }

    #[test]
    fn bwd_is_twice_fwd() {
        let p = profile();
        for l_c in [1, 6, 11] {
            assert!(
                (p.client_bwd_flops(l_c, 4) - 2.0 * p.client_fwd_flops(l_c, 4)).abs() < 1.0
            );
        }
    }

    #[test]
    fn lora_flops_scale_linearly_with_rank() {
        let p = profile();
        let base = p.client_fwd_flops(6, 0);
        let d1 = p.client_fwd_flops(6, 1) - base;
        let d8 = p.client_fwd_flops(6, 8) - base;
        assert!((d8 - 8.0 * d1).abs() < 1.0);
        assert!(d1 > 0.0);
    }

    #[test]
    fn activation_bits_constant_across_blocks_for_uniform_model() {
        let p = profile();
        // uniform d across blocks -> psi identical for every split point
        assert_eq!(p.activation_bits(1), p.activation_bits(6));
        // per sample: 512 tokens * 768 dims * 32 bits + labels
        let expect = 512.0 * 768.0 * 32.0 + 512.0 * 32.0;
        assert!((p.activation_bits(3) - expect).abs() < 1.0);
    }

    #[test]
    fn adapter_bits_match_param_count() {
        let p = profile();
        let cfg = Gpt2Config::gpt2_s();
        // l_c=6, rank=4: 6 blocks * 4 ranks * (q+v)(A+B) params * 32 bits
        let params = 6 * 4 * cfg.params_lora_per_rank_block();
        assert!((p.client_adapter_bits(6, 4) - params as f64 * 32.0).abs() < 1.0);
    }

    #[test]
    fn head_dominates_single_block_fwd() {
        // Table III shape: LM head FLOPs far exceed one block's.
        let p = profile();
        assert!(p.head_fwd_flops > p.blocks[0].fwd_flops);
    }

    #[test]
    fn ffn_exceeds_attention_flops() {
        // Table III shape: FFN 309.2 > MHA 257.7 (ratio ~1.2); ours: 16Td^2
        // vs 8Td^2+4T^2d, which for T=512, d=768 is also > 1.
        let t = 512.0;
        let d = 768.0;
        let mha = 8.0 * t * d * d + 4.0 * t * t * d;
        let ffn = 16.0 * t * d * d;
        assert!(ffn > mha);
    }

    #[test]
    fn zero_rank_means_zero_adapter_upload() {
        let p = profile();
        assert_eq!(p.client_adapter_bits(6, 0), 0.0);
    }

    #[test]
    fn workload_table_matches_profile_bit_for_bit() {
        let p = profile();
        let ranks = [1usize, 2, 4, 6, 8];
        let t = WorkloadTable::new(&p, &ranks);
        for l_c in 0..=p.blocks.len() {
            assert_eq!(t.activation_bits(l_c).to_bits(), p.activation_bits(l_c).to_bits());
            for (ri, &r) in ranks.iter().enumerate() {
                assert_eq!(t.rank_index(r), Some(ri));
                for (got, want) in [
                    (t.client_fwd_flops(l_c, ri), p.client_fwd_flops(l_c, r)),
                    (t.client_bwd_flops(l_c, ri), p.client_bwd_flops(l_c, r)),
                    (t.server_fwd_flops(l_c, ri), p.server_fwd_flops(l_c, r)),
                    (t.server_bwd_flops(l_c, ri), p.server_bwd_flops(l_c, r)),
                    (t.adapter_bits(l_c, ri), p.client_adapter_bits(l_c, r)),
                    (
                        t.client_energy_flops(l_c, ri),
                        p.client_fwd_flops(l_c, r) + p.client_bwd_flops(l_c, r),
                    ),
                ] {
                    assert_eq!(got.to_bits(), want.to_bits(), "l_c={l_c} r={r}");
                }
            }
        }
        assert_eq!(t.rank_index(3), None);
    }

    #[test]
    fn workload_table_clamps_like_profile() {
        let p = profile();
        let t = WorkloadTable::new(&p, &[4]);
        // beyond-L lookups clamp, exactly as the profile methods do
        assert_eq!(
            t.client_fwd_flops(99, 0).to_bits(),
            p.client_fwd_flops(99, 4).to_bits()
        );
        assert_eq!(t.activation_bits(99).to_bits(), p.activation_bits(99).to_bits());
    }
}
