//! Federated server: the aggregation phase (paper Sec. IV-B).
//!
//! Every I local steps the clients upload their LoRA adapter sets; the
//! federated server FedAvg-aggregates them weighted by local dataset
//! sizes (Eq. 7) and broadcasts the new global client adapter back.

use anyhow::Result;

use crate::model::lora::AdapterSet;

/// Stateless aggregator with dataset-size weights fixed at start-up.
pub struct FedServer {
    weights: Vec<f64>,
    /// Number of aggregations performed (diagnostics).
    pub rounds: usize,
}

impl FedServer {
    /// `shard_sizes[k]` = D_k, the paper's aggregation weights.
    pub fn new(shard_sizes: &[usize]) -> FedServer {
        FedServer {
            weights: shard_sizes.iter().map(|&s| s as f64).collect(),
            rounds: 0,
        }
    }

    /// Eq. 7: weighted average of the client adapter sets.
    pub fn aggregate(&mut self, sets: &[AdapterSet]) -> Result<AdapterSet> {
        let refs: Vec<&AdapterSet> = sets.iter().collect();
        self.rounds += 1;
        AdapterSet::fedavg(&refs, &self.weights)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::lora::Tensor;

    fn set(v: f32) -> AdapterSet {
        AdapterSet {
            tensors: vec![Tensor {
                name: "a".into(),
                shape: vec![2],
                data: vec![v, v],
            }],
        }
    }

    #[test]
    fn weights_follow_shard_sizes() {
        let mut fed = FedServer::new(&[30, 10]);
        let out = fed.aggregate(&[set(1.0), set(5.0)]).unwrap();
        // (30*1 + 10*5)/40 = 2.0
        assert_eq!(out.tensors[0].data, vec![2.0, 2.0]);
        assert_eq!(fed.rounds, 1);
    }
}
