//! Host-side optimizers for the LoRA adapters.
//!
//! The paper fine-tunes with learning rate 4e-4 — an Adam-class setting
//! (plain SGD at that rate barely moves LoRA adapters, whose B factor
//! starts at zero). We provide both: SGD matches the paper's update
//! equations (5)–(6) literally; Adam is what the convergence
//! experiments (Figs. 3–4, Table IV) actually use, like the LoRA paper
//! itself. Optimizer state lives on the owning node (client or main
//! server) and survives FedAvg rounds, as in standard FL practice.

use anyhow::{bail, Result};

use crate::model::lora::AdapterSet;

/// Optimizer choice for a training run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OptKind {
    Sgd,
    Adam,
}

/// Per-node optimizer with its state.
#[derive(Clone, Debug)]
pub struct Optimizer {
    kind: OptKind,
    lr: f32,
    beta1: f32,
    beta2: f32,
    eps: f32,
    t: i32,
    m: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
}

impl Optimizer {
    pub fn new(kind: OptKind, lr: f32) -> Optimizer {
        Optimizer {
            kind,
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            t: 0,
            m: Vec::new(),
            v: Vec::new(),
        }
    }

    /// Apply one update `params <- params - lr * dir(grads)`.
    pub fn step(&mut self, params: &mut AdapterSet, grads: &AdapterSet) -> Result<()> {
        if grads.tensors.len() != params.tensors.len() {
            bail!("optimizer: grad/param tensor count mismatch");
        }
        match self.kind {
            OptKind::Sgd => params.sgd_step(grads, self.lr),
            OptKind::Adam => {
                if self.m.is_empty() {
                    self.m = params.tensors.iter().map(|t| vec![0.0; t.data.len()]).collect();
                    self.v = params.tensors.iter().map(|t| vec![0.0; t.data.len()]).collect();
                }
                self.t += 1;
                let b1c = 1.0 - self.beta1.powi(self.t);
                let b2c = 1.0 - self.beta2.powi(self.t);
                for ((p, g), (m, v)) in params
                    .tensors
                    .iter_mut()
                    .zip(&grads.tensors)
                    .zip(self.m.iter_mut().zip(self.v.iter_mut()))
                {
                    if p.data.len() != g.data.len() {
                        bail!("optimizer: shape mismatch on '{}'", p.name);
                    }
                    for i in 0..p.data.len() {
                        let gi = g.data[i];
                        m[i] = self.beta1 * m[i] + (1.0 - self.beta1) * gi;
                        v[i] = self.beta2 * v[i] + (1.0 - self.beta2) * gi * gi;
                        let mhat = m[i] / b1c;
                        let vhat = v[i] / b2c;
                        p.data[i] -= self.lr * mhat / (vhat.sqrt() + self.eps);
                    }
                }
                Ok(())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::lora::Tensor;

    fn set(vals: &[f32]) -> AdapterSet {
        AdapterSet {
            tensors: vec![Tensor {
                name: "a".into(),
                shape: vec![vals.len()],
                data: vals.to_vec(),
            }],
        }
    }

    #[test]
    fn sgd_matches_manual() {
        let mut opt = Optimizer::new(OptKind::Sgd, 0.1);
        let mut p = set(&[1.0, -1.0]);
        opt.step(&mut p, &set(&[1.0, 1.0])).unwrap();
        assert_eq!(p.tensors[0].data, vec![0.9, -1.1]);
    }

    #[test]
    fn adam_first_step_is_signed_lr() {
        // bias-corrected Adam's first step is lr * sign(g) (up to eps)
        let mut opt = Optimizer::new(OptKind::Adam, 0.01);
        let mut p = set(&[0.0, 0.0]);
        opt.step(&mut p, &set(&[3.0, -0.5])).unwrap();
        assert!((p.tensors[0].data[0] + 0.01).abs() < 1e-5);
        assert!((p.tensors[0].data[1] - 0.01).abs() < 1e-5);
    }

    #[test]
    fn adam_minimizes_quadratic_faster_than_tiny_sgd() {
        // minimize ||p - 3||^2 from p=0
        let run = |kind, lr: f32| {
            let mut opt = Optimizer::new(kind, lr);
            let mut p = set(&[0.0]);
            for _ in 0..200 {
                let g = set(&[2.0 * (p.tensors[0].data[0] - 3.0)]);
                opt.step(&mut p, &g).unwrap();
            }
            (p.tensors[0].data[0] - 3.0).abs()
        };
        assert!(run(OptKind::Adam, 0.05) < 0.5);
        assert!(run(OptKind::Sgd, 0.05) < 1e-3); // sanity: sgd also converges
    }

    #[test]
    fn mismatch_errors() {
        let mut opt = Optimizer::new(OptKind::Adam, 0.01);
        let mut p = set(&[0.0]);
        assert!(opt.step(&mut p, &set(&[1.0, 2.0])).is_err());
    }
}
