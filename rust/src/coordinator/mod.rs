//! The SFL coordinator — the paper's Algorithm 1 as a running system.
//!
//! Topology (one OS thread each, message-passing only):
//!
//! ```text
//!   client 0 ─┐                       ┌─> federated server (every I steps:
//!   client 1 ─┼─ activations/adapters ┤    FedAvg Eq. 7 + broadcast)
//!   ...       │                       │
//!   client K ─┴─────> main server ────┘
//!                     (server_step, SGD Eq. 5, ds back to clients)
//! ```
//!
//! Device execution (the PJRT runtime) lives on a dedicated **device
//! thread** ([`device`]): PJRT handles are not `Send`, and the CPU
//! device is a single shared resource anyway — clients and the main
//! server submit compute requests over channels, which also gives each
//! phase a natural queueing point for the latency accounting.
//!
//! * [`device`] — the device-service thread and its typed handle;
//! * [`client`] — per-client worker (phases a, b, f + local SGD Eq. 6);
//! * [`fed_server`] — aggregation phase (Eq. 7);
//! * [`orchestrator`] — wires everything, runs E global rounds, records
//!   loss curves and phase walltimes;
//! * [`mock`] — deterministic [`crate::runtime::SflModel`] for tests.

pub mod checkpoint;
pub mod client;
pub mod device;
pub mod fed_server;
pub mod mock;
pub mod optim;
pub mod orchestrator;

pub use optim::{OptKind, Optimizer};
pub use orchestrator::{train, train_with, TrainOptions, TrainReport};
