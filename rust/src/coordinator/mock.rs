//! Deterministic mock model for coordinator tests — no PJRT involved.
//!
//! Dynamics are chosen so the *whole* training loop is verifiable in
//! closed form: the "loss" is the squared L2 norm of all adapters (plus
//! a constant), and every gradient equals the parameter itself, so SGD
//! contracts parameters geometrically (`p <- (1-lr)p`) and the loss
//! must decrease monotonically through the full client/server/fed
//! plumbing. Shapes follow the real wire format.

use anyhow::{bail, Result};

use crate::model::lora::{AdapterSet, Tensor};
use crate::runtime::{SflModel, StepOutput};
use crate::util::stats::fsum32;

/// Mock with 2 client tensors and 2 server tensors of 4 params each.
pub struct MockModel {
    batch: usize,
    seq: usize,
    d_model: usize,
    /// Counts every device call (used by overhead benches and tests).
    pub calls: usize,
}

impl MockModel {
    pub fn new(batch: usize, seq: usize, d_model: usize) -> MockModel {
        MockModel {
            batch,
            seq,
            d_model,
            calls: 0,
        }
    }

    fn adapters(tag: &str, fill: f32) -> AdapterSet {
        AdapterSet {
            tensors: (0..2)
                .map(|i| Tensor {
                    name: format!("h{i}.{tag}"),
                    shape: vec![2, 2],
                    data: vec![fill; 4],
                })
                .collect(),
        }
    }
}

impl SflModel for MockModel {
    fn batch(&self) -> usize {
        self.batch
    }

    fn seq(&self) -> usize {
        self.seq
    }

    fn d_model(&self) -> usize {
        self.d_model
    }

    fn vocab(&self) -> usize {
        256
    }

    fn init_client_adapters(&self) -> AdapterSet {
        Self::adapters("c", 1.0)
    }

    fn init_server_adapters(&self) -> AdapterSet {
        Self::adapters("s", 1.0)
    }

    fn client_forward(&mut self, adapters: &AdapterSet, tokens: &[i32]) -> Result<Vec<f32>> {
        self.calls += 1;
        if tokens.len() != self.batch * self.seq {
            bail!("bad token count");
        }
        // s encodes the client adapter norm so the server "loss" sees it
        let norm2: f32 = fsum32(
            adapters
                .tensors
                .iter()
                .flat_map(|t| &t.data)
                .map(|v| v * v),
        );
        Ok(vec![norm2; self.batch * self.seq * self.d_model])
    }

    fn server_step(
        &mut self,
        adapters: &AdapterSet,
        s: &[f32],
        tokens: &[i32],
        _mask: &[f32],
    ) -> Result<StepOutput> {
        self.calls += 1;
        if s.len() != self.batch * self.seq * self.d_model || tokens.len() != self.batch * self.seq
        {
            bail!("bad shapes");
        }
        let server_norm2: f32 = fsum32(
            adapters
                .tensors
                .iter()
                .flat_map(|t| &t.data)
                .map(|v| v * v),
        );
        let client_norm2 = s[0]; // encoded by client_forward
        let loss = client_norm2 + server_norm2;
        // grad of ||p||^2 is 2p; use p for a clean (1-lr) contraction
        let server_grads = AdapterSet {
            tensors: adapters.tensors.clone(),
        };
        Ok(StepOutput {
            loss,
            server_grads,
            ds: vec![1.0; s.len()],
        })
    }

    fn client_backward(
        &mut self,
        adapters: &AdapterSet,
        _tokens: &[i32],
        ds: &[f32],
    ) -> Result<AdapterSet> {
        self.calls += 1;
        if ds.len() != self.batch * self.seq * self.d_model {
            bail!("bad ds");
        }
        Ok(AdapterSet {
            tensors: adapters.tensors.clone(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sgd_on_mock_contracts_loss() {
        let mut m = MockModel::new(2, 4, 3);
        let mut ac = m.init_client_adapters();
        let mut asrv = m.init_server_adapters();
        let tokens = vec![0i32; 8];
        let mask = vec![1.0f32; 8];
        let mut prev = f32::INFINITY;
        for _ in 0..5 {
            let s = m.client_forward(&ac, &tokens).unwrap();
            let out = m.server_step(&asrv, &s, &tokens, &mask).unwrap();
            assert!(out.loss < prev);
            prev = out.loss;
            let gc = m.client_backward(&ac, &tokens, &out.ds).unwrap();
            ac.sgd_step(&gc, 0.1).unwrap();
            asrv.sgd_step(&out.server_grads, 0.1).unwrap();
        }
        // loss measured at iteration 4 uses params after 4 updates:
        // 16 * (0.9^2)^4 = 16 * 0.9^8
        let expect = 16.0 * 0.9f32.powi(8);
        assert!((prev - expect).abs() < 1e-3, "{prev} vs {expect}");
    }
}
