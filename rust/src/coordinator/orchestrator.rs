//! Orchestrator: wires clients, main server, federated server and the
//! device service into the paper's Algorithm 1 and runs E global rounds.
//!
//! The orchestrator thread *is* the main server: each step it collects
//! the K activation uploads, runs the server computation for each
//! client, averages the K server-adapter gradients into one SGD update
//! (the paper's combined-batch update, Eq. 5), and returns each
//! client's activation gradients. Every I steps it runs the federated
//! aggregation (Eq. 7) and, right after broadcasting, evaluates the
//! global model on held-out data — the measurement Fig. 3 plots.

use std::sync::mpsc::channel;

use anyhow::{anyhow, Context, Result};

use super::client::{run_client, ActivationUpload, AdapterUpload, ClientChannels, ClientConfig};
use super::device::{spawn_device, DeviceHandle, DeviceInit};
use super::fed_server::FedServer;
use super::optim::{OptKind, Optimizer};
use crate::data::{
    generate_byte_corpus, generate_corpus, shard_by_food, shard_iid, Batcher, E2eSample,
};
use crate::model::lora::AdapterSet;
use crate::runtime::SflModel;
use crate::util::clock::{Clock, WallClock};
use crate::util::rng::Rng;

/// Training options (defaults follow the tiny-model experiment setup).
#[derive(Clone, Debug)]
pub struct TrainOptions {
    pub clients: usize,
    /// Local steps per global round (I).
    pub local_steps: usize,
    /// Global rounds (E).
    pub global_rounds: usize,
    pub lr_client: f32,
    pub lr_server: f32,
    /// Training corpus size (split across clients).
    pub corpus_size: usize,
    /// Held-out validation corpus size.
    pub val_size: usize,
    /// Validation batches per evaluation point.
    pub eval_batches: usize,
    /// Label-skew sharding instead of IID.
    pub non_iid: bool,
    /// Optimizer for both client and server adapter updates.
    pub optimizer: OptKind,
    /// Use short patterned byte data instead of the E2E-style corpus
    /// (required for variants whose sequence window is < ~40 bytes,
    /// e.g. the `micro` integration model).
    pub byte_corpus: bool,
    /// If set, save the final global client/server adapters here
    /// (`<path>.client.ckpt` / `<path>.server.ckpt`).
    pub save_adapters: Option<String>,
    /// Transient-failure retry budget per server step (PR-10): a failed
    /// `server_step` is re-attempted up to this many times before the
    /// client is dropped from the step (training) or the error
    /// propagates (validation). 0 restores the pre-PR-10 fail-fast.
    pub retry_budget: usize,
    /// Virtual backoff charged per retry, doubling each attempt —
    /// *accounted* in [`TrainReport::backoff_s`], never slept (the
    /// coordinator takes no ambient clock reads; see
    /// [`crate::util::clock`]).
    pub retry_backoff_s: f64,
    pub seed: u64,
}

impl Default for TrainOptions {
    fn default() -> Self {
        TrainOptions {
            clients: 5,
            local_steps: 12,
            global_rounds: 10,
            lr_client: 1e-3,
            lr_server: 1e-3,
            corpus_size: 2000,
            val_size: 200,
            eval_batches: 4,
            non_iid: false,
            optimizer: OptKind::Adam,
            byte_corpus: false,
            save_adapters: None,
            retry_budget: 2,
            retry_backoff_s: 0.05,
            seed: 42,
        }
    }
}

/// Phase wall-clock accounting (seconds) for §Perf.
#[derive(Clone, Debug, Default)]
pub struct PhaseWalltime {
    pub server_compute: f64,
    pub aggregation: f64,
    pub evaluation: f64,
    pub total: f64,
}

/// Everything a training run produces.
#[derive(Clone, Debug)]
pub struct TrainReport {
    /// Mean training loss per step (over the K per-client server losses).
    pub train_loss: Vec<f64>,
    /// (step, validation loss) after every aggregation.
    pub val_loss: Vec<(usize, f64)>,
    /// Final validation perplexity (e^loss).
    pub final_ppl: f64,
    pub fed_rounds: usize,
    pub walltime: PhaseWalltime,
    /// Transient `server_step` failures that a retry recovered (PR-10).
    pub retries: usize,
    /// Client-steps dropped after the retry budget was exhausted: the
    /// client sat the step out (zero activation gradient, no loss
    /// contribution) instead of aborting the run.
    pub dropped_client_steps: usize,
    /// Total virtual backoff the retries would have cost — accounted,
    /// never slept, so retried runs stay bit-deterministic.
    pub backoff_s: f64,
    /// Final global client adapters and server adapters.
    pub client_adapters: AdapterSet,
    pub server_adapters: AdapterSet,
}

impl TrainReport {
    /// First step at which the validation loss reached `target` (Fig. 4's
    /// "steps to target loss"), if ever.
    pub fn steps_to_target(&self, target: f64) -> Option<usize> {
        self.val_loss
            .iter()
            .find(|&&(_, l)| l <= target)
            .map(|&(s, _)| s)
    }
}

/// Bounded deterministic retry over a fallible device call (PR-10).
/// Backoff is charged to a virtual accumulator, doubling per attempt —
/// never slept, so a retried run's outputs are bit-identical to a run
/// where the transient failure never happened (property-tested below).
struct RetryState {
    budget: usize,
    base_backoff_s: f64,
    retries: usize,
    dropped: usize,
    backoff_total_s: f64,
}

impl RetryState {
    fn new(opts: &TrainOptions) -> RetryState {
        RetryState {
            budget: opts.retry_budget,
            base_backoff_s: opts.retry_backoff_s,
            retries: 0,
            dropped: 0,
            backoff_total_s: 0.0,
        }
    }

    /// Run `f`, re-attempting up to `budget` times; on exhaustion the
    /// *last* error is returned so the root cause stays in the chain.
    fn attempt<T>(&mut self, mut f: impl FnMut() -> Result<T>) -> Result<T> {
        let mut backoff = self.base_backoff_s;
        let mut tries = 0usize;
        loop {
            match f() {
                Ok(v) => return Ok(v),
                Err(e) => {
                    if tries >= self.budget {
                        return Err(e);
                    }
                    tries += 1;
                    self.retries += 1;
                    self.backoff_total_s += backoff;
                    backoff *= 2.0;
                }
            }
        }
    }
}

/// Train via Algorithm 1. `factory` builds the [`SflModel`] on the
/// device thread (PJRT runtimes are not `Send`).
///
/// Walltimes in the report are real: this wires in the bench-owned
/// [`WallClock`] (the one sanctioned home for wall-clock reads). Tests
/// and the allocator service use [`train_with`] to inject a
/// deterministic clock and observe round boundaries.
pub fn train<F>(opts: &TrainOptions, factory: F) -> Result<TrainReport>
where
    F: FnOnce() -> Result<Box<dyn SflModel>> + Send + 'static,
{
    train_with(opts, factory, &WallClock::new(), |_| Ok(()))
}

/// [`train`] with an injectable [`Clock`] for the phase-walltime
/// telemetry and an `on_round` hook fired after every federated
/// aggregation (with the 1-based global round index). The hook is how
/// a training run becomes an event producer for the PR-8 allocator
/// service: each aggregation boundary maps to one `RoundTick`.
pub fn train_with<F, H>(
    opts: &TrainOptions,
    factory: F,
    clock: &dyn Clock,
    on_round: H,
) -> Result<TrainReport>
where
    F: FnOnce() -> Result<Box<dyn SflModel>> + Send + 'static,
    H: FnMut(usize) -> Result<()>,
{
    let t_start = clock.now();
    let (device, init, device_join) = spawn_device(factory)?;
    let res = train_inner(opts, &device, &init, clock, on_round);
    device.shutdown();
    let _ = device_join.join();
    let mut report = res?;
    report.walltime.total = clock.now() - t_start;
    Ok(report)
}

fn train_inner<H>(
    opts: &TrainOptions,
    device: &DeviceHandle,
    init: &DeviceInit,
    clock: &dyn Clock,
    mut on_round: H,
) -> Result<TrainReport>
where
    H: FnMut(usize) -> Result<()>,
{
    let k_n = opts.clients;
    let total_steps = opts.local_steps * opts.global_rounds;
    let mut rng = Rng::new(opts.seed);

    // data
    let (corpus, val) = if opts.byte_corpus {
        (
            generate_byte_corpus(opts.corpus_size, init.seq, &mut rng),
            generate_byte_corpus(opts.val_size, init.seq, &mut rng.fork(1)),
        )
    } else {
        (
            generate_corpus(opts.corpus_size, &mut rng),
            generate_corpus(opts.val_size, &mut rng.fork(1)),
        )
    };
    let shards: Vec<Vec<E2eSample>> = if opts.non_iid {
        shard_by_food(&corpus, k_n)
    } else {
        shard_iid(&corpus, k_n, &mut rng)
    };
    let shard_sizes: Vec<usize> = shards.iter().map(Vec::len).collect();
    if shard_sizes.iter().any(|&s| s == 0) {
        anyhow::bail!("a client shard is empty; reduce K or grow the corpus");
    }
    let val_batcher = Batcher::with_vocab(&val, init.batch, init.seq, init.vocab, rng.fork(2));

    // channels
    let (up_tx, up_rx) = channel::<ActivationUpload>();
    let (fed_tx, fed_rx) = channel::<AdapterUpload>();
    let mut ds_txs = Vec::with_capacity(k_n);
    let mut fed_bcast_txs = Vec::with_capacity(k_n);
    let mut joins = Vec::with_capacity(k_n);

    for (k, shard) in shards.into_iter().enumerate() {
        let (ds_tx, ds_rx) = channel::<Vec<f32>>();
        let (bc_tx, bc_rx) = channel::<AdapterSet>();
        ds_txs.push(ds_tx);
        fed_bcast_txs.push(bc_tx);
        let cfg = ClientConfig {
            id: k,
            local_steps: opts.local_steps,
            total_steps,
            lr: opts.lr_client,
            optimizer: opts.optimizer,
        };
        let ch = ClientChannels {
            to_server: up_tx.clone(),
            from_server: ds_rx,
            to_fed: fed_tx.clone(),
            from_fed: bc_rx,
        };
        let adapters = init.client_adapters.clone();
        let batcher =
            Batcher::with_vocab(&shard, init.batch, init.seq, init.vocab, rng.fork(100 + k as u64));
        let dev = device.clone();
        joins.push(
            std::thread::Builder::new()
                .name(format!("sfllm-client-{k}"))
                .spawn(move || run_client(cfg, adapters, batcher, dev, ch))?,
        );
    }
    drop(up_tx);
    drop(fed_tx);

    // main server + federated server loop
    let mut server_opt = Optimizer::new(opts.optimizer, opts.lr_server);
    let mut server_adapters = init.server_adapters.clone();
    let mut global_client_adapters = init.client_adapters.clone();
    let mut fed = FedServer::new(&shard_sizes);
    let mut train_loss = Vec::with_capacity(total_steps);
    let mut val_loss = Vec::new();
    let mut wall = PhaseWalltime::default();
    let mut retry = RetryState::new(opts);

    for step in 1..=total_steps {
        // phase c/d: collect K uploads, compute, average server grads
        let t0 = clock.now();
        let mut uploads: Vec<Option<ActivationUpload>> = (0..k_n).map(|_| None).collect();
        for _ in 0..k_n {
            let u = up_rx.recv().map_err(|_| anyhow!("clients died"))?;
            let id = u.client;
            uploads[id] = Some(u);
        }
        let mut grad_acc: Option<AdapterSet> = None;
        let mut step_loss = 0.0f64;
        let mut successes = 0usize;
        let mut last_err: Option<anyhow::Error> = None;
        let mut ds_out: Vec<Option<Vec<f32>>> = (0..k_n).map(|_| None).collect();
        for u in uploads.iter().flatten() {
            match retry.attempt(|| device.server_step(&server_adapters, &u.s, &u.tokens, &u.mask))
            {
                Ok(out) => {
                    successes += 1;
                    step_loss += out.loss as f64;
                    ds_out[u.client] = Some(out.ds);
                    grad_acc = Some(match grad_acc {
                        None => out.server_grads,
                        Some(mut acc) => {
                            for (a, g) in acc.tensors.iter_mut().zip(&out.server_grads.tensors) {
                                for (av, gv) in a.data.iter_mut().zip(&g.data) {
                                    *av += gv;
                                }
                            }
                            acc
                        }
                    });
                }
                Err(e) => {
                    // retry budget exhausted: this client sits the step
                    // out — a zero activation gradient keeps its local
                    // loop in lockstep without contributing an update
                    retry.dropped += 1;
                    ds_out[u.client] = Some(vec![0.0f32; u.s.len()]);
                    last_err = Some(e);
                }
            }
        }
        // combined-batch update (Eq. 5): average the surviving gradient
        // sets (all K on a healthy step, so the fault-free bytes are
        // unchanged)
        let mut grads = match grad_acc {
            Some(g) => g,
            None => {
                let e = last_err.unwrap_or_else(|| anyhow!("no uploads received"));
                return Err(e.context(format!(
                    "every client's server step failed at step {step} \
                     (retry budget {} exhausted): no combined-batch update possible",
                    opts.retry_budget
                )));
            }
        };
        let inv = 1.0 / successes as f32;
        for t in &mut grads.tensors {
            t.data.iter_mut().for_each(|v| *v *= inv);
        }
        server_opt.step(&mut server_adapters, &grads)?;
        train_loss.push(step_loss / successes as f64);
        wall.server_compute += clock.now() - t0;

        // phase e: ship activation gradients back
        for (k, ds) in ds_out.into_iter().enumerate() {
            ds_txs[k]
                .send(ds.context("missing ds")?)
                .map_err(|_| anyhow!("client {k} gone"))?;
        }

        // aggregation every I steps
        if step % opts.local_steps == 0 {
            let t1 = clock.now();
            let mut sets: Vec<Option<AdapterSet>> = (0..k_n).map(|_| None).collect();
            for _ in 0..k_n {
                let u = fed_rx.recv().map_err(|_| anyhow!("clients died (fed)"))?;
                let id = u.client;
                sets[id] = Some(u.adapters);
            }
            let sets: Vec<AdapterSet> = sets.into_iter().map(Option::unwrap).collect();
            global_client_adapters = fed.aggregate(&sets)?;
            for tx in &fed_bcast_txs {
                tx.send(global_client_adapters.clone())
                    .map_err(|_| anyhow!("broadcast failed"))?;
            }
            wall.aggregation += clock.now() - t1;

            // validation on the freshly aggregated global model
            let t2 = clock.now();
            let mut vl = 0.0f64;
            for b in 0..opts.eval_batches {
                let batch = val_batcher.eval_batch(b * init.batch);
                let s = retry
                    .attempt(|| device.client_forward(&global_client_adapters, &batch.tokens))
                    .with_context(|| format!("validation forward at step {step}"))?;
                let out = retry
                    .attempt(|| {
                        device.server_step(&server_adapters, &s, &batch.tokens, &batch.mask)
                    })
                    .with_context(|| format!("validation server step at step {step}"))?;
                vl += out.loss as f64;
            }
            val_loss.push((step, vl / opts.eval_batches as f64));
            wall.evaluation += clock.now() - t2;

            on_round(step / opts.local_steps)?;
        }
    }

    for j in joins {
        j.join().map_err(|_| anyhow!("client panicked"))??;
    }

    if let Some(base) = &opts.save_adapters {
        super::checkpoint::save(&global_client_adapters, format!("{base}.client.ckpt"))?;
        super::checkpoint::save(&server_adapters, format!("{base}.server.ckpt"))?;
    }

    let final_ppl = val_loss.last().map(|&(_, l)| l.exp()).unwrap_or(f64::NAN);
    Ok(TrainReport {
        train_loss,
        val_loss,
        final_ppl,
        fed_rounds: fed.rounds,
        walltime: wall,
        retries: retry.retries,
        dropped_client_steps: retry.dropped,
        backoff_s: retry.backoff_total_s,
        client_adapters: global_client_adapters,
        server_adapters,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::mock::MockModel;

    fn opts() -> TrainOptions {
        TrainOptions {
            clients: 3,
            local_steps: 4,
            global_rounds: 3,
            lr_client: 0.05,
            lr_server: 0.05,
            corpus_size: 120,
            val_size: 24,
            eval_batches: 2,
            non_iid: false,
            optimizer: OptKind::Sgd, // mock dynamics assume plain SGD
            byte_corpus: false,
            save_adapters: None,
            retry_budget: 2,
            retry_backoff_s: 0.05,
            seed: 11,
        }
    }

    #[test]
    fn full_loop_runs_and_loss_decreases() {
        let r = train(&opts(), || Ok(Box::new(MockModel::new(2, 64, 3)))).unwrap();
        assert_eq!(r.train_loss.len(), 12);
        assert_eq!(r.fed_rounds, 3);
        assert_eq!(r.val_loss.len(), 3);
        // mock dynamics contract monotonically
        assert!(
            r.train_loss.last().unwrap() < r.train_loss.first().unwrap(),
            "{:?}",
            r.train_loss
        );
        // val loss decreases too
        assert!(r.val_loss.last().unwrap().1 < r.val_loss.first().unwrap().1);
    }

    #[test]
    fn aggregation_counts_and_ppl_finite() {
        let r = train(&opts(), || Ok(Box::new(MockModel::new(2, 64, 3)))).unwrap();
        assert!(r.final_ppl.is_finite());
        assert!(r.walltime.total > 0.0);
    }

    #[test]
    fn steps_to_target_extraction() {
        let r = train(&opts(), || Ok(Box::new(MockModel::new(2, 64, 3)))).unwrap();
        let first = r.val_loss.first().unwrap().1;
        let last = r.val_loss.last().unwrap().1;
        let mid = 0.5 * (first + last);
        let s = r.steps_to_target(mid).unwrap();
        assert!(s > 0 && s <= 12);
        assert_eq!(r.steps_to_target(-1.0), None);
    }

    #[test]
    fn saves_adapter_checkpoints_when_asked() {
        let mut o = opts();
        let base = std::env::temp_dir()
            .join(format!("sfllm_train_ckpt_{}", std::process::id()))
            .to_string_lossy()
            .into_owned();
        o.save_adapters = Some(base.clone());
        let r = train(&o, || Ok(Box::new(MockModel::new(2, 64, 3)))).unwrap();
        let client = crate::coordinator::checkpoint::load(format!("{base}.client.ckpt")).unwrap();
        assert!(crate::coordinator::checkpoint::compatible(&client, &r.client_adapters));
        assert_eq!(client.tensors[0].data, r.client_adapters.tensors[0].data);
        std::fs::remove_file(format!("{base}.client.ckpt")).ok();
        std::fs::remove_file(format!("{base}.server.ckpt")).ok();
    }

    #[test]
    fn manual_clock_and_round_hook() {
        use crate::util::clock::ManualClock;
        let clock = ManualClock::new();
        let mut rounds = Vec::new();
        let r = train_with(
            &opts(),
            || Ok(Box::new(MockModel::new(2, 64, 3))),
            &clock,
            |round| {
                clock.advance(1.0); // deterministic "time passes" per round
                rounds.push(round);
                Ok(())
            },
        )
        .unwrap();
        // the hook saw every aggregation boundary, in order
        assert_eq!(rounds, vec![1, 2, 3]);
        // walltime is exactly what the manual clock handed out: the
        // report contains zero ambient wall-clock reads
        assert_eq!(r.walltime.total, 3.0);
        // the hook fires after each phase accrual, so with a frozen
        // clock inside the phases every per-phase bucket stays exactly 0
        assert_eq!(r.walltime.server_compute, 0.0);
        assert_eq!(r.walltime.aggregation, 0.0);
        assert_eq!(r.walltime.evaluation, 0.0);
    }

    #[test]
    fn round_hook_error_aborts_run() {
        use crate::util::clock::ManualClock;
        let clock = ManualClock::new();
        let err = train_with(
            &opts(),
            || Ok(Box::new(MockModel::new(2, 64, 3))),
            &clock,
            |round| {
                if round >= 2 {
                    anyhow::bail!("producer asked to stop at round {round}");
                }
                Ok(())
            },
        );
        let msg = format!("{:#}", err.expect_err("must fail"));
        assert!(msg.contains("stop at round 2"), "{msg}");
    }

    #[test]
    fn non_iid_sharding_runs() {
        let mut o = opts();
        o.non_iid = true;
        let r = train(&o, || Ok(Box::new(MockModel::new(2, 64, 3)))).unwrap();
        assert_eq!(r.fed_rounds, 3);
    }

    /// Mock whose `server_step` fails on 1-based calls in
    /// `(fail_from, fail_to]`: a finite window models a transient fault
    /// that recovers (the PR-10 retry path), `fail_to == usize::MAX`
    /// models a dead device. Failed calls bail *before* reaching the
    /// inner mock, so its state sees exactly the successful sequence.
    struct FailingModel {
        inner: MockModel,
        fail_from: usize,
        fail_to: usize,
        calls: std::cell::Cell<usize>,
    }

    impl crate::runtime::SflModel for FailingModel {
        fn batch(&self) -> usize {
            self.inner.batch()
        }
        fn seq(&self) -> usize {
            self.inner.seq()
        }
        fn d_model(&self) -> usize {
            self.inner.d_model()
        }
        fn vocab(&self) -> usize {
            self.inner.vocab()
        }
        fn init_client_adapters(&self) -> crate::model::lora::AdapterSet {
            self.inner.init_client_adapters()
        }
        fn init_server_adapters(&self) -> crate::model::lora::AdapterSet {
            self.inner.init_server_adapters()
        }
        fn client_forward(
            &mut self,
            a: &crate::model::lora::AdapterSet,
            t: &[i32],
        ) -> anyhow::Result<Vec<f32>> {
            self.inner.client_forward(a, t)
        }
        fn server_step(
            &mut self,
            a: &crate::model::lora::AdapterSet,
            s: &[f32],
            t: &[i32],
            m: &[f32],
        ) -> anyhow::Result<crate::runtime::StepOutput> {
            self.calls.set(self.calls.get() + 1);
            let n = self.calls.get();
            if n > self.fail_from && n <= self.fail_to {
                anyhow::bail!("injected device failure (call {n})");
            }
            self.inner.server_step(a, s, t, m)
        }
        fn client_backward(
            &mut self,
            a: &crate::model::lora::AdapterSet,
            t: &[i32],
            ds: &[f32],
        ) -> anyhow::Result<crate::model::lora::AdapterSet> {
            self.inner.client_backward(a, t, ds)
        }
    }

    #[test]
    fn device_failure_surfaces_as_error_not_hang() {
        let err = train(&opts(), || {
            Ok(Box::new(FailingModel {
                inner: MockModel::new(2, 64, 3),
                fail_from: 4,
                fail_to: usize::MAX,
                calls: std::cell::Cell::new(0),
            }))
        });
        let msg = format!("{:#}", err.expect_err("must fail"));
        assert!(msg.contains("injected device failure"), "{msg}");
        assert!(msg.contains("retry budget"), "{msg}");
    }

    #[test]
    fn transient_failure_is_retried_to_identical_bytes() {
        let baseline = train(&opts(), || Ok(Box::new(MockModel::new(2, 64, 3)))).unwrap();
        // exactly one call (the 6th) fails once; the retry recovers it
        let retried = train(&opts(), || {
            Ok(Box::new(FailingModel {
                inner: MockModel::new(2, 64, 3),
                fail_from: 5,
                fail_to: 6,
                calls: std::cell::Cell::new(0),
            }))
        })
        .unwrap();
        assert_eq!(retried.retries, 1);
        assert_eq!(retried.dropped_client_steps, 0);
        assert_eq!(retried.backoff_s, 0.05, "one retry charges one base backoff");
        // the recovered run is bit-identical to one that never failed
        assert_eq!(baseline.train_loss.len(), retried.train_loss.len());
        for (a, b) in baseline.train_loss.iter().zip(&retried.train_loss) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(baseline.val_loss, retried.val_loss);
        assert_eq!(baseline.final_ppl.to_bits(), retried.final_ppl.to_bits());
        for (a, b) in baseline
            .client_adapters
            .tensors
            .iter()
            .zip(&retried.client_adapters.tensors)
        {
            assert_eq!(a.data, b.data);
        }
        for (a, b) in baseline
            .server_adapters
            .tensors
            .iter()
            .zip(&retried.server_adapters.tensors)
        {
            assert_eq!(a.data, b.data);
        }
    }

    #[test]
    fn exhausted_retries_drop_the_client_not_the_run() {
        // calls 5..=7 fail: client 1's step-2 call plus both its
        // retries — the budget exhausts and the client sits the step out
        let r = train(&opts(), || {
            Ok(Box::new(FailingModel {
                inner: MockModel::new(2, 64, 3),
                fail_from: 4,
                fail_to: 7,
                calls: std::cell::Cell::new(0),
            }))
        })
        .unwrap();
        assert_eq!(r.retries, 2, "the full budget was spent before dropping");
        assert_eq!(r.dropped_client_steps, 1);
        assert!(r.backoff_s > 0.05, "backoff doubles across the two retries");
        // the run itself completed every round
        assert_eq!(r.train_loss.len(), 12);
        assert_eq!(r.fed_rounds, 3);
        assert!(r.final_ppl.is_finite());
    }

    #[test]
    fn too_many_clients_for_corpus_errors_cleanly() {
        let mut o = opts();
        o.clients = 50;
        o.corpus_size = 10; // some shard will be empty -> clean error
        let res = train(&o, || Ok(Box::new(MockModel::new(2, 64, 3))));
        assert!(res.is_err());
    }

    #[test]
    fn single_client_is_centralized_mode() {
        let mut o = opts();
        o.clients = 1;
        let r = train(&o, || Ok(Box::new(MockModel::new(2, 64, 3)))).unwrap();
        assert_eq!(r.fed_rounds, 3);
        assert!(r.train_loss.last().unwrap() < r.train_loss.first().unwrap());
    }
}
