//! Adapter checkpointing: save/restore LoRA adapter sets so a
//! fine-tuning run can resume (or ship its adapters for serving).
//!
//! Self-contained little-endian binary format (no serde in the offline
//! crate set), carried over [`crate::util::codec`] since PR-8:
//!
//! ```text
//! magic "SFLA" | u32 version (= 2)
//! u32 n_tensors
//! per tensor: u32 name_len | name bytes | u32 ndim | u32 dims... | f32 data...
//! u32 crc32 of everything above (IEEE, little-endian) — since v2
//! ```
//!
//! The header is the versioning contract: a magic mismatch means "this
//! is not an adapter checkpoint at all", a version mismatch means "a
//! different schema wrote this" — both fail descriptively instead of
//! misparsing bytes; a CRC mismatch (v2, PR-10) means the body was
//! corrupted in storage or transit, caught before any tensor is
//! trusted. [`encode`]/[`decode`] expose the byte form so other
//! artifacts (e.g. a service checkpoint) can embed adapter sets
//! verbatim.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::model::lora::{AdapterSet, Tensor};
use crate::util::codec::{self, BinReader, BinWriter};

const MAGIC: &[u8; 4] = b"SFLA";
/// v2 (PR-10): seals the body with a CRC32 footer.
const VERSION: u32 = 2;
/// Guard rails against reading a corrupt length as an allocation size.
const MAX_NAME_LEN: usize = 4096;
const MAX_NDIM: usize = 8;

/// Serialize an adapter set to its checkpoint byte form.
pub fn encode(set: &AdapterSet) -> Vec<u8> {
    let mut w = BinWriter::with_header(MAGIC, VERSION);
    w.u32(set.tensors.len() as u32);
    for t in &set.tensors {
        w.str(&t.name);
        w.u32(t.shape.len() as u32);
        for &d in &t.shape {
            w.u32(d as u32);
        }
        for &v in &t.data {
            w.f32(v);
        }
    }
    let mut bytes = w.into_bytes();
    codec::append_crc32(&mut bytes);
    bytes
}

/// Parse checkpoint bytes (see the module docs for the format).
pub fn decode(bytes: &[u8]) -> Result<AdapterSet> {
    // magic/version first: a wrong or outdated file should say so, not
    // fail an integrity check it never promised to pass
    let mut peek = BinReader::new(bytes);
    peek.expect_magic(MAGIC, "SfLLM adapter checkpoint")?;
    let version = peek.u32("adapter checkpoint version")?;
    if version != VERSION {
        bail!(
            "unsupported adapter checkpoint version {version} \
             (this build reads version {VERSION})"
        );
    }
    let payload = codec::check_crc32(bytes, "adapter checkpoint")?;
    let mut r = BinReader::new(payload);
    r.expect_magic(MAGIC, "SfLLM adapter checkpoint")?;
    r.u32("adapter checkpoint version")?;
    let n = r.u32("tensor count")? as usize;
    let mut tensors = Vec::new();
    for _ in 0..n {
        let name = r.str(MAX_NAME_LEN, "tensor name")?;
        let ndim = r.u32("tensor ndim")? as usize;
        if ndim > MAX_NDIM {
            bail!("corrupt checkpoint: tensor '{name}' has ndim {ndim} (limit {MAX_NDIM})");
        }
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(r.u32("tensor dim")? as usize);
        }
        let numel: usize = shape.iter().product();
        if numel.saturating_mul(4) > r.remaining() {
            bail!(
                "corrupt checkpoint: tensor '{name}' claims {numel} elements \
                 but only {} bytes remain",
                r.remaining()
            );
        }
        let mut data = Vec::with_capacity(numel);
        for _ in 0..numel {
            data.push(r.f32("tensor data")?);
        }
        tensors.push(Tensor { name, shape, data });
    }
    r.expect_end("adapter checkpoint")?;
    Ok(AdapterSet { tensors })
}

/// Write an adapter set to `path` (creating parent dirs).
pub fn save<P: AsRef<Path>>(set: &AdapterSet, path: P) -> Result<()> {
    if let Some(dir) = path.as_ref().parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    std::fs::write(&path, encode(set))
        .with_context(|| format!("writing {}", path.as_ref().display()))
}

/// Load an adapter set from `path`.
pub fn load<P: AsRef<Path>>(path: P) -> Result<AdapterSet> {
    let bytes = std::fs::read(&path)
        .with_context(|| format!("opening {}", path.as_ref().display()))?;
    decode(&bytes).with_context(|| format!("reading {}", path.as_ref().display()))
}

/// Check that a loaded checkpoint matches the expected signature
/// (same tensor names and shapes, in order).
pub fn compatible(a: &AdapterSet, b: &AdapterSet) -> bool {
    a.tensors.len() == b.tensors.len()
        && a.tensors
            .iter()
            .zip(&b.tensors)
            .all(|(x, y)| x.name == y.name && x.shape == y.shape)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> AdapterSet {
        AdapterSet {
            tensors: vec![
                Tensor {
                    name: "h0.aq_A".into(),
                    shape: vec![4, 2],
                    data: (0..8).map(|i| i as f32 * 0.5 - 1.0).collect(),
                },
                Tensor {
                    name: "h0.aq_B".into(),
                    shape: vec![2, 4],
                    data: vec![0.0; 8],
                },
            ],
        }
    }

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("sfllm_ckpt_{name}_{}.bin", std::process::id()))
    }

    #[test]
    fn round_trip_preserves_everything() {
        let path = tmp("rt");
        let set = sample();
        save(&set, &path).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back.tensors.len(), 2);
        for (a, b) in set.tensors.iter().zip(&back.tensors) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.shape, b.shape);
            assert_eq!(a.data, b.data);
        }
        assert!(compatible(&set, &back));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_garbage() {
        let path = tmp("bad");
        std::fs::write(&path, b"not a checkpoint at all").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn corrupted_header_paths_fail_descriptively() {
        let good = encode(&sample());

        // magic: flip one byte
        let mut bad_magic = good.clone();
        bad_magic[0] = b'X';
        let err = decode(&bad_magic).unwrap_err();
        assert!(
            format!("{err:#}").contains("not a SfLLM adapter checkpoint"),
            "{err:#}"
        );

        // version: a future schema number must be refused, not misread
        let mut bad_version = good.clone();
        bad_version[4..8].copy_from_slice(&99u32.to_le_bytes());
        let err = decode(&bad_version).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("version 99"), "{msg}");
        assert!(msg.contains("reads version 2"), "{msg}");

        // header cut mid-version
        let err = decode(&good[..6]).unwrap_err();
        assert!(format!("{err:#}").contains("truncated"), "{err:#}");

        // oversized name length is rejected before allocation — the
        // CRC is recomputed so the corruption reaches the parser
        let mut bad_name = good.clone();
        // first tensor's name_len sits right after magic+version+count
        bad_name[12..16].copy_from_slice(&u32::MAX.to_le_bytes());
        bad_name.truncate(bad_name.len() - 4);
        crate::util::codec::append_crc32(&mut bad_name);
        assert!(decode(&bad_name).is_err());

        // trailing garbage desynchronizes the CRC footer
        let mut trailing = good.clone();
        trailing.extend_from_slice(b"junk");
        let err = decode(&trailing).unwrap_err();
        assert!(format!("{err:#}").contains("CRC32 integrity check"), "{err:#}");
    }

    #[test]
    fn a_single_bit_flip_anywhere_in_the_body_is_caught() {
        let good = encode(&sample());
        // flip a bit in the middle of the tensor data, past every
        // header check the parser would have caught on its own
        let mut bad = good.clone();
        let mid = bad.len() / 2;
        bad[mid] ^= 0x40;
        let err = decode(&bad).unwrap_err();
        assert!(
            format!("{err:#}").contains("CRC32 integrity check"),
            "{err:#}"
        );
    }

    #[test]
    fn compatible_detects_mismatch() {
        let a = sample();
        let mut b = sample();
        b.tensors[0].shape = vec![2, 4];
        assert!(!compatible(&a, &b));
        let mut c = sample();
        c.tensors.pop();
        assert!(!compatible(&a, &c));
    }

    #[test]
    fn missing_file_errors() {
        assert!(load("/nonexistent/sfllm.ckpt").is_err());
    }
}
