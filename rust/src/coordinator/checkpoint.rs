//! Adapter checkpointing: save/restore LoRA adapter sets so a
//! fine-tuning run can resume (or ship its adapters for serving).
//!
//! Self-contained little-endian binary format (no serde in the offline
//! crate set):
//!
//! ```text
//! magic "SFLA" | u32 version | u32 n_tensors
//! per tensor: u32 name_len | name bytes | u32 ndim | u32 dims... | f32 data...
//! ```

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::model::lora::{AdapterSet, Tensor};

const MAGIC: &[u8; 4] = b"SFLA";
const VERSION: u32 = 1;

/// Write an adapter set to `path` (creating parent dirs).
pub fn save<P: AsRef<Path>>(set: &AdapterSet, path: P) -> Result<()> {
    if let Some(dir) = path.as_ref().parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(&path)?);
    f.write_all(MAGIC)?;
    f.write_all(&VERSION.to_le_bytes())?;
    f.write_all(&(set.tensors.len() as u32).to_le_bytes())?;
    for t in &set.tensors {
        let name = t.name.as_bytes();
        f.write_all(&(name.len() as u32).to_le_bytes())?;
        f.write_all(name)?;
        f.write_all(&(t.shape.len() as u32).to_le_bytes())?;
        for &d in &t.shape {
            f.write_all(&(d as u32).to_le_bytes())?;
        }
        for &v in &t.data {
            f.write_all(&v.to_le_bytes())?;
        }
    }
    f.flush()?;
    Ok(())
}

fn read_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

/// Load an adapter set from `path`.
pub fn load<P: AsRef<Path>>(path: P) -> Result<AdapterSet> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(&path)
            .with_context(|| format!("opening {}", path.as_ref().display()))?,
    );
    let mut magic = [0u8; 4];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("not an SfLLM adapter checkpoint");
    }
    let version = read_u32(&mut f)?;
    if version != VERSION {
        bail!("unsupported checkpoint version {version}");
    }
    let n = read_u32(&mut f)? as usize;
    let mut tensors = Vec::with_capacity(n);
    for _ in 0..n {
        let name_len = read_u32(&mut f)? as usize;
        if name_len > 4096 {
            bail!("corrupt checkpoint: name length {name_len}");
        }
        let mut name = vec![0u8; name_len];
        f.read_exact(&mut name)?;
        let ndim = read_u32(&mut f)? as usize;
        if ndim > 8 {
            bail!("corrupt checkpoint: ndim {ndim}");
        }
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(read_u32(&mut f)? as usize);
        }
        let numel: usize = shape.iter().product();
        let mut data = vec![0f32; numel];
        let mut buf = vec![0u8; numel * 4];
        f.read_exact(&mut buf)?;
        for (i, c) in buf.chunks_exact(4).enumerate() {
            data[i] = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
        }
        tensors.push(Tensor {
            name: String::from_utf8(name)?,
            shape,
            data,
        });
    }
    Ok(AdapterSet { tensors })
}

/// Check that a loaded checkpoint matches the expected signature
/// (same tensor names and shapes, in order).
pub fn compatible(a: &AdapterSet, b: &AdapterSet) -> bool {
    a.tensors.len() == b.tensors.len()
        && a.tensors
            .iter()
            .zip(&b.tensors)
            .all(|(x, y)| x.name == y.name && x.shape == y.shape)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> AdapterSet {
        AdapterSet {
            tensors: vec![
                Tensor {
                    name: "h0.aq_A".into(),
                    shape: vec![4, 2],
                    data: (0..8).map(|i| i as f32 * 0.5 - 1.0).collect(),
                },
                Tensor {
                    name: "h0.aq_B".into(),
                    shape: vec![2, 4],
                    data: vec![0.0; 8],
                },
            ],
        }
    }

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("sfllm_ckpt_{name}_{}.bin", std::process::id()))
    }

    #[test]
    fn round_trip_preserves_everything() {
        let path = tmp("rt");
        let set = sample();
        save(&set, &path).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back.tensors.len(), 2);
        for (a, b) in set.tensors.iter().zip(&back.tensors) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.shape, b.shape);
            assert_eq!(a.data, b.data);
        }
        assert!(compatible(&set, &back));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_garbage() {
        let path = tmp("bad");
        std::fs::write(&path, b"not a checkpoint at all").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn compatible_detects_mismatch() {
        let a = sample();
        let mut b = sample();
        b.tensors[0].shape = vec![2, 4];
        assert!(!compatible(&a, &b));
        let mut c = sample();
        c.tensors.pop();
        assert!(!compatible(&a, &c));
    }

    #[test]
    fn missing_file_errors() {
        assert!(load("/nonexistent/sfllm.ckpt").is_err());
    }
}
