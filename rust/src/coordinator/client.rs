//! Per-client worker: phases a (forward), b (upload), f (backward) of
//! Algorithm 1, plus the local SGD update (Eq. 6) and the federated
//! upload/download every I steps.
//!
//! Each client runs on its own OS thread and owns its data shard,
//! batcher and adapter copy. All tensor compute is submitted to the
//! device thread; all coordination is via channels — no shared mutable
//! state anywhere in the coordinator.

use std::sync::mpsc::{Receiver, Sender};

use anyhow::{anyhow, Result};

use super::device::DeviceHandle;
use super::optim::{OptKind, Optimizer};
use crate::data::Batcher;
use crate::model::lora::AdapterSet;

/// Client -> main server: one step's upload (phase b).
pub struct ActivationUpload {
    pub client: usize,
    pub s: Vec<f32>,
    pub tokens: Vec<i32>,
    pub mask: Vec<f32>,
}

/// Client -> federated server: adapter upload (aggregation phase a).
pub struct AdapterUpload {
    pub client: usize,
    pub adapters: AdapterSet,
}

/// Channels a client needs.
pub struct ClientChannels {
    /// Uploads to the main server.
    pub to_server: Sender<ActivationUpload>,
    /// Activation gradients back from the main server.
    pub from_server: Receiver<Vec<f32>>,
    /// Adapter uploads to the federated server.
    pub to_fed: Sender<AdapterUpload>,
    /// Aggregated global adapters back from the federated server.
    pub from_fed: Receiver<AdapterSet>,
}

/// Client configuration.
pub struct ClientConfig {
    pub id: usize,
    pub local_steps: usize, // I
    pub total_steps: usize, // E * I
    pub lr: f32,
    pub optimizer: OptKind,
}

/// Run one client to completion (called on the client's own thread).
pub fn run_client(
    cfg: ClientConfig,
    mut adapters: AdapterSet,
    mut batcher: Batcher,
    device: DeviceHandle,
    ch: ClientChannels,
) -> Result<AdapterSet> {
    let mut opt = Optimizer::new(cfg.optimizer, cfg.lr);
    for step in 1..=cfg.total_steps {
        let batch = batcher.next_batch();
        // phase a: local forward
        let s = device.client_forward(&adapters, &batch.tokens)?;
        // phase b: upload activations + labels
        ch.to_server
            .send(ActivationUpload {
                client: cfg.id,
                s,
                tokens: batch.tokens.clone(),
                mask: batch.mask.clone(),
            })
            .map_err(|_| anyhow!("main server hung up"))?;
        // phase e/f: receive ds, local backward, SGD (Eq. 6)
        let ds = ch
            .from_server
            .recv()
            .map_err(|_| anyhow!("main server dropped ds"))?;
        let grads = device.client_backward(&adapters, &batch.tokens, &ds)?;
        opt.step(&mut adapters, &grads)?;

        // aggregation phase every I steps (and at the end)
        if step % cfg.local_steps == 0 {
            ch.to_fed
                .send(AdapterUpload {
                    client: cfg.id,
                    adapters: adapters.clone(),
                })
                .map_err(|_| anyhow!("fed server hung up"))?;
            adapters = ch
                .from_fed
                .recv()
                .map_err(|_| anyhow!("fed server dropped broadcast"))?;
        }
    }
    Ok(adapters)
}
