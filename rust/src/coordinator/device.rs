//! Device-service thread: serializes all PJRT execution behind a
//! channel, because (a) PJRT handles are not `Send`, and (b) the CPU
//! device is a single shared executor in this testbed anyway.
//!
//! The model is *constructed inside* the service thread from a factory
//! closure, so non-`Send` runtimes never cross a thread boundary.

use std::sync::mpsc::{channel, Sender};
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use crate::model::lora::AdapterSet;
use crate::runtime::{SflModel, StepOutput};

/// Requests the service understands. Every request carries its own
/// response channel.
pub enum DeviceRequest {
    ClientForward {
        adapters: AdapterSet,
        tokens: Vec<i32>,
        resp: Sender<Result<Vec<f32>>>,
    },
    ServerStep {
        adapters: AdapterSet,
        s: Vec<f32>,
        tokens: Vec<i32>,
        mask: Vec<f32>,
        resp: Sender<Result<StepOutput>>,
    },
    ClientBackward {
        adapters: AdapterSet,
        tokens: Vec<i32>,
        ds: Vec<f32>,
        resp: Sender<Result<AdapterSet>>,
    },
    Shutdown,
}

/// Cloneable handle to the device thread.
#[derive(Clone)]
pub struct DeviceHandle {
    tx: Sender<DeviceRequest>,
}

impl DeviceHandle {
    pub fn client_forward(&self, adapters: &AdapterSet, tokens: &[i32]) -> Result<Vec<f32>> {
        let (tx, rx) = channel();
        self.tx
            .send(DeviceRequest::ClientForward {
                adapters: adapters.clone(),
                tokens: tokens.to_vec(),
                resp: tx,
            })
            .map_err(|_| anyhow!("device thread gone"))?;
        rx.recv().map_err(|_| anyhow!("device thread dropped response"))?
    }

    pub fn server_step(
        &self,
        adapters: &AdapterSet,
        s: &[f32],
        tokens: &[i32],
        mask: &[f32],
    ) -> Result<StepOutput> {
        let (tx, rx) = channel();
        self.tx
            .send(DeviceRequest::ServerStep {
                adapters: adapters.clone(),
                s: s.to_vec(),
                tokens: tokens.to_vec(),
                mask: mask.to_vec(),
                resp: tx,
            })
            .map_err(|_| anyhow!("device thread gone"))?;
        rx.recv().map_err(|_| anyhow!("device thread dropped response"))?
    }

    pub fn client_backward(
        &self,
        adapters: &AdapterSet,
        tokens: &[i32],
        ds: &[f32],
    ) -> Result<AdapterSet> {
        let (tx, rx) = channel();
        self.tx
            .send(DeviceRequest::ClientBackward {
                adapters: adapters.clone(),
                tokens: tokens.to_vec(),
                ds: ds.to_vec(),
                resp: tx,
            })
            .map_err(|_| anyhow!("device thread gone"))?;
        rx.recv().map_err(|_| anyhow!("device thread dropped response"))?
    }

    pub fn shutdown(&self) {
        let _ = self.tx.send(DeviceRequest::Shutdown);
    }
}

/// Spawn the service. `factory` runs on the service thread and builds
/// the model there; its init metadata (batch, seq, d_model, adapter
/// inits) is returned through a bootstrap channel.
pub struct DeviceInit {
    pub batch: usize,
    pub seq: usize,
    pub d_model: usize,
    pub vocab: usize,
    pub client_adapters: AdapterSet,
    pub server_adapters: AdapterSet,
}

pub fn spawn_device<F>(factory: F) -> Result<(DeviceHandle, DeviceInit, JoinHandle<()>)>
where
    F: FnOnce() -> Result<Box<dyn SflModel>> + Send + 'static,
{
    let (tx, rx) = channel::<DeviceRequest>();
    let (boot_tx, boot_rx) = channel::<Result<DeviceInit>>();
    let join = std::thread::Builder::new()
        .name("sfllm-device".into())
        .spawn(move || {
            let mut model = match factory() {
                Ok(m) => {
                    let _ = boot_tx.send(Ok(DeviceInit {
                        batch: m.batch(),
                        seq: m.seq(),
                        d_model: m.d_model(),
                        vocab: m.vocab(),
                        client_adapters: m.init_client_adapters(),
                        server_adapters: m.init_server_adapters(),
                    }));
                    m
                }
                Err(e) => {
                    let _ = boot_tx.send(Err(e));
                    return;
                }
            };
            while let Ok(req) = rx.recv() {
                match req {
                    DeviceRequest::ClientForward { adapters, tokens, resp } => {
                        let _ = resp.send(model.client_forward(&adapters, &tokens));
                    }
                    DeviceRequest::ServerStep { adapters, s, tokens, mask, resp } => {
                        let _ = resp.send(model.server_step(&adapters, &s, &tokens, &mask));
                    }
                    DeviceRequest::ClientBackward { adapters, tokens, ds, resp } => {
                        let _ = resp.send(model.client_backward(&adapters, &tokens, &ds));
                    }
                    DeviceRequest::Shutdown => break,
                }
            }
        })?;
    let init = boot_rx
        .recv()
        .map_err(|_| anyhow!("device thread died during init"))??;
    Ok((DeviceHandle { tx }, init, join))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::mock::MockModel;

    #[test]
    fn round_trip_through_service() {
        let (dev, init, join) = spawn_device(|| Ok(Box::new(MockModel::new(2, 4, 3)))).unwrap();
        assert_eq!(init.batch, 2);
        assert_eq!(init.d_model, 3);
        let tokens = vec![1i32; 2 * 4];
        let s = dev.client_forward(&init.client_adapters, &tokens).unwrap();
        assert_eq!(s.len(), 2 * 4 * 3);
        let out = dev
            .server_step(&init.server_adapters, &s, &tokens, &vec![1.0; 8])
            .unwrap();
        assert!(out.loss.is_finite());
        let grads = dev
            .client_backward(&init.client_adapters, &tokens, &out.ds)
            .unwrap();
        assert_eq!(grads.tensors.len(), init.client_adapters.tensors.len());
        dev.shutdown();
        join.join().unwrap();
    }

    #[test]
    fn factory_error_propagates() {
        let r = spawn_device(|| Err(anyhow!("boom")));
        assert!(r.is_err());
    }
}
