//! The allocator service (PR-8 tentpole): a long-running, observable,
//! checkpoint/resumable engine over the policy / evaluator / dynamic
//! stack.
//!
//! [`AllocatorService`] owns the process-lifetime caches (one
//! [`WorkloadCache`]; each run's delta `ColumnCache` lives in its
//! [`RoundCore`]) and consumes typed deterministic [`Event`]s — from an
//! in-memory slice ([`AllocatorService::run_events`]) or a replayable
//! JSONL file (`sfllm serve`). Per-round output streams into pluggable
//! [`MetricSink`]s as it is produced, not at the end of the run.
//!
//! **The anchor invariant** (property-tested in
//! `rust/tests/prop_service.rs` on every preset): a pure
//! `scenario_loaded` + `round_tick`* stream reproduces
//! [`crate::sim::RoundSimulator`] / [`crate::sim::PopulationSimulator`]
//! bit for bit — the tick body executes the *same* [`RoundCore`] /
//! [`DriftEnv`] statements the simulators execute (extracted into
//! [`crate::sim::engine`], not transcribed) — and *checkpoint at event
//! n, resume, finish* produces byte-identical metric streams to the
//! uninterrupted run.
//!
//! What makes resume bit-exact is a strict split of a run's state:
//!
//! * **Immutable substrate** (scenario template, policy, strategy,
//!   convergence model) — a pure function of the [`RunSpec`], rebuilt
//!   from the checkpoint's fingerprint exactly as `scenario_loaded`
//!   built it, *minus* the round-0 solve/selection (their results live
//!   in the mutable half).
//! * **Mutable trajectory** ([`RoundCore`] scalars + allocations, the
//!   drift environment's gains/compute/membership and RNG stream
//!   positions, population slots / invitation history / current view)
//!   — serialized bit for bit by [`checkpoint`].
//! * **Bit-transparent caches** ([`WorkloadCache`], `ColumnCache`) —
//!   never serialized; a resumed run recomputes what it would have had
//!   cached, with identical bits (the repo-wide cache contract).
//!
//! [`checkpoint`]: crate::service::checkpoint

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::delay::{ConvergenceModel, Scenario, WorkloadCache};
use crate::model::WorkloadTable;
use crate::net::topology::ClientSite;
use crate::opt::policy::{AllocationPolicy, PolicyRegistry};
use crate::opt::Objective;
use crate::service::checkpoint::{self, Header};
use crate::util::codec::{BinReader, BinWriter};
use crate::service::event::{Event, RunMode, RunSpec};
use crate::service::metrics::{MetricSink, RoundMetrics, RunSummary};
use crate::sim::dynamic::RoundCost;
use crate::sim::engine::{Adoption, DriftEnv, RoundCore, StepCtx};
use crate::sim::faults::{apply_to_scenario, FaultInjector};
use crate::sim::population::{comm_alloc, deadline_cut, Population, PopulationState};
use crate::sim::{ReOptStrategy, RoundRecord, ScenarioBuilder};
use crate::util::json::Json;

/// The per-run immutable substrate: everything `scenario_loaded`
/// derives from the [`RunSpec`] that never mutates afterwards. Rebuilt
/// (never serialized) on resume.
struct SessionBase {
    spec: RunSpec,
    conv: ConvergenceModel,
    objective: Objective,
    table: Arc<WorkloadTable>,
    strategy: ReOptStrategy,
    policy: Arc<dyn AllocationPolicy>,
    max_rounds: usize,
    /// The template's `dynamics.compute_jitter` (sparse-population view
    /// dirtiness — see [`crate::sim::PopulationSimulator::run`]).
    compute_jitter: f64,
    /// Candidate rank set (tier-2 feasibility repair re-solves).
    ranks: Vec<usize>,
    /// The run's fault injector — `None` for an empty [`RunSpec::faults`]
    /// spec, which keeps fault-free ticks statement-identical to PR-8.
    injector: Option<FaultInjector>,
}

/// The engine-specific mutable half of a run.
enum Engine {
    /// The K-client round-simulator loop over one drifting scenario.
    Dynamic {
        env: DriftEnv,
        /// `scn.k()` — the round simulator's `unique_participants`.
        k_n: usize,
    },
    /// The population loop: cohort selection, sparse observation,
    /// deadlines, incumbent rebasing.
    Population {
        pop: Population,
        state: PopulationState,
        /// Dense mode's evolved full-population environment.
        denv: Option<DriftEnv>,
        dense: bool,
        frozen_channel: bool,
        cur_cohort: Vec<usize>,
        cur_view: Scenario,
        online: Vec<bool>,
        /// A pending `cohort_selected` override for the next tick.
        cohort_override: Option<Vec<usize>>,
    },
}

/// One loaded run: substrate + engine + the shared round core.
struct Session {
    base: SessionBase,
    engine: Engine,
    core: RoundCore,
    /// One unit of convergence progress realized (ticks become no-ops).
    finished: bool,
    /// The run summary has been streamed (on convergence or shutdown).
    summary_emitted: bool,
}

/// The long-running allocator: consumes [`Event`]s, drives the shared
/// round engine, streams metrics, writes/loads checkpoints. See the
/// module docs for the determinism contract.
pub struct AllocatorService {
    cache: WorkloadCache,
    sinks: Vec<Box<dyn MetricSink>>,
    session: Option<Session>,
    /// Events processed so far (including the one being processed) —
    /// recorded in checkpoints so a resuming replay knows how far to
    /// skip.
    events_consumed: u64,
    /// Target of `checkpoint_requested` events that carry no path.
    default_checkpoint: Option<PathBuf>,
    /// Malformed event lines skipped by lenient replay (reported by the
    /// driver via [`AllocatorService::note_skipped_lines`]; surfaced in
    /// every [`RunSummary`]).
    lines_skipped: usize,
}

impl Default for AllocatorService {
    fn default() -> AllocatorService {
        AllocatorService::new()
    }
}

impl AllocatorService {
    pub fn new() -> AllocatorService {
        AllocatorService {
            cache: WorkloadCache::new(),
            sinks: Vec::new(),
            session: None,
            events_consumed: 0,
            default_checkpoint: None,
            lines_skipped: 0,
        }
    }

    /// Builder-style sink registration.
    pub fn with_sink(mut self, sink: Box<dyn MetricSink>) -> AllocatorService {
        self.sinks.push(sink);
        self
    }

    pub fn add_sink(&mut self, sink: Box<dyn MetricSink>) {
        self.sinks.push(sink);
    }

    /// Where path-less `checkpoint_requested` events write to.
    pub fn set_default_checkpoint<P: Into<PathBuf>>(&mut self, path: P) {
        self.default_checkpoint = Some(path.into());
    }

    pub fn events_consumed(&self) -> u64 {
        self.events_consumed
    }

    /// Record malformed event lines the driver skipped under lenient
    /// replay (see [`crate::service::parse_events_lenient`]).
    pub fn note_skipped_lines(&mut self, n: usize) {
        self.lines_skipped += n;
    }

    /// Whether the loaded run has realized one unit of convergence
    /// progress (no run loaded = false).
    pub fn is_finished(&self) -> bool {
        self.session.as_ref().map(|s| s.finished).unwrap_or(false)
    }

    /// Rounds realized since this process opened (or resumed) the run —
    /// what the simulators would have put in
    /// [`crate::sim::DynamicOutcome::rounds`]. A resumed service starts
    /// this empty: earlier rounds were already streamed to the sinks.
    pub fn rounds(&self) -> &[RoundRecord] {
        self.session.as_ref().map(|s| s.core.rounds.as_slice()).unwrap_or(&[])
    }

    /// The running summary of the loaded run (totals realized so far;
    /// `converged` says whether the run is finished).
    pub fn summary(&self) -> Option<RunSummary> {
        self.session.as_ref().map(|s| summary_of(s, self.lines_skipped))
    }

    /// Process one event. Errors are descriptive and leave the service
    /// in a well-defined state (the offending event counts as
    /// consumed).
    pub fn process(&mut self, event: &Event) -> Result<()> {
        self.events_consumed += 1;
        match event {
            Event::ScenarioLoaded(spec) => {
                if let Some(s) = &self.session {
                    if !s.finished {
                        bail!(
                            "scenario_loaded at round {} of an unfinished run: \
                             one event stream drives one run at a time",
                            s.core.round
                        );
                    }
                }
                let session = self.open_session(spec.clone())?;
                self.session = Some(session);
                Ok(())
            }
            Event::RoundTick => self.tick(),
            Event::ChannelDrift => {
                let session = self.require_session("channel_drift")?;
                match &mut session.engine {
                    Engine::Dynamic { env, .. } => {
                        if env.advance() {
                            session.core.env_dirty = true;
                        }
                        Ok(())
                    }
                    Engine::Population { denv, .. } => match denv.as_mut() {
                        Some(env) => {
                            if env.advance() {
                                session.core.env_dirty = true;
                            }
                            Ok(())
                        }
                        None => bail!(
                            "channel_drift is not available in sparse population mode: \
                             per-client channels evolve from counter-based streams keyed \
                             by round, so there is no extra step to take"
                        ),
                    },
                }
            }
            Event::CohortSelected { ids } => {
                let session = self.require_session("cohort_selected")?;
                match &mut session.engine {
                    Engine::Population { pop, cohort_override, .. } => {
                        if ids.len() != pop.cohort() {
                            bail!(
                                "cohort_selected: {} ids, the run's cohort size is {}",
                                ids.len(),
                                pop.cohort()
                            );
                        }
                        for &i in ids {
                            if i >= pop.size() {
                                bail!(
                                    "cohort_selected: client id {i} out of population \
                                     (size {})",
                                    pop.size()
                                );
                            }
                        }
                        *cohort_override = Some(ids.clone());
                        Ok(())
                    }
                    Engine::Dynamic { .. } => bail!(
                        "cohort_selected requires population mode (the dynamic engine \
                         invites every client every round)"
                    ),
                }
            }
            Event::ClientDropped { id } => self.set_member(*id, false),
            Event::ClientRejoined { id } => self.set_member(*id, true),
            Event::ReOptRequested => {
                let session = self.require_session("reopt_requested")?;
                session.core.force_reopt = true;
                Ok(())
            }
            Event::CheckpointRequested { path } => {
                let target = match path {
                    Some(p) => PathBuf::from(p),
                    None => match &self.default_checkpoint {
                        Some(p) => p.clone(),
                        None => bail!(
                            "checkpoint_requested carries no path and no default \
                             checkpoint path is configured (--checkpoint-out)"
                        ),
                    },
                };
                // flush first so a consumer of (metrics so far,
                // checkpoint) sees a consistent pair
                self.flush()?;
                self.write_checkpoint(&target)
            }
            Event::Shutdown => {
                if let Some(session) = &mut self.session {
                    if !session.summary_emitted {
                        session.summary_emitted = true;
                        let s = summary_of(session, self.lines_skipped);
                        for sink in &mut self.sinks {
                            sink.on_summary(&s)?;
                        }
                    }
                }
                self.flush()
            }
        }
    }

    /// Process a whole event stream in order.
    pub fn run_events(&mut self, events: &[Event]) -> Result<()> {
        for (i, e) in events.iter().enumerate() {
            self.process(e)
                .with_context(|| format!("event {} ({})", i + 1, e.kind()))?;
        }
        Ok(())
    }

    /// Flush every sink.
    pub fn flush(&mut self) -> Result<()> {
        for sink in &mut self.sinks {
            sink.flush()?;
        }
        Ok(())
    }

    fn require_session(&mut self, what: &str) -> Result<&mut Session> {
        match self.session.as_mut() {
            Some(s) => Ok(s),
            None => bail!("{what} before scenario_loaded"),
        }
    }

    fn set_member(&mut self, id: usize, online: bool) -> Result<()> {
        let what = if online { "client_rejoined" } else { "client_dropped" };
        let session = self.require_session(what)?;
        match &mut session.engine {
            Engine::Dynamic { env, .. } => env.set_member(id, online),
            Engine::Population { denv, .. } => match denv.as_mut() {
                Some(env) => env.set_member(id, online),
                None => bail!(
                    "{what} is not available in sparse population mode: availability \
                     evolves from each client's own seeded Markov chain (use \
                     cohort_selected to steer participation instead)"
                ),
            },
        }
    }

    // --- opening a run -------------------------------------------------

    fn open_session(&self, spec: RunSpec) -> Result<Session> {
        match spec.mode {
            RunMode::Dynamic => {
                let (base, env, k_n) = self.dynamic_parts(spec)?;
                let out0 = base
                    .policy
                    .solve_cached(&env.scn, &base.conv, &self.cache)
                    .context("service run: round-0 solve")?;
                let static_prediction = env.scn.total_delay(&out0.alloc, &base.conv);
                let core = RoundCore::new(out0.alloc, static_prediction, &base.conv);
                Ok(Session {
                    base,
                    engine: Engine::Dynamic { env, k_n },
                    core,
                    finished: false,
                    summary_emitted: false,
                })
            }
            RunMode::Population => {
                let (base, pop, dense) = self.population_parts(spec)?;
                let frozen_channel = pop.channel_frozen();
                let mut state = PopulationState::new(pop.size());
                let mut denv = if dense {
                    Some(DriftEnv::new(pop.scenario()?))
                } else {
                    None
                };
                let cur_cohort = pop.select(&mut state, 0);
                let (cur_view, online) = pop.round_view(&mut state, &mut denv, &cur_cohort, 0);
                let out0 = base
                    .policy
                    .solve_cached(&cur_view, &base.conv, &self.cache)
                    .context("service run: round-0 solve")?;
                let static_prediction = cur_view.total_delay(&out0.alloc, &base.conv);
                let core = RoundCore::new(out0.alloc, static_prediction, &base.conv);
                Ok(Session {
                    base,
                    engine: Engine::Population {
                        pop,
                        state,
                        denv,
                        dense,
                        frozen_channel,
                        cur_cohort,
                        cur_view,
                        online,
                        cohort_override: None,
                    },
                    core,
                    finished: false,
                    summary_emitted: false,
                })
            }
        }
    }

    /// The dynamic-mode substrate plus a *pristine* (round-0) drift
    /// environment — shared by `scenario_loaded` and resume, which is
    /// what guarantees a resumed substrate is the one the checkpointed
    /// run was built on.
    fn dynamic_parts(&self, spec: RunSpec) -> Result<(SessionBase, DriftEnv, usize)> {
        let cfg = spec.build_config()?;
        let scn = ScenarioBuilder::from_config(cfg.clone())
            .build()
            .with_context(|| format!("service run: scenario for preset '{}'", spec.preset))?;
        let conv = spec.conv_model();
        let objective = Objective::from_config(&scn.objective)?;
        let table = self.cache.table_for(&scn.profile, &cfg.train.ranks);
        let policy = PolicyRegistry::paper_suite(&cfg.train.ranks, cfg.system.seed, spec.draws)
            .get(&spec.policy)?;
        let strategy = ReOptStrategy::parse(&spec.strategy)?;
        let max_rounds = scn.dynamics.max_rounds;
        let compute_jitter = scn.dynamics.compute_jitter;
        let k_n = scn.k();
        let env = DriftEnv::new(scn);
        let injector = injector_for(&spec)?;
        Ok((
            SessionBase {
                spec,
                conv,
                objective,
                table,
                strategy,
                policy,
                max_rounds,
                compute_jitter,
                ranks: cfg.train.ranks.clone(),
                injector,
            },
            env,
            k_n,
        ))
    }

    /// The population-mode substrate (see [`Self::dynamic_parts`]).
    fn population_parts(&self, spec: RunSpec) -> Result<(SessionBase, Population, bool)> {
        let cfg = spec.build_config()?;
        let pop = Population::new(&cfg)?;
        let conv = spec.conv_model();
        let objective = Objective::from_config(&pop.template().objective)?;
        let table = self.cache.table_for(&pop.template().profile, &cfg.train.ranks);
        let policy = PolicyRegistry::paper_suite(&cfg.train.ranks, cfg.system.seed, spec.draws)
            .get(&spec.policy)?;
        let strategy = ReOptStrategy::parse(&spec.strategy)?;
        let max_rounds = pop.template().dynamics.max_rounds;
        let compute_jitter = pop.template().dynamics.compute_jitter;
        let dense = pop.cohort() >= pop.size();
        let injector = injector_for(&spec)?;
        Ok((
            SessionBase {
                spec,
                conv,
                objective,
                table,
                strategy,
                policy,
                max_rounds,
                compute_jitter,
                ranks: cfg.train.ranks.clone(),
                injector,
            },
            pop,
            dense,
        ))
    }

    // --- the tick ------------------------------------------------------

    /// One round: drift / select / re-opt / realize / stream — the
    /// simulators' loop bodies, statement for statement (see
    /// [`crate::sim::RoundSimulator::run`] and
    /// [`crate::sim::PopulationSimulator::run`]). Ticking a finished
    /// run is a no-op, so replaying an event file with trailing ticks
    /// past convergence stays valid.
    fn tick(&mut self) -> Result<()> {
        let session = match self.session.as_mut() {
            Some(s) => s,
            None => bail!("round_tick before scenario_loaded"),
        };
        if session.finished {
            return Ok(());
        }
        let ctx = StepCtx {
            conv: &session.base.conv,
            cache: &self.cache,
            table: &session.base.table,
            objective: &session.base.objective,
            strategy: session.base.strategy,
            ranks: &session.base.ranks,
            label: "service",
        };
        session.core.check_cap(session.base.max_rounds, &ctx)?;
        let mut resolved = session.core.round == 0;
        let mut cost_round: Option<RoundCost> = None;
        let mut dropped = 0usize;
        let mut faults = 0usize;
        let mut repair_tier = 0u8;
        let mut shed: Vec<usize> = Vec::new();
        let mut adoption = Adoption::Fresh; // round 0 adopts its own solve
        let record;
        match &mut session.engine {
            Engine::Dynamic { env, k_n } => {
                let mut undo = None;
                if session.core.round > 0 {
                    if env.advance() {
                        session.core.env_dirty = true;
                    }
                    if let Some(inj) = &session.base.injector {
                        let ov = inj.overlay(session.core.round, *k_n);
                        if !ov.is_empty() {
                            faults = ov.count();
                            session.core.faults_injected += faults;
                            undo = Some(env.apply_overlay(&ov));
                            session.core.env_dirty = true;
                        }
                    }
                    let re = session.core.maybe_reopt(
                        &ctx,
                        session.base.policy.as_ref(),
                        &env.scn,
                        &env.active,
                    )?;
                    resolved = re.resolved;
                    cost_round = re.cost;
                    adoption = re.adopted;
                    repair_tier = re.repair_tier;
                    shed = re.shed;
                }
                if shed.is_empty() {
                    record = session.core.realize(
                        &ctx,
                        &env.scn,
                        &env.active,
                        cost_round,
                        resolved,
                        *k_n,
                        0,
                        faults,
                        repair_tier,
                    );
                } else {
                    // tier-3 repair: shed clients sit the round out
                    // (their allocation rows are empty — scoring them
                    // active would be infinite)
                    let mut eff = env.active.clone();
                    for &k in &shed {
                        if let Some(a) = eff.get_mut(k) {
                            *a = false;
                        }
                    }
                    if !eff.iter().any(|&a| a) {
                        // never realize an empty federation
                        for (k, a) in eff.iter_mut().enumerate() {
                            *a = !shed.contains(&k);
                        }
                    }
                    record = session.core.realize(
                        &ctx,
                        &env.scn,
                        &eff,
                        cost_round,
                        resolved,
                        *k_n,
                        0,
                        faults,
                        repair_tier,
                    );
                }
                if let Some(u) = undo {
                    env.undo_overlay(u);
                    session.core.env_dirty = true;
                }
            }
            Engine::Population {
                pop,
                state,
                denv,
                dense: _,
                frozen_channel,
                cur_cohort,
                cur_view,
                online,
                cohort_override,
            } => {
                if session.core.round > 0 {
                    // --- evolve the environment and lower the new cohort
                    if let Some(env) = denv.as_mut() {
                        if env.advance() {
                            session.core.env_dirty = true;
                        }
                    }
                    let round = session.core.round;
                    let cohort = match cohort_override.take() {
                        Some(ids) => {
                            // the override performs select()'s
                            // invitation bookkeeping; the round's
                            // selection draw is counter-based and
                            // simply left unconsumed
                            pop.mark_invited(state, &ids, round);
                            ids
                        }
                        None => pop.select(state, round),
                    };
                    let cohort_changed = cohort != *cur_cohort;
                    let (view, on) = pop.round_view(state, denv, &cohort, round);
                    *cur_view = view;
                    *online = on;
                    if denv.is_none() {
                        // a sparse view is rebuilt from fresh
                        // observations: it drifts whenever the
                        // membership, the channel, or the compute can
                        // have moved
                        session.core.env_dirty |= cohort_changed
                            || !*frozen_channel
                            || session.base.compute_jitter > 0.0;
                    }
                    *cur_cohort = cohort;
                    if cohort_changed {
                        // rebasing happens on the clean view: it is
                        // membership bookkeeping, not a fault reaction
                        let rebased = comm_alloc(
                            cur_view,
                            session.core.alloc.l_c,
                            session.core.alloc.rank,
                        )?;
                        session.core.rebase_incumbent(rebased);
                    }
                    if let Some(inj) = &session.base.injector {
                        let ov = inj.overlay(session.core.round, cur_view.k());
                        if !ov.is_empty() {
                            faults = ov.count();
                            session.core.faults_injected += faults;
                            apply_to_scenario(cur_view, &ov);
                            if !ov.crashed.is_empty() {
                                let prev = online.clone();
                                for &k in &ov.crashed {
                                    if let Some(a) = online.get_mut(k) {
                                        *a = false;
                                    }
                                }
                                if !online.iter().any(|&a| a) {
                                    // never simulate an empty federation
                                    *online = prev;
                                }
                            }
                            session.core.env_dirty = true;
                        }
                    }
                    let re = session.core.maybe_reopt(
                        &ctx,
                        session.base.policy.as_ref(),
                        cur_view,
                        online,
                    )?;
                    resolved = re.resolved;
                    cost_round = re.cost;
                    adoption = re.adopted;
                    repair_tier = re.repair_tier;
                    shed = re.shed;
                }

                if !shed.is_empty() {
                    // tier-3 repair: shed clients sit the round out
                    // (their allocation rows are empty — scoring them
                    // active, or ranking them for the deadline, would be
                    // infinite)
                    for &k in &shed {
                        if let Some(a) = online.get_mut(k) {
                            *a = false;
                        }
                    }
                    if !online.iter().any(|&a| a) {
                        // never realize an empty federation
                        for (k, a) in online.iter_mut().enumerate() {
                            *a = !shed.contains(&k);
                        }
                    }
                }

                // --- straggler deadline: cut the slowest ⌊x·online⌋
                // cohort members by realized client-side phase delay
                let cut = deadline_cut(pop.deadline_drop(), cur_view, &session.core.alloc, online);
                if cut > 0 {
                    dropped = cut;
                    session.core.deadline_drops += cut;
                    // any cost computed above used the pre-deadline mask
                    cost_round = None;
                }

                record = session.core.realize(
                    &ctx,
                    cur_view,
                    online,
                    cost_round,
                    resolved,
                    cur_cohort.len(),
                    dropped,
                    faults,
                    repair_tier,
                );
                if faults > 0 {
                    // the checkpointed view carries this round's faults,
                    // but the drift memo must not serve its solve to the
                    // next, clean round
                    session.core.env_dirty = true;
                }
            }
        }
        let summary = if session.core.done() {
            session.finished = true;
            session.summary_emitted = true;
            Some(summary_of(session, self.lines_skipped))
        } else {
            None
        };
        let metrics = RoundMetrics { record, adoption };
        for sink in &mut self.sinks {
            sink.on_round(&metrics)?;
        }
        if let Some(s) = summary {
            for sink in &mut self.sinks {
                sink.on_summary(&s)?;
            }
        }
        Ok(())
    }

    // --- checkpoint / resume -------------------------------------------

    /// Serialize the loaded run as a versioned `SFCK` checkpoint (see
    /// [`crate::service::checkpoint`] for what is and is not inside).
    pub fn checkpoint_bytes(&self) -> Result<Vec<u8>> {
        let session = match &self.session {
            Some(s) => s,
            None => bail!("nothing to checkpoint: no run loaded"),
        };
        let mut w = BinWriter::with_header(checkpoint::MAGIC, checkpoint::VERSION);
        checkpoint::write_header(
            &mut w,
            &Header {
                fingerprint: session.base.spec.fingerprint(),
                events_consumed: self.events_consumed,
                finished: session.finished,
                mode: session.base.spec.mode,
            },
        );
        checkpoint::write_core(&mut w, &session.core);
        match &session.engine {
            Engine::Dynamic { env, .. } => checkpoint::write_env(&mut w, env),
            Engine::Population {
                state,
                denv,
                dense,
                cur_cohort,
                cur_view,
                online,
                cohort_override,
                ..
            } => {
                w.bool(*dense);
                if let Some(env) = denv {
                    checkpoint::write_env(&mut w, env);
                }
                state.checkpoint_write(&mut w);
                w.usize_slice(cur_cohort);
                match cohort_override {
                    Some(ids) => {
                        w.bool(true);
                        w.usize_slice(ids);
                    }
                    None => w.bool(false),
                }
                // the current view splice: the cohort's sites, compute,
                // and gains (everything view_from changes on the
                // template), plus the availability mask
                let d_main: Vec<f64> = cur_view.topo.clients.iter().map(|c| c.d_main_m).collect();
                let d_fed: Vec<f64> = cur_view.topo.clients.iter().map(|c| c.d_fed_m).collect();
                let f: Vec<f64> = cur_view.topo.clients.iter().map(|c| c.f_cycles).collect();
                w.f64_slice(&d_main);
                w.f64_slice(&d_fed);
                w.f64_slice(&f);
                w.f64_slice(&cur_view.main_link.client_gain);
                w.f64_slice(&cur_view.fed_link.client_gain);
                w.bool_slice(online);
            }
        }
        Ok(checkpoint::seal(w))
    }

    /// Write [`Self::checkpoint_bytes`] to `path` (creating parents).
    ///
    /// An existing file is rotated to `<path>.prev` first, so a write
    /// that never completes — or an artifact found corrupt at resume
    /// time (the CRC32 footer catches it) — always leaves a last-good
    /// checkpoint behind; `sfllm serve --resume` falls back to it
    /// automatically.
    pub fn write_checkpoint<P: AsRef<Path>>(&self, path: P) -> Result<()> {
        let bytes = self.checkpoint_bytes()?;
        crate::util::csv::ensure_parent_dir(&path)?;
        let path = path.as_ref();
        if path.exists() {
            let mut prev = path.as_os_str().to_owned();
            prev.push(".prev");
            std::fs::rename(path, &prev)
                .with_context(|| format!("rotating {} to its .prev fallback", path.display()))?;
        }
        std::fs::write(path, bytes)
            .with_context(|| format!("writing checkpoint {}", path.display()))
    }

    /// Load a checkpoint into an idle service: rebuild the immutable
    /// substrate from the fingerprint, apply the mutable trajectory,
    /// position `events_consumed`. The caller resumes the event stream
    /// from there (skipping the already-consumed prefix); the
    /// continuation is bit-identical to the uninterrupted run.
    pub fn restore(&mut self, bytes: &[u8]) -> Result<()> {
        if self.session.is_some() {
            bail!("restore into a service that already has a run loaded");
        }
        let payload = checkpoint::open(bytes)?;
        let mut r = BinReader::new(payload);
        let header = checkpoint::read_header(&mut r)?;
        let spec_json =
            Json::parse(&header.fingerprint).context("service checkpoint: run fingerprint")?;
        let spec =
            RunSpec::from_json(&spec_json).context("service checkpoint: run fingerprint")?;
        if spec.mode != header.mode {
            bail!("corrupt service checkpoint: mode tag disagrees with the run fingerprint");
        }
        let core = checkpoint::read_core(&mut r)?;
        let session = match spec.mode {
            RunMode::Dynamic => {
                let (base, mut env, k_n) = self.dynamic_parts(spec)?;
                checkpoint::apply_env(&mut r, &mut env)?;
                Session {
                    base,
                    engine: Engine::Dynamic { env, k_n },
                    core,
                    finished: header.finished,
                    summary_emitted: header.finished,
                }
            }
            RunMode::Population => {
                let (base, pop, dense) = self.population_parts(spec)?;
                let frozen_channel = pop.channel_frozen();
                let dense_flag = r.bool("dense mode flag")?;
                if dense_flag != dense {
                    bail!(
                        "corrupt service checkpoint: dense flag {dense_flag} disagrees \
                         with the rebuilt population (cohort {} of {})",
                        pop.cohort(),
                        pop.size()
                    );
                }
                let denv = if dense {
                    let mut env = DriftEnv::new(pop.scenario()?);
                    checkpoint::apply_env(&mut r, &mut env)?;
                    Some(env)
                } else {
                    None
                };
                let state = PopulationState::checkpoint_read(&mut r, pop.size())?;
                let cur_cohort = r.usize_slice("current cohort")?;
                for &i in &cur_cohort {
                    if i >= pop.size() {
                        bail!(
                            "corrupt service checkpoint: cohort id {i} out of population \
                             (size {})",
                            pop.size()
                        );
                    }
                }
                let cohort_override = if r.bool("cohort override flag")? {
                    Some(r.usize_slice("cohort override")?)
                } else {
                    None
                };
                let d_main = r.f64_slice("view d_main")?;
                let d_fed = r.f64_slice("view d_fed")?;
                let f_cycles = r.f64_slice("view f_cycles")?;
                let gain_main = r.f64_slice("view main gains")?;
                let gain_fed = r.f64_slice("view fed gains")?;
                let online = r.bool_slice("view online mask")?;
                let k = d_main.len();
                for (what, len) in [
                    ("d_fed", d_fed.len()),
                    ("f_cycles", f_cycles.len()),
                    ("main gains", gain_main.len()),
                    ("fed gains", gain_fed.len()),
                    ("online mask", online.len()),
                ] {
                    if len != k {
                        bail!(
                            "corrupt service checkpoint: view {what} holds {len} clients, \
                             d_main holds {k}"
                        );
                    }
                }
                let mut cur_view = pop.template().clone();
                cur_view.topo.clients = (0..k)
                    .map(|i| ClientSite {
                        d_main_m: d_main[i],
                        d_fed_m: d_fed[i],
                        f_cycles: f_cycles[i],
                    })
                    .collect();
                cur_view.main_link.client_gain = gain_main;
                cur_view.fed_link.client_gain = gain_fed;
                Session {
                    base,
                    engine: Engine::Population {
                        pop,
                        state,
                        denv,
                        dense,
                        frozen_channel,
                        cur_cohort,
                        cur_view,
                        online,
                        cohort_override,
                    },
                    core,
                    finished: header.finished,
                    summary_emitted: header.finished,
                }
            }
        };
        r.expect_end("service checkpoint")?;
        self.session = Some(session);
        self.events_consumed = header.events_consumed;
        Ok(())
    }
}

/// Build the per-run fault injector from the spec's `faults` string.
/// An empty plan yields `None`, which keeps the tick body free of any
/// extra statements — the fault-free bit-transparency contract.
fn injector_for(spec: &RunSpec) -> Result<Option<FaultInjector>> {
    let plan = spec.fault_plan()?;
    Ok(if plan.is_empty() {
        None
    } else {
        Some(FaultInjector::new(plan))
    })
}

/// The running summary of a session (the end-of-run totals when the
/// session has converged). `lines_skipped` is the service's lenient
/// replay counter — stream health, not run state, so it rides beside
/// the session rather than inside it.
fn summary_of(session: &Session, lines_skipped: usize) -> RunSummary {
    let (realized_delay, realized_energy) = session.core.totals();
    let unique_participants = match &session.engine {
        Engine::Dynamic { k_n, .. } => *k_n,
        Engine::Population { pop, state, dense, .. } => {
            if *dense {
                pop.size()
            } else {
                state.materialized()
            }
        }
    };
    RunSummary {
        rounds: session.core.round,
        realized_delay,
        realized_energy,
        static_prediction: session.core.static_prediction,
        resolves: session.core.resolves,
        fresh_solves: session.core.fresh_solves,
        deadline_drops: session.core.deadline_drops,
        unique_participants,
        final_l_c: session.core.alloc.l_c,
        final_rank: session.core.alloc.rank,
        faults_injected: session.core.faults_injected,
        repair_max: session.core.repair_max,
        retries: 0,
        lines_skipped,
        converged: session.core.done(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::service::metrics::MemorySink;

    fn tiny_spec() -> RunSpec {
        let mut spec = RunSpec::preset("mobile_edge");
        spec.model = Some("tiny".to_string());
        spec.clients = Some(3);
        spec.seq = Some(64);
        spec.ranks = Some(vec![1, 4]);
        spec.conv = Some([4.0, 1.0, 0.85]);
        spec
    }

    #[test]
    fn events_out_of_order_fail_descriptively() {
        let mut svc = AllocatorService::new();
        let err = format!("{:#}", svc.process(&Event::RoundTick).unwrap_err());
        assert!(err.contains("before scenario_loaded"), "{err}");
        let err = format!("{:#}", svc.process(&Event::ReOptRequested).unwrap_err());
        assert!(err.contains("before scenario_loaded"), "{err}");
        let err = format!("{:#}", svc.checkpoint_bytes().unwrap_err());
        assert!(err.contains("nothing to checkpoint"), "{err}");

        svc.process(&Event::ScenarioLoaded(tiny_spec())).unwrap();
        // dynamic mode rejects population-only events
        let err = format!(
            "{:#}",
            svc.process(&Event::CohortSelected { ids: vec![0, 1] }).unwrap_err()
        );
        assert!(err.contains("population mode"), "{err}");
        // a second load mid-run is refused
        let err = format!(
            "{:#}",
            svc.process(&Event::ScenarioLoaded(tiny_spec())).unwrap_err()
        );
        assert!(err.contains("unfinished run"), "{err}");
    }

    #[test]
    fn a_run_streams_rounds_then_exactly_one_summary() {
        let mut svc = AllocatorService::new().with_sink(Box::new(MemorySink::new(1024)));
        svc.process(&Event::ScenarioLoaded(tiny_spec())).unwrap();
        for _ in 0..64 {
            svc.process(&Event::RoundTick).unwrap();
            if svc.is_finished() {
                break;
            }
        }
        assert!(svc.is_finished(), "tiny run must converge within 64 rounds");
        let n = svc.rounds().len();
        assert!(n > 0);
        let s = svc.summary().unwrap();
        assert!(s.converged);
        assert_eq!(s.rounds, n);
        // ticking past convergence is a no-op
        svc.process(&Event::RoundTick).unwrap();
        assert_eq!(svc.rounds().len(), n);
        // shutdown does not re-emit the summary
        svc.process(&Event::Shutdown).unwrap();
        // the realized totals are the weighted per-round sums (the
        // run-length compressed accumulator agrees with the naive sum
        // to fp error)
        let naive: f64 = svc.rounds().iter().map(|r| r.weight * r.delay).sum();
        assert!(s.realized_delay > 0.0);
        assert!((s.realized_delay - naive).abs() <= 1e-9 * naive.max(1.0), "{naive}");
    }

    #[test]
    fn forced_reopt_marks_the_next_round_resolved() {
        let mut svc = AllocatorService::new();
        svc.process(&Event::ScenarioLoaded(tiny_spec())).unwrap();
        svc.process(&Event::RoundTick).unwrap(); // round 0
        svc.process(&Event::RoundTick).unwrap(); // one_shot: held
        assert!(!svc.rounds()[1].resolved);
        svc.process(&Event::ReOptRequested).unwrap();
        svc.process(&Event::RoundTick).unwrap();
        assert!(svc.rounds()[2].resolved, "forced re-opt must resolve");
        svc.process(&Event::RoundTick).unwrap();
        assert!(!svc.rounds()[3].resolved, "the force is one-shot");
    }

    #[test]
    fn restore_refuses_bad_inputs() {
        let mut svc = AllocatorService::new();
        svc.process(&Event::ScenarioLoaded(tiny_spec())).unwrap();
        svc.process(&Event::RoundTick).unwrap();
        let bytes = svc.checkpoint_bytes().unwrap();

        // restore over a loaded run
        let err = format!("{:#}", svc.restore(&bytes).unwrap_err());
        assert!(err.contains("already has a run loaded"), "{err}");

        // truncated payload
        let mut fresh = AllocatorService::new();
        let err = format!(
            "{:#}",
            fresh.restore(&bytes[..bytes.len() - 3]).unwrap_err()
        );
        assert!(!err.is_empty());
        assert!(fresh.session.is_none(), "a failed restore must not half-load");

        // a single payload bit flip is caught by the CRC32 footer with
        // a descriptive error, never a panic or a silent misparse
        let mut flipped = bytes.clone();
        let mid = flipped.len() / 2;
        flipped[mid] ^= 0x01;
        let mut fresh = AllocatorService::new();
        let err = format!("{:#}", fresh.restore(&flipped).unwrap_err());
        assert!(err.contains("CRC32 integrity check"), "{err}");
        assert!(fresh.session.is_none());
    }
}
