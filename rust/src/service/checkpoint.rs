//! The versioned allocator-service checkpoint (`SFCK`): everything
//! mutable in a half-finished run, serialized bit-exactly so that
//! *checkpoint at round j, resume, finish* produces byte-identical
//! metric streams to the uninterrupted run (property-tested in
//! `rust/tests/prop_service.rs` on every preset).
//!
//! The layout splits a run into the two halves the determinism
//! contract suggests:
//!
//! * **Immutable substrate** — scenario, policy, strategy, convergence
//!   model. Not serialized: the checkpoint stores the run's
//!   [`RunSpec`] fingerprint and the substrate is rebuilt from the
//!   spec, exactly as `scenario_loaded` built it. A resume against a
//!   different spec is a different run and is refused by fingerprint
//!   comparison.
//! * **Mutable trajectory** — the [`RoundCore`] scalars and
//!   allocations, the [`DriftEnv`] gains/compute/membership and its
//!   three RNG stream positions, and (population mode) the lazily
//!   materialized client slots, invitation history, current cohort and
//!   view splice. Serialized bit for bit ([`crate::util::codec`]).
//!
//! Deliberately *not* serialized: [`crate::delay::WorkloadCache`] and
//! [`crate::delay::ColumnCache`] (bit-transparent caches, rebuilt cold
//! — resumed runs recompute what they would have had cached, with
//! identical bits), and the per-round record vector (records already
//! streamed live in the metric sinks, not the checkpoint).
//!
//! [`RunSpec`]: crate::service::event::RunSpec

use anyhow::{bail, Result};

use crate::delay::Allocation;
use crate::util::codec::{self, BinReader, BinWriter};
use crate::service::event::RunMode;
use crate::sim::engine::{DriftEnv, RoundCore};

pub(crate) const MAGIC: &[u8; 4] = b"SFCK";
/// v2 (PR-10): appends the fault counters (`faults_injected`,
/// `repair_max`) to the core block and seals the file with a CRC32
/// footer. v1 files predate both and are refused by version.
pub(crate) const VERSION: u32 = 2;
/// Fingerprints are canonical [`RunSpec`] JSON — small; the limit only
/// guards against reading a corrupt length as an allocation size.
const MAX_FINGERPRINT: usize = 1 << 16;

/// The checkpoint header: enough to rebuild the immutable substrate
/// (via the fingerprint) and to position the event stream (via
/// `events_consumed`) before the payload is applied.
#[derive(Clone, Debug)]
pub struct Header {
    /// Canonical spec JSON ([`crate::service::event::RunSpec::fingerprint`]).
    pub fingerprint: String,
    /// Events processed when the checkpoint was written (including the
    /// opening `scenario_loaded`); a resuming replay skips this many.
    pub events_consumed: u64,
    /// Whether the run had already converged and streamed its summary.
    pub finished: bool,
    pub mode: RunMode,
}

pub(crate) fn write_header(w: &mut BinWriter, h: &Header) {
    w.str(&h.fingerprint);
    w.u64(h.events_consumed);
    w.bool(h.finished);
    w.u8(match h.mode {
        RunMode::Dynamic => 0,
        RunMode::Population => 1,
    });
}

fn require_version(version: u32) -> Result<()> {
    if version != VERSION {
        bail!(
            "unsupported service checkpoint version {version} \
             (this build reads version {VERSION})"
        );
    }
    Ok(())
}

pub(crate) fn read_header(r: &mut BinReader) -> Result<Header> {
    r.expect_magic(MAGIC, "SfLLM service checkpoint")?;
    require_version(r.u32("service checkpoint version")?)?;
    let fingerprint = r.str(MAX_FINGERPRINT, "run fingerprint")?;
    let events_consumed = r.u64("events consumed")?;
    let finished = r.bool("finished flag")?;
    let mode = match r.u8("run mode")? {
        0 => RunMode::Dynamic,
        1 => RunMode::Population,
        m => bail!("corrupt service checkpoint: unknown run mode byte {m}"),
    };
    Ok(Header {
        fingerprint,
        events_consumed,
        finished,
        mode,
    })
}

/// Seal a finished checkpoint buffer: append the CRC32 integrity
/// footer (PR-10). The counterpart of [`open`].
pub(crate) fn seal(w: BinWriter) -> Vec<u8> {
    let mut bytes = w.into_bytes();
    codec::append_crc32(&mut bytes);
    bytes
}

/// Validate a sealed checkpoint and return its payload (footer
/// stripped). Magic and version are checked *before* the CRC so a
/// wrong or outdated file fails with "not a …" / "unsupported version",
/// not a misleading integrity error; then every payload byte is
/// covered by the CRC32 check.
pub(crate) fn open(bytes: &[u8]) -> Result<&[u8]> {
    let mut peek = BinReader::new(bytes);
    peek.expect_magic(MAGIC, "SfLLM service checkpoint")?;
    require_version(peek.u32("service checkpoint version")?)?;
    codec::check_crc32(bytes, "service checkpoint")
}

/// Peek a sealed checkpoint's header without touching the payload (the
/// CLI uses this to rebuild the substrate before applying the rest).
pub fn peek_header(bytes: &[u8]) -> Result<Header> {
    read_header(&mut BinReader::new(open(bytes)?))
}

pub(crate) fn write_alloc(w: &mut BinWriter, a: &Allocation) {
    w.usize(a.l_c);
    w.usize(a.rank);
    w.usize(a.assign_main.len());
    for row in &a.assign_main {
        w.usize_slice(row);
    }
    w.usize(a.assign_fed.len());
    for row in &a.assign_fed {
        w.usize_slice(row);
    }
    w.f64_slice(&a.psd_main);
    w.f64_slice(&a.psd_fed);
}

pub(crate) fn read_alloc(r: &mut BinReader) -> Result<Allocation> {
    let l_c = r.usize("allocation l_c")?;
    let rank = r.usize("allocation rank")?;
    let read_rows = |r: &mut BinReader, what: &str| -> Result<Vec<Vec<usize>>> {
        let n = r.usize(what)?;
        // each row costs at least its 8-byte length prefix
        if n.saturating_mul(8) > r.remaining() {
            bail!(
                "corrupt service checkpoint: {what} claims {n} rows, only {} bytes remain",
                r.remaining()
            );
        }
        (0..n).map(|_| r.usize_slice(what)).collect()
    };
    let assign_main = read_rows(r, "allocation assign_main")?;
    let assign_fed = read_rows(r, "allocation assign_fed")?;
    let psd_main = r.f64_slice("allocation psd_main")?;
    let psd_fed = r.f64_slice("allocation psd_fed")?;
    Ok(Allocation {
        l_c,
        rank,
        assign_main,
        assign_fed,
        psd_main,
        psd_fed,
    })
}

pub(crate) fn write_core(w: &mut BinWriter, c: &RoundCore) {
    write_alloc(w, &c.alloc0);
    write_alloc(w, &c.alloc);
    write_alloc(w, &c.memo_fresh_alloc);
    w.bool(c.incumbent_is_initial);
    w.bool(c.initial_retired);
    w.bool(c.env_dirty);
    w.bool(c.force_reopt);
    w.usize(c.fresh_solves);
    w.usize(c.resolves);
    w.usize(c.deadline_drops);
    w.usize(c.round);
    w.f64(c.remaining);
    w.f64(c.solved_delay);
    w.f64(c.static_prediction);
    w.f64(c.realized);
    w.f64(c.seg_weight);
    w.f64(c.seg_delay);
    w.f64(c.realized_e);
    w.f64(c.seg_weight_e);
    w.f64(c.seg_energy);
    w.usize(c.faults_injected);
    w.u8(c.repair_max);
}

/// Restore a [`RoundCore`]. The column cache restarts cold
/// (bit-transparent) and the record vector restarts empty (records
/// already streamed live in the sinks).
pub(crate) fn read_core(r: &mut BinReader) -> Result<RoundCore> {
    let alloc0 = read_alloc(r)?;
    let alloc = read_alloc(r)?;
    let memo_fresh_alloc = read_alloc(r)?;
    Ok(RoundCore {
        alloc0,
        alloc,
        memo_fresh_alloc,
        incumbent_is_initial: r.bool("core incumbent_is_initial")?,
        initial_retired: r.bool("core initial_retired")?,
        env_dirty: r.bool("core env_dirty")?,
        force_reopt: r.bool("core force_reopt")?,
        fresh_solves: r.usize("core fresh_solves")?,
        resolves: r.usize("core resolves")?,
        deadline_drops: r.usize("core deadline_drops")?,
        round: r.usize("core round")?,
        remaining: r.f64("core remaining")?,
        solved_delay: r.f64("core solved_delay")?,
        static_prediction: r.f64("core static_prediction")?,
        realized: r.f64("core realized")?,
        seg_weight: r.f64("core seg_weight")?,
        seg_delay: r.f64("core seg_delay")?,
        realized_e: r.f64("core realized_e")?,
        seg_weight_e: r.f64("core seg_weight_e")?,
        seg_energy: r.f64("core seg_energy")?,
        faults_injected: r.usize("core faults_injected")?,
        repair_max: r.u8("core repair_max")?,
        col_cache: crate::delay::ColumnCache::new(4),
        rounds: Vec::new(),
    })
}

pub(crate) fn write_env(w: &mut BinWriter, env: &DriftEnv) {
    w.f64_slice(&env.scn.main_link.client_gain);
    w.f64_slice(&env.scn.fed_link.client_gain);
    let f: Vec<f64> = env.scn.topo.clients.iter().map(|c| c.f_cycles).collect();
    w.f64_slice(&f);
    w.bool_slice(&env.active);
    w.rng_state(env.jitter_rng.state());
    w.rng_state(env.drop_rng.state());
    w.rng_state(env.process.rng_state());
    w.f64_slice(&env.process.state().shadow_main_db);
    w.f64_slice(&env.process.state().shadow_fed_db);
}

/// Overwrite a freshly built (pristine) [`DriftEnv`]'s mutable state
/// with a snapshot: gains, compute, membership, the three stream
/// positions, and the AR(1) shadow state. After this, stepping the env
/// redraws the exact sequence the snapshotted env would have drawn.
pub(crate) fn apply_env(r: &mut BinReader, env: &mut DriftEnv) -> Result<()> {
    let k = env.scn.k();
    let gain_main = r.f64_slice("env main gains")?;
    let gain_fed = r.f64_slice("env fed gains")?;
    let f_cycles = r.f64_slice("env compute capabilities")?;
    let active = r.bool_slice("env membership")?;
    for (what, len) in [
        ("main gains", gain_main.len()),
        ("fed gains", gain_fed.len()),
        ("compute capabilities", f_cycles.len()),
        ("membership", active.len()),
    ] {
        if len != k {
            bail!(
                "corrupt service checkpoint: env {what} holds {len} clients, \
                 the rebuilt scenario has {k}"
            );
        }
    }
    let jitter_rng = r.rng_state("env jitter rng")?;
    let drop_rng = r.rng_state("env dropout rng")?;
    let process_rng = r.rng_state("env channel rng")?;
    let shadow_main_db = r.f64_slice("env main shadows")?;
    let shadow_fed_db = r.f64_slice("env fed shadows")?;
    if shadow_main_db.len() != k || shadow_fed_db.len() != k {
        bail!(
            "corrupt service checkpoint: env shadows hold {}/{} clients, \
             the rebuilt scenario has {k}",
            shadow_main_db.len(),
            shadow_fed_db.len()
        );
    }
    env.scn.main_link.client_gain = gain_main;
    env.scn.fed_link.client_gain = gain_fed;
    for (c, f) in env.scn.topo.clients.iter_mut().zip(f_cycles) {
        c.f_cycles = f;
    }
    env.active = active;
    env.jitter_rng = crate::util::rng::Rng::from_state(jitter_rng);
    env.drop_rng = crate::util::rng::Rng::from_state(drop_rng);
    env.process.set_state(crate::net::ChannelState {
        shadow_main_db,
        shadow_fed_db,
    });
    env.process.set_rng_state(process_rng);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::delay::ConvergenceModel;
    use crate::sim::ScenarioBuilder;

    fn tiny_scenario() -> crate::delay::Scenario {
        let mut cfg = Config::paper_defaults();
        cfg.model = "tiny".to_string();
        cfg.train.seq = 64;
        cfg.train.ranks = vec![1, 4];
        cfg.system.clients = 3;
        cfg.dynamics.seed = 11;
        cfg.dynamics.rho = 0.8;
        cfg.dynamics.compute_jitter = 0.05;
        cfg.dynamics.dropout = 0.1;
        cfg.dynamics.rejoin = 0.4;
        ScenarioBuilder::from_config(cfg).build().unwrap()
    }

    fn sample_alloc(k: usize) -> Allocation {
        Allocation {
            l_c: 3,
            rank: 4,
            assign_main: (0..k).map(|i| vec![i]).collect(),
            assign_fed: vec![(0..k).collect(), Vec::new()],
            psd_main: (0..k).map(|i| 0.25 + i as f64).collect(),
            psd_fed: (0..k).map(|i| 1.5 * i as f64).collect(),
        }
    }

    #[test]
    fn header_and_alloc_round_trip() {
        let h = Header {
            fingerprint: "{\"preset\":\"paper\"}".to_string(),
            events_consumed: 41,
            finished: false,
            mode: RunMode::Population,
        };
        let mut w = BinWriter::with_header(MAGIC, VERSION);
        write_header(&mut w, &h);
        write_alloc(&mut w, &sample_alloc(4));
        let bytes = seal(w);

        let payload = open(&bytes).unwrap();
        let mut r = BinReader::new(payload);
        let back = read_header(&mut r).unwrap();
        assert_eq!(back.fingerprint, h.fingerprint);
        assert_eq!(back.events_consumed, 41);
        assert!(!back.finished);
        assert_eq!(back.mode, RunMode::Population);
        let a = read_alloc(&mut r).unwrap();
        let want = sample_alloc(4);
        assert_eq!((a.l_c, a.rank), (want.l_c, want.rank));
        assert_eq!(a.assign_main, want.assign_main);
        assert_eq!(a.assign_fed, want.assign_fed);
        assert_eq!(a.psd_main, want.psd_main);
        assert_eq!(a.psd_fed, want.psd_fed);
        r.expect_end("test blob").unwrap();

        // header corruption fails descriptively
        let mut bad = bytes.clone();
        bad[0] = b'X';
        let err = format!("{:#}", peek_header(&bad).unwrap_err());
        assert!(err.contains("not a SfLLM service checkpoint"), "{err}");
        let mut bad = bytes.clone();
        bad[4..8].copy_from_slice(&9u32.to_le_bytes());
        let err = format!("{:#}", peek_header(&bad).unwrap_err());
        assert!(err.contains("version 9") && err.contains("reads version 2"), "{err}");
        // a payload bit flip slips past magic/version but not the CRC
        let mut bad = bytes.clone();
        let mid = bad.len() / 2;
        bad[mid] ^= 0x10;
        let err = format!("{:#}", peek_header(&bad).unwrap_err());
        assert!(err.contains("CRC32 integrity check"), "{err}");
    }

    #[test]
    fn core_round_trips_every_scalar_bit_exactly() {
        let conv = ConvergenceModel::fitted(4.0, 1.0, 0.85);
        let mut core = RoundCore::new(sample_alloc(3), 1.75, &conv);
        core.incumbent_is_initial = false;
        core.initial_retired = true;
        core.env_dirty = true;
        core.force_reopt = true;
        core.fresh_solves = 2;
        core.resolves = 5;
        core.deadline_drops = 7;
        core.round = 9;
        core.remaining = 3.25;
        core.solved_delay = 1.125;
        core.realized = 10.5;
        core.seg_weight = 0.75;
        core.seg_delay = 1.2000000000000002;
        core.realized_e = 2048.25;
        core.seg_weight_e = 1.0;
        core.seg_energy = -0.0;
        core.faults_injected = 13;
        core.repair_max = 3;
        let mut w = BinWriter::new();
        write_core(&mut w, &core);
        let bytes = w.into_bytes();
        let mut r = BinReader::new(&bytes);
        let back = read_core(&mut r).unwrap();
        r.expect_end("core").unwrap();
        assert_eq!(back.alloc.psd_main, core.alloc.psd_main);
        assert_eq!(back.alloc0.assign_main, core.alloc0.assign_main);
        assert!(!back.incumbent_is_initial);
        assert!(back.initial_retired && back.env_dirty && back.force_reopt);
        assert_eq!(
            (back.fresh_solves, back.resolves, back.deadline_drops, back.round),
            (2, 5, 7, 9)
        );
        assert_eq!(back.remaining.to_bits(), core.remaining.to_bits());
        assert_eq!(back.solved_delay.to_bits(), core.solved_delay.to_bits());
        assert_eq!(back.seg_delay.to_bits(), core.seg_delay.to_bits());
        assert_eq!(back.seg_energy.to_bits(), (-0.0f64).to_bits());
        assert_eq!((back.faults_injected, back.repair_max), (13, 3));
        assert!(back.rounds.is_empty(), "records live in the sinks, not the checkpoint");
        // totals must flush identically
        assert_eq!(back.totals().0.to_bits(), core.totals().0.to_bits());
        assert_eq!(back.totals().1.to_bits(), core.totals().1.to_bits());
    }

    #[test]
    fn env_snapshot_resumes_the_exact_drift_trajectory() {
        let scn = tiny_scenario();
        let mut env = DriftEnv::new(scn.clone());
        for _ in 0..7 {
            env.advance();
        }
        let mut w = BinWriter::new();
        write_env(&mut w, &env);
        let bytes = w.into_bytes();

        let mut resumed = DriftEnv::new(scn);
        let mut r = BinReader::new(&bytes);
        apply_env(&mut r, &mut resumed).unwrap();
        r.expect_end("env").unwrap();

        // identical state now, and identical evolution afterwards
        for step in 0..9 {
            assert_eq!(resumed.active, env.active, "step {step}");
            for (a, b) in resumed
                .scn
                .main_link
                .client_gain
                .iter()
                .chain(&resumed.scn.fed_link.client_gain)
                .zip(env.scn.main_link.client_gain.iter().chain(&env.scn.fed_link.client_gain))
            {
                assert_eq!(a.to_bits(), b.to_bits(), "step {step}");
            }
            for (a, b) in resumed.scn.topo.clients.iter().zip(&env.scn.topo.clients) {
                assert_eq!(a.f_cycles.to_bits(), b.f_cycles.to_bits(), "step {step}");
            }
            env.advance();
            resumed.advance();
        }
    }

    #[test]
    fn env_snapshot_refuses_a_different_scenario_size() {
        let scn = tiny_scenario();
        let env = DriftEnv::new(scn);
        let mut w = BinWriter::new();
        write_env(&mut w, &env);
        let bytes = w.into_bytes();

        let mut cfg = Config::paper_defaults();
        cfg.model = "tiny".to_string();
        cfg.train.seq = 64;
        cfg.system.clients = 5;
        let other = ScenarioBuilder::from_config(cfg).build().unwrap();
        let mut resumed = DriftEnv::new(other);
        let err = format!(
            "{:#}",
            apply_env(&mut BinReader::new(&bytes), &mut resumed).unwrap_err()
        );
        assert!(err.contains("clients"), "{err}");
    }
}
