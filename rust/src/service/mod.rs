//! The allocator service (PR-8): the policy / evaluator / dynamic
//! stack packaged as a long-running, observable, checkpoint/resumable
//! engine.
//!
//! Where the `sim` simulators run one closed loop to completion and
//! return an outcome, the service is *driven*: it consumes typed
//! deterministic [`Event`]s (from memory, or replayed from a JSONL
//! file — `sfllm serve`), advances the same shared round engine
//! ([`crate::sim::engine`]) one tick at a time, and streams per-round
//! records into pluggable [`MetricSink`]s as they are produced. Because
//! events carry no random payload — every random quantity comes from
//! the seeded streams the [`RunSpec`] pins down — an event file is a
//! complete, portable, replayable description of a run, and replaying
//! it is bit-identical to having run it live.
//!
//! Layout:
//!
//! * [`event`] — the typed event vocabulary and its strict JSONL wire
//!   form; [`RunSpec`], whose canonical JSON doubles as the checkpoint
//!   fingerprint;
//! * [`allocator`] — [`AllocatorService`] itself: session lifecycle,
//!   the tick (the simulators' loop bodies, statement for statement),
//!   checkpoint/resume;
//! * [`metrics`] — the shared round-record schema (CSV / JSONL / in-
//!   memory) behind every `--rounds-out` flag and service stream;
//! * [`checkpoint`] — the versioned `SFCK` state codec;
//! * `codec` (re-exported from [`crate::util::codec`] since PR-9) —
//!   the little-endian binary primitives shared with the adapter
//!   checkpoint format ([`crate::coordinator::checkpoint`]).
//!
//! The contract tying it together (property-tested in
//! `rust/tests/prop_service.rs`): a pure tick stream reproduces
//! [`crate::sim::RoundSimulator`] / [`crate::sim::PopulationSimulator`]
//! bit for bit on every preset, and *checkpoint at event n + resume*
//! continues the uninterrupted run byte-identically.

pub mod allocator;
pub mod checkpoint;
pub mod event;
pub mod metrics;

pub use crate::util::codec;

pub use self::allocator::AllocatorService;
pub use self::checkpoint::peek_header;
pub use self::event::{parse_events, parse_events_lenient, Event, RunMode, RunSpec, SkippedLine};
pub use self::metrics::{
    write_rounds_csv, AggregateSink, JsonlSink, MemorySink, MetricSink, RoundMetrics, RunSummary,
};
