//! Metric surfaces of the allocator service: the shared per-round
//! record schema and the pluggable sinks service runs stream into.
//!
//! One schema, three encodings, all byte-stable:
//!
//! * **CSV** ([`write_rounds_csv`]) — the `--rounds-out` trace of the
//!   `dynamic` and `population` subcommands and of `sfllm serve`. One
//!   row per round, columns [`TRACE_COLUMNS`], floats in Rust's
//!   shortest round-trip `{}` form (booleans as 0/1). Identical inputs
//!   produce identical bytes on every platform — golden-file tested
//!   below.
//! * **JSONL** ([`JsonlSink`]) — one self-describing object per line
//!   (`"type":"round"` / `"type":"summary"`), same field names and the
//!   same number formatting as the CSV, so the two surfaces can never
//!   disagree on a value.
//! * **In-memory** ([`MemorySink`] ring, [`AggregateSink`] totals) —
//!   for embedding the service and for tests.
//!
//! The field names are the contract documented in DESIGN.md (PR-8):
//! `round` (index), `weight` (convergence progress realized, ≤ 1),
//! `delay_s`/`energy_j` (realized per-round), `l_c`/`rank` (the
//! incumbent split decision), `cohort` (invited), `active` (online
//! after dropout/deadline), `dropped` (deadline cuts this round),
//! `resolved` (whether a re-opt decision ran). Round records from the
//! round simulator have `cohort == K` and `dropped == 0`.

use std::collections::VecDeque;
use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

use anyhow::{Context, Result};

use crate::sim::engine::Adoption;
use crate::sim::RoundRecord;
use crate::util::csv::CsvWriter;

/// Column order of the shared per-round trace (CSV and JSONL).
/// `faults` and `repair_tier` joined in PR-10: faults injected into the
/// round and the feasibility-repair tier its solve needed (both 0 on
/// clean runs).
pub const TRACE_COLUMNS: [&str; 12] = [
    "round", "weight", "delay_s", "energy_j", "l_c", "rank", "cohort", "active", "dropped",
    "resolved", "faults", "repair_tier",
];

/// One round's record plus what the allocator adopted that round.
#[derive(Clone, Debug)]
pub struct RoundMetrics {
    pub record: RoundRecord,
    /// Which candidate the re-opt step kept ([`Adoption::Held`] when no
    /// re-solve was due).
    pub adoption: Adoption,
}

/// End-of-run totals (also emitted on shutdown of an unfinished run,
/// with the totals realized so far).
#[derive(Clone, Debug)]
pub struct RunSummary {
    /// Rounds realized by the run so far.
    pub rounds: usize,
    pub realized_delay: f64,
    pub realized_energy: f64,
    pub static_prediction: f64,
    pub resolves: usize,
    pub fresh_solves: usize,
    pub deadline_drops: usize,
    pub unique_participants: usize,
    pub final_l_c: usize,
    pub final_rank: usize,
    /// Total faults injected across the run (PR-10; 0 on clean runs).
    pub faults_injected: usize,
    /// Deepest feasibility-repair tier any round's solve needed.
    pub repair_max: u8,
    /// Transient-failure retries the coordinator performed (0 for pure
    /// allocator runs, which have no transport in the loop).
    pub retries: usize,
    /// Malformed event lines skipped by lenient replay (0 under strict
    /// parsing, the default).
    pub lines_skipped: usize,
    /// Whether the run reached one unit of convergence progress.
    pub converged: bool,
}

/// Where a service run streams its per-round output.
pub trait MetricSink {
    fn on_round(&mut self, m: &RoundMetrics) -> Result<()>;
    fn on_summary(&mut self, s: &RunSummary) -> Result<()>;
    /// Flush any buffered output (called on checkpoint and shutdown).
    fn flush(&mut self) -> Result<()> {
        Ok(())
    }
}

/// Format a float exactly like [`CsvWriter::row_f64`]: Rust's shortest
/// round-trip `{}` Display. Non-finite values become `null` so JSONL
/// lines stay parseable (the CSV writer prints `inf`/`NaN` as-is;
/// realized delays are finite in any feasible run).
fn num(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

/// The shared row encoding of one record, in [`TRACE_COLUMNS`] order.
fn trace_row(r: &RoundRecord) -> [f64; 12] {
    [
        r.round as f64,
        r.weight,
        r.delay,
        r.energy,
        r.l_c as f64,
        r.rank as f64,
        r.cohort as f64,
        r.active as f64,
        r.dropped as f64,
        if r.resolved { 1.0 } else { 0.0 },
        r.faults as f64,
        r.repair_tier as f64,
    ]
}

/// Write a per-round trace as CSV under the shared schema — the one
/// writer behind every `--rounds-out` flag.
pub fn write_rounds_csv<P: AsRef<Path>>(path: P, rounds: &[RoundRecord]) -> Result<()> {
    let mut w = CsvWriter::create(path, &TRACE_COLUMNS)?;
    for r in rounds {
        w.row_f64(&trace_row(r))?;
    }
    w.flush()
}

/// One round as a JSONL line (no trailing newline).
pub fn round_json(m: &RoundMetrics) -> String {
    let r = &m.record;
    format!(
        "{{\"type\":\"round\",\"round\":{},\"weight\":{},\"delay_s\":{},\"energy_j\":{},\
         \"l_c\":{},\"rank\":{},\"cohort\":{},\"active\":{},\"dropped\":{},\
         \"resolved\":{},\"faults\":{},\"repair_tier\":{},\"adopted\":\"{}\"}}",
        r.round,
        num(r.weight),
        num(r.delay),
        num(r.energy),
        r.l_c,
        r.rank,
        r.cohort,
        r.active,
        r.dropped,
        r.resolved,
        r.faults,
        r.repair_tier,
        m.adoption.label()
    )
}

/// The run summary as a JSONL line (no trailing newline).
pub fn summary_json(s: &RunSummary) -> String {
    format!(
        "{{\"type\":\"summary\",\"rounds\":{},\"realized_delay_s\":{},\
         \"realized_energy_j\":{},\"static_prediction_s\":{},\"resolves\":{},\
         \"fresh_solves\":{},\"deadline_drops\":{},\"unique_participants\":{},\
         \"final_l_c\":{},\"final_rank\":{},\"faults_injected\":{},\
         \"repair_max\":{},\"retries\":{},\"lines_skipped\":{},\"converged\":{}}}",
        s.rounds,
        num(s.realized_delay),
        num(s.realized_energy),
        num(s.static_prediction),
        s.resolves,
        s.fresh_solves,
        s.deadline_drops,
        s.unique_participants,
        s.final_l_c,
        s.final_rank,
        s.faults_injected,
        s.repair_max,
        s.retries,
        s.lines_skipped,
        s.converged
    )
}

/// Bounded in-memory ring of the most recent rounds plus the summary.
pub struct MemorySink {
    cap: usize,
    rounds: VecDeque<RoundMetrics>,
    summary: Option<RunSummary>,
}

impl MemorySink {
    /// Keep at most `cap` most-recent rounds (`cap >= 1`).
    pub fn new(cap: usize) -> MemorySink {
        MemorySink {
            cap: cap.max(1),
            rounds: VecDeque::new(),
            summary: None,
        }
    }

    pub fn rounds(&self) -> impl Iterator<Item = &RoundMetrics> {
        self.rounds.iter()
    }

    pub fn len(&self) -> usize {
        self.rounds.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rounds.is_empty()
    }

    pub fn summary(&self) -> Option<&RunSummary> {
        self.summary.as_ref()
    }
}

impl MetricSink for MemorySink {
    fn on_round(&mut self, m: &RoundMetrics) -> Result<()> {
        if self.rounds.len() == self.cap {
            self.rounds.pop_front();
        }
        self.rounds.push_back(m.clone());
        Ok(())
    }

    fn on_summary(&mut self, s: &RunSummary) -> Result<()> {
        self.summary = Some(s.clone());
        Ok(())
    }
}

/// Byte-stable JSONL stream (one object per line; see the module docs
/// for the schema).
pub struct JsonlSink<W: Write> {
    out: W,
}

impl JsonlSink<BufWriter<File>> {
    /// Create (truncating) a JSONL file, creating parent dirs.
    pub fn create<P: AsRef<Path>>(path: P) -> Result<JsonlSink<BufWriter<File>>> {
        crate::util::csv::ensure_parent_dir(&path)?;
        let f = File::create(&path)
            .with_context(|| format!("creating {}", path.as_ref().display()))?;
        Ok(JsonlSink {
            out: BufWriter::new(f),
        })
    }
}

impl<W: Write> JsonlSink<W> {
    /// Stream into any writer (a `Vec<u8>` in tests).
    pub fn new(out: W) -> JsonlSink<W> {
        JsonlSink { out }
    }

    pub fn into_inner(self) -> W {
        self.out
    }
}

impl<W: Write> MetricSink for JsonlSink<W> {
    fn on_round(&mut self, m: &RoundMetrics) -> Result<()> {
        self.out.write_all(round_json(m).as_bytes())?;
        self.out.write_all(b"\n")?;
        Ok(())
    }

    fn on_summary(&mut self, s: &RunSummary) -> Result<()> {
        self.out.write_all(summary_json(s).as_bytes())?;
        self.out.write_all(b"\n")?;
        Ok(())
    }

    fn flush(&mut self) -> Result<()> {
        self.out.flush()?;
        Ok(())
    }
}

/// O(1)-memory aggregate over a run: weighted totals and extrema of the
/// realized per-round delay.
#[derive(Default)]
pub struct AggregateSink {
    rounds: usize,
    weight_sum: f64,
    delay_wsum: f64,
    energy_wsum: f64,
    delay_min: Option<f64>,
    delay_max: Option<f64>,
    resolves: usize,
    summary: Option<RunSummary>,
}

impl AggregateSink {
    pub fn new() -> AggregateSink {
        AggregateSink::default()
    }

    pub fn rounds(&self) -> usize {
        self.rounds
    }

    /// Weighted totals `(Σ w·d, Σ w·e)` of the rounds seen so far.
    /// Naive summation — within fp error of, but not bit-identical to,
    /// the engine's run-length-compressed accumulators.
    pub fn weighted_totals(&self) -> (f64, f64) {
        (self.delay_wsum, self.energy_wsum)
    }

    pub fn delay_range(&self) -> Option<(f64, f64)> {
        match (self.delay_min, self.delay_max) {
            (Some(lo), Some(hi)) => Some((lo, hi)),
            _ => None,
        }
    }

    pub fn resolves(&self) -> usize {
        self.resolves
    }

    pub fn summary(&self) -> Option<&RunSummary> {
        self.summary.as_ref()
    }
}

impl MetricSink for AggregateSink {
    fn on_round(&mut self, m: &RoundMetrics) -> Result<()> {
        let r = &m.record;
        self.rounds += 1;
        self.weight_sum += r.weight;
        self.delay_wsum += r.weight * r.delay;
        self.energy_wsum += r.weight * r.energy;
        self.delay_min = Some(match self.delay_min {
            Some(lo) if lo < r.delay => lo,
            _ => r.delay,
        });
        self.delay_max = Some(match self.delay_max {
            Some(hi) if hi > r.delay => hi,
            _ => r.delay,
        });
        if r.resolved {
            self.resolves += 1;
        }
        Ok(())
    }

    fn on_summary(&mut self, s: &RunSummary) -> Result<()> {
        self.summary = Some(s.clone());
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Hand-constructed records with exactly-representable floats, so
    /// the golden bytes are platform-independent by construction (no
    /// libm in sight).
    fn sample_rounds() -> Vec<RoundRecord> {
        vec![
            RoundRecord {
                round: 0,
                weight: 1.0,
                delay: 1.5,
                energy: 2048.25,
                l_c: 3,
                rank: 4,
                active: 5,
                resolved: true,
                cohort: 5,
                dropped: 0,
                faults: 0,
                repair_tier: 0,
            },
            RoundRecord {
                round: 1,
                weight: 0.25,
                delay: 1.5,
                energy: 1024.125,
                l_c: 3,
                rank: 4,
                active: 4,
                resolved: false,
                cohort: 5,
                dropped: 1,
                faults: 2,
                repair_tier: 1,
            },
        ]
    }

    fn sample_summary() -> RunSummary {
        RunSummary {
            rounds: 2,
            realized_delay: 1.875,
            realized_energy: 2304.28125,
            static_prediction: 1.75,
            resolves: 1,
            fresh_solves: 1,
            deadline_drops: 1,
            unique_participants: 5,
            final_l_c: 3,
            final_rank: 4,
            faults_injected: 2,
            repair_max: 1,
            retries: 0,
            lines_skipped: 3,
            converged: true,
        }
    }

    #[test]
    fn csv_trace_matches_the_committed_golden_bytes() {
        let golden = include_str!("../../tests/fixtures/rounds_trace.golden.csv");
        let dir = std::env::temp_dir().join(format!("sfllm_trace_{}", std::process::id()));
        let path = dir.join("trace.csv");
        write_rounds_csv(&path, &sample_rounds()).unwrap();
        let got = std::fs::read_to_string(&path).unwrap();
        assert_eq!(got, golden, "trace schema drifted from the golden file");
        // writing twice is byte-identical
        write_rounds_csv(&path, &sample_rounds()).unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), golden);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn jsonl_matches_the_committed_golden_bytes() {
        let golden = include_str!("../../tests/fixtures/rounds_trace.golden.jsonl");
        let mut sink = JsonlSink::new(Vec::new());
        for (i, r) in sample_rounds().into_iter().enumerate() {
            let adoption = if i == 0 { Adoption::Fresh } else { Adoption::Held };
            sink.on_round(&RoundMetrics {
                record: r,
                adoption,
            })
            .unwrap();
        }
        sink.on_summary(&sample_summary()).unwrap();
        let got = String::from_utf8(sink.into_inner()).unwrap();
        assert_eq!(got, golden, "JSONL schema drifted from the golden file");
    }

    #[test]
    fn memory_sink_is_a_ring() {
        let mut sink = MemorySink::new(2);
        for r in sample_rounds() {
            sink.on_round(&RoundMetrics {
                record: r,
                adoption: Adoption::Held,
            })
            .unwrap();
        }
        let extra = {
            let mut r = sample_rounds().remove(0);
            r.round = 2;
            r
        };
        sink.on_round(&RoundMetrics {
            record: extra,
            adoption: Adoption::Incumbent,
        })
        .unwrap();
        assert_eq!(sink.len(), 2);
        let kept: Vec<usize> = sink.rounds().map(|m| m.record.round).collect();
        assert_eq!(kept, vec![1, 2], "oldest round must be evicted");
        assert!(sink.summary().is_none());
        sink.on_summary(&sample_summary()).unwrap();
        assert_eq!(sink.summary().map(|s| s.rounds), Some(2));
    }

    #[test]
    fn aggregate_sink_totals_and_extrema() {
        let mut sink = AggregateSink::new();
        for r in sample_rounds() {
            sink.on_round(&RoundMetrics {
                record: r,
                adoption: Adoption::Held,
            })
            .unwrap();
        }
        assert_eq!(sink.rounds(), 2);
        assert_eq!(sink.resolves(), 1);
        let (d, e) = sink.weighted_totals();
        assert_eq!(d, 1.0 * 1.5 + 0.25 * 1.5);
        assert_eq!(e, 1.0 * 2048.25 + 0.25 * 1024.125);
        assert_eq!(sink.delay_range(), Some((1.5, 1.5)));
    }

    #[test]
    fn non_finite_values_stay_parseable_json() {
        let mut r = sample_rounds().remove(0);
        r.delay = f64::INFINITY;
        let line = round_json(&RoundMetrics {
            record: r,
            adoption: Adoption::Held,
        });
        assert!(line.contains("\"delay_s\":null"), "{line}");
        assert!(!line.contains("inf"), "{line}");
    }
}
