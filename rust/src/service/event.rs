//! Typed deterministic events driving the allocator service.
//!
//! An event stream is the service's *only* input: a run is one
//! [`Event::ScenarioLoaded`] (carrying a [`RunSpec`]) followed by
//! [`Event::RoundTick`]s, optionally interleaved with membership /
//! drift / re-optimization / checkpoint control events, and closed by
//! [`Event::Shutdown`]. Events carry **no random payload** — every
//! random quantity in a run comes from the seeded streams the spec
//! pins down — so replaying a JSONL event file reproduces a run bit
//! for bit, and an event file plus a [`ServiceCheckpoint`] is a
//! complete, portable description of a half-finished run.
//!
//! The wire form is one JSON object per line, discriminated by its
//! `"event"` key (see [`Event::from_json_line`]). Parsing is strict:
//! unknown event names and unknown keys are errors, because an event
//! file is external input and a silently ignored typo (`"cliend_id"`)
//! would change what the run simulates.
//!
//! [`ServiceCheckpoint`]: crate::service::checkpoint

use anyhow::{bail, Context, Result};

use crate::config::Config;
use crate::delay::ConvergenceModel;
use crate::sim::{FaultPlan, ScenarioBuilder};
use crate::util::json::Json;

/// Which engine a run drives: the K-client round simulator loop or the
/// population engine (cohort selection, deadlines, rebasing).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RunMode {
    Dynamic,
    Population,
}

impl RunMode {
    pub fn label(&self) -> &'static str {
        match self {
            RunMode::Dynamic => "dynamic",
            RunMode::Population => "population",
        }
    }

    pub fn parse(s: &str) -> Result<RunMode> {
        match s {
            "dynamic" => Ok(RunMode::Dynamic),
            "population" => Ok(RunMode::Population),
            other => bail!("unknown run mode '{other}' (expected dynamic | population)"),
        }
    }
}

/// Everything a `scenario_loaded` event pins down: the preset the
/// immutable substrate comes from, a small set of overrides, and the
/// policy / strategy / convergence model of the run. The spec's
/// canonical JSON form ([`RunSpec::to_json`]) doubles as the
/// checkpoint fingerprint: a resume against a different spec is a
/// different run and is refused.
///
/// Deeper knobs (bandwidths, power budgets, dynamics rates, ...) come
/// from the preset; the overrides here are the ones run harnesses
/// actually vary per run.
#[derive(Clone, Debug, PartialEq)]
pub struct RunSpec {
    /// Scenario preset name (see [`ScenarioBuilder::preset`]).
    pub preset: String,
    pub mode: RunMode,
    /// Model size key (`tiny` | `small` | ... ) overriding the preset's.
    pub model: Option<String>,
    pub clients: Option<usize>,
    pub seq: Option<usize>,
    pub ranks: Option<Vec<usize>>,
    pub subch_main: Option<usize>,
    pub subch_fed: Option<usize>,
    /// `system.seed` (geometry + static channel draw).
    pub seed: Option<u64>,
    /// `dynamics.seed` (per-round drift streams).
    pub dynamics_seed: Option<u64>,
    pub max_rounds: Option<usize>,
    /// Policy name in [`crate::opt::policy::PolicyRegistry::paper_suite`].
    pub policy: String,
    /// Re-optimization strategy spec (see
    /// [`crate::sim::ReOptStrategy::parse`]).
    pub strategy: String,
    /// Seeded draws for the randomized baselines in the registry.
    pub draws: usize,
    /// Convergence fit `[e_inf, c, alpha]`; absent = the paper fit.
    pub conv: Option<[f64; 3]>,
    /// `population.size` (population mode).
    pub population: Option<usize>,
    pub cohort: Option<usize>,
    pub selector: Option<String>,
    pub deadline_drop: Option<f64>,
    /// `population.seed` (geometry + selection lifecycle).
    pub population_seed: Option<u64>,
    /// Fault-plan spec (see [`FaultPlan::parse`]); absent = no faults.
    /// Serialized only when set, so pre-PR-10 fingerprints (and their
    /// checkpoints) stay valid.
    pub faults: Option<String>,
}

/// Key order of the canonical spec serialization (also the exhaustive
/// set of keys `scenario_loaded` accepts, minus the `event` tag).
const SPEC_KEYS: &[&str] = &[
    "preset",
    "mode",
    "model",
    "clients",
    "seq",
    "ranks",
    "subch_main",
    "subch_fed",
    "seed",
    "dynamics_seed",
    "max_rounds",
    "policy",
    "strategy",
    "draws",
    "conv",
    "population",
    "cohort",
    "selector",
    "deadline_drop",
    "population_seed",
    "faults",
];

impl RunSpec {
    /// A spec with every override absent: `preset` under the default
    /// policy/strategy, dynamic mode.
    pub fn preset(preset: &str) -> RunSpec {
        RunSpec {
            preset: preset.to_string(),
            mode: RunMode::Dynamic,
            model: None,
            clients: None,
            seq: None,
            ranks: None,
            subch_main: None,
            subch_fed: None,
            seed: None,
            dynamics_seed: None,
            max_rounds: None,
            policy: "proposed".to_string(),
            strategy: "one_shot".to_string(),
            draws: 5,
            conv: None,
            population: None,
            cohort: None,
            selector: None,
            deadline_drop: None,
            population_seed: None,
            faults: None,
        }
    }

    /// Parse a spec from a parsed JSON object (the `scenario_loaded`
    /// payload, or a checkpoint fingerprint being re-parsed on resume —
    /// the `event` tag is tolerated and ignored).
    pub(crate) fn from_json(v: &Json) -> Result<RunSpec> {
        let obj = v.as_obj()?;
        for key in obj.keys() {
            if key != "event" && !SPEC_KEYS.contains(&key.as_str()) {
                bail!("scenario_loaded: unknown key '{key}'");
            }
        }
        let opt_str = |key: &str| -> Result<Option<String>> {
            match obj.get(key) {
                Some(v) => Ok(Some(
                    v.as_str().with_context(|| format!("key '{key}'"))?.to_string(),
                )),
                None => Ok(None),
            }
        };
        let opt_usize = |key: &str| -> Result<Option<usize>> {
            match obj.get(key) {
                Some(v) => Ok(Some(v.as_usize().with_context(|| format!("key '{key}'"))?)),
                None => Ok(None),
            }
        };
        let opt_f64 = |key: &str| -> Result<Option<f64>> {
            match obj.get(key) {
                Some(v) => Ok(Some(v.as_f64().with_context(|| format!("key '{key}'"))?)),
                None => Ok(None),
            }
        };
        let mut spec = RunSpec::preset(
            opt_str("preset")?
                .as_deref()
                .context("scenario_loaded: missing key 'preset'")?,
        );
        if let Some(m) = opt_str("mode")? {
            spec.mode = RunMode::parse(&m)?;
        }
        spec.model = opt_str("model")?;
        spec.clients = opt_usize("clients")?;
        spec.seq = opt_usize("seq")?;
        if let Some(v) = obj.get("ranks") {
            let arr = v.as_arr().context("key 'ranks'")?;
            let mut ranks = Vec::with_capacity(arr.len());
            for x in arr {
                ranks.push(x.as_usize().context("key 'ranks'")?);
            }
            if ranks.is_empty() {
                bail!("scenario_loaded: 'ranks' must not be empty");
            }
            spec.ranks = Some(ranks);
        }
        spec.subch_main = opt_usize("subch_main")?;
        spec.subch_fed = opt_usize("subch_fed")?;
        spec.seed = opt_usize("seed")?.map(|s| s as u64);
        spec.dynamics_seed = opt_usize("dynamics_seed")?.map(|s| s as u64);
        spec.max_rounds = opt_usize("max_rounds")?;
        if let Some(p) = opt_str("policy")? {
            spec.policy = p;
        }
        if let Some(s) = opt_str("strategy")? {
            spec.strategy = s;
        }
        if let Some(d) = opt_usize("draws")? {
            spec.draws = d;
        }
        if let Some(v) = obj.get("conv") {
            let arr = v.as_arr().context("key 'conv'")?;
            if arr.len() != 3 {
                bail!(
                    "scenario_loaded: 'conv' must be [e_inf, c, alpha] (got {} values)",
                    arr.len()
                );
            }
            let mut fit = [0.0f64; 3];
            for (slot, x) in fit.iter_mut().zip(arr) {
                *slot = x.as_f64().context("key 'conv'")?;
            }
            spec.conv = Some(fit);
        }
        spec.population = opt_usize("population")?;
        spec.cohort = opt_usize("cohort")?;
        spec.selector = opt_str("selector")?;
        spec.deadline_drop = opt_f64("deadline_drop")?;
        spec.population_seed = opt_usize("population_seed")?.map(|s| s as u64);
        if let Some(f) = opt_str("faults")? {
            // reject a bad plan at the event, with its line number,
            // instead of rounds later when the run starts
            FaultPlan::parse(&f).context("key 'faults'")?;
            spec.faults = Some(f);
        }
        Ok(spec)
    }

    /// Canonical JSON form: fixed key order, overrides only when set.
    /// Equal specs serialize to equal strings, which is what lets this
    /// double as the checkpoint fingerprint.
    pub fn to_json(&self) -> String {
        let mut parts: Vec<String> = Vec::new();
        parts.push(format!("\"preset\":{}", jstr(&self.preset)));
        parts.push(format!("\"mode\":{}", jstr(self.mode.label())));
        if let Some(m) = &self.model {
            parts.push(format!("\"model\":{}", jstr(m)));
        }
        if let Some(n) = self.clients {
            parts.push(format!("\"clients\":{n}"));
        }
        if let Some(n) = self.seq {
            parts.push(format!("\"seq\":{n}"));
        }
        if let Some(r) = &self.ranks {
            let xs: Vec<String> = r.iter().map(|x| format!("{x}")).collect();
            parts.push(format!("\"ranks\":[{}]", xs.join(",")));
        }
        if let Some(n) = self.subch_main {
            parts.push(format!("\"subch_main\":{n}"));
        }
        if let Some(n) = self.subch_fed {
            parts.push(format!("\"subch_fed\":{n}"));
        }
        if let Some(s) = self.seed {
            parts.push(format!("\"seed\":{s}"));
        }
        if let Some(s) = self.dynamics_seed {
            parts.push(format!("\"dynamics_seed\":{s}"));
        }
        if let Some(n) = self.max_rounds {
            parts.push(format!("\"max_rounds\":{n}"));
        }
        parts.push(format!("\"policy\":{}", jstr(&self.policy)));
        parts.push(format!("\"strategy\":{}", jstr(&self.strategy)));
        parts.push(format!("\"draws\":{}", self.draws));
        if let Some(c) = &self.conv {
            let xs: Vec<String> = c.iter().map(|x| jnum(*x)).collect();
            parts.push(format!("\"conv\":[{}]", xs.join(",")));
        }
        if let Some(n) = self.population {
            parts.push(format!("\"population\":{n}"));
        }
        if let Some(n) = self.cohort {
            parts.push(format!("\"cohort\":{n}"));
        }
        if let Some(s) = &self.selector {
            parts.push(format!("\"selector\":{}", jstr(s)));
        }
        if let Some(x) = self.deadline_drop {
            parts.push(format!("\"deadline_drop\":{}", jnum(x)));
        }
        if let Some(s) = self.population_seed {
            parts.push(format!("\"population_seed\":{s}"));
        }
        if let Some(f) = &self.faults {
            parts.push(format!("\"faults\":{}", jstr(f)));
        }
        format!("{{{}}}", parts.join(","))
    }

    /// The resume identity of a run: equal fingerprints ⇔ equal specs.
    pub fn fingerprint(&self) -> String {
        self.to_json()
    }

    /// Lower the spec onto its preset's config.
    pub fn build_config(&self) -> Result<Config> {
        let mut cfg = ScenarioBuilder::preset(&self.preset)
            .with_context(|| format!("run spec preset '{}'", self.preset))?
            .into_config();
        if let Some(m) = &self.model {
            cfg.model = m.clone();
        }
        if let Some(n) = self.clients {
            cfg.system.clients = n;
        }
        if let Some(s) = self.seq {
            cfg.train.seq = s;
        }
        if let Some(r) = &self.ranks {
            cfg.train.ranks = r.clone();
        }
        if let Some(n) = self.subch_main {
            cfg.system.subch_main = n;
        }
        if let Some(n) = self.subch_fed {
            cfg.system.subch_fed = n;
        }
        if let Some(s) = self.seed {
            cfg.system.seed = s;
        }
        if let Some(s) = self.dynamics_seed {
            cfg.dynamics.seed = s;
        }
        if let Some(n) = self.max_rounds {
            cfg.dynamics.max_rounds = n;
        }
        if let Some(n) = self.population {
            cfg.population.size = n;
        }
        if let Some(n) = self.cohort {
            cfg.population.cohort = n;
        }
        if let Some(s) = &self.selector {
            cfg.population.selector = s.clone();
        }
        if let Some(x) = self.deadline_drop {
            cfg.population.deadline_drop = x;
        }
        if let Some(s) = self.population_seed {
            cfg.population.seed = s;
        }
        Ok(cfg)
    }

    /// The run's convergence model (the paper fit unless overridden).
    pub fn conv_model(&self) -> ConvergenceModel {
        match self.conv {
            Some([e_inf, c, alpha]) => ConvergenceModel::fitted(e_inf, c, alpha),
            None => ConvergenceModel::paper_default(),
        }
    }

    /// The run's fault plan (empty when the spec carries none).
    pub fn fault_plan(&self) -> Result<FaultPlan> {
        match &self.faults {
            Some(f) => FaultPlan::parse(f).context("run spec 'faults'"),
            None => Ok(FaultPlan::default()),
        }
    }
}

/// One typed input to the allocator service. See the module docs for
/// the stream grammar; per-event semantics live on
/// [`crate::service::AllocatorService::process`].
#[derive(Clone, Debug, PartialEq)]
pub enum Event {
    /// Open a run: build the scenario, solve round 0.
    ScenarioLoaded(RunSpec),
    /// Advance one round (drift, select, re-opt, realize, stream).
    RoundTick,
    /// Inject one extra channel-drift step before the next tick.
    ChannelDrift,
    /// Override the next tick's cohort (population mode; sorted
    /// distinct client ids).
    CohortSelected { ids: Vec<usize> },
    /// Force a client offline (dynamic / dense-population membership).
    ClientDropped { id: usize },
    /// Force a client back online.
    ClientRejoined { id: usize },
    /// Make the next tick re-optimize regardless of strategy.
    ReOptRequested,
    /// Write a service checkpoint now (to `path`, or the configured
    /// default when absent).
    CheckpointRequested { path: Option<String> },
    /// Flush sinks and close the stream.
    Shutdown,
}

impl Event {
    /// The wire discriminator (`"event"` key).
    pub fn kind(&self) -> &'static str {
        match self {
            Event::ScenarioLoaded(_) => "scenario_loaded",
            Event::RoundTick => "round_tick",
            Event::ChannelDrift => "channel_drift",
            Event::CohortSelected { .. } => "cohort_selected",
            Event::ClientDropped { .. } => "client_dropped",
            Event::ClientRejoined { .. } => "client_rejoined",
            Event::ReOptRequested => "reopt_requested",
            Event::CheckpointRequested { .. } => "checkpoint_requested",
            Event::Shutdown => "shutdown",
        }
    }

    /// Parse one JSONL line (strict: unknown events and keys fail).
    pub fn from_json_line(line: &str) -> Result<Event> {
        let v = Json::parse(line)?;
        let obj = v.as_obj().context("an event is a JSON object")?;
        let kind = v.get("event").context("missing 'event' key")?.as_str()?.to_string();
        let only_keys = |allowed: &[&str]| -> Result<()> {
            for key in obj.keys() {
                if key != "event" && !allowed.contains(&key.as_str()) {
                    bail!("{kind}: unknown key '{key}'");
                }
            }
            Ok(())
        };
        match kind.as_str() {
            "scenario_loaded" => Ok(Event::ScenarioLoaded(RunSpec::from_json(&v)?)),
            "round_tick" => {
                only_keys(&[])?;
                Ok(Event::RoundTick)
            }
            "channel_drift" => {
                only_keys(&[])?;
                Ok(Event::ChannelDrift)
            }
            "cohort_selected" => {
                only_keys(&["ids"])?;
                let arr = v.get("ids")?.as_arr().context("cohort_selected: 'ids'")?;
                let mut ids = Vec::with_capacity(arr.len());
                for x in arr {
                    ids.push(x.as_usize().context("cohort_selected: 'ids'")?);
                }
                if ids.is_empty() {
                    bail!("cohort_selected: 'ids' must not be empty");
                }
                if !ids.windows(2).all(|w| w[0] < w[1]) {
                    bail!("cohort_selected: 'ids' must be sorted and distinct (got {ids:?})");
                }
                Ok(Event::CohortSelected { ids })
            }
            "client_dropped" => {
                only_keys(&["id"])?;
                Ok(Event::ClientDropped { id: v.get("id")?.as_usize()? })
            }
            "client_rejoined" => {
                only_keys(&["id"])?;
                Ok(Event::ClientRejoined { id: v.get("id")?.as_usize()? })
            }
            "reopt_requested" => {
                only_keys(&[])?;
                Ok(Event::ReOptRequested)
            }
            "checkpoint_requested" => {
                only_keys(&["path"])?;
                let path = match obj.get("path") {
                    Some(p) => Some(p.as_str().context("checkpoint_requested: 'path'")?.to_string()),
                    None => None,
                };
                Ok(Event::CheckpointRequested { path })
            }
            "shutdown" => {
                only_keys(&[])?;
                Ok(Event::Shutdown)
            }
            other => bail!(
                "unknown event '{other}' (expected scenario_loaded | round_tick | \
                 channel_drift | cohort_selected | client_dropped | client_rejoined | \
                 reopt_requested | checkpoint_requested | shutdown)"
            ),
        }
    }

    /// Serialize back to one JSONL line (round-trips through
    /// [`Event::from_json_line`]; used to author fixtures).
    pub fn to_json_line(&self) -> String {
        match self {
            Event::ScenarioLoaded(spec) => {
                let body = spec.to_json();
                // splice the discriminator in front of the spec fields
                format!("{{\"event\":\"scenario_loaded\",{}", &body[1..])
            }
            Event::CohortSelected { ids } => {
                let xs: Vec<String> = ids.iter().map(|x| format!("{x}")).collect();
                format!("{{\"event\":\"cohort_selected\",\"ids\":[{}]}}", xs.join(","))
            }
            Event::ClientDropped { id } => {
                format!("{{\"event\":\"client_dropped\",\"id\":{id}}}")
            }
            Event::ClientRejoined { id } => {
                format!("{{\"event\":\"client_rejoined\",\"id\":{id}}}")
            }
            Event::CheckpointRequested { path } => match path {
                Some(p) => format!("{{\"event\":\"checkpoint_requested\",\"path\":{}}}", jstr(p)),
                None => "{\"event\":\"checkpoint_requested\"}".to_string(),
            },
            other => format!("{{\"event\":\"{}\"}}", other.kind()),
        }
    }
}

/// Parse a whole JSONL event file; blank lines and `#` comment lines
/// are skipped, errors carry 1-based line numbers.
pub fn parse_events(text: &str) -> Result<Vec<Event>> {
    let mut events = Vec::new();
    for (i, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        events
            .push(Event::from_json_line(line).with_context(|| format!("events line {}", i + 1))?);
    }
    Ok(events)
}

/// One line [`parse_events_lenient`] could not parse.
#[derive(Clone, Debug, PartialEq)]
pub struct SkippedLine {
    /// 1-based line number in the input text.
    pub line: usize,
    /// The parse error, rendered with its context chain.
    pub error: String,
}

/// Degradation-mode variant of [`parse_events`] (PR-10): malformed
/// lines are *skipped and counted* instead of failing the whole file,
/// so a replay can make progress through a truncated or bit-flipped
/// log. Well-formed lines parse to exactly what [`parse_events`]
/// produces — the lenient parser never reinterprets, only drops — and a
/// clean file yields an empty skip list, making the two modes
/// byte-equivalent on healthy input. Strict parsing stays the default:
/// silently tolerating a typo in a hand-written file would change what
/// the run simulates.
pub fn parse_events_lenient(text: &str) -> (Vec<Event>, Vec<SkippedLine>) {
    let mut events = Vec::new();
    let mut skipped = Vec::new();
    for (i, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        match Event::from_json_line(line) {
            Ok(e) => events.push(e),
            Err(err) => skipped.push(SkippedLine {
                line: i + 1,
                error: format!("{err:#}"),
            }),
        }
    }
    (events, skipped)
}

/// JSON string literal (escapes quotes, backslashes, control chars).
fn jstr(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Shortest round-trip float literal (the repo-wide text-float
/// convention; event floats are always finite).
fn jnum(v: f64) -> String {
    format!("{v}")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn full_spec() -> RunSpec {
        let mut spec = RunSpec::preset("mobile_edge");
        spec.mode = RunMode::Population;
        spec.model = Some("tiny".to_string());
        spec.clients = Some(4);
        spec.seq = Some(64);
        spec.ranks = Some(vec![1, 4]);
        spec.subch_main = Some(16);
        spec.subch_fed = Some(16);
        spec.seed = Some(7);
        spec.dynamics_seed = Some(11);
        spec.max_rounds = Some(400);
        spec.policy = "proposed".to_string();
        spec.strategy = "periodic:5".to_string();
        spec.conv = Some([4.0, 1.0, 0.85]);
        spec.population = Some(40);
        spec.cohort = Some(8);
        spec.selector = Some("staleness:2".to_string());
        spec.deadline_drop = Some(0.25);
        spec.population_seed = Some(5);
        spec.faults = Some("crash=0.1,stall=0.2:0.5:2,seed=3".to_string());
        spec
    }

    #[test]
    fn every_event_round_trips_through_jsonl() {
        let events = vec![
            Event::ScenarioLoaded(full_spec()),
            Event::ScenarioLoaded(RunSpec::preset("paper")),
            Event::RoundTick,
            Event::ChannelDrift,
            Event::CohortSelected { ids: vec![0, 3, 17] },
            Event::ClientDropped { id: 2 },
            Event::ClientRejoined { id: 2 },
            Event::ReOptRequested,
            Event::CheckpointRequested { path: None },
            Event::CheckpointRequested { path: Some("out/ck.bin".to_string()) },
            Event::Shutdown,
        ];
        for e in &events {
            let line = e.to_json_line();
            let back = Event::from_json_line(&line).unwrap_or_else(|err| {
                panic!("{line}: {err:#}");
            });
            assert_eq!(&back, e, "{line}");
        }
        // a whole file, with comments and blanks
        let mut text = String::from("# fixture\n\n");
        for e in &events {
            text.push_str(&e.to_json_line());
            text.push('\n');
        }
        assert_eq!(parse_events(&text).unwrap(), events);
    }

    #[test]
    fn spec_defaults_and_fingerprint_are_stable() {
        let spec = RunSpec::preset("paper");
        assert_eq!(spec.policy, "proposed");
        assert_eq!(spec.strategy, "one_shot");
        assert_eq!(spec.mode, RunMode::Dynamic);
        assert_eq!(spec.draws, 5);
        // minimal wire form parses to the same spec
        let parsed = match Event::from_json_line(
            "{\"event\":\"scenario_loaded\",\"preset\":\"paper\"}",
        )
        .unwrap()
        {
            Event::ScenarioLoaded(s) => s,
            other => panic!("{other:?}"),
        };
        assert_eq!(parsed, spec);
        assert_eq!(parsed.fingerprint(), spec.fingerprint());
        assert_ne!(spec.fingerprint(), full_spec().fingerprint());
    }

    #[test]
    fn spec_lowers_onto_its_presets_config() {
        let cfg = full_spec().build_config().unwrap();
        assert_eq!(cfg.model, "tiny");
        assert_eq!(cfg.system.clients, 4);
        assert_eq!(cfg.train.seq, 64);
        assert_eq!(cfg.train.ranks, vec![1, 4]);
        assert_eq!(cfg.system.subch_main, 16);
        assert_eq!(cfg.dynamics.seed, 11);
        assert_eq!(cfg.dynamics.max_rounds, 400);
        assert_eq!(cfg.population.size, 40);
        assert_eq!(cfg.population.cohort, 8);
        assert_eq!(cfg.population.selector, "staleness:2");
        assert_eq!(cfg.population.seed, 5);
        // conv override vs default
        let conv = full_spec().conv_model();
        assert_eq!(conv.rounds(4), 4.0 * (1.0 + 1.0 / 4f64.powf(0.85)));
        assert!(RunSpec::preset("paper").build_config().is_ok());
        assert!(RunSpec::preset("no_such_preset").build_config().is_err());
    }

    #[test]
    fn strict_parsing_rejects_typos_descriptively() {
        let err = |line: &str| format!("{:#}", Event::from_json_line(line).unwrap_err());
        assert!(err("{\"event\":\"round_tik\"}").contains("unknown event"));
        assert!(err("{\"event\":\"round_tick\",\"count\":3}").contains("unknown key 'count'"));
        assert!(
            err("{\"event\":\"scenario_loaded\",\"preset\":\"paper\",\"cliens\":4}")
                .contains("unknown key 'cliens'")
        );
        assert!(err("{\"event\":\"scenario_loaded\"}").contains("preset"));
        assert!(err("{\"event\":\"client_dropped\"}").contains("id"));
        assert!(err("{\"event\":\"cohort_selected\",\"ids\":[]}").contains("empty"));
        assert!(err("{\"event\":\"cohort_selected\",\"ids\":[3,1]}").contains("sorted"));
        assert!(
            err("{\"event\":\"scenario_loaded\",\"preset\":\"paper\",\"conv\":[1,2]}")
                .contains("e_inf")
        );
        assert!(err("{\"event\":\"scenario_loaded\",\"preset\":\"paper\",\"mode\":\"x\"}")
            .contains("unknown run mode"));
        // file-level errors carry line numbers
        let text = "{\"event\":\"round_tick\"}\n{\"event\":\"nope\"}\n";
        let msg = format!("{:#}", parse_events(text).unwrap_err());
        assert!(msg.contains("line 2"), "{msg}");
        // a bad fault spec is rejected at the event
        assert!(
            err("{\"event\":\"scenario_loaded\",\"preset\":\"paper\",\"faults\":\"crash=2\"}")
                .contains("faults")
        );
    }

    #[test]
    fn fault_specs_ride_the_fingerprint_only_when_set() {
        let plain = RunSpec::preset("paper");
        assert!(!plain.fingerprint().contains("faults"));
        assert!(plain.fault_plan().unwrap().is_empty());
        let mut faulted = RunSpec::preset("paper");
        faulted.faults = Some("crash=0.1,seed=3".to_string());
        assert_ne!(plain.fingerprint(), faulted.fingerprint());
        let plan = faulted.fault_plan().unwrap();
        assert!(!plan.is_empty());
        assert_eq!(plan.crash_rate, 0.1);
        assert_eq!(plan.seed, 3);
        // and the spec round-trips through the wire form
        let line = Event::ScenarioLoaded(faulted.clone()).to_json_line();
        match Event::from_json_line(&line).unwrap() {
            Event::ScenarioLoaded(back) => assert_eq!(back, faulted),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn lenient_parsing_skips_and_counts_malformed_lines() {
        let text = "# header\n\
                    {\"event\":\"round_tick\"}\n\
                    {\"event\":\"round_tick\"\n\
                    {\"event\":\"round_tik\"}\n\
                    {\"event\":\"round_tick\",\"count\":3}\n\
                    {\"event\":\"shutdown\"}\n";
        assert!(parse_events(text).is_err(), "strict must still fail");
        let (events, skipped) = parse_events_lenient(text);
        assert_eq!(events, vec![Event::RoundTick, Event::Shutdown]);
        assert_eq!(skipped.len(), 3);
        assert_eq!(
            skipped.iter().map(|s| s.line).collect::<Vec<_>>(),
            vec![3, 4, 5]
        );
        assert!(skipped[1].error.contains("unknown event"), "{:?}", skipped[1]);
        assert!(skipped[2].error.contains("unknown key"), "{:?}", skipped[2]);
        // a healthy file skips nothing and parses identically
        let clean = "{\"event\":\"round_tick\"}\n{\"event\":\"shutdown\"}\n";
        let (ev, sk) = parse_events_lenient(clean);
        assert!(sk.is_empty());
        assert_eq!(ev, parse_events(clean).unwrap());
    }
}
