//! Little-endian byte codec shared by every binary artifact the repo
//! writes: the adapter checkpoint ([`crate::coordinator::checkpoint`])
//! and the allocator-service checkpoint
//! ([`crate::service::checkpoint`]).
//!
//! The offline crate set has no serde, so each format is a hand-rolled
//! length-prefixed layout; before PR-8 each writer also hand-rolled its
//! byte plumbing. This module centralizes that plumbing with two
//! properties the formats rely on:
//!
//! * **Bit-exact floats.** `f64`/`f32` round-trip through
//!   `to_bits`/`from_bits`, never through text — the service
//!   checkpoint's resume-equals-uninterrupted contract is bitwise.
//! * **Descriptive failure.** Every read is bounds-checked and fails
//!   with the byte offset and what was being decoded, never a panic —
//!   checkpoint files are external input.

use anyhow::{bail, Result};

/// CRC-32 (IEEE 802.3, reflected polynomial 0xEDB88320) over `bytes` —
/// the integrity footer every checkpoint artifact carries since PR-10.
/// Table-driven, built once at first use.
pub fn crc32(bytes: &[u8]) -> u32 {
    static TABLE: std::sync::OnceLock<[u32; 256]> = std::sync::OnceLock::new();
    let table = TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, e) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *e = c;
        }
        t
    });
    let mut crc = 0xFFFF_FFFFu32;
    for &b in bytes {
        crc = table[((crc ^ b as u32) & 0xFF) as usize] ^ (crc >> 8);
    }
    crc ^ 0xFFFF_FFFF
}

/// Seal a serialized artifact: append the little-endian [`crc32`] of
/// everything written so far as a 4-byte footer.
pub fn append_crc32(bytes: &mut Vec<u8>) {
    let c = crc32(bytes);
    bytes.extend_from_slice(&c.to_le_bytes());
}

/// Verify a [`append_crc32`] footer and return the payload with the
/// footer stripped. `what` names the artifact in errors. Callers should
/// check magic/version *first* so a wrong-file error reads "not a …",
/// not "integrity check failed".
pub fn check_crc32<'a>(bytes: &'a [u8], what: &str) -> Result<&'a [u8]> {
    if bytes.len() < 4 {
        bail!(
            "truncated {what}: {} bytes is too short to hold the CRC32 footer",
            bytes.len()
        );
    }
    let (payload, footer) = bytes.split_at(bytes.len() - 4);
    let mut word = [0u8; 4];
    word.copy_from_slice(footer);
    let stored = u32::from_le_bytes(word);
    let computed = crc32(payload);
    if stored != computed {
        bail!(
            "{what} failed its CRC32 integrity check \
             (stored {stored:#010x}, computed {computed:#010x}): \
             the file is corrupt or truncated"
        );
    }
    Ok(payload)
}

/// Append-only little-endian writer over an owned buffer.
#[derive(Default)]
pub struct BinWriter {
    buf: Vec<u8>,
}

impl BinWriter {
    pub fn new() -> BinWriter {
        BinWriter { buf: Vec::new() }
    }

    /// Start a buffer with a 4-byte magic and a u32 schema version —
    /// the common header of every versioned artifact.
    pub fn with_header(magic: &[u8; 4], version: u32) -> BinWriter {
        let mut w = BinWriter::new();
        w.raw(magic);
        w.u32(version);
        w
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn raw(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn bool(&mut self, v: bool) {
        self.buf.push(u8::from(v));
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn usize(&mut self, v: usize) {
        self.u64(v as u64);
    }

    pub fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }

    pub fn f32(&mut self, v: f32) {
        self.u32(v.to_bits());
    }

    /// u32 byte length + UTF-8 bytes.
    pub fn str(&mut self, s: &str) {
        debug_assert!(s.len() <= u32::MAX as usize);
        self.u32(s.len() as u32);
        self.raw(s.as_bytes());
    }

    /// u64 element count + bit-exact elements.
    pub fn f64_slice(&mut self, v: &[f64]) {
        self.usize(v.len());
        for &x in v {
            self.f64(x);
        }
    }

    pub fn f32_slice(&mut self, v: &[f32]) {
        self.usize(v.len());
        for &x in v {
            self.f32(x);
        }
    }

    pub fn bool_slice(&mut self, v: &[bool]) {
        self.usize(v.len());
        for &x in v {
            self.bool(x);
        }
    }

    pub fn usize_slice(&mut self, v: &[usize]) {
        self.usize(v.len());
        for &x in v {
            self.usize(x);
        }
    }

    /// An [`crate::util::rng::Rng`] state snapshot (4 raw words).
    pub fn rng_state(&mut self, s: [u64; 4]) {
        for w in s {
            self.u64(w);
        }
    }
}

/// Bounds-checked little-endian reader over a borrowed buffer.
pub struct BinReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> BinReader<'a> {
    pub fn new(buf: &'a [u8]) -> BinReader<'a> {
        BinReader { buf, pos: 0 }
    }

    /// Current byte offset (for error messages by callers).
    pub fn offset(&self) -> usize {
        self.pos
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8]> {
        if self.remaining() < n {
            bail!(
                "truncated input: {what} needs {n} bytes at offset {}, \
                 only {} left",
                self.pos,
                self.remaining()
            );
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    /// Consume and verify the 4-byte magic; `what` names the artifact
    /// in the error (e.g. "SfLLM adapter checkpoint").
    pub fn expect_magic(&mut self, magic: &[u8; 4], what: &str) -> Result<()> {
        let got = self.take(4, "magic")?;
        if got != magic {
            bail!(
                "not a {what}: bad magic {:?} (expected {:?})",
                String::from_utf8_lossy(got),
                String::from_utf8_lossy(magic)
            );
        }
        Ok(())
    }

    pub fn u8(&mut self, what: &str) -> Result<u8> {
        Ok(self.take(1, what)?[0])
    }

    pub fn bool(&mut self, what: &str) -> Result<bool> {
        match self.u8(what)? {
            0 => Ok(false),
            1 => Ok(true),
            v => bail!("corrupt {what}: bool byte {v} at offset {}", self.pos - 1),
        }
    }

    pub fn u32(&mut self, what: &str) -> Result<u32> {
        let b = self.take(4, what)?;
        let mut word = [0u8; 4];
        word.copy_from_slice(b);
        Ok(u32::from_le_bytes(word))
    }

    pub fn u64(&mut self, what: &str) -> Result<u64> {
        let b = self.take(8, what)?;
        let mut word = [0u8; 8];
        word.copy_from_slice(b);
        Ok(u64::from_le_bytes(word))
    }

    pub fn usize(&mut self, what: &str) -> Result<usize> {
        let v = self.u64(what)?;
        match usize::try_from(v) {
            Ok(u) => Ok(u),
            Err(_) => bail!("corrupt {what}: value {v} exceeds usize"),
        }
    }

    pub fn f64(&mut self, what: &str) -> Result<f64> {
        Ok(f64::from_bits(self.u64(what)?))
    }

    pub fn f32(&mut self, what: &str) -> Result<f32> {
        Ok(f32::from_bits(self.u32(what)?))
    }

    /// u32 byte length + UTF-8 bytes; `max_len` guards against reading
    /// a corrupt length as an allocation size.
    pub fn str(&mut self, max_len: usize, what: &str) -> Result<String> {
        let len = self.u32(what)? as usize;
        if len > max_len {
            bail!("corrupt {what}: string length {len} exceeds limit {max_len}");
        }
        let bytes = self.take(len, what)?;
        match std::str::from_utf8(bytes) {
            Ok(s) => Ok(s.to_string()),
            Err(e) => bail!("corrupt {what}: invalid UTF-8 ({e})"),
        }
    }

    /// u64 element count + elements; the count is validated against the
    /// bytes actually remaining before any allocation.
    fn seq_len(&mut self, elem_bytes: usize, what: &str) -> Result<usize> {
        let len = self.usize(what)?;
        let need = len.saturating_mul(elem_bytes);
        if need > self.remaining() {
            bail!(
                "corrupt {what}: {len} elements need {need} bytes at offset {}, \
                 only {} left",
                self.pos,
                self.remaining()
            );
        }
        Ok(len)
    }

    pub fn f64_slice(&mut self, what: &str) -> Result<Vec<f64>> {
        let len = self.seq_len(8, what)?;
        (0..len).map(|_| self.f64(what)).collect()
    }

    pub fn f32_slice(&mut self, what: &str) -> Result<Vec<f32>> {
        let len = self.seq_len(4, what)?;
        (0..len).map(|_| self.f32(what)).collect()
    }

    pub fn bool_slice(&mut self, what: &str) -> Result<Vec<bool>> {
        let len = self.seq_len(1, what)?;
        (0..len).map(|_| self.bool(what)).collect()
    }

    pub fn usize_slice(&mut self, what: &str) -> Result<Vec<usize>> {
        let len = self.seq_len(8, what)?;
        (0..len).map(|_| self.usize(what)).collect()
    }

    pub fn rng_state(&mut self, what: &str) -> Result<[u64; 4]> {
        Ok([
            self.u64(what)?,
            self.u64(what)?,
            self.u64(what)?,
            self.u64(what)?,
        ])
    }

    /// Fail if any bytes remain — trailing garbage means the file is
    /// not what the schema version claims.
    pub fn expect_end(&self, what: &str) -> Result<()> {
        if self.remaining() > 0 {
            bail!(
                "corrupt {what}: {} trailing bytes after offset {}",
                self.remaining(),
                self.pos
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trips_every_primitive_bit_exactly() {
        let mut w = BinWriter::with_header(b"TEST", 3);
        w.u8(200);
        w.bool(true);
        w.bool(false);
        w.u32(0xDEAD_BEEF);
        w.u64(u64::MAX - 1);
        w.usize(77);
        w.f64(-0.0);
        w.f64(f64::NAN);
        w.f32(1.5e-38);
        w.str("héllo");
        w.f64_slice(&[1.0, f64::INFINITY, -3.25]);
        w.bool_slice(&[true, false, true]);
        w.usize_slice(&[0, 9, 18]);
        w.rng_state([1, 2, 3, 4]);
        let bytes = w.into_bytes();

        let mut r = BinReader::new(&bytes);
        r.expect_magic(b"TEST", "test blob").unwrap();
        assert_eq!(r.u32("version").unwrap(), 3);
        assert_eq!(r.u8("a").unwrap(), 200);
        assert!(r.bool("b").unwrap());
        assert!(!r.bool("c").unwrap());
        assert_eq!(r.u32("d").unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.u64("e").unwrap(), u64::MAX - 1);
        assert_eq!(r.usize("f").unwrap(), 77);
        assert_eq!(r.f64("g").unwrap().to_bits(), (-0.0f64).to_bits());
        assert_eq!(r.f64("h").unwrap().to_bits(), f64::NAN.to_bits());
        assert_eq!(r.f32("i").unwrap().to_bits(), 1.5e-38f32.to_bits());
        assert_eq!(r.str(64, "j").unwrap(), "héllo");
        let v = r.f64_slice("k").unwrap();
        assert_eq!(v.len(), 3);
        assert_eq!(v[1], f64::INFINITY);
        assert_eq!(r.bool_slice("l").unwrap(), vec![true, false, true]);
        assert_eq!(r.usize_slice("m").unwrap(), vec![0, 9, 18]);
        assert_eq!(r.rng_state("n").unwrap(), [1, 2, 3, 4]);
        r.expect_end("test blob").unwrap();
    }

    #[test]
    fn bad_magic_and_truncation_fail_descriptively() {
        let mut w = BinWriter::with_header(b"GOOD", 1);
        w.u64(42);
        let bytes = w.into_bytes();

        let mut r = BinReader::new(&bytes);
        let err = r.expect_magic(b"WANT", "thing").unwrap_err();
        assert!(format!("{err:#}").contains("not a thing"), "{err:#}");

        let mut r = BinReader::new(&bytes[..6]);
        r.expect_magic(b"GOOD", "thing").unwrap();
        let err = r.u32("version").unwrap_err();
        assert!(format!("{err:#}").contains("truncated"), "{err:#}");
    }

    #[test]
    fn corrupt_lengths_are_rejected_before_allocation() {
        let mut w = BinWriter::new();
        w.usize(usize::MAX / 2);
        let bytes = w.into_bytes();
        let mut r = BinReader::new(&bytes);
        let err = r.f64_slice("huge").unwrap_err();
        assert!(format!("{err:#}").contains("corrupt huge"), "{err:#}");

        let mut w = BinWriter::new();
        w.u32(1_000_000);
        w.raw(b"abc");
        let bytes = w.into_bytes();
        let mut r = BinReader::new(&bytes);
        assert!(r.str(64, "name").is_err());

        let mut w = BinWriter::new();
        w.u8(7);
        let bytes = w.into_bytes();
        let mut r = BinReader::new(&bytes);
        assert!(r.bool("flag").is_err());
    }

    #[test]
    fn crc32_matches_the_ieee_reference_vector() {
        // the classic check value for CRC-32/IEEE
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn crc_footer_round_trips_and_catches_every_single_bit_flip() {
        let mut w = BinWriter::with_header(b"TEST", 1);
        w.u64(0xA5A5_5A5A_0F0F_F0F0);
        w.str("payload");
        let mut bytes = w.into_bytes();
        append_crc32(&mut bytes);

        let payload = check_crc32(&bytes, "test blob").unwrap();
        assert_eq!(payload.len(), bytes.len() - 4);

        for bit in 0..bytes.len() * 8 {
            let mut bad = bytes.clone();
            bad[bit / 8] ^= 1 << (bit % 8);
            let err = format!("{:#}", check_crc32(&bad, "test blob").unwrap_err());
            assert!(err.contains("CRC32 integrity check"), "bit {bit}: {err}");
        }

        let err = format!("{:#}", check_crc32(&bytes[..3], "test blob").unwrap_err());
        assert!(err.contains("too short"), "{err}");
    }

    #[test]
    fn expect_end_catches_trailing_garbage() {
        let mut w = BinWriter::new();
        w.u32(5);
        w.raw(b"xx");
        let bytes = w.into_bytes();
        let mut r = BinReader::new(&bytes);
        r.u32("v").unwrap();
        assert!(r.expect_end("blob").is_err());
    }
}
