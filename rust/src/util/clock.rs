//! Injectable time source (PR-8).
//!
//! The determinism contract (DESIGN.md, sfllm-lint rule D002) bans raw
//! `Instant::now()` outside the bench harness: wall-clock reads that
//! leak into simulated or reported results make runs unreproducible.
//! Components that legitimately need *telemetry* time — the training
//! orchestrator's phase walltimes, the allocator service's aggregate
//! summaries — take a `&dyn Clock` instead, so production wires in
//! [`WallClock`] (the one sanctioned ambient-time source, carrying the
//! justified D002 suppression) while tests and replays inject a
//! [`ManualClock`] and stay bit-reproducible.
//!
//! The trait is deliberately minimal: a monotonically non-decreasing
//! reading in seconds since an arbitrary per-clock epoch. Durations are
//! differences of readings; no clock arithmetic beyond that is needed.

use std::cell::Cell;

/// A monotonic time source, in seconds since an arbitrary epoch.
pub trait Clock {
    /// Current reading. Must be non-decreasing across calls.
    fn now(&self) -> f64;
}

/// Deterministic clock for tests and replays: time only moves when the
/// caller advances it.
#[derive(Debug, Default)]
pub struct ManualClock {
    t: Cell<f64>,
}

impl ManualClock {
    /// New clock at t = 0.
    pub fn new() -> Self {
        ManualClock { t: Cell::new(0.0) }
    }

    /// Jump to an absolute reading (must not go backwards).
    pub fn set(&self, t: f64) {
        debug_assert!(t >= self.t.get(), "ManualClock moved backwards");
        self.t.set(t);
    }

    /// Advance by `dt` seconds (dt >= 0).
    pub fn advance(&self, dt: f64) {
        debug_assert!(dt >= 0.0, "ManualClock advanced by negative dt");
        self.t.set(self.t.get() + dt);
    }
}

/// The production [`Clock`]: wall time in seconds since the clock was
/// created. This is the single sanctioned ambient-time source — it
/// exists so the PR-9 architecture contract can keep `coordinator`
/// from depending on the `bench` harness just to read the time;
/// everything else takes a `&dyn Clock` and never reads ambient time.
#[derive(Clone, Debug)]
pub struct WallClock {
    origin: std::time::Instant,
}

impl WallClock {
    /// New clock whose epoch is "now".
    #[allow(clippy::disallowed_methods)] // the one sanctioned Instant::now, injected as Clock
    pub fn new() -> Self {
        // lint:allow(D002) the single sanctioned wall-clock read; consumers see only an injected Clock
        WallClock { origin: std::time::Instant::now() }
    }
}

impl Default for WallClock {
    fn default() -> Self {
        WallClock::new()
    }
}

impl Clock for WallClock {
    fn now(&self) -> f64 {
        self.origin.elapsed().as_secs_f64()
    }
}

impl Clock for ManualClock {
    fn now(&self) -> f64 {
        self.t.get()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manual_clock_starts_at_zero_and_advances() {
        let c = ManualClock::new();
        assert_eq!(c.now(), 0.0);
        c.advance(1.5);
        assert_eq!(c.now(), 1.5);
        c.advance(0.5);
        assert_eq!(c.now(), 2.0);
    }

    #[test]
    fn manual_clock_set_is_absolute() {
        let c = ManualClock::new();
        c.set(10.0);
        assert_eq!(c.now(), 10.0);
        c.set(10.0); // equal is fine
        assert_eq!(c.now(), 10.0);
    }

    #[test]
    fn trait_object_usable() {
        let c = ManualClock::new();
        let dynclock: &dyn Clock = &c;
        let t0 = dynclock.now();
        c.advance(3.0);
        assert_eq!(dynclock.now() - t0, 3.0);
    }
}
