//! Micro property-testing harness (no proptest in the offline set).
//!
//! [`check`] runs a property over N seeded cases; on failure it reports
//! the failing case index and seed so the case replays exactly. Used by
//! the optimizer-invariant tests (`rust/tests/prop_optimizer.rs`).

use crate::util::rng::Rng;

/// Outcome of a single property case.
pub type CaseResult = Result<(), String>;

/// Run `cases` seeded property executions; panic with the first failure.
///
/// The closure receives a per-case [`Rng`] derived from (`seed`, case
/// index), so failures print a standalone reproduction seed.
pub fn check<F: FnMut(&mut Rng) -> CaseResult>(name: &str, seed: u64, cases: usize, mut f: F) {
    for case in 0..cases {
        let case_seed = seed ^ (case as u64).wrapping_mul(0x2545F4914F6CDD1D);
        let mut rng = Rng::new(case_seed);
        if let Err(msg) = f(&mut rng) {
            panic!(
                "property '{name}' failed at case {case}/{cases} \
                 (replay seed {case_seed:#x}): {msg}"
            );
        }
    }
}

/// Assert helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("trivial", 1, 25, |rng| {
            count += 1;
            let v = rng.f64();
            if (0.0..1.0).contains(&v) {
                Ok(())
            } else {
                Err(format!("out of range {v}"))
            }
        });
        assert_eq!(count, 25);
    }

    #[test]
    #[should_panic(expected = "property 'failing'")]
    fn failing_property_panics_with_seed() {
        check("failing", 2, 10, |rng| {
            if rng.f64() < 2.0 {
                Err("always".to_string())
            } else {
                Ok(())
            }
        });
    }
}
