//! CSV writer for experiment outputs (loss curves, latency sweeps).
//!
//! Every bench writes its series under `results/` so figures can be
//! re-plotted without re-running; EXPERIMENTS.md references these files.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

use anyhow::{Context, Result};

/// Create the parent directory of an output file, so writers never
/// fail on a fresh checkout just because `results/` doesn't exist yet.
pub fn ensure_parent_dir<P: AsRef<Path>>(path: P) -> Result<()> {
    if let Some(dir) = path.as_ref().parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating {}", dir.display()))?;
        }
    }
    Ok(())
}

/// Streaming CSV writer with a fixed header.
pub struct CsvWriter {
    out: BufWriter<File>,
    cols: usize,
}

impl CsvWriter {
    /// Create (truncating) `path` and write the header row.
    pub fn create<P: AsRef<Path>>(path: P, header: &[&str]) -> Result<CsvWriter> {
        ensure_parent_dir(&path)?;
        let f = File::create(&path)
            .with_context(|| format!("creating {}", path.as_ref().display()))?;
        let mut w = CsvWriter {
            out: BufWriter::new(f),
            cols: header.len(),
        };
        w.write_raw(header)?;
        Ok(w)
    }

    fn write_raw(&mut self, fields: &[&str]) -> Result<()> {
        let mut first = true;
        for f in fields {
            if !first {
                self.out.write_all(b",")?;
            }
            first = false;
            self.out.write_all(escape_field(f).as_bytes())?;
        }
        self.out.write_all(b"\n")?;
        Ok(())
    }

    /// Write one row; panics (in debug) if column count mismatches.
    pub fn row(&mut self, fields: &[String]) -> Result<()> {
        debug_assert_eq!(fields.len(), self.cols, "csv column mismatch");
        let refs: Vec<&str> = fields.iter().map(String::as_str).collect();
        self.write_raw(&refs)
    }

    /// Convenience: all-numeric row.
    pub fn row_f64(&mut self, fields: &[f64]) -> Result<()> {
        let strs: Vec<String> = fields.iter().map(|v| format!("{v}")).collect();
        self.row(&strs)
    }

    pub fn flush(&mut self) -> Result<()> {
        self.out.flush()?;
        Ok(())
    }
}

/// Escape one field exactly the way [`CsvWriter`] serializes it:
/// quoted (with `""` doubling) iff it contains a comma, quote, or
/// newline. Shared with [`crate::sim::SweepReport`] so its in-memory
/// CSV string and the file on disk are byte-identical.
pub fn escape_field(f: &str) -> String {
    if f.contains(',') || f.contains('"') || f.contains('\n') {
        format!("\"{}\"", f.replace('"', "\"\""))
    } else {
        f.to_string()
    }
}

/// Read a simple CSV (no embedded newlines) into (header, rows).
pub fn read_csv<P: AsRef<Path>>(path: P) -> Result<(Vec<String>, Vec<Vec<String>>)> {
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("reading {}", path.as_ref().display()))?;
    let mut lines = text.lines();
    let header = lines
        .next()
        .map(split_csv_line)
        .unwrap_or_default();
    let rows = lines
        .filter(|l| !l.trim().is_empty())
        .map(split_csv_line)
        .collect();
    Ok((header, rows))
}

fn split_csv_line(line: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut field = String::new();
    let mut in_quotes = false;
    let mut chars = line.chars().peekable();
    while let Some(c) = chars.next() {
        match c {
            '"' if in_quotes && chars.peek() == Some(&'"') => {
                chars.next();
                field.push('"');
            }
            '"' => in_quotes = !in_quotes,
            ',' if !in_quotes => {
                out.push(std::mem::take(&mut field));
            }
            _ => field.push(c),
        }
    }
    out.push(field);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trips_through_reader() {
        let dir = std::env::temp_dir().join("sfllm_csv_rt");
        let path = dir.join("rt.csv");
        {
            let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
            w.row(&["1".into(), "x,\"y".into()]).unwrap();
            w.flush().unwrap();
        }
        let (header, rows) = read_csv(&path).unwrap();
        assert_eq!(header, vec!["a", "b"]);
        assert_eq!(rows, vec![vec!["1".to_string(), "x,\"y".to_string()]]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn writes_header_and_rows() {
        let dir = std::env::temp_dir().join("sfllm_csv_test");
        let path = dir.join("t.csv");
        {
            let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
            w.row(&["1".into(), "x,y".into()]).unwrap();
            w.row_f64(&[2.5, 3.0]).unwrap();
            w.flush().unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\n1,\"x,y\"\n2.5,3\n");
        std::fs::remove_dir_all(&dir).ok();
    }
}
