//! Deterministic PRNG: xoshiro256++ seeded via SplitMix64.
//!
//! The offline crate set has no `rand`, and determinism is a feature
//! here anyway — every experiment in EXPERIMENTS.md is replayable from
//! its seed. The generator passes the usual smoke statistics (see unit
//! tests) and is more than adequate for scenario sampling.

/// xoshiro256++ (Blackman & Vigna) with SplitMix64 seeding.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed deterministically; any u64 is a valid seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream (for per-client / per-thread rngs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Snapshot the generator state (for checkpoint/resume). The four
    /// words are the raw xoshiro256++ state; feeding them back through
    /// [`Rng::from_state`] resumes the exact stream position.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator at a previously snapshotted stream position.
    pub fn from_state(s: [u64; 4]) -> Rng {
        Rng { s }
    }

    pub fn next_u64(&mut self) -> u64 {
        let [mut s0, mut s1, mut s2, mut s3] = self.s;
        let result = s0.wrapping_add(s3).rotate_left(23).wrapping_add(s0);
        let t = s1 << 17;
        s2 ^= s0;
        s3 ^= s1;
        s1 ^= s2;
        s0 ^= s3;
        s2 ^= t;
        s3 = s3.rotate_left(45);
        self.s = [s0, s1, s2, s3];
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n) without modulo bias (n > 0).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        let n = n as u64;
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n) as usize;
            }
        }
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with given mean / standard deviation.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval_and_uniformish() {
        let mut r = Rng::new(42);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let v = r.normal();
            s1 += v;
            s2 += v * v;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn state_snapshot_resumes_exact_stream() {
        let mut a = Rng::new(123);
        for _ in 0..17 {
            a.next_u64();
        }
        let snap = a.state();
        let tail: Vec<u64> = (0..32).map(|_| a.next_u64()).collect();
        let mut b = Rng::from_state(snap);
        let resumed: Vec<u64> = (0..32).map(|_| b.next_u64()).collect();
        assert_eq!(tail, resumed);
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut base = Rng::new(9);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }
}
