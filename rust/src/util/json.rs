//! Minimal JSON parser — just enough for `artifacts/manifest.json`.
//!
//! Supports the full JSON grammar except exotic number forms beyond
//! f64. No serde in the offline crate set; this stays ~200 lines and is
//! unit-tested against the shapes the AOT exporter actually emits.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            b: text.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key '{key}'")),
            _ => bail!("not an object (looking for '{key}')"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("not a non-negative integer: {n}");
        }
        Ok(n as usize)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at byte {}", c as char, self.i);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}', got '{}' at {}", c as char, self.i),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']', got '{}' at {}", c as char, self.i),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("bad \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                }
                _ => {
                    // copy raw UTF-8 bytes through
                    let start = self.i - 1;
                    while self.i < self.b.len()
                        && self.b[self.i] != b'"'
                        && self.b[self.i] != b'\\'
                    {
                        self.i += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse::<f64>().map_err(|e| {
            anyhow!("bad number '{text}' at byte {start}: {e}")
        })?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": {}}"#).unwrap();
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").unwrap().as_str().unwrap(), "x");
    }

    #[test]
    fn manifest_like_shape() {
        let j = Json::parse(
            r#"{"variants": {"tiny_s2_r4": {"rank": 4, "entries":
               {"client_fwd": {"inputs": [{"name": "wte", "shape": [256, 192],
               "dtype": "f32"}]}}}}}"#,
        )
        .unwrap();
        let v = j.get("variants").unwrap().get("tiny_s2_r4").unwrap();
        assert_eq!(v.get("rank").unwrap().as_usize().unwrap(), 4);
        let inp = &v.get("entries").unwrap().get("client_fwd").unwrap().get("inputs").unwrap()
            .as_arr()
            .unwrap()[0];
        let shape: Vec<usize> = inp
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|s| s.as_usize().unwrap())
            .collect();
        assert_eq!(shape, vec![256, 192]);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(
            Json::parse("\"\\u0041\"").unwrap(),
            Json::Str("A".into())
        );
    }
}
