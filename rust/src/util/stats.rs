//! Small statistics helpers used by benches and the experiment harness.
//!
//! Sweep data can legitimately contain non-finite delays — an
//! infeasible grid point reports `f64::INFINITY` (a zero-rate client in
//! `Scenario::phase_delays`) — so every aggregate here is defined on
//! the *finite* subset of its input: `mean`/`std_dev` skip non-finite
//! values instead of poisoning to NaN, and `percentile` orders with
//! `total_cmp` instead of panicking on NaN.

/// Arithmetic mean of the finite entries; non-finite values (±∞, NaN)
/// are skipped. 0.0 when no finite entry exists.
pub fn mean(xs: &[f64]) -> f64 {
    let mut sum = 0.0;
    let mut n = 0usize;
    for &x in xs {
        if x.is_finite() {
            sum += x;
            n += 1;
        }
    }
    if n == 0 {
        0.0
    } else {
        sum / n as f64
    }
}

/// Population standard deviation of the finite entries; 0.0 when fewer
/// than two finite entries exist.
pub fn std_dev(xs: &[f64]) -> f64 {
    let m = mean(xs);
    let mut acc = 0.0;
    let mut n = 0usize;
    for &x in xs {
        if x.is_finite() {
            acc += (x - m) * (x - m);
            n += 1;
        }
    }
    if n < 2 {
        0.0
    } else {
        (acc / n as f64).sqrt()
    }
}

/// Percentile by linear interpolation, p in [0, 100]. NaNs are dropped;
/// ±∞ participate (an infeasible tail shows up as an infinite high
/// percentile). 0.0 for input with no non-NaN entry.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    let mut v: Vec<f64> = xs.iter().copied().filter(|x| !x.is_nan()).collect();
    if v.is_empty() {
        return 0.0;
    }
    v.sort_by(f64::total_cmp);
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi || v[lo] == v[hi] {
        // the equal-value guard also keeps inf..inf from producing
        // inf + 0*(inf - inf) = NaN
        v[lo]
    } else if !v[lo].is_finite() {
        // interpolating away from an infinite endpoint saturates at it
        // (-inf..x stays -inf; also covers -inf..inf without inf - inf)
        v[lo]
    } else if !v[hi].is_finite() {
        v[hi]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// min/max that ignore NaN-free assumption violations gracefully.
pub fn min(xs: &[f64]) -> f64 {
    let mut m = f64::INFINITY;
    for x in xs {
        m = f64::min(m, *x);
    }
    m
}

pub fn max(xs: &[f64]) -> f64 {
    let mut m = f64::NEG_INFINITY;
    for x in xs {
        m = f64::max(m, *x);
    }
    m
}

/// Fixed-order f64 sum: a plain left-to-right loop, bit-identical to
/// `Iterator::sum::<f64>()` on the same iteration order. This is the
/// sanctioned `D104` reduction — call sites that spell the loop out
/// through this helper are visibly committed to the in-order
/// accumulation the reproducibility contract freezes, and the lint's
/// taint pass (unwrap/sum reachable from a spawn site) stays silent
/// because there is no `.sum()`/`.fold()` anywhere on the path.
pub fn fsum(xs: impl IntoIterator<Item = f64>) -> f64 {
    let mut acc = 0.0f64;
    for x in xs {
        acc += x;
    }
    acc
}

/// Fixed-order f32 sum; see [`fsum`].
pub fn fsum32(xs: impl IntoIterator<Item = f32>) -> f32 {
    let mut acc = 0.0f32;
    for x in xs {
        acc += x;
    }
    acc
}

/// Fixed-order usize sum; see [`fsum`]. Integer addition commutes, but
/// routing counts through the same helper keeps spawn-reachable code
/// free of bare iterator reductions.
pub fn usum(xs: impl IntoIterator<Item = usize>) -> usize {
    let mut acc = 0usize;
    for x in xs {
        acc += x;
    }
    acc
}

/// Straggler max over non-negative stage delays (Eqs. 16/17): the
/// slowest participant bounds the stage, with 0.0 for an empty cohort.
///
/// Value-identical to `fold(0.0, f64::max)` on the non-negative,
/// NaN-free inputs every preset produces, but NaN-*propagating* for
/// both NaN signs — `f64::max` silently drops a NaN argument, and a
/// `total_cmp`-based max would order negative-signed NaNs (what x86
/// produces for 0·∞) *below* −∞ and drop them too. This is the
/// sanctioned `N002` reduction for scoring/argmax paths in
/// `opt/`/`delay/`/`sim/`.
pub fn stage_max(xs: impl IntoIterator<Item = f64>) -> f64 {
    let mut m = 0.0f64;
    for x in xs {
        if x.is_nan() {
            return f64::NAN;
        }
        if x > m {
            m = x;
        }
    }
    m
}

/// Simple least-squares fit of y = a + b*x; returns (a, b).
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let mx = mean(xs);
    let my = mean(ys);
    let mut num = 0.0;
    let mut den = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        num += (x - mx) * (y - my);
        den += (x - mx) * (x - mx);
    }
    let b = if den.abs() < 1e-300 { 0.0 } else { num / den };
    let _ = n;
    (my - b * mx, b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&v), 2.5);
        assert!((std_dev(&v) - 1.118).abs() < 1e-3);
    }

    #[test]
    fn percentiles() {
        let v = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 100.0), 4.0);
        assert_eq!(percentile(&v, 50.0), 2.5);
    }

    #[test]
    fn fit_recovers_line() {
        let xs: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 + 2.0 * x).collect();
        let (a, b) = linear_fit(&xs, &ys);
        assert!((a - 3.0).abs() < 1e-9);
        assert!((b - 2.0).abs() < 1e-9);
    }

    #[test]
    fn empty_inputs_are_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn stage_max_matches_fold_on_clean_input_and_propagates_nan() {
        let xs = [0.25, 3.5, 1.0, f64::INFINITY, 2.0];
        assert_eq!(
            stage_max(xs.iter().copied()),
            xs.iter().copied().fold(0.0f64, f64::max)
        );
        assert_eq!(stage_max([0.0f64; 0]), 0.0);
        assert_eq!(stage_max([0.0, 0.5]), 0.5);
        // f64::max would silently drop the NaN; stage_max surfaces it,
        // including the negative-signed NaN x86 produces for 0*inf.
        assert!(stage_max([1.0, f64::NAN, 2.0]).is_nan());
        assert!(stage_max([1.0, -f64::NAN]).is_nan());
    }

    #[test]
    fn fixed_order_sums_match_iterator_sum() {
        let xs = [0.1, 0.7, 1e16, -1e16, 0.3];
        assert_eq!(fsum(xs.iter().copied()), xs.iter().copied().sum::<f64>());
        assert_eq!(fsum(std::iter::empty()), 0.0);
        let ys = [0.5f32, 1.25, -0.75];
        assert_eq!(fsum32(ys.iter().copied()), ys.iter().copied().sum::<f32>());
        assert_eq!(usum([3usize, 4, 5]), 12);
    }

    #[test]
    fn non_finite_values_do_not_poison_mean_or_std() {
        // infeasible sweep points report infinite delay
        assert_eq!(mean(&[1.0, f64::INFINITY, 3.0]), 2.0);
        assert_eq!(mean(&[1.0, f64::NAN, 3.0]), 2.0);
        assert_eq!(mean(&[f64::INFINITY, f64::NAN]), 0.0);
        let finite = std_dev(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(std_dev(&[1.0, 2.0, f64::NEG_INFINITY, 3.0, 4.0, f64::NAN]), finite);
        assert_eq!(std_dev(&[f64::INFINITY, 5.0]), 0.0);
    }

    #[test]
    fn percentile_is_nan_safe_and_keeps_infinities() {
        // NaN used to panic via partial_cmp().unwrap()
        assert_eq!(percentile(&[2.0, f64::NAN, 1.0, 3.0], 50.0), 2.0);
        assert_eq!(percentile(&[f64::NAN, f64::NAN], 50.0), 0.0);
        // infinite tail is visible at the top, finite body below
        let v = [1.0, 2.0, 3.0, f64::INFINITY];
        assert_eq!(percentile(&v, 100.0), f64::INFINITY);
        assert_eq!(percentile(&v, 0.0), 1.0);
        // all-infinite input interpolates to infinity, not NaN
        assert_eq!(percentile(&[f64::INFINITY, f64::INFINITY], 50.0), f64::INFINITY);
        // infinite endpoints never leak NaN out of the interpolation
        assert_eq!(percentile(&[f64::NEG_INFINITY, 1.0], 50.0), f64::NEG_INFINITY);
        assert_eq!(percentile(&[1.0, f64::INFINITY], 50.0), f64::INFINITY);
        assert_eq!(
            percentile(&[f64::NEG_INFINITY, f64::INFINITY], 50.0),
            f64::NEG_INFINITY
        );
    }
}
