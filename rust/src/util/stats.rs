//! Small statistics helpers used by benches and the experiment harness.

/// Arithmetic mean; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile by linear interpolation, p in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// min/max that ignore NaN-free assumption violations gracefully.
pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Simple least-squares fit of y = a + b*x; returns (a, b).
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let mx = mean(xs);
    let my = mean(ys);
    let mut num = 0.0;
    let mut den = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        num += (x - mx) * (y - my);
        den += (x - mx) * (x - mx);
    }
    let b = if den.abs() < 1e-300 { 0.0 } else { num / den };
    let _ = n;
    (my - b * mx, b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&v), 2.5);
        assert!((std_dev(&v) - 1.118).abs() < 1e-3);
    }

    #[test]
    fn percentiles() {
        let v = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 100.0), 4.0);
        assert_eq!(percentile(&v, 50.0), 2.5);
    }

    #[test]
    fn fit_recovers_line() {
        let xs: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 + 2.0 * x).collect();
        let (a, b) = linear_fit(&xs, &ys);
        assert!((a - 3.0).abs() < 1e-9);
        assert!((b - 2.0).abs() < 1e-9);
    }

    #[test]
    fn empty_inputs_are_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }
}
