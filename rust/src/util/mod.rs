//! Shared substrates the offline image forces us to own: PRNG, CLI,
//! TOML/JSON parsing, CSV output, basic statistics, and a tiny
//! property-testing harness built on the PRNG.

pub mod cli;
pub mod clock;
pub mod codec;
pub mod csv;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod toml;
