//! Tiny CLI argument parser (no clap in the offline crate set).
//!
//! Grammar: `--key value`, `--flag` (boolean), and positional args.
//! Unknown keys are collected and reported by [`Args::finish`] so every
//! binary fails loudly on typos.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

/// Parsed command line.
#[derive(Debug, Default)]
pub struct Args {
    named: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
    consumed: Vec<String>,
}

impl Args {
    /// Parse from `std::env::args` (skipping argv\[0\]).
    pub fn from_env() -> Args {
        Self::from_iter(std::env::args().skip(1))
    }

    pub fn from_iter<I: IntoIterator<Item = String>>(iter: I) -> Args {
        let mut args = Args::default();
        let mut it = iter.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                // `--key=value` or `--key value` or bare flag
                if let Some((k, v)) = key.split_once('=') {
                    args.named.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    args.named.insert(key.to_string(), v);
                } else {
                    args.flags.push(key.to_string());
                }
            } else {
                args.positional.push(a);
            }
        }
        args
    }

    pub fn flag(&mut self, name: &str) -> bool {
        self.consumed.push(name.to_string());
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&mut self, name: &str) -> Option<String> {
        self.consumed.push(name.to_string());
        self.named.get(name).cloned()
    }

    pub fn str_or(&mut self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or_else(|| default.to_string())
    }

    pub fn f64_or(&mut self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow!("--{name}: bad float '{v}': {e}")),
        }
    }

    pub fn usize_or(&mut self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow!("--{name}: bad integer '{v}': {e}")),
        }
    }

    pub fn u64_or(&mut self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow!("--{name}: bad integer '{v}': {e}")),
        }
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// Error on any argument that no call above asked about.
    pub fn finish(&self) -> Result<()> {
        for k in self.named.keys() {
            if !self.consumed.iter().any(|c| c == k) {
                bail!("unknown option --{k}");
            }
        }
        for f in &self.flags {
            if !self.consumed.iter().any(|c| c == f) {
                bail!("unknown flag --{f}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::from_iter(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_named_flags_positional() {
        let mut a = parse("run --steps 100 --verbose --lr=0.01 file.toml");
        assert_eq!(a.usize_or("steps", 0).unwrap(), 100);
        assert!(a.flag("verbose"));
        assert_eq!(a.f64_or("lr", 0.0).unwrap(), 0.01);
        assert_eq!(a.positional(), &["run".to_string(), "file.toml".to_string()]);
        a.finish().unwrap();
    }

    #[test]
    fn defaults_apply() {
        let mut a = parse("");
        assert_eq!(a.usize_or("k", 5).unwrap(), 5);
        assert!(!a.flag("x"));
    }

    #[test]
    fn unknown_option_rejected() {
        let mut a = parse("--typo 3");
        let _ = a.usize_or("steps", 0);
        assert!(a.finish().is_err());
    }

    #[test]
    fn bad_value_errors() {
        let mut a = parse("--steps abc");
        assert!(a.usize_or("steps", 0).is_err());
    }
}
