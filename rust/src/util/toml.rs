//! TOML-subset parser for experiment config files.
//!
//! Supported: `[section]` / `[a.b]` headers, `key = value` with string,
//! integer, float, boolean and flat-array values, `#` comments. This
//! covers everything `configs/*.toml` uses; nested tables beyond one
//! dotted level and multi-line values are intentionally out of scope.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// A scalar or flat-array TOML value.
#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            TomlValue::Float(f) => Ok(*f),
            TomlValue::Int(i) => Ok(*i as f64),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_i64(&self) -> Result<i64> {
        match self {
            TomlValue::Int(i) => Ok(*i),
            _ => bail!("not an integer: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let i = self.as_i64()?;
        if i < 0 {
            bail!("negative where usize expected: {i}");
        }
        Ok(i as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            TomlValue::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            TomlValue::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }

    pub fn as_f64_arr(&self) -> Result<Vec<f64>> {
        match self {
            TomlValue::Arr(v) => v.iter().map(|x| x.as_f64()).collect(),
            _ => bail!("not an array: {self:?}"),
        }
    }
}

/// Parsed document: keys are `"section.key"` (or bare `"key"` before
/// any section header).
#[derive(Clone, Debug, Default)]
pub struct TomlDoc {
    pub entries: BTreeMap<String, TomlValue>,
}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<TomlDoc> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .with_context(|| format!("line {}: unterminated section", lineno + 1))?
                    .trim();
                if name.is_empty() {
                    bail!("line {}: empty section name", lineno + 1);
                }
                section = name.to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .with_context(|| format!("line {}: expected key = value", lineno + 1))?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            let value = parse_value(v.trim())
                .with_context(|| format!("line {}: bad value '{}'", lineno + 1, v.trim()))?;
            doc.entries.insert(key, value);
        }
        Ok(doc)
    }

    pub fn get(&self, key: &str) -> Option<&TomlValue> {
        self.entries.get(key)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        self.get(key).map_or(Ok(default), |v| v.as_f64())
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        self.get(key).map_or(Ok(default), |v| v.as_usize())
    }

    pub fn str_or(&self, key: &str, default: &str) -> Result<String> {
        self.get(key)
            .map_or(Ok(default.to_string()), |v| Ok(v.as_str()?.to_string()))
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' outside quotes starts a comment
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue> {
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .context("unterminated string")?;
        return Ok(TomlValue::Str(inner.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner.strip_suffix(']').context("unterminated array")?;
        let mut out = Vec::new();
        for part in split_top(inner) {
            let part = part.trim();
            if !part.is_empty() {
                out.push(parse_value(part)?);
            }
        }
        return Ok(TomlValue::Arr(out));
    }
    if !s.contains('.') && !s.contains('e') && !s.contains('E') {
        if let Ok(i) = s.parse::<i64>() {
            return Ok(TomlValue::Int(i));
        }
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    bail!("unparseable value '{s}'")
}

/// Split on commas that are not inside quotes.
fn split_top(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_typical_config() {
        let doc = TomlDoc::parse(
            r#"
            # paper Table II
            seed = 42
            [system]
            clients = 5            # K
            bandwidth_hz = 500e3
            ranks = [1, 2, 4, 6, 8]
            name = "tableII"
            shadowing = true
            "#,
        )
        .unwrap();
        assert_eq!(doc.get("seed").unwrap().as_i64().unwrap(), 42);
        assert_eq!(doc.get("system.clients").unwrap().as_usize().unwrap(), 5);
        assert_eq!(doc.get("system.bandwidth_hz").unwrap().as_f64().unwrap(), 500e3);
        assert_eq!(
            doc.get("system.ranks").unwrap().as_f64_arr().unwrap(),
            vec![1.0, 2.0, 4.0, 6.0, 8.0]
        );
        assert_eq!(doc.get("system.name").unwrap().as_str().unwrap(), "tableII");
        assert!(doc.get("system.shadowing").unwrap().as_bool().unwrap());
    }

    #[test]
    fn defaults() {
        let doc = TomlDoc::parse("").unwrap();
        assert_eq!(doc.f64_or("x", 1.5).unwrap(), 1.5);
        assert_eq!(doc.usize_or("y", 3).unwrap(), 3);
    }

    #[test]
    fn comment_inside_string_kept() {
        let doc = TomlDoc::parse("k = \"a#b\"").unwrap();
        assert_eq!(doc.get("k").unwrap().as_str().unwrap(), "a#b");
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(TomlDoc::parse("[unterminated").is_err());
        assert!(TomlDoc::parse("novalue").is_err());
        assert!(TomlDoc::parse("k = @@").is_err());
    }
}
