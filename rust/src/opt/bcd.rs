//! Algorithm 3 — BCD over the four subproblems P1–P4.
//!
//! Each outer iteration alternates: greedy subchannel assignment
//! (Algorithm 2), exact convex power control (P2), and the exhaustive
//! *joint* split×rank search (P3+P4 together, the paper's "exhaustive
//! search … for optimal split position and rank selection"). Running
//! P3 and P4 as two sequential 1-D scans can settle on a (μ, r) pair a
//! true joint scan beats — split depth and adapter rank trade off
//! against each other (deeper split ⇒ more client LoRA compute and a
//! larger federated upload per rank) — so the joint grid is scanned on
//! a cached [`DelayEvaluator`], which makes the full grid cheaper than
//! the two clone-per-candidate 1-D scans used to be (see
//! `benches/micro_hotpath.rs`).
//!
//! The paper notes the mixed-integer problem has no formal convergence
//! guarantee; we add the standard safeguard of only *accepting* a
//! block update if it does not worsen the objective, which makes the
//! trajectory monotonically non-increasing (asserted by the property
//! tests) while preserving the paper's update order.
//!
//! The inner loop runs on the incremental engines: P1 is the heap-based
//! [`assignment::algorithm2_with`] sharing one [`assignment::AssignScratch`]
//! (per-link sort orders hoisted out of the iterations), and P2 is
//! [`power::solve_power_hinted`] warm-started from the previous
//! iteration's `(t1, t3)` with reused probe buffers — both bit-identical
//! to their one-shot forms (property-tested), so the trajectory is
//! unchanged and only the per-iteration cost drops.
//!
//! The loop is objective-generic ([`crate::opt::Objective`]): the
//! P1/P2 block is scored through `objective::score_alloc` (so a comm
//! block that wins delay but loses the weighted or budgeted score is
//! rejected — P2 itself still solves the paper's min-max delay
//! program, the objective enters at the acceptance step), and P3+P4
//! run as [`DelayEvaluator::best_split_rank_obj`]. Under the default
//! [`Objective::Delay`] every comparison is bit-identical to the
//! pure-delay loop.

use anyhow::{bail, Result};

use crate::delay::{Allocation, ConvergenceModel, DelayEvaluator, Scenario, WorkloadCache};
use crate::delay::objective::{score_alloc, Objective};
use crate::opt::{assignment, power};

/// Options for the BCD loop.
#[derive(Clone, Debug)]
pub struct BcdOptions {
    /// Convergence tolerance ε on the objective.
    pub eps: f64,
    /// Maximum outer iterations τ_max.
    pub max_iter: usize,
    /// Candidate LoRA ranks for P4.
    pub ranks: Vec<usize>,
    /// Initial split point and rank.
    pub init_l_c: usize,
    pub init_rank: usize,
    /// Optimization objective; `None` (the default) resolves the
    /// scenario's own `objective` config — which is pure delay unless
    /// a config/preset/axis says otherwise.
    pub objective: Option<Objective>,
}

impl Default for BcdOptions {
    fn default() -> Self {
        BcdOptions {
            eps: 1e-6,
            max_iter: 20,
            ranks: vec![1, 2, 4, 6, 8],
            init_l_c: 0, // 0 = pick the middle of the model
            init_rank: 4,
            objective: None,
        }
    }
}

/// Output of [`optimize`].
#[derive(Clone, Debug)]
pub struct BcdResult {
    pub alloc: Allocation,
    /// Final objective score (equals `delay` under the delay
    /// objective; joules under `energy`; etc.).
    pub objective: f64,
    /// Total training delay T (Eq. 17) of `alloc`, seconds.
    pub delay: f64,
    /// Total training energy of `alloc` at the scenario's ζ, joules.
    pub energy: f64,
    /// Objective after every outer iteration (monotone non-increasing).
    pub trajectory: Vec<f64>,
    pub iterations: usize,
}

/// Build a feasible initial allocation: Algorithm 2 assignment at the
/// nominal PSD, scaled into the power budgets.
pub fn initial_alloc(scn: &Scenario, l_c: usize, rnk: usize) -> Allocation {
    initial_alloc_with(scn, l_c, rnk, &mut assignment::AssignScratch::new())
}

/// [`initial_alloc`] reusing the caller's [`assignment::AssignScratch`]
/// (the BCD loop shares one scratch between the initial allocation and
/// every P1 iteration, so each link is sorted once per solve).
pub fn initial_alloc_with(
    scn: &Scenario,
    l_c: usize,
    rnk: usize,
    scratch: &mut assignment::AssignScratch,
) -> Allocation {
    let a = assignment::algorithm2_with(scn, l_c, rnk, scratch);
    let mut alloc = Allocation {
        assign_main: a.assign_main,
        assign_fed: a.assign_fed,
        psd_main: vec![a.psd_main_nominal; scn.main_link.subch.len()],
        psd_fed: vec![a.psd_fed_nominal; scn.fed_link.subch.len()],
        l_c,
        rank: rnk,
    };
    scale_into_budget(scn, &mut alloc);
    alloc
}

/// Scale PSDs down until C4/C5 hold (used for nominal and random
/// allocations; never scales up). The constraints are per-link — C4
/// caps each client on each link separately, C5 caps each server's
/// total — so each link is scaled by *its own* worst violation ratio:
/// a fed-link budget overrun must not throttle main-link PSDs (or vice
/// versa), which the old shared scale factor did.
pub fn scale_into_budget(scn: &Scenario, alloc: &mut Allocation) {
    let mut worst_main: f64 = 1.0;
    let mut worst_fed: f64 = 1.0;
    let mut tot_main = 0.0;
    let mut tot_fed = 0.0;
    for k in 0..scn.k() {
        let pm = scn.power_main(alloc, k);
        let pf = scn.power_fed(alloc, k);
        if pm > 0.0 {
            worst_main = worst_main.max(pm / scn.p_max_w);
        }
        if pf > 0.0 {
            worst_fed = worst_fed.max(pf / scn.p_max_w);
        }
        tot_main += pm;
        tot_fed += pf;
    }
    if tot_main > 0.0 {
        worst_main = worst_main.max(tot_main / scn.p_th_main_w);
    }
    if tot_fed > 0.0 {
        worst_fed = worst_fed.max(tot_fed / scn.p_th_fed_w);
    }
    if worst_main > 1.0 {
        let s = 1.0 / worst_main;
        alloc.psd_main.iter_mut().for_each(|p| *p *= s);
    }
    if worst_fed > 1.0 {
        let s = 1.0 / worst_fed;
        alloc.psd_fed.iter_mut().for_each(|p| *p *= s);
    }
}

/// Algorithm 3: alternate P1–P4 until |ΔT| ≤ ε or τ_max.
pub fn optimize(scn: &Scenario, conv: &ConvergenceModel, opts: &BcdOptions) -> Result<BcdResult> {
    optimize_cached(scn, conv, opts, &WorkloadCache::new())
}

/// [`optimize`] with a caller-provided [`WorkloadCache`], so repeated
/// solves over the same model/sequence/rank set (sweep grid points,
/// convergence benches) share one workload table.
pub fn optimize_cached(
    scn: &Scenario,
    conv: &ConvergenceModel,
    opts: &BcdOptions,
    cache: &WorkloadCache,
) -> Result<BcdResult> {
    let objective = match opts.objective {
        Some(o) => o,
        None => Objective::from_config(&scn.objective)?,
    };
    let table = cache.table_for(&scn.profile, &opts.ranks);
    let init_l_c = if opts.init_l_c == 0 {
        (scn.profile.blocks.len() / 2).max(1)
    } else {
        opts.init_l_c
    };
    // Per-solve reusable state: one assignment scratch (each link's
    // widest-first/phase-1 sorts are computed once, not per iteration),
    // one set of P2 probe buffers, and the last P2 optimum as the next
    // iteration's warm-start hint. None of these change any result —
    // the hinted P2 solve is bit-identical to the cold one — they only
    // cut the per-iteration cost (tracked by `benches/micro_hotpath.rs`
    // and the `bench` CLI).
    let mut assign_scratch = assignment::AssignScratch::new();
    let mut power_scratch = power::PowerScratch::default();
    let mut p2_hint: Option<(f64, f64)> = None;
    let mut alloc = initial_alloc_with(scn, init_l_c, opts.init_rank, &mut assign_scratch);
    let mut obj = score_alloc(scn, &alloc, conv, &objective);
    let mut trajectory = vec![obj];
    let mut iters = 0;

    for _ in 0..opts.max_iter {
        iters += 1;
        let prev_obj = obj;

        // --- P1 + P2: assignment then exact power, accepted only if
        // they do not worsen the objective (BCD safeguard). P2 solves
        // the paper's min-max delay program; the objective decides at
        // the acceptance step whether its power profile is kept.
        let mut cand = alloc.clone();
        let a = assignment::algorithm2_with(scn, cand.l_c, cand.rank, &mut assign_scratch);
        cand.assign_main = a.assign_main;
        cand.assign_fed = a.assign_fed;
        let ps = power::solve_power_hinted(scn, &cand, p2_hint, &mut power_scratch)?;
        p2_hint = Some((ps.t1, ps.t3));
        cand.psd_main = ps.psd_main;
        cand.psd_fed = ps.psd_fed;
        let cand_obj = score_alloc(scn, &cand, conv, &objective);
        if cand_obj <= obj {
            alloc = cand;
            obj = cand_obj;
        } else {
            // keep assignment fixed, still re-solve power exactly for the
            // current assignment (never hurts under the delay objective:
            // P2 is exact; other objectives judge it at acceptance)
            let ps = power::solve_power_hinted(scn, &alloc, p2_hint, &mut power_scratch)?;
            p2_hint = Some((ps.t1, ps.t3));
            let mut cand2 = alloc.clone();
            cand2.psd_main = ps.psd_main;
            cand2.psd_fed = ps.psd_fed;
            let o2 = score_alloc(scn, &cand2, conv, &objective);
            if o2 <= obj {
                alloc = cand2;
                obj = o2;
            }
        }

        // --- P3 + P4: one exhaustive scan over the full split×rank
        // grid on the cached evaluator (the grid contains every point
        // the old sequential split-then-rank scans could reach, so the
        // joint argmin is never worse). The communication block just
        // got fixed above, so the evaluator is valid for the whole scan.
        let ev = DelayEvaluator::new(scn, &alloc, conv, table.clone());
        let choice = ev.best_split_rank_obj(&objective);
        if choice.score <= obj {
            alloc.l_c = choice.l_c;
            alloc.rank = choice.rank;
            obj = choice.score;
        }

        trajectory.push(obj);
        if (prev_obj - obj).abs() <= opts.eps {
            break;
        }
    }

    if !obj.is_finite() {
        bail!(
            "BCD objective '{}' is non-finite ({obj}): the scenario is \
             infeasible under this objective (starved uplink, or an \
             energy budget no candidate meets)",
            objective.label()
        );
    }
    // final report quantities, on the same cached engine (eval /
    // eval_energy are bit-identical to the uncached totals)
    let ev = DelayEvaluator::new(scn, &alloc, conv, table);
    let delay = ev.eval(alloc.l_c, alloc.rank);
    let energy = ev.eval_energy(alloc.l_c, alloc.rank);

    Ok(BcdResult {
        alloc,
        objective: obj,
        delay,
        energy,
        trajectory,
        iterations: iters,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delay::testutil::toy_scenario;

    #[test]
    fn trajectory_is_monotone_nonincreasing() {
        let scn = toy_scenario();
        let conv = ConvergenceModel::paper_default();
        let res = optimize(&scn, &conv, &BcdOptions::default()).unwrap();
        for w in res.trajectory.windows(2) {
            assert!(w[1] <= w[0] + 1e-9, "trajectory rose: {:?}", res.trajectory);
        }
        assert!(res.objective.is_finite() && res.objective > 0.0);
    }

    #[test]
    fn final_alloc_is_valid_and_feasible() {
        let scn = toy_scenario();
        let conv = ConvergenceModel::paper_default();
        let res = optimize(&scn, &conv, &BcdOptions::default()).unwrap();
        res.alloc
            .validate(scn.main_link.subch.len(), scn.fed_link.subch.len())
            .unwrap();
        assert!(scn.power_feasible(&res.alloc, 1e-6));
        assert!(scn.profile.split_candidates().contains(&res.alloc.l_c));
        assert!([1, 2, 4, 6, 8].contains(&res.alloc.rank));
    }

    #[test]
    fn beats_naive_initial_allocation() {
        let scn = toy_scenario();
        let conv = ConvergenceModel::paper_default();
        let init = initial_alloc(&scn, 6, 4);
        let t_init = scn.total_delay(&init, &conv);
        let res = optimize(&scn, &conv, &BcdOptions::default()).unwrap();
        assert!(res.objective <= t_init + 1e-9);
    }

    #[test]
    fn scale_into_budget_scales_each_link_independently() {
        let scn = toy_scenario();
        // main link comfortably inside C4/C5; fed link 10x over the
        // per-client cap (5e-4 W/Hz * 250 kHz = 125 W > p_max = 15 W)
        let mut alloc = Allocation {
            assign_main: vec![vec![0, 1], vec![2, 3]],
            assign_fed: vec![vec![0], vec![1]],
            psd_main: vec![1e-5; 4],
            psd_fed: vec![5e-4; 2],
            l_c: 3,
            rank: 4,
        };
        let psd_main_before = alloc.psd_main.clone();
        scale_into_budget(&scn, &mut alloc);
        // the fed-link violation must not throttle the main link
        assert_eq!(alloc.psd_main, psd_main_before, "main-link PSDs were rescaled");
        assert!(alloc.psd_fed[0] < 5e-4, "fed-link PSDs were not rescaled");
        assert!(scn.power_feasible(&alloc, 1e-9));
        // and the fed scale is tight: the worst fed constraint binds
        let worst_fed = (0..scn.k())
            .map(|k| scn.power_fed(&alloc, k) / scn.p_max_w)
            .fold(0.0f64, f64::max)
            .max((0..scn.k()).map(|k| scn.power_fed(&alloc, k)).sum::<f64>() / scn.p_th_fed_w);
        assert!((worst_fed - 1.0).abs() < 1e-9, "fed scaling not tight: {worst_fed}");
    }

    #[test]
    fn scale_into_budget_never_scales_up() {
        let scn = toy_scenario();
        let mut alloc = Allocation {
            assign_main: vec![vec![0, 1], vec![2, 3]],
            assign_fed: vec![vec![0], vec![1]],
            psd_main: vec![1e-5; 4],
            psd_fed: vec![1e-5; 2],
            l_c: 3,
            rank: 4,
        };
        let before = alloc.clone();
        scale_into_budget(&scn, &mut alloc);
        assert_eq!(alloc.psd_main, before.psd_main);
        assert_eq!(alloc.psd_fed, before.psd_fed);
    }

    #[test]
    fn joint_scan_matches_grid_argmin_over_bcd_ranks() {
        let scn = toy_scenario();
        let conv = ConvergenceModel::paper_default();
        let opts = BcdOptions::default();
        let res = optimize(&scn, &conv, &opts).unwrap();
        // the final (l_c, rank) is grid-optimal for the final comm block
        for l_c in scn.profile.split_candidates() {
            for &r in &opts.ranks {
                let mut cand = res.alloc.clone();
                cand.l_c = l_c;
                cand.rank = r;
                assert!(
                    scn.total_delay(&cand, &conv) >= res.objective - 1e-9,
                    "({l_c}, {r}) beats the BCD result"
                );
            }
        }
    }

    #[test]
    fn cached_optimize_matches_uncached() {
        let scn = toy_scenario();
        let conv = ConvergenceModel::paper_default();
        let opts = BcdOptions::default();
        let cache = WorkloadCache::new();
        let a = optimize_cached(&scn, &conv, &opts, &cache).unwrap();
        let b = optimize_cached(&scn, &conv, &opts, &cache).unwrap();
        let c = optimize(&scn, &conv, &opts).unwrap();
        assert_eq!(a.objective.to_bits(), b.objective.to_bits());
        assert_eq!(a.objective.to_bits(), c.objective.to_bits());
        assert_eq!(cache.tables(), 1, "repeat solves must share one table");
    }

    #[test]
    fn result_reports_delay_and_energy_of_the_final_alloc() {
        let scn = toy_scenario();
        let conv = ConvergenceModel::paper_default();
        let res = optimize(&scn, &conv, &BcdOptions::default()).unwrap();
        // delay objective: score IS the delay
        assert_eq!(res.objective.to_bits(), res.delay.to_bits());
        assert_eq!(
            res.delay.to_bits(),
            scn.total_delay(&res.alloc, &conv).to_bits()
        );
        assert_eq!(
            res.energy.to_bits(),
            crate::delay::energy::total_energy(&scn, &res.alloc, &conv, scn.objective.zeta)
                .to_bits()
        );
        assert!(res.energy.is_finite() && res.energy > 0.0);
    }

    #[test]
    fn weighted_lambda_zero_matches_the_delay_objective_bit_for_bit() {
        let scn = toy_scenario();
        let conv = ConvergenceModel::paper_default();
        let base = optimize(&scn, &conv, &BcdOptions::default()).unwrap();
        let w0 = optimize(
            &scn,
            &conv,
            &BcdOptions {
                objective: Some(Objective::Weighted { lambda: 0.0 }),
                ..BcdOptions::default()
            },
        )
        .unwrap();
        assert_eq!(base.objective.to_bits(), w0.objective.to_bits());
        assert_eq!(base.alloc.l_c, w0.alloc.l_c);
        assert_eq!(base.alloc.rank, w0.alloc.rank);
        assert_eq!(base.trajectory.len(), w0.trajectory.len());
        for (a, b) in base.trajectory.iter().zip(&w0.trajectory) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn energy_objective_descends_energy_and_reports_it_as_the_score() {
        let scn = toy_scenario();
        let conv = ConvergenceModel::paper_default();
        let init = initial_alloc(&scn, 6, 4);
        let e_init =
            crate::delay::energy::total_energy(&scn, &init, &conv, scn.objective.zeta);
        let e = optimize(
            &scn,
            &conv,
            &BcdOptions {
                objective: Some(Objective::Energy),
                init_l_c: 6,
                init_rank: 4,
                ..BcdOptions::default()
            },
        )
        .unwrap();
        assert_eq!(e.objective.to_bits(), e.energy.to_bits());
        // the acceptance safeguard makes the energy trajectory monotone
        // non-increasing from the initial allocation's energy
        assert!(
            e.energy <= e_init * (1.0 + 1e-12),
            "final energy {} above initial {}",
            e.energy,
            e_init
        );
        for w in e.trajectory.windows(2) {
            assert!(w[1] <= w[0] * (1.0 + 1e-12), "trajectory rose: {:?}", e.trajectory);
        }
        // and the final (l_c, rank) is energy-grid-optimal for the
        // final communication block
        let ev = DelayEvaluator::build(&scn, &e.alloc, &conv, &[1, 2, 4, 6, 8]);
        for l_c in scn.profile.split_candidates() {
            for &r in &[1usize, 2, 4, 6, 8] {
                assert!(
                    ev.eval_energy(l_c, r) >= e.energy * (1.0 - 1e-12),
                    "({l_c}, {r}) beats the energy BCD result"
                );
            }
        }
    }

    #[test]
    fn impossible_energy_budget_fails_with_an_explicit_error() {
        let scn = toy_scenario();
        let conv = ConvergenceModel::paper_default();
        let err = optimize(
            &scn,
            &conv,
            &BcdOptions {
                objective: Some(Objective::EnergyBudget { joules: 1e-30 }),
                ..BcdOptions::default()
            },
        )
        .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("non-finite") || msg.contains("infeasible"), "{msg}");
    }

    #[test]
    fn converges_within_max_iter() {
        let scn = toy_scenario();
        let conv = ConvergenceModel::paper_default();
        let res = optimize(&scn, &conv, &BcdOptions::default()).unwrap();
        assert!(res.iterations <= 20);
        // last two objective values within eps
        let n = res.trajectory.len();
        if n >= 2 {
            assert!((res.trajectory[n - 1] - res.trajectory[n - 2]).abs() <= 1e-6 + 1e-12);
        }
    }
}
