//! P2 — the convex power-control subproblem (paper Eqs. 20–24) solved
//! exactly, without an external convex solver.
//!
//! Structure the paper's reformulation exposes (and we exploit):
//!
//! 1. With assignment, split and rank fixed, the objective
//!    `E(r)·(I·(T1 + TsF + TsB + T2) + T3)` depends on the main-link
//!    PSDs only through `T1` and on the fed-link PSDs only through
//!    `T3`; the power constraints C4/C5 are also per-link. The problem
//!    therefore decomposes into two independent min-max-delay power
//!    allocations.
//!
//! 2. Within one link, minimizing the max over clients of
//!    `a_k + C_k / Σ_ξ θ_{k,ξ}` subject to a power budget is monotone:
//!    a target delay `T` is feasible iff every client can reach rate
//!    `C_k / (T − a_k)` within its power cap and the per-link total cap.
//!    The minimum power for a client to reach a given rate over its
//!    subchannels is classic **water-filling** (the KKT condition of
//!    constraint Ĉ4/Ĉ5's exponential costs): `θ_ξ = B_ξ·log2(λ g_ξ /ln2)`
//!    clipped at 0, with the water level λ bisected to meet the rate.
//!    Client powers are separable, so summing per-client minima gives
//!    the exact feasibility test, and bisection on `T` yields the exact
//!    optimum of the min-max program.
//!
//! ## §Perf iteration 3 — allocation-free probes and warm starts
//!
//! A [`Link`] stores one gain **per client** (there is no per-subchannel
//! fading in the model), so every water-fill the T-bisection performs is
//! the *equal-gain* case, whose closed form needs no per-subchannel
//! `g`/`b` vectors at all: the feasibility oracle now computes one
//! scalar PSD per client ([`waterfill_equal_gain`]) and writes into a
//! reused probe buffer ([`ProbeScratch`]) — zero allocation across the
//! ~60 probes × K clients of a solve, where the old path built three
//! `Vec`s per client per probe. (The general unequal-gain water-fill
//! stays available as [`waterfill_min_power`], the property-tested
//! public API.)
//!
//! [`solve_link_hinted`] additionally accepts a **warm-start hint** —
//! the previous BCD iteration's `(t1, t3)` — probed once to seed
//! monotone skip bounds: feasibility is monotone in `T`, so a canonical
//! bisection midpoint at/above a probed-feasible `T` is feasible (and
//! at/below a probed-infeasible one is infeasible) *without running the
//! oracle*. The bisection therefore visits the **identical**
//! `(lo, hi, T*)` sequence as the cold solve — the hint only removes
//! probes whose outcome is implied — and the PSD image is materialized
//! at the exact accepted `T*`, keeping the solution bit-identical to
//! the unhinted path for any hint whatsoever (property-tested in
//! `rust/tests/prop_optimizer.rs`).
//!
//! The unit tests verify water-filling optimality against random
//! perturbations and the equal-gain closed form; `tests/prop_optimizer.rs`
//! re-verifies both properties and the bisection tightness as seeded
//! property sweeps.

use anyhow::{bail, Result};

use crate::delay::{Allocation, Scenario};
use crate::net::Link;
use crate::util::stats::fsum;

/// Result of one P2 solve.
#[derive(Clone, Debug)]
pub struct PowerSolution {
    pub psd_main: Vec<f64>,
    pub psd_fed: Vec<f64>,
    /// Optimal epigraph values (Eq. 21): T1 = max_k (T_k^F + T_k^s),
    /// T3 = max_k T_k^f.
    pub t1: f64,
    pub t3: f64,
}

/// Reusable probe buffers for one link's T-bisection (the candidate and
/// incumbent per-subchannel PSD images).
#[derive(Clone, Debug, Default)]
pub struct ProbeScratch {
    probe: Vec<f64>,
    best: Vec<f64>,
}

/// Scratch for a full [`solve_power_hinted`] call: one
/// [`ProbeScratch`] per link, reused across every feasibility probe of
/// every BCD iteration.
#[derive(Clone, Debug, Default)]
pub struct PowerScratch {
    main: ProbeScratch,
    fed: ProbeScratch,
}

/// Water-filling: minimum power for one client to push `rate` bit/s
/// through its assigned subchannels. Returns (total watts, per-subchannel
/// PSD, aligned with `subs`).
pub fn waterfill_min_power(link: &Link, k: usize, subs: &[usize], rate: f64) -> (f64, Vec<f64>) {
    if rate <= 0.0 || subs.is_empty() {
        return (0.0, vec![0.0; subs.len()]);
    }
    let g: Vec<f64> = subs.iter().map(|_| link.snr_coeff(k)).collect();
    let b: Vec<f64> = subs.iter().map(|&i| link.subch.bandwidth_hz[i]).collect();

    // §Perf iteration 2 — closed form for the (ubiquitous) equal-gain
    // case: a client's subchannels all share its channel gain, so the
    // KKT water level puts theta_i proportional to B_i, i.e. a common
    // spectral efficiency R/B_tot on every subchannel. This removes the
    // inner bisection from the P2 hot loop entirely.
    // lint:allow(P101) windows(2) yields exactly-2-element slices, so w[0]/w[1] are in bounds
    let equal_gain = g.windows(2).all(|w| (w[0] - w[1]).abs() <= 1e-12 * w[0].abs());
    if equal_gain {
        let (power, psd_common) = waterfill_equal_gain(link, k, subs, rate);
        return (power, vec![psd_common; subs.len()]);
    }

    // rate achieved at water level lam: sum_i B_i * max(0, log2(lam*g_i/ln2))
    let rate_at = |lam: f64| -> f64 {
        b.iter()
            .zip(&g)
            .map(|(&bi, &gi)| bi * ((lam * gi / std::f64::consts::LN_2).log2()).max(0.0))
            .sum()
    };

    // bracket the water level
    let mut lo = f64::INFINITY;
    for &gi in &g {
        lo = lo.min(std::f64::consts::LN_2 / gi); // rate becomes 0 at/below this
    }
    let mut hi = lo;
    while rate_at(hi) < rate {
        hi *= 2.0;
        if !hi.is_finite() {
            return (f64::INFINITY, vec![0.0; subs.len()]);
        }
    }
    // 60 iterations of bisection reach ~1e-18 relative width from any
    // bracket; 1e-12 early-exit is far below any delay-decision scale
    // (§Perf iteration 1: was 200 iters @ 1e-15 — 5x slower, no
    // measurable accuracy difference in the tightness property tests).
    for _ in 0..60 {
        let mid = 0.5 * (lo + hi);
        if rate_at(mid) < rate {
            lo = mid;
        } else {
            hi = mid;
        }
        if (hi - lo) / hi < 1e-12 {
            break;
        }
    }
    let lam = hi;
    let mut power = 0.0;
    let mut psd = Vec::with_capacity(subs.len());
    // distribute exactly `rate` with the final water level, then scale
    // the per-channel rates so the sum matches `rate` exactly.
    let mut rates: Vec<f64> = b
        .iter()
        .zip(&g)
        .map(|(&bi, &gi)| bi * ((lam * gi / std::f64::consts::LN_2).log2()).max(0.0))
        .collect();
    let sum: f64 = rates.iter().sum();
    if sum > 0.0 {
        let scale = rate / sum;
        rates.iter_mut().for_each(|r| *r *= scale);
    }
    for ((&bi, &gi), &ri) in b.iter().zip(&g).zip(&rates) {
        let p = ((ri / bi).exp2() - 1.0) / gi; // PSD W/Hz
        power += p * bi;
        psd.push(p);
    }
    (power, psd)
}

/// The equal-gain water-fill closed form every in-tree link hits (a
/// [`Link`] carries one gain per *client*, never per subchannel): the
/// KKT water level spreads rate uniformly per Hz, so one scalar PSD
/// covers all of the client's subchannels. Returns
/// `(total watts, common PSD)` — bit-identical to
/// [`waterfill_min_power`]'s equal-gain path (same folds, same ops),
/// with zero allocation.
fn waterfill_equal_gain(link: &Link, k: usize, subs: &[usize], rate: f64) -> (f64, f64) {
    let b_tot: f64 = fsum(subs.iter().map(|&i| link.subch.bandwidth_hz[i]));
    let se = rate / b_tot; // bit/s/Hz, uniform across subchannels
    let psd_common = (se.exp2() - 1.0) / link.snr_coeff(k);
    (psd_common * b_tot, psd_common)
}

/// Feasibility oracle for one link: can every client k reach delay
/// `a_k + C_k/R_k <= t` within per-client cap and total cap? On success
/// the per-subchannel PSD image (indexed by global subchannel id) is
/// left in `psd`; on failure `psd` holds garbage. Allocation-free.
#[allow(clippy::too_many_arguments)]
fn feasible_at(
    link: &Link,
    assign: &[Vec<usize>],
    a: &[f64],
    c_bits: &[f64],
    t: f64,
    p_max_w: f64,
    p_th_w: f64,
    psd: &mut [f64],
) -> bool {
    psd.fill(0.0);
    let mut total = 0.0;
    for (k, subs) in assign.iter().enumerate() {
        if c_bits[k] <= 0.0 {
            continue;
        }
        if t <= a[k] {
            return false;
        }
        debug_assert!(!subs.is_empty(), "validated by solve_link");
        let rate = c_bits[k] / (t - a[k]);
        let (pw, psd_common) = waterfill_equal_gain(link, k, subs, rate);
        if !pw.is_finite() || pw > p_max_w * (1.0 + 1e-12) {
            return false;
        }
        total += pw;
        for &i in subs {
            psd[i] = psd_common;
        }
    }
    if total > p_th_w * (1.0 + 1e-12) {
        return false;
    }
    true
}

/// Exact min-max delay power allocation for one link.
///
/// `a[k]` is the additive compute delay (zero for the fed link),
/// `c_bits[k]` the payload bits of client k. Returns (T*, psd).
pub fn solve_link(
    link: &Link,
    assign: &[Vec<usize>],
    a: &[f64],
    c_bits: &[f64],
    p_max_w: f64,
    p_th_w: f64,
) -> Result<(f64, Vec<f64>)> {
    solve_link_hinted(link, assign, a, c_bits, p_max_w, p_th_w, None, &mut ProbeScratch::default())
}

/// [`solve_link`] with a warm-start hint and caller-provided probe
/// buffers. The hint (typically the previous BCD iteration's optimum)
/// is probed once and converted into monotone skip bounds; the
/// bisection then walks the *canonical* midpoint sequence, skipping
/// oracle calls whose outcome the bounds imply. Any hint — stale, way
/// off, non-finite — yields the bit-identical `(T*, psd)` of the cold
/// solve; a good hint just pays fewer probes.
#[allow(clippy::too_many_arguments)]
pub fn solve_link_hinted(
    link: &Link,
    assign: &[Vec<usize>],
    a: &[f64],
    c_bits: &[f64],
    p_max_w: f64,
    p_th_w: f64,
    hint: Option<f64>,
    scratch: &mut ProbeScratch,
) -> Result<(f64, Vec<f64>)> {
    let k_n = assign.len();
    if a.len() != k_n || c_bits.len() != k_n {
        bail!("dimension mismatch in solve_link");
    }
    for (k, subs) in assign.iter().enumerate() {
        if c_bits[k] > 0.0 && subs.is_empty() {
            bail!("client {k} has payload but no subchannels");
        }
    }
    // Upper bound: every client spends min(p_max, p_th/K) — feasible by
    // construction — and we take the resulting worst delay.
    let share = p_max_w.min(p_th_w / k_n.max(1) as f64);
    let mut hi = 0.0f64;
    for (k, subs) in assign.iter().enumerate() {
        if c_bits[k] <= 0.0 {
            continue;
        }
        // equal PSD over the client's subchannels at power `share`
        let bw: f64 = fsum(subs.iter().map(|&i| link.subch.bandwidth_hz[i]));
        let psd = share / bw;
        let rate: f64 = fsum(subs.iter().map(|&i| link.subch_rate(k, i, psd)));
        if rate <= 0.0 {
            bail!("client {k} cannot achieve positive rate");
        }
        hi = hi.max(a[k] + c_bits[k] / rate);
    }
    if hi == 0.0 {
        // nothing to send on this link
        return Ok((0.0, vec![0.0; link.subch.len()]));
    }
    let mut lo = crate::util::stats::stage_max(
        a.iter()
            .zip(c_bits)
            .filter(|(_, &c)| c > 0.0)
            .map(|(&ak, _)| ak),
    );

    let m = link.subch.len();
    scratch.probe.clear();
    scratch.probe.resize(m, 0.0);
    scratch.best.clear();
    scratch.best.resize(m, 0.0);

    // canonical upper-bound probe — also the fallback PSD image
    if !feasible_at(link, assign, a, c_bits, hi, p_max_w, p_th_w, &mut scratch.best) {
        bail!("upper bound infeasible (internal)");
    }
    let mut t_star = hi;
    let mut best_t = hi; // the t `scratch.best` was computed at

    // Warm start: one probe at the hint seeds the monotone skip bounds.
    // Feasibility is monotone in t, so every skipped decision equals
    // what the oracle would have returned — the (lo, hi, t*) sequence
    // is the cold solve's, bit for bit.
    let mut known_feasible = f64::INFINITY;
    let mut known_infeasible = f64::NEG_INFINITY;
    if let Some(h) = hint {
        if h.is_finite() && h > lo && h < hi {
            if feasible_at(link, assign, a, c_bits, h, p_max_w, p_th_w, &mut scratch.probe) {
                known_feasible = h;
            } else {
                known_infeasible = h;
            }
        }
    }

    // bisection on T
    // §Perf iteration 1: 1e-9 relative tolerance on T* (delays are
    // seconds; decisions differ at >1e-3) — was 100 iters @ 1e-12.
    for _ in 0..60 {
        let mid = 0.5 * (lo + hi);
        let feas = if mid >= known_feasible {
            true // implied by a probed-feasible t <= mid
        } else if mid <= known_infeasible {
            false // implied by a probed-infeasible t >= mid
        } else if feasible_at(link, assign, a, c_bits, mid, p_max_w, p_th_w, &mut scratch.probe) {
            std::mem::swap(&mut scratch.probe, &mut scratch.best);
            best_t = mid;
            known_feasible = known_feasible.min(mid);
            true
        } else {
            known_infeasible = known_infeasible.max(mid);
            false
        };
        if feas {
            t_star = mid;
            hi = mid;
        } else {
            lo = mid;
        }
        if (hi - lo) / hi.max(1e-30) < 1e-9 {
            break;
        }
    }
    if best_t != t_star {
        // t* was accepted through the skip fast path; materialize its
        // exact PSD image with one final oracle call.
        let ok = feasible_at(link, assign, a, c_bits, t_star, p_max_w, p_th_w, &mut scratch.best);
        if !ok {
            // cannot happen while the oracle is monotone in t; fail
            // loudly rather than return a PSD image from another t
            bail!("warm-start accepted an infeasible T* (internal)");
        }
    }
    Ok((t_star, scratch.best.clone()))
}

/// Solve P2 for the full scenario under a fixed assignment/split/rank:
/// independent exact solves for the main and fed links.
pub fn solve_power(scn: &Scenario, alloc: &Allocation) -> Result<PowerSolution> {
    solve_power_hinted(scn, alloc, None, &mut PowerScratch::default())
}

/// [`solve_power`] with warm-start hints `(t1, t3)` (the previous BCD
/// iteration's epigraph optima) and reusable probe buffers —
/// bit-identical results for any hint, fewer feasibility probes for a
/// good one. The BCD loop threads its last `PowerSolution` through
/// here; one-shot callers use [`solve_power`].
pub fn solve_power_hinted(
    scn: &Scenario,
    alloc: &Allocation,
    hint: Option<(f64, f64)>,
    scratch: &mut PowerScratch,
) -> Result<PowerSolution> {
    let k_n = scn.k();
    let b = scn.batch as f64;
    let (l_c, r) = (alloc.l_c, alloc.rank);

    // main link: a_k = T_k^F, payload = b * Gamma_s bits
    let a_main: Vec<f64> = (0..k_n)
        .map(|k| {
            b * scn.kappa_client * scn.profile.client_fwd_flops(l_c, r)
                / scn.topo.clients[k].f_cycles
        })
        .collect();
    let c_main: Vec<f64> = (0..k_n).map(|_| b * scn.profile.activation_bits(l_c)).collect();
    let (t1, psd_main) = solve_link_hinted(
        &scn.main_link,
        &alloc.assign_main,
        &a_main,
        &c_main,
        scn.p_max_w,
        scn.p_th_main_w,
        hint.map(|h| h.0),
        &mut scratch.main,
    )?;

    // fed link: no compute offset, payload = Delta Theta_c bits
    let a_fed = vec![0.0; k_n];
    let c_fed: Vec<f64> = (0..k_n)
        .map(|_| scn.profile.client_adapter_bits(l_c, r))
        .collect();
    let (t3, psd_fed) = solve_link_hinted(
        &scn.fed_link,
        &alloc.assign_fed,
        &a_fed,
        &c_fed,
        scn.p_max_w,
        scn.p_th_fed_w,
        hint.map(|h| h.1),
        &mut scratch.fed,
    )?;

    Ok(PowerSolution {
        psd_main,
        psd_fed,
        t1,
        t3,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::SubchannelSet;
    use crate::util::rng::Rng;

    fn test_link(bw: Vec<f64>, gains: Vec<f64>) -> Link {
        Link {
            subch: SubchannelSet { bandwidth_hz: bw },
            gain_product: 160.0,
            noise_psd: 3.98e-21,
            client_gain: gains,
        }
    }

    fn feasible(
        link: &Link,
        assign: &[Vec<usize>],
        a: &[f64],
        c: &[f64],
        t: f64,
        p_max: f64,
        p_th: f64,
    ) -> bool {
        let mut psd = vec![0.0; link.subch.len()];
        feasible_at(link, assign, a, c, t, p_max, p_th, &mut psd)
    }

    #[test]
    fn waterfill_equal_bandwidth_closed_form() {
        // equal gains & bandwidths -> equal rate split
        let link = test_link(vec![25e3; 4], vec![8.9e-10]);
        let rate = 1e6;
        let (power, psd) = waterfill_min_power(&link, 0, &[0, 1, 2, 3], rate);
        assert!(power.is_finite());
        // each subchannel should carry rate/4
        for &p in &psd {
            let r = link.subch_rate(0, 0, p);
            assert!((r - rate / 4.0).abs() / rate < 1e-6);
        }
        let total_rate: f64 = (0..4).map(|i| link.subch_rate(0, i, psd[i])).sum();
        assert!((total_rate - rate).abs() / rate < 1e-9);
    }

    #[test]
    fn equal_gain_helper_matches_public_waterfill_bit_for_bit() {
        let link = test_link(vec![10e3, 40e3, 25e3], vec![5e-10]);
        for &rate in &[1e4, 8e5, 3e6] {
            let (p_pub, psd_pub) = waterfill_min_power(&link, 0, &[0, 1, 2], rate);
            let (p_fast, psd_common) = waterfill_equal_gain(&link, 0, &[0, 1, 2], rate);
            assert_eq!(p_pub.to_bits(), p_fast.to_bits(), "rate {rate}");
            for &p in &psd_pub {
                assert_eq!(p.to_bits(), psd_common.to_bits(), "rate {rate}");
            }
        }
    }

    #[test]
    fn waterfill_unequal_bandwidth_matches_rate() {
        let link = test_link(vec![10e3, 40e3, 25e3], vec![5e-10]);
        let rate = 8e5;
        let (_, psd) = waterfill_min_power(&link, 0, &[0, 1, 2], rate);
        let total: f64 = (0..3).map(|i| link.subch_rate(0, i, psd[i])).sum();
        assert!((total - rate).abs() / rate < 1e-9);
        // wider subchannel carries proportionally more rate at equal PSD
        assert!(link.subch_rate(0, 1, psd[1]) > link.subch_rate(0, 0, psd[0]));
    }

    #[test]
    fn waterfill_is_optimal_under_perturbation() {
        // no rate-preserving perturbation may use less power
        let link = test_link(vec![10e3, 40e3, 25e3], vec![5e-10]);
        let rate = 6e5;
        let subs = [0usize, 1, 2];
        let (p_star, psd) = waterfill_min_power(&link, 0, &subs, rate);
        let rates: Vec<f64> = subs.iter().enumerate().map(|(j, &i)| link.subch_rate(0, i, psd[j])).collect();
        let mut rng = Rng::new(7);
        for _ in 0..200 {
            // move delta rate from one channel to another
            let from = rng.below(3);
            let to = (from + 1 + rng.below(2)) % 3;
            let delta = rates[from] * rng.range(0.01, 0.5);
            let mut r2 = rates.clone();
            r2[from] -= delta;
            r2[to] += delta;
            let p2: f64 = subs
                .iter()
                .enumerate()
                .map(|(j, &i)| link.power_w(i, link.psd_for_rate(0, i, r2[j])))
                .sum();
            assert!(
                p2 >= p_star * (1.0 - 1e-9),
                "perturbation beat water-filling: {p2} < {p_star}"
            );
        }
    }

    #[test]
    fn solve_link_minmax_is_tight() {
        // two clients with different compute offsets and channels
        let link = test_link(vec![25e3; 6], vec![8.9e-10, 3e-10]);
        let assign = vec![vec![0, 1, 2], vec![3, 4, 5]];
        let a = vec![0.5, 0.1];
        let c = vec![2e6, 2e6];
        let (t, psd) = solve_link(&link, &assign, &a, &c, 15.0, 20.0).unwrap();
        // achieved delays must be <= t (and the max ~= t)
        let mut worst: f64 = 0.0;
        for k in 0..2 {
            let rate: f64 = assign[k].iter().map(|&i| link.subch_rate(k, i, psd[i])).sum();
            let d = a[k] + c[k] / rate;
            assert!(d <= t * (1.0 + 1e-6));
            worst = worst.max(d);
        }
        assert!((worst - t).abs() / t < 1e-3, "max delay {worst} vs T* {t}");
        // shrinking T* must be infeasible
        assert!(
            !feasible(&link, &assign, &a, &c, t * 0.999, 15.0, 20.0),
            "T* not tight"
        );
    }

    #[test]
    fn hinted_solve_is_bit_identical_for_any_hint() {
        let link = test_link(vec![25e3; 6], vec![8.9e-10, 3e-10]);
        let assign = vec![vec![0, 1, 2], vec![3, 4, 5]];
        let a = vec![0.5, 0.1];
        let c = vec![2e6, 2e6];
        let (t_cold, psd_cold) = solve_link(&link, &assign, &a, &c, 15.0, 20.0).unwrap();
        let mut scratch = ProbeScratch::default();
        for hint in [
            None,
            Some(t_cold),
            Some(t_cold * (1.0 + 1e-9)),
            Some(t_cold * 0.5),
            Some(t_cold * 64.0),
            Some(0.0),
            Some(f64::NAN),
            Some(f64::INFINITY),
            Some(-3.0),
        ] {
            let (t, psd) =
                solve_link_hinted(&link, &assign, &a, &c, 15.0, 20.0, hint, &mut scratch).unwrap();
            assert_eq!(t.to_bits(), t_cold.to_bits(), "hint {hint:?}");
            assert_eq!(psd.len(), psd_cold.len());
            for (x, y) in psd.iter().zip(&psd_cold) {
                assert_eq!(x.to_bits(), y.to_bits(), "hint {hint:?}");
            }
        }
    }

    #[test]
    fn solve_link_respects_power_caps() {
        let link = test_link(vec![25e3; 4], vec![8.9e-10, 8.9e-10]);
        let assign = vec![vec![0, 1], vec![2, 3]];
        let (_, psd) = solve_link(&link, &assign, &[0.0, 0.0], &[1e7, 1e7], 15.0, 20.0).unwrap();
        for k in 0..2 {
            let pw: f64 = assign[k].iter().map(|&i| link.power_w(i, psd[i])).sum();
            assert!(pw <= 15.0 * (1.0 + 1e-9));
        }
        let total: f64 = (0..4).map(|i| link.power_w(i, psd[i])).sum();
        assert!(total <= 20.0 * (1.0 + 1e-9));
    }

    #[test]
    fn zero_payload_zero_power() {
        let link = test_link(vec![25e3; 2], vec![8.9e-10]);
        let (t, psd) = solve_link(&link, &[vec![0, 1]], &[0.3], &[0.0], 15.0, 20.0).unwrap();
        assert_eq!(t, 0.0);
        assert!(psd.iter().all(|&p| p == 0.0));
    }

    #[test]
    fn tighter_budget_larger_delay() {
        let link = test_link(vec![25e3; 4], vec![5e-10, 4e-10]);
        let assign = vec![vec![0, 1], vec![2, 3]];
        let (t_loose, _) = solve_link(&link, &assign, &[0.0, 0.0], &[5e6, 5e6], 15.0, 30.0).unwrap();
        let (t_tight, _) = solve_link(&link, &assign, &[0.0, 0.0], &[5e6, 5e6], 1.0, 1.5).unwrap();
        assert!(t_tight > t_loose);
    }
}
