//! P4 — LoRA rank selection by exhaustive search (paper Eq. 26).
//!
//! The rank trades per-round cost (compute Δρ/Δϖ, federated upload
//! ΔΘ_c) against convergence speed E(r); with everything else fixed the
//! candidate set is small ({1, 2, 4, 6, 8} in the paper), so exhaustive
//! evaluation of Eq. 17 is exact.
//!
//! Inside the BCD loop P4 no longer runs alone: [`crate::opt::bcd`]
//! scans split and rank *jointly* on a cached
//! [`crate::delay::DelayEvaluator`]. This standalone entry point is a
//! one-call convenience wrapper over that evaluator; repeat-scan
//! callers like baseline c use
//! [`crate::delay::DelayEvaluator::best_rank`] directly on a shared
//! table instead.

use crate::delay::{Allocation, ConvergenceModel, DelayEvaluator, Scenario};

/// Returns (best rank, its total delay) over `candidates`. Ties resolve
/// to the earlier candidate.
pub fn best_rank(
    scn: &Scenario,
    alloc: &Allocation,
    conv: &ConvergenceModel,
    candidates: &[usize],
) -> (usize, f64) {
    assert!(!candidates.is_empty());
    DelayEvaluator::build(scn, alloc, conv, candidates).best_rank(alloc.l_c)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delay::testutil::toy_scenario;

    fn base_alloc() -> Allocation {
        Allocation {
            assign_main: vec![vec![0, 1], vec![2, 3]],
            assign_fed: vec![vec![0], vec![1]],
            psd_main: vec![5e-5; 4],
            psd_fed: vec![5e-5; 2],
            l_c: 3,
            rank: 1,
        }
    }

    #[test]
    fn exhaustive_is_argmin() {
        let scn = toy_scenario();
        let conv = ConvergenceModel::paper_default();
        let alloc = base_alloc();
        let cands = [1, 2, 4, 6, 8];
        let (r_star, t_star) = best_rank(&scn, &alloc, &conv, &cands);
        for &r in &cands {
            let mut cand = alloc.clone();
            cand.rank = r;
            assert!(scn.total_delay(&cand, &conv) >= t_star - 1e-12);
        }
        assert!(cands.contains(&r_star));
    }

    #[test]
    fn flat_convergence_prefers_smallest_rank() {
        // if E(r) is constant, extra rank only costs -> rank 1 wins
        let scn = toy_scenario();
        let conv = ConvergenceModel::fitted(10.0, 0.0, 1.0);
        let (r_star, _) = best_rank(&scn, &base_alloc(), &conv, &[1, 2, 4, 6, 8]);
        assert_eq!(r_star, 1);
    }

    #[test]
    fn steep_convergence_prefers_larger_rank() {
        // if E(r) falls sharply with rank, a larger rank wins
        let scn = toy_scenario();
        let conv = ConvergenceModel::fitted(5.0, 50.0, 2.0);
        let (r_star, _) = best_rank(&scn, &base_alloc(), &conv, &[1, 2, 4, 6, 8]);
        assert!(r_star >= 4, "rank {r_star}");
    }
}
