//! Baselines a–d from the paper's evaluation (Sec. VII-C).
//!
//! * **a** — random subchannel assignment and PSD, random rank and
//!   split location;
//! * **b** — random subchannels and PSD; *proposed* rank and split
//!   selection;
//! * **c** — random split; proposed subchannel, power and rank;
//! * **d** — proposed subchannel, power and split; random rank.
//!
//! Random draws are seeded; random PSDs are scaled into the power
//! budgets (C4/C5) so every baseline is feasible, and random
//! assignments still give each client at least one subchannel per link
//! (otherwise its delay is unboundedly infinite and the comparison
//! collapses to a degenerate case the paper clearly doesn't plot).
//!
//! Every baseline scores its draw under the scenario's
//! [`crate::opt::Objective`] — the "proposed" blocks of b/c/d optimize
//! the same objective the proposed scheme does, so a baseline column
//! next to an energy-objective `proposed` column is an apples-to-apples
//! comparison. Under the default delay objective every draw is
//! bit-identical to the pure-delay baselines.

use anyhow::Result;

use crate::delay::{Allocation, ConvergenceModel, DelayEvaluator, Scenario, WorkloadCache};
use crate::opt::bcd;
use crate::delay::objective::{score_alloc, Objective};
use crate::opt::power;
use crate::util::rng::Rng;

/// Random assignment: first a random 1-per-client pass, then uniform.
fn random_assignment(k_n: usize, m: usize, rng: &mut Rng) -> Vec<Vec<usize>> {
    let mut assign = vec![Vec::new(); k_n];
    let mut chans: Vec<usize> = (0..m).collect();
    rng.shuffle(&mut chans);
    for (slot, &ch) in chans.iter().enumerate() {
        if slot < k_n && slot < m {
            assign[slot].push(ch);
        } else {
            assign[rng.below(k_n)].push(ch);
        }
    }
    assign
}

/// Random PSDs uniform in (0, nominal], then scaled into C4/C5.
fn random_psd(len: usize, nominal: f64, rng: &mut Rng) -> Vec<f64> {
    (0..len).map(|_| nominal * rng.range(0.1, 1.0)).collect()
}

fn random_alloc(scn: &Scenario, ranks: &[usize], rng: &mut Rng) -> Allocation {
    let m = scn.main_link.subch.len();
    let n = scn.fed_link.subch.len();
    let l = scn.profile.blocks.len();
    let mut alloc = Allocation {
        assign_main: random_assignment(scn.k(), m, rng),
        assign_fed: random_assignment(scn.k(), n, rng),
        psd_main: random_psd(m, scn.p_th_main_w / scn.main_link.subch.total_hz(), rng),
        psd_fed: random_psd(n, scn.p_th_fed_w / scn.fed_link.subch.total_hz(), rng),
        l_c: 1 + rng.below(l.saturating_sub(1).max(1)),
        rank: *rng.choose(ranks),
    };
    bcd::scale_into_budget(scn, &mut alloc);
    alloc
}

/// Baseline a: everything random, scored under the scenario objective.
pub fn baseline_a(
    scn: &Scenario,
    conv: &ConvergenceModel,
    ranks: &[usize],
    rng: &mut Rng,
) -> Result<(Allocation, f64)> {
    let objective = Objective::from_config(&scn.objective)?;
    let alloc = random_alloc(scn, ranks, rng);
    let t = score_alloc(scn, &alloc, conv, &objective);
    Ok((alloc, t))
}

/// Baseline b: random subchannels + PSD; proposed (exhaustive joint)
/// rank and split under that fixed communication configuration.
pub fn baseline_b(
    scn: &Scenario,
    conv: &ConvergenceModel,
    ranks: &[usize],
    rng: &mut Rng,
    cache: &WorkloadCache,
) -> Result<(Allocation, f64)> {
    let objective = Objective::from_config(&scn.objective)?;
    let mut alloc = random_alloc(scn, ranks, rng);
    // one joint split×rank scan on the cached evaluator — the true grid
    // argmin, which the old alternating 1-D scans only approximated
    let ev = DelayEvaluator::new(scn, &alloc, conv, cache.table_for(&scn.profile, ranks));
    let choice = ev.best_split_rank_obj(&objective);
    alloc.l_c = choice.l_c;
    alloc.rank = choice.rank;
    Ok((alloc, choice.score))
}

/// Baseline c: random split; proposed subchannel/power/rank via BCD
/// with the split frozen.
pub fn baseline_c(
    scn: &Scenario,
    conv: &ConvergenceModel,
    ranks: &[usize],
    rng: &mut Rng,
    cache: &WorkloadCache,
) -> Result<(Allocation, f64)> {
    let objective = Objective::from_config(&scn.objective)?;
    let table = cache.table_for(&scn.profile, ranks);
    let l = scn.profile.blocks.len();
    let frozen_l_c = 1 + rng.below(l.saturating_sub(1).max(1));
    let mut alloc = bcd::initial_alloc(scn, frozen_l_c, 4);
    let mut obj = score_alloc(scn, &alloc, conv, &objective);
    for _ in 0..8 {
        let prev = obj;
        let a = crate::opt::assignment::algorithm2(scn, alloc.l_c, alloc.rank);
        let mut cand = alloc.clone();
        cand.assign_main = a.assign_main;
        cand.assign_fed = a.assign_fed;
        let ps = power::solve_power(scn, &cand)?;
        cand.psd_main = ps.psd_main;
        cand.psd_fed = ps.psd_fed;
        let o = score_alloc(scn, &cand, conv, &objective);
        if o <= obj {
            alloc = cand;
            obj = o;
        }
        let ev = DelayEvaluator::new(scn, &alloc, conv, table.clone());
        let (r, t_r) = ev.best_rank_obj(alloc.l_c, &objective);
        if t_r <= obj {
            alloc.rank = r;
            obj = t_r;
        }
        if (prev - obj).abs() < 1e-9 {
            break;
        }
    }
    Ok((alloc, obj))
}

/// Baseline d: proposed subchannel/power/split via BCD, random rank.
pub fn baseline_d(
    scn: &Scenario,
    conv: &ConvergenceModel,
    ranks: &[usize],
    rng: &mut Rng,
    cache: &WorkloadCache,
) -> Result<(Allocation, f64)> {
    let objective = Objective::from_config(&scn.objective)?;
    let table = cache.table_for(&scn.profile, ranks);
    let frozen_rank = *rng.choose(ranks);
    let mut alloc = bcd::initial_alloc(scn, (scn.profile.blocks.len() / 2).max(1), frozen_rank);
    let mut obj = score_alloc(scn, &alloc, conv, &objective);
    for _ in 0..8 {
        let prev = obj;
        let a = crate::opt::assignment::algorithm2(scn, alloc.l_c, alloc.rank);
        let mut cand = alloc.clone();
        cand.assign_main = a.assign_main;
        cand.assign_fed = a.assign_fed;
        let ps = power::solve_power(scn, &cand)?;
        cand.psd_main = ps.psd_main;
        cand.psd_fed = ps.psd_fed;
        let o = score_alloc(scn, &cand, conv, &objective);
        if o <= obj {
            alloc = cand;
            obj = o;
        }
        let ev = DelayEvaluator::new(scn, &alloc, conv, table.clone());
        let (l_c, t_s) = ev.best_split_obj(alloc.rank, &objective);
        if t_s <= obj {
            alloc.l_c = l_c;
            obj = t_s;
        }
        if (prev - obj).abs() < 1e-9 {
            break;
        }
    }
    Ok((alloc, obj))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delay::testutil::toy_scenario;

    const RANKS: [usize; 5] = [1, 2, 4, 6, 8];

    #[test]
    fn all_baselines_feasible() {
        let scn = toy_scenario();
        let conv = ConvergenceModel::paper_default();
        let cache = WorkloadCache::new();
        let mut rng = Rng::new(1);
        let (a, _) = baseline_a(&scn, &conv, &RANKS, &mut rng).unwrap();
        let (b, _) = baseline_b(&scn, &conv, &RANKS, &mut rng, &cache).unwrap();
        let (c, _) = baseline_c(&scn, &conv, &RANKS, &mut rng, &cache).unwrap();
        let (d, _) = baseline_d(&scn, &conv, &RANKS, &mut rng, &cache).unwrap();
        for (name, alloc) in [("a", &a), ("b", &b), ("c", &c), ("d", &d)] {
            alloc
                .validate(scn.main_link.subch.len(), scn.fed_link.subch.len())
                .unwrap_or_else(|e| panic!("baseline {name}: {e}"));
            assert!(scn.power_feasible(alloc, 1e-6), "baseline {name} power");
        }
    }

    #[test]
    fn baseline_b_objective_is_the_joint_grid_argmin() {
        let scn = toy_scenario();
        let conv = ConvergenceModel::paper_default();
        let cache = WorkloadCache::new();
        let mut rng = Rng::new(9);
        let (alloc, t) = baseline_b(&scn, &conv, &RANKS, &mut rng, &cache).unwrap();
        assert_eq!(t.to_bits(), scn.total_delay(&alloc, &conv).to_bits());
        for l_c in scn.profile.split_candidates() {
            for &r in &RANKS {
                let mut cand = alloc.clone();
                cand.l_c = l_c;
                cand.rank = r;
                assert!(scn.total_delay(&cand, &conv) >= t, "({l_c}, {r}) beats baseline b");
            }
        }
    }

    #[test]
    fn partial_optimization_helps() {
        // each partially-optimized baseline should beat fully-random (a)
        // on average over draws (same shared-stream draws the removed
        // compare_all shim used, so the pinned behaviour carries over)
        let scn = toy_scenario();
        let conv = ConvergenceModel::paper_default();
        let cache = WorkloadCache::new();
        let mut acc = [0.0f64; 4];
        for d in 0..5u64 {
            let mut rng = Rng::new(3 ^ d.wrapping_mul(0x9E3779B97F4A7C15));
            acc[0] += baseline_a(&scn, &conv, &RANKS, &mut rng).unwrap().1;
            acc[1] += baseline_b(&scn, &conv, &RANKS, &mut rng, &cache).unwrap().1;
            acc[2] += baseline_c(&scn, &conv, &RANKS, &mut rng, &cache).unwrap().1;
            acc[3] += baseline_d(&scn, &conv, &RANKS, &mut rng, &cache).unwrap().1;
        }
        let [a, b, c, d] = acc.map(|x| x / 5.0);
        assert!(b <= a * 1.05, "b={b} vs a={a}");
        assert!(c <= a * 1.05, "c={c} vs a={a}");
        assert!(d <= a * 1.05, "d={d} vs a={a}");
    }
}
