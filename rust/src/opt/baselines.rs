//! Baselines a–d from the paper's evaluation (Sec. VII-C).
//!
//! * **a** — random subchannel assignment and PSD, random rank and
//!   split location;
//! * **b** — random subchannels and PSD; *proposed* rank and split
//!   selection;
//! * **c** — random split; proposed subchannel, power and rank;
//! * **d** — proposed subchannel, power and split; random rank.
//!
//! Random draws are seeded; random PSDs are scaled into the power
//! budgets (C4/C5) so every baseline is feasible, and random
//! assignments still give each client at least one subchannel per link
//! (otherwise its delay is unboundedly infinite and the comparison
//! collapses to a degenerate case the paper clearly doesn't plot).

use anyhow::Result;

use crate::delay::{Allocation, ConvergenceModel, Scenario};
use crate::opt::bcd::{self, BcdOptions};
use crate::opt::{power, rank, split};
use crate::util::rng::Rng;

/// Random assignment: first a random 1-per-client pass, then uniform.
fn random_assignment(k_n: usize, m: usize, rng: &mut Rng) -> Vec<Vec<usize>> {
    let mut assign = vec![Vec::new(); k_n];
    let mut chans: Vec<usize> = (0..m).collect();
    rng.shuffle(&mut chans);
    for (slot, &ch) in chans.iter().enumerate() {
        if slot < k_n && slot < m {
            assign[slot].push(ch);
        } else {
            assign[rng.below(k_n)].push(ch);
        }
    }
    assign
}

/// Random PSDs uniform in (0, nominal], then scaled into C4/C5.
fn random_psd(len: usize, nominal: f64, rng: &mut Rng) -> Vec<f64> {
    (0..len).map(|_| nominal * rng.range(0.1, 1.0)).collect()
}

fn random_alloc(scn: &Scenario, ranks: &[usize], rng: &mut Rng) -> Allocation {
    let m = scn.main_link.subch.len();
    let n = scn.fed_link.subch.len();
    let l = scn.profile.blocks.len();
    let mut alloc = Allocation {
        assign_main: random_assignment(scn.k(), m, rng),
        assign_fed: random_assignment(scn.k(), n, rng),
        psd_main: random_psd(m, scn.p_th_main_w / scn.main_link.subch.total_hz(), rng),
        psd_fed: random_psd(n, scn.p_th_fed_w / scn.fed_link.subch.total_hz(), rng),
        l_c: 1 + rng.below(l.saturating_sub(1).max(1)),
        rank: *rng.choose(ranks),
    };
    bcd::scale_into_budget(scn, &mut alloc);
    alloc
}

/// Baseline a: everything random.
pub fn baseline_a(
    scn: &Scenario,
    conv: &ConvergenceModel,
    ranks: &[usize],
    rng: &mut Rng,
) -> (Allocation, f64) {
    let alloc = random_alloc(scn, ranks, rng);
    let t = scn.total_delay(&alloc, conv);
    (alloc, t)
}

/// Baseline b: random subchannels + PSD; proposed (exhaustive joint)
/// rank and split under that fixed communication configuration.
pub fn baseline_b(
    scn: &Scenario,
    conv: &ConvergenceModel,
    ranks: &[usize],
    rng: &mut Rng,
) -> (Allocation, f64) {
    let mut alloc = random_alloc(scn, ranks, rng);
    // alternate the two exhaustive searches to a fixed point (<= L*R evals)
    for _ in 0..4 {
        let (l, _) = split::best_split(scn, &alloc, conv);
        alloc.l_c = l;
        let (r, _) = rank::best_rank(scn, &alloc, conv, ranks);
        if r == alloc.rank {
            break;
        }
        alloc.rank = r;
    }
    let t = scn.total_delay(&alloc, conv);
    (alloc, t)
}

/// Baseline c: random split; proposed subchannel/power/rank via BCD
/// with the split frozen.
pub fn baseline_c(
    scn: &Scenario,
    conv: &ConvergenceModel,
    ranks: &[usize],
    rng: &mut Rng,
) -> Result<(Allocation, f64)> {
    let l = scn.profile.blocks.len();
    let frozen_l_c = 1 + rng.below(l.saturating_sub(1).max(1));
    let mut alloc = bcd::initial_alloc(scn, frozen_l_c, 4);
    let mut obj = scn.total_delay(&alloc, conv);
    for _ in 0..8 {
        let prev = obj;
        let a = crate::opt::assignment::algorithm2(scn, alloc.l_c, alloc.rank);
        let mut cand = alloc.clone();
        cand.assign_main = a.assign_main;
        cand.assign_fed = a.assign_fed;
        let ps = power::solve_power(scn, &cand)?;
        cand.psd_main = ps.psd_main;
        cand.psd_fed = ps.psd_fed;
        let o = scn.total_delay(&cand, conv);
        if o <= obj {
            alloc = cand;
            obj = o;
        }
        let (r, t_r) = rank::best_rank(scn, &alloc, conv, ranks);
        if t_r <= obj {
            alloc.rank = r;
            obj = t_r;
        }
        if (prev - obj).abs() < 1e-9 {
            break;
        }
    }
    Ok((alloc, obj))
}

/// Baseline d: proposed subchannel/power/split via BCD, random rank.
pub fn baseline_d(
    scn: &Scenario,
    conv: &ConvergenceModel,
    ranks: &[usize],
    rng: &mut Rng,
) -> Result<(Allocation, f64)> {
    let frozen_rank = *rng.choose(ranks);
    let mut alloc = bcd::initial_alloc(scn, (scn.profile.blocks.len() / 2).max(1), frozen_rank);
    let mut obj = scn.total_delay(&alloc, conv);
    for _ in 0..8 {
        let prev = obj;
        let a = crate::opt::assignment::algorithm2(scn, alloc.l_c, alloc.rank);
        let mut cand = alloc.clone();
        cand.assign_main = a.assign_main;
        cand.assign_fed = a.assign_fed;
        let ps = power::solve_power(scn, &cand)?;
        cand.psd_main = ps.psd_main;
        cand.psd_fed = ps.psd_fed;
        let o = scn.total_delay(&cand, conv);
        if o <= obj {
            alloc = cand;
            obj = o;
        }
        let (l_c, t_s) = split::best_split(scn, &alloc, conv);
        if t_s <= obj {
            alloc.l_c = l_c;
            obj = t_s;
        }
        if (prev - obj).abs() < 1e-9 {
            break;
        }
    }
    Ok((alloc, obj))
}

/// Run the proposed scheme plus all four baselines; returns
/// `(proposed, a, b, c, d)` objectives, averaging the random baselines
/// over `draws` seeded repetitions.
///
/// Deprecated: the experiment API now expresses this as a policy list —
/// `PolicyRegistry::paper_suite(ranks, seed, draws).resolve("all")` run
/// through a [`crate::sim::SweepRunner`] (or `solve`d directly). The
/// shim is kept so existing callers migrate in-tree; its draw streams
/// differ slightly from per-policy solves (one shared rng across all
/// four baselines per draw here, an independent stream per policy
/// there), which does not change any qualitative result.
#[deprecated(note = "use opt::PolicyRegistry::paper_suite(..) with sim::SweepRunner")]
pub fn compare_all(
    scn: &Scenario,
    conv: &ConvergenceModel,
    ranks: &[usize],
    seed: u64,
    draws: usize,
) -> Result<[f64; 5]> {
    let opts = BcdOptions {
        ranks: ranks.to_vec(),
        ..BcdOptions::default()
    };
    let proposed = bcd::optimize(scn, conv, &opts)?.objective;
    let mut acc = [0.0f64; 4];
    for d in 0..draws {
        let mut rng = Rng::new(seed ^ (d as u64).wrapping_mul(0x9E3779B97F4A7C15));
        acc[0] += baseline_a(scn, conv, ranks, &mut rng).1;
        acc[1] += baseline_b(scn, conv, ranks, &mut rng).1;
        acc[2] += baseline_c(scn, conv, ranks, &mut rng)?.1;
        acc[3] += baseline_d(scn, conv, ranks, &mut rng)?.1;
    }
    let n = draws.max(1) as f64;
    Ok([proposed, acc[0] / n, acc[1] / n, acc[2] / n, acc[3] / n])
}

#[cfg(test)]
mod tests {
    #![allow(deprecated)] // compare_all's behaviour is pinned by these tests
    use super::*;
    use crate::delay::testutil::toy_scenario;

    const RANKS: [usize; 5] = [1, 2, 4, 6, 8];

    #[test]
    fn all_baselines_feasible() {
        let scn = toy_scenario();
        let conv = ConvergenceModel::paper_default();
        let mut rng = Rng::new(1);
        let (a, _) = baseline_a(&scn, &conv, &RANKS, &mut rng);
        let (b, _) = baseline_b(&scn, &conv, &RANKS, &mut rng);
        let (c, _) = baseline_c(&scn, &conv, &RANKS, &mut rng).unwrap();
        let (d, _) = baseline_d(&scn, &conv, &RANKS, &mut rng).unwrap();
        for (name, alloc) in [("a", &a), ("b", &b), ("c", &c), ("d", &d)] {
            alloc
                .validate(scn.main_link.subch.len(), scn.fed_link.subch.len())
                .unwrap_or_else(|e| panic!("baseline {name}: {e}"));
            assert!(scn.power_feasible(alloc, 1e-6), "baseline {name} power");
        }
    }

    #[test]
    fn proposed_beats_every_baseline() {
        let scn = toy_scenario();
        let conv = ConvergenceModel::paper_default();
        let [p, a, b, c, d] = compare_all(&scn, &conv, &RANKS, 7, 3).unwrap();
        assert!(p <= a && p <= b && p <= c && p <= d, "p={p} a={a} b={b} c={c} d={d}");
    }

    #[test]
    fn partial_optimization_helps() {
        // each partially-optimized baseline should beat fully-random (a)
        // on average over draws
        let scn = toy_scenario();
        let conv = ConvergenceModel::paper_default();
        let [_, a, b, c, d] = compare_all(&scn, &conv, &RANKS, 3, 5).unwrap();
        assert!(b <= a * 1.05, "b={b} vs a={a}");
        assert!(c <= a * 1.05, "c={c} vs a={a}");
        assert!(d <= a * 1.05, "d={d} vs a={a}");
    }
}
