//! The experiment-facing allocation-policy API.
//!
//! The paper's evaluation (Sec. VII-C, Figs. 5–8) is a matrix of
//! *policies × scenarios × sweep axes*. This module provides the policy
//! leg of that matrix: [`AllocationPolicy`] abstracts "given a scenario,
//! produce an allocation and its objective", with the proposed BCD
//! scheme (Algorithm 3) and baselines a–d as implementations, and a
//! string-keyed [`PolicyRegistry`] so the CLI, benches, and sweeps can
//! select policies by name (`proposed`, `baseline_a` … `baseline_d`).
//!
//! Policies are `Send + Sync` and stateless across calls — any
//! randomness (the baselines' draws) is re-seeded inside `solve` — so a
//! single policy instance can be shared by every worker thread of a
//! [`crate::sim::SweepRunner`] and still produce bit-identical results
//! at any thread count.

use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::delay::{Allocation, ConvergenceModel, Scenario, WorkloadCache};
use crate::opt::baselines;
use crate::opt::bcd::{self, BcdOptions};
use crate::util::rng::Rng;

/// Everything a policy reports for one scenario.
#[derive(Clone, Debug)]
pub struct PolicyOutcome {
    /// Name of the policy that produced this outcome.
    pub policy: String,
    /// The chosen allocation. For draw-averaged baselines this is the
    /// best draw's allocation while [`PolicyOutcome::objective`] is the
    /// mean over draws (the quantity the paper plots).
    pub alloc: Allocation,
    /// The objective score the policy minimized — total training delay
    /// T (Eq. 17, seconds) under the default delay objective; joules
    /// under `energy`; the scalarized value for `weighted`/`budget`
    /// (see [`crate::opt::Objective`]).
    pub objective: f64,
    /// Total training delay T (Eq. 17) of `alloc`, seconds —
    /// regardless of the objective.
    pub delay: f64,
    /// Total training energy of `alloc` at the scenario's ζ, joules.
    pub energy: f64,
    /// Objective after every outer iteration, when the policy is
    /// iterative (BCD); `None` for one-shot baselines.
    pub trajectory: Option<Vec<f64>>,
    /// Outer iterations (BCD) or random draws (baselines).
    pub iterations: usize,
    /// Feasibility-repair tier that produced this outcome (PR-10):
    /// 0 = clean solve, 1 = re-scored incumbent, 2 = baseline-d
    /// fallback, 3 = worst-channel clients shed (see
    /// [`solve_with_repair`]). Always 0 from a direct
    /// [`AllocationPolicy::solve_cached`].
    pub repair_tier: u8,
    /// View-indices of clients shed by tier 3 (empty below tier 3).
    /// Their `alloc` rows are empty — callers must drop them from the
    /// round's participation mask.
    pub shed: Vec<usize>,
}

/// A named allocation scheme: scenario in, allocation + objective out.
///
/// Implementations must be deterministic functions of
/// `(self, scenario, convergence model)` — see the module docs. The
/// [`WorkloadCache`] passed to [`AllocationPolicy::solve_cached`] is a
/// pure memo of per-(l_c, rank) workload tables and must never change a
/// result, only its cost — [`crate::sim::SweepRunner`] hands every grid
/// point the same cache so solves over the same model/rank set share
/// one table.
///
/// **Cohort-view contract:** workload tables are keyed on the model
/// profile and candidate rank set only — never on K, the channel, or
/// anything else a per-round cohort view changes. A caller that lowers
/// shifting cohorts out of a large population
/// ([`crate::sim::PopulationSimulator`]) therefore solves every view
/// against one shared table, and a solve over a cohort view must be
/// bit-identical to a solve over any other scenario with the same
/// per-client numbers. Policies must not stash per-scenario state
/// across calls.
pub trait AllocationPolicy: Send + Sync {
    /// Stable identifier used by [`PolicyRegistry`] and report columns.
    fn name(&self) -> &str;

    /// Solve the scenario, reusing workload tables from `cache`.
    fn solve_cached(
        &self,
        scn: &Scenario,
        conv: &ConvergenceModel,
        cache: &WorkloadCache,
    ) -> Result<PolicyOutcome>;

    /// Solve the scenario with a private single-use cache.
    fn solve(&self, scn: &Scenario, conv: &ConvergenceModel) -> Result<PolicyOutcome> {
        self.solve_cached(scn, conv, &WorkloadCache::new())
    }
}

/// The proposed scheme: Algorithm 3, BCD over subproblems P1–P4.
#[derive(Clone, Debug)]
pub struct Proposed {
    pub opts: BcdOptions,
}

impl Proposed {
    pub fn new(opts: BcdOptions) -> Proposed {
        Proposed { opts }
    }

    /// Default BCD options with the given candidate rank set.
    pub fn with_ranks(ranks: &[usize]) -> Proposed {
        Proposed {
            opts: BcdOptions {
                ranks: ranks.to_vec(),
                ..BcdOptions::default()
            },
        }
    }
}

impl AllocationPolicy for Proposed {
    fn name(&self) -> &str {
        "proposed"
    }

    fn solve_cached(
        &self,
        scn: &Scenario,
        conv: &ConvergenceModel,
        cache: &WorkloadCache,
    ) -> Result<PolicyOutcome> {
        let res = bcd::optimize_cached(scn, conv, &self.opts, cache)?;
        Ok(PolicyOutcome {
            policy: self.name().to_string(),
            alloc: res.alloc,
            objective: res.objective,
            delay: res.delay,
            energy: res.energy,
            trajectory: Some(res.trajectory),
            iterations: res.iterations,
            repair_tier: 0,
            shed: Vec::new(),
        })
    }
}

/// Which of the paper's four baselines a [`RandomBaseline`] runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BaselineKind {
    /// a — random subchannels, PSD, split, and rank.
    A,
    /// b — random communication; proposed rank + split.
    B,
    /// c — random split; proposed subchannel/power/rank.
    C,
    /// d — random rank; proposed subchannel/power/split.
    D,
}

impl BaselineKind {
    pub fn label(self) -> &'static str {
        match self {
            BaselineKind::A => "baseline_a",
            BaselineKind::B => "baseline_b",
            BaselineKind::C => "baseline_c",
            BaselineKind::D => "baseline_d",
        }
    }

    /// Short human description for tables.
    pub fn describe(self) -> &'static str {
        match self {
            BaselineKind::A => "random everything",
            BaselineKind::B => "random comm",
            BaselineKind::C => "random split",
            BaselineKind::D => "random rank",
        }
    }
}

/// A seeded, draw-averaged baseline policy (paper Sec. VII-C).
///
/// Each draw re-seeds its own [`Rng`] from `(seed, draw index)`, so the
/// result is independent of call order and thread placement.
#[derive(Clone, Debug)]
pub struct RandomBaseline {
    pub kind: BaselineKind,
    pub ranks: Vec<usize>,
    pub seed: u64,
    pub draws: usize,
}

impl RandomBaseline {
    pub fn new(kind: BaselineKind, ranks: &[usize], seed: u64, draws: usize) -> RandomBaseline {
        RandomBaseline {
            kind,
            ranks: ranks.to_vec(),
            seed,
            draws: draws.max(1),
        }
    }

    fn draw_rng(&self, draw: u64) -> Rng {
        Rng::new(self.seed ^ draw.wrapping_mul(0x9E3779B97F4A7C15))
    }
}

impl AllocationPolicy for RandomBaseline {
    fn name(&self) -> &str {
        self.kind.label()
    }

    fn solve_cached(
        &self,
        scn: &Scenario,
        conv: &ConvergenceModel,
        cache: &WorkloadCache,
    ) -> Result<PolicyOutcome> {
        let mut sum = 0.0;
        let mut best: Option<(Allocation, f64)> = None;
        for d in 0..self.draws {
            let mut rng = self.draw_rng(d as u64);
            let (alloc, t) = match self.kind {
                BaselineKind::A => baselines::baseline_a(scn, conv, &self.ranks, &mut rng)?,
                BaselineKind::B => {
                    baselines::baseline_b(scn, conv, &self.ranks, &mut rng, cache)?
                }
                BaselineKind::C => {
                    baselines::baseline_c(scn, conv, &self.ranks, &mut rng, cache)?
                }
                BaselineKind::D => {
                    baselines::baseline_d(scn, conv, &self.ranks, &mut rng, cache)?
                }
            };
            sum += t;
            if best.as_ref().map(|&(_, bt)| t < bt).unwrap_or(true) {
                best = Some((alloc, t));
            }
        }
        let (alloc, _) =
            best.ok_or_else(|| anyhow!("baseline {:?} completed zero draws", self.kind))?;
        let delay = scn.total_delay(&alloc, conv);
        let energy =
            crate::delay::energy::total_energy(scn, &alloc, conv, scn.objective.zeta);
        Ok(PolicyOutcome {
            policy: self.name().to_string(),
            alloc,
            objective: sum / self.draws as f64,
            delay,
            energy,
            trajectory: None,
            iterations: self.draws,
            repair_tier: 0,
            shed: Vec::new(),
        })
    }
}

/// Build a repaired outcome from an allocation scored on the (full or
/// subset) scenario it lives on.
fn repaired_outcome(
    name: &str,
    alloc: Allocation,
    scn: &Scenario,
    conv: &ConvergenceModel,
    objective: &crate::delay::Objective,
    tier: u8,
    shed: Vec<usize>,
) -> PolicyOutcome {
    let score = crate::delay::objective::score_alloc(scn, &alloc, conv, objective);
    let delay = scn.total_delay(&alloc, conv);
    let energy = crate::delay::energy::total_energy(scn, &alloc, conv, scn.objective.zeta);
    PolicyOutcome {
        policy: name.to_string(),
        alloc,
        objective: score,
        delay,
        energy,
        trajectory: None,
        iterations: 0,
        repair_tier: tier,
        shed,
    }
}

/// The scenario restricted to the `kept` clients (sorted view-indices):
/// only the per-client data shrinks — subchannels, budgets, and the
/// workload profile are K-independent, which is exactly the cohort-view
/// contract the workload cache already relies on.
fn subset_scenario(scn: &Scenario, kept: &[usize]) -> Scenario {
    let mut sub = scn.clone();
    sub.topo.clients = kept.iter().map(|&k| scn.topo.clients[k].clone()).collect();
    sub.main_link.client_gain = kept.iter().map(|&k| scn.main_link.client_gain[k]).collect();
    sub.fed_link.client_gain = kept.iter().map(|&k| scn.fed_link.client_gain[k]).collect();
    sub
}

/// Expand a subset-scenario allocation back to the full client index
/// space: kept clients get their subset rows, shed clients get empty
/// rows (no subchannels ⇒ they must be excluded from the round's
/// participation mask). PSD vectors are per-subchannel and carry over
/// unchanged, so the expanded allocation still satisfies C1/C2/C6.
fn expand_alloc(sub: &Allocation, kept: &[usize], k_full: usize) -> Allocation {
    let mut assign_main = vec![Vec::new(); k_full];
    let mut assign_fed = vec![Vec::new(); k_full];
    for (j, &k) in kept.iter().enumerate() {
        assign_main[k] = sub.assign_main[j].clone();
        assign_fed[k] = sub.assign_fed[j].clone();
    }
    Allocation {
        assign_main,
        assign_fed,
        psd_main: sub.psd_main.clone(),
        psd_fed: sub.psd_fed.clone(),
        l_c: sub.l_c,
        rank: sub.rank,
    }
}

/// Four-tier feasibility repair (PR-10): degrade instead of die when a
/// scenario turns infeasible mid-run (subchannel outages and blackouts
/// can starve an uplink outright).
///
/// * **Tier 0** — the policy's own solve; returned untouched when it
///   succeeds with a finite objective, so the healthy path is
///   bit-identical to calling [`AllocationPolicy::solve_cached`]
///   directly (nothing below even constructs).
/// * **Tier 1** — re-score the caller's incumbent allocation on the
///   current scenario; adopt it when finite (the fleet keeps running on
///   yesterday's allocation).
/// * **Tier 2** — a deterministic single-draw baseline-d allocation
///   (proposed subchannel/power/split, frozen random rank) from a fixed
///   seed, adopted when finite.
/// * **Tier 3** — shed the worst-channel clients: rank clients by
///   `min(gain_main, gain_fed)` ascending (ties by index), drop the
///   smallest prefix that makes the remaining subset solvable, and
///   expand the subset allocation back to the full index space with
///   empty rows for the shed clients. The outcome's
///   objective/delay/energy are those of the *participating* subset
///   (the shed clients sit the round out).
///
/// The chosen tier and shed set are recorded in
/// [`PolicyOutcome::repair_tier`] / [`PolicyOutcome::shed`]; when every
/// tier fails, the tier-0 error is returned with the repair trail
/// attached.
pub fn solve_with_repair(
    policy: &dyn AllocationPolicy,
    scn: &Scenario,
    conv: &ConvergenceModel,
    cache: &WorkloadCache,
    incumbent: Option<&Allocation>,
    ranks: &[usize],
) -> Result<PolicyOutcome> {
    // tier 0: the clean solve — the only statements on the healthy path
    let err = match policy.solve_cached(scn, conv, cache) {
        Ok(out) if out.objective.is_finite() => return Ok(out),
        Ok(out) => anyhow!(
            "{}: solve returned a non-finite objective ({})",
            policy.name(),
            out.objective
        ),
        Err(e) => e,
    };
    let objective = crate::delay::Objective::from_config(&scn.objective)?;
    // tier 1: re-score the incumbent on the current channel
    if let Some(inc) = incumbent {
        if inc.assign_main.len() == scn.k() {
            let out = repaired_outcome(
                policy.name(),
                inc.clone(),
                scn,
                conv,
                &objective,
                1,
                Vec::new(),
            );
            if out.objective.is_finite() {
                return Ok(out);
            }
        }
    }
    // tier 2: deterministic baseline-d fallback (fixed seed — the
    // repair schedule must replay bit-for-bit)
    let mut rng = Rng::new(0xD_FA17);
    if let Ok((alloc, score)) = baselines::baseline_d(scn, conv, ranks, &mut rng, cache) {
        if score.is_finite() {
            let mut out =
                repaired_outcome(policy.name(), alloc, scn, conv, &objective, 2, Vec::new());
            out.objective = score;
            return Ok(out);
        }
    }
    // tier 3: shed worst-channel clients until the subset solves
    let k_full = scn.k();
    let mut order: Vec<usize> = (0..k_full).collect();
    order.sort_by(|&a, &b| {
        let ga = scn.main_link.client_gain[a].min(scn.fed_link.client_gain[a]);
        let gb = scn.main_link.client_gain[b].min(scn.fed_link.client_gain[b]);
        ga.total_cmp(&gb).then(a.cmp(&b))
    });
    // a client with an exactly-zero gain can never upload — start by
    // shedding all of those at once, then widen one client at a time
    let dead = order
        .iter()
        .take_while(|&&k| {
            scn.main_link.client_gain[k].min(scn.fed_link.client_gain[k]) == 0.0
        })
        .count();
    for shed_n in dead.max(1)..k_full {
        let mut shed: Vec<usize> = order[..shed_n].to_vec();
        shed.sort_unstable();
        let kept: Vec<usize> = (0..k_full).filter(|k| !shed.contains(k)).collect();
        let sub_scn = subset_scenario(scn, &kept);
        let sub = match policy.solve_cached(&sub_scn, conv, cache) {
            Ok(out) if out.objective.is_finite() => out,
            _ => continue,
        };
        let alloc = expand_alloc(&sub.alloc, &kept, k_full);
        return Ok(PolicyOutcome {
            policy: sub.policy,
            alloc,
            objective: sub.objective,
            delay: sub.delay,
            energy: sub.energy,
            trajectory: sub.trajectory,
            iterations: sub.iterations,
            repair_tier: 3,
            shed,
        });
    }
    Err(err.context(
        "feasibility repair exhausted: fresh solve failed, incumbent re-score non-finite, \
         baseline-d fallback non-finite, and no sheddable client subset solved",
    ))
}

/// String-keyed policy lookup, preserving registration order (which
/// becomes the column order of sweep reports).
#[derive(Clone, Default)]
pub struct PolicyRegistry {
    policies: Vec<Arc<dyn AllocationPolicy>>,
}

impl PolicyRegistry {
    pub fn new() -> PolicyRegistry {
        PolicyRegistry::default()
    }

    /// The paper's evaluation suite: `proposed` plus `baseline_{a..d}`,
    /// baselines averaged over `draws` seeded repetitions.
    pub fn paper_suite(ranks: &[usize], seed: u64, draws: usize) -> PolicyRegistry {
        let mut reg = PolicyRegistry::new();
        reg.register(Arc::new(Proposed::with_ranks(ranks)));
        for kind in [
            BaselineKind::A,
            BaselineKind::B,
            BaselineKind::C,
            BaselineKind::D,
        ] {
            reg.register(Arc::new(RandomBaseline::new(kind, ranks, seed, draws)));
        }
        reg
    }

    /// Add a policy; a same-named earlier registration is replaced in
    /// place (so callers can override `proposed` with tuned options).
    pub fn register(&mut self, policy: Arc<dyn AllocationPolicy>) {
        match self.policies.iter().position(|p| p.name() == policy.name()) {
            Some(i) => self.policies[i] = policy,
            None => self.policies.push(policy),
        }
    }

    /// Registered names, in registration order.
    pub fn names(&self) -> Vec<String> {
        self.policies.iter().map(|p| p.name().to_string()).collect()
    }

    pub fn is_empty(&self) -> bool {
        self.policies.is_empty()
    }

    pub fn len(&self) -> usize {
        self.policies.len()
    }

    /// Look one policy up by name.
    pub fn get(&self, name: &str) -> Result<Arc<dyn AllocationPolicy>> {
        self.policies
            .iter()
            .find(|p| p.name() == name)
            .cloned()
            .ok_or_else(|| {
                anyhow!(
                    "unknown policy '{name}' (available: {})",
                    self.names().join(", ")
                )
            })
    }

    /// Resolve a CLI-style spec: `all`, or a comma-separated name list.
    pub fn resolve(&self, spec: &str) -> Result<Vec<Arc<dyn AllocationPolicy>>> {
        if spec.trim() == "all" {
            return Ok(self.policies.clone());
        }
        spec.split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(|name| self.get(name))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delay::testutil::toy_scenario;

    const RANKS: [usize; 5] = [1, 2, 4, 6, 8];

    fn suite() -> PolicyRegistry {
        PolicyRegistry::paper_suite(&RANKS, 7, 2)
    }

    #[test]
    fn registry_resolves_all_paper_policies_by_name() {
        let reg = suite();
        assert_eq!(
            reg.names(),
            vec!["proposed", "baseline_a", "baseline_b", "baseline_c", "baseline_d"]
        );
        for name in reg.names() {
            assert_eq!(reg.get(&name).unwrap().name(), name);
        }
        assert!(reg.get("nope").is_err());
    }

    #[test]
    fn resolve_handles_all_and_lists() {
        let reg = suite();
        assert_eq!(reg.resolve("all").unwrap().len(), 5);
        let two = reg.resolve("proposed, baseline_c").unwrap();
        assert_eq!(two[0].name(), "proposed");
        assert_eq!(two[1].name(), "baseline_c");
        assert!(reg.resolve("proposed,typo").is_err());
    }

    #[test]
    fn register_replaces_same_name_in_place() {
        let mut reg = suite();
        reg.register(Arc::new(Proposed::new(BcdOptions {
            max_iter: 3,
            ..BcdOptions::default()
        })));
        assert_eq!(reg.len(), 5);
        assert_eq!(reg.names()[0], "proposed");
    }

    #[test]
    fn every_policy_is_feasible_on_the_toy_scenario() {
        let scn = toy_scenario();
        let conv = ConvergenceModel::paper_default();
        for policy in suite().resolve("all").unwrap() {
            let out = policy.solve(&scn, &conv).unwrap();
            assert_eq!(out.policy, policy.name());
            assert!(out.objective.is_finite() && out.objective > 0.0, "{}", out.policy);
            out.alloc
                .validate(scn.main_link.subch.len(), scn.fed_link.subch.len())
                .unwrap_or_else(|e| panic!("{}: {e}", policy.name()));
            assert!(scn.power_feasible(&out.alloc, 1e-6), "{}", policy.name());
        }
    }

    #[test]
    fn policies_are_deterministic_across_calls() {
        let scn = toy_scenario();
        let conv = ConvergenceModel::paper_default();
        for policy in suite().resolve("all").unwrap() {
            let a = policy.solve(&scn, &conv).unwrap();
            let b = policy.solve(&scn, &conv).unwrap();
            assert_eq!(a.objective.to_bits(), b.objective.to_bits(), "{}", policy.name());
        }
    }

    #[test]
    fn shared_cache_never_changes_a_result() {
        let scn = toy_scenario();
        let conv = ConvergenceModel::paper_default();
        let cache = crate::delay::WorkloadCache::new();
        for policy in suite().resolve("all").unwrap() {
            let fresh = policy.solve(&scn, &conv).unwrap();
            let cached = policy.solve_cached(&scn, &conv, &cache).unwrap();
            let again = policy.solve_cached(&scn, &conv, &cache).unwrap();
            assert_eq!(fresh.objective.to_bits(), cached.objective.to_bits(), "{}", policy.name());
            assert_eq!(cached.objective.to_bits(), again.objective.to_bits(), "{}", policy.name());
            assert_eq!(cached.alloc.l_c, fresh.alloc.l_c, "{}", policy.name());
            assert_eq!(cached.alloc.rank, fresh.alloc.rank, "{}", policy.name());
        }
        // proposed + all baselines share the one (profile, ranks) table
        assert_eq!(cache.tables(), 1);
    }

    #[test]
    fn cohort_views_of_every_size_share_one_workload_table() {
        // the cohort-view contract: tables key on (profile, ranks) only,
        // so solves over views of different K all hit the same entry
        let mut cfg = crate::config::Config::paper_defaults();
        cfg.model = "tiny".to_string();
        cfg.train.seq = 64;
        let conv = ConvergenceModel::paper_default();
        let cache = crate::delay::WorkloadCache::new();
        let policy = Proposed::with_ranks(&RANKS);
        for k in [3usize, 5, 8] {
            let mut kcfg = cfg.clone();
            kcfg.system.clients = k;
            let scn = crate::sim::ScenarioBuilder::from_config(kcfg).build().unwrap();
            let shared = policy.solve_cached(&scn, &conv, &cache).unwrap();
            // and the shared table never changes the result for any K
            let private = policy.solve(&scn, &conv).unwrap();
            assert_eq!(shared.objective.to_bits(), private.objective.to_bits(), "K={k}");
            assert_eq!(shared.alloc.l_c, private.alloc.l_c, "K={k}");
            assert_eq!(shared.alloc.rank, private.alloc.rank, "K={k}");
        }
        assert_eq!(cache.tables(), 1);
    }

    #[test]
    fn outcomes_carry_delay_and_energy_for_every_policy() {
        let scn = toy_scenario();
        let conv = ConvergenceModel::paper_default();
        for policy in suite().resolve("all").unwrap() {
            let out = policy.solve(&scn, &conv).unwrap();
            assert_eq!(
                out.delay.to_bits(),
                scn.total_delay(&out.alloc, &conv).to_bits(),
                "{}",
                out.policy
            );
            assert_eq!(
                out.energy.to_bits(),
                crate::delay::energy::total_energy(&scn, &out.alloc, &conv, scn.objective.zeta)
                    .to_bits(),
                "{}",
                out.policy
            );
            assert!(out.energy.is_finite() && out.energy > 0.0, "{}", out.policy);
        }
        // under the default delay objective the proposed score IS delay
        let p = suite().get("proposed").unwrap().solve(&scn, &conv).unwrap();
        assert_eq!(p.objective.to_bits(), p.delay.to_bits());
    }

    #[test]
    fn energy_objective_flows_from_the_scenario_to_every_policy() {
        // scenario-driven objective: every registry policy minimizes
        // energy and reports it as the score
        let mut scn = toy_scenario();
        scn.objective.kind = "energy".to_string();
        let conv = ConvergenceModel::paper_default();
        for policy in suite().resolve("all").unwrap() {
            let out = policy.solve(&scn, &conv).unwrap();
            assert!(out.objective.is_finite() && out.objective > 0.0, "{}", out.policy);
            if out.policy == "proposed" {
                assert_eq!(
                    out.objective.to_bits(),
                    out.energy.to_bits(),
                    "proposed must score by energy"
                );
            }
        }
    }

    /// A mock policy whose solve always fails — forces the repair
    /// chain past tier 0.
    struct AlwaysFails;

    impl AllocationPolicy for AlwaysFails {
        fn name(&self) -> &str {
            "always_fails"
        }

        fn solve_cached(
            &self,
            _scn: &Scenario,
            _conv: &ConvergenceModel,
            _cache: &WorkloadCache,
        ) -> Result<PolicyOutcome> {
            Err(anyhow!("mock: solver exploded"))
        }
    }

    #[test]
    fn repair_tier0_is_the_clean_solve_bit_for_bit() {
        let scn = toy_scenario();
        let conv = ConvergenceModel::paper_default();
        let cache = WorkloadCache::new();
        let policy = Proposed::with_ranks(&RANKS);
        let direct = policy.solve_cached(&scn, &conv, &cache).unwrap();
        let repaired =
            solve_with_repair(&policy, &scn, &conv, &cache, None, &RANKS).unwrap();
        assert_eq!(repaired.repair_tier, 0);
        assert!(repaired.shed.is_empty());
        assert_eq!(repaired.objective.to_bits(), direct.objective.to_bits());
        assert_eq!(repaired.delay.to_bits(), direct.delay.to_bits());
        assert_eq!(repaired.alloc.l_c, direct.alloc.l_c);
        assert_eq!(repaired.alloc.rank, direct.alloc.rank);
    }

    #[test]
    fn repair_tier1_adopts_a_finite_incumbent() {
        let scn = toy_scenario();
        let conv = ConvergenceModel::paper_default();
        let cache = WorkloadCache::new();
        let inc = Proposed::with_ranks(&RANKS)
            .solve_cached(&scn, &conv, &cache)
            .unwrap()
            .alloc;
        let out =
            solve_with_repair(&AlwaysFails, &scn, &conv, &cache, Some(&inc), &RANKS).unwrap();
        assert_eq!(out.repair_tier, 1);
        assert!(out.shed.is_empty());
        assert!(out.objective.is_finite());
        assert_eq!(out.policy, "always_fails");
        assert_eq!(out.alloc.l_c, inc.l_c);
        assert_eq!(
            out.delay.to_bits(),
            scn.total_delay(&inc, &conv).to_bits(),
            "tier 1 must re-score the incumbent on the current scenario"
        );
    }

    #[test]
    fn repair_tier2_falls_back_to_baseline_d() {
        let scn = toy_scenario();
        let conv = ConvergenceModel::paper_default();
        let cache = WorkloadCache::new();
        let out = solve_with_repair(&AlwaysFails, &scn, &conv, &cache, None, &RANKS).unwrap();
        assert_eq!(out.repair_tier, 2);
        assert!(out.objective.is_finite());
        out.alloc
            .validate(scn.main_link.subch.len(), scn.fed_link.subch.len())
            .unwrap();
        // deterministic: the fallback draw is fixed-seeded
        let again = solve_with_repair(&AlwaysFails, &scn, &conv, &cache, None, &RANKS).unwrap();
        assert_eq!(out.objective.to_bits(), again.objective.to_bits());
    }

    #[test]
    fn repair_tier3_sheds_the_dead_uplink_client() {
        // client 1's main uplink is gone entirely: every allocation
        // gives it rate 0 ⇒ infinite delay, so tiers 0–2 are all
        // non-finite and the chain must shed client 1
        let mut scn = toy_scenario();
        scn.main_link.client_gain[1] = 0.0;
        let conv = ConvergenceModel::paper_default();
        let cache = WorkloadCache::new();
        let policy = Proposed::with_ranks(&RANKS);
        let inc = Proposed::with_ranks(&RANKS)
            .solve_cached(&toy_scenario(), &conv, &cache)
            .unwrap()
            .alloc;
        let out =
            solve_with_repair(&policy, &scn, &conv, &cache, Some(&inc), &RANKS).unwrap();
        assert_eq!(out.repair_tier, 3);
        assert_eq!(out.shed, vec![1]);
        assert!(out.objective.is_finite());
        assert!(out.alloc.assign_main[1].is_empty() && out.alloc.assign_fed[1].is_empty());
        // kept client owns every subchannel: C1/C2 still hold
        out.alloc
            .validate(scn.main_link.subch.len(), scn.fed_link.subch.len())
            .unwrap();
    }

    #[test]
    fn repair_exhaustion_reports_the_whole_trail() {
        // every uplink dead ⇒ nothing is solvable at any tier
        let mut scn = toy_scenario();
        scn.main_link.client_gain = vec![0.0, 0.0];
        let err = solve_with_repair(
            &AlwaysFails,
            &scn,
            &ConvergenceModel::paper_default(),
            &WorkloadCache::new(),
            None,
            &RANKS,
        )
        .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("feasibility repair exhausted"), "{msg}");
        assert!(msg.contains("mock: solver exploded"), "{msg}");
    }

    #[test]
    fn proposed_reports_monotone_trajectory() {
        let scn = toy_scenario();
        let conv = ConvergenceModel::paper_default();
        let out = suite().get("proposed").unwrap().solve(&scn, &conv).unwrap();
        let traj = out.trajectory.expect("BCD must report a trajectory");
        for w in traj.windows(2) {
            assert!(w[1] <= w[0] + 1e-9, "trajectory rose: {traj:?}");
        }
        assert_eq!(out.objective, *traj.last().unwrap());
    }
}
