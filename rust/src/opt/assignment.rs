//! P1 — greedy subchannel assignment (paper Algorithm 2), as an
//! **incremental engine**.
//!
//! Phase 1 guarantees every client at least one subchannel on each
//! link, pairing the *weakest* client (lowest f_k on the main link,
//! farthest d_k^f on the fed link) with the *widest* remaining
//! subchannel. Phase 2 repeatedly gives the widest remaining subchannel
//! to the current straggler — the client with the largest
//! `T_k^F + T_k^s` (main link) or `T_k^f` (fed link) — skipping clients
//! for whom the power caps C4/C5 the subchannel *at hand* would violate
//! at the current PSD. (Eligibility is re-tested per subchannel: a
//! client barred from a wide subchannel may still fit a narrower,
//! cheaper one later in the pass — the old implementation latched the
//! exclusion for the rest of the pass, permanently starving the
//! straggler; see `rust/tests/prop_assignment.rs` for the regression.)
//!
//! During assignment the rates are evaluated at a *nominal* PSD (the
//! per-link total budget spread uniformly over the whole band); the
//! exact PSDs are re-optimized right after by [`super::power`], matching
//! the BCD ordering of Algorithm 3.
//!
//! ## The incremental hot path
//!
//! The straggler scan used to recompute every client's stage delay
//! (summing that client's subchannel rates from scratch) and the full
//! per-link transmit-power total for **every one** of the N phase-2
//! grants — `O(N·K·(K+S))` work dominated by `log2` rate evaluations.
//! [`algorithm2`] instead keeps
//!
//! * a per-client **rate accumulator** (one new `subch_rate` per grant,
//!   added in exactly the left-to-right order the from-scratch sum
//!   folds in, so every derived float is bit-identical),
//! * a per-client **power accumulator** (same argument), and
//! * a **lazy max-heap** over straggler delays: only the granted
//!   client's delay ever changes, so each grant pushes one fresh entry
//!   and stale entries are discarded on pop via a per-client epoch.
//!
//! which brings a grant down to `O(log K)` heap work plus one `O(K)`
//! float-add pass for the C5 total. (The C5 total is deliberately
//! re-summed grouped by client — the exact summation order of the
//! reference scan — because the nominal PSD fills the budget *exactly*
//! when every subchannel is granted, so the final grants sit on the C5
//! float boundary and any re-association could flip them.)
//!
//! [`algorithm2_reference`] keeps the naive `O(N·K·(K+S))` scan as the
//! executable spec: `rust/tests/prop_assignment.rs` asserts the heap
//! engine is **bit-identical** to it on every preset and on seeded
//! random scenarios, and `benches/micro_hotpath.rs` / the `bench` CLI
//! subcommand track the speedup (the `algorithm2` axis).
//!
//! [`AssignScratch`] hoists the widest-first subchannel order and the
//! phase-1 client order (plus all accumulator buffers) out of the call,
//! so the BCD loop's repeated `algorithm2` invocations on one scenario
//! sort each link once instead of once per iteration.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use crate::delay::Scenario;
use crate::net::Link;
use crate::util::stats::fsum;

/// Assignment produced by Algorithm 2 for both links.
#[derive(Clone, Debug)]
pub struct AssignmentResult {
    pub assign_main: Vec<Vec<usize>>,
    pub assign_fed: Vec<Vec<usize>>,
    /// Nominal PSDs used during the greedy evaluation (useful as a
    /// starting point before the exact P2 solve).
    pub psd_main_nominal: f64,
    pub psd_fed_nominal: f64,
}

/// Sort subchannel ids by bandwidth, widest first (ties by id for
/// determinism).
fn widest_first(link: &Link) -> Vec<usize> {
    let mut ids: Vec<usize> = (0..link.subch.len()).collect();
    // total_cmp == partial_cmp on the strictly positive bandwidths,
    // without the NaN panic path
    ids.sort_by(|&a, &b| {
        link.subch.bandwidth_hz[b]
            .total_cmp(&link.subch.bandwidth_hz[a])
            .then(a.cmp(&b))
    });
    ids
}

/// One straggler-heap entry. Max-heap order: larger delay first, ties
/// to the **smaller** client index — the same client the reference
/// scan's first-maximum linear pass selects.
#[derive(Clone, Copy, Debug)]
struct Entry {
    delay: f64,
    k: usize,
    epoch: u32,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for Entry {}

impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        // total_cmp matches partial_cmp on the non-negative delays the
        // stage metrics produce (including +inf for starved clients)
        self.delay
            .total_cmp(&other.delay)
            .then_with(|| other.k.cmp(&self.k))
    }
}

/// Per-link reusable state: the two cached sort orders (invalidated by
/// comparing against the exact inputs they were computed from, so a
/// scratch can never serve a stale order) and the phase-2 accumulators.
#[derive(Default)]
struct LinkScratch {
    /// Widest-first subchannel order + the bandwidths it was sorted from.
    widest: Vec<usize>,
    widest_src: Vec<f64>,
    /// Phase-1 client order + the priority values it was sorted from.
    order: Vec<usize>,
    order_src: Vec<f64>,
    /// Per-client accumulated uplink rate / transmit power at the
    /// nominal PSD.
    rate: Vec<f64>,
    power: Vec<f64>,
    /// Lazy-deletion epoch per client (entry is live iff epochs match).
    epoch: Vec<u32>,
    heap: BinaryHeap<Entry>,
    /// Clients set aside because C4 barred them from the subchannel at
    /// hand; restored to the heap before the next subchannel.
    deferred: Vec<Entry>,
}

impl LinkScratch {
    /// Refresh the cached orders if their inputs changed and reset the
    /// per-call accumulators.
    fn prepare<FP: Fn(usize) -> f64>(&mut self, link: &Link, k_n: usize, priority: FP) {
        if self.widest_src != link.subch.bandwidth_hz {
            self.widest = widest_first(link);
            self.widest_src.clear();
            self.widest_src.extend_from_slice(&link.subch.bandwidth_hz);
        }
        let prio: Vec<f64> = (0..k_n).map(&priority).collect();
        if self.order_src != prio {
            let mut order: Vec<usize> = (0..k_n).collect();
            // weakest (largest priority value) first, ties by index —
            // the reference's exact sort (total_cmp: priorities are
            // finite and never NaN, so the order is unchanged)
            order.sort_by(|&a, &b| prio[b].total_cmp(&prio[a]).then(a.cmp(&b)));
            self.order = order;
            self.order_src = prio;
        }
        self.rate.clear();
        self.rate.resize(k_n, 0.0);
        self.power.clear();
        self.power.resize(k_n, 0.0);
        self.epoch.clear();
        self.epoch.resize(k_n, 0);
        self.heap.clear();
        self.deferred.clear();
    }
}

/// Reusable state for repeated [`algorithm2_with`] calls: the sorted
/// subchannel/client orders per link plus all phase-2 buffers. One
/// scratch serves any sequence of calls — the cached orders are
/// validated against their exact inputs on every call, so reusing a
/// scratch across scenarios is safe (just pointless). The BCD loop
/// keeps one scratch per `optimize` call so its iterations sort each
/// link once.
#[derive(Default)]
pub struct AssignScratch {
    main: LinkScratch,
    fed: LinkScratch,
}

impl AssignScratch {
    pub fn new() -> AssignScratch {
        AssignScratch::default()
    }
}

/// One link's greedy pass on the incremental engine. `stage_delay`
/// evaluates the phase-2 straggler metric from a client's *accumulated*
/// uplink rate.
fn greedy_link_fast<FD>(
    link: &Link,
    k_n: usize,
    psd_nominal: f64,
    p_max_w: f64,
    p_th_w: f64,
    ls: &mut LinkScratch,
    stage_delay: FD,
) -> Vec<Vec<usize>>
where
    FD: Fn(usize, f64) -> f64,
{
    let mut assign: Vec<Vec<usize>> = vec![Vec::new(); k_n];
    let LinkScratch {
        widest,
        order,
        rate,
        power,
        epoch,
        heap,
        deferred,
        ..
    } = ls;

    // Phase 1: weakest client first, widest subchannel each. Rates and
    // powers accumulate in grant order — the same left-to-right folds
    // the reference's from-scratch sums perform.
    let mut wi = 0usize;
    for &k in order.iter() {
        if wi >= widest.len() {
            break;
        }
        let ch = widest[wi];
        wi += 1;
        assign[k].push(ch);
        rate[k] += link.subch_rate(k, ch, psd_nominal);
        power[k] += link.power_w(ch, psd_nominal);
    }

    // Phase 2: widest remaining subchannel to the current straggler,
    // respecting C4 (per-client) and C5 (per-link total) at the nominal
    // PSD, straggler search served by the lazy max-heap.
    for (k, &r) in rate.iter().enumerate() {
        heap.push(Entry {
            delay: stage_delay(k, r),
            k,
            epoch: 0,
        });
    }
    while wi < widest.len() {
        let ch = widest[wi];
        wi += 1;
        let add_power = link.power_w(ch, psd_nominal);
        // C5 is client-independent, so it is decided once per
        // subchannel. The total is re-summed grouped by client — the
        // reference scan's exact association — because the nominal PSD
        // fills the budget exactly once every subchannel is granted,
        // parking the final grants on the C5 float boundary.
        let total: f64 = fsum(power.iter().copied());
        let mut chosen: Option<usize> = None;
        if total + add_power <= p_th_w {
            while let Some(e) = heap.pop() {
                if e.epoch != epoch[e.k] {
                    continue; // stale: superseded by a later grant
                }
                if power[e.k] + add_power > p_max_w {
                    // C4 would break for THIS subchannel only: set the
                    // client aside and retry it on the next (narrower,
                    // cheaper) subchannel instead of latching it out.
                    deferred.push(e);
                    continue;
                }
                chosen = Some(e.k);
                break;
            }
        }
        // all clients capped: spread the rest round-robin; the exact
        // P2 solve will de-rate the PSDs anyway.
        let k = chosen.unwrap_or(ch % k_n);
        assign[k].push(ch);
        rate[k] += link.subch_rate(k, ch, psd_nominal);
        power[k] += add_power;
        epoch[k] += 1;
        heap.push(Entry {
            delay: stage_delay(k, rate[k]),
            k,
            epoch: epoch[k],
        });
        for e in deferred.drain(..) {
            heap.push(e);
        }
    }
    assign
}

/// One link's greedy pass, naive form — the executable spec the heap
/// engine is property-tested against (`rust/tests/prop_assignment.rs`).
/// `initial_priority` ranks clients for phase 1 (largest value served
/// first); `stage_delay` evaluates the phase-2 straggler metric for a
/// client given its current subchannel set.
fn greedy_link_reference<FP, FD>(
    link: &Link,
    k_n: usize,
    psd_nominal: f64,
    p_max_w: f64,
    p_th_w: f64,
    initial_priority: FP,
    stage_delay: FD,
) -> Vec<Vec<usize>>
where
    FP: Fn(usize) -> f64,
    FD: Fn(usize, &[usize]) -> f64,
{
    let mut assign: Vec<Vec<usize>> = vec![Vec::new(); k_n];
    let mut remaining = widest_first(link);
    remaining.reverse(); // pop() takes the widest

    // Phase 1: weakest client first, widest subchannel each.
    let mut order: Vec<usize> = (0..k_n).collect();
    // total_cmp == partial_cmp on the NaN-free priorities
    order.sort_by(|&a, &b| {
        initial_priority(b)
            .total_cmp(&initial_priority(a))
            .then(a.cmp(&b))
    });
    for &k in &order {
        if let Some(ch) = remaining.pop() {
            assign[k].push(ch);
        }
    }

    // Phase 2: widest remaining subchannel to the current straggler,
    // respecting C4/C5 at the nominal PSD. Eligibility is per
    // subchannel: a client the power caps bar from this subchannel is
    // skipped for this subchannel only.
    let client_power = |subs: &[usize]| -> f64 {
        subs.iter().map(|&i| link.power_w(i, psd_nominal)).sum()
    };
    while let Some(ch) = remaining.pop() {
        let add_power = link.power_w(ch, psd_nominal);
        let mut blocked: Vec<bool> = vec![false; k_n];
        loop {
            // straggler among the clients not blocked for this subchannel
            let mut best: Option<(usize, f64)> = None;
            for k in 0..k_n {
                if blocked[k] {
                    continue;
                }
                let d = stage_delay(k, &assign[k]);
                if best.map(|(_, bd)| d > bd).unwrap_or(true) {
                    best = Some((k, d));
                }
            }
            let Some((k, _)) = best else {
                // all clients capped: spread the rest round-robin; the
                // exact P2 solve will de-rate the PSDs anyway.
                let k = ch % k_n;
                assign[k].push(ch);
                break;
            };
            let total: f64 = assign.iter().map(|s| client_power(s)).sum();
            if client_power(&assign[k]) + add_power > p_max_w
                || total + add_power > p_th_w
            {
                blocked[k] = true; // C4/C5 would break: skip for this subchannel
                continue;
            }
            assign[k].push(ch);
            break;
        }
    }
    assign
}

/// The shared per-call setup of both Algorithm-2 engines: the nominal
/// PSDs, the phase-1 priorities, and every constant the straggler
/// metrics read. Factoring it out guarantees the heap engine and the
/// reference scan always solve the *same* problem — the only thing the
/// two entry points differ in is the greedy pass itself.
struct Algo2Setup {
    psd_main_nominal: f64,
    psd_fed_nominal: f64,
    /// `b · Γ_s(l_c)` — the batch's activation payload (main link).
    act_bits: f64,
    /// `ΔΘ_c(l_c, r)` — the adapter payload (fed link).
    adapter_bits: f64,
    /// `T_k^F` per client (the additive compute term of the main-link
    /// straggler metric).
    fwd_delay: Vec<f64>,
}

impl Algo2Setup {
    fn new(scn: &Scenario, l_c: usize, rank: usize) -> Algo2Setup {
        let b = scn.batch as f64;
        Algo2Setup {
            psd_main_nominal: scn.p_th_main_w / scn.main_link.subch.total_hz(),
            psd_fed_nominal: scn.p_th_fed_w / scn.fed_link.subch.total_hz(),
            act_bits: b * scn.profile.activation_bits(l_c),
            adapter_bits: scn.profile.client_adapter_bits(l_c, rank),
            fwd_delay: (0..scn.k())
                .map(|k| {
                    b * scn.kappa_client * scn.profile.client_fwd_flops(l_c, rank)
                        / scn.topo.clients[k].f_cycles
                })
                .collect(),
        }
    }

    /// Main-link straggler metric `T_k^F + T_k^s` from an accumulated
    /// rate.
    fn main_delay(&self, k: usize, rate: f64) -> f64 {
        if rate <= 0.0 {
            f64::INFINITY
        } else {
            self.fwd_delay[k] + self.act_bits / rate
        }
    }

    /// Fed-link straggler metric `T_k^f` from an accumulated rate.
    fn fed_delay(&self, rate: f64) -> f64 {
        if rate <= 0.0 {
            f64::INFINITY
        } else {
            self.adapter_bits / rate
        }
    }
}

/// Algorithm 2 over both links for the current (l_c, rank), on the
/// incremental heap engine with a private single-use scratch. Use
/// [`algorithm2_with`] to amortize the per-link sorts across repeated
/// calls.
pub fn algorithm2(scn: &Scenario, l_c: usize, rank: usize) -> AssignmentResult {
    algorithm2_with(scn, l_c, rank, &mut AssignScratch::new())
}

/// [`algorithm2`] with caller-provided reusable state: repeated calls
/// for the same scenario (every BCD iteration) reuse one widest-first
/// subchannel order and one phase-1 client order per link instead of
/// re-sorting both links per call.
pub fn algorithm2_with(
    scn: &Scenario,
    l_c: usize,
    rank: usize,
    scratch: &mut AssignScratch,
) -> AssignmentResult {
    let k_n = scn.k();
    let s = Algo2Setup::new(scn, l_c, rank);

    // ---- main link: straggler metric T_k^F + T_k^s ----------------------
    let main = {
        let link = &scn.main_link;
        // phase 1: weakest compute first (arg min f_k == arg max -f_k)
        scratch
            .main
            .prepare(link, k_n, |k| -scn.topo.clients[k].f_cycles);
        greedy_link_fast(
            link,
            k_n,
            s.psd_main_nominal,
            scn.p_max_w,
            scn.p_th_main_w,
            &mut scratch.main,
            |k, rate| s.main_delay(k, rate),
        )
    };

    // ---- fed link: straggler metric T_k^f --------------------------------
    let fed = {
        let link = &scn.fed_link;
        // phase 1: farthest client first (worst channel to fed server)
        scratch
            .fed
            .prepare(link, k_n, |k| scn.topo.clients[k].d_fed_m);
        greedy_link_fast(
            link,
            k_n,
            s.psd_fed_nominal,
            scn.p_max_w,
            scn.p_th_fed_w,
            &mut scratch.fed,
            |_, rate| s.fed_delay(rate),
        )
    };

    AssignmentResult {
        assign_main: main,
        assign_fed: fed,
        psd_main_nominal: s.psd_main_nominal,
        psd_fed_nominal: s.psd_fed_nominal,
    }
}

/// Algorithm 2 on the naive quadratic scan — the reference
/// implementation the heap engine must match **bit for bit** (same
/// grants, in the same per-client order). Kept callable (not
/// `#[cfg(test)]`) so `rust/tests/prop_assignment.rs` and the perf
/// harness (`benches/micro_hotpath.rs`, the `bench` CLI axis that
/// tracks the speedup) can both reach it; production paths must use
/// [`algorithm2`]. Both entry points draw the problem constants from
/// one [`Algo2Setup`], so they can only ever differ in the greedy pass
/// under test.
pub fn algorithm2_reference(scn: &Scenario, l_c: usize, rank: usize) -> AssignmentResult {
    let k_n = scn.k();
    let s = Algo2Setup::new(scn, l_c, rank);

    let main = {
        let link = &scn.main_link;
        greedy_link_reference(
            link,
            k_n,
            s.psd_main_nominal,
            scn.p_max_w,
            scn.p_th_main_w,
            |k| -scn.topo.clients[k].f_cycles,
            |k, subs| {
                let rate: f64 = subs
                    .iter()
                    .map(|&i| link.subch_rate(k, i, s.psd_main_nominal))
                    .sum();
                s.main_delay(k, rate)
            },
        )
    };

    let fed = {
        let link = &scn.fed_link;
        greedy_link_reference(
            link,
            k_n,
            s.psd_fed_nominal,
            scn.p_max_w,
            scn.p_th_fed_w,
            |k| scn.topo.clients[k].d_fed_m,
            |k, subs| {
                let rate: f64 = subs
                    .iter()
                    .map(|&i| link.subch_rate(k, i, s.psd_fed_nominal))
                    .sum();
                s.fed_delay(rate)
            },
        )
    };

    AssignmentResult {
        assign_main: main,
        assign_fed: fed,
        psd_main_nominal: s.psd_main_nominal,
        psd_fed_nominal: s.psd_fed_nominal,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{Gpt2Config, WorkloadProfile};
    use crate::net::topology::ClientSite;
    use crate::net::{ChannelModel, SubchannelSet, Topology};

    fn scenario(k: usize, m: usize, n: usize) -> Scenario {
        let topo = Topology {
            clients: (0..k)
                .map(|i| ClientSite {
                    d_main_m: 95.0 + 5.0 * i as f64,
                    d_fed_m: 5.0 + 3.0 * i as f64,
                    f_cycles: 1.0e9 + 0.15e9 * i as f64,
                })
                .collect(),
        };
        let ch = ChannelModel::new(0.0);
        let main_link = crate::net::Link {
            subch: SubchannelSet::equal_split(500e3, m),
            gain_product: 160.0,
            noise_psd: 3.98e-21,
            client_gain: topo.clients.iter().map(|c| ch.gain_deterministic(c.d_main_m)).collect(),
        };
        let fed_link = crate::net::Link {
            subch: SubchannelSet::equal_split(500e3, n),
            gain_product: 80.0,
            noise_psd: 3.98e-21,
            client_gain: topo.clients.iter().map(|c| ch.gain_deterministic(c.d_fed_m)).collect(),
        };
        Scenario {
            profile: WorkloadProfile::new(Gpt2Config::gpt2_s(), 512),
            topo,
            main_link,
            fed_link,
            dynamics: crate::config::DynamicsConfig::default(),
            objective: crate::config::ObjectiveConfig::default(),
            kappa_client: 1.0 / 1024.0,
            kappa_server: 1.0 / 32768.0,
            f_server: 5e9,
            batch: 16,
            local_steps: 12,
            p_max_w: 15.0,
            p_th_main_w: 50.0,
            p_th_fed_w: 50.0,
        }
    }

    #[test]
    fn every_subchannel_assigned_exactly_once() {
        let scn = scenario(5, 20, 20);
        let r = algorithm2(&scn, 2, 4);
        let mut alloc = crate::delay::Allocation {
            assign_main: r.assign_main,
            assign_fed: r.assign_fed,
            psd_main: vec![0.0; 20],
            psd_fed: vec![0.0; 20],
            l_c: 2,
            rank: 4,
        };
        alloc.psd_main.iter_mut().for_each(|p| *p = r.psd_main_nominal);
        alloc.psd_fed.iter_mut().for_each(|p| *p = r.psd_fed_nominal);
        alloc.validate(20, 20).unwrap();
    }

    #[test]
    fn every_client_gets_at_least_one_subchannel() {
        let scn = scenario(5, 20, 20);
        let r = algorithm2(&scn, 2, 4);
        for k in 0..5 {
            assert!(!r.assign_main[k].is_empty(), "client {k} main");
            assert!(!r.assign_fed[k].is_empty(), "client {k} fed");
        }
    }

    #[test]
    fn weakest_client_gets_more_main_subchannels() {
        // client 0 has the lowest f_k and the best main channel distance
        // tie goes to compute: the straggler should end up with >= the
        // fastest client's subchannel count.
        let scn = scenario(5, 20, 20);
        let r = algorithm2(&scn, 2, 4);
        assert!(
            r.assign_main[0].len() >= r.assign_main[4].len(),
            "straggler {} vs fastest {}",
            r.assign_main[0].len(),
            r.assign_main[4].len()
        );
    }

    #[test]
    fn more_clients_than_subchannels_is_handled() {
        let scn = scenario(6, 4, 4);
        let r = algorithm2(&scn, 2, 4);
        // only 4 subchannels: phase 1 serves the 4 weakest; no dupes
        let all: Vec<usize> = r.assign_main.iter().flatten().copied().collect();
        let mut sorted = all.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), all.len());
        assert_eq!(all.len(), 4);
    }

    #[test]
    fn balances_straggler_delay() {
        // with equal compute, the client with the worse channel should
        // get at least as many main subchannels
        let mut scn = scenario(2, 10, 10);
        scn.topo.clients[0].f_cycles = 1.2e9;
        scn.topo.clients[1].f_cycles = 1.2e9;
        scn.main_link.client_gain[1] /= 8.0; // much worse channel
        let r = algorithm2(&scn, 2, 4);
        assert!(r.assign_main[1].len() >= r.assign_main[0].len());
    }

    #[test]
    fn heap_engine_matches_reference_bit_for_bit() {
        for (k, m, n) in [(5, 20, 20), (6, 4, 4), (3, 17, 9), (2, 10, 10)] {
            let scn = scenario(k, m, n);
            for (l_c, r) in [(2, 4), (6, 1), (9, 8)] {
                let fast = algorithm2(&scn, l_c, r);
                let refr = algorithm2_reference(&scn, l_c, r);
                assert_eq!(fast.assign_main, refr.assign_main, "main K={k} M={m} l={l_c} r={r}");
                assert_eq!(fast.assign_fed, refr.assign_fed, "fed K={k} N={n} l={l_c} r={r}");
                assert_eq!(fast.psd_main_nominal.to_bits(), refr.psd_main_nominal.to_bits());
                assert_eq!(fast.psd_fed_nominal.to_bits(), refr.psd_fed_nominal.to_bits());
            }
        }
    }

    #[test]
    fn scratch_reuse_is_bit_identical_to_fresh_calls() {
        let scn = scenario(5, 20, 20);
        let mut scratch = AssignScratch::new();
        for (l_c, r) in [(2, 4), (6, 1), (2, 4), (9, 8)] {
            let with = algorithm2_with(&scn, l_c, r, &mut scratch);
            let fresh = algorithm2(&scn, l_c, r);
            assert_eq!(with.assign_main, fresh.assign_main, "l={l_c} r={r}");
            assert_eq!(with.assign_fed, fresh.assign_fed, "l={l_c} r={r}");
        }
    }
}
