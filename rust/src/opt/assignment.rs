//! P1 — greedy subchannel assignment (paper Algorithm 2).
//!
//! Phase 1 guarantees every client at least one subchannel on each
//! link, pairing the *weakest* client (lowest f_k on the main link,
//! farthest d_k^f on the fed link) with the *widest* remaining
//! subchannel. Phase 2 repeatedly gives the widest remaining subchannel
//! to the current straggler — the client with the largest
//! `T_k^F + T_k^s` (main link) or `T_k^f` (fed link) — skipping clients
//! whose power caps C4/C5 a further subchannel would violate at the
//! current PSD.
//!
//! During assignment the rates are evaluated at a *nominal* PSD (the
//! per-link total budget spread uniformly over the whole band); the
//! exact PSDs are re-optimized right after by [`super::power`], matching
//! the BCD ordering of Algorithm 3.

use crate::delay::Scenario;
use crate::net::Link;

/// Assignment produced by Algorithm 2 for both links.
#[derive(Clone, Debug)]
pub struct AssignmentResult {
    pub assign_main: Vec<Vec<usize>>,
    pub assign_fed: Vec<Vec<usize>>,
    /// Nominal PSDs used during the greedy evaluation (useful as a
    /// starting point before the exact P2 solve).
    pub psd_main_nominal: f64,
    pub psd_fed_nominal: f64,
}

/// Sort subchannel ids by bandwidth, widest first (ties by id for
/// determinism).
fn widest_first(link: &Link) -> Vec<usize> {
    let mut ids: Vec<usize> = (0..link.subch.len()).collect();
    ids.sort_by(|&a, &b| {
        link.subch.bandwidth_hz[b]
            .partial_cmp(&link.subch.bandwidth_hz[a])
            .unwrap()
            .then(a.cmp(&b))
    });
    ids
}

/// One link's greedy pass. `initial_priority` ranks clients for phase 1
/// (largest value served first); `stage_delay` evaluates the phase-2
/// straggler metric for a client given its current subchannel set.
fn greedy_link<FP, FD>(
    link: &Link,
    k_n: usize,
    psd_nominal: f64,
    p_max_w: f64,
    p_th_w: f64,
    initial_priority: FP,
    stage_delay: FD,
) -> Vec<Vec<usize>>
where
    FP: Fn(usize) -> f64,
    FD: Fn(usize, &[usize]) -> f64,
{
    let mut assign: Vec<Vec<usize>> = vec![Vec::new(); k_n];
    let mut remaining = widest_first(link);
    remaining.reverse(); // pop() takes the widest

    // Phase 1: weakest client first, widest subchannel each.
    let mut order: Vec<usize> = (0..k_n).collect();
    order.sort_by(|&a, &b| {
        initial_priority(b)
            .partial_cmp(&initial_priority(a))
            .unwrap()
            .then(a.cmp(&b))
    });
    for &k in &order {
        if let Some(ch) = remaining.pop() {
            assign[k].push(ch);
        }
    }

    // Phase 2: widest remaining subchannel to the current straggler,
    // respecting C4 (per-client) and C5 (per-link total) at the nominal PSD.
    let client_power = |subs: &[usize]| -> f64 {
        subs.iter().map(|&i| link.power_w(i, psd_nominal)).sum()
    };
    let mut eligible: Vec<bool> = vec![true; k_n];
    while let Some(ch) = remaining.pop() {
        let add_power = link.power_w(ch, psd_nominal);
        loop {
            // straggler among eligible clients
            let mut best: Option<(usize, f64)> = None;
            for k in 0..k_n {
                if !eligible[k] {
                    continue;
                }
                let d = stage_delay(k, &assign[k]);
                if best.map(|(_, bd)| d > bd).unwrap_or(true) {
                    best = Some((k, d));
                }
            }
            let Some((k, _)) = best else {
                // all clients capped: spread the rest round-robin; the
                // exact P2 solve will de-rate the PSDs anyway.
                let k = ch % k_n;
                assign[k].push(ch);
                break;
            };
            let total: f64 = assign.iter().map(|s| client_power(s)).sum();
            if client_power(&assign[k]) + add_power > p_max_w
                || total + add_power > p_th_w
            {
                eligible[k] = false; // C4/C5 would break: drop from A
                continue;
            }
            assign[k].push(ch);
            break;
        }
    }
    assign
}

/// Algorithm 2 over both links for the current (l_c, rank).
pub fn algorithm2(scn: &Scenario, l_c: usize, rank: usize) -> AssignmentResult {
    let k_n = scn.k();
    let b = scn.batch as f64;

    let psd_main_nominal = scn.p_th_main_w / scn.main_link.subch.total_hz();
    let psd_fed_nominal = scn.p_th_fed_w / scn.fed_link.subch.total_hz();

    // ---- main link: straggler metric T_k^F + T_k^s ----------------------
    let act_bits = b * scn.profile.activation_bits(l_c);
    let fwd_delay: Vec<f64> = (0..k_n)
        .map(|k| {
            b * scn.kappa_client * scn.profile.client_fwd_flops(l_c, rank)
                / scn.topo.clients[k].f_cycles
        })
        .collect();
    let main = {
        let link = &scn.main_link;
        greedy_link(
            link,
            k_n,
            psd_main_nominal,
            scn.p_max_w,
            scn.p_th_main_w,
            // phase 1: weakest compute first (arg min f_k == arg max -f_k)
            |k| -scn.topo.clients[k].f_cycles,
            |k, subs| {
                let rate: f64 = subs.iter().map(|&i| link.subch_rate(k, i, psd_main_nominal)).sum();
                if rate <= 0.0 {
                    f64::INFINITY
                } else {
                    fwd_delay[k] + act_bits / rate
                }
            },
        )
    };

    // ---- fed link: straggler metric T_k^f --------------------------------
    let adapter_bits = scn.profile.client_adapter_bits(l_c, rank);
    let fed = {
        let link = &scn.fed_link;
        greedy_link(
            link,
            k_n,
            psd_fed_nominal,
            scn.p_max_w,
            scn.p_th_fed_w,
            // phase 1: farthest client first (worst channel to fed server)
            |k| scn.topo.clients[k].d_fed_m,
            |k, subs| {
                let rate: f64 = subs.iter().map(|&i| link.subch_rate(k, i, psd_fed_nominal)).sum();
                if rate <= 0.0 {
                    f64::INFINITY
                } else {
                    adapter_bits / rate
                }
            },
        )
    };

    AssignmentResult {
        assign_main: main,
        assign_fed: fed,
        psd_main_nominal,
        psd_fed_nominal,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{Gpt2Config, WorkloadProfile};
    use crate::net::topology::ClientSite;
    use crate::net::{ChannelModel, SubchannelSet, Topology};

    fn scenario(k: usize, m: usize, n: usize) -> Scenario {
        let topo = Topology {
            clients: (0..k)
                .map(|i| ClientSite {
                    d_main_m: 95.0 + 5.0 * i as f64,
                    d_fed_m: 5.0 + 3.0 * i as f64,
                    f_cycles: 1.0e9 + 0.15e9 * i as f64,
                })
                .collect(),
        };
        let ch = ChannelModel::new(0.0);
        let main_link = crate::net::Link {
            subch: SubchannelSet::equal_split(500e3, m),
            gain_product: 160.0,
            noise_psd: 3.98e-21,
            client_gain: topo.clients.iter().map(|c| ch.gain_deterministic(c.d_main_m)).collect(),
        };
        let fed_link = crate::net::Link {
            subch: SubchannelSet::equal_split(500e3, n),
            gain_product: 80.0,
            noise_psd: 3.98e-21,
            client_gain: topo.clients.iter().map(|c| ch.gain_deterministic(c.d_fed_m)).collect(),
        };
        Scenario {
            profile: WorkloadProfile::new(Gpt2Config::gpt2_s(), 512),
            topo,
            main_link,
            fed_link,
            dynamics: crate::config::DynamicsConfig::default(),
            objective: crate::config::ObjectiveConfig::default(),
            kappa_client: 1.0 / 1024.0,
            kappa_server: 1.0 / 32768.0,
            f_server: 5e9,
            batch: 16,
            local_steps: 12,
            p_max_w: 15.0,
            p_th_main_w: 50.0,
            p_th_fed_w: 50.0,
        }
    }

    #[test]
    fn every_subchannel_assigned_exactly_once() {
        let scn = scenario(5, 20, 20);
        let r = algorithm2(&scn, 2, 4);
        let mut alloc = crate::delay::Allocation {
            assign_main: r.assign_main,
            assign_fed: r.assign_fed,
            psd_main: vec![0.0; 20],
            psd_fed: vec![0.0; 20],
            l_c: 2,
            rank: 4,
        };
        alloc.psd_main.iter_mut().for_each(|p| *p = r.psd_main_nominal);
        alloc.psd_fed.iter_mut().for_each(|p| *p = r.psd_fed_nominal);
        alloc.validate(20, 20).unwrap();
    }

    #[test]
    fn every_client_gets_at_least_one_subchannel() {
        let scn = scenario(5, 20, 20);
        let r = algorithm2(&scn, 2, 4);
        for k in 0..5 {
            assert!(!r.assign_main[k].is_empty(), "client {k} main");
            assert!(!r.assign_fed[k].is_empty(), "client {k} fed");
        }
    }

    #[test]
    fn weakest_client_gets_more_main_subchannels() {
        // client 0 has the lowest f_k and the best main channel distance
        // tie goes to compute: the straggler should end up with >= the
        // fastest client's subchannel count.
        let scn = scenario(5, 20, 20);
        let r = algorithm2(&scn, 2, 4);
        assert!(
            r.assign_main[0].len() >= r.assign_main[4].len(),
            "straggler {} vs fastest {}",
            r.assign_main[0].len(),
            r.assign_main[4].len()
        );
    }

    #[test]
    fn more_clients_than_subchannels_is_handled() {
        let scn = scenario(6, 4, 4);
        let r = algorithm2(&scn, 2, 4);
        // only 4 subchannels: phase 1 serves the 4 weakest; no dupes
        let all: Vec<usize> = r.assign_main.iter().flatten().copied().collect();
        let mut sorted = all.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), all.len());
        assert_eq!(all.len(), 4);
    }

    #[test]
    fn balances_straggler_delay() {
        // with equal compute, the client with the worse channel should
        // get at least as many main subchannels
        let mut scn = scenario(2, 10, 10);
        scn.topo.clients[0].f_cycles = 1.2e9;
        scn.topo.clients[1].f_cycles = 1.2e9;
        scn.main_link.client_gain[1] /= 8.0; // much worse channel
        let r = algorithm2(&scn, 2, 4);
        assert!(r.assign_main[1].len() >= r.assign_main[0].len());
    }
}
