//! Section VI: the joint resource-allocation optimizer.
//!
//! Problem P (Eq. 18) — minimize total training delay over subchannel
//! assignment r^s/r^f, transmit PSD p^s/p^f, split point μ, and LoRA
//! rank r — decomposed exactly as the paper does:
//!
//! * [`assignment`] — P1 via the greedy heuristic (Algorithm 2);
//! * [`power`] — P2, the convex power-control subproblem, solved
//!   *exactly* by bisection on the epigraph delay + per-client KKT
//!   water-filling (no external solver needed; see module docs);
//! * [`split`] / [`rank`] — standalone single-call P3 / P4 exhaustive
//!   scans (thin wrappers over the cached evaluator; the baselines use
//!   [`crate::delay::DelayEvaluator`] directly so repeat scans share
//!   one workload table);
//! * [`bcd`] — Algorithm 3: the alternating (block-coordinate-descent)
//!   loop, with P3+P4 run as one **joint** split×rank exhaustive scan
//!   on the cached [`crate::delay::DelayEvaluator`];
//! * `objective` (re-exported from [`crate::delay::objective`] since
//!   PR-9 — the scoring catalogue is consumed by the cached evaluator,
//!   which sits *below* the optimizer in the architecture contract) —
//!   the optimization-objective catalogue ([`Objective`]: delay,
//!   energy, λ-weighted sum, energy budget) every scoring path shares;
//! * [`baselines`] — baselines a–d from Section VII-C (the raw seeded
//!   draw functions);
//! * [`policy`] — the experiment-facing API: the [`AllocationPolicy`]
//!   trait over all of the above, plus the string-keyed
//!   [`PolicyRegistry`] (`proposed`, `baseline_a` … `baseline_d`) that
//!   the CLI, the figure benches, and [`crate::sim::SweepRunner`]
//!   select policies from.

pub mod assignment;
pub mod baselines;
pub mod bcd;
pub mod policy;
pub mod power;
pub mod rank;
pub mod split;

pub use crate::delay::objective;
pub use crate::delay::objective::Objective;
pub use bcd::{BcdOptions, BcdResult};
pub use policy::{solve_with_repair, AllocationPolicy, PolicyOutcome, PolicyRegistry};
