//! P3 — split-point selection by exhaustive search (paper Eq. 25).
//!
//! With assignment, PSDs and rank held fixed, evaluate the total delay
//! (Eq. 17) at every admissible split prefix and keep the argmin. The
//! candidate count equals the block count, so exhaustive search is
//! exact and cheap — precisely the paper's argument.
//!
//! Inside the BCD loop P3 no longer runs alone: [`crate::opt::bcd`]
//! scans split and rank *jointly* on a cached
//! [`crate::delay::DelayEvaluator`]. This standalone entry point is a
//! one-call convenience wrapper over that evaluator (single-rank
//! table); repeat-scan callers like baseline d use
//! [`crate::delay::DelayEvaluator::best_split`] directly on a shared
//! table instead.

use crate::delay::{Allocation, ConvergenceModel, DelayEvaluator, Scenario};

/// Returns (best l_c, its total delay). Ties resolve to the smaller
/// l_c (less client compute).
pub fn best_split(
    scn: &Scenario,
    alloc: &Allocation,
    conv: &ConvergenceModel,
) -> (usize, f64) {
    DelayEvaluator::build(scn, alloc, conv, &[alloc.rank]).best_split(alloc.rank)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delay::testutil::toy_scenario;

    fn base_alloc() -> Allocation {
        Allocation {
            assign_main: vec![vec![0, 1], vec![2, 3]],
            assign_fed: vec![vec![0], vec![1]],
            psd_main: vec![5e-5; 4],
            psd_fed: vec![5e-5; 2],
            l_c: 6,
            rank: 4,
        }
    }

    #[test]
    fn exhaustive_is_argmin() {
        let scn = toy_scenario();
        let conv = ConvergenceModel::paper_default();
        let alloc = base_alloc();
        let (l_star, t_star) = best_split(&scn, &alloc, &conv);
        for l_c in scn.profile.split_candidates() {
            let mut cand = alloc.clone();
            cand.l_c = l_c;
            assert!(scn.total_delay(&cand, &conv) >= t_star - 1e-12);
        }
        assert!(scn.profile.split_candidates().contains(&l_star));
    }

    #[test]
    fn never_worse_than_current() {
        let scn = toy_scenario();
        let conv = ConvergenceModel::paper_default();
        let alloc = base_alloc();
        let (_, t_star) = best_split(&scn, &alloc, &conv);
        assert!(t_star <= scn.total_delay(&alloc, &conv) + 1e-12);
    }

    #[test]
    fn slow_clients_push_split_to_server() {
        // make clients drastically slower: optimal split should shrink
        let mut scn = toy_scenario();
        let conv = ConvergenceModel::paper_default();
        let alloc = base_alloc();
        let (l_fast, _) = best_split(&scn, &alloc, &conv);
        for c in &mut scn.topo.clients {
            c.f_cycles /= 50.0;
        }
        let (l_slow, _) = best_split(&scn, &alloc, &conv);
        assert!(l_slow <= l_fast);
    }
}
