//! `sfllm-lint` — the offline static-analysis pass that machine-checks
//! the repo's bit-reproducibility contract.
//!
//! Every result this crate ships (Eq. 17 predictions, frozen-run
//! bit-identity, the incremental-vs-reference equivalences, the
//! cross-PR bench gate) rests on three informal disciplines: fixed
//! reduction orders, seeded counter-based RNG streams, and NaN-safe
//! total-order comparisons. This module makes those disciplines
//! CI-failing lint classes instead of code-review folklore.
//!
//! Since PR-9 the engine is structural, not just lexical: a
//! dependency-free tokenizer ([`lexer`]) feeds both the per-file rule
//! engine ([`rules`]) and an item-skeleton parser ([`parse`]) whose
//! output drives two whole-program passes — the module dependency
//! graph with its machine-checked layering contract ([`graph`]:
//! G001/G002, `ARCH.json`) and the name-resolution-lite call graph
//! behind the interprocedural taint rules ([`callgraph`]: P101/D104).
//!
//! Entry points: [`lint_source`] for one in-memory file (lexical rules
//! only), [`lint_sources`] for a whole in-memory program (what the
//! fixture self-tests in `rust/tests/lint_self.rs` drive),
//! [`lint_repo`] for the tree walk, and
//! `sfllm lint [--root <dir>] [--json <path>] [--arch-json <path>]
//! [--dot-out <path>] [--allow-unused]` on the CLI — exit status is
//! nonzero on any unsuppressed finding, and the JSON report
//! (`sfllm-lint-v2`) plus `ARCH.json` (`sfllm-arch-v1`) are what the
//! CI `lint` job archives.
//!
//! Suppressions are inline: `// lint:allow(<RULE>) <justification>`,
//! justification mandatory (≥ 10 chars). A valid suppression that
//! silences nothing is itself a finding (A002) unless
//! [`LintOptions::allow_unused`] is set — stale allows rot into
//! misinformation, so they fail the build by default.

pub mod callgraph;
pub mod graph;
pub mod lexer;
pub mod parse;
pub mod rules;

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

pub use graph::ArchReport;
pub use rules::{check_source, rule_ids, Finding, Suppression, RULES};

/// Directories scanned by [`lint_repo`], relative to the repo root.
pub const WALK_ROOTS: &[&str] = &["rust/src", "rust/benches", "rust/tests", "examples"];

/// One in-memory source file for [`lint_sources`]. `rel` is the
/// repo-relative path with forward slashes; it drives rule scoping and
/// module identity.
#[derive(Clone, Debug)]
pub struct SourceFile {
    pub rel: String,
    pub src: String,
}

/// Knobs for a lint run.
#[derive(Clone, Debug, Default)]
pub struct LintOptions {
    /// Suppress A002 (unused `lint:allow`) — an escape hatch for
    /// mid-refactor states where allows are expected to go stale.
    pub allow_unused: bool,
}

/// Full-repo lint result.
#[derive(Clone, Debug)]
pub struct LintReport {
    pub files_scanned: usize,
    pub findings: Vec<Finding>,
    pub suppressions: Vec<Suppression>,
    /// Module graph + layering verdicts (also serialized separately as
    /// `ARCH.json`). Its G001/G002 findings are merged into
    /// `findings` above (minus any suppressed ones).
    pub arch: ArchReport,
}

/// Lints one in-memory source file with the lexical rules; `rel`
/// (repo-relative, forward slashes) drives rule scoping. Alias of
/// [`rules::check_source`]. Program-level rules need the whole tree —
/// use [`lint_sources`].
pub fn lint_source(rel: &str, src: &str) -> (Vec<Finding>, Vec<Suppression>) {
    check_source(rel, src)
}

/// Lints a whole in-memory program: lexical rules per file, then the
/// structural passes (module graph, call graph) over every
/// `rust/src/` file, then suppression matching and the A002 sweep.
pub fn lint_sources(files: &[SourceFile], opts: &LintOptions) -> LintReport {
    let mut findings = Vec::new();
    let mut suppressions = Vec::new();
    let mut parsed = Vec::new();
    for f in files {
        let rel = f.rel.replace('\\', "/");
        let (fs, sups) = check_source(&rel, &f.src);
        findings.extend(fs);
        suppressions.extend(sups);
        if rel.starts_with("rust/src/") {
            parsed.push(parse::parse_file(&rel, &f.src));
        }
    }
    let arch = graph::build(&parsed);
    let mut program = arch.findings.clone();
    program.extend(callgraph::program_findings(&parsed));
    for f in program {
        let suppressed = suppressions.iter_mut().any(|s| {
            let hit = s.file == f.file
                && s.covers.contains(&f.line)
                && s.rules.iter().any(|r| r == f.rule);
            if hit {
                s.used = true;
            }
            hit
        });
        if !suppressed {
            findings.push(f);
        }
    }
    if !opts.allow_unused {
        for s in &suppressions {
            // Malformed allows are already A001; only well-formed
            // ones can be "unused".
            let malformed = s.rules.is_empty()
                || s.rules.iter().any(|r| !rule_ids().contains(&r.as_str()))
                || s.justification.chars().count() < 10;
            if malformed || s.used {
                continue;
            }
            findings.push(Finding {
                rule: "A002",
                file: s.file.clone(),
                line: s.line,
                snippet: format!("lint:allow({})", s.rules.join(",")),
                message: format!(
                    "suppression for {} silences nothing — delete it or fix the justification",
                    s.rules.join(",")
                ),
            });
        }
    }
    findings.sort_by(|a, b| {
        a.file
            .cmp(&b.file)
            .then(a.line.cmp(&b.line))
            .then(a.rule.cmp(b.rule))
    });
    LintReport {
        files_scanned: files.len(),
        findings,
        suppressions,
        arch,
    }
}

/// Deterministic (sorted) recursive walk, skipping `lint_fixtures`
/// directories — fixtures fire by design.
fn collect_files(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let mut entries: Vec<_> = std::fs::read_dir(dir)
        .with_context(|| format!("reading directory {}", dir.display()))?
        .collect::<std::io::Result<Vec<_>>>()
        .with_context(|| format!("listing {}", dir.display()))?;
    entries.sort_by_key(|e| e.file_name());
    for e in entries {
        let path = e.path();
        if path.is_dir() {
            if path.file_name() == Some(std::ffi::OsStr::new("lint_fixtures")) {
                continue;
            }
            collect_files(&path, out)?;
        } else if path.extension() == Some(std::ffi::OsStr::new("rs")) {
            out.push(path);
        }
    }
    Ok(())
}

/// Walks [`WALK_ROOTS`] under `root` and lints every `.rs` file,
/// lexical and structural rules both. Findings are sorted by
/// (file, line, rule); the walk itself is sorted, so the report — and
/// `ARCH.json` — is byte-stable across runs and machines.
pub fn lint_repo(root: &Path, opts: &LintOptions) -> Result<LintReport> {
    let mut files = Vec::new();
    for r in WALK_ROOTS {
        let base = root.join(r);
        if base.is_dir() {
            collect_files(&base, &mut files)?;
        }
    }
    if files.is_empty() {
        bail!("no Rust sources under {} (expected {:?})", root.display(), WALK_ROOTS);
    }
    let mut sources = Vec::with_capacity(files.len());
    for path in &files {
        let rel = path
            .strip_prefix(root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        let src = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        sources.push(SourceFile { rel, src });
    }
    Ok(lint_sources(&sources, opts))
}

/// Locates the repo root from the current directory: works from the
/// repo root itself (`rust/src` exists) or from `rust/` (CI runs with
/// `working-directory: rust`).
pub fn detect_root() -> Result<PathBuf> {
    let cwd = std::env::current_dir().context("reading current directory")?;
    if cwd.join("rust/src").is_dir() {
        return Ok(cwd);
    }
    if cwd.join("src").is_dir() {
        if let Some(parent) = cwd.parent() {
            if parent.join("rust/src").is_dir() {
                return Ok(parent.to_path_buf());
            }
        }
    }
    bail!("cannot locate the repo root; run from the repo root or rust/, or pass --root <dir>")
}

pub(crate) fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

impl LintReport {
    /// Machine-readable report (schema `sfllm-lint-v2`), the artifact
    /// the CI `lint` job uploads and gates on.
    pub fn to_json(&self) -> String {
        let findings: Vec<String> = self
            .findings
            .iter()
            .map(|f| {
                format!(
                    "    {{\"rule\": \"{}\", \"file\": \"{}\", \"line\": {}, \
                     \"snippet\": \"{}\", \"message\": \"{}\"}}",
                    f.rule,
                    json_escape(&f.file),
                    f.line,
                    json_escape(&f.snippet),
                    json_escape(&f.message)
                )
            })
            .collect();
        let sups: Vec<String> = self
            .suppressions
            .iter()
            .map(|s| {
                let rules: Vec<String> = s
                    .rules
                    .iter()
                    .map(|r| format!("\"{}\"", json_escape(r)))
                    .collect();
                format!(
                    "    {{\"rules\": [{}], \"file\": \"{}\", \"line\": {}, \
                     \"justification\": \"{}\", \"used\": {}}}",
                    rules.join(", "),
                    json_escape(&s.file),
                    s.line,
                    json_escape(&s.justification),
                    s.used
                )
            })
            .collect();
        format!(
            "{{\n  \"schema\": \"sfllm-lint-v2\",\n  \"files_scanned\": {},\n  \
             \"finding_count\": {},\n  \"suppression_count\": {},\n  \
             \"arch_fingerprint\": \"{}\",\n  \"findings\": [\n{}\n  ],\n  \
             \"suppressions\": [\n{}\n  ]\n}}\n",
            self.files_scanned,
            self.findings.len(),
            self.suppressions.len(),
            json_escape(&self.arch.fingerprint),
            findings.join(",\n"),
            sups.join(",\n")
        )
    }
}
