//! `sfllm-lint` — the offline static-analysis pass that machine-checks
//! the repo's bit-reproducibility contract.
//!
//! Every result this crate ships (Eq. 17 predictions, frozen-run
//! bit-identity, the incremental-vs-reference equivalences, the
//! cross-PR bench gate) rests on three informal disciplines: fixed
//! reduction orders, seeded counter-based RNG streams, and NaN-safe
//! total-order comparisons. This module makes those disciplines
//! CI-failing lint classes instead of code-review folklore: a
//! dependency-free tokenizer ([`lexer`]) walks `rust/src`,
//! `rust/benches`, `rust/tests`, and `examples/`, and a rule engine
//! ([`rules`]) matches the hazard patterns (rule table in
//! [`rules::RULES`]; rationale per rule in DESIGN.md "PR-7: the
//! determinism contract").
//!
//! Entry points: [`lint_source`] for one in-memory file (what the
//! fixture self-tests in `rust/tests/lint_self.rs` drive),
//! [`lint_repo`] for the tree walk, and `sfllm lint [--root <dir>]
//! [--json <path>]` on the CLI — exit status is nonzero on any
//! unsuppressed finding, and the JSON report (`sfllm-lint-v1`) is what
//! the CI `lint` job archives.
//!
//! Suppressions are inline: `// lint:allow(<RULE>) <justification>`,
//! justification mandatory (≥ 10 chars). Unused suppressions are
//! reported in the JSON (`"used": false`) but do not fail the run.

pub mod lexer;
pub mod rules;

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

pub use rules::{check_source, rule_ids, Finding, Suppression, RULES};

/// Directories scanned by [`lint_repo`], relative to the repo root.
pub const WALK_ROOTS: &[&str] = &["rust/src", "rust/benches", "rust/tests", "examples"];

/// Full-repo lint result.
#[derive(Clone, Debug)]
pub struct LintReport {
    pub files_scanned: usize,
    pub findings: Vec<Finding>,
    pub suppressions: Vec<Suppression>,
}

/// Lints one in-memory source file; `rel` (repo-relative, forward
/// slashes) drives rule scoping. Alias of [`rules::check_source`].
pub fn lint_source(rel: &str, src: &str) -> (Vec<Finding>, Vec<Suppression>) {
    check_source(rel, src)
}

/// Deterministic (sorted) recursive walk, skipping `lint_fixtures`
/// directories — fixtures fire by design.
fn collect_files(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let mut entries: Vec<_> = std::fs::read_dir(dir)
        .with_context(|| format!("reading directory {}", dir.display()))?
        .collect::<std::io::Result<Vec<_>>>()
        .with_context(|| format!("listing {}", dir.display()))?;
    entries.sort_by_key(|e| e.file_name());
    for e in entries {
        let path = e.path();
        if path.is_dir() {
            if path.file_name() == Some(std::ffi::OsStr::new("lint_fixtures")) {
                continue;
            }
            collect_files(&path, out)?;
        } else if path.extension() == Some(std::ffi::OsStr::new("rs")) {
            out.push(path);
        }
    }
    Ok(())
}

/// Walks [`WALK_ROOTS`] under `root` and lints every `.rs` file.
/// Findings are sorted by (file, line, rule); the walk itself is
/// sorted, so the report is byte-stable across runs and machines.
pub fn lint_repo(root: &Path) -> Result<LintReport> {
    let mut files = Vec::new();
    for r in WALK_ROOTS {
        let base = root.join(r);
        if base.is_dir() {
            collect_files(&base, &mut files)?;
        }
    }
    if files.is_empty() {
        bail!("no Rust sources under {} (expected {:?})", root.display(), WALK_ROOTS);
    }
    let mut findings = Vec::new();
    let mut suppressions = Vec::new();
    for path in &files {
        let rel = path
            .strip_prefix(root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        let src = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let (f, s) = check_source(&rel, &src);
        findings.extend(f);
        suppressions.extend(s);
    }
    findings.sort_by(|a, b| {
        a.file
            .cmp(&b.file)
            .then(a.line.cmp(&b.line))
            .then(a.rule.cmp(b.rule))
    });
    Ok(LintReport {
        files_scanned: files.len(),
        findings,
        suppressions,
    })
}

/// Locates the repo root from the current directory: works from the
/// repo root itself (`rust/src` exists) or from `rust/` (CI runs with
/// `working-directory: rust`).
pub fn detect_root() -> Result<PathBuf> {
    let cwd = std::env::current_dir().context("reading current directory")?;
    if cwd.join("rust/src").is_dir() {
        return Ok(cwd);
    }
    if cwd.join("src").is_dir() {
        if let Some(parent) = cwd.parent() {
            if parent.join("rust/src").is_dir() {
                return Ok(parent.to_path_buf());
            }
        }
    }
    bail!("cannot locate the repo root; run from the repo root or rust/, or pass --root <dir>")
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

impl LintReport {
    /// Machine-readable report (schema `sfllm-lint-v1`), the artifact
    /// the CI `lint` job uploads and gates on.
    pub fn to_json(&self) -> String {
        let findings: Vec<String> = self
            .findings
            .iter()
            .map(|f| {
                format!(
                    "    {{\"rule\": \"{}\", \"file\": \"{}\", \"line\": {}, \
                     \"snippet\": \"{}\", \"message\": \"{}\"}}",
                    f.rule,
                    json_escape(&f.file),
                    f.line,
                    json_escape(&f.snippet),
                    json_escape(f.message)
                )
            })
            .collect();
        let sups: Vec<String> = self
            .suppressions
            .iter()
            .map(|s| {
                let rules: Vec<String> = s
                    .rules
                    .iter()
                    .map(|r| format!("\"{}\"", json_escape(r)))
                    .collect();
                format!(
                    "    {{\"rules\": [{}], \"file\": \"{}\", \"line\": {}, \
                     \"justification\": \"{}\", \"used\": {}}}",
                    rules.join(", "),
                    json_escape(&s.file),
                    s.line,
                    json_escape(&s.justification),
                    s.used
                )
            })
            .collect();
        format!(
            "{{\n  \"schema\": \"sfllm-lint-v1\",\n  \"files_scanned\": {},\n  \
             \"finding_count\": {},\n  \"suppression_count\": {},\n  \"findings\": [\n{}\n  ],\n  \
             \"suppressions\": [\n{}\n  ]\n}}\n",
            self.files_scanned,
            self.findings.len(),
            self.suppressions.len(),
            findings.join(",\n"),
            sups.join(",\n")
        )
    }
}
