//! Rule engine for `sfllm-lint`: the determinism / numeric-safety /
//! panic-surface contract, checked over the token stream.
//!
//! Rule catalogue v2 (see DESIGN.md "PR-7: the determinism contract"
//! and "PR-9: the architecture contract" for the motivating bug behind
//! each ID). Lexical rules match the token stream of one file;
//! program rules run over the whole parsed tree (see
//! [`super::graph`] and [`super::callgraph`]) and are attached by
//! [`super::lint_sources`].
//!
//! | ID   | class       | level   | pattern |
//! |------|-------------|---------|---------|
//! | D001 | determinism | lexical | `HashMap`/`HashSet` in non-test library code |
//! | D002 | determinism | lexical | `Instant::now`/`SystemTime::now` outside `src/bench.rs` |
//! | D003 | determinism | lexical | `thread_rng`/`ThreadRng`/`from_entropy`/`OsRng`/`rand::random` anywhere |
//! | D005 | determinism | lexical | `env::var`/`env!`/`option_env!` outside `main.rs`, `bench.rs`, `runtime/` |
//! | D104 | determinism | program | `.sum()`/`.fold()` reachable from a thread-spawn site |
//! | N001 | numeric     | lexical | `partial_cmp(..).unwrap()`/`.expect()` on floats |
//! | N002 | numeric     | lexical | bare `partial_cmp`/`f64::max`/`f64::min` in `opt/`/`delay/`/`sim/` |
//! | P101 | panic       | program | unwrap/expect/literal index reachable from a hot-scope entry |
//! | G001 | structure   | program | module dependency cycle |
//! | G002 | structure   | program | architecture layering inversion |
//! | A001 | hygiene     | lexical | `lint:allow` without justification or with unknown rule id |
//! | A002 | hygiene     | program | `lint:allow` that silences nothing |
//!
//! The lexical hot-scope rules P001/P002 and the spawn-module rule
//! D004 are retired: P101 and D104 supersede them with whole-program
//! reachability (their IDs are no longer in the catalogue, so a stale
//! allow naming them fails as A001).
//!
//! Suppression: `// lint:allow(<ID>[,<ID>…]) <justification>` covers
//! findings on its own line; a comment alone on a line also covers the
//! next line that carries code. Justification text is mandatory (≥ 10
//! characters, enforced as A001). Only plain `//` comments can carry a
//! suppression — doc comments (`///`, `//!`) are ignored, so prose
//! like this paragraph can name the syntax safely. Since PR-9 a valid
//! suppression that silences nothing is itself a finding (A002),
//! escapable with `--allow-unused` during refactors.

use super::lexer::{lex, Comment, Tok, TokKind};

/// The rule catalogue: `(id, description)`.
pub const RULES: &[(&str, &str)] = &[
    ("D001", "order-nondeterministic hash container in library code"),
    ("D002", "wall-clock read outside the bench harness"),
    ("D003", "unseeded / entropy-based RNG"),
    ("D005", "environment read outside main.rs / bench.rs / runtime/"),
    ("D104", "iterator reduction reachable from a thread-spawn site"),
    ("N001", "partial_cmp().unwrap() on floats"),
    ("N002", "NaN-unsafe float ordering in scoring/argmin path"),
    ("P101", "panic site reachable from a solver/simulator entry point"),
    ("G001", "module dependency cycle"),
    ("G002", "architecture layering inversion"),
    ("A001", "lint:allow without justification or with unknown rule id"),
    ("A002", "lint:allow suppression that silences nothing"),
];

/// All rule IDs, in catalogue order.
pub fn rule_ids() -> Vec<&'static str> {
    RULES.iter().map(|(id, _)| *id).collect()
}

fn rule_message(rule: &str) -> &'static str {
    RULES
        .iter()
        .find(|(id, _)| *id == rule)
        .map(|(_, d)| *d)
        .unwrap_or("unknown rule")
}

/// One lint finding, pointing at a repo-relative `file:line`.
#[derive(Clone, Debug)]
pub struct Finding {
    pub rule: &'static str,
    pub file: String,
    pub line: u32,
    /// The matched token sequence, for the human report.
    pub snippet: String,
    /// The rule description; program rules embed the call chain or
    /// edge that produced the finding.
    pub message: String,
}

/// One `lint:allow` suppression comment.
#[derive(Clone, Debug)]
pub struct Suppression {
    pub file: String,
    pub line: u32,
    pub rules: Vec<String>,
    pub justification: String,
    /// Lines this suppression applies to (its own, plus the next code
    /// line when the comment stands alone).
    pub(crate) covers: Vec<u32>,
    /// Whether any finding was actually silenced by it.
    pub used: bool,
}

#[derive(Clone, Copy, PartialEq, Eq)]
enum FileClass {
    Src,
    Bench,
    TestDir,
    Example,
    Other,
}

fn classify(rel: &str) -> FileClass {
    if rel.starts_with("rust/src/") {
        FileClass::Src
    } else if rel.starts_with("rust/benches/") {
        FileClass::Bench
    } else if rel.starts_with("rust/tests/") {
        FileClass::TestDir
    } else if rel.starts_with("examples/") {
        FileClass::Example
    } else {
        FileClass::Other
    }
}

/// Marks every token inside a `#[cfg(test)]`-gated item or a `#[test]`
/// function (attribute through matching close brace), so rules scoped
/// to non-test code can skip them. Shared with [`super::parse`].
pub(crate) fn test_mask(toks: &[Tok]) -> Vec<bool> {
    let mut mask = vec![false; toks.len()];
    let mut i = 0usize;
    while i < toks.len() {
        let mut hit = false;
        if toks[i].text == "#" && i + 1 < toks.len() && toks[i + 1].text == "[" {
            let after = &toks[i + 2..];
            let rest: Vec<&str> = after.iter().take(5).map(|t| t.text.as_str()).collect();
            if rest.len() >= 5 && rest[..5] == ["cfg", "(", "test", ")", "]"] {
                hit = true;
            } else if rest.len() >= 2 && rest[..2] == ["test", "]"] {
                hit = true;
            }
        }
        if !hit {
            i += 1;
            continue;
        }
        let mut j = i;
        while j < toks.len() && toks[j].text != "{" {
            j += 1;
        }
        let mut depth = 0i64;
        while j < toks.len() {
            match toks[j].text.as_str() {
                "{" => depth += 1,
                "}" => {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                }
                _ => {}
            }
            j += 1;
        }
        let end = toks.len().min(j + 1);
        for m in mask.iter_mut().take(end).skip(i) {
            *m = true;
        }
        i = j + 1;
    }
    mask
}

/// Parses `lint:allow(<ids>) <justification>` out of one comment.
fn parse_allow(text: &str) -> Option<(Vec<String>, String)> {
    let pos = text.find("lint:allow")?;
    let rest = text[pos + "lint:allow".len()..].strip_prefix('(')?;
    let close = rest.find(')')?;
    let rules: Vec<String> = rest[..close]
        .split(',')
        .map(str::trim)
        .filter(|r| !r.is_empty())
        .map(str::to_string)
        .collect();
    let tail = rest[close + 1..].trim_start();
    let tail = tail.strip_prefix(':').unwrap_or(tail);
    Some((rules, tail.trim().to_string()))
}

fn collect_suppressions(
    rel: &str,
    src: &str,
    toks: &[Tok],
    comments: &[Comment],
) -> Vec<Suppression> {
    let lines: Vec<&str> = src.lines().collect();
    let mut tok_lines: Vec<u32> = toks.iter().map(|t| t.line).collect();
    tok_lines.sort_unstable();
    tok_lines.dedup();
    let mut out = Vec::new();
    for c in comments {
        // Doc comments can't carry suppressions — they *document* the
        // allow syntax without invoking it.
        if c.text.starts_with("///") || c.text.starts_with("//!") {
            continue;
        }
        let Some((rules, justification)) = parse_allow(&c.text) else {
            continue;
        };
        let mut covers = vec![c.line];
        let alone = lines
            .get(c.line as usize - 1)
            .is_some_and(|l| l.trim_start().starts_with("//"));
        if alone {
            if let Some(&next) = tok_lines.iter().find(|&&l| l > c.line) {
                covers.push(next);
            }
        }
        out.push(Suppression {
            file: rel.to_string(),
            line: c.line,
            rules,
            justification,
            covers,
            used: false,
        });
    }
    out
}

fn txt(toks: &[Tok], i: usize) -> &str {
    toks.get(i).map(|t| t.text.as_str()).unwrap_or("")
}

/// Lints one source file. `rel` is the repo-relative path (forward
/// slashes), which drives rule scoping; the file need not exist on
/// disk, so fixtures and tests can feed synthetic sources.
pub fn check_source(rel: &str, src: &str) -> (Vec<Finding>, Vec<Suppression>) {
    let rel_norm = rel.replace('\\', "/");
    let (toks, comments) = lex(src);
    let mask = test_mask(&toks);
    let mut sups = collect_suppressions(&rel_norm, src, &toks, &comments);
    let cls = classify(&rel_norm);
    let is_bench_mod = rel_norm == "rust/src/bench.rs";
    let hot = ["rust/src/opt/", "rust/src/delay/", "rust/src/sim/"]
        .iter()
        .any(|d| rel_norm.starts_with(d));
    // D005 scope: library code minus the sanctioned configuration
    // surfaces, plus integration tests (deliberately ignoring the
    // test mask — env-gated tests must carry a justified allow).
    let env_scoped = (cls == FileClass::Src
        && !is_bench_mod
        && rel_norm != "rust/src/main.rs"
        && !rel_norm.starts_with("rust/src/runtime/"))
        || cls == FileClass::TestDir;

    let mut raw: Vec<(&'static str, u32, String)> = Vec::new();
    for i in 0..toks.len() {
        let t = &toks[i];
        let lib_nontest = cls == FileClass::Src && !mask[i];
        if t.kind == TokKind::Ident {
            if (t.text == "HashMap" || t.text == "HashSet") && lib_nontest {
                raw.push(("D001", t.line, t.text.clone()));
            }
            if (t.text == "Instant" || t.text == "SystemTime")
                && txt(&toks, i + 1) == "::"
                && txt(&toks, i + 2) == "now"
                && lib_nontest
                && !is_bench_mod
            {
                raw.push(("D002", t.line, format!("{}::now", t.text)));
            }
            if cls != FileClass::Other {
                if matches!(
                    t.text.as_str(),
                    "thread_rng" | "ThreadRng" | "from_entropy" | "OsRng"
                ) {
                    raw.push(("D003", t.line, t.text.clone()));
                }
                if t.text == "rand" && txt(&toks, i + 1) == "::" && txt(&toks, i + 2) == "random" {
                    raw.push(("D003", t.line, "rand::random".to_string()));
                }
            }
            if env_scoped {
                if t.text == "env"
                    && txt(&toks, i + 1) == "::"
                    && matches!(txt(&toks, i + 2), "var" | "var_os" | "vars")
                {
                    raw.push(("D005", t.line, format!("env::{}", txt(&toks, i + 2))));
                }
                if (t.text == "env" || t.text == "option_env") && txt(&toks, i + 1) == "!" {
                    raw.push(("D005", t.line, format!("{}!", t.text)));
                }
            }
            if t.text == "partial_cmp" && (i == 0 || toks[i - 1].text != "fn") {
                let mut n001 = false;
                if txt(&toks, i + 1) == "(" {
                    let mut depth = 0i64;
                    let mut j = i + 1;
                    while j < toks.len() {
                        match toks[j].text.as_str() {
                            "(" => depth += 1,
                            ")" => {
                                depth -= 1;
                                if depth == 0 {
                                    break;
                                }
                            }
                            _ => {}
                        }
                        j += 1;
                    }
                    if txt(&toks, j + 1) == "."
                        && matches!(txt(&toks, j + 2), "unwrap" | "expect")
                    {
                        n001 = true;
                    }
                }
                if n001 && (lib_nontest || matches!(cls, FileClass::Bench | FileClass::Example)) {
                    raw.push(("N001", t.line, "partial_cmp().unwrap()".to_string()));
                } else if hot && lib_nontest {
                    raw.push(("N002", t.line, "partial_cmp".to_string()));
                }
            }
            if (t.text == "f64" || t.text == "f32")
                && txt(&toks, i + 1) == "::"
                && matches!(txt(&toks, i + 2), "max" | "min")
                && hot
                && lib_nontest
            {
                raw.push(("N002", t.line, format!("{}::{}", t.text, txt(&toks, i + 2))));
            }
        }
    }

    let mut findings = Vec::new();
    for (rule, line, snippet) in raw {
        let suppressed = sups.iter_mut().any(|s| {
            let hit = s.covers.contains(&line) && s.rules.iter().any(|r| r == rule);
            if hit {
                s.used = true;
            }
            hit
        });
        if !suppressed {
            findings.push(Finding {
                rule,
                file: rel_norm.clone(),
                line,
                snippet,
                message: rule_message(rule).to_string(),
            });
        }
    }
    for s in &sups {
        let unknown = s.rules.iter().any(|r| !rule_ids().contains(&r.as_str()));
        if s.rules.is_empty() || unknown || s.justification.chars().count() < 10 {
            findings.push(Finding {
                rule: "A001",
                file: rel_norm.clone(),
                line: s.line,
                snippet: format!("lint:allow({})", s.rules.join(",")),
                message: rule_message("A001").to_string(),
            });
        }
    }
    findings.sort_by(|a, b| {
        a.file
            .cmp(&b.file)
            .then(a.line.cmp(&b.line))
            .then(a.rule.cmp(b.rule))
    });
    (findings, sups)
}
