//! Module dependency graph and the machine-checked layering contract.
//!
//! The crate has a deliberate architecture: leaf utilities at the
//! bottom, the delay model above them, optimizers above that, then the
//! simulation harness, and the long-running surfaces (service,
//! coordinator) on top. PR-9 turns that prose into a contract: every
//! non-test `crate::X` reference is an edge in a module graph, each
//! module has a layer, and the allowed-edge table below is the single
//! source of truth. Violations are lint findings:
//!
//! - **G001** — a dependency cycle between modules (any strongly
//!   connected component with more than one module).
//! - **G002** — a layering inversion: an edge not in the allowed table
//!   (including edges to unknown modules).
//!
//! The allowed table is strictly layer-decreasing (unit-tested), so a
//! clean graph is a DAG by construction and G001 can only fire when
//! G002 also fires — but the cycle report names the loop explicitly,
//! which the inversion report cannot.
//!
//! [`ArchReport::to_json`] is byte-stable: modules sorted by
//! (layer, name), edges by (from, to), and a FNV-1a fingerprint of the
//! contract tables so CI can detect silent contract edits.

use super::parse::ParsedFile;
use super::rules::Finding;
use std::collections::{BTreeMap, BTreeSet};

/// Layer assignment for every first-party module. Lower layers may not
/// depend on higher ones. `analysis` and `runtime` are leaves by
/// design (nothing in the simulator may depend on the linter or the
/// runtime shim); `lib` is pure re-export glue.
pub const LAYERS: &[(&str, u8)] = &[
    ("util", 0),
    ("analysis", 1),
    ("config", 1),
    ("data", 1),
    ("model", 1),
    ("net", 1),
    ("delay", 2),
    ("runtime", 2),
    ("opt", 3),
    ("sim", 4),
    ("coordinator", 5),
    ("service", 5),
    ("bench", 6),
    ("lib", 6),
    ("main", 6),
];

/// The allowed-edge table: `(module, modules it may reference)`.
/// Every entry is strictly layer-decreasing — see
/// `contract_is_strictly_layer_decreasing`.
pub const ALLOWED: &[(&str, &[&str])] = &[
    ("util", &[]),
    ("analysis", &["util"]),
    ("config", &["util"]),
    ("data", &["util"]),
    ("model", &["util"]),
    ("net", &["util"]),
    ("delay", &["config", "model", "net", "util"]),
    ("runtime", &["model", "util"]),
    ("opt", &["config", "delay", "model", "net", "util"]),
    ("sim", &["config", "delay", "model", "net", "opt", "util"]),
    ("coordinator", &["data", "model", "runtime", "util"]),
    ("service", &["config", "delay", "model", "net", "opt", "sim", "util"]),
    ("bench", &["analysis", "delay", "opt", "service", "sim", "util"]),
    ("lib", &[]),
    (
        "main",
        &[
            "analysis", "bench", "config", "coordinator", "data", "delay", "model", "net", "opt",
            "runtime", "service", "sim", "util",
        ],
    ),
];

/// Layer of `module`, or `u8::MAX` when unknown to the contract.
pub fn layer_of(module: &str) -> u8 {
    LAYERS
        .iter()
        .find(|(m, _)| *m == module)
        .map(|(_, l)| *l)
        .unwrap_or(u8::MAX)
}

fn allowed_deps(module: &str) -> &'static [&'static str] {
    ALLOWED
        .iter()
        .find(|(m, _)| *m == module)
        .map(|(_, d)| *d)
        .unwrap_or(&[])
}

/// FNV-1a 64 over the canonical contract dump, so ARCH.json carries a
/// fingerprint that changes iff the layer/allowed tables change.
pub fn layer_fingerprint() -> String {
    let mut dump = String::new();
    for (m, l) in LAYERS {
        dump.push_str(m);
        dump.push('=');
        dump.push_str(&l.to_string());
        dump.push(':');
        dump.push_str(&allowed_deps(m).join(","));
        dump.push(';');
    }
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in dump.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    format!("{h:016x}")
}

/// One module as seen in the scanned tree.
#[derive(Clone, Debug)]
pub struct ModuleInfo {
    pub name: String,
    pub layer: u8,
    pub files: usize,
}

/// One aggregated dependency edge (`from` references `to` in non-test
/// code). `file`/`line` anchor the first reference seen.
#[derive(Clone, Debug)]
pub struct EdgeInfo {
    pub from: String,
    pub to: String,
    pub refs: usize,
    pub allowed: bool,
    pub file: String,
    pub line: u32,
}

/// The architecture report: graph + contract verdicts. Serialized to
/// `ARCH.json` (schema `sfllm-arch-v1`) and graphviz.
#[derive(Clone, Debug)]
pub struct ArchReport {
    pub modules: Vec<ModuleInfo>,
    pub edges: Vec<EdgeInfo>,
    pub fingerprint: String,
    pub findings: Vec<Finding>,
}

/// Builds the module graph from parsed `rust/src` files and checks the
/// contract. Files outside `rust/src/` are ignored (tests, benches,
/// and examples may cross layers freely).
pub fn build(files: &[ParsedFile]) -> ArchReport {
    let mut mod_files: BTreeMap<&str, usize> = BTreeMap::new();
    let mut edges: BTreeMap<(String, String), EdgeInfo> = BTreeMap::new();
    for f in files {
        if !f.rel.starts_with("rust/src/") {
            continue;
        }
        *mod_files.entry(f.module.as_str()).or_insert(0) += 1;
        for (to, line) in &f.crate_refs {
            if *to == f.module {
                continue;
            }
            let key = (f.module.clone(), to.clone());
            let e = edges.entry(key).or_insert_with(|| EdgeInfo {
                from: f.module.clone(),
                to: to.clone(),
                refs: 0,
                allowed: allowed_deps(&f.module).contains(&to.as_str()),
                file: f.rel.clone(),
                line: *line,
            });
            e.refs += 1;
            if (f.rel.as_str(), *line) < (e.file.as_str(), e.line) {
                e.file = f.rel.clone();
                e.line = *line;
            }
        }
    }

    let mut findings = Vec::new();
    for e in edges.values() {
        if e.allowed {
            continue;
        }
        let (lf, lt) = (layer_of(&e.from), layer_of(&e.to));
        let allowed = allowed_deps(&e.from);
        let allowed_txt = if allowed.is_empty() { "none".to_string() } else { allowed.join(", ") };
        findings.push(Finding {
            rule: "G002",
            file: e.file.clone(),
            line: e.line,
            message: format!(
                "layering inversion: module `{}` (layer {}) may not depend on `{}` (layer {}); allowed deps: {}",
                e.from,
                lf,
                e.to,
                if lt == u8::MAX { "?".to_string() } else { lt.to_string() },
                allowed_txt
            ),
            snippet: format!("{} -> {}", e.from, e.to),
        });
    }

    findings.extend(cycle_findings(&edges));
    findings.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule))
    });

    let mut modules: Vec<ModuleInfo> = mod_files
        .iter()
        .map(|(name, files)| ModuleInfo {
            name: name.to_string(),
            layer: layer_of(name),
            files: *files,
        })
        .collect();
    modules.sort_by(|a, b| (a.layer, a.name.as_str()).cmp(&(b.layer, b.name.as_str())));

    let edges: Vec<EdgeInfo> = edges.into_values().collect();
    ArchReport { modules, edges, fingerprint: layer_fingerprint(), findings }
}

/// One G001 finding per strongly connected component of size > 1,
/// anchored at the smallest (file, line) among the component's edges.
fn cycle_findings(edges: &BTreeMap<(String, String), EdgeInfo>) -> Vec<Finding> {
    let nodes: BTreeSet<&str> = edges
        .keys()
        .flat_map(|(a, b)| [a.as_str(), b.as_str()])
        .collect();
    let nodes: Vec<&str> = nodes.into_iter().collect();
    let idx: BTreeMap<&str, usize> = nodes.iter().enumerate().map(|(i, n)| (*n, i)).collect();
    let n = nodes.len();
    // tiny graph: transitive closure by iterated relaxation
    let mut reach = vec![vec![false; n]; n];
    for (a, b) in edges.keys() {
        reach[idx[a.as_str()]][idx[b.as_str()]] = true;
    }
    loop {
        let mut changed = false;
        for i in 0..n {
            for j in 0..n {
                if !reach[i][j] {
                    continue;
                }
                for k in 0..n {
                    if reach[j][k] && !reach[i][k] {
                        reach[i][k] = true;
                        changed = true;
                    }
                }
            }
        }
        if !changed {
            break;
        }
    }
    let mut seen = vec![false; n];
    let mut out = Vec::new();
    for i in 0..n {
        if seen[i] {
            continue;
        }
        let mut comp = vec![i];
        for j in (i + 1)..n {
            if reach[i][j] && reach[j][i] {
                comp.push(j);
                seen[j] = true;
            }
        }
        if comp.len() < 2 {
            continue;
        }
        let names: Vec<&str> = comp.iter().map(|&c| nodes[c]).collect();
        let member = |m: &str| names.contains(&m);
        let mut anchor: Option<(&str, u32)> = None;
        for e in edges.values() {
            if member(&e.from) && member(&e.to) {
                let cand = (e.file.as_str(), e.line);
                if anchor.is_none() || cand < anchor.unwrap() {
                    anchor = Some(cand);
                }
            }
        }
        let (file, line) = anchor.unwrap_or(("", 0));
        out.push(Finding {
            rule: "G001",
            file: file.to_string(),
            line,
            message: format!("module dependency cycle: {}", names.join(" -> ")),
            snippet: names.join(" <-> "),
        });
    }
    out
}

fn esc(s: &str) -> String {
    super::json_escape(s)
}

impl ArchReport {
    /// Count of findings with the given rule id.
    pub fn count(&self, rule: &str) -> usize {
        self.findings.iter().filter(|f| f.rule == rule).count()
    }

    /// Byte-stable JSON: fixed key order, sorted collections, no
    /// floats, no timestamps. Two runs over the same tree must produce
    /// identical bytes (asserted in `rust/tests/lint_self.rs` and CI).
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        s.push_str("{\n  \"schema\": \"sfllm-arch-v1\",\n");
        s.push_str(&format!("  \"fingerprint\": \"{}\",\n", esc(&self.fingerprint)));
        s.push_str(&format!("  \"g001\": {},\n", self.count("G001")));
        s.push_str(&format!("  \"g002\": {},\n", self.count("G002")));
        s.push_str("  \"modules\": [\n");
        for (i, m) in self.modules.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"name\": \"{}\", \"layer\": {}, \"files\": {}}}{}\n",
                esc(&m.name),
                m.layer,
                m.files,
                if i + 1 < self.modules.len() { "," } else { "" }
            ));
        }
        s.push_str("  ],\n  \"edges\": [\n");
        for (i, e) in self.edges.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"from\": \"{}\", \"to\": \"{}\", \"refs\": {}, \"allowed\": {}, \"file\": \"{}\", \"line\": {}}}{}\n",
                esc(&e.from),
                esc(&e.to),
                e.refs,
                e.allowed,
                esc(&e.file),
                e.line,
                if i + 1 < self.edges.len() { "," } else { "" }
            ));
        }
        s.push_str("  ]\n}\n");
        s
    }

    /// Graphviz dot: one rank per layer, disallowed edges red/bold.
    pub fn to_dot(&self) -> String {
        let mut s = String::new();
        s.push_str("digraph arch {\n  rankdir=BT;\n  node [shape=box, fontname=\"monospace\"];\n");
        let mut by_layer: BTreeMap<u8, Vec<&str>> = BTreeMap::new();
        for m in &self.modules {
            by_layer.entry(m.layer).or_default().push(&m.name);
        }
        for (layer, mods) in &by_layer {
            s.push_str(&format!("  {{ rank=same; /* layer {layer} */"));
            for m in mods {
                s.push_str(&format!(" \"{}\";", esc(m)));
            }
            s.push_str(" }\n");
        }
        for e in &self.edges {
            if e.allowed {
                s.push_str(&format!("  \"{}\" -> \"{}\";\n", esc(&e.from), esc(&e.to)));
            } else {
                s.push_str(&format!(
                    "  \"{}\" -> \"{}\" [color=red, penwidth=2.0, label=\"G002\"];\n",
                    esc(&e.from),
                    esc(&e.to)
                ));
            }
        }
        s.push_str("}\n");
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::parse::parse_file;

    #[test]
    fn contract_tables_cover_the_same_modules() {
        let layered: Vec<&str> = LAYERS.iter().map(|(m, _)| *m).collect();
        let allowed: Vec<&str> = ALLOWED.iter().map(|(m, _)| *m).collect();
        assert_eq!(layered, allowed);
    }

    #[test]
    fn contract_is_strictly_layer_decreasing() {
        for (m, deps) in ALLOWED {
            let lm = layer_of(m);
            assert!(lm != u8::MAX, "module {m} missing from LAYERS");
            for d in *deps {
                let ld = layer_of(d);
                assert!(
                    ld < lm,
                    "allowed edge {m} (layer {lm}) -> {d} (layer {ld}) is not strictly decreasing"
                );
            }
        }
    }

    #[test]
    fn fingerprint_is_stable_hex() {
        let a = layer_fingerprint();
        let b = layer_fingerprint();
        assert_eq!(a, b);
        assert_eq!(a.len(), 16);
        assert!(a.bytes().all(|c| c.is_ascii_hexdigit()));
    }

    #[test]
    fn inversion_yields_g002() {
        let files = vec![
            parse_file("rust/src/util/bad.rs", "pub fn f() { crate::opt::run(); }"),
            parse_file("rust/src/opt/ok.rs", "pub fn run() { crate::util::bad::f(); }"),
        ];
        let rep = build(&files);
        assert_eq!(rep.count("G002"), 1, "{:?}", rep.findings);
        // util -> opt -> util is also a cycle
        assert_eq!(rep.count("G001"), 1, "{:?}", rep.findings);
        let g2 = rep.findings.iter().find(|f| f.rule == "G002").unwrap();
        assert_eq!(g2.snippet, "util -> opt");
        assert_eq!(g2.file, "rust/src/util/bad.rs");
    }

    #[test]
    fn allowed_edges_are_clean_and_json_is_byte_stable() {
        let files = vec![
            parse_file("rust/src/opt/a.rs", "pub fn f() { crate::delay::eval(); }"),
            parse_file("rust/src/delay/b.rs", "pub fn g() -> f64 { crate::net::rate() }"),
        ];
        let rep = build(&files);
        assert!(rep.findings.is_empty(), "{:?}", rep.findings);
        let rep2 = build(&files);
        assert_eq!(rep.to_json(), rep2.to_json());
        assert!(rep.to_json().contains("\"schema\": \"sfllm-arch-v1\""));
        assert!(rep.to_dot().starts_with("digraph arch {"));
    }

    #[test]
    fn test_only_refs_do_not_create_edges() {
        let src = "#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() { crate::service::spin(); }\n}\n";
        let files = vec![parse_file("rust/src/util/t.rs", src)];
        let rep = build(&files);
        assert!(rep.edges.is_empty());
        assert!(rep.findings.is_empty());
    }
}
