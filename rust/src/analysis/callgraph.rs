//! Name-resolution-lite call graph and the interprocedural taint rules.
//!
//! Built on [`super::parse`]: every function in `rust/src` is a node
//! keyed by its module path (`opt::bcd::run`,
//! `delay::eval::DelayEvaluator::evaluate`), and call references
//! resolve to nodes by a deliberately simple scheme:
//!
//! - **Qualified paths** (`crate::opt::power::solve_power(..)`,
//!   `bcd::initial_alloc(..)`, `Objective::from_config(..)`) normalize
//!   `crate`/`self`/`super` and file-local `use` aliases, then match
//!   keys exactly, then by progressively shorter path suffixes (at
//!   least two segments) — so re-exported spellings land on the real
//!   definition.
//! - **Unqualified calls** (`helper(..)`) match same-file free
//!   functions, then imported names.
//! - **Method calls** (`x.solve(..)`) match `impl`/`trait` members
//!   with that name, but only when the caller's file is the defining
//!   file or mentions the implementing type / trait name — this is
//!   what keeps `.expect(..)` on an `Option` a panic site everywhere
//!   except inside the one file that defines a `fn expect`.
//!
//! The approximations and their false-negative bounds are documented
//! in `DESIGN.md` (PR-9 section). On top of the graph:
//!
//! - **P101** — `.unwrap()` / `.expect()` / literal indexing in any
//!   function reachable from a hot-scope entry point (public non-test
//!   fns of `opt`, `delay`, `sim`). The finding carries the full call
//!   chain from the entry point, which the file-local lexical rules it
//!   replaces (P001/P002) could never see.
//! - **D104** — `.sum()` / `.fold(..)` reductions in any function
//!   reachable from a `spawn` site: accumulation order must not depend
//!   on thread interleaving, so reachable reductions are required to
//!   go through the fixed-order helpers in `util::stats` or carry a
//!   justified allow.

use super::parse::{FnInfo, ParsedFile, SiteKind};
use super::rules::Finding;
use std::collections::{BTreeMap, BTreeSet, VecDeque};

/// Hot modules: taint roots for P101 are the public non-test functions
/// declared under these top-level modules.
pub const HOT_MODULES: &[&str] = &["delay", "opt", "sim"];

/// The whole-program call graph over `rust/src` functions.
pub struct CallGraph {
    pub fns: Vec<FnInfo>,
    by_name: BTreeMap<String, Vec<usize>>,
    file_idents: BTreeMap<String, BTreeSet<String>>,
    file_imports: BTreeMap<String, BTreeMap<String, String>>,
    /// `edges[i]` = indices of functions `fns[i]` calls (sorted, deduped).
    pub edges: Vec<Vec<usize>>,
}

fn parent_path(mod_path: &str, supers: usize) -> Vec<String> {
    let mut segs: Vec<String> = mod_path.split("::").map(|s| s.to_string()).collect();
    for _ in 0..supers {
        segs.pop();
    }
    segs
}

impl CallGraph {
    /// Builds the graph. Only functions from files under `rust/src/`
    /// participate; the input order does not matter (nodes are sorted
    /// by key for determinism).
    pub fn build(files: &[ParsedFile]) -> CallGraph {
        let mut fns: Vec<FnInfo> = Vec::new();
        let mut file_idents = BTreeMap::new();
        let mut file_imports: BTreeMap<String, BTreeMap<String, String>> = BTreeMap::new();
        for f in files {
            if !f.rel.starts_with("rust/src/") {
                continue;
            }
            fns.extend(f.fns.iter().cloned());
            file_idents.insert(f.rel.clone(), f.idents.clone());
            let imports = file_imports.entry(f.rel.clone()).or_default();
            for u in &f.uses {
                if u.alias == "*" || u.path.is_empty() {
                    continue; // glob imports are ignored (documented approximation)
                }
                let head = u.path.first().map(|s| s.as_str()).unwrap_or("");
                let resolved: Vec<String> = match head {
                    "crate" | "sfllm" => u.path.iter().skip(1).cloned().collect(),
                    "self" => {
                        let mut v = parent_path(&f.mod_path, 0);
                        v.extend(u.path.iter().skip(1).cloned());
                        v
                    }
                    "super" => {
                        let supers = u.path.iter().take_while(|s| s.as_str() == "super").count();
                        let mut v = parent_path(&f.mod_path, supers);
                        v.extend(u.path.iter().skip(supers).cloned());
                        v
                    }
                    _ => continue, // external crate / std — not ours
                };
                imports.insert(u.alias.clone(), resolved.join("::"));
            }
        }
        fns.sort_by(|a, b| {
            (a.key.as_str(), a.file.as_str(), a.line)
                .cmp(&(b.key.as_str(), b.file.as_str(), b.line))
        });
        let mut by_name: BTreeMap<String, Vec<usize>> = BTreeMap::new();
        for (i, f) in fns.iter().enumerate() {
            by_name.entry(f.name.clone()).or_default().push(i);
        }
        let mut cg = CallGraph { fns, by_name, file_idents, file_imports, edges: Vec::new() };
        let mut edges = Vec::with_capacity(cg.fns.len());
        for i in 0..cg.fns.len() {
            let mut targets = BTreeSet::new();
            let caller = cg.fns[i].clone();
            for call in &caller.calls {
                let resolved = if call.method {
                    cg.resolve_method(i, &call.name)
                } else if call.qual.len() == 1 && call.qual[0] == "Self" {
                    cg.resolve_self_assoc(i, &call.name)
                } else {
                    cg.resolve_path(&caller, &call.qual, &call.name)
                };
                targets.extend(resolved);
            }
            edges.push(targets.into_iter().collect());
        }
        cg.edges = edges;
        cg
    }

    /// In-repo targets of a `.name(..)` method call from `fns[caller]`:
    /// impl/trait members with that name whose defining file is the
    /// caller's file, or whose type / trait name appears in the
    /// caller's file. Empty means "std or external" — for
    /// unwrap/expect/sum/fold that is exactly the taint case.
    pub fn resolve_method(&self, caller: usize, name: &str) -> Vec<usize> {
        let cf = &self.fns[caller];
        let empty = BTreeSet::new();
        let idents = self.file_idents.get(&cf.file).unwrap_or(&empty);
        self.by_name
            .get(name)
            .map(|cands| {
                cands
                    .iter()
                    .copied()
                    .filter(|&j| {
                        let f = &self.fns[j];
                        f.is_method
                            && !f.is_test
                            && (f.file == cf.file
                                || (!f.impl_type.is_empty() && idents.contains(&f.impl_type))
                                || (!f.impl_trait.is_empty() && idents.contains(&f.impl_trait)))
                    })
                    .collect()
            })
            .unwrap_or_default()
    }

    /// `Self::name(..)` — associated functions of the caller's own impl.
    fn resolve_self_assoc(&self, caller: usize, name: &str) -> Vec<usize> {
        let cf = &self.fns[caller];
        if cf.impl_type.is_empty() {
            return Vec::new();
        }
        self.by_name
            .get(name)
            .map(|cands| {
                cands
                    .iter()
                    .copied()
                    .filter(|&j| {
                        let f = &self.fns[j];
                        !f.is_test && f.file == cf.file && f.impl_type == cf.impl_type
                    })
                    .collect()
            })
            .unwrap_or_default()
    }

    /// Qualified or free-path call resolution (see module docs).
    fn resolve_path(&self, caller: &FnInfo, qual: &[String], name: &str) -> Vec<usize> {
        let imports = self.file_imports.get(&caller.file);
        if qual.is_empty() {
            // same-file free functions first
            let same_file: Vec<usize> = self
                .by_name
                .get(name)
                .map(|c| {
                    c.iter()
                        .copied()
                        .filter(|&j| {
                            let f = &self.fns[j];
                            !f.is_method && !f.is_test && f.file == caller.file
                        })
                        .collect()
                })
                .unwrap_or_default();
            if !same_file.is_empty() {
                return same_file;
            }
            if let Some(path) = imports.and_then(|m| m.get(name)) {
                return self.match_abs(&path.split("::").map(|s| s.to_string()).collect::<Vec<_>>());
            }
            return Vec::new();
        }
        let mut path: Vec<String> = qual.to_vec();
        path.push(name.to_string());
        let head = path.first().map(|s| s.as_str()).unwrap_or("");
        let abs: Vec<String> = match head {
            "crate" | "sfllm" => path.iter().skip(1).cloned().collect(),
            "self" => {
                let mut v = parent_path(&caller.mod_path, 0);
                v.extend(path.iter().skip(1).cloned());
                v
            }
            "super" => {
                let supers = path.iter().take_while(|s| s.as_str() == "super").count();
                let mut v = parent_path(&caller.mod_path, supers);
                v.extend(path.iter().skip(supers).cloned());
                v
            }
            _ => {
                if let Some(resolved) = imports.and_then(|m| m.get(head)) {
                    let mut v: Vec<String> =
                        resolved.split("::").map(|s| s.to_string()).collect();
                    v.extend(path.iter().skip(1).cloned());
                    v
                } else {
                    path
                }
            }
        };
        self.match_abs(&abs)
    }

    /// Exact key match, then progressively shorter suffixes of at
    /// least two segments (`a::b::c::f` → `b::c::f` → `c::f`).
    fn match_abs(&self, abs: &[String]) -> Vec<usize> {
        if abs.is_empty() {
            return Vec::new();
        }
        let name = abs.last().unwrap().as_str();
        let cands = match self.by_name.get(name) {
            Some(c) => c,
            None => return Vec::new(),
        };
        for drop in 0..abs.len() {
            let suffix = abs[drop..].join("::");
            if abs.len() - drop < 2 {
                break;
            }
            let hit: Vec<usize> = cands
                .iter()
                .copied()
                .filter(|&j| {
                    let f = &self.fns[j];
                    !f.is_test
                        && (f.key == suffix || f.key.ends_with(&format!("::{suffix}")))
                })
                .collect();
            if !hit.is_empty() {
                return hit;
            }
        }
        Vec::new()
    }

    /// Deterministic multi-root BFS. Returns visit order and, for each
    /// visited node, its predecessor (`usize::MAX` for roots).
    pub fn bfs(&self, roots: &[usize]) -> (Vec<usize>, Vec<usize>) {
        let mut roots: Vec<usize> = roots.to_vec();
        roots.sort_by(|&a, &b| self.fns[a].key.cmp(&self.fns[b].key));
        let mut parent = vec![usize::MAX; self.fns.len()];
        let mut seen = vec![false; self.fns.len()];
        let mut order = Vec::new();
        let mut q = VecDeque::new();
        for r in roots {
            if !seen[r] {
                seen[r] = true;
                q.push_back(r);
            }
        }
        while let Some(i) = q.pop_front() {
            order.push(i);
            for &j in &self.edges[i] {
                if !seen[j] {
                    seen[j] = true;
                    parent[j] = i;
                    q.push_back(j);
                }
            }
        }
        (order, parent)
    }

    /// Call chain from the BFS root down to `i`, as `a -> b -> c` keys.
    fn chain(&self, parent: &[usize], i: usize) -> String {
        let mut keys = vec![self.fns[i].key.clone()];
        let mut cur = i;
        while parent[cur] != usize::MAX {
            cur = parent[cur];
            keys.push(self.fns[cur].key.clone());
        }
        keys.reverse();
        keys.join(" -> ")
    }
}

/// Runs the interprocedural rules over a parsed program and returns
/// P101/D104 findings (sorted by file, line, rule).
pub fn program_findings(files: &[ParsedFile]) -> Vec<Finding> {
    let cg = CallGraph::build(files);
    let mut out = Vec::new();

    let p101_roots: Vec<usize> = (0..cg.fns.len())
        .filter(|&i| {
            let f = &cg.fns[i];
            HOT_MODULES.contains(&f.module.as_str()) && f.is_pub && !f.is_test
        })
        .collect();
    let (order, parent) = cg.bfs(&p101_roots);
    for &i in &order {
        let f = &cg.fns[i];
        if f.is_test {
            continue;
        }
        for site in &f.sites {
            let fires = match site.kind {
                SiteKind::Index => true,
                SiteKind::Unwrap => cg.resolve_method(i, "unwrap").is_empty(),
                SiteKind::Expect => cg.resolve_method(i, "expect").is_empty(),
                _ => false,
            };
            if fires {
                out.push(Finding {
                    rule: "P101",
                    file: f.file.clone(),
                    line: site.line,
                    message: format!(
                        "panic site reachable from hot entry: {}",
                        cg.chain(&parent, i)
                    ),
                    snippet: site.snippet.clone(),
                });
            }
        }
    }

    let d104_roots: Vec<usize> = (0..cg.fns.len())
        .filter(|&i| {
            let f = &cg.fns[i];
            f.has_spawn && !f.is_test
        })
        .collect();
    let (order, parent) = cg.bfs(&d104_roots);
    for &i in &order {
        let f = &cg.fns[i];
        if f.is_test {
            continue;
        }
        for site in &f.sites {
            let fires = match site.kind {
                SiteKind::Sum => cg.resolve_method(i, "sum").is_empty(),
                SiteKind::Fold => cg.resolve_method(i, "fold").is_empty(),
                _ => false,
            };
            if fires {
                out.push(Finding {
                    rule: "D104",
                    file: f.file.clone(),
                    line: site.line,
                    message: format!(
                        "iterator reduction reachable from a spawn site ({}): use the fixed-order helpers in util::stats or justify",
                        cg.chain(&parent, i)
                    ),
                    snippet: site.snippet.clone(),
                });
            }
        }
    }

    out.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule))
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::parse::parse_file;

    fn program(files: &[(&str, &str)]) -> Vec<ParsedFile> {
        files.iter().map(|(rel, src)| parse_file(rel, src)).collect()
    }

    #[test]
    fn cross_module_chain_reaches_helper_unwrap() {
        // hot entry in opt calls a util helper whose unwrap must be
        // attributed back through the chain.
        let files = program(&[
            (
                "rust/src/opt/entry.rs",
                "use crate::util::pick;\npub fn solve(xs: &[f64]) -> f64 { pick(xs) }\n",
            ),
            (
                "rust/src/util/mod.rs",
                "pub fn pick(xs: &[f64]) -> f64 { *xs.first().unwrap() }\n",
            ),
        ]);
        let fs = program_findings(&files);
        assert_eq!(fs.len(), 1, "{fs:?}");
        assert_eq!(fs[0].rule, "P101");
        assert_eq!(fs[0].file, "rust/src/util/mod.rs");
        assert!(fs[0].message.contains("opt::entry::solve -> util::pick"), "{}", fs[0].message);
    }

    #[test]
    fn unreachable_unwrap_is_silent() {
        let files = program(&[
            ("rust/src/opt/entry.rs", "pub fn solve() -> f64 { 1.0 }\n"),
            (
                "rust/src/util/mod.rs",
                "pub fn dead(xs: &[f64]) -> f64 { *xs.first().unwrap() }\n",
            ),
        ]);
        assert!(program_findings(&files).is_empty());
    }

    #[test]
    fn method_calls_resolve_through_impls() {
        let files = program(&[
            (
                "rust/src/opt/entry.rs",
                "use crate::model::Profile;\npub fn solve(p: &Profile) -> f64 { p.cost() }\n",
            ),
            (
                "rust/src/model/mod.rs",
                "pub struct Profile;\nimpl Profile {\n    pub fn cost(&self) -> f64 { self.raw()[0] }\n    fn raw(&self) -> Vec<f64> { vec![1.0] }\n}\n",
            ),
        ]);
        let fs = program_findings(&files);
        // the literal index inside Profile::cost is reachable via the
        // method call
        assert_eq!(fs.len(), 1, "{fs:?}");
        assert_eq!(fs[0].rule, "P101");
        assert_eq!(fs[0].snippet, "[0]");
        assert!(fs[0].message.contains("Profile::cost"), "{}", fs[0].message);
    }

    #[test]
    fn in_repo_expect_method_is_a_call_not_a_panic() {
        // a file-local `fn expect` swallows `.expect(..)` there, while
        // every other file still reports the std panic site.
        let files = program(&[
            (
                "rust/src/util/parser.rs",
                "pub struct P;\nimpl P {\n    pub fn expect(&mut self, c: u8) {}\n}\npub fn drive(p: &mut P) { p.expect(b'x'); }\n",
            ),
            (
                "rust/src/opt/entry.rs",
                "pub fn solve(x: Option<f64>) -> f64 { x.expect(\"set\") }\n",
            ),
        ]);
        let fs = program_findings(&files);
        assert_eq!(fs.len(), 1, "{fs:?}");
        assert_eq!(fs[0].file, "rust/src/opt/entry.rs");
    }

    #[test]
    fn d104_flags_reductions_reachable_from_spawn() {
        let files = program(&[
            (
                "rust/src/sim/run.rs",
                "use crate::util::acc;\nfn worker(xs: &[f64]) -> f64 { acc(xs) }\npub fn fan_out(xs: &[f64]) -> f64 {\n    std::thread::scope(|s| { s.spawn(|| worker(xs)); });\n    0.0\n}\n",
            ),
            (
                "rust/src/util/mod.rs",
                "pub fn acc(xs: &[f64]) -> f64 { xs.iter().sum() }\n",
            ),
        ]);
        let fs = program_findings(&files);
        let d104: Vec<&Finding> = fs.iter().filter(|f| f.rule == "D104").collect();
        assert_eq!(d104.len(), 1, "{fs:?}");
        assert_eq!(d104[0].file, "rust/src/util/mod.rs");
        assert!(d104[0].message.contains("sim::run::fan_out"), "{}", d104[0].message);
    }

    #[test]
    fn test_functions_are_neither_roots_nor_sites() {
        let files = program(&[(
            "rust/src/opt/entry.rs",
            "#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() { Some(1).unwrap(); }\n}\n",
        )]);
        assert!(program_findings(&files).is_empty());
    }

    #[test]
    fn suffix_matching_resolves_reexported_paths() {
        let files = program(&[
            (
                "rust/src/opt/entry.rs",
                "use crate::opt::Objective;\npub fn solve() -> f64 { Objective::weight() }\n",
            ),
            (
                "rust/src/delay/objective.rs",
                "pub struct Objective;\nimpl Objective {\n    pub fn weight() -> f64 { DEFAULTS[0] }\n}\nconst DEFAULTS: [f64; 1] = [0.5];\n",
            ),
        ]);
        let fs = program_findings(&files);
        assert_eq!(fs.len(), 1, "{fs:?}");
        assert!(fs[0].message.contains("delay::objective::Objective::weight"), "{}", fs[0].message);
    }
}
