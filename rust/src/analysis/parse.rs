//! Item-skeleton parser for the `sfllm-lint` structural passes.
//!
//! A recursive-descent pass over the [`super::lexer`] token stream that
//! recovers just enough structure for whole-program analysis: top-level
//! items (with spans that partition the token stream — the round-trip
//! tests in `rust/tests/lint_self.rs` assert full coverage with no
//! overlaps), `use` declarations flattened to leaf paths, `impl`/`trait`
//! blocks with their type/trait names, and per-function bodies reduced
//! to call references plus the panic/reduction sites the interprocedural
//! rules ([`super::callgraph`]) classify. There is deliberately no
//! expression grammar: a function body is a flat scan for
//! `ident(…)` / `path::ident(…)` / `.method(…)` shapes, attribute
//! groups are skipped, and nested `fn` items recurse.
//!
//! Keys follow the file layout: `rust/src/opt/bcd.rs` contributes
//! functions under `opt::bcd::…`, an `impl DelayEvaluator` member in
//! `rust/src/delay/eval.rs` becomes `delay::eval::DelayEvaluator::new`,
//! and in-file `mod` blocks extend the prefix. Qualified calls resolve
//! against these keys by progressively shorter path suffixes (see
//! [`super::callgraph`]), so `crate::`-absolute, re-exported, and
//! locally-imported spellings all land on the same function.

use super::lexer::{lex, Tok, TokKind};
use super::rules::test_mask;
use std::collections::BTreeSet;

/// Item classes the skeleton parser distinguishes. `Other` is the
/// failsafe bucket — unrecognized constructs still get a span so item
/// spans always partition the file.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ItemKind {
    Use,
    Mod,
    Fn,
    Impl,
    Struct,
    Enum,
    Trait,
    Const,
    Static,
    TypeAlias,
    MacroDef,
    MacroCall,
    Other,
}

/// One parsed item: token span `[lo, hi)` plus the declared name where
/// the construct has one (`impl` blocks report the implemented type).
#[derive(Clone, Debug)]
pub struct Item {
    pub kind: ItemKind,
    pub name: String,
    pub lo: usize,
    pub hi: usize,
    pub line: u32,
}

/// One call reference inside a function body. `qual` holds the path
/// segments before the final name (`["crate", "opt", "power"]` for
/// `crate::opt::power::solve_power(..)`, empty for a bare `helper(..)`),
/// and `method` marks `.name(..)` receiver calls.
#[derive(Clone, Debug)]
pub struct CallSite {
    pub qual: Vec<String>,
    pub name: String,
    pub method: bool,
    pub line: u32,
}

/// Site classes the interprocedural rules care about.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SiteKind {
    Unwrap,
    Expect,
    Index,
    Sum,
    Fold,
}

/// One panic/reduction candidate site inside a function body.
#[derive(Clone, Debug)]
pub struct Site {
    pub kind: SiteKind,
    pub line: u32,
    pub snippet: String,
}

/// One function with everything the call graph needs.
#[derive(Clone, Debug)]
pub struct FnInfo {
    /// Fully-qualified key, e.g. `opt::bcd::run` or
    /// `delay::eval::DelayEvaluator::new`.
    pub key: String,
    /// Module path of the enclosing scope (no type name), e.g. `opt::bcd`.
    pub mod_path: String,
    pub name: String,
    /// Top-level module (`opt`, `util`, `bench`, `main`, …).
    pub module: String,
    pub file: String,
    pub line: u32,
    pub is_pub: bool,
    pub is_test: bool,
    /// Declared inside an `impl` or `trait` block.
    pub is_method: bool,
    pub impl_type: String,
    pub impl_trait: String,
    pub has_spawn: bool,
    pub calls: Vec<CallSite>,
    pub sites: Vec<Site>,
}

/// One flattened `use` leaf: `use crate::opt::{bcd, power as pw};`
/// yields two entries with aliases `bcd` and `pw`. Glob leaves get the
/// alias `*` (and are ignored by resolution — a documented
/// approximation).
#[derive(Clone, Debug)]
pub struct UseDecl {
    pub path: Vec<String>,
    pub alias: String,
    pub line: u32,
}

/// Everything the structural passes need from one source file.
#[derive(Clone, Debug)]
pub struct ParsedFile {
    pub rel: String,
    /// Top-level module this file belongs to (`opt` for
    /// `rust/src/opt/bcd.rs`, `bench` for `rust/src/bench.rs`).
    pub module: String,
    /// Module path of the file scope (`opt::bcd`; `sim` for
    /// `rust/src/sim/mod.rs`).
    pub mod_path: String,
    pub items: Vec<Item>,
    pub fns: Vec<FnInfo>,
    pub uses: Vec<UseDecl>,
    /// Non-test `crate::X` / `sfllm::X` references: `(target module,
    /// line)` — the raw material of the module dependency graph.
    pub crate_refs: Vec<(String, u32)>,
    /// Every identifier in non-test code (drives the method-resolution
    /// "type mentioned in this file" heuristic).
    pub idents: BTreeSet<String>,
    /// Token count, for the round-trip span tests.
    pub token_count: usize,
}

/// Words that can precede `(` without being a call.
const KEYWORDS: &[&str] = &[
    "as", "async", "await", "break", "const", "continue", "crate", "dyn", "else", "enum",
    "extern", "false", "fn", "for", "if", "impl", "in", "let", "loop", "match", "mod", "move",
    "mut", "pub", "ref", "return", "static", "struct", "super", "trait", "true", "type",
    "unsafe", "use", "where", "while", "Self", "self",
];

fn txt(toks: &[Tok], i: usize) -> &str {
    toks.get(i).map(|t| t.text.as_str()).unwrap_or("")
}

fn line_at(toks: &[Tok], i: usize) -> u32 {
    toks.get(i).map(|t| t.line).unwrap_or(0)
}

/// Index just past the delimiter group opening at `open_idx`
/// (`toks[open_idx]` must be `open`). Saturates at `hi`.
fn skip_balanced(toks: &[Tok], open_idx: usize, hi: usize, open: &str, close: &str) -> usize {
    let mut depth = 0i64;
    let mut i = open_idx;
    while i < hi {
        let t = txt(toks, i);
        if t == open {
            depth += 1;
        } else if t == close {
            depth -= 1;
            if depth == 0 {
                return i + 1;
            }
        }
        i += 1;
    }
    hi
}

/// Index just past a balanced `<…>` starting at `open_idx` (which must
/// be `<`). A `>` directly preceded by `-` or `=` is an arrow, not a
/// closer. Saturates at `hi`.
fn skip_angles(toks: &[Tok], open_idx: usize, hi: usize) -> usize {
    let mut depth = 0i64;
    let mut i = open_idx;
    while i < hi {
        let t = txt(toks, i);
        if t == "<" {
            depth += 1;
        } else if t == ">" && i > 0 && txt(toks, i - 1) != "-" && txt(toks, i - 1) != "=" {
            depth -= 1;
            if depth == 0 {
                return i + 1;
            }
        }
        i += 1;
    }
    hi
}

/// Index just past the `;` ending the statement that starts at `i`,
/// tracking `{}`/`()`/`[]` depth so initializer blocks don't end it
/// early. Saturates at `hi`.
fn scan_past_semi(toks: &[Tok], i: usize, hi: usize) -> usize {
    let mut depth = 0i64;
    let mut j = i;
    while j < hi {
        match txt(toks, j) {
            "{" | "(" | "[" => depth += 1,
            "}" | ")" | "]" => depth -= 1,
            ";" if depth <= 0 => return j + 1,
            _ => {}
        }
        j += 1;
    }
    hi
}

/// First index in `[i, hi)` whose token text is in `whats`, or `hi`.
fn find_first(toks: &[Tok], i: usize, hi: usize, whats: &[&str]) -> usize {
    let mut j = i;
    while j < hi {
        if whats.contains(&txt(toks, j)) {
            return j;
        }
        j += 1;
    }
    hi
}

/// Splits `[lo, hi)` into items. Spans are contiguous and cover the
/// whole range: every token index lands in exactly one item.
pub fn parse_items(toks: &[Tok], lo: usize, hi: usize) -> Vec<Item> {
    let mut items = Vec::new();
    let mut i = lo;
    while i < hi {
        let start = i;
        // leading outer/inner attributes: #[…] and #![…]
        while txt(toks, i) == "#" {
            let mut j = i + 1;
            if txt(toks, j) == "!" {
                j += 1;
            }
            if txt(toks, j) == "[" {
                i = skip_balanced(toks, j, hi, "[", "]");
            } else {
                break; // stray '#' (shebang debris) — Other below
            }
        }
        if i >= hi {
            items.push(Item {
                kind: ItemKind::Other,
                name: String::new(),
                lo: start,
                hi,
                line: line_at(toks, start),
            });
            break;
        }
        // visibility
        let mut j = i;
        if txt(toks, j) == "pub" {
            j += 1;
            if txt(toks, j) == "(" {
                j = skip_balanced(toks, j, hi, "(", ")");
            }
        }
        // fn modifiers
        loop {
            match txt(toks, j) {
                "unsafe" | "async" | "default" => j += 1,
                "const" if txt(toks, j + 1) == "fn" => j += 1,
                "extern"
                    if toks.get(j + 1).is_some_and(|t| t.kind == TokKind::Str)
                        && txt(toks, j + 2) == "fn" =>
                {
                    j += 2
                }
                _ => break,
            }
        }
        let line = line_at(toks, start);
        let (kind, name, end) = match txt(toks, j) {
            "use" => (ItemKind::Use, String::new(), scan_past_semi(toks, j, hi)),
            "mod" => {
                let name = txt(toks, j + 1).to_string();
                let p = find_first(toks, j + 1, hi, &["{", ";"]);
                let end = if txt(toks, p) == "{" {
                    skip_balanced(toks, p, hi, "{", "}")
                } else {
                    (p + 1).min(hi)
                };
                (ItemKind::Mod, name, end)
            }
            "fn" => {
                let name = txt(toks, j + 1).to_string();
                let p = find_first(toks, j + 1, hi, &["{", ";"]);
                let end = if txt(toks, p) == "{" {
                    skip_balanced(toks, p, hi, "{", "}")
                } else {
                    (p + 1).min(hi)
                };
                (ItemKind::Fn, name, end)
            }
            "struct" | "enum" | "union" => {
                let k = if txt(toks, j) == "enum" { ItemKind::Enum } else { ItemKind::Struct };
                let name = txt(toks, j + 1).to_string();
                let p = find_first(toks, j + 1, hi, &["{", ";"]);
                let end = if txt(toks, p) == "{" {
                    skip_balanced(toks, p, hi, "{", "}")
                } else {
                    (p + 1).min(hi)
                };
                (k, name, end)
            }
            "trait" => {
                let name = txt(toks, j + 1).to_string();
                let p = find_first(toks, j + 1, hi, &["{", ";"]);
                let end = if txt(toks, p) == "{" {
                    skip_balanced(toks, p, hi, "{", "}")
                } else {
                    (p + 1).min(hi)
                };
                (ItemKind::Trait, name, end)
            }
            "impl" => {
                let p = find_first(toks, j + 1, hi, &["{", ";"]);
                let end = if txt(toks, p) == "{" {
                    skip_balanced(toks, p, hi, "{", "}")
                } else {
                    (p + 1).min(hi)
                };
                let (ty, _) = impl_header(toks, j, p);
                (ItemKind::Impl, ty, end)
            }
            "type" => (ItemKind::TypeAlias, txt(toks, j + 1).to_string(),
                scan_past_semi(toks, j, hi)),
            "static" => (ItemKind::Static, String::new(), scan_past_semi(toks, j, hi)),
            "const" => (ItemKind::Const, String::new(), scan_past_semi(toks, j, hi)),
            "macro_rules" => {
                let name = txt(toks, j + 2).to_string();
                let p = find_first(toks, j + 2, hi, &["{", "(", "["]);
                let end = match txt(toks, p) {
                    "{" => skip_balanced(toks, p, hi, "{", "}"),
                    "(" => scan_past_semi(toks, skip_balanced(toks, p, hi, "(", ")") - 1, hi),
                    "[" => scan_past_semi(toks, skip_balanced(toks, p, hi, "[", "]") - 1, hi),
                    _ => (p + 1).min(hi),
                };
                (ItemKind::MacroDef, name, end)
            }
            _ if toks.get(j).is_some_and(|t| t.kind == TokKind::Ident)
                && txt(toks, j + 1) == "!" =>
            {
                // item-level macro invocation, e.g. `thread_local! { … }`
                let name = txt(toks, j).to_string();
                let p = find_first(toks, j + 1, hi, &["{", "(", "["]);
                let end = match txt(toks, p) {
                    "{" => skip_balanced(toks, p, hi, "{", "}"),
                    "(" => scan_past_semi(toks, skip_balanced(toks, p, hi, "(", ")") - 1, hi),
                    "[" => scan_past_semi(toks, skip_balanced(toks, p, hi, "[", "]") - 1, hi),
                    _ => (p + 1).min(hi),
                };
                (ItemKind::MacroCall, name, end)
            }
            _ => {
                // failsafe: swallow to the next `;` or balanced block
                let p = find_first(toks, j, hi, &["{", ";"]);
                let end = if txt(toks, p) == "{" {
                    skip_balanced(toks, p, hi, "{", "}")
                } else {
                    (p + 1).min(hi)
                };
                (ItemKind::Other, String::new(), end)
            }
        };
        let end = end.clamp(start + 1, hi);
        items.push(Item { kind, name, lo: start, hi: end, line });
        i = end;
    }
    items
}

/// Extracts `(type, trait)` names from an `impl` header spanning
/// `[impl_idx, body_open)`: the last generics-depth-0 identifier on
/// each side of `for` (empty trait when inherent).
fn impl_header(toks: &[Tok], impl_idx: usize, body_open: usize) -> (String, String) {
    let mut i = impl_idx + 1;
    if txt(toks, i) == "<" {
        i = skip_angles(toks, i, body_open);
    }
    let mut parts: Vec<Vec<&str>> = vec![Vec::new()];
    let mut depth = 0i64;
    while i < body_open {
        let t = txt(toks, i);
        match t {
            "<" => depth += 1,
            ">" if txt(toks, i - 1) != "-" && txt(toks, i - 1) != "=" => depth -= 1,
            "where" if depth <= 0 => break,
            "for" if depth <= 0 => parts.push(Vec::new()),
            _ => {
                if depth <= 0 && toks.get(i).is_some_and(|x| x.kind == TokKind::Ident) {
                    if let Some(last) = parts.last_mut() {
                        last.push(t);
                    }
                }
            }
        }
        i += 1;
    }
    let last_of = |v: &Vec<&str>| v.last().map(|s| s.to_string()).unwrap_or_default();
    if parts.len() >= 2 {
        // `impl Trait for Type` — trait part first, type part second
        (last_of(&parts[1]), last_of(&parts[0]))
    } else {
        (last_of(&parts[0]), String::new())
    }
}

/// `rust/src/opt/bcd.rs` → `opt::bcd`; `rust/src/sim/mod.rs` → `sim`;
/// `rust/src/bench.rs` → `bench`.
fn mod_path_of(rel: &str) -> String {
    let p = rel.strip_prefix("rust/src/").unwrap_or(rel);
    let p = p.strip_suffix(".rs").unwrap_or(p);
    let mut segs: Vec<&str> = p.split('/').collect();
    if segs.len() > 1 && segs.last() == Some(&"mod") {
        segs.pop();
    }
    segs.join("::")
}

struct FileCtx<'a> {
    toks: &'a [Tok],
    mask: &'a [bool],
    rel: &'a str,
    module: String,
}

/// Parses one source file into the structures the graph passes consume.
/// `rel` must be the repo-relative path with forward slashes.
pub fn parse_file(rel: &str, src: &str) -> ParsedFile {
    let (toks, _comments) = lex(src);
    let mask = test_mask(&toks);
    let mod_path = mod_path_of(rel);
    let module = mod_path.split("::").next().unwrap_or("").to_string();

    let mut idents = BTreeSet::new();
    let mut crate_refs = Vec::new();
    for i in 0..toks.len() {
        if mask[i] || toks[i].kind != TokKind::Ident {
            continue;
        }
        idents.insert(toks[i].text.clone());
        if (toks[i].text == "crate" || toks[i].text == "sfllm")
            && txt(&toks, i + 1) == "::"
            && toks.get(i + 2).is_some_and(|t| t.kind == TokKind::Ident)
        {
            crate_refs.push((toks[i + 2].text.clone(), toks[i].line));
        }
    }

    let items = parse_items(&toks, 0, toks.len());
    let ctx = FileCtx { toks: &toks, mask: &mask, rel, module: module.clone() };
    let mut fns = Vec::new();
    let mut uses = Vec::new();
    walk_items(&ctx, &items, &mod_path, "", "", &mut fns, &mut uses);

    ParsedFile {
        rel: rel.to_string(),
        module,
        mod_path,
        items,
        fns,
        uses,
        crate_refs,
        idents,
        token_count: toks.len(),
    }
}

fn walk_items(
    ctx: &FileCtx,
    items: &[Item],
    mod_path: &str,
    impl_type: &str,
    impl_trait: &str,
    fns: &mut Vec<FnInfo>,
    uses: &mut Vec<UseDecl>,
) {
    for item in items {
        match item.kind {
            ItemKind::Fn => read_fn(ctx, item.lo, item.hi, mod_path, impl_type, impl_trait, fns),
            ItemKind::Use => {
                if !ctx.mask.get(item.lo).copied().unwrap_or(false) {
                    parse_use(ctx.toks, item.lo, item.hi, uses);
                }
            }
            ItemKind::Mod => {
                if let Some(open) = body_open(ctx.toks, item.lo, item.hi) {
                    let inner = parse_items(ctx.toks, open + 1, item.hi.saturating_sub(1));
                    let sub = if mod_path.is_empty() {
                        item.name.clone()
                    } else {
                        format!("{mod_path}::{}", item.name)
                    };
                    walk_items(ctx, &inner, &sub, "", "", fns, uses);
                }
            }
            ItemKind::Impl => {
                if let Some(open) = body_open(ctx.toks, item.lo, item.hi) {
                    let impl_idx = find_first(ctx.toks, item.lo, open, &["impl"]);
                    let (ty, tr) = impl_header(ctx.toks, impl_idx, open);
                    let inner = parse_items(ctx.toks, open + 1, item.hi.saturating_sub(1));
                    walk_items(ctx, &inner, mod_path, &ty, &tr, fns, uses);
                }
            }
            ItemKind::Trait => {
                if let Some(open) = body_open(ctx.toks, item.lo, item.hi) {
                    let inner = parse_items(ctx.toks, open + 1, item.hi.saturating_sub(1));
                    walk_items(ctx, &inner, mod_path, "", &item.name, fns, uses);
                }
            }
            _ => {}
        }
    }
}

/// First `{` in the item span (the body opener for mod/impl/trait/fn —
/// attributes and headers cannot contain a brace token).
fn body_open(toks: &[Tok], lo: usize, hi: usize) -> Option<usize> {
    let p = find_first(toks, lo, hi, &["{"]);
    (p < hi).then_some(p)
}

#[allow(clippy::too_many_arguments)]
fn read_fn(
    ctx: &FileCtx,
    lo: usize,
    hi: usize,
    mod_path: &str,
    impl_type: &str,
    impl_trait: &str,
    fns: &mut Vec<FnInfo>,
) {
    let toks = ctx.toks;
    let fn_idx = find_first(toks, lo, hi, &["fn"]);
    if fn_idx >= hi {
        return;
    }
    let name = txt(toks, fn_idx + 1).to_string();
    let mut is_pub = false;
    let mut k = lo;
    while k < fn_idx {
        if txt(toks, k) == "#" && txt(toks, k + 1) == "[" {
            k = skip_balanced(toks, k + 1, fn_idx, "[", "]");
            continue;
        }
        if txt(toks, k) == "pub" {
            is_pub = true;
        }
        k += 1;
    }
    let prefix = if impl_type.is_empty() && impl_trait.is_empty() {
        mod_path.to_string()
    } else if impl_type.is_empty() {
        format!("{mod_path}::{impl_trait}")
    } else {
        format!("{mod_path}::{impl_type}")
    };
    let key = if prefix.is_empty() { name.clone() } else { format!("{prefix}::{name}") };
    let mut info = FnInfo {
        key,
        mod_path: mod_path.to_string(),
        name,
        module: ctx.module.clone(),
        file: ctx.rel.to_string(),
        line: line_at(toks, fn_idx),
        is_pub,
        is_test: ctx.mask.get(fn_idx).copied().unwrap_or(false),
        is_method: !impl_type.is_empty() || !impl_trait.is_empty(),
        impl_type: impl_type.to_string(),
        impl_trait: impl_trait.to_string(),
        has_spawn: false,
        calls: Vec::new(),
        sites: Vec::new(),
    };
    let sig_end = find_first(toks, fn_idx + 1, hi, &["{", ";"]);
    if txt(toks, sig_end) == "{" {
        let body_hi = skip_balanced(toks, sig_end, hi, "{", "}").saturating_sub(1);
        scan_body(ctx, sig_end + 1, body_hi, &mut info, fns);
    }
    fns.push(info);
}

/// Flat body scan: call references, panic/reduction sites, `spawn`
/// markers. Attribute groups are skipped; nested `fn` items recurse as
/// their own [`FnInfo`] under the enclosing function's key.
fn scan_body(ctx: &FileCtx, lo: usize, hi: usize, info: &mut FnInfo, fns: &mut Vec<FnInfo>) {
    let toks = ctx.toks;
    let mut i = lo;
    while i < hi {
        let t = &toks[i];
        if t.text == "#" {
            let mut j = i + 1;
            if txt(toks, j) == "!" {
                j += 1;
            }
            if txt(toks, j) == "[" {
                i = skip_balanced(toks, j, hi, "[", "]");
                continue;
            }
        }
        if t.text == "fn" && toks.get(i + 1).is_some_and(|x| x.kind == TokKind::Ident) {
            let p = find_first(toks, i + 1, hi, &["{", ";"]);
            let end = if txt(toks, p) == "{" {
                skip_balanced(toks, p, hi, "{", "}")
            } else {
                (p + 1).min(hi)
            };
            read_fn(ctx, i, end, &info.key, "", "", fns);
            i = end;
            continue;
        }
        if t.kind == TokKind::Punct && t.text == "[" && i > lo {
            let p = &toks[i - 1];
            let prev_ok = p.kind == TokKind::Ident || p.text == ")" || p.text == "]";
            if prev_ok
                && toks.get(i + 1).is_some_and(|x| x.kind == TokKind::Num)
                && txt(toks, i + 2) == "]"
            {
                info.sites.push(Site {
                    kind: SiteKind::Index,
                    line: t.line,
                    snippet: format!("[{}]", toks[i + 1].text),
                });
            }
            i += 1;
            continue;
        }
        if t.kind != TokKind::Ident {
            i += 1;
            continue;
        }
        if t.text == "spawn" {
            info.has_spawn = true;
        }
        // a call is `ident (` or `ident ::<…> (`, not preceded by `fn`
        let mut call_paren = None;
        if txt(toks, i + 1) == "(" {
            call_paren = Some(i + 1);
        } else if txt(toks, i + 1) == "::" && txt(toks, i + 2) == "<" {
            let e = skip_angles(toks, i + 2, hi);
            if txt(toks, e) == "(" {
                call_paren = Some(e);
            }
        }
        if call_paren.is_none()
            || KEYWORDS.contains(&t.text.as_str())
            || (i > lo && txt(toks, i - 1) == "fn")
        {
            i += 1;
            continue;
        }
        let method = i > lo && txt(toks, i - 1) == ".";
        let mut qual: Vec<String> = Vec::new();
        if !method {
            let mut p = i;
            while p >= 2
                && txt(toks, p - 1) == "::"
                && toks.get(p - 2).is_some_and(|x| x.kind == TokKind::Ident)
            {
                qual.insert(0, toks[p - 2].text.clone());
                p -= 2;
            }
        }
        info.calls.push(CallSite { qual, name: t.text.clone(), method, line: t.line });
        if method {
            let site = match t.text.as_str() {
                "unwrap" => Some((SiteKind::Unwrap, ".unwrap()")),
                "expect" => Some((SiteKind::Expect, ".expect()")),
                "sum" => Some((SiteKind::Sum, ".sum()")),
                "fold" => Some((SiteKind::Fold, ".fold()")),
                _ => None,
            };
            if let Some((kind, snip)) = site {
                info.sites.push(Site { kind, line: t.line, snippet: snip.to_string() });
            }
        }
        i += 1;
    }
}

/// Flattens the use-tree of one `use` item into leaf paths.
fn parse_use(toks: &[Tok], lo: usize, hi: usize, out: &mut Vec<UseDecl>) {
    let use_idx = find_first(toks, lo, hi, &["use"]);
    if use_idx >= hi {
        return;
    }
    let line = line_at(toks, use_idx);
    let mut prefix = Vec::new();
    let mut i = use_idx + 1;
    use_tree(toks, &mut i, hi, &mut prefix, line, out);
}

fn use_tree(
    toks: &[Tok],
    i: &mut usize,
    hi: usize,
    prefix: &mut Vec<String>,
    line: u32,
    out: &mut Vec<UseDecl>,
) {
    let depth_at_entry = prefix.len();
    loop {
        let t = txt(toks, *i);
        if *i >= hi || t == ";" || t == "," || t == "}" {
            break;
        }
        if t == "{" {
            *i += 1;
            loop {
                use_tree(toks, i, hi, prefix, line, out);
                if txt(toks, *i) == "," {
                    *i += 1;
                    continue;
                }
                break;
            }
            if txt(toks, *i) == "}" {
                *i += 1;
            }
            prefix.truncate(depth_at_entry);
            return;
        }
        if t == "*" {
            out.push(UseDecl { path: prefix.clone(), alias: "*".to_string(), line });
            *i += 1;
            prefix.truncate(depth_at_entry);
            return;
        }
        if toks.get(*i).is_some_and(|x| x.kind == TokKind::Ident) {
            let seg = t.to_string();
            *i += 1;
            if txt(toks, *i) == "::" {
                prefix.push(seg);
                *i += 1;
                continue;
            }
            // leaf; `as` alias?
            let mut alias = seg.clone();
            if txt(toks, *i) == "as" {
                alias = txt(toks, *i + 1).to_string();
                *i += 2;
            }
            let mut path = prefix.clone();
            path.push(seg);
            out.push(UseDecl { path, alias, line });
            prefix.truncate(depth_at_entry);
            return;
        }
        *i += 1; // unexpected token — skip, keep making progress
    }
    prefix.truncate(depth_at_entry);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(src: &str) -> ParsedFile {
        parse_file("rust/src/opt/fixture.rs", src)
    }

    #[test]
    fn item_spans_partition_the_token_stream() {
        let src = r#"
//! doc
use crate::util::rng::Rng;

#[derive(Clone)]
pub struct S { pub x: f64 }

pub const C: usize = { 1 + 2 };

impl S {
    pub fn get(&self) -> f64 { self.x }
}

pub fn free(n: usize) -> usize { n + 1 }

mod inner {
    pub fn helper() {}
}
"#;
        let pf = parse(src);
        let mut covered = 0usize;
        for it in &pf.items {
            assert_eq!(it.lo, covered, "gap/overlap before item {:?}", it.kind);
            assert!(it.hi > it.lo);
            covered = it.hi;
        }
        assert_eq!(covered, pf.token_count);
        let kinds: Vec<ItemKind> = pf.items.iter().map(|i| i.kind).collect();
        assert_eq!(
            kinds,
            [
                ItemKind::Use,
                ItemKind::Struct,
                ItemKind::Const,
                ItemKind::Impl,
                ItemKind::Fn,
                ItemKind::Mod
            ]
        );
    }

    #[test]
    fn fn_keys_follow_file_and_impl_layout() {
        let src = r#"
pub struct Solver;
impl Solver {
    pub fn new() -> Self { Solver }
    fn inner(&self) {}
}
impl Default for Solver {
    fn default() -> Self { Solver::new() }
}
pub fn run() { let s = Solver::new(); s.inner(); }
mod nested { pub fn deep() {} }
"#;
        let pf = parse(src);
        let keys: Vec<&str> = pf.fns.iter().map(|f| f.key.as_str()).collect();
        assert_eq!(
            keys,
            [
                "opt::fixture::Solver::new",
                "opt::fixture::Solver::inner",
                "opt::fixture::Solver::default",
                "opt::fixture::run",
                "opt::fixture::nested::deep",
            ]
        );
        let default_fn = &pf.fns[2];
        assert_eq!(default_fn.impl_type, "Solver");
        assert_eq!(default_fn.impl_trait, "Default");
        assert!(default_fn.is_method);
        let run = &pf.fns[3];
        assert!(run.is_pub && !run.is_method);
        // Solver::new() is a qualified call, s.inner() a method call
        assert!(run
            .calls
            .iter()
            .any(|c| c.name == "new" && c.qual == ["Solver"] && !c.method));
        assert!(run.calls.iter().any(|c| c.name == "inner" && c.method));
    }

    #[test]
    fn sites_and_spawn_are_collected() {
        let src = r#"
pub fn work(xs: &[f64]) -> f64 {
    std::thread::scope(|s| { s.spawn(|| ()); });
    let a = xs[0];
    let b: f64 = xs.iter().sum();
    let c = xs.iter().fold(0.0, |m, x| m + x);
    let d = xs.first().unwrap();
    let e = xs.first().expect("nonempty");
    a + b + c + d + e
}
"#;
        let pf = parse(src);
        let f = &pf.fns[0];
        assert!(f.has_spawn);
        let kinds: Vec<SiteKind> = f.sites.iter().map(|s| s.kind).collect();
        assert_eq!(
            kinds,
            [SiteKind::Index, SiteKind::Sum, SiteKind::Fold, SiteKind::Unwrap, SiteKind::Expect]
        );
    }

    #[test]
    fn nested_turbofish_calls_are_still_calls() {
        let src = "pub fn f(xs: &[f64]) -> f64 { xs.iter().copied().sum::<f64>() }";
        let pf = parse(src);
        assert!(pf.fns[0].sites.iter().any(|s| s.kind == SiteKind::Sum));
        let src2 = "pub fn g() { let v = make::<Vec<Vec<u8>>>(); drop(v); }";
        let pf2 = parse_file("rust/src/opt/fixture.rs", src2);
        assert!(pf2.fns[0].calls.iter().any(|c| c.name == "make"));
    }

    #[test]
    fn use_trees_flatten_with_aliases_and_globs() {
        let src = "use crate::opt::{bcd, power as pw, assignment::*};\nuse super::eval::Cols;\n";
        let pf = parse(src);
        let flat: Vec<(String, String)> = pf
            .uses
            .iter()
            .map(|u| (u.path.join("::"), u.alias.clone()))
            .collect();
        assert_eq!(
            flat,
            [
                ("crate::opt::bcd".to_string(), "bcd".to_string()),
                ("crate::opt::power".to_string(), "pw".to_string()),
                ("crate::opt::assignment".to_string(), "*".to_string()),
                ("super::eval::Cols".to_string(), "Cols".to_string()),
            ]
        );
    }

    #[test]
    fn test_masked_fns_and_refs_are_flagged() {
        let src = r#"
pub fn live() { crate::util::noop(); }
#[cfg(test)]
mod tests {
    #[test]
    fn t() { crate::delay::check(); }
}
"#;
        let pf = parse(src);
        assert!(!pf.fns.iter().find(|f| f.name == "live").unwrap().is_test);
        assert!(pf.fns.iter().find(|f| f.name == "t").unwrap().is_test);
        // the test-only crate::delay ref must not leak into the graph
        assert_eq!(pf.crate_refs, [("util".to_string(), 2)]);
    }

    #[test]
    fn mod_paths_derive_from_rel() {
        assert_eq!(parse_file("rust/src/sim/mod.rs", "").mod_path, "sim");
        assert_eq!(parse_file("rust/src/bench.rs", "").mod_path, "bench");
        assert_eq!(parse_file("rust/src/util/codec.rs", "").mod_path, "util::codec");
        assert_eq!(parse_file("rust/src/main.rs", "").module, "main");
    }
}
