//! Minimal Rust lexer for the `sfllm-lint` analyzer.
//!
//! Token-level, not syntax-level: rules in [`super::rules`] match short
//! token sequences (`Instant :: now`, `. unwrap (`, `[ 0 ]`), so the
//! lexer only has to get the hard boundaries right — comments (kept,
//! because `lint:allow` suppressions live there), strings in all their
//! forms (raw, byte, char vs lifetime), and numbers including the
//! tuple-field case where `b.1.partial_cmp` must lex as
//! `b` `.` `1` `.` `partial_cmp`, never as the float `1.`.
//!
//! The lexer is byte-oriented; non-ASCII characters outside strings and
//! comments are skipped (they never participate in any rule pattern).

/// Token class. String-like literals all collapse to [`TokKind::Str`]
/// with placeholder text — their contents never match a rule.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokKind {
    Ident,
    Punct,
    Num,
    Str,
    Lifetime,
}

/// One lexed token with the 1-based line it starts on.
#[derive(Clone, Debug)]
pub struct Tok {
    pub text: String,
    pub line: u32,
    pub kind: TokKind,
}

/// A `//` comment with the 1-based line it starts on (block comments
/// are skipped — `lint:allow` must be a line comment).
#[derive(Clone, Debug)]
pub struct Comment {
    pub line: u32,
    pub text: String,
}

fn is_ident_start(c: u8) -> bool {
    c.is_ascii_alphabetic() || c == b'_'
}

fn is_ident_cont(c: u8) -> bool {
    c.is_ascii_alphanumeric() || c == b'_'
}

/// Byte length of the UTF-8 character whose leading byte is `b`
/// (1 for anything malformed, so the scan always advances).
fn utf8_len(b: u8) -> usize {
    match b {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF4 => 4,
        _ => 1,
    }
}

fn count_newlines(b: &[u8]) -> u32 {
    b.iter().filter(|&&x| x == b'\n').count() as u32
}

/// If byte position `i` starts a raw (optionally byte) string literal
/// — `r"…"`, `r#"…"#`, `br#"…"#` — returns the index just past its
/// closing delimiter (or `len` when unterminated).
fn raw_string_end(b: &[u8], i: usize) -> Option<usize> {
    let mut j = i;
    if b.get(j) == Some(&b'b') {
        j += 1;
    }
    if b.get(j) != Some(&b'r') {
        return None;
    }
    j += 1;
    let hash_start = j;
    while b.get(j) == Some(&b'#') {
        j += 1;
    }
    let hashes = j - hash_start;
    if b.get(j) != Some(&b'"') {
        return None;
    }
    j += 1;
    while j + hashes < b.len() {
        if b[j] == b'"' && b[j + 1..j + 1 + hashes].iter().all(|&x| x == b'#') {
            return Some(j + 1 + hashes);
        }
        j += 1;
    }
    Some(b.len())
}

/// Lex `src` into tokens plus the `//` comments (which carry
/// suppressions). Never fails: unrecognized bytes are skipped.
pub fn lex(src: &str) -> (Vec<Tok>, Vec<Comment>) {
    let b = src.as_bytes();
    let n = b.len();
    let mut toks: Vec<Tok> = Vec::new();
    let mut comments: Vec<Comment> = Vec::new();
    let mut i = 0usize;
    let mut line = 1u32;
    while i < n {
        let c = b[i];
        if c == b'\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c == b' ' || c == b'\t' || c == b'\r' {
            i += 1;
            continue;
        }
        if b[i..].starts_with(b"//") {
            let j = b[i..].iter().position(|&x| x == b'\n').map_or(n, |p| i + p);
            comments.push(Comment {
                line,
                text: src[i..j].to_string(),
            });
            i = j;
            continue;
        }
        if b[i..].starts_with(b"/*") {
            let mut depth = 1usize;
            i += 2;
            while i < n && depth > 0 {
                if b[i..].starts_with(b"/*") {
                    depth += 1;
                    i += 2;
                } else if b[i..].starts_with(b"*/") {
                    depth -= 1;
                    i += 2;
                } else {
                    if b[i] == b'\n' {
                        line += 1;
                    }
                    i += 1;
                }
            }
            continue;
        }
        if let Some(end) = raw_string_end(b, i) {
            line += count_newlines(&b[i..end]);
            toks.push(Tok {
                text: "<rawstr>".to_string(),
                line,
                kind: TokKind::Str,
            });
            i = end;
            continue;
        }
        if b[i..].starts_with(b"r#") && i + 2 < n && is_ident_start(b[i + 2]) {
            let mut j = i + 2;
            while j < n && is_ident_cont(b[j]) {
                j += 1;
            }
            toks.push(Tok {
                text: src[i + 2..j].to_string(),
                line,
                kind: TokKind::Ident,
            });
            i = j;
            continue;
        }
        if c == b'"' || b[i..].starts_with(b"b\"") {
            i += if c == b'b' { 2 } else { 1 };
            while i < n {
                if b[i] == b'\\' {
                    // an escaped newline (string continuation) still
                    // ends a physical line
                    if b.get(i + 1) == Some(&b'\n') {
                        line += 1;
                    }
                    i += 2;
                    continue;
                }
                if b[i] == b'"' {
                    i += 1;
                    break;
                }
                if b[i] == b'\n' {
                    line += 1;
                }
                i += 1;
            }
            toks.push(Tok {
                text: "<str>".to_string(),
                line,
                kind: TokKind::Str,
            });
            continue;
        }
        if c == b'\'' {
            // lifetime ('a, '_, 'outer) iff ident-shaped and NOT closed
            // by another quote; otherwise a char literal
            let mut j = i + 1;
            while j < n && is_ident_cont(b[j]) {
                j += 1;
            }
            if j > i + 1 && is_ident_start(b[i + 1]) && b.get(j) != Some(&b'\'') {
                toks.push(Tok {
                    text: src[i..j].to_string(),
                    line,
                    kind: TokKind::Lifetime,
                });
                i = j;
                continue;
            }
            i += 1;
            while i < n {
                if b[i] == b'\\' {
                    i += 2;
                    continue;
                }
                if b[i] == b'\'' {
                    i += 1;
                    break;
                }
                i += 1;
            }
            toks.push(Tok {
                text: "<char>".to_string(),
                line,
                kind: TokKind::Str,
            });
            continue;
        }
        if is_ident_start(c) {
            let mut j = i + 1;
            while j < n && is_ident_cont(b[j]) {
                j += 1;
            }
            toks.push(Tok {
                text: src[i..j].to_string(),
                line,
                kind: TokKind::Ident,
            });
            i = j;
            continue;
        }
        if c.is_ascii_digit() {
            let mut j = i + 1;
            while j < n && (b[j].is_ascii_digit() || b[j] == b'_') {
                j += 1;
            }
            // fractional part only when a digit follows the dot, so a
            // tuple-field access like `x.1.partial_cmp` stays `1`
            if j + 1 < n && b[j] == b'.' && b[j + 1].is_ascii_digit() {
                j += 2;
                while j < n && (b[j].is_ascii_digit() || b[j] == b'_') {
                    j += 1;
                }
            }
            if j < n && (b[j] == b'e' || b[j] == b'E') {
                let mut k = j + 1;
                if k < n && (b[k] == b'+' || b[k] == b'-') {
                    k += 1;
                }
                if k < n && (b[k].is_ascii_digit() || b[k] == b'_') {
                    while k < n && (b[k].is_ascii_digit() || b[k] == b'_') {
                        k += 1;
                    }
                    j = k;
                }
            }
            // type suffix or hex/oct/bin body (0x…, 1f64, 3usize)
            if j < n && is_ident_start(b[j]) {
                j += 1;
                while j < n && is_ident_cont(b[j]) {
                    j += 1;
                }
            }
            toks.push(Tok {
                text: src[i..j].to_string(),
                line,
                kind: TokKind::Num,
            });
            i = j;
            continue;
        }
        if b[i..].starts_with(b"::") {
            toks.push(Tok {
                text: "::".to_string(),
                line,
                kind: TokKind::Punct,
            });
            i += 2;
            continue;
        }
        if c.is_ascii() {
            toks.push(Tok {
                text: (c as char).to_string(),
                line,
                kind: TokKind::Punct,
            });
            i += 1;
        } else {
            i += utf8_len(c);
        }
    }
    (toks, comments)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn texts(src: &str) -> Vec<String> {
        lex(src).0.into_iter().map(|t| t.text).collect()
    }

    #[test]
    fn tuple_field_access_does_not_become_a_float() {
        assert_eq!(
            texts("b.1.partial_cmp(&a.1)"),
            ["b", ".", "1", ".", "partial_cmp", "(", "&", "a", ".", "1", ")"]
        );
        assert_eq!(texts("x = 1.5e-3;"), ["x", "=", "1.5e-3", ";"]);
        assert_eq!(texts("0x9E37_79B9"), ["0x9E37_79B9"]);
    }

    #[test]
    fn strings_and_lifetimes_do_not_leak_idents() {
        assert_eq!(
            texts(r##"let s = r#"Instant::now()"#; f('x', 'a');"##),
            ["let", "s", "=", "<rawstr>", ";", "f", "(", "<char>", ",", "<char>", ")", ";"]
        );
        assert_eq!(
            texts("fn f<'a>(x: &'a str) {}"),
            ["fn", "f", "<", "'a", ">", "(", "x", ":", "&", "'a", "str", ")", "{", "}"]
        );
    }

    #[test]
    fn escaped_newline_in_string_still_counts_the_line() {
        let (toks, _) = lex("let s = \"a \\\n b\";\nlet t = 1;");
        assert_eq!(toks.last().unwrap().line, 3);
    }

    #[test]
    fn comments_are_captured_with_lines() {
        let (toks, comments) = lex("let a = 1; // lint:allow(D001) because\nlet b = 2;");
        assert_eq!(comments.len(), 1);
        assert_eq!(comments[0].line, 1);
        assert!(comments[0].text.contains("lint:allow"));
        assert_eq!(toks.last().unwrap().line, 2);
    }

    #[test]
    fn raw_identifiers_lex_as_plain_idents() {
        // `r#type` is an escaped keyword, not a raw string prefix: the
        // parser must see the same text as an unescaped ident.
        assert_eq!(texts("let r#type = r#fn(r#match);"), [
            "let", "type", "=", "fn", "(", "match", ")", ";"
        ]);
        let (toks, _) = lex("let r#type = 1;");
        assert_eq!(toks[1].kind, TokKind::Ident);
        // ...while `r#"…"#` right next to it is still a raw string
        assert_eq!(texts(r###"r#type(r#"s"#)"###), ["type", "(", "<rawstr>", ")"]);
    }

    #[test]
    fn nested_turbofish_closers_stay_single_puncts() {
        // `Vec<Vec<u8>>` must yield two separate `>` tokens (no `>>`
        // shift token), or generic-depth tracking in the parser breaks.
        assert_eq!(
            texts("x::<Vec<Vec<u8>>>()"),
            ["x", "::", "<", "Vec", "<", "Vec", "<", "u8", ">", ">", ">", "(", ")"]
        );
        // arrow inside a generic: `>` after `-` is part of `->`
        assert_eq!(
            texts("impl<F: Fn(f64) -> f64> S<F> {}"),
            ["impl", "<", "F", ":", "Fn", "(", "f64", ")", "-", ">", "f64", ">", "S", "<",
             "F", ">", "{", "}"]
        );
    }

    #[test]
    fn crlf_sources_count_lines_by_newline_only() {
        let (toks, comments) = lex("let a = 1;\r\n// c\r\nlet b = 2;\r\n");
        assert_eq!(toks.last().unwrap().line, 3);
        assert_eq!(comments[0].line, 2);
        // the skipped '\r' never merges two lines
        assert_eq!(toks[0].line, 1);
    }

    #[test]
    fn shebang_and_inner_attribute_lines_lex_without_damage() {
        // `#!/usr/bin/env run-cargo-script` style header: `#`, `!`, `/`
        // puncts and path idents — noise, but line-accurate noise.
        let (toks, _) = lex("#!/usr/bin/env x\nfn main() {}\n");
        assert_eq!(toks.iter().find(|t| t.text == "fn").unwrap().line, 2);
        // inner attributes (`#![allow(dead_code)]`) keep their brackets
        assert_eq!(
            texts("#![allow(dead_code)]\nfn f() {}"),
            ["#", "!", "[", "allow", "(", "dead_code", ")", "]", "fn", "f", "(", ")", "{", "}"]
        );
    }
}
