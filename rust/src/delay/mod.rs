//! Training-delay model — paper Section V-A, Eqs. 8–17.
//!
//! Given a [`Scenario`] (workload profile + geometry + links + compute
//! parameters) and an [`Allocation`] (the decision variables
//! r^s, r^f, p^s, p^f, μ, r), computes every phase delay of one local
//! round, `T_local` (Eq. 16) and the total training delay
//! `T = E(r)·(I·T_local + max_k T_k^f)` (Eq. 17).
//!
//! Server-to-client broadcasts and aggregation compute are neglected,
//! exactly as the paper argues (high server transmit power, small
//! payloads, ample server compute).

pub mod convergence;
pub mod energy;
pub mod eval;
pub mod objective;

pub use convergence::ConvergenceModel;
pub use objective::Objective;
pub use eval::{ColumnCache, DelayEvaluator, GridChoice, RateColumns, WorkloadCache};

use crate::model::WorkloadProfile;
use crate::net::{Link, Topology};
use crate::util::stats::fsum;

/// A complete latency scenario (everything that is *not* a decision).
#[derive(Clone, Debug)]
pub struct Scenario {
    pub profile: WorkloadProfile,
    pub topo: Topology,
    pub main_link: Link,
    pub fed_link: Link,
    /// Round-varying environment process parameters (frozen by
    /// default); consumed by [`crate::sim::RoundSimulator`], inert for
    /// every static evaluation path.
    pub dynamics: crate::config::DynamicsConfig,
    /// Optimization-objective / energy-model parameters (pure delay by
    /// default); resolved by policies via
    /// `crate::opt::Objective::from_config`, with `objective.zeta`
    /// feeding every energy evaluation (validated at scenario build).
    pub objective: crate::config::ObjectiveConfig,
    /// GPU cycles per FLOP on clients / main server (κ_k, κ_s).
    pub kappa_client: f64,
    pub kappa_server: f64,
    /// Main-server capability f_s (cycles/s).
    pub f_server: f64,
    /// Mini-batch size b and local steps per global round I.
    pub batch: usize,
    pub local_steps: usize,
    /// Per-client max power and per-server totals (W) — constraints C4/C5.
    pub p_max_w: f64,
    pub p_th_main_w: f64,
    pub p_th_fed_w: f64,
}

/// Decision variables (r^s, r^f, p^s, p^f, μ, r).
///
/// Subchannel assignment is stored per client (the set `M_k`/`N_k` of
/// Sec. VI-B); exclusivity C2 is an invariant checked by
/// [`Allocation::validate`]. The split vector μ is summarized by its
/// prefix length `l_c` (constraint C3 forces μ monotone).
#[derive(Clone, Debug)]
pub struct Allocation {
    /// Subchannel indices of the main-server link owned by each client.
    pub assign_main: Vec<Vec<usize>>,
    /// Subchannel indices of the federated-server link per client.
    pub assign_fed: Vec<Vec<usize>>,
    /// Transmit PSD (W/Hz) per main-link subchannel.
    pub psd_main: Vec<f64>,
    /// Transmit PSD (W/Hz) per fed-link subchannel.
    pub psd_fed: Vec<f64>,
    /// Split point: number of blocks on the client (μ prefix).
    pub l_c: usize,
    /// LoRA rank r.
    pub rank: usize,
}

impl Allocation {
    /// Check structural invariants C1/C2 (each subchannel exactly one
    /// owner) and non-negativity C6.
    pub fn validate(&self, m: usize, n: usize) -> Result<(), String> {
        let mut owner_main = vec![usize::MAX; m];
        for (k, subs) in self.assign_main.iter().enumerate() {
            for &i in subs {
                if i >= m {
                    return Err(format!("main subchannel {i} out of range"));
                }
                if owner_main[i] != usize::MAX {
                    return Err(format!("main subchannel {i} double-assigned"));
                }
                owner_main[i] = k;
            }
        }
        let mut owner_fed = vec![usize::MAX; n];
        for (k, subs) in self.assign_fed.iter().enumerate() {
            for &i in subs {
                if i >= n {
                    return Err(format!("fed subchannel {i} out of range"));
                }
                if owner_fed[i] != usize::MAX {
                    return Err(format!("fed subchannel {i} double-assigned"));
                }
                owner_fed[i] = k;
            }
        }
        if owner_main.iter().any(|&o| o == usize::MAX) {
            return Err("unassigned main subchannel (C2)".into());
        }
        if owner_fed.iter().any(|&o| o == usize::MAX) {
            return Err("unassigned fed subchannel (C2)".into());
        }
        if self.psd_main.iter().chain(&self.psd_fed).any(|&p| p < 0.0) {
            return Err("negative PSD (C6)".into());
        }
        Ok(())
    }
}

/// All per-phase delays of one local round (seconds).
#[derive(Clone, Debug)]
pub struct PhaseDelays {
    /// T_k^F (Eq. 8) per client.
    pub client_fwd: Vec<f64>,
    /// T_k^s (Eq. 10) per client.
    pub act_upload: Vec<f64>,
    /// T_s^F (Eq. 11).
    pub server_fwd: f64,
    /// T_s^B (Eq. 12).
    pub server_bwd: f64,
    /// T_k^B (Eq. 13) per client.
    pub client_bwd: Vec<f64>,
    /// T_k^f (Eq. 15) per client (adapter upload to the federated server).
    pub fed_upload: Vec<f64>,
}

impl PhaseDelays {
    /// T_local (Eq. 16).
    pub fn t_local(&self) -> f64 {
        let stage1 = crate::util::stats::stage_max(
            self.client_fwd.iter().zip(&self.act_upload).map(|(a, b)| a + b),
        );
        let stage3 = crate::util::stats::stage_max(self.client_bwd.iter().copied());
        stage1 + self.server_fwd + self.server_bwd + stage3
    }

    /// max_k T_k^f — the aggregation-phase upload bottleneck.
    pub fn t_fed(&self) -> f64 {
        crate::util::stats::stage_max(self.fed_upload.iter().copied())
    }
}

impl Scenario {
    pub fn k(&self) -> usize {
        self.topo.k()
    }

    /// Uplink rate of client k to the main server under `alloc` (Eq. 9).
    pub fn rate_main(&self, alloc: &Allocation, k: usize) -> f64 {
        fsum(
            alloc.assign_main[k]
                .iter()
                .map(|&i| self.main_link.subch_rate(k, i, alloc.psd_main[i])),
        )
    }

    /// Uplink rate of client k to the federated server (Eq. 14).
    pub fn rate_fed(&self, alloc: &Allocation, k: usize) -> f64 {
        fsum(
            alloc.assign_fed[k]
                .iter()
                .map(|&i| self.fed_link.subch_rate(k, i, alloc.psd_fed[i])),
        )
    }

    /// Total transmit power of client k on the main link (W) — C4 LHS.
    pub fn power_main(&self, alloc: &Allocation, k: usize) -> f64 {
        fsum(
            alloc.assign_main[k]
                .iter()
                .map(|&i| self.main_link.power_w(i, alloc.psd_main[i])),
        )
    }

    pub fn power_fed(&self, alloc: &Allocation, k: usize) -> f64 {
        fsum(
            alloc.assign_fed[k]
                .iter()
                .map(|&i| self.fed_link.power_w(i, alloc.psd_fed[i])),
        )
    }

    /// All phase delays for one local round (Eqs. 8–15).
    pub fn phase_delays(&self, alloc: &Allocation) -> PhaseDelays {
        let k = self.k();
        let b = self.batch as f64;
        let p = &self.profile;
        let (l_c, r) = (alloc.l_c, alloc.rank);

        let mut client_fwd = Vec::with_capacity(k);
        let mut act_upload = Vec::with_capacity(k);
        let mut client_bwd = Vec::with_capacity(k);
        let mut fed_upload = Vec::with_capacity(k);

        for kk in 0..k {
            let f_k = self.topo.clients[kk].f_cycles;
            // Eq. 8
            client_fwd.push(b * self.kappa_client * p.client_fwd_flops(l_c, r) / f_k);
            // Eq. 10
            let rate_s = self.rate_main(alloc, kk);
            act_upload.push(if rate_s > 0.0 {
                b * p.activation_bits(l_c) / rate_s
            } else {
                f64::INFINITY
            });
            // Eq. 13
            client_bwd.push(b * self.kappa_client * p.client_bwd_flops(l_c, r) / f_k);
            // Eq. 15
            let rate_f = self.rate_fed(alloc, kk);
            fed_upload.push(if rate_f > 0.0 {
                p.client_adapter_bits(l_c, r) / rate_f
            } else {
                f64::INFINITY
            });
        }

        // Eqs. 11–12: the server batches all K clients' activations.
        let server_fwd =
            k as f64 * b * self.kappa_server * p.server_fwd_flops(l_c, r) / self.f_server;
        let server_bwd =
            k as f64 * b * self.kappa_server * p.server_bwd_flops(l_c, r) / self.f_server;

        PhaseDelays {
            client_fwd,
            act_upload,
            server_fwd,
            server_bwd,
            client_bwd,
            fed_upload,
        }
    }

    /// T_local (Eq. 16).
    pub fn t_local(&self, alloc: &Allocation) -> f64 {
        self.phase_delays(alloc).t_local()
    }

    /// Total training delay (Eq. 17): `E(r)·(I·T_local + max_k T_k^f)`.
    pub fn total_delay(&self, alloc: &Allocation, conv: &ConvergenceModel) -> f64 {
        let ph = self.phase_delays(alloc);
        conv.rounds(alloc.rank) * (self.local_steps as f64 * ph.t_local() + ph.t_fed())
    }

    /// Feasibility of the power constraints C4/C5 under `alloc`.
    pub fn power_feasible(&self, alloc: &Allocation, tol: f64) -> bool {
        let mut tot_main = 0.0;
        let mut tot_fed = 0.0;
        for k in 0..self.k() {
            let pm = self.power_main(alloc, k);
            let pf = self.power_fed(alloc, k);
            if pm > self.p_max_w * (1.0 + tol) || pf > self.p_max_w * (1.0 + tol) {
                return false;
            }
            tot_main += pm;
            tot_fed += pf;
        }
        tot_main <= self.p_th_main_w * (1.0 + tol) && tot_fed <= self.p_th_fed_w * (1.0 + tol)
    }
}

/// Test fixtures shared across the crate's unit tests.
#[cfg(test)]
pub mod testutil {
    use super::*;
    use crate::model::{Gpt2Config, WorkloadProfile};
    use crate::net::topology::ClientSite;
    use crate::net::{ChannelModel, SubchannelSet, Topology};

    /// Small handcrafted scenario: 2 clients, 4+2 subchannels.
    pub fn toy_scenario() -> Scenario {
        let profile = WorkloadProfile::new(Gpt2Config::gpt2_s(), 128);
        let topo = Topology {
            clients: vec![
                ClientSite { d_main_m: 100.0, d_fed_m: 10.0, f_cycles: 1.0e9 },
                ClientSite { d_main_m: 110.0, d_fed_m: 15.0, f_cycles: 1.5e9 },
            ],
        };
        let ch = ChannelModel::new(0.0);
        let main_link = Link {
            subch: SubchannelSet::equal_split(500e3, 4),
            gain_product: 160.0,
            noise_psd: 3.98e-21,
            client_gain: topo.clients.iter().map(|c| ch.gain_deterministic(c.d_main_m)).collect(),
        };
        let fed_link = Link {
            subch: SubchannelSet::equal_split(500e3, 2),
            gain_product: 80.0,
            noise_psd: 3.98e-21,
            client_gain: topo.clients.iter().map(|c| ch.gain_deterministic(c.d_fed_m)).collect(),
        };
        Scenario {
            profile,
            topo,
            main_link,
            fed_link,
            dynamics: crate::config::DynamicsConfig::default(),
            objective: crate::config::ObjectiveConfig::default(),
            kappa_client: 1.0 / 1024.0,
            kappa_server: 1.0 / 32768.0,
            f_server: 5.0e9,
            batch: 4,
            local_steps: 3,
            p_max_w: 15.0,
            p_th_main_w: 50.0,
            p_th_fed_w: 50.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::testutil::toy_scenario;
    use super::*;

    fn toy_alloc() -> Allocation {
        Allocation {
            assign_main: vec![vec![0, 1], vec![2, 3]],
            assign_fed: vec![vec![0], vec![1]],
            psd_main: vec![1e-4; 4],
            psd_fed: vec![1e-4; 2],
            l_c: 3,
            rank: 4,
        }
    }

    #[test]
    fn validate_catches_violations() {
        let a = toy_alloc();
        assert!(a.validate(4, 2).is_ok());
        let mut dup = a.clone();
        dup.assign_main[1][0] = 0; // double assignment
        assert!(dup.validate(4, 2).is_err());
        let mut neg = a.clone();
        neg.psd_fed[0] = -1.0;
        assert!(neg.validate(4, 2).is_err());
        let mut missing = a;
        missing.assign_fed[1].clear();
        assert!(missing.validate(4, 2).is_err());
    }

    #[test]
    fn eq8_hand_check() {
        // T_k^F = b*κ*(Φ+ΔΦ)/f for client 0
        let s = toy_scenario();
        let a = toy_alloc();
        let ph = s.phase_delays(&a);
        let flops = s.profile.client_fwd_flops(3, 4);
        let expect = 4.0 * (1.0 / 1024.0) * flops / 1.0e9;
        assert!((ph.client_fwd[0] - expect).abs() / expect < 1e-12);
    }

    #[test]
    fn eq10_hand_check() {
        let s = toy_scenario();
        let a = toy_alloc();
        let ph = s.phase_delays(&a);
        let rate: f64 = (0..2).map(|i| s.main_link.subch_rate(0, i, 1e-4)).sum();
        let expect = 4.0 * s.profile.activation_bits(3) / rate;
        assert!((ph.act_upload[0] - expect).abs() / expect < 1e-12);
    }

    #[test]
    fn t_local_composition() {
        let s = toy_scenario();
        let a = toy_alloc();
        let ph = s.phase_delays(&a);
        let stage1 = (ph.client_fwd[0] + ph.act_upload[0])
            .max(ph.client_fwd[1] + ph.act_upload[1]);
        let expect = stage1 + ph.server_fwd + ph.server_bwd
            + ph.client_bwd[0].max(ph.client_bwd[1]);
        assert!((ph.t_local() - expect).abs() < 1e-12);
    }

    #[test]
    fn total_delay_uses_convergence_model() {
        let s = toy_scenario();
        let a = toy_alloc();
        let conv = ConvergenceModel::fitted(10.0, 1.0, 1.0);
        let ph = s.phase_delays(&a);
        let expect = conv.rounds(4) * (3.0 * ph.t_local() + ph.t_fed());
        assert!((s.total_delay(&a, &conv) - expect).abs() < 1e-9);
    }

    #[test]
    fn more_power_less_delay() {
        let s = toy_scenario();
        let a = toy_alloc();
        let mut a2 = a.clone();
        a2.psd_main.iter_mut().for_each(|p| *p *= 4.0);
        assert!(s.phase_delays(&a2).act_upload[0] < s.phase_delays(&a).act_upload[0]);
    }

    #[test]
    fn larger_split_moves_work_to_client() {
        let s = toy_scenario();
        let a = toy_alloc();
        let mut deeper = a.clone();
        deeper.l_c = 9;
        let (p1, p2) = (s.phase_delays(&a), s.phase_delays(&deeper));
        assert!(p2.client_fwd[0] > p1.client_fwd[0]);
        assert!(p2.server_fwd < p1.server_fwd);
    }

    #[test]
    fn power_feasibility() {
        let s = toy_scenario();
        let mut a = toy_alloc();
        // 5e-5 W/Hz: 6.25 W per 125 kHz main subchannel (12.5 W/client),
        // 12.5 W per 250 kHz fed subchannel — all within C4/C5.
        a.psd_main.iter_mut().for_each(|p| *p = 5e-5);
        a.psd_fed.iter_mut().for_each(|p| *p = 5e-5);
        assert!(s.power_feasible(&a, 1e-9));
        let mut hot = a;
        // 1 W/Hz over 125 kHz = 125 kW >> caps
        hot.psd_main.iter_mut().for_each(|p| *p = 1.0);
        assert!(!s.power_feasible(&hot, 1e-9));
    }
}
