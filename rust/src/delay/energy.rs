//! Energy model — the paper's stated future-work extension
//! ("exploring an energy-efficient SflLLM framework"), built on the
//! same Section-V quantities and promoted to a first-class objective
//! by `opt::Objective`.
//!
//! Per **local** round, client k spends:
//!
//! * compute energy `E_cmp = zeta_k * f_k^2 * C_k` — the standard
//!   CMOS dynamic-power model (energy per cycle ∝ f², as in the
//!   paper's reference [28]'s formulation), with `C_k` the cycles for
//!   its forward+backward work;
//! * transmit energy `E_tx = P_k * T_k` on each uplink — transmit
//!   power times airtime, both already produced by the delay model.
//!
//! # The amortization contract
//!
//! [`round_energy`] is a **per-local-round** ledger: the adapter upload
//! to the federated server happens once per *global* round (I local
//! rounds), so its energy enters the ledger divided by `I`
//! (`Scenario::local_steps`). [`total_energy`] is then
//! `E(r) · (I · E_round)` — the exact energy analogue of Eq. 17's
//! `E(r)·(I·T_local + T_fed)` — which restores the federated upload to
//! once per global round. `local_steps ≥ 1` is validated at scenario
//! build ([`crate::sim::ScenarioBuilder::build`]); these functions
//! assert it rather than papering over `I = 0` with a `max(1)` that
//! silently zeroed the total.
//!
//! # Infeasibility is explicit
//!
//! A client with a zero uplink rate has an *infinite* airtime; its
//! transmit energy is reported as `+∞` via [`tx_energy`] — mirroring
//! the delay model's explicit-infinity handling — and never as the
//! silent NaN of `0·∞` (a starved client also has zero transmit
//! power). No energy path can emit NaN.
//!
//! Consumers: `DelayEvaluator::eval_energy` (bit-identical cached
//! path, property-tested in `rust/tests/prop_eval.rs`), the
//! objective-aware P3×P4 scans, `sim::RoundSimulator`'s realized-energy
//! accounting, and the `examples/rank_sweep.rs` /
//! `examples/energy_tradeoff.rs` studies.

use super::{Allocation, PhaseDelays, Scenario};
use crate::util::stats::fsum;

/// Effective switched-capacitance coefficient (J·s²/cycle³ scale).
/// Typical edge-device magnitude; configurable per study via
/// `config::ObjectiveConfig::zeta` (→ `Scenario::objective.zeta`).
/// Declared in [`crate::config`] (the default belongs to the config
/// layer, which sits below `delay` in the architecture contract) and
/// re-exported here next to the model that consumes it.
pub use crate::config::DEFAULT_ZETA;

/// Transmit energy `P·T` with explicit infeasibility: an infinite
/// airtime (starved uplink) costs infinite energy even at zero
/// transmit power — never the silent NaN of `0·∞`.
pub fn tx_energy(power_w: f64, airtime_s: f64) -> f64 {
    if airtime_s.is_finite() {
        power_w * airtime_s
    } else {
        f64::INFINITY
    }
}

/// Energy ledger for one local round (Joules).
#[derive(Clone, Debug, Default)]
pub struct RoundEnergy {
    /// Per-client compute energy (FP + BP).
    pub client_compute: Vec<f64>,
    /// Per-client activation-upload transmit energy.
    pub act_upload: Vec<f64>,
    /// Per-client federated-upload transmit energy, amortized per local
    /// round: the adapter upload happens once every I local rounds, so
    /// each ledger entry carries 1/I of it (see the module docs).
    pub fed_upload: Vec<f64>,
}

impl RoundEnergy {
    /// Total energy across clients for one local round.
    pub fn total(&self) -> f64 {
        fsum(self.client_compute.iter().copied())
            + fsum(self.act_upload.iter().copied())
            + fsum(self.fed_upload.iter().copied())
    }

    /// Per-client totals.
    pub fn per_client(&self) -> Vec<f64> {
        (0..self.client_compute.len())
            .map(|k| self.client_compute[k] + self.act_upload[k] + self.fed_upload[k])
            .collect()
    }
}

/// Compute the per-local-round energy ledger for an allocation.
///
/// Requires `scn.local_steps >= 1` (the scenario-build invariant; see
/// the module docs for the amortization contract).
pub fn round_energy(scn: &Scenario, alloc: &Allocation, zeta: f64) -> RoundEnergy {
    let ph = scn.phase_delays(alloc);
    round_energy_with_phases(scn, alloc, zeta, &ph)
}

/// [`round_energy`] with the phase delays already in hand, so callers
/// that need both totals (e.g. `opt::objective::score_alloc`) pay for
/// one `Scenario::phase_delays` pass instead of two.
pub fn round_energy_with_phases(
    scn: &Scenario,
    alloc: &Allocation,
    zeta: f64,
    ph: &PhaseDelays,
) -> RoundEnergy {
    assert!(
        scn.local_steps >= 1,
        "local_steps must be >= 1 (validated at scenario build)"
    );
    let b = scn.batch as f64;
    let steps = scn.local_steps as f64;
    let mut out = RoundEnergy::default();
    for k in 0..scn.k() {
        let f_k = scn.topo.clients[k].f_cycles;
        // cycles for this round's client work
        let flops = b
            * (scn.profile.client_fwd_flops(alloc.l_c, alloc.rank)
                + scn.profile.client_bwd_flops(alloc.l_c, alloc.rank));
        let cycles = scn.kappa_client * flops;
        out.client_compute.push(zeta * f_k * f_k * cycles);
        // transmit energy = power * airtime, infinity-explicit
        out.act_upload
            .push(tx_energy(scn.power_main(alloc, k), ph.act_upload[k]));
        out.fed_upload
            .push(tx_energy(scn.power_fed(alloc, k), ph.fed_upload[k]) / steps);
    }
    out
}

/// Total training energy `E(r) · (I · E_round)` — the energy analogue
/// of Eq. 17, with exactly this association so the dynamic engine's
/// run-length-compressed realized-energy accumulation reproduces it
/// bit for bit on frozen runs (`rust/tests/prop_dynamic.rs`).
pub fn total_energy(
    scn: &Scenario,
    alloc: &Allocation,
    conv: &super::ConvergenceModel,
    zeta: f64,
) -> f64 {
    let ph = scn.phase_delays(alloc);
    total_energy_with_phases(scn, alloc, conv, zeta, &ph)
}

/// [`total_energy`] with the phase delays already in hand (same bits —
/// `round_energy` consumes the phases verbatim).
pub fn total_energy_with_phases(
    scn: &Scenario,
    alloc: &Allocation,
    conv: &super::ConvergenceModel,
    zeta: f64,
    ph: &PhaseDelays,
) -> f64 {
    let per_round = round_energy_with_phases(scn, alloc, zeta, ph).total();
    conv.rounds(alloc.rank) * (scn.local_steps as f64 * per_round)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delay::testutil::toy_scenario;
    use crate::delay::ConvergenceModel;

    fn alloc() -> Allocation {
        Allocation {
            assign_main: vec![vec![0, 1], vec![2, 3]],
            assign_fed: vec![vec![0], vec![1]],
            psd_main: vec![5e-5; 4],
            psd_fed: vec![5e-5; 2],
            l_c: 3,
            rank: 4,
        }
    }

    #[test]
    fn energy_components_positive_and_sum() {
        let scn = toy_scenario();
        let e = round_energy(&scn, &alloc(), DEFAULT_ZETA);
        assert_eq!(e.client_compute.len(), 2);
        assert!(e.client_compute.iter().all(|&v| v > 0.0));
        assert!(e.act_upload.iter().all(|&v| v > 0.0));
        let total = e.total();
        let sum: f64 = e.per_client().iter().sum();
        assert!((total - sum).abs() < 1e-9 * total);
    }

    #[test]
    fn deeper_split_costs_more_client_energy() {
        let scn = toy_scenario();
        let mut deep = alloc();
        deep.l_c = 9;
        let e1 = round_energy(&scn, &alloc(), DEFAULT_ZETA);
        let e2 = round_energy(&scn, &deep, DEFAULT_ZETA);
        assert!(e2.client_compute[0] > e1.client_compute[0]);
    }

    #[test]
    fn higher_rank_costs_more_energy() {
        let scn = toy_scenario();
        let mut hi = alloc();
        hi.rank = 8;
        let mut lo = alloc();
        lo.rank = 1;
        let e_hi = round_energy(&scn, &hi, DEFAULT_ZETA);
        let e_lo = round_energy(&scn, &lo, DEFAULT_ZETA);
        assert!(e_hi.client_compute[0] > e_lo.client_compute[0]);
        assert!(e_hi.fed_upload[0] >= e_lo.fed_upload[0]);
    }

    #[test]
    fn total_energy_scales_with_rounds() {
        let scn = toy_scenario();
        let a = alloc();
        let e1 = total_energy(&scn, &a, &ConvergenceModel::fitted(10.0, 0.0, 1.0), DEFAULT_ZETA);
        let e2 = total_energy(&scn, &a, &ConvergenceModel::fitted(20.0, 0.0, 1.0), DEFAULT_ZETA);
        assert!((e2 - 2.0 * e1).abs() < 1e-9 * e1);
    }

    #[test]
    fn starved_client_energy_is_infinite_never_nan() {
        // a zero-rate client used to make 0·∞ = NaN propagate silently
        // through total(); infeasibility must be an explicit infinity
        let scn = toy_scenario();
        let mut starved = alloc();
        starved.assign_fed[1].clear(); // client 1: no fed subchannels
        let e = round_energy(&scn, &starved, DEFAULT_ZETA);
        assert!(e.fed_upload[1].is_infinite());
        assert!(!e.fed_upload[1].is_nan());
        let total = e.total();
        assert!(total.is_infinite() && !total.is_nan());
        let t = total_energy(&scn, &starved, &ConvergenceModel::paper_default(), DEFAULT_ZETA);
        assert!(t.is_infinite() && !t.is_nan());
        // same for the main link
        let mut starved_main = alloc();
        starved_main.assign_main[0].clear();
        let e2 = round_energy(&scn, &starved_main, DEFAULT_ZETA);
        assert!(e2.act_upload[0].is_infinite() && !e2.act_upload[0].is_nan());
    }

    #[test]
    fn fed_energy_is_amortized_over_local_steps_consistently() {
        // the ledger carries 1/I of the adapter upload; the total must
        // restore it to exactly once per global round: I rounds of the
        // ledger sum to (I·compute + I·act + fed_once) per global round
        let scn = toy_scenario(); // I = 3
        let a = alloc();
        let e = round_energy(&scn, &a, DEFAULT_ZETA);
        let fed_once: f64 = (0..scn.k())
            .map(|k| {
                let ph = scn.phase_delays(&a);
                tx_energy(scn.power_fed(&a, k), ph.fed_upload[k])
            })
            .sum();
        let ledger_fed: f64 = e.fed_upload.iter().sum();
        assert!(
            (scn.local_steps as f64 * ledger_fed - fed_once).abs() <= 1e-12 * fed_once,
            "I x amortized fed energy {ledger_fed} must equal the one-shot upload {fed_once}"
        );
        // and the global-round structure of total_energy matches
        let conv = ConvergenceModel::fitted(10.0, 0.0, 1.0); // E(r) = 10
        let want = 10.0 * (scn.local_steps as f64 * e.total());
        let got = total_energy(&scn, &a, &conv, DEFAULT_ZETA);
        assert_eq!(got.to_bits(), want.to_bits());
    }

    #[test]
    #[should_panic(expected = "local_steps")]
    fn zero_local_steps_is_rejected_loudly() {
        // a hand-built scenario with I = 0 used to yield *zero* total
        // energy (the `.max(1)` papering); now it fails fast
        let mut scn = toy_scenario();
        scn.local_steps = 0;
        let _ = round_energy(&scn, &alloc(), DEFAULT_ZETA);
    }

    #[test]
    fn more_transmit_power_can_cost_energy_despite_less_delay() {
        // airtime falls ~log with power while power rises linearly: at
        // high SNR more PSD costs net energy — the trade-off the
        // energy extension exists to expose.
        let scn = toy_scenario();
        let a = alloc();
        let mut hot = a.clone();
        hot.psd_main.iter_mut().for_each(|p| *p *= 8.0);
        let e_cool = round_energy(&scn, &a, DEFAULT_ZETA);
        let e_hot = round_energy(&scn, &hot, DEFAULT_ZETA);
        assert!(
            e_hot.act_upload[0] > e_cool.act_upload[0],
            "8x PSD at ~30 bit/s/Hz should cost net transmit energy"
        );
    }
}
