//! Energy model — the paper's stated future-work extension
//! ("exploring an energy-efficient SflLLM framework"), built on the
//! same Section-V quantities.
//!
//! Per local round, client k spends:
//!
//! * compute energy `E_cmp = zeta_k * f_k^2 * C_k` — the standard
//!   CMOS dynamic-power model (energy per cycle ∝ f², as in the
//!   paper's reference [28]'s formulation), with `C_k` the cycles for
//!   its forward+backward work;
//! * transmit energy `E_tx = P_k * T_k` on each uplink — transmit
//!   power times airtime, both already produced by the delay model.
//!
//! This enables the energy/delay trade-off study in
//! `examples/rank_sweep.rs` (energy column) and the ablation test in
//! `rust/tests/integration_optimizer.rs`.

use super::{Allocation, PhaseDelays, Scenario};

/// Effective switched-capacitance coefficient (J·s²/cycle³ scale).
/// Typical edge-device magnitude; configurable per study.
pub const DEFAULT_ZETA: f64 = 1e-28;

/// Energy ledger for one local round (Joules).
#[derive(Clone, Debug, Default)]
pub struct RoundEnergy {
    /// Per-client compute energy (FP + BP).
    pub client_compute: Vec<f64>,
    /// Per-client activation-upload transmit energy.
    pub act_upload: Vec<f64>,
    /// Per-client federated-upload transmit energy (amortized per round:
    /// the adapter upload happens once every I rounds).
    pub fed_upload: Vec<f64>,
}

impl RoundEnergy {
    /// Total energy across clients for one local round.
    pub fn total(&self) -> f64 {
        self.client_compute.iter().sum::<f64>()
            + self.act_upload.iter().sum::<f64>()
            + self.fed_upload.iter().sum::<f64>()
    }

    /// Per-client totals.
    pub fn per_client(&self) -> Vec<f64> {
        (0..self.client_compute.len())
            .map(|k| self.client_compute[k] + self.act_upload[k] + self.fed_upload[k])
            .collect()
    }
}

/// Compute the per-round energy ledger for an allocation.
pub fn round_energy(scn: &Scenario, alloc: &Allocation, zeta: f64) -> RoundEnergy {
    let ph: PhaseDelays = scn.phase_delays(alloc);
    let b = scn.batch as f64;
    let mut out = RoundEnergy::default();
    for k in 0..scn.k() {
        let f_k = scn.topo.clients[k].f_cycles;
        // cycles for this round's client work
        let flops = b
            * (scn.profile.client_fwd_flops(alloc.l_c, alloc.rank)
                + scn.profile.client_bwd_flops(alloc.l_c, alloc.rank));
        let cycles = scn.kappa_client * flops;
        out.client_compute.push(zeta * f_k * f_k * cycles);
        // transmit energy = power * airtime
        out.act_upload.push(scn.power_main(alloc, k) * ph.act_upload[k]);
        out.fed_upload
            .push(scn.power_fed(alloc, k) * ph.fed_upload[k] / scn.local_steps.max(1) as f64);
    }
    out
}

/// Total training energy: per-round energy × rounds (Eq. 17 structure).
pub fn total_energy(
    scn: &Scenario,
    alloc: &Allocation,
    conv: &super::ConvergenceModel,
    zeta: f64,
) -> f64 {
    let per_round = round_energy(scn, alloc, zeta).total();
    conv.rounds(alloc.rank) * scn.local_steps as f64 * per_round
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delay::testutil::toy_scenario;
    use crate::delay::ConvergenceModel;

    fn alloc() -> Allocation {
        Allocation {
            assign_main: vec![vec![0, 1], vec![2, 3]],
            assign_fed: vec![vec![0], vec![1]],
            psd_main: vec![5e-5; 4],
            psd_fed: vec![5e-5; 2],
            l_c: 3,
            rank: 4,
        }
    }

    #[test]
    fn energy_components_positive_and_sum() {
        let scn = toy_scenario();
        let e = round_energy(&scn, &alloc(), DEFAULT_ZETA);
        assert_eq!(e.client_compute.len(), 2);
        assert!(e.client_compute.iter().all(|&v| v > 0.0));
        assert!(e.act_upload.iter().all(|&v| v > 0.0));
        let total = e.total();
        let sum: f64 = e.per_client().iter().sum();
        assert!((total - sum).abs() < 1e-9 * total);
    }

    #[test]
    fn deeper_split_costs_more_client_energy() {
        let scn = toy_scenario();
        let mut deep = alloc();
        deep.l_c = 9;
        let e1 = round_energy(&scn, &alloc(), DEFAULT_ZETA);
        let e2 = round_energy(&scn, &deep, DEFAULT_ZETA);
        assert!(e2.client_compute[0] > e1.client_compute[0]);
    }

    #[test]
    fn higher_rank_costs_more_energy() {
        let scn = toy_scenario();
        let mut hi = alloc();
        hi.rank = 8;
        let mut lo = alloc();
        lo.rank = 1;
        let e_hi = round_energy(&scn, &hi, DEFAULT_ZETA);
        let e_lo = round_energy(&scn, &lo, DEFAULT_ZETA);
        assert!(e_hi.client_compute[0] > e_lo.client_compute[0]);
        assert!(e_hi.fed_upload[0] >= e_lo.fed_upload[0]);
    }

    #[test]
    fn total_energy_scales_with_rounds() {
        let scn = toy_scenario();
        let a = alloc();
        let e1 = total_energy(&scn, &a, &ConvergenceModel::fitted(10.0, 0.0, 1.0), DEFAULT_ZETA);
        let e2 = total_energy(&scn, &a, &ConvergenceModel::fitted(20.0, 0.0, 1.0), DEFAULT_ZETA);
        assert!((e2 - 2.0 * e1).abs() < 1e-9 * e1);
    }

    #[test]
    fn more_transmit_power_can_cost_energy_despite_less_delay() {
        // airtime falls ~log with power while power rises linearly: at
        // high SNR more PSD costs net energy — the trade-off the
        // energy extension exists to expose.
        let scn = toy_scenario();
        let a = alloc();
        let mut hot = a.clone();
        hot.psd_main.iter_mut().for_each(|p| *p *= 8.0);
        let e_cool = round_energy(&scn, &a, DEFAULT_ZETA);
        let e_hot = round_energy(&scn, &hot, DEFAULT_ZETA);
        assert!(
            e_hot.act_upload[0] > e_cool.act_upload[0],
            "8x PSD at ~30 bit/s/Hz should cost net transmit energy"
        );
    }
}
