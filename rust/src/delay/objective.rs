//! Optimization objectives over the (delay, energy) pair — the energy
//! extension the paper names as future work ("exploring an
//! energy-efficient SflLLM framework"), promoted to a first-class axis
//! of the Section-VI optimizer.
//!
//! Every objective is a scalarization of the two Section-V totals:
//! total training delay `T` (Eq. 17) and total training energy `E`
//! (`delay::energy::total_energy`, same `E(r)·(I·…)` structure):
//!
//! * [`Objective::Delay`] — the paper's problem P: minimize `T`;
//! * [`Objective::Energy`] — minimize `E`;
//! * [`Objective::Weighted`] — minimize `T + λ·E` (λ in s/J; λ = 0 is
//!   **exactly** the delay objective, bit for bit);
//! * [`Objective::EnergyBudget`] — minimize `T` subject to
//!   `E ≤ budget`; over-budget candidates score `+∞`, so an exhausted
//!   budget surfaces as an explicit infeasibility error rather than a
//!   silently wrong allocation.
//!
//! The scoring contract is shared by every consumer — the BCD
//! acceptance steps (P1/P2), the joint P3×P4 grid scan
//! ([`crate::delay::DelayEvaluator::best_split_rank_obj`]), the
//! baselines, and the dynamic engine's re-opt adoption — so "optimal
//! under objective O" means the same thing on every path.

use anyhow::{anyhow, bail, Result};

use crate::config::ObjectiveConfig;
use crate::delay::{Allocation, ConvergenceModel, Scenario};

/// A scalarization of (total delay T, total energy E). See the module
/// docs for the catalogue.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Objective {
    /// Minimize T (the paper's problem P).
    Delay,
    /// Minimize E.
    Energy,
    /// Minimize `T + lambda·E` (lambda in seconds per joule).
    Weighted { lambda: f64 },
    /// Minimize T subject to `E ≤ joules`.
    EnergyBudget { joules: f64 },
}

impl Objective {
    /// Parse a CLI/config spec: `delay`, `energy`, `weighted:<lambda>`,
    /// `budget:<joules>`. Bare `weighted` / `budget` are only valid
    /// through [`Objective::from_config`], which supplies the parameter
    /// from the config's `lambda` / `budget_j` fields.
    pub fn parse(spec: &str) -> Result<Objective> {
        Objective::parse_with(spec, None, None)
    }

    /// Resolve a config section: the `kind` spec, with bare `weighted` /
    /// `budget` taking their parameter from the sibling fields.
    pub fn from_config(cfg: &ObjectiveConfig) -> Result<Objective> {
        Objective::parse_with(&cfg.kind, Some(cfg.lambda), Some(cfg.budget_j))
    }

    fn parse_with(
        spec: &str,
        default_lambda: Option<f64>,
        default_budget: Option<f64>,
    ) -> Result<Objective> {
        let spec = spec.trim();
        let (head, arg) = match spec.split_once(':') {
            Some((h, a)) => (h.trim(), Some(a.trim())),
            None => (spec, None),
        };
        let num = |what: &str, arg: Option<&str>, default: Option<f64>| -> Result<f64> {
            match arg {
                Some(a) => a
                    .parse::<f64>()
                    .map_err(|e| anyhow!("bad {what} '{a}': {e}")),
                None => default.ok_or_else(|| {
                    anyhow!("objective '{spec}' needs an inline parameter (e.g. '{spec}:0.05')")
                }),
            }
        };
        Ok(match head {
            "delay" if arg.is_none() => Objective::Delay,
            "energy" if arg.is_none() => Objective::Energy,
            "weighted" => {
                let lambda = num("weighted lambda", arg, default_lambda)?;
                if !lambda.is_finite() || lambda < 0.0 {
                    bail!("weighted objective lambda must be finite and >= 0, got {lambda}");
                }
                Objective::Weighted { lambda }
            }
            "budget" | "energy_budget" => {
                let joules = num("energy budget", arg, default_budget)?;
                if joules.is_nan() || joules <= 0.0 {
                    bail!("energy budget must be > 0 joules (or inf), got {joules}");
                }
                Objective::EnergyBudget { joules }
            }
            _ => bail!(
                "unknown objective '{spec}' \
                 (available: delay, energy, weighted:<lambda>, budget:<joules>)"
            ),
        })
    }

    /// A spec string [`Objective::parse`] round-trips.
    pub fn label(&self) -> String {
        match self {
            Objective::Delay => "delay".to_string(),
            Objective::Energy => "energy".to_string(),
            Objective::Weighted { lambda } => format!("weighted:{lambda}"),
            Objective::EnergyBudget { joules } => format!("budget:{joules}"),
        }
    }

    /// Whether [`Objective::score`] reads its `energy` argument. When
    /// this is `false` callers may pass any placeholder (0.0) — the
    /// delay objective, λ = 0, and an infinite budget never consume
    /// energy, which is what keeps those paths bit-identical to the
    /// pure-delay scans.
    pub fn needs_energy(&self) -> bool {
        match self {
            Objective::Delay => false,
            Objective::Energy => true,
            Objective::Weighted { lambda } => *lambda != 0.0,
            Objective::EnergyBudget { joules } => joules.is_finite(),
        }
    }

    /// The scalar this objective minimizes, given the candidate's total
    /// delay (s) and total energy (J). Infinite inputs propagate as
    /// infinite scores (explicit infeasibility); no combination can
    /// produce NaN — the λ = 0 and infinite-budget branches return the
    /// delay untouched instead of evaluating `0·∞`.
    pub fn score(&self, delay: f64, energy: f64) -> f64 {
        match self {
            Objective::Delay => delay,
            Objective::Energy => energy,
            Objective::Weighted { lambda } => {
                if *lambda == 0.0 {
                    delay
                } else {
                    delay + lambda * energy
                }
            }
            Objective::EnergyBudget { joules } => {
                if joules.is_infinite() || energy <= *joules {
                    delay
                } else {
                    f64::INFINITY
                }
            }
        }
    }
}

/// Score one concrete allocation under `obj`: Eq. 17's total delay,
/// plus the energy total at the scenario's ζ when the objective
/// consumes it. This is the uncached counterpart of the evaluator's
/// grid scans, used by the BCD P1/P2 acceptance steps and the
/// baselines' final scoring; under [`Objective::Delay`] it is exactly
/// `Scenario::total_delay` (same bits).
pub fn score_alloc(
    scn: &Scenario,
    alloc: &Allocation,
    conv: &ConvergenceModel,
    obj: &Objective,
) -> f64 {
    if !obj.needs_energy() {
        return obj.score(scn.total_delay(alloc, conv), 0.0);
    }
    // both totals from one phase-delay pass; the delay expression
    // replicates `Scenario::total_delay` operation for operation (same
    // bits), so energy-aware scoring costs one evaluation, not two
    let ph = scn.phase_delays(alloc);
    let delay = conv.rounds(alloc.rank) * (scn.local_steps as f64 * ph.t_local() + ph.t_fed());
    let energy =
        crate::delay::energy::total_energy_with_phases(scn, alloc, conv, scn.objective.zeta, &ph);
    obj.score(delay, energy)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn specs_round_trip_and_reject_garbage() {
        for spec in ["delay", "energy", "weighted:0.25", "budget:5000"] {
            let o = Objective::parse(spec).unwrap();
            assert_eq!(o.label(), spec);
            assert_eq!(Objective::parse(&o.label()).unwrap(), o);
        }
        assert_eq!(
            Objective::parse(" weighted: 0.5 ").unwrap(),
            Objective::Weighted { lambda: 0.5 }
        );
        assert_eq!(
            Objective::parse("energy_budget:10").unwrap(),
            Objective::EnergyBudget { joules: 10.0 }
        );
        for bad in [
            "nope",
            "weighted",   // bare spec without config defaults
            "weighted:-1",
            "weighted:nan",
            "budget",
            "budget:0",
            "budget:-5",
            "delay:2",
            "energy:1",
        ] {
            assert!(Objective::parse(bad).is_err(), "{bad} should fail");
        }
    }

    #[test]
    fn parse_errors_name_the_valid_specs() {
        // descriptive errors: a typo'd spec tells the user what exists,
        // a bad parameter echoes the offending value
        let unknown = Objective::parse("latency").unwrap_err().to_string();
        assert!(unknown.contains("latency") && unknown.contains("delay"), "{unknown}");
        assert!(unknown.contains("weighted:<lambda>"), "{unknown}");
        let bare = Objective::parse("weighted").unwrap_err().to_string();
        assert!(bare.contains("weighted:0.05"), "{bare}");
        let bad_num = Objective::parse("budget:lots").unwrap_err().to_string();
        assert!(bad_num.contains("lots"), "{bad_num}");
        let neg = Objective::parse("weighted:-2").unwrap_err().to_string();
        assert!(neg.contains(">= 0") && neg.contains("-2"), "{neg}");
    }

    #[test]
    fn from_config_supplies_bare_parameters() {
        let mut cfg = ObjectiveConfig::default();
        assert_eq!(Objective::from_config(&cfg).unwrap(), Objective::Delay);
        cfg.kind = "weighted".to_string();
        cfg.lambda = 0.1;
        assert_eq!(
            Objective::from_config(&cfg).unwrap(),
            Objective::Weighted { lambda: 0.1 }
        );
        cfg.kind = "weighted:0.7".to_string();
        // inline parameter beats the field
        assert_eq!(
            Objective::from_config(&cfg).unwrap(),
            Objective::Weighted { lambda: 0.7 }
        );
        cfg.kind = "budget".to_string();
        cfg.budget_j = 123.0;
        assert_eq!(
            Objective::from_config(&cfg).unwrap(),
            Objective::EnergyBudget { joules: 123.0 }
        );
        cfg.lambda = -3.0;
        cfg.kind = "weighted".to_string();
        assert!(Objective::from_config(&cfg).is_err(), "negative lambda");
    }

    #[test]
    fn score_semantics_and_no_nan() {
        let d = 100.0;
        let e = 3000.0;
        assert_eq!(Objective::Delay.score(d, e), d);
        assert_eq!(Objective::Energy.score(d, e), e);
        assert_eq!(Objective::Weighted { lambda: 0.01 }.score(d, e), d + 0.01 * e);
        // lambda = 0 returns the delay bits untouched, even against an
        // infinite energy (the 0*inf = NaN trap)
        let w0 = Objective::Weighted { lambda: 0.0 };
        assert_eq!(w0.score(d, f64::INFINITY).to_bits(), d.to_bits());
        assert!(!w0.needs_energy());
        // budget: pass-through under budget, +inf over it, and an
        // infinite budget never consumes energy
        let b = Objective::EnergyBudget { joules: 5000.0 };
        assert_eq!(b.score(d, e), d);
        assert!(b.score(d, 6000.0).is_infinite());
        assert!(b.needs_energy());
        let b_inf = Objective::EnergyBudget { joules: f64::INFINITY };
        assert!(!b_inf.needs_energy());
        assert_eq!(b_inf.score(d, f64::INFINITY).to_bits(), d.to_bits());
        // infinite inputs propagate as infinity, never NaN
        for obj in [
            Objective::Delay,
            Objective::Energy,
            Objective::Weighted { lambda: 0.5 },
            Objective::EnergyBudget { joules: 5000.0 },
        ] {
            assert!(!obj.score(f64::INFINITY, f64::INFINITY).is_nan(), "{obj:?}");
        }
    }
}
