//! E(r): global rounds to reach the target loss as a function of the
//! LoRA rank (paper Fig. 4, consumed by Eq. 17 and subproblem P4).
//!
//! The paper estimates E(r) "offline through pretraining on a
//! representative dataset". We support both forms:
//!
//! * [`ConvergenceModel::Table`] — measured (rank, rounds) points from
//!   the Fig. 3/4 runs (`cargo bench --bench fig4_steps_to_target`
//!   writes them), interpolated monotonically;
//! * [`ConvergenceModel::Fitted`] — the parametric law
//!   `E(r) = e_inf * (1 + c / r^alpha)`, least-squares fitted to the
//!   measurements. Higher rank → fewer rounds with diminishing returns,
//!   exactly the shape Fig. 4 reports.

/// Rounds-to-target model.
#[derive(Clone, Debug)]
pub enum ConvergenceModel {
    /// Measured (rank, rounds) points; piecewise-linear in 1/r between
    /// points, clamped outside.
    Table(Vec<(usize, f64)>),
    /// E(r) = e_inf * (1 + c / r^alpha).
    Fitted { e_inf: f64, c: f64, alpha: f64 },
}

impl ConvergenceModel {
    pub fn fitted(e_inf: f64, c: f64, alpha: f64) -> ConvergenceModel {
        ConvergenceModel::Fitted { e_inf, c, alpha }
    }

    /// Sorted, deduplicated measurement table.
    pub fn table(mut points: Vec<(usize, f64)>) -> ConvergenceModel {
        points.sort_by_key(|&(r, _)| r);
        points.dedup_by_key(|&mut (r, _)| r);
        assert!(!points.is_empty(), "empty convergence table");
        ConvergenceModel::Table(points)
    }

    /// Default calibration used before any measurement exists: shaped to
    /// the paper's Fig. 4 trend (rank 1 needs ~1.9x the rounds of rank 8).
    pub fn paper_default() -> ConvergenceModel {
        ConvergenceModel::fitted(24.0, 1.0, 0.85)
    }

    /// E(r): expected global rounds at rank `r` (r >= 1).
    pub fn rounds(&self, rank: usize) -> f64 {
        let r = rank.max(1) as f64;
        match self {
            ConvergenceModel::Fitted { e_inf, c, alpha } => e_inf * (1.0 + c / r.powf(*alpha)),
            ConvergenceModel::Table(points) => {
                assert!(!points.is_empty(), "empty convergence table");
                // `table()` sorts and deduplicates, but the variant is
                // public and can be constructed directly — normalize
                // here before interpolating rather than trusting the
                // invariant (an unsorted table silently mis-clamps).
                // lint:allow(P101) windows(2) slices always hold exactly two points
                if points.windows(2).all(|w| w[0].0 < w[1].0) {
                    Self::interp_table(points, r)
                } else {
                    let mut sorted = points.clone();
                    sorted.sort_by_key(|&(pr, _)| pr);
                    sorted.dedup_by_key(|&mut (pr, _)| pr);
                    Self::interp_table(&sorted, r)
                }
            }
        }
    }

    /// Table interpolation at rank `r`, linear in u = 1/r (which
    /// straightens the hyperbolic trend), clamped outside the table.
    /// `points` must be sorted by rank ascending without duplicates.
    fn interp_table(points: &[(usize, f64)], r: f64) -> f64 {
        let u = 1.0 / r;
        let pt = |&(pr, pe): &(usize, f64)| (1.0 / pr.max(1) as f64, pe);
        // lint:allow(P101) rounds() asserts the table is non-empty before calling
        let first = pt(points.first().unwrap());
        // lint:allow(P101) same non-empty invariant as `first` above
        let last = pt(points.last().unwrap());
        // table sorted by r ascending -> u descending
        if u >= first.0 {
            return first.1;
        }
        if u <= last.0 {
            return last.1;
        }
        for w in points.windows(2) {
            // lint:allow(P101) windows(2) slices always hold exactly two points
            let (u0, e0) = pt(&w[0]);
            // lint:allow(P101) windows(2) slices always hold exactly two points
            let (u1, e1) = pt(&w[1]);
            if u <= u0 && u >= u1 {
                let t = if (u0 - u1).abs() < 1e-12 { 0.0 } else { (u0 - u) / (u0 - u1) };
                return e0 + t * (e1 - e0);
            }
        }
        last.1
    }

    /// Least-squares fit of the parametric law to measured points
    /// (grid search over alpha, closed-form for e_inf/c at fixed alpha).
    ///
    /// Only fits with a non-negative slope `b` are admissible: `b < 0`
    /// means `c < 0`, an E(r) that *increases* with rank — which would
    /// invert P4's trade-off and make the optimizer always pick the
    /// maximum rank. When no alpha admits a valid fit (e.g. noisy
    /// measurements that happen to trend upward), the model falls back
    /// to the flat fit `E(r) = mean(E)`.
    pub fn fit(points: &[(usize, f64)]) -> ConvergenceModel {
        assert!(points.len() >= 2, "need at least two points to fit");
        let mut best = (f64::INFINITY, 1.0, 0.0, 1.0); // (sse, e_inf, c, alpha)
        let mut alpha = 0.1;
        while alpha <= 2.5 {
            // model: E = e_inf + e_inf*c * r^-alpha  == a + b*x with
            // x = r^-alpha; linear least squares for (a, b)
            let xs: Vec<f64> = points.iter().map(|&(r, _)| (r.max(1) as f64).powf(-alpha)).collect();
            let ys: Vec<f64> = points.iter().map(|&(_, e)| e).collect();
            let (a, b) = crate::util::stats::linear_fit(&xs, &ys);
            if a > 0.0 && b >= 0.0 {
                let sse: f64 = xs
                    .iter()
                    .zip(&ys)
                    .map(|(x, y)| {
                        let pred = a + b * x;
                        (pred - y) * (pred - y)
                    })
                    .sum();
                if sse < best.0 {
                    best = (sse, a, b / a, alpha);
                }
            }
            alpha += 0.05;
        }
        if !best.0.is_finite() {
            let mean = points.iter().map(|&(_, e)| e).sum::<f64>() / points.len() as f64;
            return ConvergenceModel::fitted(mean.max(1e-9), 0.0, 1.0);
        }
        ConvergenceModel::fitted(best.1, best.2, best.3)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fitted_is_decreasing_with_diminishing_returns() {
        let m = ConvergenceModel::paper_default();
        let e: Vec<f64> = [1, 2, 4, 6, 8].iter().map(|&r| m.rounds(r)).collect();
        for w in e.windows(2) {
            assert!(w[1] < w[0], "must decrease: {e:?}");
        }
        // diminishing: drop 1->2 exceeds drop 6->8
        assert!(e[0] - e[1] > e[3] - e[4]);
    }

    #[test]
    fn table_interpolates_and_clamps() {
        let m = ConvergenceModel::table(vec![(1, 100.0), (4, 40.0), (8, 30.0)]);
        assert_eq!(m.rounds(1), 100.0);
        assert_eq!(m.rounds(8), 30.0);
        assert_eq!(m.rounds(16), 30.0); // clamped beyond table
        let e2 = m.rounds(2);
        assert!(e2 < 100.0 && e2 > 40.0);
    }

    #[test]
    fn fit_recovers_parametric_points() {
        let truth = ConvergenceModel::fitted(20.0, 1.5, 0.8);
        let pts: Vec<(usize, f64)> = [1, 2, 4, 6, 8].iter().map(|&r| (r, truth.rounds(r))).collect();
        let fit = ConvergenceModel::fit(&pts);
        for &(r, e) in &pts {
            let err = (fit.rounds(r) - e).abs() / e;
            assert!(err < 0.02, "rank {r}: {} vs {e}", fit.rounds(r));
        }
    }

    #[test]
    fn rank_zero_treated_as_one() {
        let m = ConvergenceModel::paper_default();
        assert_eq!(m.rounds(0), m.rounds(1));
    }

    #[test]
    fn fit_on_noisy_decreasing_measurements_keeps_c_nonnegative() {
        // Fig. 4-shaped data with measurement noise: E must still come
        // out non-increasing in rank (c >= 0), never inverted
        let pts = vec![
            (1usize, 47.3),
            (2, 34.1),
            (4, 29.8),
            (6, 27.2),
            (8, 26.9),
        ];
        let fit = ConvergenceModel::fit(&pts);
        if let ConvergenceModel::Fitted { e_inf, c, .. } = &fit {
            assert!(*e_inf > 0.0);
            assert!(*c >= 0.0, "negative c {c} inverts the rank trade-off");
        } else {
            panic!("fit must return the parametric form");
        }
        let mut prev = f64::INFINITY;
        for r in [1usize, 2, 4, 6, 8, 16] {
            let e = fit.rounds(r);
            assert!(e <= prev + 1e-9, "E({r})={e} rose above {prev}");
            prev = e;
        }
    }

    #[test]
    fn fit_on_increasing_measurements_falls_back_flat_not_inverted() {
        // adversarial: rounds that (nonsensically) grow with rank used
        // to produce c < 0, i.e. an E(r) increasing in rank that made
        // P4 always pick the maximum rank
        let pts = vec![(1usize, 20.0), (2, 24.0), (4, 30.0), (8, 40.0)];
        let fit = ConvergenceModel::fit(&pts);
        let e1 = fit.rounds(1);
        let e8 = fit.rounds(8);
        assert!(
            e8 <= e1 + 1e-9,
            "E(8)={e8} > E(1)={e1}: fit still rewards higher rank"
        );
        if let ConvergenceModel::Fitted { c, .. } = &fit {
            assert!(*c >= 0.0, "clamp failed: c = {c}");
        }
        // the flat fallback sits at the sample mean
        assert!((e1 - 28.5).abs() < 1e-9, "flat fallback off: {e1}");
    }

    #[test]
    fn directly_constructed_unsorted_table_matches_normalized_one() {
        // the public variant bypasses `table()`'s sort/dedup
        let raw = ConvergenceModel::Table(vec![(8, 30.0), (1, 100.0), (4, 40.0), (4, 999.0)]);
        let norm = ConvergenceModel::table(vec![(8, 30.0), (1, 100.0), (4, 40.0), (4, 999.0)]);
        for r in [0usize, 1, 2, 3, 4, 5, 6, 8, 12, 16] {
            assert_eq!(raw.rounds(r).to_bits(), norm.rounds(r).to_bits(), "rank {r}");
        }
        // interpolation is sane, not clamp-everything
        assert_eq!(raw.rounds(1), 100.0);
        assert_eq!(raw.rounds(8), 30.0);
        let e2 = raw.rounds(2);
        assert!(e2 < 100.0 && e2 > 40.0, "E(2)={e2}");
    }
}
