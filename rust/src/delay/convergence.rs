//! E(r): global rounds to reach the target loss as a function of the
//! LoRA rank (paper Fig. 4, consumed by Eq. 17 and subproblem P4).
//!
//! The paper estimates E(r) "offline through pretraining on a
//! representative dataset". We support both forms:
//!
//! * [`ConvergenceModel::Table`] — measured (rank, rounds) points from
//!   the Fig. 3/4 runs (`cargo bench --bench fig4_steps_to_target`
//!   writes them), interpolated monotonically;
//! * [`ConvergenceModel::Fitted`] — the parametric law
//!   `E(r) = e_inf * (1 + c / r^alpha)`, least-squares fitted to the
//!   measurements. Higher rank → fewer rounds with diminishing returns,
//!   exactly the shape Fig. 4 reports.

/// Rounds-to-target model.
#[derive(Clone, Debug)]
pub enum ConvergenceModel {
    /// Measured (rank, rounds) points; piecewise-linear in 1/r between
    /// points, clamped outside.
    Table(Vec<(usize, f64)>),
    /// E(r) = e_inf * (1 + c / r^alpha).
    Fitted { e_inf: f64, c: f64, alpha: f64 },
}

impl ConvergenceModel {
    pub fn fitted(e_inf: f64, c: f64, alpha: f64) -> ConvergenceModel {
        ConvergenceModel::Fitted { e_inf, c, alpha }
    }

    /// Sorted, deduplicated measurement table.
    pub fn table(mut points: Vec<(usize, f64)>) -> ConvergenceModel {
        points.sort_by_key(|&(r, _)| r);
        points.dedup_by_key(|&mut (r, _)| r);
        assert!(!points.is_empty(), "empty convergence table");
        ConvergenceModel::Table(points)
    }

    /// Default calibration used before any measurement exists: shaped to
    /// the paper's Fig. 4 trend (rank 1 needs ~1.9x the rounds of rank 8).
    pub fn paper_default() -> ConvergenceModel {
        ConvergenceModel::fitted(24.0, 1.0, 0.85)
    }

    /// E(r): expected global rounds at rank `r` (r >= 1).
    pub fn rounds(&self, rank: usize) -> f64 {
        let r = rank.max(1) as f64;
        match self {
            ConvergenceModel::Fitted { e_inf, c, alpha } => e_inf * (1.0 + c / r.powf(*alpha)),
            ConvergenceModel::Table(points) => {
                // interpolate linearly in u = 1/r, which straightens the
                // hyperbolic trend
                let u = 1.0 / r;
                let pt = |&(pr, pe): &(usize, f64)| (1.0 / pr.max(1) as f64, pe);
                let first = pt(points.first().unwrap());
                let last = pt(points.last().unwrap());
                // table sorted by r ascending -> u descending
                if u >= first.0 {
                    return first.1;
                }
                if u <= last.0 {
                    return last.1;
                }
                for w in points.windows(2) {
                    let (u0, e0) = pt(&w[0]);
                    let (u1, e1) = pt(&w[1]);
                    if u <= u0 && u >= u1 {
                        let t = if (u0 - u1).abs() < 1e-12 { 0.0 } else { (u0 - u) / (u0 - u1) };
                        return e0 + t * (e1 - e0);
                    }
                }
                last.1
            }
        }
    }

    /// Least-squares fit of the parametric law to measured points
    /// (grid search over alpha, closed-form for e_inf/c at fixed alpha).
    pub fn fit(points: &[(usize, f64)]) -> ConvergenceModel {
        assert!(points.len() >= 2, "need at least two points to fit");
        let mut best = (f64::INFINITY, 1.0, 0.0, 1.0); // (sse, e_inf, c, alpha)
        let mut alpha = 0.1;
        while alpha <= 2.5 {
            // model: E = e_inf + e_inf*c * r^-alpha  == a + b*x with
            // x = r^-alpha; linear least squares for (a, b)
            let xs: Vec<f64> = points.iter().map(|&(r, _)| (r.max(1) as f64).powf(-alpha)).collect();
            let ys: Vec<f64> = points.iter().map(|&(_, e)| e).collect();
            let (a, b) = crate::util::stats::linear_fit(&xs, &ys);
            if a > 0.0 {
                let sse: f64 = xs
                    .iter()
                    .zip(&ys)
                    .map(|(x, y)| {
                        let pred = a + b * x;
                        (pred - y) * (pred - y)
                    })
                    .sum();
                if sse < best.0 {
                    best = (sse, a, b / a, alpha);
                }
            }
            alpha += 0.05;
        }
        ConvergenceModel::fitted(best.1, best.2, best.3)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fitted_is_decreasing_with_diminishing_returns() {
        let m = ConvergenceModel::paper_default();
        let e: Vec<f64> = [1, 2, 4, 6, 8].iter().map(|&r| m.rounds(r)).collect();
        for w in e.windows(2) {
            assert!(w[1] < w[0], "must decrease: {e:?}");
        }
        // diminishing: drop 1->2 exceeds drop 6->8
        assert!(e[0] - e[1] > e[3] - e[4]);
    }

    #[test]
    fn table_interpolates_and_clamps() {
        let m = ConvergenceModel::table(vec![(1, 100.0), (4, 40.0), (8, 30.0)]);
        assert_eq!(m.rounds(1), 100.0);
        assert_eq!(m.rounds(8), 30.0);
        assert_eq!(m.rounds(16), 30.0); // clamped beyond table
        let e2 = m.rounds(2);
        assert!(e2 < 100.0 && e2 > 40.0);
    }

    #[test]
    fn fit_recovers_parametric_points() {
        let truth = ConvergenceModel::fitted(20.0, 1.5, 0.8);
        let pts: Vec<(usize, f64)> = [1, 2, 4, 6, 8].iter().map(|&r| (r, truth.rounds(r))).collect();
        let fit = ConvergenceModel::fit(&pts);
        for &(r, e) in &pts {
            let err = (fit.rounds(r) - e).abs() / e;
            assert!(err < 0.02, "rank {r}: {} vs {e}", fit.rounds(r));
        }
    }

    #[test]
    fn rank_zero_treated_as_one() {
        let m = ConvergenceModel::paper_default();
        assert_eq!(m.rounds(0), m.rounds(1));
    }
}
