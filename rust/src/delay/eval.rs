//! Cached delay evaluation for the P3/P4 candidate scans.
//!
//! `Scenario::total_delay` is exact but wasteful inside the optimizer's
//! exhaustive searches: every candidate (l_c, rank) used to clone the
//! whole `Allocation` and recompute every subchannel rate, even though
//! the uplink rates depend only on the communication block (assignment
//! + PSDs) and the workload sums depend only on (profile, l_c, rank).
//!
//! [`DelayEvaluator`] factors the computation accordingly. Built once
//! per (scenario, assignment, PSD) block, it precomputes
//!
//! * per-client uplink rates to both servers (Eqs. 9/14), and
//! * per-(l_c, rank) workload sums as a [`WorkloadTable`] lookup,
//!
//! and then serves `eval(l_c, rank)` — the total training delay of
//! Eq. 17 — as an O(K) pass with **zero allocation** and **bit-identical
//! results** to `Scenario::total_delay` (the arithmetic replicates the
//! order of operations of `Scenario::phase_delays` exactly; asserted by
//! `rust/tests/prop_eval.rs`). The joint split×rank exhaustive scan of
//! [`DelayEvaluator::best_split_rank`] — the paper's "exhaustive search
//! … for optimal split position and rank selection" — is what P3/P4 in
//! [`crate::opt::bcd`] run on.
//!
//! The same factoring serves the **energy** model:
//! [`DelayEvaluator::eval_energy`] is bit-identical to
//! `delay::energy::total_energy` at the scenario's ζ (per-client powers
//! are cached next to the rates; the `fwd+bwd` energy FLOPs are one
//! more [`WorkloadTable`] column), and
//! [`DelayEvaluator::best_split_rank_obj`] runs the joint grid scan
//! under any [`crate::opt::Objective`] — with [`Objective::Delay`]
//! (and λ = 0) it performs the identical float comparisons as
//! [`DelayEvaluator::best_split_rank`], so promoting the objective to a
//! parameter changed no delay-optimal result anywhere.
//!
//! [`WorkloadCache`] shares the (profile, rank set) tables across
//! evaluator builds: all BCD iterations, baseline draws, and
//! [`crate::sim::SweepRunner`] grid points that keep the same model and
//! sequence length hit the same table.
//!
//! The channel-dependent half of the evaluator is factored out as
//! [`RateColumns`] (the four per-client column vectors), with
//! [`ColumnCache`] serving **delta updates** to the round-varying
//! simulator: between rounds only the rate rows of clients whose gain
//! actually changed are recomputed (the power columns never read a
//! gain), and a frozen channel recomputes nothing — all bit-identical
//! to a from-scratch [`DelayEvaluator::new`] build (property-tested in
//! `rust/tests/prop_eval.rs`).

use std::borrow::Cow;
use std::sync::{Arc, Mutex};

use crate::delay::energy::tx_energy;
use crate::delay::{Allocation, ConvergenceModel, Scenario};
use crate::model::{WorkloadProfile, WorkloadTable};
use crate::delay::objective::Objective;

/// The per-(l_c, rank) workload sums one delay/energy evaluation
/// consumes.
struct Workload {
    client_fwd: f64,
    client_bwd: f64,
    server_fwd: f64,
    server_bwd: f64,
    act_bits: f64,
    adapter_bits: f64,
    /// `client_fwd + client_bwd`, pre-added (the energy model's Φ).
    client_energy: f64,
}

/// Cached total-delay evaluator over one communication block.
///
/// Valid as long as the assignment and PSDs it was built from stay
/// fixed; rebuild after every P1/P2 update (the constructor is O(K·M),
/// i.e. one rate computation per subchannel — the same cost as a single
/// `total_delay` call).
pub struct DelayEvaluator<'s> {
    scn: &'s Scenario,
    conv: &'s ConvergenceModel,
    table: Arc<WorkloadTable>,
    /// E(r) per candidate rank, aligned with `table.ranks()`.
    rounds: Vec<f64>,
    /// Per-client uplink rates under the frozen assignment/PSDs
    /// (owned when computed here, borrowed when served by a
    /// [`ColumnCache`] — the delta path allocates nothing per build).
    rate_main: Cow<'s, [f64]>,
    rate_fed: Cow<'s, [f64]>,
    /// Per-client transmit powers (C4's LHS) under the same frozen
    /// block — the energy model's `P_k` factors.
    power_main: Cow<'s, [f64]>,
    power_fed: Cow<'s, [f64]>,
    /// Switched-capacitance ζ, from `Scenario::objective.zeta`.
    zeta: f64,
}

impl<'s> DelayEvaluator<'s> {
    /// Build from a shared workload table (see [`WorkloadCache`]).
    pub fn new(
        scn: &'s Scenario,
        alloc: &Allocation,
        conv: &'s ConvergenceModel,
        table: Arc<WorkloadTable>,
    ) -> DelayEvaluator<'s> {
        DelayEvaluator::with_columns(scn, conv, table, RateColumns::compute(scn, alloc))
    }

    /// Build from precomputed per-client columns (see [`RateColumns`] /
    /// [`ColumnCache`]): the round-varying simulator's delta path,
    /// which hands back cached rows instead of recomputing every
    /// subchannel rate. With `RateColumns::compute`'s output this is
    /// exactly [`DelayEvaluator::new`].
    pub fn with_columns(
        scn: &'s Scenario,
        conv: &'s ConvergenceModel,
        table: Arc<WorkloadTable>,
        cols: RateColumns,
    ) -> DelayEvaluator<'s> {
        DelayEvaluator::from_cows(
            scn,
            conv,
            table,
            Cow::Owned(cols.rate_main),
            Cow::Owned(cols.rate_fed),
            Cow::Owned(cols.power_main),
            Cow::Owned(cols.power_fed),
        )
    }

    /// [`Self::with_columns`] borrowing the columns in place — the
    /// round simulator's per-round path, which builds an evaluator over
    /// a [`ColumnCache`] entry without copying (or allocating) a single
    /// row.
    pub fn with_cached_columns(
        scn: &'s Scenario,
        conv: &'s ConvergenceModel,
        table: Arc<WorkloadTable>,
        cols: &'s RateColumns,
    ) -> DelayEvaluator<'s> {
        DelayEvaluator::from_cows(
            scn,
            conv,
            table,
            Cow::Borrowed(&cols.rate_main),
            Cow::Borrowed(&cols.rate_fed),
            Cow::Borrowed(&cols.power_main),
            Cow::Borrowed(&cols.power_fed),
        )
    }

    /// The one constructor both column paths share.
    #[allow(clippy::too_many_arguments)]
    fn from_cows(
        scn: &'s Scenario,
        conv: &'s ConvergenceModel,
        table: Arc<WorkloadTable>,
        rate_main: Cow<'s, [f64]>,
        rate_fed: Cow<'s, [f64]>,
        power_main: Cow<'s, [f64]>,
        power_fed: Cow<'s, [f64]>,
    ) -> DelayEvaluator<'s> {
        let rounds = table.ranks().iter().map(|&r| conv.rounds(r)).collect();
        DelayEvaluator {
            scn,
            conv,
            rounds,
            rate_main,
            rate_fed,
            power_main,
            power_fed,
            zeta: scn.objective.zeta,
            table,
        }
    }

    /// Convenience constructor that builds its own single-use table.
    pub fn build(
        scn: &'s Scenario,
        alloc: &Allocation,
        conv: &'s ConvergenceModel,
        ranks: &[usize],
    ) -> DelayEvaluator<'s> {
        let table = Arc::new(WorkloadTable::new(&scn.profile, ranks));
        DelayEvaluator::new(scn, alloc, conv, table)
    }

    /// The candidate ranks the cached table covers.
    pub fn ranks(&self) -> &[usize] {
        self.table.ranks()
    }

    /// Admissible split points (1 ..= L-1).
    pub fn splits(&self) -> std::ops::Range<usize> {
        self.scn.profile.split_candidates()
    }

    /// Total training delay T (Eq. 17) at (`l_c`, `rank`) under the
    /// frozen communication block. Ranks outside the cached candidate
    /// set fall back to the profile's prefix sums — same arithmetic,
    /// same bits, no table hit.
    pub fn eval(&self, l_c: usize, rank: usize) -> f64 {
        match self.table.rank_index(rank) {
            Some(ri) => self.total(&self.lookup(l_c, ri), self.rounds[ri]),
            None => self.total(&self.profile_workload(l_c, rank), self.conv.rounds(rank)),
        }
    }

    /// One-round delay `I·T_local + max_k T_k^f` at (`l_c`, `rank`) —
    /// Eq. 17 without the E(r) factor; [`Self::eval`] is exactly
    /// `E(rank) ×` this value (same bits).
    pub fn round_delay(&self, l_c: usize, rank: usize) -> f64 {
        self.round(&self.workload(l_c, rank), None)
    }

    /// [`Self::round_delay`] restricted to the clients marked `true` in
    /// `active` (dropped clients neither compute nor upload, and the
    /// server only batches the active cohort). With an all-`true` mask
    /// the arithmetic — and therefore the bits — match
    /// [`Self::round_delay`]. Returns 0 for an all-`false` mask.
    pub fn round_delay_active(&self, l_c: usize, rank: usize, active: &[bool]) -> f64 {
        assert_eq!(
            active.len(),
            self.scn.k(),
            "participation mask length must equal the client count"
        );
        self.round(&self.workload(l_c, rank), Some(active))
    }

    /// The workload sums at (`l_c`, `rank`): table hit for cached
    /// candidate ranks, profile prefix-sum fallback otherwise.
    fn workload(&self, l_c: usize, rank: usize) -> Workload {
        match self.table.rank_index(rank) {
            Some(ri) => self.lookup(l_c, ri),
            None => self.profile_workload(l_c, rank),
        }
    }

    /// Off-table fallback: the profile's prefix sums — same arithmetic,
    /// same bits as the tabulated path.
    fn profile_workload(&self, l_c: usize, rank: usize) -> Workload {
        let p: &WorkloadProfile = &self.scn.profile;
        Workload {
            client_fwd: p.client_fwd_flops(l_c, rank),
            client_bwd: p.client_bwd_flops(l_c, rank),
            server_fwd: p.server_fwd_flops(l_c, rank),
            server_bwd: p.server_bwd_flops(l_c, rank),
            act_bits: p.activation_bits(l_c),
            adapter_bits: p.client_adapter_bits(l_c, rank),
            client_energy: p.client_fwd_flops(l_c, rank) + p.client_bwd_flops(l_c, rank),
        }
    }

    /// Table lookup of the workload sums at (`l_c`, rank index `ri`).
    fn lookup(&self, l_c: usize, ri: usize) -> Workload {
        Workload {
            client_fwd: self.table.client_fwd_flops(l_c, ri),
            client_bwd: self.table.client_bwd_flops(l_c, ri),
            server_fwd: self.table.server_fwd_flops(l_c, ri),
            server_bwd: self.table.server_bwd_flops(l_c, ri),
            act_bits: self.table.activation_bits(l_c),
            adapter_bits: self.table.adapter_bits(l_c, ri),
            client_energy: self.table.client_energy_flops(l_c, ri),
        }
    }

    /// Eq. 17 with the workload sums in hand: `E(r) ×` the one-round
    /// delay of [`Self::round`].
    fn total(&self, w: &Workload, rounds: f64) -> f64 {
        rounds * self.round(w, None)
    }

    /// One-round delay `I·T_local + max_k T_k^f` with the workload sums
    /// in hand, optionally restricted to the active clients. The
    /// expressions replicate `Scenario::phase_delays` /
    /// `PhaseDelays::t_local` operation by operation — and the masked
    /// path performs the identical float sequence when every client is
    /// active — so [`Self::eval`] stays bit-identical to the uncached
    /// `Scenario::total_delay`.
    fn round(&self, w: &Workload, active: Option<&[bool]>) -> f64 {
        let scn = self.scn;
        let k_n = scn.k();
        let b = scn.batch as f64;
        let mut stage1 = 0.0f64;
        let mut stage3 = 0.0f64;
        let mut t_fed = 0.0f64;
        let mut n_active = 0usize;
        for k in 0..k_n {
            if let Some(mask) = active {
                if !mask[k] {
                    continue;
                }
            }
            n_active += 1;
            let f_k = scn.topo.clients[k].f_cycles;
            let client_fwd = b * scn.kappa_client * w.client_fwd / f_k;
            let act_upload = if self.rate_main[k] > 0.0 {
                b * w.act_bits / self.rate_main[k]
            } else {
                f64::INFINITY
            };
            stage1 = stage1.max(client_fwd + act_upload);
            stage3 = stage3.max(b * scn.kappa_client * w.client_bwd / f_k);
            t_fed = t_fed.max(if self.rate_fed[k] > 0.0 {
                w.adapter_bits / self.rate_fed[k]
            } else {
                f64::INFINITY
            });
        }
        let server_fwd = n_active as f64 * b * scn.kappa_server * w.server_fwd / scn.f_server;
        let server_bwd = n_active as f64 * b * scn.kappa_server * w.server_bwd / scn.f_server;
        let t_local = stage1 + server_fwd + server_bwd + stage3;
        scn.local_steps as f64 * t_local + t_fed
    }

    /// Total training energy `E(r)·(I·E_round)` at (`l_c`, `rank`)
    /// under the frozen communication block — **bit-identical** to
    /// `delay::energy::total_energy` at the scenario's ζ (asserted by
    /// `rust/tests/prop_eval.rs`), with the same zero-allocation /
    /// table-fallback structure as [`Self::eval`].
    pub fn eval_energy(&self, l_c: usize, rank: usize) -> f64 {
        match self.table.rank_index(rank) {
            Some(ri) => self.total_energy(&self.lookup(l_c, ri), self.rounds[ri]),
            None => self.total_energy(&self.profile_workload(l_c, rank), self.conv.rounds(rank)),
        }
    }

    /// Per-local-round energy ledger total at (`l_c`, `rank`) —
    /// `delay::energy::round_energy(..).total()` on the cached block
    /// (same bits); [`Self::eval_energy`] is exactly
    /// `E(rank) × (I ×` this value `)`.
    pub fn round_energy_total(&self, l_c: usize, rank: usize) -> f64 {
        self.round_energy(&self.workload(l_c, rank), None)
    }

    /// [`Self::round_energy_total`] restricted to the clients marked
    /// `true` in `active`: dropped clients spend nothing — no compute,
    /// no uploads. With an all-`true` mask the arithmetic (and the
    /// bits) match the unmasked total. Returns 0 for an all-`false`
    /// mask.
    pub fn round_energy_active(&self, l_c: usize, rank: usize, active: &[bool]) -> f64 {
        assert_eq!(
            active.len(),
            self.scn.k(),
            "participation mask length must equal the client count"
        );
        self.round_energy(&self.workload(l_c, rank), Some(active))
    }

    /// Energy analogue of [`Self::total`]: `E(r) × (I × E_round)` —
    /// exactly `delay::energy::total_energy`'s association.
    fn total_energy(&self, w: &Workload, rounds: f64) -> f64 {
        rounds * (self.scn.local_steps as f64 * self.round_energy(w, None))
    }

    /// Per-local-round energy with the workload sums in hand,
    /// optionally restricted to the active clients. Replicates
    /// `delay::energy::round_energy` + `RoundEnergy::total` operation
    /// by operation: three component accumulators filled in client
    /// order, then `(compute + act) + fed` — so the cached path stays
    /// bit-identical to the uncached one. Starved uplinks contribute an
    /// explicit `+∞` via [`tx_energy`], never NaN.
    fn round_energy(&self, w: &Workload, active: Option<&[bool]>) -> f64 {
        let scn = self.scn;
        let b = scn.batch as f64;
        let steps = scn.local_steps as f64;
        debug_assert!(scn.local_steps >= 1, "validated at scenario build");
        let mut compute = 0.0f64;
        let mut act = 0.0f64;
        let mut fed = 0.0f64;
        for k in 0..scn.k() {
            if let Some(mask) = active {
                if !mask[k] {
                    continue;
                }
            }
            let f_k = scn.topo.clients[k].f_cycles;
            let flops = b * w.client_energy;
            let cycles = scn.kappa_client * flops;
            compute += self.zeta * f_k * f_k * cycles;
            let act_airtime = if self.rate_main[k] > 0.0 {
                b * w.act_bits / self.rate_main[k]
            } else {
                f64::INFINITY
            };
            act += tx_energy(self.power_main[k], act_airtime);
            let fed_airtime = if self.rate_fed[k] > 0.0 {
                w.adapter_bits / self.rate_fed[k]
            } else {
                f64::INFINITY
            };
            fed += tx_energy(self.power_fed[k], fed_airtime) / steps;
        }
        compute + act + fed
    }

    /// P3 alone: argmin over split points at a fixed rank. Ties resolve
    /// to the smaller l_c (less client compute).
    pub fn best_split(&self, rank: usize) -> (usize, f64) {
        let mut best = (self.splits().start, f64::INFINITY);
        for l_c in self.splits() {
            let t = self.eval(l_c, rank);
            if t < best.1 {
                best = (l_c, t);
            }
        }
        best
    }

    /// P4 alone: argmin over the cached candidate ranks at a fixed
    /// split. Ties resolve to the earlier candidate.
    pub fn best_rank(&self, l_c: usize) -> (usize, f64) {
        // lint:allow(P101) WorkloadTable construction rejects an empty rank set
        let mut best = (self.table.ranks()[0], f64::INFINITY);
        for (ri, &r) in self.table.ranks().iter().enumerate() {
            let t = self.total(&self.lookup(l_c, ri), self.rounds[ri]);
            if t < best.1 {
                best = (r, t);
            }
        }
        best
    }

    /// The joint P3×P4 exhaustive scan (Eqs. 25/26 solved together):
    /// argmin of Eq. 17 over the full split×rank candidate grid.
    /// Returns (l_c*, rank*, T*). Ties resolve to the smaller l_c, then
    /// the earlier candidate rank — consistent with [`Self::best_split`]
    /// followed by [`Self::best_rank`].
    pub fn best_split_rank(&self) -> (usize, usize, f64) {
        // lint:allow(P101) WorkloadTable construction rejects an empty rank set
        let mut best = (self.splits().start, self.table.ranks()[0], f64::INFINITY);
        for l_c in self.splits() {
            for (ri, &r) in self.table.ranks().iter().enumerate() {
                let t = self.total(&self.lookup(l_c, ri), self.rounds[ri]);
                if t < best.2 {
                    best = (l_c, r, t);
                }
            }
        }
        best
    }

    /// The joint P3×P4 scan under an arbitrary [`Objective`]: argmin of
    /// `obj.score(T, E)` over the split×rank candidate grid, with the
    /// same iteration order and strict-`<` tie-break as
    /// [`Self::best_split_rank`]. Under [`Objective::Delay`] (and any
    /// objective with `needs_energy() == false`) the scan performs the
    /// **identical float comparisons** as the plain delay scan — energy
    /// is only computed once, for the winner's report — so the delay
    /// path is bit-identical (property-tested).
    pub fn best_split_rank_obj(&self, obj: &Objective) -> GridChoice {
        let need_e = obj.needs_energy();
        let mut best = GridChoice {
            l_c: self.splits().start,
            // lint:allow(P101) WorkloadTable construction rejects an empty rank set
            rank: self.table.ranks()[0],
            delay: f64::INFINITY,
            energy: f64::INFINITY,
            score: f64::INFINITY,
        };
        for l_c in self.splits() {
            for (ri, &r) in self.table.ranks().iter().enumerate() {
                let w = self.lookup(l_c, ri);
                let d = self.total(&w, self.rounds[ri]);
                let e = if need_e {
                    self.total_energy(&w, self.rounds[ri])
                } else {
                    0.0
                };
                let s = obj.score(d, e);
                if s < best.score {
                    best = GridChoice {
                        l_c,
                        rank: r,
                        delay: d,
                        energy: e,
                        score: s,
                    };
                }
            }
        }
        if !need_e {
            // score comparisons never touched energy; fill the winner's
            // report column with one post-hoc evaluation
            best.energy = self.eval_energy(best.l_c, best.rank);
        }
        best
    }

    /// P3 alone under an arbitrary objective: argmin of the score over
    /// split points at a fixed rank; returns (l_c*, score*). Identical
    /// comparisons to [`Self::best_split`] when the objective never
    /// consumes energy.
    pub fn best_split_obj(&self, rank: usize, obj: &Objective) -> (usize, f64) {
        let need_e = obj.needs_energy();
        let mut best = (self.splits().start, f64::INFINITY);
        for l_c in self.splits() {
            let d = self.eval(l_c, rank);
            let e = if need_e { self.eval_energy(l_c, rank) } else { 0.0 };
            let s = obj.score(d, e);
            if s < best.1 {
                best = (l_c, s);
            }
        }
        best
    }

    /// P4 alone under an arbitrary objective: argmin of the score over
    /// the cached candidate ranks at a fixed split; returns
    /// (rank*, score*). Identical comparisons to [`Self::best_rank`]
    /// when the objective never consumes energy.
    pub fn best_rank_obj(&self, l_c: usize, obj: &Objective) -> (usize, f64) {
        let need_e = obj.needs_energy();
        // lint:allow(P101) WorkloadTable construction rejects an empty rank set
        let mut best = (self.table.ranks()[0], f64::INFINITY);
        for (ri, &r) in self.table.ranks().iter().enumerate() {
            let w = self.lookup(l_c, ri);
            let d = self.total(&w, self.rounds[ri]);
            let e = if need_e {
                self.total_energy(&w, self.rounds[ri])
            } else {
                0.0
            };
            let s = obj.score(d, e);
            if s < best.1 {
                best = (r, s);
            }
        }
        best
    }
}

/// One grid candidate chosen by [`DelayEvaluator::best_split_rank_obj`]:
/// the argmin coordinates plus all three report quantities.
#[derive(Clone, Copy, Debug)]
pub struct GridChoice {
    pub l_c: usize,
    pub rank: usize,
    /// Total training delay T (Eq. 17) at the winner.
    pub delay: f64,
    /// Total training energy at the winner (scenario ζ).
    pub energy: f64,
    /// The objective score the scan minimized
    /// (`obj.score(delay, energy)`).
    pub score: f64,
}

/// The four per-client column vectors a [`DelayEvaluator`] serves
/// delay/energy evaluations from: uplink rates to both servers
/// (channel-**dependent**) and transmit powers (channel-**independent**
/// — `Σ_i p_i·B_i` never reads a gain), all under one frozen
/// communication block (assignment + PSDs).
#[derive(Clone, Debug, Default)]
pub struct RateColumns {
    pub rate_main: Vec<f64>,
    pub rate_fed: Vec<f64>,
    pub power_main: Vec<f64>,
    pub power_fed: Vec<f64>,
}

impl RateColumns {
    /// Compute all four columns from scratch — exactly the per-client
    /// maps [`DelayEvaluator::new`] performs (it delegates here).
    pub fn compute(scn: &Scenario, alloc: &Allocation) -> RateColumns {
        let k_n = scn.k();
        RateColumns {
            rate_main: (0..k_n).map(|k| scn.rate_main(alloc, k)).collect(),
            rate_fed: (0..k_n).map(|k| scn.rate_fed(alloc, k)).collect(),
            power_main: (0..k_n).map(|k| scn.power_main(alloc, k)).collect(),
            power_fed: (0..k_n).map(|k| scn.power_fed(alloc, k)).collect(),
        }
    }
}

/// One [`ColumnCache`] entry: the communication block plus a snapshot
/// of **everything else the columns read** — the per-client SNR
/// coefficients `G·γ_k/σ²` (which fold the channel gains, the antenna
/// gain product, and the noise PSD into the one number the Shannon
/// rate consumes) and the per-subchannel bandwidths. Keying on the
/// full input set means the cache can never serve stale columns, even
/// if a caller hands it scenarios that differ in more than their
/// gains.
struct ColumnEntry {
    assign_main: Vec<Vec<usize>>,
    assign_fed: Vec<Vec<usize>>,
    psd_main: Vec<f64>,
    psd_fed: Vec<f64>,
    bw_main: Vec<f64>,
    bw_fed: Vec<f64>,
    snr_main: Vec<f64>,
    snr_fed: Vec<f64>,
    cols: RateColumns,
}

fn snr_coeffs(link: &crate::net::Link) -> Vec<f64> {
    (0..link.k()).map(|k| link.snr_coeff(k)).collect()
}

impl ColumnEntry {
    fn new(scn: &Scenario, alloc: &Allocation) -> ColumnEntry {
        ColumnEntry {
            assign_main: alloc.assign_main.clone(),
            assign_fed: alloc.assign_fed.clone(),
            psd_main: alloc.psd_main.clone(),
            psd_fed: alloc.psd_fed.clone(),
            bw_main: scn.main_link.subch.bandwidth_hz.clone(),
            bw_fed: scn.fed_link.subch.bandwidth_hz.clone(),
            snr_main: snr_coeffs(&scn.main_link),
            snr_fed: snr_coeffs(&scn.fed_link),
            cols: RateColumns::compute(scn, alloc),
        }
    }

    /// Does this entry hold columns for `alloc`'s communication block
    /// on `scn`'s band plan? (The split/rank coordinates are
    /// irrelevant: rates and powers read only the assignment, the
    /// PSDs, the bandwidths, and the SNR coefficients — the last are
    /// delta-refreshed per client in [`Self::refresh`].)
    fn matches(&self, scn: &Scenario, alloc: &Allocation) -> bool {
        self.assign_main == alloc.assign_main
            && self.assign_fed == alloc.assign_fed
            && self.psd_main == alloc.psd_main
            && self.psd_fed == alloc.psd_fed
            && self.bw_main == scn.main_link.subch.bandwidth_hz
            && self.bw_fed == scn.fed_link.subch.bandwidth_hz
            && self.snr_main.len() == scn.main_link.k()
            && self.snr_fed.len() == scn.fed_link.k()
    }

    /// Refresh the channel-dependent rows of clients whose SNR
    /// coefficient moved since the snapshot. Each refreshed row runs
    /// the exact `Scenario::rate_*` computation a full rebuild would,
    /// and an unchanged coefficient reproduces the cached value by
    /// determinism — so the delta result is bit-identical to
    /// [`RateColumns::compute`] (property-tested in
    /// `rust/tests/prop_eval.rs`). Powers read neither gains nor noise
    /// and are left untouched.
    fn refresh(&mut self, scn: &Scenario, alloc: &Allocation) {
        for k in 0..scn.k() {
            let sm = scn.main_link.snr_coeff(k);
            if sm != self.snr_main[k] {
                self.snr_main[k] = sm;
                self.cols.rate_main[k] = scn.rate_main(alloc, k);
            }
            let sf = scn.fed_link.snr_coeff(k);
            if sf != self.snr_fed[k] {
                self.snr_fed[k] = sf;
                self.cols.rate_fed[k] = scn.rate_fed(alloc, k);
            }
        }
    }
}

/// Delta-updating cache of [`RateColumns`], keyed by communication
/// block, for the round-varying simulator: per round only the rate rows
/// of clients whose channel gain actually changed are recomputed, a
/// frozen channel (ρ = 1 / σ = 0) recomputes **nothing**, and the
/// gain-independent power columns are computed once per block, ever.
/// A small LRU (the dynamic engine's adoption step juggles at most
/// three candidate blocks: incumbent, round-0, fresh) bounds the
/// footprint.
pub struct ColumnCache {
    entries: Vec<ColumnEntry>,
    capacity: usize,
}

impl ColumnCache {
    /// `capacity` = number of distinct communication blocks kept (≥ 1).
    pub fn new(capacity: usize) -> ColumnCache {
        ColumnCache {
            entries: Vec::new(),
            capacity: capacity.max(1),
        }
    }

    /// Columns for `(scn, alloc)` — bit-identical to
    /// [`RateColumns::compute`], served from the cache when possible.
    /// The most recently used entry sits at the back; a miss evicts the
    /// front.
    pub fn columns_for(&mut self, scn: &Scenario, alloc: &Allocation) -> &RateColumns {
        if let Some(i) = self.entries.iter().position(|e| e.matches(scn, alloc)) {
            let mut e = self.entries.remove(i);
            e.refresh(scn, alloc);
            self.entries.push(e);
        } else {
            if self.entries.len() >= self.capacity {
                self.entries.remove(0);
            }
            self.entries.push(ColumnEntry::new(scn, alloc));
        }
        // lint:allow(P101) entry pushed on the line above; last() cannot be None
        &self.entries.last().expect("just pushed").cols
    }

    /// Number of blocks currently cached.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// Identity of a [`WorkloadTable`]: everything `WorkloadProfile::new`
/// reads, plus the candidate rank set.
#[derive(Clone, Debug, PartialEq, Eq)]
struct TableKey {
    n_layers: usize,
    d_model: usize,
    n_heads: usize,
    vocab: usize,
    seq: usize,
    ranks: Vec<usize>,
}

impl TableKey {
    fn of(profile: &WorkloadProfile, ranks: &[usize]) -> TableKey {
        TableKey {
            n_layers: profile.cfg.n_layers,
            d_model: profile.cfg.d_model,
            n_heads: profile.cfg.n_heads,
            vocab: profile.cfg.vocab,
            seq: profile.seq,
            ranks: ranks.to_vec(),
        }
    }
}

/// Thread-safe share point for [`WorkloadTable`]s, keyed by the model
/// dimensions, sequence length and rank set that fully determine a
/// table. One cache per [`crate::sim::SweepRunner`] lets every grid
/// point, BCD iteration and baseline draw reuse the same table instead
/// of recomputing the prefix sums.
///
/// Profiles are assumed to come from `WorkloadProfile::new` (the only
/// constructor in-tree); a hand-mutated `blocks` vector would alias its
/// key.
#[derive(Default)]
pub struct WorkloadCache {
    entries: Mutex<Vec<(TableKey, Arc<WorkloadTable>)>>,
}

impl WorkloadCache {
    pub fn new() -> WorkloadCache {
        WorkloadCache::default()
    }

    /// Fetch (or build and memoize) the table for `(profile, ranks)`.
    pub fn table_for(&self, profile: &WorkloadProfile, ranks: &[usize]) -> Arc<WorkloadTable> {
        let key = TableKey::of(profile, ranks);
        // lint:allow(P101) lock poisoning implies a sibling solve already panicked
        let mut entries = self.entries.lock().expect("workload cache lock");
        if let Some((_, table)) = entries.iter().find(|(k, _)| *k == key) {
            return table.clone();
        }
        let table = Arc::new(WorkloadTable::new(profile, ranks));
        entries.push((key, table.clone()));
        table
    }

    /// Number of distinct tables currently memoized.
    pub fn tables(&self) -> usize {
        // lint:allow(P101) lock poisoning implies a sibling solve already panicked
        self.entries.lock().expect("workload cache lock").len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::delay::testutil::toy_scenario;

    const RANKS: [usize; 5] = [1, 2, 4, 6, 8];

    fn toy_alloc() -> Allocation {
        Allocation {
            assign_main: vec![vec![0, 1], vec![2, 3]],
            assign_fed: vec![vec![0], vec![1]],
            psd_main: vec![5e-5; 4],
            psd_fed: vec![5e-5; 2],
            l_c: 6,
            rank: 4,
        }
    }

    #[test]
    fn eval_matches_total_delay_bit_for_bit() {
        let scn = toy_scenario();
        let conv = ConvergenceModel::paper_default();
        let alloc = toy_alloc();
        let ev = DelayEvaluator::build(&scn, &alloc, &conv, &RANKS);
        for l_c in scn.profile.split_candidates() {
            for &r in &RANKS {
                let mut cand = alloc.clone();
                cand.l_c = l_c;
                cand.rank = r;
                let want = scn.total_delay(&cand, &conv);
                let got = ev.eval(l_c, r);
                assert_eq!(got.to_bits(), want.to_bits(), "l_c={l_c} r={r}");
            }
        }
    }

    #[test]
    fn eval_outside_candidate_set_falls_back_bit_for_bit() {
        let scn = toy_scenario();
        let conv = ConvergenceModel::paper_default();
        let alloc = toy_alloc();
        let ev = DelayEvaluator::build(&scn, &alloc, &conv, &[1, 8]);
        let mut cand = alloc.clone();
        cand.rank = 3; // not in the table
        cand.l_c = 5;
        assert_eq!(
            ev.eval(5, 3).to_bits(),
            scn.total_delay(&cand, &conv).to_bits()
        );
    }

    #[test]
    fn starved_client_evaluates_to_infinity() {
        let scn = toy_scenario();
        let conv = ConvergenceModel::paper_default();
        let mut alloc = toy_alloc();
        // client 1 loses its fed subchannel -> infinite adapter upload
        alloc.assign_fed[1].clear();
        let ev = DelayEvaluator::build(&scn, &alloc, &conv, &RANKS);
        assert!(ev.eval(6, 4).is_infinite());
        assert_eq!(
            ev.eval(6, 4).to_bits(),
            scn.total_delay(&alloc, &conv).to_bits()
        );
    }

    #[test]
    fn joint_scan_is_grid_argmin_with_smallest_tiebreak() {
        let scn = toy_scenario();
        let conv = ConvergenceModel::paper_default();
        let alloc = toy_alloc();
        let ev = DelayEvaluator::build(&scn, &alloc, &conv, &RANKS);
        let (l_star, r_star, t_star) = ev.best_split_rank();
        assert!(scn.profile.split_candidates().contains(&l_star));
        assert!(RANKS.contains(&r_star));
        for l_c in scn.profile.split_candidates() {
            for &r in &RANKS {
                assert!(ev.eval(l_c, r) >= t_star, "({l_c}, {r}) beats the scan");
            }
        }
        assert_eq!(t_star.to_bits(), ev.eval(l_star, r_star).to_bits());
    }

    #[test]
    fn joint_scan_never_worse_than_either_single_scan() {
        let scn = toy_scenario();
        let conv = ConvergenceModel::paper_default();
        let alloc = toy_alloc();
        let ev = DelayEvaluator::build(&scn, &alloc, &conv, &RANKS);
        let (_, _, t_joint) = ev.best_split_rank();
        let (l_split, t_split) = ev.best_split(alloc.rank);
        let (_, t_rank) = ev.best_rank(l_split);
        assert!(t_joint <= t_split);
        assert!(t_joint <= t_rank);
    }

    #[test]
    fn eval_is_rounds_times_round_delay_bit_for_bit() {
        let scn = toy_scenario();
        let conv = ConvergenceModel::paper_default();
        let alloc = toy_alloc();
        let ev = DelayEvaluator::build(&scn, &alloc, &conv, &RANKS);
        for l_c in scn.profile.split_candidates() {
            for &r in &[1usize, 3, 4, 8] {
                // 3 exercises the off-table fallback
                let d = ev.round_delay(l_c, r);
                let want = conv.rounds(r) * d;
                assert_eq!(ev.eval(l_c, r).to_bits(), want.to_bits(), "l_c={l_c} r={r}");
            }
        }
    }

    #[test]
    fn full_participation_mask_matches_unmasked_bit_for_bit() {
        let scn = toy_scenario();
        let conv = ConvergenceModel::paper_default();
        let alloc = toy_alloc();
        let ev = DelayEvaluator::build(&scn, &alloc, &conv, &RANKS);
        let all = vec![true; scn.k()];
        for l_c in scn.profile.split_candidates() {
            let a = ev.round_delay(l_c, 4);
            let b = ev.round_delay_active(l_c, 4, &all);
            assert_eq!(a.to_bits(), b.to_bits(), "l_c={l_c}");
        }
    }

    #[test]
    fn dropped_clients_leave_the_round() {
        let scn = toy_scenario();
        let conv = ConvergenceModel::paper_default();
        let alloc = toy_alloc();
        let ev = DelayEvaluator::build(&scn, &alloc, &conv, &RANKS);
        let full = ev.round_delay(6, 4);
        // client 1 dropped: server batches one client, maxima over {0}
        let d0 = ev.round_delay_active(6, 4, &[true, false]);
        assert!(d0 < full, "single-client round {d0} not cheaper than {full}");
        assert!(d0.is_finite() && d0 > 0.0);
        // nobody active: an idle round costs nothing
        assert_eq!(ev.round_delay_active(6, 4, &[false, false]), 0.0);
        // dropping the starved client makes an infinite round finite
        let mut starved = toy_alloc();
        starved.assign_fed[1].clear();
        let ev2 = DelayEvaluator::build(&scn, &starved, &conv, &RANKS);
        assert!(ev2.round_delay(6, 4).is_infinite());
        assert!(ev2.round_delay_active(6, 4, &[true, false]).is_finite());
    }

    #[test]
    fn eval_energy_matches_total_energy_bit_for_bit() {
        let scn = toy_scenario();
        let conv = ConvergenceModel::paper_default();
        let alloc = toy_alloc();
        let ev = DelayEvaluator::build(&scn, &alloc, &conv, &RANKS);
        for l_c in scn.profile.split_candidates() {
            for &r in &[1usize, 3, 4, 8] {
                // 3 exercises the off-table fallback
                let mut cand = alloc.clone();
                cand.l_c = l_c;
                cand.rank = r;
                let want =
                    crate::delay::energy::total_energy(&scn, &cand, &conv, scn.objective.zeta);
                let got = ev.eval_energy(l_c, r);
                assert_eq!(got.to_bits(), want.to_bits(), "l_c={l_c} r={r}");
            }
        }
    }

    #[test]
    fn eval_energy_is_rounds_times_steps_times_round_energy() {
        let scn = toy_scenario();
        let conv = ConvergenceModel::paper_default();
        let alloc = toy_alloc();
        let ev = DelayEvaluator::build(&scn, &alloc, &conv, &RANKS);
        for l_c in scn.profile.split_candidates() {
            let e_round = ev.round_energy_total(l_c, 4);
            let want = conv.rounds(4) * (scn.local_steps as f64 * e_round);
            assert_eq!(ev.eval_energy(l_c, 4).to_bits(), want.to_bits(), "l_c={l_c}");
        }
    }

    #[test]
    fn energy_mask_all_active_matches_unmasked_and_dropouts_spend_nothing() {
        let scn = toy_scenario();
        let conv = ConvergenceModel::paper_default();
        let alloc = toy_alloc();
        let ev = DelayEvaluator::build(&scn, &alloc, &conv, &RANKS);
        let all = vec![true; scn.k()];
        let full = ev.round_energy_total(6, 4);
        assert_eq!(full.to_bits(), ev.round_energy_active(6, 4, &all).to_bits());
        let solo = ev.round_energy_active(6, 4, &[true, false]);
        assert!(solo > 0.0 && solo < full, "dropping a client must shed its spend");
        assert_eq!(ev.round_energy_active(6, 4, &[false, false]), 0.0);
    }

    #[test]
    fn starved_client_energy_is_infinite_not_nan() {
        let scn = toy_scenario();
        let conv = ConvergenceModel::paper_default();
        let mut alloc = toy_alloc();
        alloc.assign_fed[1].clear(); // zero fed rate, zero fed power
        let ev = DelayEvaluator::build(&scn, &alloc, &conv, &RANKS);
        let e = ev.eval_energy(6, 4);
        assert!(e.is_infinite() && !e.is_nan(), "got {e}");
        // dropping the starved client makes the spend finite again
        assert!(ev.round_energy_active(6, 4, &[true, false]).is_finite());
    }

    #[test]
    fn delay_objective_scan_is_bit_identical_to_plain_scan() {
        let scn = toy_scenario();
        let conv = ConvergenceModel::paper_default();
        let alloc = toy_alloc();
        let ev = DelayEvaluator::build(&scn, &alloc, &conv, &RANKS);
        let (l, r, t) = ev.best_split_rank();
        for obj in [Objective::Delay, Objective::Weighted { lambda: 0.0 }] {
            let c = ev.best_split_rank_obj(&obj);
            assert_eq!((c.l_c, c.rank), (l, r), "{obj:?}");
            assert_eq!(c.score.to_bits(), t.to_bits(), "{obj:?}");
            assert_eq!(c.delay.to_bits(), t.to_bits(), "{obj:?}");
            assert_eq!(c.energy.to_bits(), ev.eval_energy(l, r).to_bits(), "{obj:?}");
        }
        // the 1-D scans agree with their delay twins too
        let (ls, ts) = ev.best_split(4);
        let (lo, so) = ev.best_split_obj(4, &Objective::Delay);
        assert_eq!((ls, ts.to_bits()), (lo, so.to_bits()));
        let (rs, tr) = ev.best_rank(6);
        let (ro, sr) = ev.best_rank_obj(6, &Objective::Delay);
        assert_eq!((rs, tr.to_bits()), (ro, sr.to_bits()));
    }

    #[test]
    fn energy_objective_scan_is_the_energy_grid_argmin() {
        let scn = toy_scenario();
        let conv = ConvergenceModel::paper_default();
        let alloc = toy_alloc();
        let ev = DelayEvaluator::build(&scn, &alloc, &conv, &RANKS);
        let c = ev.best_split_rank_obj(&Objective::Energy);
        assert_eq!(c.score.to_bits(), c.energy.to_bits());
        for l_c in scn.profile.split_candidates() {
            for &r in &RANKS {
                assert!(
                    ev.eval_energy(l_c, r) >= c.energy,
                    "({l_c}, {r}) beats the energy scan"
                );
            }
        }
        assert_eq!(c.delay.to_bits(), ev.eval(c.l_c, c.rank).to_bits());
    }

    #[test]
    fn budget_objective_is_constrained_delay() {
        let scn = toy_scenario();
        let conv = ConvergenceModel::paper_default();
        let alloc = toy_alloc();
        let ev = DelayEvaluator::build(&scn, &alloc, &conv, &RANKS);
        let (l, r, t) = ev.best_split_rank();
        // a generous budget reproduces the delay argmin
        let generous = ev.best_split_rank_obj(&Objective::EnergyBudget {
            joules: f64::INFINITY,
        });
        assert_eq!((generous.l_c, generous.rank), (l, r));
        assert_eq!(generous.score.to_bits(), t.to_bits());
        // a budget nobody can meet leaves every candidate at +inf
        let starved = ev.best_split_rank_obj(&Objective::EnergyBudget { joules: 1e-30 });
        assert!(starved.score.is_infinite() && !starved.score.is_nan());
        // a budget pinned just under the delay argmin's energy must
        // move the choice (when some other candidate still fits it)
        let e_star = ev.eval_energy(l, r);
        let budget = e_star * (1.0 - 1e-9);
        let cheaper = ev.best_split_rank_obj(&Objective::Energy);
        if cheaper.energy <= budget {
            let pinched = ev.best_split_rank_obj(&Objective::EnergyBudget { joules: budget });
            assert!(
                (pinched.l_c, pinched.rank) != (l, r),
                "budget below the delay optimum's energy must exclude it"
            );
            assert!(pinched.energy <= budget);
        }
    }

    #[test]
    fn cache_shares_tables_and_keys_on_ranks() {
        let scn = toy_scenario();
        let cache = WorkloadCache::new();
        let a = cache.table_for(&scn.profile, &RANKS);
        let b = cache.table_for(&scn.profile, &RANKS);
        assert!(Arc::ptr_eq(&a, &b), "same key must share one table");
        assert_eq!(cache.tables(), 1);
        let c = cache.table_for(&scn.profile, &[1, 8]);
        assert!(!Arc::ptr_eq(&a, &c));
        assert_eq!(cache.tables(), 2);
    }
}
