//! # SfLLM — Split Federated Learning for LLMs over Communication Networks
//!
//! Full-system reproduction of *"Efficient Split Federated Learning for
//! Large Language Models over Communication Networks"* (Zhao et al.,
//! 2025) as a three-layer Rust + JAX + Pallas stack.
//!
//! This crate is Layer 3: the SFL coordinator (clients ∥ main server ∥
//! federated server, the paper's Algorithm 1), the wireless-network
//! substrate, the Section-V training-delay model, and the Section-VI
//! joint resource-allocation optimizer (Algorithms 2 and 3). The
//! compute path (split GPT-2 with LoRA adapters, and the fused LoRA
//! Pallas kernel) is AOT-compiled from JAX to HLO text by
//! `python/compile/` and executed through PJRT by [`runtime`].
//!
//! Module map (see DESIGN.md for the full inventory):
//!
//! * [`util`] — PRNG, CLI/TOML/JSON parsing, CSV, stats (offline image:
//!   no external crates beyond `xla` + `anyhow`).
//! * [`analysis`] — `sfllm-lint`: the dependency-free static-analysis
//!   pass (`sfllm lint`) that machine-checks the determinism /
//!   numeric-safety / panic-surface contract (DESIGN.md, PR-7).
//! * [`config`] — typed experiment configuration (paper Table II).
//! * [`model`] — GPT-2 architecture profiles and the per-layer
//!   FLOPs/bytes workload model (paper Table III), LoRA adapter state.
//! * [`net`] — wireless substrate: path loss, shadow fading, FDMA
//!   subchannels, Shannon rates (Eqs. 9/14), and the seeded AR(1)
//!   shadowing process behind the round-varying simulations.
//! * [`delay`] — the Section-V latency model (Eqs. 8–17), the E(r)
//!   convergence-steps model, the [`delay::energy`] model (the paper's
//!   future-work energy axis), and [`delay::eval`]: the cached
//!   delay/energy-evaluation engine the exhaustive searches run on.
//! * [`opt`] — Algorithm 2 (greedy subchannel assignment), the exact
//!   convex power-control solver for P2, the joint split×rank
//!   exhaustive scan (P3×P4, objective-aware), the BCD loop
//!   (Algorithm 3), baselines a–d, the [`opt::objective`] catalogue
//!   (delay / energy / weighted / budget), and the [`opt::policy`]
//!   layer: the `AllocationPolicy` trait + string-keyed
//!   `PolicyRegistry` every experiment selects schemes from.
//! * [`runtime`] — PJRT engine: load HLO-text artifacts, compile once,
//!   execute from the training hot path.
//! * [`data`] — synthetic E2E-style corpus generator + byte tokenizer.
//! * [`coordinator`] — Algorithm 1 end-to-end: threaded clients, main
//!   server, federated server, SGD + FedAvg on host buffers.
//! * [`bench`] — the tracked perf-bench harness (`sfllm bench`):
//!   machine-readable timings for the optimizer/simulator hot paths,
//!   emitted as `BENCH_pr5.json` and validated/uploaded by CI.
//! * [`sim`] — experiment harness: `ScenarioBuilder` (seeded scenario
//!   construction with heterogeneity presets), `SweepRunner`
//!   (multi-threaded policy × grid sweeps with CSV/JSON reports), and
//!   `RoundSimulator` (round-varying channel/compute/membership
//!   dynamics with re-optimization strategies and realized-delay
//!   accounting) — the machinery behind every figure bench and the
//!   CLI subcommands.
//! * [`service`] — the allocator service (PR-8): the policy /
//!   evaluator / dynamic stack as a long-running engine driven by
//!   typed deterministic events (`sfllm serve`), streaming per-round
//!   metrics into pluggable sinks, with versioned bit-exact
//!   checkpoint/resume.

// Hygiene gates (PR-7): the lint contract is also carried by the
// compiler where it can be — no unsafe anywhere in this crate, and no
// lookalike identifiers.
#![forbid(unsafe_code)]
#![deny(non_ascii_idents)]

pub mod analysis;
pub mod bench;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod delay;
pub mod model;
pub mod net;
pub mod opt;
pub mod runtime;
pub mod service;
pub mod sim;
pub mod util;
