//! The tracked perf-bench harness behind the `bench` CLI subcommand.
//!
//! Runs the `micro_hotpath` axes — the optimizer pieces the BCD loop
//! and the round-varying simulator hit per iteration/round — and emits
//! a machine-readable JSON report (`BENCH_pr10.json`) so the repo's
//! perf trajectory is tracked in CI instead of living in bench stdout:
//!
//! * `algorithm2` — the heap-based Algorithm 2 vs the naive reference
//!   scan at K ∈ {5, 100, 1000} on the `many_clients` preset;
//! * `p2_power` — the exact P2 solve, cold vs warm-started
//!   (`solve_power_hinted` with the previous optimum + reused probe
//!   buffers, the BCD loop's steady state);
//! * `solve_cached` — one full proposed-policy solve (Algorithm 3 on
//!   the cached engine) at the same K scaling points;
//! * `grid_scan` — the joint split×rank grid, clone-per-candidate vs
//!   the cached `DelayEvaluator`;
//! * `dynamic` — full round-varying runs per re-opt strategy on the
//!   paper preset (ρ = 0.8), with the actual-solver-call count
//!   (`fresh_solves`) next to the wall time;
//! * `population` — per-round cohort cost on the `metro_population`
//!   preset at population ∈ {10^3, 10^4, 10^5} with the cohort fixed
//!   at 64: the whole point of the lazy population engine is that
//!   `round_ms` is O(cohort), so it must stay flat (CI asserts ≤2x
//!   between 10^3 and 10^5) while `select_us` — the only O(population)
//!   step — is tracked separately;
//! * `faults` — full dynamic runs under each fault-matrix level
//!   (none / light / heavy, `sim::faults::matrix_levels`) on the same
//!   paper-preset run as the `dynamic` axis; the `none` level's
//!   `overhead_vs_clean` against the injector-free `run()` loop is the
//!   zero-fault-overhead number CI gates at <2%;
//! * `service` — the allocator service replaying a pure tick stream vs
//!   the closed-loop `RoundSimulator` on the identical run: the cost of
//!   event dispatch, sink streaming, and per-run session (re)build —
//!   the number EXPERIMENTS.md quotes as service-mode overhead.
//!
//! Timings auto-scale their iteration counts to a small per-axis time
//! budget, so a default run stays CI-friendly (~1–2 min); `--full`
//! quadruples the budgets for lower-variance numbers. The report stamps
//! its provenance (real `unix_time` plus the `rustc --version` string)
//! so cross-PR artifact comparisons know what produced each number. CI
//! validates the JSON, gates on >25% regressions vs the previous PR's
//! artifact, and uploads it (see `.github/workflows/ci.yml`);
//! EXPERIMENTS.md §Perf narrates the trajectory.

// The bench harness is the sanctioned home for wall-clock reads
// (sfllm-lint D002 exempts src/bench.rs; clippy mirror opts out here).
#![allow(clippy::disallowed_methods)]

use std::time::Instant;

use anyhow::{Context, Result};

use crate::delay::{ConvergenceModel, DelayEvaluator, WorkloadCache};
use crate::opt::policy::Proposed;
use crate::opt::{assignment, bcd, power, AllocationPolicy};
use crate::sim::{
    Population, PopulationSimulator, PopulationState, ReOptStrategy, RoundSimulator,
    ScenarioBuilder,
};

/// Options for one harness run.
#[derive(Clone, Debug, Default)]
pub struct BenchOptions {
    /// 4x the per-measurement time budget (lower variance, slower run).
    pub full: bool,
}

pub use crate::util::clock::WallClock;

/// One `algorithm2` scaling point: heap engine vs naive reference.
#[derive(Clone, Debug)]
pub struct Algo2Point {
    pub k: usize,
    pub m: usize,
    pub heap_us: f64,
    pub reference_us: f64,
    pub speedup: f64,
}

/// One P2 point: cold solve vs warm-started (hint + scratch) solve.
#[derive(Clone, Debug)]
pub struct P2Point {
    pub k: usize,
    pub cold_us: f64,
    pub warm_us: f64,
    pub speedup: f64,
}

/// One full proposed-policy solve (BCD on the cached engine).
#[derive(Clone, Debug)]
pub struct SolvePoint {
    pub k: usize,
    pub us: f64,
}

/// The joint split×rank grid, clone-per-candidate vs cached evaluator.
#[derive(Clone, Debug)]
pub struct GridScanPoint {
    pub clone_us: f64,
    pub cached_us: f64,
    pub speedup: f64,
}

/// One dynamic-run strategy point.
#[derive(Clone, Debug)]
pub struct DynPoint {
    pub strategy: String,
    pub ms: f64,
    pub rounds: usize,
    pub fresh_solves: usize,
}

/// One fault-matrix level on the `faults` axis: a full dynamic run on
/// the paper preset under the level's plan (PR-10).
#[derive(Clone, Debug)]
pub struct FaultsPoint {
    pub level: String,
    pub ms: f64,
    pub rounds: usize,
    pub faults_injected: usize,
    pub repair_max: u8,
    /// Per-run time relative to the injector-free `run()` loop. On the
    /// `none` level this is the zero-fault overhead of the PR-10 fault
    /// plumbing, which CI gates at <1.02 (the empty plan constructs no
    /// injector and must execute the same statements `run` always has).
    pub overhead_vs_clean: f64,
}

/// One population scaling point: cohort selection + per-round cost on
/// the `metro_population` preset at a fixed cohort of 64.
#[derive(Clone, Debug)]
pub struct PopPoint {
    pub population: usize,
    pub cohort: usize,
    /// One cohort selection over the whole fleet (the O(population) step).
    pub select_us: f64,
    /// Full-run wall time divided by rounds (must stay O(cohort)).
    pub round_ms: f64,
    pub rounds: usize,
}

/// Service-mode overhead: the allocator service replaying a pure tick
/// stream vs the closed-loop round simulator on the identical run
/// (same preset, policy, strategy, and convergence fit). `serve_ms`
/// includes the per-run session (re)build the service pays on
/// `scenario_loaded`; the workload cache is warm on both sides.
#[derive(Clone, Debug)]
pub struct ServicePoint {
    pub rounds: usize,
    pub sim_ms: f64,
    pub serve_ms: f64,
    /// `serve_ms / sim_ms` — what one run costs through the event loop.
    pub overhead: f64,
}

/// Whole-repo static analysis: one `sfllm-lint` pass (lexing, lexical
/// rules, item parsing, module graph, call graph) over the working
/// tree. Tracks the cost of the PR-9 structural engine so rule or
/// parser additions can't silently blow up CI lint time.
#[derive(Clone, Debug)]
pub struct AnalysisPoint {
    pub files: usize,
    pub lint_ms: f64,
}

/// Everything one harness run measured.
#[derive(Clone, Debug)]
pub struct BenchReport {
    pub algorithm2: Vec<Algo2Point>,
    pub p2_power: Vec<P2Point>,
    pub solve_cached: Vec<SolvePoint>,
    pub grid_scan: GridScanPoint,
    pub dynamic: Vec<DynPoint>,
    pub faults: Vec<FaultsPoint>,
    pub population: Vec<PopPoint>,
    pub service: ServicePoint,
    pub analysis: AnalysisPoint,
    /// `rustc --version` of the toolchain that produced this report
    /// (`"unknown"` when no rustc is on PATH).
    pub rustc: String,
}

/// Seconds per op: one warmup + measurement pass sizes the iteration
/// count to `budget_s`, then the timed loop runs.
fn time_auto<F: FnMut()>(budget_s: f64, mut f: F) -> f64 {
    let t0 = Instant::now();
    f(); // warmup + pilot
    let pilot = t0.elapsed().as_secs_f64().max(1e-9);
    let iters = ((budget_s / pilot) as usize).clamp(2, 2000);
    let t1 = Instant::now();
    for _ in 0..iters {
        f();
    }
    t1.elapsed().as_secs_f64() / iters as f64
}

fn jnum(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.3}")
    } else {
        "null".to_string()
    }
}

/// The K-scaling `algorithm2` axis on the `many_clients` preset: heap
/// engine vs naive reference at each K, with the shared per-point time
/// budget. Exposed on its own so `benches/micro_hotpath.rs` and the
/// JSON harness measure through the *same* loop — the CI-tracked
/// numbers and the human-facing bench cannot drift apart.
pub fn algorithm2_axis(budget_s: f64) -> Result<Vec<Algo2Point>> {
    let mut points = Vec::new();
    for &k in &[5usize, 100, 1000] {
        let scn = scaling_scenario(k)?;
        let m = scn.main_link.subch.len();
        eprintln!("bench: algorithm2 axis K={k} M={m} ...");
        let heap_s = {
            let mut scratch = assignment::AssignScratch::new();
            time_auto(budget_s, || {
                let a = assignment::algorithm2_with(&scn, 6, 4, &mut scratch);
                std::hint::black_box(&a);
            })
        };
        let reference_s = time_auto(budget_s, || {
            let a = assignment::algorithm2_reference(&scn, 6, 4);
            std::hint::black_box(&a);
        });
        points.push(Algo2Point {
            k,
            m,
            heap_us: heap_s * 1e6,
            reference_us: reference_s * 1e6,
            speedup: reference_s / heap_s,
        });
    }
    Ok(points)
}

/// The scaling points' shared scenario: `many_clients` at the given K.
fn scaling_scenario(k: usize) -> Result<crate::delay::Scenario> {
    ScenarioBuilder::preset("many_clients")
        .context("many_clients preset")?
        .clients(k)
        .build()
        .with_context(|| format!("building many_clients K={k}"))
}

/// The toolchain provenance string stamped into the JSON report.
fn rustc_version() -> String {
    std::process::Command::new("rustc")
        .arg("--version")
        .output()
        .ok()
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".to_string())
}

/// The population scaling axis: `metro_population` with the fleet size
/// swept across three decades while the cohort stays at 64. Uses a
/// short fitted convergence model so each probe run finishes in a few
/// dozen rounds; the per-round number is what CI gates on.
pub fn population_axis(budget_s: f64) -> Result<Vec<PopPoint>> {
    let conv = ConvergenceModel::fitted(4.0, 1.0, 0.85);
    let ranks = vec![1usize, 4];
    let mut points = Vec::new();
    for &n in &[1_000usize, 10_000, 100_000] {
        eprintln!("bench: population axis N={n} ...");
        let mut cfg = ScenarioBuilder::preset("metro_population")
            .context("metro_population preset")?
            .into_config();
        cfg.population.size = n;
        cfg.train.ranks = ranks.clone();
        let pop = Population::new(&cfg)
            .with_context(|| format!("population axis: building the N={n} fleet"))?;

        // selection alone — the only step allowed to scale with N
        let mut state = PopulationState::new(pop.size());
        let mut round = 0usize;
        let select_s = time_auto(budget_s, || {
            let cohort = pop.select(&mut state, round);
            std::hint::black_box(&cohort);
            round += 1;
        });

        // full runs: per-round cost must be independent of N
        let cache = WorkloadCache::new();
        let sim = PopulationSimulator::new(&pop, &conv, &cache, &ranks);
        let proposed = Proposed::with_ranks(&ranks);
        let probe = sim
            .run(&proposed, ReOptStrategy::Periodic(5))
            .with_context(|| format!("population axis: probe run at N={n}"))?;
        let rounds = probe.rounds.len().max(1);
        let run_s = time_auto(budget_s.max(0.3), || {
            let r = sim.run(&proposed, ReOptStrategy::Periodic(5)).unwrap();
            std::hint::black_box(r.realized_delay);
        });
        points.push(PopPoint {
            population: n,
            cohort: pop.cohort(),
            select_us: select_s * 1e6,
            round_ms: run_s * 1e3 / rounds as f64,
            rounds,
        });
    }
    Ok(points)
}

/// Run every axis and collect the report.
pub fn run(opts: &BenchOptions) -> Result<BenchReport> {
    let budget = if opts.full { 0.6 } else { 0.15 };
    let conv = ConvergenceModel::paper_default();
    let ranks = [1usize, 2, 4, 6, 8];
    let cache = WorkloadCache::new();

    let algorithm2 = algorithm2_axis(budget)?;

    // --- P2 + solve_cached scaling on many_clients --------------------
    let mut p2_power = Vec::new();
    let mut solve_cached = Vec::new();
    for &k in &[5usize, 100, 1000] {
        let scn = scaling_scenario(k)?;

        // P2 on the Algorithm-2 assignment for this K
        eprintln!("bench: p2_power axis K={k} ...");
        let a2 = assignment::algorithm2(&scn, 6, 4);
        let alloc = crate::delay::Allocation {
            assign_main: a2.assign_main,
            assign_fed: a2.assign_fed,
            psd_main: vec![0.0; scn.main_link.subch.len()],
            psd_fed: vec![0.0; scn.fed_link.subch.len()],
            l_c: 6,
            rank: 4,
        };
        let cold_s = time_auto(budget, || {
            let s = power::solve_power(&scn, &alloc).unwrap();
            std::hint::black_box(s.t1);
        });
        let seed_sol = power::solve_power(&scn, &alloc)?;
        let hint = Some((seed_sol.t1, seed_sol.t3));
        let mut pscratch = power::PowerScratch::default();
        let warm_s = time_auto(budget, || {
            let s = power::solve_power_hinted(&scn, &alloc, hint, &mut pscratch).unwrap();
            std::hint::black_box(s.t1);
        });
        p2_power.push(P2Point {
            k,
            cold_us: cold_s * 1e6,
            warm_us: warm_s * 1e6,
            speedup: cold_s / warm_s,
        });

        // full proposed solve on the cached engine
        eprintln!("bench: solve_cached axis K={k} ...");
        let policy = Proposed::with_ranks(&ranks);
        let solve_s = time_auto(budget.max(0.4), || {
            let out = policy.solve_cached(&scn, &conv, &cache).unwrap();
            std::hint::black_box(out.objective);
        });
        solve_cached.push(SolvePoint { k, us: solve_s * 1e6 });
    }

    // --- joint grid: clone-per-candidate vs cached evaluator ----------
    eprintln!("bench: grid_scan axis ...");
    let scn = ScenarioBuilder::new().build()?;
    let alloc = bcd::initial_alloc(&scn, 6, 4);
    let splits: Vec<usize> = scn.profile.split_candidates().collect();
    let clone_s = time_auto(budget, || {
        let mut best = f64::INFINITY;
        for &l_c in &splits {
            for &r in &ranks {
                let mut cand = alloc.clone();
                cand.l_c = l_c;
                cand.rank = r;
                best = best.min(scn.total_delay(&cand, &conv));
            }
        }
        std::hint::black_box(best);
    });
    let cached_s = time_auto(budget, || {
        let ev = DelayEvaluator::new(&scn, &alloc, &conv, cache.table_for(&scn.profile, &ranks));
        std::hint::black_box(ev.best_split_rank());
    });
    let grid_scan = GridScanPoint {
        clone_us: clone_s * 1e6,
        cached_us: cached_s * 1e6,
        speedup: clone_s / cached_s,
    };

    // --- dynamic runs per strategy -------------------------------------
    let scn_dyn = ScenarioBuilder::new()
        .channel_correlation(0.8)
        .dynamics_seed(7)
        .build()?;
    let dyn_cache = WorkloadCache::new();
    let ranks_vec: Vec<usize> = ranks.to_vec();
    let sim = RoundSimulator::new(&scn_dyn, &conv, &dyn_cache, &ranks_vec);
    let proposed = Proposed::with_ranks(&ranks_vec);
    let mut dynamic = Vec::new();
    for strategy in [
        ReOptStrategy::OneShot,
        ReOptStrategy::Periodic(5),
        ReOptStrategy::EveryRound,
    ] {
        eprintln!("bench: dynamic axis {} ...", strategy.label());
        let probe = sim.run(&proposed, strategy)?;
        let s = time_auto(budget.max(0.3), || {
            let r = sim.run(&proposed, strategy).unwrap();
            std::hint::black_box(r.realized_delay);
        });
        dynamic.push(DynPoint {
            strategy: strategy.label(),
            ms: s * 1e3,
            rounds: probe.rounds.len(),
            fresh_solves: probe.fresh_solves,
        });
    }

    // --- fault-matrix levels on the same dynamic run --------------------
    // the `none` level gates PR-10's promise that the fault plumbing is
    // free when unused: the empty plan constructs no injector, so its
    // per-run time must sit within noise of the plain `run()` loop
    let clean_s = time_auto(budget.max(0.3), || {
        let r = sim.run(&proposed, ReOptStrategy::Periodic(5)).unwrap();
        std::hint::black_box(r.realized_delay);
    });
    let mut faults = Vec::new();
    for (name, plan) in crate::sim::faults::matrix_levels(0xFA17) {
        eprintln!("bench: faults axis level {name} ...");
        let probe = sim.run_faulted(&proposed, ReOptStrategy::Periodic(5), &plan)?;
        let s = time_auto(budget.max(0.3), || {
            let r = sim
                .run_faulted(&proposed, ReOptStrategy::Periodic(5), &plan)
                .unwrap();
            std::hint::black_box(r.realized_delay);
        });
        faults.push(FaultsPoint {
            level: name.to_string(),
            ms: s * 1e3,
            rounds: probe.rounds.len(),
            faults_injected: probe.faults_injected,
            repair_max: probe.repair_max,
            overhead_vs_clean: s / clean_s,
        });
    }

    // --- population scaling at fixed cohort ----------------------------
    let population = population_axis(budget)?;

    // --- service replay vs the closed loop ------------------------------
    // identical run both ways: same preset config, same policy bits
    // (the registry's "proposed" is Proposed::with_ranks), same
    // strategy, same short convergence fit
    eprintln!("bench: service axis ...");
    let spec = {
        let mut s = crate::service::RunSpec::preset("paper");
        s.strategy = "periodic:5".to_string();
        s.conv = Some([4.0, 1.0, 0.85]);
        s
    };
    let svc_conv = spec.conv_model();
    let svc_cfg = spec.build_config()?;
    let scn_svc = ScenarioBuilder::from_config(svc_cfg.clone()).build()?;
    let svc_cache = WorkloadCache::new();
    let svc_sim = RoundSimulator::new(&scn_svc, &svc_conv, &svc_cache, &svc_cfg.train.ranks);
    let svc_proposed = Proposed::with_ranks(&svc_cfg.train.ranks);
    let sim_probe = svc_sim.run(&svc_proposed, ReOptStrategy::Periodic(5))?;
    let sim_s = time_auto(budget.max(0.3), || {
        let r = svc_sim.run(&svc_proposed, ReOptStrategy::Periodic(5)).unwrap();
        std::hint::black_box(r.realized_delay);
    });
    let open = crate::service::Event::ScenarioLoaded(spec);
    let mut svc = crate::service::AllocatorService::new()
        .with_sink(Box::new(crate::service::AggregateSink::new()));
    let serve_s = time_auto(budget.max(0.3), || {
        // a finished run may be reopened: the service's workload cache
        // stays warm across sessions, mirroring the long-running story
        svc.process(&open).unwrap();
        while !svc.is_finished() {
            svc.process(&crate::service::Event::RoundTick).unwrap();
        }
        std::hint::black_box(svc.events_consumed());
    });
    let service = ServicePoint {
        rounds: sim_probe.rounds.len(),
        sim_ms: sim_s * 1e3,
        serve_ms: serve_s * 1e3,
        overhead: serve_s / sim_s,
    };

    // --- whole-repo static analysis -------------------------------------
    // the full lint pipeline (lexical + graph + call-graph) over the
    // working tree; nulls when the tree is not available (e.g. an
    // installed binary run outside the repo)
    eprintln!("bench: analysis axis ...");
    let analysis = match crate::analysis::detect_root() {
        Ok(root) => {
            let lint_opts = crate::analysis::LintOptions::default();
            let probe = crate::analysis::lint_repo(&root, &lint_opts)?;
            let lint_s = time_auto(budget.max(0.3), || {
                let rep = crate::analysis::lint_repo(&root, &lint_opts).unwrap();
                std::hint::black_box(rep.findings.len());
            });
            AnalysisPoint { files: probe.files_scanned, lint_ms: lint_s * 1e3 }
        }
        Err(_) => AnalysisPoint { files: 0, lint_ms: f64::NAN },
    };

    Ok(BenchReport {
        algorithm2,
        p2_power,
        solve_cached,
        grid_scan,
        dynamic,
        faults,
        population,
        service,
        analysis,
        rustc: rustc_version(),
    })
}

impl BenchReport {
    /// Human-readable summary.
    pub fn print(&self) {
        println!("perf bench (tracked axes — see EXPERIMENTS.md §Perf):");
        println!("\nalgorithm2: heap engine vs naive reference (many_clients preset):");
        for p in &self.algorithm2 {
            println!(
                "  K={:<5} M={:<5} heap {:>10.2} us   reference {:>10.2} us   speedup {:>6.1}x",
                p.k, p.m, p.heap_us, p.reference_us, p.speedup
            );
        }
        println!("\nP2 exact solve: cold vs warm-started (hint + probe scratch):");
        for p in &self.p2_power {
            println!(
                "  K={:<5} cold {:>10.2} us   warm {:>10.2} us   speedup {:>6.2}x",
                p.k, p.cold_us, p.warm_us, p.speedup
            );
        }
        println!("\nfull proposed solve (Algorithm 3, cached engine):");
        for p in &self.solve_cached {
            println!("  K={:<5} {:>12.2} us/solve", p.k, p.us);
        }
        println!("\njoint split x rank grid:");
        println!(
            "  clone-per-candidate {:>10.2} us   cached evaluator {:>10.2} us   speedup {:>6.1}x",
            self.grid_scan.clone_us, self.grid_scan.cached_us, self.grid_scan.speedup
        );
        println!("\ndynamic runs (paper preset, rho=0.8):");
        for p in &self.dynamic {
            println!(
                "  {:<16} {:>10.2} ms/run   ({} rounds, {} fresh solves)",
                p.strategy, p.ms, p.rounds, p.fresh_solves
            );
        }
        println!("\nfault-matrix levels (paper preset, periodic:5):");
        for p in &self.faults {
            println!(
                "  {:<8} {:>10.2} ms/run   overhead vs clean {:>6.3}x   \
                 ({} rounds, {} faults, max repair tier {})",
                p.level, p.ms, p.overhead_vs_clean, p.rounds, p.faults_injected, p.repair_max
            );
        }
        println!("\npopulation scaling (metro_population, cohort fixed):");
        for p in &self.population {
            println!(
                "  N={:<7} cohort={:<4} select {:>10.2} us   round {:>10.3} ms   ({} rounds)",
                p.population, p.cohort, p.select_us, p.round_ms, p.rounds
            );
        }
        println!("\nservice replay vs closed-loop simulator (identical run):");
        println!(
            "  sim {:>10.3} ms/run   serve {:>10.3} ms/run   overhead {:>6.2}x   ({} rounds)",
            self.service.sim_ms, self.service.serve_ms, self.service.overhead, self.service.rounds
        );
        println!("\nwhole-repo static analysis (lexical + graph + call-graph lint):");
        println!(
            "  lint {:>10.3} ms/pass   ({} files)",
            self.analysis.lint_ms, self.analysis.files
        );
        println!("\ntoolchain: {}", self.rustc);
    }

    /// The machine-readable report (schema `sfllm-bench-v1`).
    pub fn to_json_string(&self) -> String {
        let algorithm2: Vec<String> = self
            .algorithm2
            .iter()
            .map(|p| {
                format!(
                    "{{\"k\": {}, \"m\": {}, \"heap_us\": {}, \"reference_us\": {}, \"speedup\": {}}}",
                    p.k,
                    p.m,
                    jnum(p.heap_us),
                    jnum(p.reference_us),
                    jnum(p.speedup)
                )
            })
            .collect();
        let p2: Vec<String> = self
            .p2_power
            .iter()
            .map(|p| {
                format!(
                    "{{\"k\": {}, \"cold_us\": {}, \"warm_us\": {}, \"speedup\": {}}}",
                    p.k,
                    jnum(p.cold_us),
                    jnum(p.warm_us),
                    jnum(p.speedup)
                )
            })
            .collect();
        let solve: Vec<String> = self
            .solve_cached
            .iter()
            .map(|p| format!("{{\"k\": {}, \"us\": {}}}", p.k, jnum(p.us)))
            .collect();
        let dynamic: Vec<String> = self
            .dynamic
            .iter()
            .map(|p| {
                format!(
                    "{{\"strategy\": \"{}\", \"ms\": {}, \"rounds\": {}, \"fresh_solves\": {}}}",
                    p.strategy,
                    jnum(p.ms),
                    p.rounds,
                    p.fresh_solves
                )
            })
            .collect();
        let faults: Vec<String> = self
            .faults
            .iter()
            .map(|p| {
                format!(
                    "{{\"level\": \"{}\", \"ms\": {}, \"rounds\": {}, \
                     \"faults_injected\": {}, \"repair_max\": {}, \
                     \"overhead_vs_clean\": {}}}",
                    p.level,
                    jnum(p.ms),
                    p.rounds,
                    p.faults_injected,
                    p.repair_max,
                    jnum(p.overhead_vs_clean)
                )
            })
            .collect();
        let population: Vec<String> = self
            .population
            .iter()
            .map(|p| {
                format!(
                    "{{\"population\": {}, \"cohort\": {}, \"select_us\": {}, \
                     \"round_ms\": {}, \"rounds\": {}}}",
                    p.population,
                    p.cohort,
                    jnum(p.select_us),
                    jnum(p.round_ms),
                    p.rounds
                )
            })
            .collect();
        let unix = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_secs())
            .unwrap_or(0);
        let service = format!(
            "{{\"rounds\": {}, \"sim_ms\": {}, \"serve_ms\": {}, \"overhead\": {}}}",
            self.service.rounds,
            jnum(self.service.sim_ms),
            jnum(self.service.serve_ms),
            jnum(self.service.overhead)
        );
        let analysis = format!(
            "{{\"files\": {}, \"lint_ms\": {}}}",
            self.analysis.files,
            jnum(self.analysis.lint_ms)
        );
        let rustc = self.rustc.replace('\\', "\\\\").replace('"', "\\\"");
        format!(
            "{{\n  \"schema\": \"sfllm-bench-v1\",\n  \"pr\": \"pr10\",\n  \
             \"provenance\": \"generated by `sfllm bench`\",\n  \"unix_time\": {unix},\n  \
             \"rustc\": \"{rustc}\",\n  \
             \"axes\": {{\n    \"algorithm2\": [{}],\n    \"p2_power\": [{}],\n    \
             \"solve_cached\": [{}],\n    \"grid_scan\": {{\"clone_us\": {}, \"cached_us\": {}, \
             \"speedup\": {}}},\n    \"dynamic\": [{}],\n    \"faults\": [{}],\n    \
             \"population\": [{}],\n    \
             \"service\": {service},\n    \"analysis\": {analysis}\n  }}\n}}\n",
            algorithm2.join(", "),
            p2.join(", "),
            solve.join(", "),
            jnum(self.grid_scan.clone_us),
            jnum(self.grid_scan.cached_us),
            jnum(self.grid_scan.speedup),
            dynamic.join(", "),
            faults.join(", "),
            population.join(", ")
        )
    }

    /// Write the JSON report (parent directories created as needed).
    pub fn write_json(&self, path: &str) -> Result<()> {
        if let Some(parent) = std::path::Path::new(path).parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)
                    .with_context(|| format!("creating {}", parent.display()))?;
            }
        }
        std::fs::write(path, self.to_json_string()).with_context(|| format!("writing {path}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_json_is_parseable_and_carries_the_axis_keys() {
        // a hand-built report (running the axes is a bench, not a test)
        let rep = BenchReport {
            algorithm2: vec![Algo2Point {
                k: 5,
                m: 1024,
                heap_us: 10.0,
                reference_us: 100.0,
                speedup: 10.0,
            }],
            p2_power: vec![P2Point { k: 5, cold_us: 50.0, warm_us: 25.0, speedup: 2.0 }],
            solve_cached: vec![SolvePoint { k: 5, us: 1234.5 }],
            grid_scan: GridScanPoint { clone_us: 9.0, cached_us: 3.0, speedup: 3.0 },
            dynamic: vec![DynPoint {
                strategy: "every_round".to_string(),
                ms: 42.0,
                rounds: 28,
                fresh_solves: 27,
            }],
            faults: vec![FaultsPoint {
                level: "none".to_string(),
                ms: 41.8,
                rounds: 28,
                faults_injected: 0,
                repair_max: 0,
                overhead_vs_clean: 1.005,
            }],
            population: vec![PopPoint {
                population: 100_000,
                cohort: 64,
                select_us: 120.0,
                round_ms: 3.5,
                rounds: 30,
            }],
            service: ServicePoint {
                rounds: 8,
                sim_ms: 4.0,
                serve_ms: 4.4,
                overhead: 1.1,
            },
            analysis: AnalysisPoint { files: 60, lint_ms: 80.0 },
            rustc: "rustc 1.0.0 (\"quoted\")".to_string(),
        };
        let j = crate::util::json::Json::parse(&rep.to_json_string()).unwrap();
        assert_eq!(j.get("schema").unwrap().as_str().unwrap(), "sfllm-bench-v1");
        assert_eq!(j.get("pr").unwrap().as_str().unwrap(), "pr10");
        // provenance: a real timestamp plus the (escaped) toolchain string
        assert!(j.get("unix_time").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(j.get("rustc").unwrap().as_str().unwrap(), "rustc 1.0.0 (\"quoted\")");
        let axes = j.get("axes").unwrap();
        for key in [
            "algorithm2",
            "p2_power",
            "solve_cached",
            "grid_scan",
            "dynamic",
            "faults",
            "population",
            "service",
            "analysis",
        ] {
            assert!(axes.get(key).is_ok(), "missing axis {key}");
        }
        let a2 = &axes.get("algorithm2").unwrap().as_arr().unwrap()[0];
        assert_eq!(a2.get("k").unwrap().as_usize().unwrap(), 5);
        assert!(a2.get("speedup").unwrap().as_f64().unwrap() > 1.0);
        let d = &axes.get("dynamic").unwrap().as_arr().unwrap()[0];
        assert_eq!(d.get("fresh_solves").unwrap().as_usize().unwrap(), 27);
        let f = &axes.get("faults").unwrap().as_arr().unwrap()[0];
        assert_eq!(f.get("level").unwrap().as_str().unwrap(), "none");
        assert_eq!(f.get("faults_injected").unwrap().as_usize().unwrap(), 0);
        let overhead = f.get("overhead_vs_clean").unwrap().as_f64().unwrap();
        assert!(overhead > 0.9 && overhead < 1.02, "zero-fault overhead {overhead}");
        let p = &axes.get("population").unwrap().as_arr().unwrap()[0];
        assert_eq!(p.get("population").unwrap().as_usize().unwrap(), 100_000);
        assert_eq!(p.get("cohort").unwrap().as_usize().unwrap(), 64);
        assert!(p.get("round_ms").unwrap().as_f64().unwrap() > 0.0);
        let s = axes.get("service").unwrap();
        assert_eq!(s.get("rounds").unwrap().as_usize().unwrap(), 8);
        assert!(s.get("overhead").unwrap().as_f64().unwrap() > 1.0);
        let a = axes.get("analysis").unwrap();
        assert_eq!(a.get("files").unwrap().as_usize().unwrap(), 60);
        assert!(a.get("lint_ms").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn rustc_version_never_panics_and_is_nonempty() {
        assert!(!rustc_version().is_empty());
    }
}
