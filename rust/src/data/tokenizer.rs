//! Byte-level tokenizer and fixed-shape batcher.
//!
//! Vocabulary = raw bytes (0–255); token 0 doubles as padding. Each
//! sample is laid out `MR § text` (§ = 0x1F unit separator) and padded
//! to the model sequence length. The loss mask is 1.0 only on the text
//! span — completion-style fine-tuning: the model learns to realize the
//! MR, not to predict the MR itself.

use crate::data::corpus::E2eSample;
use crate::util::rng::Rng;

/// Separator byte between MR and realization.
pub const SEP: u8 = 0x1F;
/// Padding token.
pub const PAD: i32 = 0;

/// Byte-level tokenizer (stateless; the struct namespaces the API).
pub struct Tokenizer;

impl Tokenizer {
    /// Tokenize one sample to exactly `seq` tokens + loss mask.
    /// Returns None if the sample cannot fit.
    pub fn encode(sample: &E2eSample, seq: usize) -> Option<(Vec<i32>, Vec<f32>)> {
        let mr = sample.mr.as_bytes();
        let tx = sample.text.as_bytes();
        let used = mr.len() + 1 + tx.len();
        if used > seq {
            return None;
        }
        let mut tokens = Vec::with_capacity(seq);
        let mut mask = Vec::with_capacity(seq);
        for &b in mr {
            tokens.push(b as i32);
            mask.push(0.0);
        }
        tokens.push(SEP as i32);
        mask.push(0.0);
        for &b in tx {
            tokens.push(b as i32);
            mask.push(1.0);
        }
        while tokens.len() < seq {
            tokens.push(PAD);
            mask.push(0.0);
        }
        Some((tokens, mask))
    }
}

/// One fixed-shape batch: tokens [B*T] and mask [B*T], flattened row-major.
#[derive(Clone, Debug)]
pub struct Batch {
    pub tokens: Vec<i32>,
    pub mask: Vec<f32>,
    pub batch: usize,
    pub seq: usize,
}

/// Cycling mini-batch sampler over a client's shard.
pub struct Batcher {
    encoded: Vec<(Vec<i32>, Vec<f32>)>,
    batch: usize,
    seq: usize,
    rng: Rng,
}

/// Clamp byte tokens into a model vocabulary by modulo (identity for
/// vocab >= 256 — the tiny model's byte vocab).
fn clamp_vocab(tokens: &mut [i32], vocab: usize) {
    if vocab < 256 {
        for t in tokens.iter_mut() {
            *t %= vocab as i32;
        }
    }
}

impl Batcher {
    /// Encode a shard; samples that don't fit `seq` are dropped (none
    /// are, for the built-in generator + tiny model).
    pub fn new(shard: &[E2eSample], batch: usize, seq: usize, rng: Rng) -> Batcher {
        Self::with_vocab(shard, batch, seq, 256, rng)
    }

    /// Like [`Batcher::new`] but clamps tokens into `vocab` (needed for
    /// the reduced-vocabulary `micro` test variant).
    pub fn with_vocab(
        shard: &[E2eSample],
        batch: usize,
        seq: usize,
        vocab: usize,
        rng: Rng,
    ) -> Batcher {
        let encoded: Vec<_> = shard
            .iter()
            .filter_map(|s| {
                Tokenizer::encode(s, seq).map(|(mut t, m)| {
                    clamp_vocab(&mut t, vocab);
                    (t, m)
                })
            })
            .collect();
        assert!(!encoded.is_empty(), "empty shard after encoding");
        Batcher {
            encoded,
            batch,
            seq,
            rng,
        }
    }

    pub fn len(&self) -> usize {
        self.encoded.len()
    }

    pub fn is_empty(&self) -> bool {
        self.encoded.is_empty()
    }

    /// Sample one mini-batch (with replacement — the paper's "randomly
    /// selects a mini-batch").
    pub fn next_batch(&mut self) -> Batch {
        let mut tokens = Vec::with_capacity(self.batch * self.seq);
        let mut mask = Vec::with_capacity(self.batch * self.seq);
        for _ in 0..self.batch {
            let i = self.rng.below(self.encoded.len());
            tokens.extend_from_slice(&self.encoded[i].0);
            mask.extend_from_slice(&self.encoded[i].1);
        }
        Batch {
            tokens,
            mask,
            batch: self.batch,
            seq: self.seq,
        }
    }

    /// Deterministic sequential batches for evaluation (wraps around).
    pub fn eval_batch(&self, start: usize) -> Batch {
        let mut tokens = Vec::with_capacity(self.batch * self.seq);
        let mut mask = Vec::with_capacity(self.batch * self.seq);
        for j in 0..self.batch {
            let i = (start + j) % self.encoded.len();
            tokens.extend_from_slice(&self.encoded[i].0);
            mask.extend_from_slice(&self.encoded[i].1);
        }
        Batch {
            tokens,
            mask,
            batch: self.batch,
            seq: self.seq,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::generate_corpus;

    fn sample() -> E2eSample {
        E2eSample {
            mr: "name[Aromi], food[Thai], price[cheap]".into(),
            text: "Aromi serves cheap Thai food.".into(),
            food_id: 0,
        }
    }

    #[test]
    fn encode_layout() {
        let (tokens, mask) = Tokenizer::encode(&sample(), 72).unwrap();
        assert_eq!(tokens.len(), 72);
        assert_eq!(mask.len(), 72);
        let mr_len = sample().mr.len();
        // MR span unmasked
        assert!(mask[..mr_len].iter().all(|&m| m == 0.0));
        assert_eq!(tokens[mr_len], SEP as i32);
        // text span masked 1.0
        let text_len = sample().text.len();
        assert!(mask[mr_len + 1..mr_len + 1 + text_len].iter().all(|&m| m == 1.0));
        // padding
        assert!(tokens[mr_len + 1 + text_len..].iter().all(|&t| t == PAD));
        // tokens are valid bytes
        assert!(tokens.iter().all(|&t| (0..256).contains(&t)));
    }

    #[test]
    fn too_long_sample_rejected() {
        assert!(Tokenizer::encode(&sample(), 10).is_none());
    }

    #[test]
    fn batcher_shapes_and_determinism() {
        let mut rng = Rng::new(5);
        let corpus = generate_corpus(40, &mut rng);
        let mut b1 = Batcher::new(&corpus, 4, 64, Rng::new(9));
        let mut b2 = Batcher::new(&corpus, 4, 64, Rng::new(9));
        let x1 = b1.next_batch();
        let x2 = b2.next_batch();
        assert_eq!(x1.tokens, x2.tokens);
        assert_eq!(x1.tokens.len(), 4 * 64);
        assert_eq!(x1.mask.len(), 4 * 64);
    }

    #[test]
    fn eval_batches_cycle_deterministically() {
        let mut rng = Rng::new(6);
        let corpus = generate_corpus(10, &mut rng);
        let b = Batcher::new(&corpus, 4, 64, Rng::new(0));
        let e1 = b.eval_batch(0);
        let e2 = b.eval_batch(0);
        assert_eq!(e1.tokens, e2.tokens);
        // wrap-around reuses early samples
        let e3 = b.eval_batch(8);
        assert_eq!(&e3.tokens[2 * 64..3 * 64], &e1.tokens[..64]);
    }
}
