//! Synthetic E2E-style corpus generator.
//!
//! Mirrors the E2E NLG challenge schema: a meaning representation (MR)
//! of attribute slots and a natural-language realization. Slot pools
//! and templates are chosen so every rendered sample fits the tiny
//! model's 64-byte window.

use crate::util::rng::Rng;

/// One (meaning representation, utterance) pair.
#[derive(Clone, Debug, PartialEq)]
pub struct E2eSample {
    pub mr: String,
    pub text: String,
    /// Food-slot index (used for non-IID sharding).
    pub food_id: usize,
}

// Slot pools sized so that `MR § text` always fits the tiny model's
// 64-byte window (names <= 6 bytes, foods <= 7, prices <= 8).
const NAMES: &[&str] = &[
    "Aromi", "Bento", "Cocum", "Eagle", "Lilly", "Rex", "Sole", "Strada",
    "Vaults", "Zizzi",
];
const FOODS: &[&str] = &[
    "Thai", "Chinese", "French", "Indian", "Italian", "Turkish", "English",
];
const PRICES: &[&str] = &["cheap", "moderate", "high"];
const AREAS: &[&str] = &["centre", "river"];
const RATINGS: &[&str] = &["low", "average", "high"];

/// Render one sample from slot indices (deterministic given indices).
fn render(name: usize, food: usize, price: usize, area: usize, rating: usize, tpl: usize) -> E2eSample {
    let (n, f, p, a, r) = (NAMES[name], FOODS[food], PRICES[price], AREAS[area], RATINGS[rating]);
    let mr = format!("{n}|{f}|{p}");
    let text = match tpl {
        0 => format!("{n} serves {p} {f} food."),
        1 => format!("{n} is a {p} {f} spot."),
        2 => format!("Try {n} for {f} food."),
        3 => format!("{n} has {r} rated {f}."),
        _ => format!("{n} is {p}, at the {a}."),
    };
    E2eSample {
        mr,
        text,
        food_id: food,
    }
}

/// Generate `n` samples with a seeded RNG.
pub fn generate_corpus(n: usize, rng: &mut Rng) -> Vec<E2eSample> {
    (0..n)
        .map(|_| {
            render(
                rng.below(NAMES.len()),
                rng.below(FOODS.len()),
                rng.below(PRICES.len()),
                rng.below(AREAS.len()),
                rng.below(RATINGS.len()),
                rng.below(5),
            )
        })
        .collect()
}

/// Short patterned byte sequences for tiny-window variants (the
/// `micro` integration model has seq = 8: real E2E samples cannot fit,
/// so plumbing tests train on these instead). Empty MR; the text is a
/// learnable repeated-letter pattern.
pub fn generate_byte_corpus(n: usize, max_len: usize, rng: &mut Rng) -> Vec<E2eSample> {
    const ALPHA: &[u8] = b"abcd";
    (0..n)
        .map(|_| {
            let a = ALPHA[rng.below(ALPHA.len())];
            let b = ALPHA[rng.below(ALPHA.len())];
            let len = 2 + rng.below(max_len.saturating_sub(3).max(1));
            let text: String = (0..len)
                .map(|i| if i % 2 == 0 { a as char } else { b as char })
                .collect();
            E2eSample {
                mr: String::new(),
                text,
                food_id: (a % 4) as usize,
            }
        })
        .collect()
}

/// IID sharding: round-robin after a seeded shuffle.
pub fn shard_iid(corpus: &[E2eSample], k: usize, rng: &mut Rng) -> Vec<Vec<E2eSample>> {
    let mut idx: Vec<usize> = (0..corpus.len()).collect();
    rng.shuffle(&mut idx);
    let mut shards = vec![Vec::new(); k];
    for (pos, &i) in idx.iter().enumerate() {
        shards[pos % k].push(corpus[i].clone());
    }
    shards
}

/// Non-IID sharding by food type: client k predominantly sees foods
/// congruent to k (a simple label-skew partition, the heterogeneity the
/// paper's FedAvg aggregation is claimed to absorb).
pub fn shard_by_food(corpus: &[E2eSample], k: usize) -> Vec<Vec<E2eSample>> {
    let mut shards = vec![Vec::new(); k];
    for s in corpus {
        shards[s.food_id % k].push(s.clone());
    }
    shards
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn samples_fit_tiny_window() {
        let mut rng = Rng::new(1);
        for s in generate_corpus(500, &mut rng) {
            let total = s.mr.len() + 1 + s.text.len(); // + separator
            assert!(total <= 64, "sample too long ({total}): {s:?}");
            assert!(s.text.len() >= 10);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = generate_corpus(50, &mut Rng::new(7));
        let b = generate_corpus(50, &mut Rng::new(7));
        assert_eq!(a, b);
    }

    #[test]
    fn corpus_is_diverse() {
        let mut rng = Rng::new(2);
        let c = generate_corpus(200, &mut rng);
        let uniq: std::collections::BTreeSet<&str> = c.iter().map(|s| s.text.as_str()).collect();
        assert!(uniq.len() > 100, "only {} unique samples", uniq.len());
    }

    #[test]
    fn iid_shards_balanced() {
        let mut rng = Rng::new(3);
        let c = generate_corpus(103, &mut rng);
        let shards = shard_iid(&c, 5, &mut rng);
        let sizes: Vec<usize> = shards.iter().map(Vec::len).collect();
        assert_eq!(sizes.iter().sum::<usize>(), 103);
        assert!(sizes.iter().all(|&s| (20..=21).contains(&s)), "{sizes:?}");
    }

    #[test]
    fn food_shards_are_skewed() {
        let mut rng = Rng::new(4);
        let c = generate_corpus(700, &mut rng);
        let shards = shard_by_food(&c, 3);
        // every shard sees only foods with id % 3 == shard index
        for (k, shard) in shards.iter().enumerate() {
            assert!(!shard.is_empty());
            assert!(shard.iter().all(|s| s.food_id % 3 == k));
        }
    }
}
