//! Data substrate: a synthetic E2E-NLG-style corpus.
//!
//! The paper fine-tunes on the E2E dataset (restaurant meaning
//! representations → natural-language utterances). That dataset is not
//! available offline, so we generate a faithful synthetic counterpart
//! from the same schema — attribute slots (name, eatType, food,
//! priceRange, area, rating) filled from pools and rendered through
//! templated realizations (DESIGN.md §2 records this substitution).
//!
//! Tokenization is byte-level (vocab 256 — matching the `tiny` model);
//! each training sample is `MR § utterance` padded to the model's
//! sequence length, with the loss mask covering only the utterance
//! (completion-style fine-tuning, as LoRA's E2E setup does).

pub mod corpus;
pub mod tokenizer;

pub use corpus::{generate_byte_corpus, generate_corpus, shard_by_food, shard_iid, E2eSample};
pub use tokenizer::{Batch, Batcher, Tokenizer};
