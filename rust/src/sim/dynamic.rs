//! Round-varying simulation: realized-delay accounting over a drifting
//! environment, plus re-optimization strategies on top of any
//! [`AllocationPolicy`].
//!
//! The static model scores an allocation by Eq. 17's *prediction*
//! `E(r)·(I·T_local + max_k T_k^f)` against one frozen channel draw.
//! [`RoundSimulator`] instead plays the fine-tuning run out round by
//! round: per-client shadowing evolves as a seeded AR(1) Gauss–Markov
//! process ([`crate::net::ChannelProcess`]), client compute optionally
//! jitters, clients drop out and return — and the run accumulates the
//! **realized** total delay `Σ_e w_e·(I·T_local(e) + max_k T_k^f(e))`
//! alongside the **realized** total energy `Σ_e w_e·(I·E_round(e))`:
//! dropped clients spend nothing in their absent rounds, and compute
//! jitter rescales compute energy via `f²` (the delay scales `1/f`).
//!
//! Accounting details that make the engine exact where the static
//! model applies:
//!
//! * **Progress.** Each round at rank r advances convergence by
//!   `1/E(r)`; the run ends when one unit of progress is reached, the
//!   final round weighted by the remaining fraction. A rank change
//!   rescales the remaining rounds by `E(r_new)/E(r_old)`.
//! * **Run-length accumulation.** Consecutive rounds with an identical
//!   realized delay collapse into one `weight × delay` product, so a
//!   frozen environment degenerates to the closed-form `E(r)·d` — the
//!   realized total of a frozen run under [`ReOptStrategy::OneShot`]
//!   is **bit-identical** to `Scenario::total_delay`. Energy gets its
//!   own run-length segments, so the frozen realized energy is equally
//!   bit-identical to `delay::energy::total_energy`'s
//!   `E(r)·(I·E_round)` (both property-tested in
//!   `rust/tests/prop_dynamic.rs`).
//!
//! Re-solves go through the same [`crate::delay::WorkloadCache`] for
//! the whole run, so only the channel-dependent half of the evaluator
//! (per-client rates) is ever recomputed. When a strategy does
//! re-solve, the adopted allocation is the best of {fresh solve,
//! incumbent, round-0 allocation} under the *current* channel, so
//! re-optimizing can never do worse than holding still on any round.
//!
//! **Delta re-optimization.** Two layers make per-round work
//! proportional to what actually changed, without moving a single bit
//! of any result (both property-tested in `rust/tests/prop_dynamic.rs`):
//!
//! * Round costs are evaluated on a [`ColumnCache`]: each candidate
//!   allocation's per-client rate/power columns persist across rounds,
//!   and only the rate rows of clients whose channel gain moved are
//!   recomputed (powers never read gains). A frozen channel recomputes
//!   nothing.
//! * The fresh solve is **memoized against environment drift**: the
//!   policy is a deterministic function of the scenario, so while no
//!   gain and no compute capability has changed since the last actual
//!   solve, the "fresh" candidate *is* the memoized allocation —
//!   re-solving would reproduce it bit for bit. A frozen ρ=1/σ=0 run
//!   under `EveryRound` therefore performs **zero** solver work beyond
//!   the adoption compare ([`DynamicOutcome::fresh_solves`] stays 0
//!   while [`DynamicOutcome::resolves`] still counts the strategy's
//!   decisions), and produces byte-identical records to the eager
//!   implementation.
//!
//! [`DynamicPolicy`] adapts a `(policy, strategy)` pair back into an
//! [`AllocationPolicy`] whose objective is the realized delay, which
//! plugs the dynamic engine straight into [`crate::sim::SweepRunner`]
//! grids (dynamics axes: `SweepAxis::channel_correlation`,
//! `SweepAxis::dropout`, `SweepAxis::reopt_period`) and the `dynamic`
//! CLI subcommand.

use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use crate::delay::{
    Allocation, ColumnCache, ConvergenceModel, DelayEvaluator, Scenario, WorkloadCache,
};
use crate::model::WorkloadTable;
use crate::opt::policy::{AllocationPolicy, PolicyOutcome};
use crate::opt::Objective;
use crate::sim::engine::{DriftEnv, RoundCore, StepCtx};
use crate::sim::faults::{FaultInjector, FaultPlan};

/// When (and whether) to re-run the allocation policy as the
/// environment drifts.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ReOptStrategy {
    /// Solve once on the initial channel, hold the allocation for the
    /// whole run (the static model's implicit assumption).
    OneShot,
    /// Re-solve at the start of every round.
    EveryRound,
    /// Re-solve every J rounds (J >= 1; `Periodic(1)` == `EveryRound`).
    Periodic(usize),
    /// Re-solve only when the incumbent's realized round delay exceeds
    /// `(1 + threshold) ×` its value at the last solve.
    OnDegrade(f64),
}

impl ReOptStrategy {
    /// Parse a CLI/config spec: `one_shot`, `every_round`,
    /// `periodic:<J>`, `on_degrade:<threshold>`.
    pub fn parse(spec: &str) -> Result<ReOptStrategy> {
        let spec = spec.trim();
        let (head, arg) = match spec.split_once(':') {
            Some((h, a)) => (h.trim(), Some(a.trim())),
            None => (spec, None),
        };
        Ok(match (head, arg) {
            ("one_shot", None) => ReOptStrategy::OneShot,
            ("every_round", None) => ReOptStrategy::EveryRound,
            ("periodic", Some(a)) => {
                let j: usize = a
                    .parse()
                    .map_err(|e| anyhow!("bad periodic period '{a}': {e}"))?;
                if j == 0 {
                    bail!("periodic re-opt period must be >= 1");
                }
                ReOptStrategy::Periodic(j)
            }
            ("on_degrade", Some(a)) => {
                let th: f64 = a
                    .parse()
                    .map_err(|e| anyhow!("bad on_degrade threshold '{a}': {e}"))?;
                if !th.is_finite() || th < 0.0 {
                    bail!("on_degrade threshold must be finite and >= 0, got {th}");
                }
                ReOptStrategy::OnDegrade(th)
            }
            _ => bail!(
                "unknown re-optimization strategy '{spec}' \
                 (available: one_shot, every_round, periodic:<J>, on_degrade:<threshold>)"
            ),
        })
    }

    /// The spec string [`Self::parse`] round-trips.
    pub fn label(&self) -> String {
        match self {
            ReOptStrategy::OneShot => "one_shot".to_string(),
            ReOptStrategy::EveryRound => "every_round".to_string(),
            ReOptStrategy::Periodic(j) => format!("periodic:{j}"),
            ReOptStrategy::OnDegrade(th) => format!("on_degrade:{th}"),
        }
    }
}

/// One simulated global round.
#[derive(Clone, Debug)]
pub struct RoundRecord {
    pub round: usize,
    /// Fraction of a full round counted toward the total (1.0 except
    /// possibly the final, partial round).
    pub weight: f64,
    /// Realized per-round delay `I·T_local + max_k T_k^f` (s).
    pub delay: f64,
    /// Realized per-round energy `I·E_round` (J) of the active cohort
    /// (dropped clients spend nothing).
    pub energy: f64,
    pub l_c: usize,
    pub rank: usize,
    /// Clients participating this round.
    pub active: usize,
    /// Whether the policy was (re-)solved this round (always true for
    /// round 0).
    pub resolved: bool,
    /// Clients invited into the round's cohort. [`RoundSimulator`]
    /// always invites everyone (`cohort == K`); the population engine
    /// reports the selector's cohort size.
    pub cohort: usize,
    /// Cohort members cut by the straggler deadline this round (always
    /// 0 for [`RoundSimulator`], which has no deadline).
    pub dropped: usize,
    /// Faults active this round (PR-10 injection; 0 on clean runs).
    pub faults: usize,
    /// Feasibility-repair tier this round's solve needed (0 = healthy;
    /// see [`crate::opt::solve_with_repair`]).
    pub repair_tier: u8,
}

/// Outcome of one dynamic run.
#[derive(Clone, Debug)]
pub struct DynamicOutcome {
    /// Realized total delay `Σ_e w_e·(I·T_local(e) + max_k T_k^f(e))`.
    pub realized_delay: f64,
    /// Realized total energy `Σ_e w_e·(I·E_round(e))` (J); on a frozen
    /// run this is bit-identical to `delay::energy::total_energy`.
    pub realized_energy: f64,
    /// Eq. 17's static prediction for the round-0 solve — what the
    /// one-shot optimizer believes the run will cost.
    pub static_prediction: f64,
    /// Allocation in force when the run finished.
    pub final_alloc: Allocation,
    /// Per-round trace, in order.
    pub rounds: Vec<RoundRecord>,
    /// Policy re-solve *decisions* taken after round 0 (what the
    /// strategy asked for; [`RoundRecord::resolved`] per round).
    pub resolves: usize,
    /// Re-solves that actually ran the solver: a re-solve on an
    /// environment that has not drifted since the last solve is served
    /// from the memoized allocation instead (bit-identical by policy
    /// determinism), so `fresh_solves <= resolves` — and a frozen
    /// ρ=1/σ=0 run reports 0 under every strategy.
    pub fresh_solves: usize,
    /// Distinct clients ever invited into a cohort over the run.
    /// [`RoundSimulator`] invites everyone every round, so this is K;
    /// the population engine reports how far the selector reached into
    /// the population.
    pub unique_participants: usize,
    /// Total cohort members cut by the straggler deadline, summed over
    /// rounds (always 0 for [`RoundSimulator`]).
    pub deadline_drops: usize,
    /// Total faults injected over the run (0 without a fault plan).
    pub faults_injected: usize,
    /// Highest feasibility-repair tier any round needed (0 = every
    /// solve was healthy).
    pub repair_max: u8,
}

/// Realized per-round quantities of one (scenario, allocation, cohort)
/// evaluation — see [`round_cost`].
#[derive(Clone, Copy, Debug)]
pub(crate) struct RoundCost {
    pub(crate) delay: f64,
    pub(crate) energy: f64,
    pub(crate) score: f64,
}

/// Realized per-round cost of `alloc` on `scn` under the participation
/// mask `active`: the round delay, the per-global-round energy spend
/// `I·E_round`, and the objective score per unit of convergence
/// progress (`obj.score(E(rank)·delay, E(rank)·energy)` — the quantity
/// re-opt candidates are compared on; under the delay objective this is
/// exactly `E(rank)·delay`). The evaluator is built from `cols` — the
/// run's delta [`ColumnCache`] — so only rate rows behind an actual
/// gain change are recomputed (bit-identical to a cold build). Shared
/// verbatim between [`RoundSimulator`] and
/// [`crate::sim::PopulationSimulator`] so the degenerate-population
/// anchor invariant compares the same arithmetic.
pub(crate) fn round_cost(
    scn: &Scenario,
    conv: &ConvergenceModel,
    table: &Arc<WorkloadTable>,
    alloc: &Allocation,
    active: &[bool],
    obj: &Objective,
    cols: &mut ColumnCache,
) -> RoundCost {
    let ev =
        DelayEvaluator::with_cached_columns(scn, conv, table.clone(), cols.columns_for(scn, alloc));
    let d = ev.round_delay_active(alloc.l_c, alloc.rank, active);
    let e = scn.local_steps as f64 * ev.round_energy_active(alloc.l_c, alloc.rank, active);
    let rounds = conv.rounds(alloc.rank);
    RoundCost {
        delay: d,
        energy: e,
        score: obj.score(rounds * d, rounds * e),
    }
}

/// Plays a scenario's fine-tuning run out over `E(r)` global rounds
/// under the scenario's [`crate::config::DynamicsConfig`].
pub struct RoundSimulator<'a> {
    base: &'a Scenario,
    conv: &'a ConvergenceModel,
    cache: &'a WorkloadCache,
    ranks: Vec<usize>,
}

impl<'a> RoundSimulator<'a> {
    /// `ranks` is the candidate rank set shared with the policies being
    /// simulated, so evaluator builds hit the same cached
    /// [`WorkloadTable`] the solves use.
    pub fn new(
        base: &'a Scenario,
        conv: &'a ConvergenceModel,
        cache: &'a WorkloadCache,
        ranks: &[usize],
    ) -> RoundSimulator<'a> {
        assert!(!ranks.is_empty(), "empty candidate rank set");
        RoundSimulator {
            base,
            conv,
            cache,
            ranks: ranks.to_vec(),
        }
    }

    /// Simulate one full run of `policy` under `strategy`.
    ///
    /// Dropped clients keep their subchannels but neither compute nor
    /// upload during their absent rounds; rounds always advance full
    /// convergence progress (the E(r) model tracks rounds, not cohort
    /// size). Policy solves see the current channel but not the
    /// participation mask.
    pub fn run(
        &self,
        policy: &dyn AllocationPolicy,
        strategy: ReOptStrategy,
    ) -> Result<DynamicOutcome> {
        self.run_faulted(policy, strategy, &FaultPlan::default())
    }

    /// [`RoundSimulator::run`] under a fault plan (PR-10): each round's
    /// stateless overlay is applied to the drifted environment before
    /// the strategy/solve step and undone after the round realizes. An
    /// empty plan constructs no injector and executes exactly the
    /// statements `run` always has, so fault-free runs are
    /// bit-identical to `run` (pinned in `rust/tests/prop_faults.rs`).
    pub fn run_faulted(
        &self,
        policy: &dyn AllocationPolicy,
        strategy: ReOptStrategy,
        plan: &FaultPlan,
    ) -> Result<DynamicOutcome> {
        let dynamics = &self.base.dynamics;
        if dynamics.shadow_sigma_db < 0.0 && dynamics.rho < 1.0 {
            // same bug class as a directly-constructed ConvergenceModel
            // table: the -1 "inherit" sentinel is resolved by
            // ScenarioBuilder::build; silently clamping it to 0 here
            // would freeze a channel the caller asked to drift
            bail!(
                "dynamics.shadow_sigma_db is the unresolved 'inherit' sentinel ({}) \
                 but rho = {} requests channel drift; build the scenario through \
                 ScenarioBuilder or set dynamics.shadow_sigma_db explicitly",
                dynamics.shadow_sigma_db,
                dynamics.rho
            );
        }
        let k_n = self.base.k();
        let objective = Objective::from_config(&self.base.objective)?;
        let table = self.cache.table_for(&self.base.profile, &self.ranks);
        let injector = if plan.is_empty() {
            None
        } else {
            plan.validate()?;
            Some(FaultInjector::new(plan.clone()))
        };

        // working copy whose gains / compute / membership evolve, plus
        // the seeded drift streams (PR-8: shared engine state — the
        // statements live in `sim::engine`, transplanted verbatim)
        let mut env = DriftEnv::new(self.base.clone());

        // round 0: solve on the initial (static) scenario
        let out0 = policy
            .solve_cached(&env.scn, self.conv, self.cache)
            .context("dynamic run: round-0 solve")?;
        let static_prediction = env.scn.total_delay(&out0.alloc, self.conv);
        let mut core = RoundCore::new(out0.alloc, static_prediction, self.conv);
        let ctx = StepCtx {
            conv: self.conv,
            cache: self.cache,
            table: &table,
            objective: &objective,
            strategy,
            ranks: &self.ranks,
            label: "dynamic",
        };

        while !core.done() {
            core.check_cap(dynamics.max_rounds, &ctx)?;
            let mut resolved = core.round == 0;
            // round cost of the current (scn, alloc, active), computed
            // at most once per round: the strategy decision and the
            // candidate adoption reuse their evaluator passes
            let mut cost_round: Option<RoundCost> = None;
            let mut faults = 0usize;
            let mut repair_tier = 0u8;
            let mut shed: Vec<usize> = Vec::new();
            let mut undo = None;
            if core.round > 0 {
                if env.advance() {
                    core.env_dirty = true;
                }
                if let Some(inj) = &injector {
                    let ov = inj.overlay(core.round, k_n);
                    if !ov.is_empty() {
                        faults = ov.count();
                        core.faults_injected += faults;
                        undo = Some(env.apply_overlay(&ov));
                        core.env_dirty = true;
                    }
                }
                let re = core.maybe_reopt(&ctx, policy, &env.scn, &env.active)?;
                resolved = re.resolved;
                cost_round = re.cost;
                repair_tier = re.repair_tier;
                shed = re.shed;
            }
            if shed.is_empty() {
                core.realize(
                    &ctx, &env.scn, &env.active, cost_round, resolved, k_n, 0, faults,
                    repair_tier,
                );
            } else {
                // tier-3 repair: shed clients sit the round out (their
                // allocation rows are empty — scoring them active would
                // be infinite)
                let mut eff = env.active.clone();
                for &k in &shed {
                    if let Some(a) = eff.get_mut(k) {
                        *a = false;
                    }
                }
                if !eff.iter().any(|&a| a) {
                    // never realize an empty federation: the kept
                    // clients participate even if the dropout process
                    // had them offline this round
                    for (k, a) in eff.iter_mut().enumerate() {
                        *a = !shed.contains(&k);
                    }
                }
                core.realize(
                    &ctx, &env.scn, &eff, cost_round, resolved, k_n, 0, faults, repair_tier,
                );
            }
            if let Some(u) = undo {
                env.undo_overlay(u);
                core.env_dirty = true;
            }
        }
        Ok(core.finish(k_n))
    }
}

/// A `(policy, re-opt strategy)` pair exposed as an
/// [`AllocationPolicy`] whose objective is the **realized** dynamic
/// score — `obj.score(realized delay, realized energy)` under the
/// scenario's objective, i.e. exactly the realized delay for the
/// default delay objective — so `SweepRunner` grids, reports, and the
/// CLI compare re-optimization strategies exactly like any other
/// policy column.
///
/// With an explicit strategy the policy is named
/// `<inner>+<strategy>` (e.g. `proposed+every_round`); with
/// [`DynamicPolicy::from_scenario`] the strategy is parsed per solve
/// from the scenario's `dynamics.strategy`, which is what makes the
/// `SweepAxis::reopt_period` axis work.
pub struct DynamicPolicy {
    inner: Arc<dyn AllocationPolicy>,
    strategy: Option<ReOptStrategy>,
    ranks: Vec<usize>,
    name: String,
}

impl DynamicPolicy {
    pub fn new(
        inner: Arc<dyn AllocationPolicy>,
        strategy: ReOptStrategy,
        ranks: &[usize],
    ) -> DynamicPolicy {
        let name = format!("{}+{}", inner.name(), strategy.label());
        DynamicPolicy {
            inner,
            strategy: Some(strategy),
            ranks: ranks.to_vec(),
            name,
        }
    }

    /// Defer the strategy to each scenario's `dynamics.strategy` spec.
    pub fn from_scenario(inner: Arc<dyn AllocationPolicy>, ranks: &[usize]) -> DynamicPolicy {
        let name = format!("dyn:{}", inner.name());
        DynamicPolicy {
            inner,
            strategy: None,
            ranks: ranks.to_vec(),
            name,
        }
    }
}

impl AllocationPolicy for DynamicPolicy {
    fn name(&self) -> &str {
        &self.name
    }

    fn solve_cached(
        &self,
        scn: &Scenario,
        conv: &ConvergenceModel,
        cache: &WorkloadCache,
    ) -> Result<PolicyOutcome> {
        let strategy = match self.strategy {
            Some(s) => s,
            None => ReOptStrategy::parse(&scn.dynamics.strategy)?,
        };
        let sim = RoundSimulator::new(scn, conv, cache, &self.ranks);
        let out = sim.run(self.inner.as_ref(), strategy)?;
        // the realized analogue of the static scoring: under the
        // default delay objective this is exactly the realized delay
        let objective = Objective::from_config(&scn.objective)?
            .score(out.realized_delay, out.realized_energy);
        Ok(PolicyOutcome {
            policy: self.name.clone(),
            alloc: out.final_alloc,
            objective,
            delay: out.realized_delay,
            energy: out.realized_energy,
            trajectory: Some(out.rounds.iter().map(|r| r.delay).collect()),
            iterations: out.rounds.len(),
            repair_tier: out.repair_max,
            shed: Vec::new(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::opt::policy::Proposed;
    use crate::sim::ScenarioBuilder;

    const RANKS: [usize; 2] = [1, 4];

    fn small_conv() -> ConvergenceModel {
        // keep simulated runs short in unit tests: E(1) = 8, E(4) ~ 5.2
        ConvergenceModel::fitted(4.0, 1.0, 0.85)
    }

    fn dynamic_builder(rho: f64) -> ScenarioBuilder {
        ScenarioBuilder::new()
            .clients(3)
            .channel_correlation(rho)
            .tweak(|c| {
                c.train.seq = 128;
                c.dynamics.seed = 11;
            })
    }

    #[test]
    fn strategy_specs_round_trip_and_reject_garbage() {
        for spec in ["one_shot", "every_round", "periodic:5", "on_degrade:0.25"] {
            let s = ReOptStrategy::parse(spec).unwrap();
            assert_eq!(s.label(), spec);
            assert_eq!(ReOptStrategy::parse(&s.label()).unwrap(), s);
        }
        assert_eq!(
            ReOptStrategy::parse("  periodic: 3 ").unwrap(),
            ReOptStrategy::Periodic(3)
        );
        for bad in [
            "nope",
            "periodic",
            "periodic:0",
            "periodic:x",
            "on_degrade",
            "on_degrade:-1",
            "one_shot:2",
        ] {
            assert!(ReOptStrategy::parse(bad).is_err(), "{bad} should fail");
        }
    }

    #[test]
    fn one_shot_run_records_consistent_accounting() {
        let scn = dynamic_builder(0.7).build().unwrap();
        let conv = small_conv();
        let cache = WorkloadCache::new();
        let sim = RoundSimulator::new(&scn, &conv, &cache, &RANKS);
        let policy = Proposed::with_ranks(&RANKS);
        let out = sim.run(&policy, ReOptStrategy::OneShot).unwrap();

        assert!(out.realized_delay.is_finite() && out.realized_delay > 0.0);
        assert_eq!(out.resolves, 0, "one-shot must never re-solve");
        // weights: all 1.0 except a final fractional round, summing to
        // E(rank) of the (never-changing) round-0 rank
        let e = conv.rounds(out.final_alloc.rank);
        let wsum: f64 = out.rounds.iter().map(|r| r.weight).sum();
        assert!((wsum - e).abs() < 1e-9, "weights {wsum} vs E {e}");
        assert_eq!(out.rounds.len(), e.ceil() as usize);
        for (i, r) in out.rounds.iter().enumerate() {
            assert_eq!(r.round, i);
            assert_eq!(r.rank, out.final_alloc.rank);
            assert_eq!(r.active, scn.k());
            assert_eq!(r.resolved, i == 0);
            assert!(r.weight > 0.0 && r.weight <= 1.0);
        }
        // realized totals equal the (naively summed) trace within fp
        let naive: f64 = out.rounds.iter().map(|r| r.weight * r.delay).sum();
        assert!((out.realized_delay - naive).abs() <= 1e-9 * naive.abs());
        let naive_e: f64 = out.rounds.iter().map(|r| r.weight * r.energy).sum();
        assert!(out.realized_energy.is_finite() && out.realized_energy > 0.0);
        assert!((out.realized_energy - naive_e).abs() <= 1e-9 * naive_e.abs());
        assert!(out.rounds.iter().all(|r| r.energy > 0.0 && r.energy.is_finite()));
    }

    #[test]
    fn periodic_resolves_on_schedule_and_every_round_always() {
        let scn = dynamic_builder(0.6).build().unwrap();
        let conv = small_conv();
        let cache = WorkloadCache::new();
        let sim = RoundSimulator::new(&scn, &conv, &cache, &RANKS);
        let policy = Proposed::with_ranks(&RANKS);

        let per = sim.run(&policy, ReOptStrategy::Periodic(3)).unwrap();
        for r in &per.rounds {
            let expect = r.round == 0 || r.round % 3 == 0;
            assert_eq!(r.resolved, expect, "round {}", r.round);
        }
        assert_eq!(per.resolves, per.rounds.iter().filter(|r| r.round > 0 && r.resolved).count());

        let every = sim.run(&policy, ReOptStrategy::EveryRound).unwrap();
        assert!(every.rounds.iter().all(|r| r.resolved));
        assert_eq!(every.resolves, every.rounds.len() - 1);
    }

    #[test]
    fn on_degrade_threshold_zero_resolves_on_any_worsening_and_huge_never() {
        let scn = dynamic_builder(0.3).build().unwrap();
        // longer run (~13 rounds) so a fast-mixing channel is certain
        // to produce at least one worse-than-last-solve round
        let conv = ConvergenceModel::fitted(8.0, 1.0, 0.85);
        let cache = WorkloadCache::new();
        let sim = RoundSimulator::new(&scn, &conv, &cache, &RANKS);
        let policy = Proposed::with_ranks(&RANKS);

        let never = sim.run(&policy, ReOptStrategy::OnDegrade(1e12)).unwrap();
        assert_eq!(never.resolves, 0, "astronomic threshold must behave one-shot");
        let one_shot = sim.run(&policy, ReOptStrategy::OneShot).unwrap();
        assert_eq!(
            never.realized_delay.to_bits(),
            one_shot.realized_delay.to_bits(),
            "never-triggering on_degrade must equal one_shot bit-for-bit"
        );

        let eager = sim.run(&policy, ReOptStrategy::OnDegrade(0.0)).unwrap();
        // with rho = 0.3 the channel moves every round; some round must
        // realize worse than its last solve and trigger
        assert!(eager.resolves > 0, "threshold 0 never triggered");
        assert!(eager.realized_delay.is_finite() && eager.realized_delay > 0.0);
    }

    #[test]
    fn dropout_shrinks_rounds_and_rejoin_recovers() {
        let scn = dynamic_builder(0.9)
            .tweak(|c| {
                c.dynamics.dropout = 0.4;
                c.dynamics.rejoin = 0.5;
            })
            .build()
            .unwrap();
        let conv = small_conv();
        let cache = WorkloadCache::new();
        let sim = RoundSimulator::new(&scn, &conv, &cache, &RANKS);
        let out = sim
            .run(&Proposed::with_ranks(&RANKS), ReOptStrategy::OneShot)
            .unwrap();
        assert!(out.rounds.iter().all(|r| r.active >= 1), "empty federation simulated");
        assert!(
            out.rounds.iter().any(|r| r.active < scn.k()),
            "40% dropout never dropped anyone"
        );
        assert!(out.realized_delay.is_finite() && out.realized_delay > 0.0);
    }

    #[test]
    fn max_rounds_cap_fails_loudly() {
        let scn = dynamic_builder(1.0)
            .tweak(|c| c.dynamics.max_rounds = 2)
            .build()
            .unwrap();
        let conv = small_conv(); // needs ~6 rounds > cap
        let cache = WorkloadCache::new();
        let sim = RoundSimulator::new(&scn, &conv, &cache, &RANKS);
        let err = sim
            .run(&Proposed::with_ranks(&RANKS), ReOptStrategy::OneShot)
            .unwrap_err();
        assert!(format!("{err:#}").contains("max_rounds"), "{err:#}");
    }

    #[test]
    fn dynamic_policy_wraps_the_simulator() {
        let scn = dynamic_builder(0.8).build().unwrap();
        let conv = small_conv();
        let cache = WorkloadCache::new();
        let inner: Arc<dyn AllocationPolicy> = Arc::new(Proposed::with_ranks(&RANKS));
        let dynp = DynamicPolicy::new(inner.clone(), ReOptStrategy::Periodic(2), &RANKS);
        assert_eq!(dynp.name(), "proposed+periodic:2");
        let out = dynp.solve_cached(&scn, &conv, &cache).unwrap();
        let sim = RoundSimulator::new(&scn, &conv, &cache, &RANKS);
        let direct = sim.run(inner.as_ref(), ReOptStrategy::Periodic(2)).unwrap();
        assert_eq!(out.objective.to_bits(), direct.realized_delay.to_bits());
        assert_eq!(out.iterations, direct.rounds.len());
        let traj = out.trajectory.expect("dynamic policy must report a trace");
        assert_eq!(traj.len(), direct.rounds.len());

        // config-driven strategy: scenario says periodic:2
        let scn2 = dynamic_builder(0.8)
            .reopt_strategy("periodic:2")
            .build()
            .unwrap();
        let from_cfg = DynamicPolicy::from_scenario(inner, &RANKS);
        assert_eq!(from_cfg.name(), "dyn:proposed");
        let out2 = from_cfg.solve_cached(&scn2, &conv, &cache).unwrap();
        assert_eq!(out2.objective.to_bits(), out.objective.to_bits());
    }

    #[test]
    fn frozen_every_round_memoizes_every_re_solve() {
        // rho = 1: the channel never moves, so after round 0 the policy
        // would reproduce its own solution bit for bit — the memo must
        // serve every re-solve (fresh_solves == 0) and the run must be
        // bit-identical to one_shot.
        let scn = dynamic_builder(1.0)
            .tweak(|c| {
                c.dynamics.compute_jitter = 0.0;
                c.dynamics.dropout = 0.0;
            })
            .build()
            .unwrap();
        let conv = small_conv();
        let cache = WorkloadCache::new();
        let sim = RoundSimulator::new(&scn, &conv, &cache, &RANKS);
        let policy = Proposed::with_ranks(&RANKS);
        let one_shot = sim.run(&policy, ReOptStrategy::OneShot).unwrap();
        let every = sim.run(&policy, ReOptStrategy::EveryRound).unwrap();
        assert_eq!(every.fresh_solves, 0, "frozen run must not re-run the solver");
        assert_eq!(every.resolves, every.rounds.len() - 1, "decisions still counted");
        assert_eq!(one_shot.fresh_solves, 0);
        assert_eq!(
            every.realized_delay.to_bits(),
            one_shot.realized_delay.to_bits()
        );
        assert_eq!(
            every.realized_energy.to_bits(),
            one_shot.realized_energy.to_bits()
        );
    }

    #[test]
    fn drifting_or_jittering_runs_do_solve_fresh() {
        // a drifting channel dirties the environment every round
        let scn = dynamic_builder(0.6).build().unwrap();
        let conv = small_conv();
        let cache = WorkloadCache::new();
        let sim = RoundSimulator::new(&scn, &conv, &cache, &RANKS);
        let policy = Proposed::with_ranks(&RANKS);
        let every = sim.run(&policy, ReOptStrategy::EveryRound).unwrap();
        assert_eq!(every.fresh_solves, every.resolves);
        assert!(every.fresh_solves > 0);

        // a frozen channel with compute jitter is still dirty: the
        // memo must NOT serve stale solutions
        let scn_j = dynamic_builder(1.0)
            .tweak(|c| c.dynamics.compute_jitter = 0.15)
            .build()
            .unwrap();
        let sim_j = RoundSimulator::new(&scn_j, &conv, &cache, &RANKS);
        let every_j = sim_j.run(&policy, ReOptStrategy::EveryRound).unwrap();
        assert_eq!(every_j.fresh_solves, every_j.resolves);
        assert!(every_j.fresh_solves > 0);
    }

    #[test]
    fn empty_fault_plan_is_bit_transparent() {
        let scn = dynamic_builder(0.5)
            .tweak(|c| {
                c.dynamics.compute_jitter = 0.1;
                c.dynamics.dropout = 0.1;
            })
            .build()
            .unwrap();
        let conv = small_conv();
        let cache = WorkloadCache::new();
        let sim = RoundSimulator::new(&scn, &conv, &cache, &RANKS);
        let policy = Proposed::with_ranks(&RANKS);
        let plain = sim.run(&policy, ReOptStrategy::EveryRound).unwrap();
        let faulted = sim
            .run_faulted(&policy, ReOptStrategy::EveryRound, &FaultPlan::default())
            .unwrap();
        assert_eq!(faulted.faults_injected, 0);
        assert_eq!(faulted.repair_max, 0);
        assert_eq!(plain.realized_delay.to_bits(), faulted.realized_delay.to_bits());
        assert_eq!(plain.realized_energy.to_bits(), faulted.realized_energy.to_bits());
        assert_eq!(plain.rounds.len(), faulted.rounds.len());
        for (x, y) in plain.rounds.iter().zip(&faulted.rounds) {
            assert_eq!(x.delay.to_bits(), y.delay.to_bits());
            assert_eq!(x.active, y.active);
            assert_eq!(y.faults, 0);
            assert_eq!(y.repair_tier, 0);
        }
    }

    #[test]
    fn crash_faults_shrink_rounds_and_replay_identically() {
        let scn = dynamic_builder(1.0).build().unwrap();
        let conv = small_conv();
        let cache = WorkloadCache::new();
        let sim = RoundSimulator::new(&scn, &conv, &cache, &RANKS);
        let policy = Proposed::with_ranks(&RANKS);
        let plan = FaultPlan::parse("crash=0.6:1,seed=3").unwrap();
        let a = sim
            .run_faulted(&policy, ReOptStrategy::OneShot, &plan)
            .unwrap();
        assert!(a.faults_injected > 0, "60% crash rate never fired");
        assert!(
            a.rounds.iter().any(|r| r.active < scn.k()),
            "crashes never took a client offline"
        );
        assert!(a.rounds.iter().all(|r| r.active >= 1), "empty federation simulated");
        assert_eq!(
            a.rounds.iter().map(|r| r.faults).sum::<usize>(),
            a.faults_injected
        );
        // identical seeds replay identical schedules and realizations
        let b = sim
            .run_faulted(&policy, ReOptStrategy::OneShot, &plan)
            .unwrap();
        assert_eq!(a.realized_delay.to_bits(), b.realized_delay.to_bits());
        assert_eq!(a.faults_injected, b.faults_injected);
        for (x, y) in a.rounds.iter().zip(&b.rounds) {
            assert_eq!(x.faults, y.faults);
            assert_eq!(x.active, y.active);
            assert_eq!(x.delay.to_bits(), y.delay.to_bits());
        }
    }

    #[test]
    fn stall_faults_slow_rounds_but_recover() {
        // frozen channel, one_shot: every round's delay equals the
        // baseline except the stalled ones, which are strictly slower
        let scn = dynamic_builder(1.0).build().unwrap();
        let conv = small_conv();
        let cache = WorkloadCache::new();
        let sim = RoundSimulator::new(&scn, &conv, &cache, &RANKS);
        let policy = Proposed::with_ranks(&RANKS);
        let clean = sim.run(&policy, ReOptStrategy::OneShot).unwrap();
        let plan = FaultPlan::parse("stall=0.5:0.25:1,seed=9").unwrap();
        let stalled = sim
            .run_faulted(&policy, ReOptStrategy::OneShot, &plan)
            .unwrap();
        assert!(stalled.faults_injected > 0, "50% stall rate never fired");
        assert_eq!(clean.rounds.len(), stalled.rounds.len());
        for (c, s) in clean.rounds.iter().zip(&stalled.rounds) {
            if s.faults == 0 {
                assert_eq!(
                    c.delay.to_bits(),
                    s.delay.to_bits(),
                    "round {}: fault-free round must realize baseline bits",
                    s.round
                );
            } else {
                assert!(
                    s.delay > c.delay,
                    "round {}: a compute stall must slow the round",
                    s.round
                );
            }
        }
        assert!(stalled.realized_delay > clean.realized_delay);
    }

    #[test]
    fn total_outage_triggers_the_repair_chain() {
        // a hard outage (factor 0) starves the victim's uplink: a fresh
        // solve is infeasible, so the engine must degrade, not die
        let scn = dynamic_builder(1.0).build().unwrap();
        let conv = small_conv();
        let cache = WorkloadCache::new();
        let sim = RoundSimulator::new(&scn, &conv, &cache, &RANKS);
        let policy = Proposed::with_ranks(&RANKS);
        let plan = FaultPlan::parse("outage=0.5:0:1,seed=2").unwrap();
        let out = sim
            .run_faulted(&policy, ReOptStrategy::EveryRound, &plan)
            .unwrap();
        assert!(out.faults_injected > 0, "50% outage rate never fired");
        assert!(out.repair_max > 0, "outage rounds must have needed repair");
        assert!(out.realized_delay.is_finite(), "degradation must stay finite");
        assert_eq!(
            out.repair_max,
            out.rounds.iter().map(|r| r.repair_tier).max().unwrap()
        );
    }

    #[test]
    fn runs_are_deterministic_across_repeats() {
        let scn = dynamic_builder(0.5)
            .tweak(|c| {
                c.dynamics.compute_jitter = 0.1;
                c.dynamics.dropout = 0.1;
            })
            .build()
            .unwrap();
        let conv = small_conv();
        let cache = WorkloadCache::new();
        let sim = RoundSimulator::new(&scn, &conv, &cache, &RANKS);
        let policy = Proposed::with_ranks(&RANKS);
        let a = sim.run(&policy, ReOptStrategy::EveryRound).unwrap();
        let b = sim.run(&policy, ReOptStrategy::EveryRound).unwrap();
        assert_eq!(a.realized_delay.to_bits(), b.realized_delay.to_bits());
        assert_eq!(a.rounds.len(), b.rounds.len());
        for (x, y) in a.rounds.iter().zip(&b.rounds) {
            assert_eq!(x.delay.to_bits(), y.delay.to_bits());
            assert_eq!(x.weight.to_bits(), y.weight.to_bits());
            assert_eq!(x.active, y.active);
            assert_eq!(x.rank, y.rank);
        }
    }
}
