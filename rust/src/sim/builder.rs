//! Fluent, seeded scenario construction with named heterogeneity
//! presets.
//!
//! [`ScenarioBuilder`] is the one way to make a [`Scenario`]: it
//! starts from a preset (or an explicit [`Config`]), lets callers
//! override the knobs experiments actually sweep — clients, bandwidth,
//! compute, power, seed — and then samples the geometry/fading exactly
//! as Sec. VII-A prescribes. The same builder value can be rebuilt any
//! number of times; identical settings give identical scenarios.

use anyhow::{bail, Result};

use crate::config::Config;
use crate::delay::Scenario;
use crate::model::{Gpt2Config, WorkloadProfile};
use crate::net::{power, ChannelModel, Link, SubchannelSet, Topology};
use crate::util::rng::Rng;

/// Named scenario presets (see [`ScenarioBuilder::preset`]).
pub const PRESETS: [&str; 5] = [
    "paper",
    "dense_cell",
    "weak_edge",
    "asymmetric_links",
    "many_clients",
];

/// Fluent scenario constructor over a [`Config`].
#[derive(Clone, Debug)]
pub struct ScenarioBuilder {
    cfg: Config,
}

impl Default for ScenarioBuilder {
    fn default() -> Self {
        ScenarioBuilder::new()
    }
}

impl ScenarioBuilder {
    /// Start from the paper's Table II defaults.
    pub fn new() -> ScenarioBuilder {
        ScenarioBuilder {
            cfg: Config::paper_defaults(),
        }
    }

    /// Start from an explicit config (TOML/CLI loaded).
    pub fn from_config(cfg: Config) -> ScenarioBuilder {
        ScenarioBuilder { cfg }
    }

    /// Start from a named preset:
    ///
    /// * `paper` — Table II exactly (K=5, M=N=20, 500 kHz links);
    /// * `dense_cell` — 24 clients in a 50 m cell, 48 subchannels and
    ///   2 MHz per link: the many-client regime of FedsLLM-style
    ///   deployments;
    /// * `weak_edge` — 8 battery-class clients with skewed low compute
    ///   (0.2–0.6 GHz, 512 FLOPs/cycle): stresses the split decision;
    /// * `asymmetric_links` — wide main-server uplink (1 MHz / 32
    ///   subchannels) against a narrow federated link (125 kHz / 8),
    ///   with a far main server: stresses the two-link power trade;
    /// * `many_clients` — the production-scale regime: 1000 clients in
    ///   a 250 m cell sharing 1024 subchannels and 20 MHz per link,
    ///   with a raised per-server power budget. Exercises the cached
    ///   delay-evaluation path at large K (see the large-K axis of
    ///   `benches/micro_hotpath.rs`).
    pub fn preset(name: &str) -> Result<ScenarioBuilder> {
        let mut cfg = Config::paper_defaults();
        match name {
            "paper" => {}
            "dense_cell" => {
                cfg.system.clients = 24;
                cfg.system.subch_main = 48;
                cfg.system.subch_fed = 48;
                cfg.system.bandwidth_main_hz = 2e6;
                cfg.system.bandwidth_fed_hz = 2e6;
                cfg.system.d_max_m = 50.0;
            }
            "weak_edge" => {
                cfg.system.clients = 8;
                cfg.system.f_client_lo = 0.2e9;
                cfg.system.f_client_hi = 0.6e9;
                cfg.system.kappa_client = 1.0 / 512.0;
            }
            "asymmetric_links" => {
                cfg.system.bandwidth_main_hz = 1e6;
                cfg.system.subch_main = 32;
                cfg.system.bandwidth_fed_hz = 125e3;
                cfg.system.subch_fed = 8;
                cfg.system.d_main_m = 200.0;
            }
            "many_clients" => {
                cfg.system.clients = 1000;
                cfg.system.subch_main = 1024;
                cfg.system.subch_fed = 1024;
                cfg.system.bandwidth_main_hz = 20e6;
                cfg.system.bandwidth_fed_hz = 20e6;
                cfg.system.d_max_m = 250.0;
                cfg.system.p_th_main_dbm = 50.0;
                cfg.system.p_th_fed_dbm = 50.0;
            }
            other => bail!(
                "unknown scenario preset '{other}' (available: {})",
                PRESETS.join(", ")
            ),
        }
        Ok(ScenarioBuilder { cfg })
    }

    /// Scenario seed (placement, fading, capability draws).
    pub fn seed(mut self, seed: u64) -> ScenarioBuilder {
        self.cfg.system.seed = seed;
        self
    }

    /// Number of participating clients K.
    pub fn clients(mut self, k: usize) -> ScenarioBuilder {
        self.cfg.system.clients = k;
        self
    }

    /// Workload model variant (`gpt2-s`, `gpt2-m`, `tiny`, …).
    pub fn model(mut self, name: &str) -> ScenarioBuilder {
        self.cfg.model = name.to_string();
        self
    }

    /// Total uplink bandwidth to the main / federated server (Hz).
    pub fn bandwidth_hz(mut self, main: f64, fed: f64) -> ScenarioBuilder {
        self.cfg.system.bandwidth_main_hz = main;
        self.cfg.system.bandwidth_fed_hz = fed;
        self
    }

    /// Subchannel counts M (main link) and N (federated link).
    pub fn subchannels(mut self, m: usize, n: usize) -> ScenarioBuilder {
        self.cfg.system.subch_main = m;
        self.cfg.system.subch_fed = n;
        self
    }

    /// Client compute capability range [lo, hi] (cycles/s).
    pub fn client_compute_hz(mut self, lo: f64, hi: f64) -> ScenarioBuilder {
        self.cfg.system.f_client_lo = lo;
        self.cfg.system.f_client_hi = hi;
        self
    }

    /// Main-server compute capability (cycles/s).
    pub fn server_compute_hz(mut self, f: f64) -> ScenarioBuilder {
        self.cfg.system.f_server = f;
        self
    }

    /// Per-client maximum transmit power (dBm).
    pub fn p_max_dbm(mut self, dbm: f64) -> ScenarioBuilder {
        self.cfg.system.p_max_dbm = dbm;
        self
    }

    /// Escape hatch: arbitrary config mutation for axes the named
    /// setters don't cover.
    pub fn tweak<F: FnOnce(&mut Config)>(mut self, f: F) -> ScenarioBuilder {
        f(&mut self.cfg);
        self
    }

    /// The effective config this builder will sample from.
    pub fn config(&self) -> &Config {
        &self.cfg
    }

    pub fn into_config(self) -> Config {
        self.cfg
    }

    /// Sample the scenario: geometry and capability draws from the
    /// config seed, shadowed channel gains, both FDMA links.
    ///
    /// Rejects configurations the optimizer cannot serve: zero clients,
    /// or more clients than subchannels on either link (Algorithm 2 and
    /// the baselines guarantee every client >= 1 subchannel per link
    /// only when K <= M and K <= N).
    pub fn build(&self) -> Result<Scenario> {
        let s = &self.cfg.system;
        if s.clients == 0 {
            bail!("scenario has zero clients");
        }
        if s.clients > s.subch_main || s.clients > s.subch_fed {
            bail!(
                "{} clients exceed the subchannel counts (M={}, N={}); \
                 every client needs at least one subchannel per link",
                s.clients,
                s.subch_main,
                s.subch_fed
            );
        }
        let mut rng = Rng::new(s.seed);
        let topo = Topology::sample(
            s.clients,
            s.d_max_m,
            s.d_main_m,
            s.f_client_lo,
            s.f_client_hi,
            &mut rng,
        );
        let ch = ChannelModel::new(s.shadowing_db);
        let mut gain_rng = rng.fork(0xC0FFEE);
        let main_gain: Vec<f64> = topo
            .clients
            .iter()
            .map(|c| ch.gain(c.d_main_m, &mut gain_rng))
            .collect();
        let fed_gain: Vec<f64> = topo
            .clients
            .iter()
            .map(|c| ch.gain(c.d_fed_m, &mut gain_rng))
            .collect();
        let noise = power::dbm_per_hz_to_watt_per_hz(s.noise_dbm_hz);

        let arch = Gpt2Config::by_name(&self.cfg.model)?;
        let profile = WorkloadProfile::new(arch, self.cfg.train.seq);

        Ok(Scenario {
            profile,
            topo,
            main_link: Link {
                subch: SubchannelSet::equal_split(s.bandwidth_main_hz, s.subch_main),
                gain_product: s.gain_main,
                noise_psd: noise,
                client_gain: main_gain,
            },
            fed_link: Link {
                subch: SubchannelSet::equal_split(s.bandwidth_fed_hz, s.subch_fed),
                gain_product: s.gain_fed,
                noise_psd: noise,
                client_gain: fed_gain,
            },
            kappa_client: s.kappa_client,
            kappa_server: s.kappa_server,
            f_server: s.f_server,
            batch: self.cfg.train.batch,
            local_steps: self.cfg.train.local_steps,
            p_max_w: power::dbm_to_watt(s.p_max_dbm),
            p_th_main_w: power::dbm_to_watt(s.p_th_main_dbm),
            p_th_fed_w: power::dbm_to_watt(s.p_th_fed_dbm),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_preset_matches_table_ii() {
        let scn = ScenarioBuilder::preset("paper").unwrap().build().unwrap();
        assert_eq!(scn.k(), 5);
        assert_eq!(scn.main_link.subch.len(), 20);
        assert_eq!(scn.profile.blocks.len(), 12); // gpt2-s
        assert!((scn.p_max_w - 15.0).abs() < 0.05);
        for &g in scn.main_link.client_gain.iter().chain(&scn.fed_link.client_gain) {
            assert!(g > 0.0 && g < 1.0);
        }
    }

    #[test]
    fn unknown_preset_is_rejected_with_catalog() {
        let err = ScenarioBuilder::preset("nope").unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("dense_cell"), "{msg}");
    }

    #[test]
    fn every_preset_builds_and_serves_all_clients() {
        for name in PRESETS {
            let b = ScenarioBuilder::preset(name).unwrap();
            let scn = b.build().unwrap_or_else(|e| panic!("{name}: {e:#}"));
            assert!(scn.k() >= 1, "{name}");
            // K <= M, N so every client can hold >= 1 subchannel per link
            assert!(scn.main_link.subch.len() >= scn.k(), "{name}");
            assert!(scn.fed_link.subch.len() >= scn.k(), "{name}");
        }
    }

    #[test]
    fn many_clients_is_production_scale() {
        let b = ScenarioBuilder::preset("many_clients").unwrap();
        assert_eq!(b.config().system.clients, 1000);
        let scn = b.build().unwrap();
        assert_eq!(scn.k(), 1000);
        assert!(scn.main_link.subch.len() >= scn.k());
        assert_eq!(scn.main_link.client_gain.len(), 1000);
    }

    #[test]
    fn dense_cell_is_dense_and_weak_edge_is_weak() {
        let dense = ScenarioBuilder::preset("dense_cell").unwrap();
        assert!(dense.config().system.clients >= 20);
        let weak = ScenarioBuilder::preset("weak_edge").unwrap();
        let paper = ScenarioBuilder::preset("paper").unwrap();
        assert!(weak.config().system.f_client_hi < paper.config().system.f_client_lo);
    }

    #[test]
    fn same_seed_same_scenario_different_seed_differs() {
        let b = ScenarioBuilder::new().seed(9);
        let a = b.build().unwrap();
        let c = b.build().unwrap();
        assert_eq!(a.main_link.client_gain, c.main_link.client_gain);
        let d = ScenarioBuilder::new().seed(10).build().unwrap();
        assert_ne!(a.main_link.client_gain, d.main_link.client_gain);
    }

    #[test]
    fn overrides_apply() {
        let scn = ScenarioBuilder::new()
            .clients(3)
            .bandwidth_hz(250e3, 750e3)
            .subchannels(10, 15)
            .server_compute_hz(1e10)
            .p_max_dbm(30.0)
            .tweak(|c| c.train.batch = 2)
            .build()
            .unwrap();
        assert_eq!(scn.k(), 3);
        assert!((scn.main_link.subch.total_hz() - 250e3).abs() < 1e-6);
        assert!((scn.fed_link.subch.total_hz() - 750e3).abs() < 1e-6);
        assert_eq!(scn.main_link.subch.len(), 10);
        assert_eq!(scn.fed_link.subch.len(), 15);
        assert_eq!(scn.f_server, 1e10);
        assert_eq!(scn.batch, 2);
        assert!((scn.p_max_w - 1.0).abs() < 1e-9);
    }
}
