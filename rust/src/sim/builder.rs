//! Fluent, seeded scenario construction with named heterogeneity
//! presets.
//!
//! [`ScenarioBuilder`] is the one way to make a [`Scenario`]: it
//! starts from a preset (or an explicit [`Config`]), lets callers
//! override the knobs experiments actually sweep — clients, bandwidth,
//! compute, power, seed — and then samples the geometry/fading exactly
//! as Sec. VII-A prescribes. The same builder value can be rebuilt any
//! number of times; identical settings give identical scenarios.

use anyhow::{bail, Context, Result};

use crate::config::Config;
use crate::delay::Scenario;
use crate::model::{Gpt2Config, WorkloadProfile};
use crate::net::{power, ChannelModel, ChannelState, Link, SubchannelSet, Topology};
use crate::util::rng::Rng;

/// Named scenario presets (see [`ScenarioBuilder::preset`]).
pub const PRESETS: [&str; 8] = [
    "paper",
    "dense_cell",
    "weak_edge",
    "asymmetric_links",
    "many_clients",
    "mobile_edge",
    "battery_edge",
    "metro_population",
];

/// Fluent scenario constructor over a [`Config`].
#[derive(Clone, Debug)]
pub struct ScenarioBuilder {
    cfg: Config,
}

impl Default for ScenarioBuilder {
    fn default() -> Self {
        ScenarioBuilder::new()
    }
}

impl ScenarioBuilder {
    /// Start from the paper's Table II defaults.
    pub fn new() -> ScenarioBuilder {
        ScenarioBuilder {
            cfg: Config::paper_defaults(),
        }
    }

    /// Start from an explicit config (TOML/CLI loaded).
    pub fn from_config(cfg: Config) -> ScenarioBuilder {
        ScenarioBuilder { cfg }
    }

    /// Start from a named preset:
    ///
    /// * `paper` — Table II exactly (K=5, M=N=20, 500 kHz links);
    /// * `dense_cell` — 24 clients in a 50 m cell, 48 subchannels and
    ///   2 MHz per link: the many-client regime of FedsLLM-style
    ///   deployments;
    /// * `weak_edge` — 8 battery-class clients with skewed low compute
    ///   (0.2–0.6 GHz, 512 FLOPs/cycle): stresses the split decision;
    /// * `asymmetric_links` — wide main-server uplink (1 MHz / 32
    ///   subchannels) against a narrow federated link (125 kHz / 8),
    ///   with a far main server: stresses the two-link power trade;
    /// * `many_clients` — the production-scale regime: 1000 clients in
    ///   a 250 m cell sharing 1024 subchannels and 20 MHz per link,
    ///   with a raised per-server power budget. Exercises the cached
    ///   delay-evaluation path at large K (see the large-K axis of
    ///   `benches/micro_hotpath.rs`);
    /// * `mobile_edge` — the round-varying regime: 12 clients in a
    ///   100 m cell whose shadowing drifts as an AR(1) process
    ///   (ρ = 0.85), with compute jitter and occasional dropout/return
    ///   — the FedsLLM-style mobile deployment the dynamic engine
    ///   ([`crate::sim::RoundSimulator`]) simulates; the default
    ///   re-optimization strategy is `periodic:5`;
    /// * `battery_edge` — the energy-bound regime: 6 battery-powered
    ///   clients (0.4–0.9 GHz) on 1 W-class radios with tight server
    ///   power budgets, optimizing the λ-weighted delay/energy sum
    ///   (`objective = weighted`, λ = 0.05 s/J) — the scenario family
    ///   behind `examples/energy_tradeoff.rs`;
    /// * `metro_population` — the population-scale regime: a fleet of
    ///   100 000 modeled clients in a 400 m metro cell, of which a
    ///   64-client cohort is invited each round (`staleness:5`
    ///   selection, 10% straggler deadline) onto 128 subchannels and
    ///   4 MHz per link, with drifting shadowing (ρ = 0.9), compute
    ///   jitter, and dropout/rejoin — the scenario behind the
    ///   `population` CLI subcommand and
    ///   [`crate::sim::PopulationSimulator`]. (`system.clients` is
    ///   set to the cohort so the preset also builds as a plain
    ///   64-client scenario.)
    pub fn preset(name: &str) -> Result<ScenarioBuilder> {
        let mut cfg = Config::paper_defaults();
        match name {
            "paper" => {}
            "dense_cell" => {
                cfg.system.clients = 24;
                cfg.system.subch_main = 48;
                cfg.system.subch_fed = 48;
                cfg.system.bandwidth_main_hz = 2e6;
                cfg.system.bandwidth_fed_hz = 2e6;
                cfg.system.d_max_m = 50.0;
            }
            "weak_edge" => {
                cfg.system.clients = 8;
                cfg.system.f_client_lo = 0.2e9;
                cfg.system.f_client_hi = 0.6e9;
                cfg.system.kappa_client = 1.0 / 512.0;
            }
            "asymmetric_links" => {
                cfg.system.bandwidth_main_hz = 1e6;
                cfg.system.subch_main = 32;
                cfg.system.bandwidth_fed_hz = 125e3;
                cfg.system.subch_fed = 8;
                cfg.system.d_main_m = 200.0;
            }
            "many_clients" => {
                cfg.system.clients = 1000;
                cfg.system.subch_main = 1024;
                cfg.system.subch_fed = 1024;
                cfg.system.bandwidth_main_hz = 20e6;
                cfg.system.bandwidth_fed_hz = 20e6;
                cfg.system.d_max_m = 250.0;
                cfg.system.p_th_main_dbm = 50.0;
                cfg.system.p_th_fed_dbm = 50.0;
            }
            "battery_edge" => {
                cfg.system.clients = 6;
                cfg.system.f_client_lo = 0.4e9;
                cfg.system.f_client_hi = 0.9e9;
                cfg.system.p_max_dbm = 30.0; // 1 W-class mobile radio
                cfg.system.p_th_main_dbm = 36.0; // ~4 W per server
                cfg.system.p_th_fed_dbm = 36.0;
                cfg.objective.kind = "weighted".to_string();
                cfg.objective.lambda = 0.05;
            }
            "mobile_edge" => {
                cfg.system.clients = 12;
                cfg.system.subch_main = 24;
                cfg.system.subch_fed = 24;
                cfg.system.bandwidth_main_hz = 1e6;
                cfg.system.bandwidth_fed_hz = 1e6;
                cfg.system.d_max_m = 100.0;
                cfg.dynamics.rho = 0.85;
                cfg.dynamics.compute_jitter = 0.08;
                cfg.dynamics.dropout = 0.05;
                cfg.dynamics.rejoin = 0.5;
                cfg.dynamics.strategy = "periodic:5".to_string();
            }
            "metro_population" => {
                cfg.population.size = 100_000;
                cfg.population.cohort = 64;
                cfg.population.selector = "staleness:5".to_string();
                cfg.population.deadline_drop = 0.1;
                cfg.system.clients = 64;
                cfg.system.subch_main = 128;
                cfg.system.subch_fed = 128;
                cfg.system.bandwidth_main_hz = 4e6;
                cfg.system.bandwidth_fed_hz = 4e6;
                cfg.system.d_max_m = 400.0;
                cfg.system.d_main_m = 500.0;
                cfg.dynamics.rho = 0.9;
                cfg.dynamics.compute_jitter = 0.05;
                cfg.dynamics.dropout = 0.02;
                cfg.dynamics.rejoin = 0.3;
                cfg.dynamics.strategy = "periodic:5".to_string();
            }
            other => bail!(
                "unknown scenario preset '{other}' (available: {})",
                PRESETS.join(", ")
            ),
        }
        Ok(ScenarioBuilder { cfg })
    }

    /// Scenario seed (placement, fading, capability draws).
    pub fn seed(mut self, seed: u64) -> ScenarioBuilder {
        self.cfg.system.seed = seed;
        self
    }

    /// Number of participating clients K.
    pub fn clients(mut self, k: usize) -> ScenarioBuilder {
        self.cfg.system.clients = k;
        self
    }

    /// Workload model variant (`gpt2-s`, `gpt2-m`, `tiny`, …).
    pub fn model(mut self, name: &str) -> ScenarioBuilder {
        self.cfg.model = name.to_string();
        self
    }

    /// Total uplink bandwidth to the main / federated server (Hz).
    pub fn bandwidth_hz(mut self, main: f64, fed: f64) -> ScenarioBuilder {
        self.cfg.system.bandwidth_main_hz = main;
        self.cfg.system.bandwidth_fed_hz = fed;
        self
    }

    /// Subchannel counts M (main link) and N (federated link).
    pub fn subchannels(mut self, m: usize, n: usize) -> ScenarioBuilder {
        self.cfg.system.subch_main = m;
        self.cfg.system.subch_fed = n;
        self
    }

    /// Client compute capability range [lo, hi] (cycles/s).
    pub fn client_compute_hz(mut self, lo: f64, hi: f64) -> ScenarioBuilder {
        self.cfg.system.f_client_lo = lo;
        self.cfg.system.f_client_hi = hi;
        self
    }

    /// Main-server compute capability (cycles/s).
    pub fn server_compute_hz(mut self, f: f64) -> ScenarioBuilder {
        self.cfg.system.f_server = f;
        self
    }

    /// Per-client maximum transmit power (dBm).
    pub fn p_max_dbm(mut self, dbm: f64) -> ScenarioBuilder {
        self.cfg.system.p_max_dbm = dbm;
        self
    }

    /// AR(1) round-to-round shadowing correlation ρ in [0, 1]
    /// (1.0 = the channel stays at its initial draw).
    pub fn channel_correlation(mut self, rho: f64) -> ScenarioBuilder {
        self.cfg.dynamics.rho = rho;
        self
    }

    /// Per-round client dropout / rejoin probabilities.
    pub fn dropout(mut self, p_drop: f64, p_rejoin: f64) -> ScenarioBuilder {
        self.cfg.dynamics.dropout = p_drop;
        self.cfg.dynamics.rejoin = p_rejoin;
        self
    }

    /// Log-normal σ of the per-round client compute jitter (0 = off).
    pub fn compute_jitter(mut self, sigma: f64) -> ScenarioBuilder {
        self.cfg.dynamics.compute_jitter = sigma;
        self
    }

    /// Dynamics stream seed (independent of the scenario seed, so the
    /// environment can be redrawn over a fixed geometry).
    pub fn dynamics_seed(mut self, seed: u64) -> ScenarioBuilder {
        self.cfg.dynamics.seed = seed;
        self
    }

    /// Default re-optimization strategy spec (`one_shot`,
    /// `every_round`, `periodic:<J>`, `on_degrade:<threshold>`) used by
    /// config-driven dynamic surfaces; validated at [`Self::build`].
    pub fn reopt_strategy(mut self, spec: &str) -> ScenarioBuilder {
        self.cfg.dynamics.strategy = spec.to_string();
        self
    }

    /// Escape hatch: arbitrary config mutation for axes the named
    /// setters don't cover.
    pub fn tweak<F: FnOnce(&mut Config)>(mut self, f: F) -> ScenarioBuilder {
        f(&mut self.cfg);
        self
    }

    /// The effective config this builder will sample from.
    pub fn config(&self) -> &Config {
        &self.cfg
    }

    pub fn into_config(self) -> Config {
        self.cfg
    }

    /// Sample the scenario: geometry and capability draws from the
    /// config seed, shadowed channel gains, both FDMA links.
    ///
    /// Rejects configurations the optimizer cannot serve: zero clients,
    /// or more clients than subchannels on either link (Algorithm 2 and
    /// the baselines guarantee every client >= 1 subchannel per link
    /// only when K <= M and K <= N).
    pub fn build(&self) -> Result<Scenario> {
        let s = &self.cfg.system;
        if s.clients == 0 {
            bail!("scenario has zero clients");
        }
        if s.clients > s.subch_main || s.clients > s.subch_fed {
            bail!(
                "{} clients exceed the subchannel counts (M={}, N={}); \
                 every client needs at least one subchannel per link",
                s.clients,
                s.subch_main,
                s.subch_fed
            );
        }
        if self.cfg.train.local_steps == 0 {
            bail!(
                "train.local_steps must be >= 1: Eq. 17 counts I local \
                 rounds per global round and the energy model amortizes \
                 the federated upload over them"
            );
        }
        if self.cfg.train.batch == 0 {
            bail!("train.batch must be >= 1");
        }
        let objective = self.cfg.objective.clone();
        crate::opt::Objective::from_config(&objective).context("objective")?;
        if !objective.zeta.is_finite() || objective.zeta <= 0.0 {
            bail!(
                "objective.zeta must be finite and > 0 (J·s²/cycle³), got {}",
                objective.zeta
            );
        }
        let mut dynamics = self.cfg.dynamics.clone();
        if !(0.0..=1.0).contains(&dynamics.rho) {
            bail!("dynamics.rho must be in [0, 1], got {}", dynamics.rho);
        }
        if !(0.0..=1.0).contains(&dynamics.dropout) || !(0.0..=1.0).contains(&dynamics.rejoin) {
            bail!(
                "dynamics dropout/rejoin must be probabilities in [0, 1], got {} / {}",
                dynamics.dropout,
                dynamics.rejoin
            );
        }
        if dynamics.compute_jitter < 0.0 || !dynamics.compute_jitter.is_finite() {
            bail!(
                "dynamics.compute_jitter must be finite and >= 0, got {}",
                dynamics.compute_jitter
            );
        }
        crate::sim::dynamic::ReOptStrategy::parse(&dynamics.strategy)
            .context("dynamics.strategy")?;
        if dynamics.shadow_sigma_db < 0.0 {
            // "inherit" sentinel: the AR(1) process keeps the static
            // model's stationary shadowing
            dynamics.shadow_sigma_db = s.shadowing_db;
        }
        let mut rng = Rng::new(s.seed);
        let topo = Topology::sample(
            s.clients,
            s.d_max_m,
            s.d_main_m,
            s.f_client_lo,
            s.f_client_hi,
            &mut rng,
        );
        let ch = ChannelModel::new(s.shadowing_db);
        let mut gain_rng = rng.fork(0xC0FFEE);
        // all main-link draws, then all fed-link draws — the order
        // ChannelState::sample fixes, shared with the dynamic process
        let shadows = ChannelState::sample(s.clients, &ch, &mut gain_rng);
        let (main_gain, fed_gain) = shadows.gains(&topo, &ch);
        let noise = power::dbm_per_hz_to_watt_per_hz(s.noise_dbm_hz);

        let arch = Gpt2Config::by_name(&self.cfg.model)?;
        let profile = WorkloadProfile::new(arch, self.cfg.train.seq);

        Ok(Scenario {
            profile,
            topo,
            dynamics,
            objective,
            main_link: Link {
                subch: SubchannelSet::equal_split(s.bandwidth_main_hz, s.subch_main),
                gain_product: s.gain_main,
                noise_psd: noise,
                client_gain: main_gain,
            },
            fed_link: Link {
                subch: SubchannelSet::equal_split(s.bandwidth_fed_hz, s.subch_fed),
                gain_product: s.gain_fed,
                noise_psd: noise,
                client_gain: fed_gain,
            },
            kappa_client: s.kappa_client,
            kappa_server: s.kappa_server,
            f_server: s.f_server,
            batch: self.cfg.train.batch,
            local_steps: self.cfg.train.local_steps,
            p_max_w: power::dbm_to_watt(s.p_max_dbm),
            p_th_main_w: power::dbm_to_watt(s.p_th_main_dbm),
            p_th_fed_w: power::dbm_to_watt(s.p_th_fed_dbm),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_preset_matches_table_ii() {
        let scn = ScenarioBuilder::preset("paper").unwrap().build().unwrap();
        assert_eq!(scn.k(), 5);
        assert_eq!(scn.main_link.subch.len(), 20);
        assert_eq!(scn.profile.blocks.len(), 12); // gpt2-s
        assert!((scn.p_max_w - 15.0).abs() < 0.05);
        for &g in scn.main_link.client_gain.iter().chain(&scn.fed_link.client_gain) {
            assert!(g > 0.0 && g < 1.0);
        }
    }

    #[test]
    fn unknown_preset_is_rejected_with_catalog() {
        let err = ScenarioBuilder::preset("nope").unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("dense_cell"), "{msg}");
    }

    #[test]
    fn every_preset_builds_and_serves_all_clients() {
        for name in PRESETS {
            let b = ScenarioBuilder::preset(name).unwrap();
            let scn = b.build().unwrap_or_else(|e| panic!("{name}: {e:#}"));
            assert!(scn.k() >= 1, "{name}");
            // K <= M, N so every client can hold >= 1 subchannel per link
            assert!(scn.main_link.subch.len() >= scn.k(), "{name}");
            assert!(scn.fed_link.subch.len() >= scn.k(), "{name}");
        }
    }

    #[test]
    fn many_clients_is_production_scale() {
        let b = ScenarioBuilder::preset("many_clients").unwrap();
        assert_eq!(b.config().system.clients, 1000);
        let scn = b.build().unwrap();
        assert_eq!(scn.k(), 1000);
        assert!(scn.main_link.subch.len() >= scn.k());
        assert_eq!(scn.main_link.client_gain.len(), 1000);
    }

    #[test]
    fn dense_cell_is_dense_and_weak_edge_is_weak() {
        let dense = ScenarioBuilder::preset("dense_cell").unwrap();
        assert!(dense.config().system.clients >= 20);
        let weak = ScenarioBuilder::preset("weak_edge").unwrap();
        let paper = ScenarioBuilder::preset("paper").unwrap();
        assert!(weak.config().system.f_client_hi < paper.config().system.f_client_lo);
    }

    #[test]
    fn gain_sampling_matches_the_legacy_inline_draws_bit_for_bit() {
        // the ChannelState refactor must not move any rng draw: same
        // seed, same gains as two sequential ch.gain() passes
        let scn = ScenarioBuilder::new().seed(123).build().unwrap();
        let s = ScenarioBuilder::new().seed(123).into_config().system;
        let mut rng = Rng::new(s.seed);
        let topo = crate::net::Topology::sample(
            s.clients,
            s.d_max_m,
            s.d_main_m,
            s.f_client_lo,
            s.f_client_hi,
            &mut rng,
        );
        let ch = ChannelModel::new(s.shadowing_db);
        let mut gain_rng = rng.fork(0xC0FFEE);
        let main: Vec<f64> = topo
            .clients
            .iter()
            .map(|c| ch.gain(c.d_main_m, &mut gain_rng))
            .collect();
        let fed: Vec<f64> = topo
            .clients
            .iter()
            .map(|c| ch.gain(c.d_fed_m, &mut gain_rng))
            .collect();
        assert_eq!(scn.main_link.client_gain, main);
        assert_eq!(scn.fed_link.client_gain, fed);
    }

    #[test]
    fn mobile_edge_is_dynamic_and_other_presets_stay_static() {
        let b = ScenarioBuilder::preset("mobile_edge").unwrap();
        let scn = b.build().unwrap();
        assert_eq!(scn.k(), 12);
        assert!(scn.dynamics.rho < 1.0);
        assert!(scn.dynamics.dropout > 0.0 && scn.dynamics.compute_jitter > 0.0);
        assert_eq!(scn.dynamics.strategy, "periodic:5");
        // the sigma sentinel resolves to the scenario's shadowing
        assert_eq!(scn.dynamics.shadow_sigma_db, b.config().system.shadowing_db);
        for name in [
            "paper",
            "dense_cell",
            "weak_edge",
            "asymmetric_links",
            "many_clients",
            "battery_edge",
        ] {
            let scn = ScenarioBuilder::preset(name).unwrap().build().unwrap();
            assert_eq!(scn.dynamics.rho, 1.0, "{name} must stay static");
            assert_eq!(scn.dynamics.dropout, 0.0, "{name} must stay static");
        }
    }

    #[test]
    fn battery_edge_is_energy_weighted_and_other_presets_stay_delay_only() {
        let b = ScenarioBuilder::preset("battery_edge").unwrap();
        let scn = b.build().unwrap();
        assert_eq!(scn.k(), 6);
        assert_eq!(scn.objective.kind, "weighted");
        assert_eq!(scn.objective.lambda, 0.05);
        assert!((scn.p_max_w - 1.0).abs() < 0.01, "1 W-class radio");
        let paper = ScenarioBuilder::preset("paper").unwrap();
        assert!(b.config().system.f_client_hi < paper.config().system.f_client_lo);
        for name in ["paper", "dense_cell", "weak_edge", "asymmetric_links", "mobile_edge"] {
            let scn = ScenarioBuilder::preset(name).unwrap().build().unwrap();
            assert_eq!(scn.objective.kind, "delay", "{name} must stay delay-only");
        }
    }

    #[test]
    fn degenerate_training_and_objective_configs_are_rejected() {
        let err = ScenarioBuilder::new()
            .tweak(|c| c.train.local_steps = 0)
            .build()
            .unwrap_err();
        assert!(format!("{err:#}").contains("local_steps"), "{err:#}");
        assert!(ScenarioBuilder::new().tweak(|c| c.train.batch = 0).build().is_err());
        let err = ScenarioBuilder::new()
            .tweak(|c| c.objective.kind = "typo".to_string())
            .build()
            .unwrap_err();
        assert!(format!("{err:#}").contains("objective"), "{err:#}");
        assert!(ScenarioBuilder::new()
            .tweak(|c| c.objective.zeta = 0.0)
            .build()
            .is_err());
        assert!(ScenarioBuilder::new()
            .tweak(|c| c.objective.zeta = f64::NAN)
            .build()
            .is_err());
        // a bare weighted/budget kind resolves via the config fields
        let scn = ScenarioBuilder::new()
            .tweak(|c| {
                c.objective.kind = "budget".to_string();
                c.objective.budget_j = 1e6;
            })
            .build()
            .unwrap();
        assert_eq!(scn.objective.budget_j, 1e6);
    }

    #[test]
    fn dynamics_setters_apply_and_bad_values_are_rejected() {
        let scn = ScenarioBuilder::new()
            .channel_correlation(0.7)
            .dropout(0.1, 0.6)
            .compute_jitter(0.05)
            .dynamics_seed(99)
            .reopt_strategy("on_degrade:0.3")
            .build()
            .unwrap();
        assert_eq!(scn.dynamics.rho, 0.7);
        assert_eq!(scn.dynamics.dropout, 0.1);
        assert_eq!(scn.dynamics.rejoin, 0.6);
        assert_eq!(scn.dynamics.compute_jitter, 0.05);
        assert_eq!(scn.dynamics.seed, 99);
        assert_eq!(scn.dynamics.strategy, "on_degrade:0.3");

        assert!(ScenarioBuilder::new().channel_correlation(1.5).build().is_err());
        assert!(ScenarioBuilder::new().channel_correlation(-0.1).build().is_err());
        assert!(ScenarioBuilder::new().dropout(2.0, 0.5).build().is_err());
        assert!(ScenarioBuilder::new().compute_jitter(-1.0).build().is_err());
        let err = ScenarioBuilder::new().reopt_strategy("typo").build().unwrap_err();
        assert!(format!("{err:#}").contains("strategy"), "{err:#}");
    }

    #[test]
    fn same_seed_same_scenario_different_seed_differs() {
        let b = ScenarioBuilder::new().seed(9);
        let a = b.build().unwrap();
        let c = b.build().unwrap();
        assert_eq!(a.main_link.client_gain, c.main_link.client_gain);
        let d = ScenarioBuilder::new().seed(10).build().unwrap();
        assert_ne!(a.main_link.client_gain, d.main_link.client_gain);
    }

    #[test]
    fn overrides_apply() {
        let scn = ScenarioBuilder::new()
            .clients(3)
            .bandwidth_hz(250e3, 750e3)
            .subchannels(10, 15)
            .server_compute_hz(1e10)
            .p_max_dbm(30.0)
            .tweak(|c| c.train.batch = 2)
            .build()
            .unwrap();
        assert_eq!(scn.k(), 3);
        assert!((scn.main_link.subch.total_hz() - 250e3).abs() < 1e-6);
        assert!((scn.fed_link.subch.total_hz() - 750e3).abs() < 1e-6);
        assert_eq!(scn.main_link.subch.len(), 10);
        assert_eq!(scn.fed_link.subch.len(), 15);
        assert_eq!(scn.f_server, 1e10);
        assert_eq!(scn.batch, 2);
        assert!((scn.p_max_w - 1.0).abs() < 1e-9);
    }
}
